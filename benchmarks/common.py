"""Benchmark helpers: timing + the `name,us_per_call,derived` CSV contract."""
from __future__ import annotations

import time

ROWS: list[tuple] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn, *, repeats: int = 3, number: int = 1) -> float:
    """Best-of wall time in µs per call."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(number):
            fn()
        best = min(best, (time.perf_counter() - t0) / number)
    return best * 1e6


def section(title: str):
    print(f"\n# --- {title} ---")


def write_json(path: str):
    """Dump every emitted row as JSON (the ``BENCH_*.json`` artifact)."""
    import json

    with open(path, "w") as f:
        json.dump(
            [{"name": n, "us_per_call": u, "derived": d}
             for n, u, d in ROWS],
            f, indent=2)
    print(f"# wrote {len(ROWS)} rows to {path}")
