"""Benchmark helpers: timing + the `name,us_per_call,derived` CSV contract."""
from __future__ import annotations

import sys
import time

ROWS: list[tuple] = []


def peak_rss_mb() -> float:
    """Process peak RSS in MB (``getrusage``; monotone within a run)."""
    import resource

    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    # ru_maxrss is KB on Linux, bytes on macOS.
    return ru / (1024.0 * 1024.0) if sys.platform == "darwin" \
        else ru / 1024.0


def emit(name: str, us_per_call: float, derived: str = ""):
    # Every row carries the peak RSS at emit time so BENCH_*.json
    # doubles as a memory trajectory; rows run in a fixed order, so
    # same-named rows compare apples-to-apples across runs even though
    # the counter is monotone within one process.
    if "peak_rss_mb" not in (derived or ""):
        rss = f"peak_rss_mb={peak_rss_mb():.0f}"
        derived = f"{derived};{rss}" if derived else rss
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")


def timeit(fn, *, repeats: int = 3, number: int = 1) -> float:
    """Best-of wall time in µs per call."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(number):
            fn()
        best = min(best, (time.perf_counter() - t0) / number)
    return best * 1e6


def section(title: str):
    print(f"\n# --- {title} ---")


def parse_derived(derived: str) -> dict:
    """Parse a ``k=v;k=v`` derived field into {k: v-string}."""
    out = {}
    for part in (derived or "").split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k.strip()] = v.strip()
    return out


def compare_rows(baseline: list[dict], fresh,
                 slowdown: float = 2.0,
                 min_base_us: float = 1000.0,
                 mem_factor: float = 2.0,
                 min_base_mb: float = 100.0) -> list[str]:
    """Diff a fresh benchmark run against a committed baseline.

    Returns failure strings for

    * any fresh row whose derived ``drift`` field is nonzero or whose
      ``same_clusters`` field is not 1 (correctness canaries — checked
      whether or not the row exists in the baseline),
    * any baseline row missing from the fresh run (a silently
      disappearing canary must not pass the gate),
    * any row present in both runs whose wall time regressed by more
      than ``slowdown``x (rows under ``min_base_us`` in the baseline
      are skipped — they are dominated by timer noise — as are
      ``*_saved`` rows, whose value is a benefit, not a cost), and
    * any row whose derived ``peak_rss_mb`` regressed by more than
      ``mem_factor``x against a baseline value >= ``min_base_mb`` (the
      memory-regression gate; sub-``min_base_mb`` baselines are
      dominated by the interpreter + JAX runtime footprint).

    ``fresh`` is a list of ``(name, us_per_call, derived)`` tuples (the
    ``ROWS`` accumulator) or baseline-shaped dicts.
    """
    fresh_rows = [
        (r["name"], r["us_per_call"], r.get("derived", ""))
        if isinstance(r, dict) else tuple(r)
        for r in fresh
    ]
    base_by_name = {r["name"]: r for r in baseline}
    failures = []
    fresh_names = {name for name, _, _ in fresh_rows}
    for name in base_by_name:
        if name not in fresh_names:
            failures.append(f"{name}: present in baseline but missing "
                            f"from the fresh run")
    for name, us, derived in fresh_rows:
        d = parse_derived(derived)
        if "drift" in d and float(d["drift"]) != 0:
            failures.append(f"{name}: drift={d['drift']} (expected 0)")
        if "same_clusters" in d and float(d["same_clusters"]) != 1:
            failures.append(
                f"{name}: same_clusters={d['same_clusters']} "
                f"(expected 1)")
        base = base_by_name.get(name)
        if base is None or name.endswith("_saved"):
            continue
        base_d = parse_derived(base.get("derived", ""))
        if "peak_rss_mb" in d and "peak_rss_mb" in base_d:
            base_mb = float(base_d["peak_rss_mb"])
            fresh_mb = float(d["peak_rss_mb"])
            if base_mb >= min_base_mb and fresh_mb > mem_factor * base_mb:
                failures.append(
                    f"{name}: peak_rss {fresh_mb:.0f}MB vs baseline "
                    f"{base_mb:.0f}MB ({fresh_mb / base_mb:.2f}x > "
                    f"{mem_factor:.1f}x)")
        if base["us_per_call"] < min_base_us:
            continue
        ratio = us / base["us_per_call"]
        if ratio > slowdown:
            failures.append(
                f"{name}: {us:.0f}us vs baseline "
                f"{base['us_per_call']:.0f}us ({ratio:.2f}x > "
                f"{slowdown:.1f}x)")
    return failures


def write_json(path: str):
    """Dump every emitted row as JSON (the ``BENCH_*.json`` artifact)."""
    import json

    with open(path, "w") as f:
        json.dump(
            [{"name": n, "us_per_call": u, "derived": d}
             for n, u, d in ROWS],
            f, indent=2)
    print(f"# wrote {len(ROWS)} rows to {path}")
