"""Paper Figs 5-7 (§9.2) + Fig 8 (§9.2.1) + §11: in-memory vs Database
Design 1 vs Design 2 — time vs #notes / #words, memory, and the §11
memory-limit table."""
from __future__ import annotations

import time
import tracemalloc

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, section
from repro.core import lsh, minhash, shingle
from repro.core.bandstore import (
    Design1Store, Design2Store, candidate_pairs_from_store,
)
from repro.data import make_i2b2_like


def _bands_for(notes):
    token_lists = [shingle.tokenize(t) for t in notes]
    packed = shingle.pack_documents(token_lists)
    ng, valid = shingle.ngram_hashes(
        jnp.asarray(packed.tokens), jnp.asarray(packed.lengths), n=8)
    sig = minhash.signatures(ng, valid,
                             jnp.asarray(minhash.default_seeds(100)))
    return np.asarray(lsh.band_values(sig, 2))


def _run_in_memory(bands):
    return lsh.all_candidate_pairs(bands)


def _run_store(bands, store):
    for d in range(len(bands)):
        store.insert_document(d, bands[d])
    store.commit()
    return candidate_pairs_from_store(store, bands.shape[1])


def run():
    section("figs 5-7: time vs #notes, in-memory vs Design 1 vs Design 2")
    for n_notes in (100, 200, 400, 800):
        notes = make_i2b2_like(n_notes, seed=1)
        bands = _bands_for(notes)
        t0 = time.perf_counter()
        p_mem = _run_in_memory(bands)
        t_mem = time.perf_counter() - t0

        s1 = Design1Store()
        t0 = time.perf_counter()
        p_d1 = _run_store(bands, s1)
        t_d1 = time.perf_counter() - t0

        s2 = Design2Store(part_size=max(10, n_notes // 10))
        t0 = time.perf_counter()
        p_d2 = _run_store(bands, s2)
        t_d2 = time.perf_counter() - t0

        assert set(map(tuple, p_d1)) == set(map(tuple, p_mem))
        assert set(map(tuple, p_d2)) == set(map(tuple, p_mem))
        emit(f"designs_n{n_notes}_inmem", t_mem * 1e6, f"pairs={len(p_mem)}")
        emit(f"designs_n{n_notes}_d1", t_d1 * 1e6,
             f"writes={s1.n_writes};bytes={s1.write_bytes}")
        emit(f"designs_n{n_notes}_d2", t_d2 * 1e6,
             f"writes={s2.n_writes};bytes={s2.write_bytes}")


def run_memory():
    section("fig 8 + §11: memory")
    notes = make_i2b2_like(400, seed=2)
    bands = _bands_for(notes)

    for name, fn in [
        ("inmem", lambda: _run_in_memory(bands)),
        ("d1", lambda: _run_store(bands, Design1Store())),
        ("d2", lambda: _run_store(bands, Design2Store(part_size=40))),
    ]:
        tracemalloc.start()
        fn()
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        emit(f"memory_{name}", 0.0, f"peak_bytes={peak}")

    # §11 theoretical limits at 4 GB, b=50 bands, 8-byte values.
    gb4 = 4 * 1024**3
    inmem_limit = gb4 // (50 * 8)
    d1_limit = gb4 // 8
    d2_limit = gb4 // (50 * 8 // 10)
    emit("limit_inmem_notes", 0.0, f"{inmem_limit}")        # ~10M (paper)
    emit("limit_design1_notes", 0.0, f"{d1_limit}")         # ~500M
    emit("limit_design2_notes", 0.0, f"{d2_limit}")         # ~100M


if __name__ == "__main__":
    run()
    run_memory()
