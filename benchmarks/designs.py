"""Paper Figs 5-7 (§9.2) + Fig 8 (§9.2.1) + §11: in-memory vs Database
Design 1 vs Design 2 — time vs #notes / #words, memory, and the §11
memory-limit table.  ``run_sharded`` adds the production-mesh analogue:
the dist_lsh Design-2 shuffle vs the host engine on the same corpus
(verify throughput + edge drift, which must be 0)."""
from __future__ import annotations

import time
import tracemalloc

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, section, timeit
from repro.core import lsh, minhash, shingle
from repro.core.bandstore import (
    Design1Store, Design2Store, SqliteBandStore,
    candidate_pairs_from_store,
)
from repro.data import inject_near_duplicates, make_i2b2_like


def _bands_for(notes):
    token_lists = [shingle.tokenize(t) for t in notes]
    packed = shingle.pack_documents(token_lists)
    ng, valid = shingle.ngram_hashes(
        jnp.asarray(packed.tokens), jnp.asarray(packed.lengths), n=8)
    sig = minhash.signatures(ng, valid,
                             jnp.asarray(minhash.default_seeds(100)))
    return np.asarray(lsh.band_values(sig, 2))


def _run_in_memory(bands):
    return lsh.all_candidate_pairs(bands)


def _run_store(bands, store):
    for d in range(len(bands)):
        store.insert_document(d, bands[d])
    store.commit()
    return candidate_pairs_from_store(store, bands.shape[1])


def run():
    section("figs 5-7: time vs #notes, in-memory vs Design 1 vs Design 2")
    for n_notes in (100, 200, 400, 800):
        notes = make_i2b2_like(n_notes, seed=1)
        bands = _bands_for(notes)
        t0 = time.perf_counter()
        p_mem = _run_in_memory(bands)
        t_mem = time.perf_counter() - t0

        s1 = Design1Store()
        t0 = time.perf_counter()
        p_d1 = _run_store(bands, s1)
        t_d1 = time.perf_counter() - t0

        s2 = Design2Store(part_size=max(10, n_notes // 10))
        t0 = time.perf_counter()
        p_d2 = _run_store(bands, s2)
        t_d2 = time.perf_counter() - t0

        assert set(map(tuple, p_d1)) == set(map(tuple, p_mem))
        assert set(map(tuple, p_d2)) == set(map(tuple, p_mem))
        emit(f"designs_n{n_notes}_inmem", t_mem * 1e6, f"pairs={len(p_mem)}")
        emit(f"designs_n{n_notes}_d1", t_d1 * 1e6,
             f"writes={s1.n_writes};bytes={s1.write_bytes}")
        emit(f"designs_n{n_notes}_d2", t_d2 * 1e6,
             f"writes={s2.n_writes};bytes={s2.write_bytes}")


def run_memory():
    section("fig 8 + §11: memory")
    notes = make_i2b2_like(400, seed=2)
    bands = _bands_for(notes)

    for name, fn in [
        ("inmem", lambda: _run_in_memory(bands)),
        ("d1", lambda: _run_store(bands, Design1Store())),
        ("d2", lambda: _run_store(bands, Design2Store(part_size=40))),
    ]:
        tracemalloc.start()
        fn()
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
        emit(f"memory_{name}", 0.0, f"peak_bytes={peak}")

    # §11 theoretical limits at 4 GB, b=50 bands, 8-byte values.
    gb4 = 4 * 1024**3
    inmem_limit = gb4 // (50 * 8)
    d1_limit = gb4 // 8
    d2_limit = gb4 // (50 * 8 // 10)
    emit("limit_inmem_notes", 0.0, f"{inmem_limit}")        # ~10M (paper)
    emit("limit_design1_notes", 0.0, f"{d1_limit}")         # ~500M
    emit("limit_design2_notes", 0.0, f"{d2_limit}")         # ~100M


def run_band_probe(n_notes: int = 200, n_queries: int = 64):
    """PR 10 disk tier: Bloom-first probe vs the in-memory dict walk.

    Same corpus in both tiers; half the query batch re-probes ingested
    docs (guaranteed hits), half is novel (the Bloom filter's fast-miss
    case).  ``drift`` counts per-query candidate-set mismatches between
    the disk probe and the dict walk and MUST be 0 (the --compare gate
    checks it); ``fp_rate`` is the primary filter's false-positive rate
    over this batch — each FP costs one empty SELECT, never a wrong
    candidate.  Honest framing: at smoke sizes the in-memory walk is
    expected to WIN on latency (DESIGN.md §12 quantifies when); the
    disk row is here for its trajectory and its correctness canary,
    not to beat the dict.
    """
    section("PR 10: Bloom-first disk probe vs in-memory dict walk")
    notes = make_i2b2_like(n_notes, seed=7)
    bands = _bands_for(notes)
    store = SqliteBandStore(num_bands=bands.shape[1])
    store.put_band_rows(np.arange(len(bands), dtype=np.int64), bands)
    store.commit()

    rng = np.random.RandomState(8)
    novel = rng.randint(0, 2**31, size=(n_queries // 2, bands.shape[1],
                                        2)).astype(np.uint32)
    qbands = np.concatenate([bands[: n_queries - len(novel)], novel])

    # The in-memory reference: the view-walk over exported dict maps
    # (what a memory-tier SessionView probe does).
    maps = store.export_maps()

    def dict_walk():
        cands = [set() for _ in range(len(qbands))]
        for j, m in enumerate(maps):
            col = qbands[:, j, :]
            for i in range(len(qbands)):
                olds = m.get((int(col[i, 0]), int(col[i, 1])))
                if olds is not None:
                    cands[i].update(olds)
        return [np.array(sorted(s), dtype=np.int64) for s in cands]

    t_disk = timeit(lambda: store.probe_keys(qbands))
    t_mem = timeit(dict_walk)
    got, _ = store.probe_keys(qbands)
    want = dict_walk()
    drift = sum(int(g.tolist() != w.tolist())
                for g, w in zip(got, want))
    st = store.probe_stats(qbands)
    emit("band_probe_disk", t_disk,
         f"queries={len(qbands)};drift={drift};"
         f"bloom_maybe={st['bloom_maybe']};disk_hits={st['disk_hits']};"
         f"fp_rate={st['fp_rate']:.5f}")
    emit("band_probe_mem", t_mem,
         f"queries={len(qbands)};keys={sum(len(m) for m in maps)};"
         f"disk_vs_mem={t_disk / max(t_mem, 1e-9):.1f}x")


def run_sharded(n_notes: int = 160, n_dups: int = 64):
    """Sharded dist_lsh path vs host engine: verify parity + throughput.

    Runs the two-stage sharded path (on-device prefix prescreen ->
    ShardedEdgeSource -> ShardedEdgeVerifier -> cluster_source) and the
    host engine (BandMatrixSource -> SignatureVerifier) over the same
    corpus, then re-scores every sharded-path evaluated pair with the
    host verifier: the edge-drift count MUST be 0 (same signatures,
    same estimator), and clusters must be identical.
    """
    import jax

    from repro.core.candidates import BandMatrixSource
    from repro.core.dist_lsh import (
        DistLSHConfig, cluster_step_output, docs_mesh, make_dedup_step,
    )
    from repro.core.engine import cluster_source
    from repro.core.verify import ShardedEdgeVerifier, SignatureVerifier

    ndev = len(jax.devices())
    section(f"sharded dist_lsh vs host engine ({ndev} devices)")
    notes = make_i2b2_like(n_notes, seed=3)
    notes, _ = inject_near_duplicates(notes, n_dups, frac_low=0.0,
                                      frac_high=0.01, seed=4)
    token_lists = [shingle.tokenize(t) for t in notes]
    token_lists += [["pad"]] * ((-len(token_lists)) % ndev)
    packed = shingle.pack_documents(token_lists)
    dcfg = DistLSHConfig(edge_threshold=0.75, bucket_slack=16.0)
    step = make_dedup_step(dcfg, docs_mesh())

    step_args = (jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
                 jnp.asarray(minhash.default_seeds(dcfg.num_hashes)))
    # Warm the jit cache: the timed row tracks steady-state step cost
    # across commits; compile time is load-dependent and would make the
    # --compare slowdown gate flaky.
    jax.block_until_ready(step(*step_args)["edges"])
    t0 = time.perf_counter()
    out = step(*step_args)
    jax.block_until_ready(out["edges"])
    t_dev = time.perf_counter() - t0

    t_merge = float("inf")
    for _ in range(3):          # best-of: single shots are noise-bound
        t0 = time.perf_counter()
        res = cluster_step_output(out, dcfg, tree_threshold=0.40,
                                  num_docs=len(notes))
        t_merge = min(t_merge, time.perf_counter() - t0)
    emit("sharded_device_step", t_dev * 1e6,
         f"edges={res.num_edges};overflow={res.overflow};"
         f"retried={int(res.retried)}")
    emit("sharded_verify_throughput", t_merge * 1e6,
         f"pairs={res.stats.pairs_evaluated};"
         f"batches={res.stats.verify_batches};"
         f"pps={res.stats.verify_pairs_per_second:.0f}")

    # Host engine over the step's own signatures (same corpus/estimator).
    sig = np.asarray(out["sig"])[: len(notes)]
    bands = np.asarray(lsh.band_values(jnp.asarray(sig),
                                       dcfg.rows_per_band))
    t_host = float("inf")
    for _ in range(3):          # best-of: single shots are noise-bound
        host_v = SignatureVerifier(sig)
        t0 = time.perf_counter()
        uf_h, st_h, _ = cluster_source(BandMatrixSource(bands), host_v,
                                       dcfg.edge_threshold, 0.40)
        t_host = min(t_host, time.perf_counter() - t0)
    emit("host_engine_verify_throughput", t_host * 1e6,
         f"pairs={st_h.pairs_evaluated};"
         f"pps={st_h.verify_pairs_per_second:.0f}")

    # Edge drift: the sharded stage-2 verifier re-scores its evaluated
    # pairs against the host verifier (same signatures, same backend).
    drift = 0
    if res.pairs:
        pairs = np.array([(a, b) for a, b, _ in res.pairs],
                         dtype=np.int64)
        drift = ShardedEdgeVerifier(sig).drift_count(pairs, host_v)

    def canon(labels):
        # first-occurrence relabeling: partitions compare independently
        # of which member union-by-rank picked as representative
        first = {}
        return [first.setdefault(int(l), i) for i, l in enumerate(labels)]

    same_clusters = int(canon(res.labels()) == canon(uf_h.components()))
    assert drift == 0, f"sharded-vs-host edge drift: {drift}"
    emit("sharded_edge_drift", 0.0,
         f"drift={drift};same_clusters={same_clusters};"
         f"edges={len(res.pairs)}")


def run_band_group_overlap(n_notes: int = 160, n_dups: int = 64,
                           band_groups: int = 5):
    """Band-group streaming: overlapped vs serialized host merge.

    Serialized (``stream=False``) = block until every group's device
    shuffle has finished, then run the host merge (the PR 2 end-of-step
    shape).  Overlapped (``stream=True``) = start the merge immediately
    after dispatch; group g's buffers are materialized only when the
    engine reaches them, so the merge of group g runs while groups
    g+1.. are still shuffling on the device.

    A committed baseline once reported the overlap losing 44%
    (``saved_us=-58703``); the diagnosis is single-shot timing noise —
    at smoke sizes one run swings by tens of ms on a shared runner, so
    every mode here is timed best-of-3.  Measured that way the overlap
    wins ~20-25% even on a 2-core CPU host (the numpy/GIL-bound merge
    overlaps XLA's own compute threads); ``cluster_step_output``'s
    default policy (``dist_lsh._resolve_stream``) streams accordingly,
    and the third timing exercises it.  The headline
    ``band_group_overlap_saved`` row reports the auto policy's
    ``saved_us`` vs the serialized merge.  Cluster results must be
    identical in every mode.
    """
    import jax

    from repro.core.dist_lsh import (
        DistLSHConfig, _resolve_stream, cluster_step_output, docs_mesh,
        make_streamed_dedup_step,
    )

    ndev = len(jax.devices())
    section(f"band-group streamed merge overlap ({ndev} devices, "
            f"G={band_groups})")
    notes = make_i2b2_like(n_notes, seed=5)
    notes, _ = inject_near_duplicates(notes, n_dups, frac_low=0.0,
                                      frac_high=0.01, seed=6)
    token_lists = [shingle.tokenize(t) for t in notes]
    token_lists += [["pad"]] * ((-len(token_lists)) % ndev)
    packed = shingle.pack_documents(token_lists)
    dcfg = DistLSHConfig(edge_threshold=0.75, bucket_slack=16.0,
                         band_groups=band_groups)
    step = make_streamed_dedup_step(dcfg, docs_mesh())
    args = (jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
            jnp.asarray(minhash.default_seeds(dcfg.num_hashes)))

    def block_groups(out):
        jax.block_until_ready([g["edges"] for g in out["groups"]])

    # Warm the compile caches so every timing measures steady state.
    warm = step(*args)
    block_groups(warm)
    cluster_step_output(warm, dcfg, num_docs=len(notes))

    def timed(stream, repeats=3):
        """Best-of-N end-to-end (dispatch + merge) for one stream mode
        — single-shot timings are noise-dominated at smoke sizes."""
        best, res = float("inf"), None
        for _ in range(repeats):
            t0 = time.perf_counter()
            out = step(*args)
            res = cluster_step_output(out, dcfg, num_docs=len(notes),
                                      stream=stream)
            best = min(best, time.perf_counter() - t0)
        return best, res

    t_shuffle = t_merge = t_serialized = float("inf")
    res_serial = None
    for _ in range(3):
        t0 = time.perf_counter()
        out = step(*args)
        block_groups(out)
        ts = time.perf_counter() - t0
        t0 = time.perf_counter()
        res_serial = cluster_step_output(out, dcfg, num_docs=len(notes),
                                         stream=False)
        tm = time.perf_counter() - t0
        if ts + tm < t_serialized:
            t_shuffle, t_merge, t_serialized = ts, tm, ts + tm

    t_overlapped, res_overlap = timed(stream=True)
    t_auto, res_auto = timed(stream=None)

    for res in (res_overlap, res_auto):
        assert np.array_equal(res_serial.labels(), res.labels())
        assert res_serial.pairs == res.pairs

    auto_mode = "stream" if _resolve_stream(None) else "block"
    saved_forced = (t_serialized - t_overlapped) * 1e6
    saved_auto = (t_serialized - t_auto) * 1e6
    emit("band_group_merge_serialized", t_serialized * 1e6,
         f"groups={band_groups};shuffle_us={t_shuffle*1e6:.0f};"
         f"merge_us={t_merge*1e6:.0f}")
    emit("band_group_merge_overlapped", t_overlapped * 1e6,
         f"groups={band_groups};edges={res_overlap.num_edges};"
         f"saved_us={saved_forced:.0f}")
    emit("band_group_merge_auto", t_auto * 1e6,
         f"groups={band_groups};mode={auto_mode};"
         f"saved_us={saved_auto:.0f}")
    # Headline: what the default policy saves vs the serialized merge.
    emit("band_group_overlap_saved", saved_auto,
         f"mode={auto_mode};forced_overlap_saved_us={saved_forced:.0f}")


if __name__ == "__main__":
    run()
    run_memory()
    run_band_probe()
    run_sharded()
    run_band_group_overlap()
