"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and
prints the three-term table.  Does NOT recompile anything.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, section

ART_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")


def load_records(art_dir: str = ART_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(art_dir: str = ART_DIR):
    section("roofline terms per (arch x cell x mesh)")
    recs = load_records(art_dir)
    if not recs:
        emit("roofline_no_artifacts", 0.0,
             "run `python -m repro.launch.dryrun` first")
        return
    for r in recs:
        tag = f"{r['arch']}__{r['cell']}__{r['mesh']}"
        if r["status"] != "ok":
            emit(f"roofline_{tag}", 0.0, r["status"])
            continue
        roof = r["roofline"]
        emit(
            f"roofline_{tag}", roof["step_s"] * 1e6,
            f"compute={roof['compute_s']:.3g}s;"
            f"memory={roof['memory_s']:.3g}s;"
            f"collective={roof['collective_s']:.3g}s;"
            f"bottleneck={roof['bottleneck']};"
            f"frac={roof['roofline_fraction']:.4f};"
            f"flops_eff={roof['flops_efficiency']:.3f}")


if __name__ == "__main__":
    run()
