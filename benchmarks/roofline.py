"""Roofline tables (EXPERIMENTS.md §Roofline).

Two parts:

* the ANALYTIC table from dry-run artifacts (experiments/dryrun/*.json,
  produced by repro.launch.dryrun) — does NOT recompile anything, and
* the MEASURED dedup-ingest roofline (``run_ingest_roofline``) — times
  the staged three-dispatch ingest chain against the fused one-pass
  kernel on this host's devices and reports docs/sec/device alongside
  the analytic HBM bytes each path moves.  Artifact-independent, so it
  runs even when no dry-run artifacts exist.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, section, timeit

ART_DIR = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "experiments", "dryrun")


def load_records(art_dir: str = ART_DIR):
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def ingest_bytes_moved(D: int, L: int, M: int, r: int,
                       tm: int = 128, lb: int | None = None):
    """Analytic HBM traffic (bytes) of one ingest batch: staged vs fused.

    Staged chain round-trips every intermediate through HBM:
      tokens in, n-gram hashes out+in, valid mask out+in,
      signatures out+in, band values out.
    Fused keeps n-gram hashes and the hash cube in VMEM; its only HBM
    traffic is tokens in (re-read once per M-tile, ``ceil(M/tm)``),
    seeds in, signatures out, band values out.

    With ``lb`` (padded byte-matrix width) a third term is returned for
    the byte-ingest path (``kernels/byte_shingle.bytes_to_bands``): raw
    uint8 bytes in, the per-position token/end matrices out+in around
    the compaction, the compacted token matrix (width ``lb//2 + 1``)
    written once and re-read per M-tile by the fused stage, then the
    fused stage's own seed/signature/band traffic.  HOST->DEVICE
    transfer drops 4x PER MATRIX ELEMENT (uint8 vs int32); the net
    measured ratio depends on mean token length and rides the
    ``roofline_ingest_transfer`` bench row.
    """
    b_bands = (M // r) * 2 * 4  # per-doc band bytes (2 fold lanes)
    staged = (D * L * 4            # tokens in (shingle)
              + 2 * D * L * 4      # ngram hashes out + in
              + 2 * D * L         # valid mask out + in (int8)
              + M * 4              # seeds in
              + 2 * D * M * 4      # signatures out + in
              + D * b_bands)       # band values out
    m_tiles = -(-M // tm)
    fused = (m_tiles * D * L * 4   # tokens re-read per M-tile
             + M * 4               # seeds in
             + D * M * 4           # signatures out (once, final flush)
             + D * b_bands)        # band values out
    if lb is None:
        return staged, fused
    lbe = lb + 1                   # +1 emission column (byte_shingle)
    lt = lbe // 2 + 1              # compacted token-matrix width
    byte_fused = (D * lbe          # raw uint8 bytes in (byte kernel)
                  + 2 * D * lbe * 4  # token-hash matrix out + in
                  + 2 * D * lbe * 4  # token-end matrix out + in
                  + D * lt * 4     # compacted tokens out
                  + m_tiles * D * lt * 4  # re-read per fused M-tile
                  + M * 4          # seeds in
                  + D * M * 4      # signatures out
                  + D * b_bands)   # band values out
    return staged, fused, byte_fused


def run_ingest_roofline(D: int = 256, L: int = 512, M: int = 128,
                        n: int = 8, r: int = 2):
    """Measured dedup-ingest roofline: docs/sec/device, staged vs fused."""
    section("measured dedup-ingest roofline (docs/sec/device)")
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    rng = np.random.RandomState(3)
    tokens = rng.randint(0, 2**32, size=(D, L), dtype=np.uint64
                         ).astype(np.uint32)
    lengths = rng.randint(L // 2, L, size=(D,)).astype(np.int32)
    seeds = rng.randint(0, 2**32, size=(M,), dtype=np.uint64
                        ).astype(np.uint32)
    tj, lj, sj = map(jnp.asarray, (tokens, lengths, seeds))

    def staged():
        ng, valid = ops.ngram_hashes(tj, lj, n=n)
        sig = ops.minhash_signatures(ng, valid, sj)
        return jax.block_until_ready(ops.band_values(sig, r))

    def fused():
        return jax.block_until_ready(
            ops.fused_ingest(tj, lj, sj, n=n, r=r)[1])

    staged()  # compile outside the timed region
    fused()
    staged_us = timeit(staged)
    fused_us = timeit(fused)
    # The batch runs on one device; per-device throughput is the
    # number a pod multiplies by its device count.
    docs_fused = D / (fused_us * 1e-6)
    docs_staged = D / (staged_us * 1e-6)
    bytes_staged, bytes_fused = ingest_bytes_moved(D, L, M, r)
    emit(
        "roofline_dedup_ingest", fused_us,
        f"docs_per_s_per_device={docs_fused:.0f};"
        f"staged_docs_per_s_per_device={docs_staged:.0f};"
        f"bytes_hbm_fused={bytes_fused};"
        f"bytes_hbm_staged={bytes_staged};"
        f"traffic_ratio={bytes_staged / bytes_fused:.2f};"
        f"backend={jax.default_backend()};D={D};L={L};M={M}")
    run_transfer_roofline(D=D, M=M, n=n, r=r)


def run_transfer_roofline(D: int = 256, M: int = 128,
                          n: int = 8, r: int = 2):
    """Measured host->device transfer: padded tokens vs raw bytes.

    Same corpus both ways.  The token path stages host tokenize +
    ``pack_documents`` and ships a padded int32 matrix; the byte path
    ships the uint8 byte matrix and lets ``bytes_to_bands`` tokenize on
    device.  ``bytes_h2d_*`` are the actual ``.nbytes`` of what crosses
    PCIe per batch.  Per matrix element the byte path moves 4x less
    (uint8 vs int32); the net ``transfer_ratio`` depends on mean token
    length — word-level corpora average >4 bytes/token, so the decisive
    win there is removing host tokenize from the critical path
    (measured by ``byte_ingest_speedup``), while the transfer win is
    realized for short-token/character-shingle regimes.
    """
    section("measured ingest transfer: int32 tokens vs uint8 bytes")
    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.core import shingle
    from repro.data import make_i2b2_like
    from repro.kernels import ops

    notes = list(make_i2b2_like(D, seed=3))
    rng = np.random.RandomState(3)
    seeds = rng.randint(0, 2**32, size=(M,), dtype=np.uint64
                        ).astype(np.uint32)
    sj = jnp.asarray(seeds)

    toks = [shingle.tokenize(t, do_stem=False) for t in notes]
    lt_bucket = shingle.pow2_bucket(max(len(t) for t in toks))
    ptok = shingle.pack_documents(toks, lt_bucket)
    lb_bucket = shingle.pow2_bucket(
        max(len(t.encode("utf-8")) for t in notes) + 1)
    pbyt = shingle.pack_bytes(notes, lb_bucket)

    # What actually crosses host->device per batch.
    h2d_tok = ptok.tokens.nbytes + ptok.lengths.nbytes
    h2d_byt = pbyt.data.nbytes + pbyt.lengths.nbytes

    def token_path():
        return jax.block_until_ready(
            ops.fused_ingest(jnp.asarray(ptok.tokens),
                             jnp.asarray(ptok.lengths), sj,
                             n=n, r=r)[1])

    def byte_path():
        return jax.block_until_ready(
            ops.bytes_to_bands(jnp.asarray(pbyt.data),
                               jnp.asarray(pbyt.lengths), sj,
                               n=n, r=r)[1])

    token_path()  # compile outside the timed region
    byte_path()
    tok_us = timeit(token_path)
    byt_us = timeit(byte_path)
    _, hbm_tok, hbm_byt = ingest_bytes_moved(
        D, lt_bucket, M, r, lb=lb_bucket)
    emit(
        "roofline_ingest_transfer", byt_us,
        f"token_us={tok_us:.1f};"
        f"bytes_h2d_tokens={h2d_tok};"
        f"bytes_h2d_bytes={h2d_byt};"
        f"transfer_ratio={h2d_tok / h2d_byt:.2f};"
        f"bytes_hbm_token_fused={hbm_tok};"
        f"bytes_hbm_byte_fused={hbm_byt};"
        f"backend={jax.default_backend()};D={D};M={M}")


def run(art_dir: str = ART_DIR):
    section("roofline terms per (arch x cell x mesh)")
    recs = load_records(art_dir)
    if not recs:
        emit("roofline_no_artifacts", 0.0,
             "run `python -m repro.launch.dryrun` first")
    for r in recs:
        tag = f"{r['arch']}__{r['cell']}__{r['mesh']}"
        if r["status"] != "ok":
            emit(f"roofline_{tag}", 0.0, r["status"])
            continue
        roof = r["roofline"]
        emit(
            f"roofline_{tag}", roof["step_s"] * 1e6,
            f"compute={roof['compute_s']:.3g}s;"
            f"memory={roof['memory_s']:.3g}s;"
            f"collective={roof['collective_s']:.3g}s;"
            f"bottleneck={roof['bottleneck']};"
            f"frac={roof['roofline_fraction']:.4f};"
            f"flops_eff={roof['flops_efficiency']:.3f}")
    # The measured ingest roofline is artifact-independent: report it on
    # BOTH paths (previously the no-artifact path emitted only the
    # placeholder row and no roofline at all).
    run_ingest_roofline()


if __name__ == "__main__":
    run()
