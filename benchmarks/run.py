"""Benchmark entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only accuracy,kernels
  PYTHONPATH=src python -m benchmarks.run --smoke --json BENCH_smoke.json
  PYTHONPATH=src python -m benchmarks.run --compare BENCH_smoke.json
"""
from __future__ import annotations

import argparse
import os
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: accuracy,designs,"
                         "clustering,scale,kernels,roofline,serving")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-size CI smoke: sharded-vs-host parity, "
                         "verify throughput, band-group merge overlap; "
                         "writes BENCH_smoke.json at the repo root "
                         "unless --json overrides")
    ap.add_argument("--json", default=None,
                    help="also write emitted rows to this JSON file "
                         "(the BENCH_*.json perf-trajectory artifact)")
    ap.add_argument("--compare", default=None, metavar="BASELINE",
                    help="run the smoke set and diff it against a "
                         "committed BENCH_*.json baseline: exits "
                         "nonzero on a >2x slowdown of any comparable "
                         "row, a >2x peak_rss_mb memory regression, "
                         "or any derived drift != 0 / "
                         "same_clusters != 1 field (the bench-smoke "
                         "CI regression gate)")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    print("name,us_per_call,derived")
    t0 = time.perf_counter()

    if args.smoke or args.compare:
        import json

        from benchmarks import designs
        from benchmarks.common import ROWS, compare_rows, write_json

        baseline = None
        if args.compare:
            with open(args.compare) as f:
                baseline = json.load(f)

        designs.run_sharded(n_notes=96, n_dups=32)
        designs.run_band_group_overlap(n_notes=96, n_dups=32)
        # PR 10 disk tier: Bloom-first probe throughput + FP rate vs
        # the in-memory dict walk (drift must stay 0).
        designs.run_band_probe(n_notes=96, n_queries=48)
        from benchmarks import kernels, roofline

        # Fused-ingest perf gate: drift must stay 0 (bit parity with
        # the staged chain) and the fused wall must not regress >2x.
        # byte_ingest holds the same contract for the bytes->bands
        # path vs host tokenize + fused; the ingest roofline also
        # emits the measured host->device transfer row.
        kernels.run_fused_ingest()
        kernels.run_byte_ingest()
        roofline.run_ingest_roofline()
        from benchmarks import serving_dedup

        # Online query service gate: p50/p99 latency + QPS rows, with
        # the microbatch==sequential parity canary (same_clusters).
        serving_dedup.run_smoke()
        # The smoke artifact is committed at the repo root so the perf
        # trajectory accumulates in-tree, not only as a CI artifact.
        write_json(args.json or os.path.join(REPO_ROOT,
                                             "BENCH_smoke.json"))
        print(f"\n# benchmarks completed in {time.perf_counter()-t0:.1f}s")
        if baseline is not None:
            failures = compare_rows(baseline, ROWS)
            if failures:
                print(f"# REGRESSION vs {args.compare}:")
                for msg in failures:
                    print(f"#   {msg}")
                sys.exit(1)
            print(f"# no regression vs {args.compare} "
                  f"({len(baseline)} baseline rows)")
        return

    if want("accuracy"):
        from benchmarks import accuracy
        accuracy.run()
        accuracy.run_time_vs_bands()
    if want("designs"):
        from benchmarks import designs
        designs.run()
        designs.run_memory()
        designs.run_band_probe()
        designs.run_sharded()
    if want("clustering"):
        from benchmarks import clustering
        clustering.run()
        clustering.run_verify_throughput()
        clustering.run_engine_end_to_end()
        clustering.run_louvain()
    if want("scale"):
        from benchmarks import scale
        scale.run()
    if want("kernels"):
        from benchmarks import kernels
        kernels.run()
    if want("roofline"):
        from benchmarks import roofline
        roofline.run()
    if want("serving"):
        from benchmarks import serving_dedup
        serving_dedup.run()

    if args.json:
        from benchmarks.common import write_json

        write_json(args.json)
    print(f"\n# benchmarks completed in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
