"""Benchmark entry point — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (the harness contract).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only accuracy,kernels
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: accuracy,designs,"
                         "clustering,scale,kernels,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    print("name,us_per_call,derived")
    t0 = time.perf_counter()

    if want("accuracy"):
        from benchmarks import accuracy
        accuracy.run()
        accuracy.run_time_vs_bands()
    if want("designs"):
        from benchmarks import designs
        designs.run()
        designs.run_memory()
    if want("clustering"):
        from benchmarks import clustering
        clustering.run()
        clustering.run_verify_throughput()
        clustering.run_engine_end_to_end()
        clustering.run_louvain()
    if want("scale"):
        from benchmarks import scale
        scale.run()
    if want("kernels"):
        from benchmarks import kernels
        kernels.run()
    if want("roofline"):
        from benchmarks import roofline
        roofline.run()

    print(f"\n# benchmarks completed in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
