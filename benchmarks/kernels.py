"""Per-kernel µs/call (interpret mode on CPU) + allclose spot-check.

On-TPU these kernels lower via Mosaic; interpret mode here validates the
kernel bodies and gives relative cost shapes, not TPU wall time.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, section, timeit
from repro.kernels import ops, ref


def run():
    section("kernels: pallas(interpret) vs jnp ref, µs/call")
    rng = np.random.RandomState(0)
    D, L, M = 128, 512, 128
    tokens = rng.randint(0, 2**32, size=(D, L), dtype=np.uint64
                         ).astype(np.uint32)
    lengths = rng.randint(L // 2, L, size=(D,)).astype(np.int32)
    seeds = rng.randint(0, 2**32, size=(M,), dtype=np.uint64
                        ).astype(np.uint32)
    tj, lj, sj = map(jnp.asarray, (tokens, lengths, seeds))

    ng_k, valid = ops.ngram_hashes(tj, lj, n=8)
    ng_r, _ = ref.ngram_hashes(tj, lj, n=8)
    vm = np.asarray(valid)
    assert np.array_equal(np.asarray(ng_k)[vm], np.asarray(ng_r)[vm])
    for name, fn in [
        ("ngram_pallas", lambda: jax.block_until_ready(
            ops.ngram_hashes(tj, lj, n=8)[0])),
        ("ngram_ref", lambda: jax.block_until_ready(
            ref.ngram_hashes(tj, lj, n=8)[0])),
    ]:
        emit(name, timeit(fn), f"D={D};L={L}")

    sig_k = ops.minhash_signatures(ng_k, valid, sj)
    sig_r = ref.minhash_signatures(ng_k, valid, sj)
    assert np.array_equal(np.asarray(sig_k), np.asarray(sig_r))
    for name, fn in [
        ("minhash_pallas", lambda: jax.block_until_ready(
            ops.minhash_signatures(ng_k, valid, sj))),
        ("minhash_ref", lambda: jax.block_until_ready(
            ref.minhash_signatures(ng_k, valid, sj))),
    ]:
        emit(name, timeit(fn), f"D={D};L={L};M={M}")

    for name, fn in [
        ("bandfold_pallas", lambda: jax.block_until_ready(
            ops.band_values(sig_k, 2))),
        ("bandfold_ref", lambda: jax.block_until_ready(
            ref.band_values(sig_k, 2))),
    ]:
        emit(name, timeit(fn), f"D={D};b={M//2}")

    a = jnp.asarray(np.asarray(sig_k)[rng.randint(0, D, 512)])
    b = jnp.asarray(np.asarray(sig_k)[rng.randint(0, D, 512)])
    for name, fn in [
        ("sigjaccard_pallas", lambda: jax.block_until_ready(
            ops.pair_estimate(a, b))),
        ("sigjaccard_ref", lambda: jax.block_until_ready(
            ref.pair_estimate(a, b))),
    ]:
        emit(name, timeit(fn), "P=512")

    run_fused_ingest()
    run_byte_ingest()


def run_fused_ingest(D: int = 256, L: int = 512, M: int = 128,
                     n: int = 8, r: int = 2):
    """Fused one-pass ingest vs the staged three-dispatch chain.

    ``us_per_call`` is the fused wall time; ``derived`` carries the
    staged wall, the speedup, and a ``drift`` canary (#mismatching
    uint32 words across signatures AND band values vs staged — the
    bit-parity contract, gated to 0 by ``compare_rows``).
    """
    section("fused ingest: one-pass shingle->minhash->fold vs staged")
    rng = np.random.RandomState(7)
    tokens = rng.randint(0, 2**32, size=(D, L), dtype=np.uint64
                         ).astype(np.uint32)
    lengths = rng.randint(L // 2, L, size=(D,)).astype(np.int32)
    seeds = rng.randint(0, 2**32, size=(M,), dtype=np.uint64
                        ).astype(np.uint32)
    tj, lj, sj = map(jnp.asarray, (tokens, lengths, seeds))

    def staged():
        ng, valid = ops.ngram_hashes(tj, lj, n=n)
        sig = ops.minhash_signatures(ng, valid, sj)
        return jax.block_until_ready(ops.band_values(sig, r))

    def fused():
        return jax.block_until_ready(ops.fused_ingest(tj, lj, sj,
                                                      n=n, r=r)[1])

    bands_s = np.asarray(staged())
    ng, valid = ops.ngram_hashes(tj, lj, n=n)
    sig_s = np.asarray(ops.minhash_signatures(ng, valid, sj))
    sig_f, bands_f, _ = ops.fused_ingest(tj, lj, sj, n=n, r=r)
    drift = int((np.asarray(sig_f) != sig_s).sum()
                + (np.asarray(bands_f) != bands_s).sum())

    staged_us = timeit(staged)
    fused_us = timeit(fused)
    emit("fused_ingest_speedup", fused_us,
         f"staged_us={staged_us:.1f};"
         f"speedup={staged_us / max(fused_us, 1e-9):.2f};"
         f"drift={drift};D={D};L={L};M={M}")


def run_byte_ingest(D: int = 256, M: int = 128, n: int = 8, r: int = 2):
    """Zero-copy bytes->bands vs the host-tokenize + fused-ingest path.

    Both sides run their FULL ingest honestly: the host side pays
    tokenize + token_ids + pack + fused dispatch, the byte side pays
    pack_bytes + the ``bytes_to_bands`` chain.  ``drift`` counts
    mismatching uint32 words across signatures AND band values (the
    bit-parity contract for no-stem tokenization, gated to 0 by
    ``compare_rows``).
    """
    section("byte ingest: device bytes->bands vs host tokenize + fused")
    from repro.core import shingle
    from repro.data import make_i2b2_like

    notes = list(make_i2b2_like(D, seed=11))
    rng = np.random.RandomState(11)
    seeds = rng.randint(0, 2**32, size=(M,), dtype=np.uint64
                        ).astype(np.uint32)
    sj = jnp.asarray(seeds)

    def host_path():
        toks = [shingle.tokenize(t, do_stem=False) for t in notes]
        lt_bucket = shingle.pow2_bucket(max(len(t) for t in toks))
        packed = shingle.pack_documents(toks, lt_bucket)
        return ops.fused_ingest(jnp.asarray(packed.tokens),
                                jnp.asarray(packed.lengths), sj,
                                n=n, r=r)

    def byte_path():
        lb_bucket = shingle.pow2_bucket(
            max(len(t.encode("utf-8")) for t in notes) + 1)
        packed = shingle.pack_bytes(notes, lb_bucket)
        return ops.bytes_to_bands(jnp.asarray(packed.data),
                                  jnp.asarray(packed.lengths), sj,
                                  n=n, r=r)

    sig_h, bands_h, _ = host_path()
    sig_b, bands_b, _ = byte_path()
    drift = int((np.asarray(sig_b) != np.asarray(sig_h)).sum()
                + (np.asarray(bands_b) != np.asarray(bands_h)).sum())

    host_us = timeit(lambda: jax.block_until_ready(host_path()[1]))
    byte_us = timeit(lambda: jax.block_until_ready(byte_path()[1]))
    emit("byte_ingest_speedup", byte_us,
         f"host_us={host_us:.1f};"
         f"speedup={host_us / max(byte_us, 1e-9):.2f};"
         f"drift={drift};D={D};M={M}")


if __name__ == "__main__":
    run()
