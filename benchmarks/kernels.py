"""Per-kernel µs/call (interpret mode on CPU) + allclose spot-check.

On-TPU these kernels lower via Mosaic; interpret mode here validates the
kernel bodies and gives relative cost shapes, not TPU wall time.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, section, timeit
from repro.kernels import ops, ref


def run():
    section("kernels: pallas(interpret) vs jnp ref, µs/call")
    rng = np.random.RandomState(0)
    D, L, M = 128, 512, 128
    tokens = rng.randint(0, 2**32, size=(D, L), dtype=np.uint64
                         ).astype(np.uint32)
    lengths = rng.randint(L // 2, L, size=(D,)).astype(np.int32)
    seeds = rng.randint(0, 2**32, size=(M,), dtype=np.uint64
                        ).astype(np.uint32)
    tj, lj, sj = map(jnp.asarray, (tokens, lengths, seeds))

    ng_k, valid = ops.ngram_hashes(tj, lj, n=8)
    ng_r, _ = ref.ngram_hashes(tj, lj, n=8)
    vm = np.asarray(valid)
    assert np.array_equal(np.asarray(ng_k)[vm], np.asarray(ng_r)[vm])
    for name, fn in [
        ("ngram_pallas", lambda: jax.block_until_ready(
            ops.ngram_hashes(tj, lj, n=8)[0])),
        ("ngram_ref", lambda: jax.block_until_ready(
            ref.ngram_hashes(tj, lj, n=8)[0])),
    ]:
        emit(name, timeit(fn), f"D={D};L={L}")

    sig_k = ops.minhash_signatures(ng_k, valid, sj)
    sig_r = ref.minhash_signatures(ng_k, valid, sj)
    assert np.array_equal(np.asarray(sig_k), np.asarray(sig_r))
    for name, fn in [
        ("minhash_pallas", lambda: jax.block_until_ready(
            ops.minhash_signatures(ng_k, valid, sj))),
        ("minhash_ref", lambda: jax.block_until_ready(
            ref.minhash_signatures(ng_k, valid, sj))),
    ]:
        emit(name, timeit(fn), f"D={D};L={L};M={M}")

    for name, fn in [
        ("bandfold_pallas", lambda: jax.block_until_ready(
            ops.band_values(sig_k, 2))),
        ("bandfold_ref", lambda: jax.block_until_ready(
            ref.band_values(sig_k, 2))),
    ]:
        emit(name, timeit(fn), f"D={D};b={M//2}")

    a = jnp.asarray(np.asarray(sig_k)[rng.randint(0, D, 512)])
    b = jnp.asarray(np.asarray(sig_k)[rng.randint(0, D, 512)])
    for name, fn in [
        ("sigjaccard_pallas", lambda: jax.block_until_ready(
            ops.pair_estimate(a, b))),
        ("sigjaccard_ref", lambda: jax.block_until_ready(
            ref.pair_estimate(a, b))),
    ]:
        emit(name, timeit(fn), "P=512")

    run_fused_ingest()


def run_fused_ingest(D: int = 256, L: int = 512, M: int = 128,
                     n: int = 8, r: int = 2):
    """Fused one-pass ingest vs the staged three-dispatch chain.

    ``us_per_call`` is the fused wall time; ``derived`` carries the
    staged wall, the speedup, and a ``drift`` canary (#mismatching
    uint32 words across signatures AND band values vs staged — the
    bit-parity contract, gated to 0 by ``compare_rows``).
    """
    section("fused ingest: one-pass shingle->minhash->fold vs staged")
    rng = np.random.RandomState(7)
    tokens = rng.randint(0, 2**32, size=(D, L), dtype=np.uint64
                         ).astype(np.uint32)
    lengths = rng.randint(L // 2, L, size=(D,)).astype(np.int32)
    seeds = rng.randint(0, 2**32, size=(M,), dtype=np.uint64
                        ).astype(np.uint32)
    tj, lj, sj = map(jnp.asarray, (tokens, lengths, seeds))

    def staged():
        ng, valid = ops.ngram_hashes(tj, lj, n=n)
        sig = ops.minhash_signatures(ng, valid, sj)
        return jax.block_until_ready(ops.band_values(sig, r))

    def fused():
        return jax.block_until_ready(ops.fused_ingest(tj, lj, sj,
                                                      n=n, r=r)[1])

    bands_s = np.asarray(staged())
    ng, valid = ops.ngram_hashes(tj, lj, n=n)
    sig_s = np.asarray(ops.minhash_signatures(ng, valid, sj))
    sig_f, bands_f, _ = ops.fused_ingest(tj, lj, sj, n=n, r=r)
    drift = int((np.asarray(sig_f) != sig_s).sum()
                + (np.asarray(bands_f) != bands_s).sum())

    staged_us = timeit(staged)
    fused_us = timeit(fused)
    emit("fused_ingest_speedup", fused_us,
         f"staged_us={staged_us:.1f};"
         f"speedup={staged_us / max(fused_us, 1e-9):.2f};"
         f"drift={drift};D={D};L={L};M={M}")


if __name__ == "__main__":
    run()
