"""Long-ingest soak: a bounded-memory DedupSession under a fixed budget.

Streams ``--steps`` chunks through one ``DedupSession`` with a
``RetentionPolicy`` (rows evict down to cluster representatives + an LRU
window, band-index keys compact into Bloom filters) and checks the two
properties the retention layer promises (DESIGN.md §7):

* **Bounded memory** — peak RSS (``resource.getrusage``) stays under a
  ceiling derived from the first-step footprint plus a fixed headroom
  (or an explicit ``--rss-ceiling-mb``).  The per-step retained-row and
  RSS curves go into the JSON report so a regression is diagnosable.
* **No cluster drift** — the corpus is built so every duplicate recurs
  within the retention window; the final clustering must be IDENTICAL
  (same labels, bit-identical shared sims) to an unevicted append-only
  session fed the same chunks with the same refine cadence.

Exits nonzero on a ceiling or parity violation — the CI ``soak`` job
runs ``--steps 20 --retain-budget small`` and uploads the report.

  PYTHONPATH=src python -m benchmarks.soak --steps 20 \
      --retain-budget small --json soak_report.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks.common import peak_rss_mb as rss_mb


def make_chunks(steps: int, fresh_per_step: int, dups_per_step: int,
                recur_steps: int, seed: int = 0):
    """Chunk stream whose duplicates all recur within ``recur_steps``.

    Each step carries ``fresh_per_step`` new notes plus
    ``dups_per_step`` near-exact copies of notes from the previous
    ``recur_steps`` steps — the regime where bounded retention promises
    exact parity with the unevicted session.
    """
    import numpy as np

    from repro.data import inject_near_duplicates, make_i2b2_like

    rng = np.random.RandomState(seed)
    chunks, recent = [], []
    for t in range(steps):
        fresh = make_i2b2_like(fresh_per_step, seed=seed + 1000 + t)
        chunk = list(fresh)
        pool = [n for c in recent[-recur_steps:] for n in c]
        if pool and dups_per_step:
            picks = rng.choice(len(pool), size=dups_per_step)
            dup_src = [pool[i] for i in picks]
            # Same near-exact mutation the repo's corpus helper uses.
            mutated, _ = inject_near_duplicates(
                dup_src, len(dup_src), frac_low=0.0, frac_high=0.005,
                seed=seed + 2000 + t)
            chunk.extend(mutated[len(dup_src):])
        recent.append(fresh)
        chunks.append(chunk)
    return chunks


def run_session(cfg, chunks, retention, refine_every,
                store_path=":memory:"):
    from repro.core import DedupSession

    sess = DedupSession(cfg, backend="host", retention=retention,
                        store_path=store_path)
    # Disk-tier sessions additionally log the sqlite file size per step
    # (PRAGMA page_count * page_size) — the soak's disk-plateau gate.
    file_bytes = getattr(sess.band_index, "file_size_bytes", None)
    curve = []
    for t, chunk in enumerate(chunks):
        snap = sess.ingest(chunk)
        if retention is None and refine_every and \
                (t + 1) % refine_every == 0:
            # The unevicted reference refines on the same cadence the
            # policy auto-triggers, so the comparison is like-for-like.
            snap = sess.refine()
        point = {
            "step": t + 1,
            "n_docs": snap.n_docs,
            "retained_rows": snap.retained_rows,
            "evicted": snap.evicted,
            "filter_only_hits": snap.filter_only_hits,
            "refine_merges": snap.refine_merges,
            "clusters": snap.num_clusters,
            "rss_mb": round(rss_mb(), 1),
        }
        if file_bytes is not None:
            point["store_file_kb"] = round(file_bytes() / 1024.0, 1)
        curve.append(point)
    return sess, snap, curve


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--fresh-per-step", type=int, default=40)
    ap.add_argument("--dups-per-step", type=int, default=16)
    ap.add_argument("--recur-steps", type=int, default=2,
                    help="duplicates copy notes at most this many "
                         "steps back (must fit the retention window)")
    ap.add_argument("--retain-budget", default="small",
                    choices=("small", "medium", "unlimited"))
    ap.add_argument("--key-budget", type=int, default=0,
                    help="override the preset's per-band key budget so "
                         "the lossy compaction path is exercised at "
                         "soak scale (0 = keep the preset's; the CI "
                         "job passes 256 and then REQUIRES compaction)")
    ap.add_argument("--refine-every", type=int, default=5)
    ap.add_argument("--store", default=None,
                    choices=("memory", "sqlite"),
                    help="band-index tier for the bounded session "
                         "(default: $REPRO_STORE_BACKEND or memory). "
                         "sqlite additionally gates the disk-plateau "
                         "property: the database file must stop "
                         "growing once retention reaches steady state")
    ap.add_argument("--store-path", default=":memory:",
                    help="sqlite database path for the bounded session "
                         "(the reference session always uses its own "
                         ":memory: store)")
    ap.add_argument("--rss-ceiling-mb", type=float, default=0.0,
                    help="absolute peak-RSS ceiling; 0 derives "
                         "first-step RSS + --rss-headroom-mb")
    ap.add_argument("--rss-headroom-mb", type=float, default=512.0)
    ap.add_argument("--json", default=None,
                    help="write the report here (CI artifact)")
    args = ap.parse_args(argv)

    from repro.core import DedupConfig, RetentionPolicy

    from dataclasses import replace as dc_replace

    cfg = DedupConfig(exact_verification=False,
                      **({"store": args.store} if args.store else {}))
    policy = RetentionPolicy.preset(args.retain_budget,
                                    refine_every=args.refine_every)
    if args.key_budget:
        policy = dc_replace(policy, band_key_budget=args.key_budget)
    window_docs = args.recur_steps * (args.fresh_per_step
                                      + args.dups_per_step)
    if policy.lru_window < window_docs:
        print(f"# note: recurrence window {window_docs} docs exceeds "
              f"the {args.retain_budget!r} LRU window "
              f"{policy.lru_window}; parity relies on representative "
              f"band keys")

    chunks = make_chunks(args.steps, args.fresh_per_step,
                         args.dups_per_step, args.recur_steps)

    t0 = time.perf_counter()
    sess, snap, curve = run_session(cfg, chunks, policy,
                                    args.refine_every,
                                    store_path=args.store_path)
    bounded_s = time.perf_counter() - t0
    peak_mb = rss_mb()   # recorded BEFORE the reference run inflates it
    ceiling = args.rss_ceiling_mb or (curve[0]["rss_mb"]
                                      + args.rss_headroom_mb)

    t0 = time.perf_counter()
    _, ref_snap, _ = run_session(cfg, chunks, None, args.refine_every)
    reference_s = time.perf_counter() - t0

    import numpy as np

    parity = bool(np.array_equal(snap.labels, ref_snap.labels))
    ref_sims = {(a, b): s for a, b, s in ref_snap.pairs}
    shared = [(a, b, s) for a, b, s in snap.pairs if (a, b) in ref_sims]
    sims_ok = all(s == ref_sims[(a, b)] for a, b, s in shared)
    failures = []
    if peak_mb > ceiling:
        failures.append(f"peak RSS {peak_mb:.0f}MB exceeds ceiling "
                        f"{ceiling:.0f}MB")
    if not parity:
        failures.append("final clusters drifted from the unevicted "
                        "reference session")
    if not shared:
        failures.append("no shared verified pairs between bounded and "
                        "reference runs — degenerate soak config "
                        "(raise --dups-per-step / --steps)")
    elif not sims_ok:
        failures.append("shared verified sims are not bit-identical "
                        "to the reference")
    if snap.evicted == 0:
        failures.append("soak never evicted a row — the budget did "
                        "not exercise retention")
    if args.key_budget and sess.band_index.compacted_keys == 0:
        # Only an explicit override promises compaction at this scale;
        # preset budgets may legitimately never fill on a short soak.
        failures.append("soak never compacted a band key — the lossy "
                        "Bloom path is not being gated (shrink "
                        "--key-budget or scale the corpus)")
    ratios = [p["store_file_kb"] / max(1, p["retained_rows"])
              for p in curve if "store_file_kb" in p]
    if ratios:
        # Disk plateau: the retained-row count itself grows with fresh
        # unique notes (each stays a root forever), so the file cannot
        # plateau in absolute bytes on this corpus — the property
        # compaction actually promises is that disk tracks RETAINED
        # state, not ingest history.  Gate the normalized curve:
        # KB per retained row must stop growing over the final quarter
        # of steps (evicted rows are rewritten away, budget-compacted
        # keys are deleted, and sqlite reuses the freed pages).
        tail_at = max(0, (3 * len(ratios)) // 4 - 1)
        if ratios[-1] > 1.10 * ratios[tail_at]:
            failures.append(
                f"sqlite store kept growing per retained row after "
                f"compaction: {ratios[tail_at]:.2f}KB/row at step "
                f"{tail_at + 1} -> {ratios[-1]:.2f}KB/row at step "
                f"{len(ratios)} (> 10% tail growth)")

    report = {
        "steps": args.steps,
        "store": cfg.store,
        "retain_budget": args.retain_budget,
        "refine_every": args.refine_every,
        "n_docs": snap.n_docs,
        "clusters": snap.num_clusters,
        "retained_rows": snap.retained_rows,
        "evicted": snap.evicted,
        "filter_only_hits": snap.filter_only_hits,
        "refine_merges": snap.refine_merges,
        "band_index": sess.band_index.stats(),
        "peak_rss_mb": round(peak_mb, 1),
        "rss_ceiling_mb": round(ceiling, 1),
        "cluster_parity": parity,
        "sims_bit_identical": sims_ok,
        "bounded_seconds": round(bounded_s, 2),
        "reference_seconds": round(reference_s, 2),
        "curve": curve,
        "failures": failures,
    }
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {args.json}")

    print(f"soak: {snap.n_docs} docs in {args.steps} steps, "
          f"{snap.retained_rows} rows retained ({snap.evicted} evicted, "
          f"{snap.filter_only_hits} filter-only hits, "
          f"{snap.refine_merges} refine merges), peak RSS "
          f"{peak_mb:.0f}MB / ceiling {ceiling:.0f}MB, "
          f"parity={parity}, {bounded_s:.1f}s "
          f"(reference {reference_s:.1f}s)")
    for step in curve:
        print(f"  step {step['step']:3d}: {step['n_docs']:5d} docs, "
              f"{step['retained_rows']:5d} retained, "
              f"rss {step['rss_mb']:.0f}MB")
    if failures:
        for msg in failures:
            print(f"# SOAK FAILURE: {msg}")
        return 1
    print("# soak ok")
    return 0


if __name__ == "__main__":
    sys.exit(main())
