"""Online dedup query service: latency + sustained-QPS benchmark.

Measures the PR 7 read path (DESIGN.md §9) over a warm session:

* ``dedup_query_p50_ms`` — single-document synchronous query latency
  (fused ingest of one doc -> band probe -> batched verify), p50 as
  the row wall with p50/p99 in the derived field;
* ``dedup_query_qps`` — sustained throughput of the microbatched
  ``submit``/``step`` loop, with a ``same_clusters`` parity canary
  (microbatched verdicts must equal sequential ones) that joins the
  ``--compare`` regression gate.

  PYTHONPATH=src python -m benchmarks.serving_dedup          # full
  PYTHONPATH=src python -m benchmarks.serving_dedup --smoke  # CI sizes
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, section


def _warm_service(n_notes: int, n_dups: int, *, max_batch: int = 32):
    from repro.core import DedupConfig, DedupQueryService, DedupSession
    from repro.data import inject_near_duplicates, make_i2b2_like

    notes = make_i2b2_like(n_notes, seed=0)
    notes, _ = inject_near_duplicates(notes, n_dups, seed=1)
    sess = DedupSession(DedupConfig(exact_verification=False),
                        backend="host")
    sess.ingest(notes)
    svc = DedupQueryService(sess, max_batch=max_batch)
    svc.query([notes[0]])        # publish the view + jit/alloc warmup
    return svc, notes


def run_queries(n_notes: int = 240, n_dups: int = 120,
                n_latency: int = 48, n_qps: int = 192,
                max_batch: int = 32) -> None:
    """Emit the p50/p99 latency and microbatched QPS rows."""
    section("serving: online dedup query service")
    svc, notes = _warm_service(n_notes, n_dups, max_batch=max_batch)
    rng = np.random.default_rng(0)

    # Single-document synchronous latency (the interactive path).
    lat_docs = [notes[i] for i in
                rng.integers(0, len(notes), size=n_latency)]
    lats = []
    for doc in lat_docs:
        t0 = time.perf_counter()
        svc.query([doc])
        lats.append(time.perf_counter() - t0)
    lats_us = np.array(lats) * 1e6
    p50, p99 = np.percentile(lats_us, [50, 99])
    emit("dedup_query_p50_ms", float(p50),
         f"p50_ms={p50 / 1e3:.3f};p99_ms={p99 / 1e3:.3f};"
         f"n={n_latency}")

    # Microbatched sustained throughput + sequential-parity canary.
    qps_docs = [notes[i] for i in
                rng.integers(0, len(notes), size=n_qps)]
    sequential = svc.query(qps_docs)
    rids = [svc.submit(d) for d in qps_docs]
    t0 = time.perf_counter()
    finished = svc.run_until_drained()
    elapsed = time.perf_counter() - t0
    by_rid = {r.rid: r.result for r in finished}
    same = int([by_rid[r] for r in rids] == sequential)
    qps = n_qps / elapsed
    emit("dedup_query_qps", elapsed / n_qps * 1e6,
         f"qps={qps:.0f};same_clusters={same};"
         f"batches={svc.stats.microbatches};n={n_qps}")


def run_smoke() -> None:
    """CI-sized rows for BENCH_smoke.json (seconds, not minutes)."""
    run_queries(n_notes=96, n_dups=32, n_latency=24, n_qps=96,
                max_batch=32)


def run() -> None:
    run_queries()


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if args.smoke:
        run_smoke()
    else:
        run()
