"""Paper Figs 1-3: false positives (candidates) & false negatives vs
(b, r) at Jaccard thresholds 0.2 / 0.3 / 0.4, on the §9.1 test set
(521 notes + 10 near-duplicates at 10% word change).

Also Fig 4: in-memory LSH time vs number of hash functions.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, section, timeit
from repro.core import jaccard, lsh, minhash, shingle
from repro.data import accuracy_testset


def _prepare(seed=0):
    notes, srcs = accuracy_testset(seed=seed)
    token_lists = [shingle.tokenize(t) for t in notes]
    sets = [shingle.ngram_set(t, 8) for t in token_lists]
    packed = shingle.pack_documents(token_lists)
    ng, valid = shingle.ngram_hashes(
        jnp.asarray(packed.tokens), jnp.asarray(packed.lengths), n=8)
    return notes, sets, ng, valid


def _true_pairs(sets, threshold):
    n = len(sets)
    out = set()
    for i in range(n):
        for j in range(i + 1, n):
            if jaccard.exact_jaccard(sets[i], sets[j]) > threshold:
                out.add((i, j))
    return out


def run():
    section("figs 1-3: FP/FN vs (b, r) at thresholds 0.2/0.3/0.4")
    notes, sets, ng, valid = _prepare()
    seeds_all = minhash.default_seeds(512)

    results = []
    for threshold in (0.2, 0.3, 0.4):
        truth = _true_pairs(sets, threshold)
        for r in (1, 2, 4):
            for b in (5, 10, 25, 50):
                t0 = time.perf_counter()
                m = b * r
                sig = np.asarray(minhash.signatures(
                    ng, valid, jnp.asarray(seeds_all[:m])))
                bands = np.asarray(
                    lsh.band_values(jnp.asarray(sig), r))
                cand = set(map(tuple, lsh.all_candidate_pairs(bands)))
                dt = time.perf_counter() - t0
                sims = {
                    p: jaccard.exact_jaccard(sets[p[0]], sets[p[1]])
                    for p in cand}
                fp = sum(1 for p, s in sims.items() if s <= threshold)
                fn = len(truth - cand)
                results.append((threshold, b, r, fp, fn, dt))
                emit(f"accuracy_t{threshold}_b{b}_r{r}", dt * 1e6,
                     f"FP={fp};FN={fn};true={len(truth)}")
    # Paper's chosen operating point: r=2 b=50 avoids false negatives.
    chosen = [x for x in results if x[1] == 50 and x[2] == 2]
    for threshold, b, r, fp, fn, dt in chosen:
        emit(f"accuracy_paper_point_t{threshold}", dt * 1e6,
             f"FN={fn}(paper:0);FP={fp}")
    return results


def run_time_vs_bands():
    section("fig 4: in-memory LSH time vs number of hash functions")
    notes, sets, ng, valid = _prepare()
    seeds_all = minhash.default_seeds(512)
    for b in (5, 10, 25, 50, 100):
        m = 2 * b

        def go():
            sig = minhash.signatures(ng, valid,
                                     jnp.asarray(seeds_all[:m]))
            return np.asarray(lsh.band_values(sig, 2))

        us = timeit(go, repeats=2)
        emit(f"time_bands_b{b}", us, f"M={m}")


if __name__ == "__main__":
    run()
    run_time_vs_bands()
