"""Paper §10 Tables 5-6 + §10.2 Table 7 (Louvain comparison), on the
clustering test set (521 notes + 500 injected near-duplicates, 0-20%
word changes).  Runs on the staged engine (CandidateSource ->
BatchVerifier -> ThresholdUnionFind) and additionally reports
batched-verification throughput: scalar per-pair callback vs the
batched exact / signature-estimate verifiers (numpy / jnp / pallas)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, section
from repro.core import jaccard, shingle
from repro.core.candidates import BandMatrixSource, candidate_pairs
from repro.core.cluster import cluster_bands, modularity
from repro.core.pipeline import DedupConfig, DedupPipeline
from repro.core.verify import (
    CallbackVerifier, ExactJaccardVerifier, SignatureVerifier,
)
from repro.data import clustering_testset


def _prepare():
    notes, prov = clustering_testset(seed=0)
    pipe = DedupPipeline(DedupConfig())
    toks = pipe.tokenize(notes)
    sig = pipe.compute_signatures(toks)
    bands = pipe.compute_bands(sig)
    sets = [shingle.ngram_set(t, 8) for t in toks]
    return notes, toks, sets, sig, bands


def run():
    notes, toks, sets, sig, bands = _prepare()
    verifier = ExactJaccardVerifier.from_token_lists(toks, 8)

    section("table 5/6: pairs excluded, modularity vs edge threshold")
    # Baseline without disjoint sets (paper: 6388 pairs on their data).
    _, st_off, pairs_off = cluster_bands(bands, verifier, 0.60, 0.40,
                                         False)
    emit("cluster_no_ds_pairs", 0.0,
         f"evaluated={st_off.pairs_evaluated}")

    tree_t = 0.40
    for edge_pct in (60, 65, 70, 75, 80, 85, 90, 95):
        edge_t = edge_pct / 100
        t0 = time.perf_counter()
        uf, st, pairs = cluster_bands(bands, verifier, edge_t, tree_t,
                                      True)
        dt = time.perf_counter() - t0
        labels = uf.components()
        excluded = st_off.pairs_evaluated - st.pairs_evaluated
        # category counts (paper fig 9)
        same_high = diff_high = same_mid = 0
        for a, b, s in pairs:
            same = labels[a] == labels[b]
            if s > edge_t:
                same_high += int(same)
                diff_high += int(not same)
            elif s > tree_t and same:
                same_mid += 1
        q = modularity(labels, pairs)
        sizes = {}
        for l in labels:
            sizes[l] = sizes.get(l, 0) + 1
        n_clusters = sum(1 for v in sizes.values() if v >= 2)
        emit(f"cluster_edge{edge_pct}", dt * 1e6,
             f"excluded={excluded};sameHigh={same_high};"
             f"diffHigh={diff_high};sameMid={same_mid};"
             f"Q={q:.3f};clusters={n_clusters}")


def run_verify_throughput():
    """Batched verification vs the scalar per-pair callback it replaced."""
    notes, toks, sets, sig, bands = _prepare()
    pairs = candidate_pairs(BandMatrixSource(bands))
    section(f"batched pair verification throughput ({len(pairs)} "
            "candidate pairs)")

    verifiers = [
        ("scalar_exact_callback",
         CallbackVerifier(
             lambda a, b: jaccard.exact_jaccard(sets[a], sets[b]))),
        ("batched_exact",
         ExactJaccardVerifier.from_token_lists(toks, 8)),
        ("scalar_estimate_callback",
         CallbackVerifier(lambda a, b: float((sig[a] == sig[b]).mean()))),
        ("batched_estimate_numpy", SignatureVerifier(sig, "numpy")),
        ("batched_estimate_jnp", SignatureVerifier(sig, "jnp")),
        ("batched_estimate_pallas", SignatureVerifier(sig, "pallas")),
    ]
    ref = None
    for name, v in verifiers:
        v(pairs)  # full-size warm-up: jit of the real bucket shapes
        v.n_pairs, v.n_batches, v.seconds = 0, 0, 0.0
        sims = v(pairs)
        if "exact" in name:
            if ref is None:
                ref = sims
            else:
                np.testing.assert_allclose(sims, ref, atol=1e-6)
        emit(f"verify_{name}", v.seconds * 1e6,
             f"pairs={v.n_pairs};batches={v.n_batches};"
             f"pairs_per_s={v.pairs_per_second:.0f}")


def run_engine_end_to_end():
    """Full staged engine, batched vs scalar verification (host path)."""
    notes, toks, sets, sig, bands = _prepare()
    section("staged engine end-to-end (edge=75)")
    for name, verifier, batch in (
            ("scalar_callback",
             CallbackVerifier(
                 lambda a, b: jaccard.exact_jaccard(sets[a], sets[b])),
             "run"),
            ("batched_exact",
             ExactJaccardVerifier.from_token_lists(toks, 8), "run"),
            ("batched_exact_bandmode",
             ExactJaccardVerifier.from_token_lists(toks, 8), "band")):
        t0 = time.perf_counter()
        uf, st, _ = cluster_bands(bands, verifier, 0.75, 0.40, True,
                                  batch=batch)
        dt = time.perf_counter() - t0
        emit(f"engine_{name}", dt * 1e6,
             f"evaluated={st.pairs_evaluated};"
             f"excluded={st.pairs_excluded};"
             f"verify_s={st.verify_seconds:.4f};"
             f"verify_pairs_per_s={st.verify_pairs_per_second:.0f};"
             f"clusters={len(uf.clusters())}")


def run_louvain():
    import networkx as nx

    notes, toks, sets, sig, bands = _prepare()
    verifier = ExactJaccardVerifier.from_token_lists(toks, 8)
    section("table 7: comparison with the Louvain method (edge=75)")

    _, _, pairs = cluster_bands(bands, verifier, 0.0, 0.0, False)
    g = nx.Graph()
    g.add_nodes_from(range(len(notes)))
    for a, b, s in pairs:
        if s > 0:
            g.add_edge(a, b, weight=s)
    t0 = time.perf_counter()
    comms = nx.community.louvain_communities(g, weight="weight", seed=0)
    t_louvain = time.perf_counter() - t0
    lv_label = {}
    for ci, comm in enumerate(comms):
        for v in comm:
            lv_label[v] = ci

    uf, st, pairs_ds = cluster_bands(bands, verifier, 0.75, 0.40, True)
    ds_label = uf.components()

    def categories(labels):
        same_h = same_m = same_l = diff_h = 0
        for a, b, s in pairs:
            same = labels[a] == labels[b]
            if s > 0.75:
                same_h += int(same)
                diff_h += int(not same)
            elif s > 0.40:
                same_m += int(same)
            else:
                same_l += int(same)
        return same_h, same_m, same_l, diff_h

    for name, labels, secs in (
            ("louvain", [lv_label[i] for i in range(len(notes))],
             t_louvain),
            ("disjoint_set", ds_label, 0.0)):
        sh, sm, sl, dh = categories(labels)
        q = modularity(np.asarray(labels), pairs)
        nclust = len({l for l in labels}) - sum(
            1 for l in set(labels)
            if sum(1 for x in labels if x == l) == 1)
        emit(f"louvain_cmp_{name}", secs * 1e6,
             f"sameHigh={sh};sameMid={sm};sameLow={sl};diffHigh={dh};"
             f"Q={q:.3f};clusters={nclust}")
    emit("louvain_cmp_saved_evals", 0.0,
         f"excluded={st.pairs_excluded}")


if __name__ == "__main__":
    run()
    run_verify_throughput()
    run_engine_end_to_end()
    run_louvain()
