"""Paper §10 Tables 5-6 + §10.2 Table 7 (Louvain comparison), on the
clustering test set (521 notes + 500 injected near-duplicates, 0-20%
word changes)."""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, section
from repro.core import jaccard, shingle
from repro.core.cluster import cluster_bands, modularity
from repro.core.pipeline import DedupConfig, DedupPipeline
from repro.data import clustering_testset


def _prepare():
    notes, prov = clustering_testset(seed=0)
    pipe = DedupPipeline(DedupConfig())
    toks = pipe.tokenize(notes)
    sig = pipe.compute_signatures(toks)
    bands = pipe.compute_bands(sig)
    sets = [shingle.ngram_set(t, 8) for t in toks]
    return notes, sets, bands


def run():
    notes, sets, bands = _prepare()
    simfn = lambda a, b: jaccard.exact_jaccard(sets[a], sets[b])

    section("table 5/6: pairs excluded, modularity vs edge threshold")
    # Baseline without disjoint sets (paper: 6388 pairs on their data).
    _, st_off, pairs_off = cluster_bands(bands, simfn, 0.60, 0.40, False)
    emit("cluster_no_ds_pairs", 0.0,
         f"evaluated={st_off.pairs_evaluated}")

    tree_t = 0.40
    for edge_pct in (60, 65, 70, 75, 80, 85, 90, 95):
        edge_t = edge_pct / 100
        t0 = time.perf_counter()
        uf, st, pairs = cluster_bands(bands, simfn, edge_t, tree_t, True)
        dt = time.perf_counter() - t0
        labels = uf.components()
        excluded = st_off.pairs_evaluated - st.pairs_evaluated
        # category counts (paper fig 9)
        same_high = diff_high = same_mid = 0
        for a, b, s in pairs:
            same = labels[a] == labels[b]
            if s > edge_t:
                same_high += int(same)
                diff_high += int(not same)
            elif s > tree_t and same:
                same_mid += 1
        q = modularity(labels, pairs)
        sizes = {}
        for l in labels:
            sizes[l] = sizes.get(l, 0) + 1
        n_clusters = sum(1 for v in sizes.values() if v >= 2)
        emit(f"cluster_edge{edge_pct}", dt * 1e6,
             f"excluded={excluded};sameHigh={same_high};"
             f"diffHigh={diff_high};sameMid={same_mid};"
             f"Q={q:.3f};clusters={n_clusters}")


def run_louvain():
    import networkx as nx

    notes, sets, bands = _prepare()
    simfn = lambda a, b: jaccard.exact_jaccard(sets[a], sets[b])
    section("table 7: comparison with the Louvain method (edge=75)")

    _, _, pairs = cluster_bands(bands, simfn, 0.0, 0.0, False)
    g = nx.Graph()
    g.add_nodes_from(range(len(notes)))
    for a, b, s in pairs:
        if s > 0:
            g.add_edge(a, b, weight=s)
    t0 = time.perf_counter()
    comms = nx.community.louvain_communities(g, weight="weight", seed=0)
    t_louvain = time.perf_counter() - t0
    lv_label = {}
    for ci, comm in enumerate(comms):
        for v in comm:
            lv_label[v] = ci

    uf, st, pairs_ds = cluster_bands(bands, simfn, 0.75, 0.40, True)
    ds_label = uf.components()

    def categories(labels):
        same_h = same_m = same_l = diff_h = 0
        for a, b, s in pairs:
            same = labels[a] == labels[b]
            if s > 0.75:
                same_h += int(same)
                diff_h += int(not same)
            elif s > 0.40:
                same_m += int(same)
            else:
                same_l += int(same)
        return same_h, same_m, same_l, diff_h

    for name, labels, secs in (
            ("louvain", [lv_label[i] for i in range(len(notes))],
             t_louvain),
            ("disjoint_set", ds_label, 0.0)):
        sh, sm, sl, dh = categories(labels)
        q = modularity(np.asarray(labels), pairs)
        nclust = len({l for l in labels}) - sum(
            1 for l in set(labels)
            if sum(1 for x in labels if x == l) == 1)
        emit(f"louvain_cmp_{name}", secs * 1e6,
             f"sameHigh={sh};sameMid={sm};sameLow={sl};diffHigh={dh};"
             f"Q={q:.3f}")
    emit("louvain_cmp_saved_evals", 0.0,
         f"excluded={st.pairs_excluded}")


if __name__ == "__main__":
    run()
    run_louvain()
