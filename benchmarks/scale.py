"""Paper §12 (production run) — scalability extrapolation.

Measures signature+banding throughput at growing corpus sizes, fits the
linear rate, and extrapolates to the paper's 10M-note corpus; reports
cluster statistics analogous to §12 on the largest size that fits CI.
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, section
from repro.core import lsh, minhash, shingle
from repro.core.pipeline import DedupConfig, DedupPipeline
from repro.data import inject_near_duplicates, make_i2b2_like


def run():
    section("§12: throughput scaling + 10M-note extrapolation")
    rates = []
    for n in (250, 500, 1000, 2000):
        notes = make_i2b2_like(n, seed=4)
        token_lists = [shingle.tokenize(t) for t in notes]
        packed = shingle.pack_documents(token_lists)
        t0 = time.perf_counter()
        ng, valid = shingle.ngram_hashes(
            jnp.asarray(packed.tokens), jnp.asarray(packed.lengths), n=8)
        sig = minhash.signatures(
            ng, valid, jnp.asarray(minhash.default_seeds(100)))
        _bands = np.asarray(lsh.band_values(sig, 2))
        dt = time.perf_counter() - t0
        rates.append(n / dt)
        emit(f"scale_signatures_n{n}", dt * 1e6 / n,
             f"notes_per_s={n/dt:.0f}")
    rate = np.median(rates)
    hours_10m = 10e6 / rate / 3600
    emit("scale_extrapolate_10M_hours", 0.0,
         f"{hours_10m:.2f}h_single_CPU(paper:75h_signatures)")
    # On the 256-chip pod the dedup step is embarrassingly parallel over
    # docs; the dry-run artifact gives the per-step roofline instead.

    section("§12-style cluster stats (4k-note corpus w/ heavy duplication)")
    notes = make_i2b2_like(1500, seed=5)
    notes, _ = inject_near_duplicates(notes, 1500, frac_low=0.0,
                                      frac_high=0.2, seed=6)
    t0 = time.perf_counter()
    res = DedupPipeline(DedupConfig(edge_threshold=0.75)).run(notes)
    dt = time.perf_counter() - t0
    sizes = {}
    for l in res.labels:
        sizes[int(l)] = sizes.get(int(l), 0) + 1
    clusters = [v for v in sizes.values() if v >= 2]
    exact = sum(1 for a, b, s in res.pairs if s > 0.999)
    emit("scale_cluster_run", dt * 1e6,
         f"notes={len(notes)};clusters={len(clusters)};"
         f"largest={max(clusters) if clusters else 0};"
         f"pairs={len(res.pairs)};exact_pairs={exact};"
         f"removed={res.num_duplicates_removed}")


if __name__ == "__main__":
    run()
