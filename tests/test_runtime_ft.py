"""Fault tolerance: checkpoint/restore, crash-resume determinism,
straggler detection, elastic re-mesh."""
import os

import numpy as np
import jax
import pytest

from repro import checkpoint as ckpt
from repro import optim
from repro.data import synthetic_batch_fn
from repro.models.config import ModelConfig
from repro.runtime import (
    FTLoop, FTLoopConfig, SimulatedFailure, StragglerDetector,
    plan_remesh,
)
from repro.training.step import TrainConfig, init_state, make_train_step

CFG = ModelConfig(name="ft", family="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                  param_dtype="float32", compute_dtype="float32",
                  remat="none")


def test_checkpoint_roundtrip(tmp_ckpt_dir):
    tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
            "b": {"c": np.array([1, 2, 3], dtype=np.int8),
                  "d": (np.float32(2.5) * np.ones(5),)}}
    ckpt.save(tmp_ckpt_dir, 7, tree)
    assert ckpt.latest_step(tmp_ckpt_dir) == 7
    back = ckpt.restore(tmp_ckpt_dir, 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_async(tmp_ckpt_dir):
    tree = {"w": np.zeros(4)}
    futs = [ckpt.save(tmp_ckpt_dir, s, tree, keep=2, async_=True)
            for s in (1, 2, 3)]
    for f in futs:
        f.result()
    # async + keep=2: GC may race on the middle save; the LATEST must
    # survive and old ones must eventually be collected.
    steps = ckpt.all_steps(tmp_ckpt_dir)
    assert steps[-1] == 3 and len(steps) <= 3 and 1 not in steps[:-2]


def test_no_partial_checkpoint_visible(tmp_ckpt_dir):
    tree = {"w": np.zeros((1000, 100))}
    ckpt.save(tmp_ckpt_dir, 1, tree)
    # tmp dirs must not be listed
    ckpt.save(tmp_ckpt_dir, 2, tree)
    for name in os.listdir(tmp_ckpt_dir):
        assert not name.endswith(".tmp")


def _make_loop(tmp_dir, fail_at=None):
    tcfg = TrainConfig(adamw=optim.AdamWConfig(lr=1e-3), warmup_steps=1)
    step = jax.jit(make_train_step(CFG, tcfg))
    return FTLoop(
        config=FTLoopConfig(ckpt_dir=tmp_dir, ckpt_every=5,
                            async_ckpt=False, fail_at_step=fail_at),
        train_step=step,
        batch_fn=synthetic_batch_fn(CFG.vocab_size, 2, 16),
    ), tcfg


def test_crash_resume_reproduces_trajectory(tmp_ckpt_dir):
    # Uninterrupted run.
    loop, tcfg = _make_loop(os.path.join(tmp_ckpt_dir, "clean"))
    state0, _ = init_state(CFG, tcfg, jax.random.PRNGKey(0))
    _, hist_clean = loop.run(state0, 12)

    # Crash at step 8, then resume.
    crash_dir = os.path.join(tmp_ckpt_dir, "crash")
    loop2, _ = _make_loop(crash_dir, fail_at=8)
    state0b, _ = init_state(CFG, tcfg, jax.random.PRNGKey(0))
    with pytest.raises(SimulatedFailure):
        loop2.run(state0b, 12)
    assert ckpt.latest_step(crash_dir) == 5
    loop3, _ = _make_loop(crash_dir)
    state0c, _ = init_state(CFG, tcfg, jax.random.PRNGKey(0))
    _, hist_resumed = loop3.run(state0c, 12)

    # Post-resume losses match the uninterrupted run exactly (CPU determinism).
    clean = {h["step"]: h["loss"] for h in hist_clean}
    for h in hist_resumed:
        assert abs(h["loss"] - clean[h["step"]]) < 1e-6, h


def test_straggler_detector_flags_outlier():
    det = StragglerDetector(z_threshold=3.0, warmup_steps=3)
    for i in range(20):
        det.observe(i, 0.10 + 0.001 * (i % 3))
    assert det.num_flagged == 0
    assert det.observe(20, 0.50)   # 5x the EMA -> flagged
    assert det.num_flagged == 1
    # baseline not poisoned by the straggler
    assert det.mean < 0.12


def test_remesh_plan():
    plan = plan_remesh(200, (16, 16))
    assert plan.new_shape == (12, 16)           # keep TP=16, shrink DP
    assert plan.n_lost == 56
    plan2 = plan_remesh(15, (16, 16))
    assert int(np.prod(plan2.new_shape)) <= 15
    assert plan2.new_shape[-1] in (1, 2, 4, 8, 16)
    plan3 = plan_remesh(300, (2, 16, 16))
    assert plan3.new_shape == (1, 18, 16)


def test_elastic_reshard_on_host_devices():
    from tests.conftest import run_with_devices

    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.runtime import plan_remesh, remesh, reshard_tree
        devs = jax.devices()
        mesh8 = jax.make_mesh((4, 2), ("data", "model"),
                              devices=devs[:8])
        x = jax.device_put(
            jnp.arange(64.).reshape(8, 8),
            NamedSharding(mesh8, P("data", "model")))
        # lose 4 devices -> replan on survivors
        plan = plan_remesh(4, (4, 2))
        new_mesh = remesh(plan, devs[:4])
        y = reshard_tree({"x": x}, {"x": P("data", "model")}, new_mesh)
        assert np.array_equal(np.asarray(y["x"]), np.asarray(x))
        print("elastic ok")
    """, n_devices=8)
