"""Self-tests for the repro.analysis lint pass (RPR001-RPR005).

Each rule gets an intentionally-bad fixture (every violation class is
flagged) and a clean fixture (zero findings across ALL rules — the
false-positive guard).  Fixtures live under ``tests/fixtures/analysis``
which the driver's default discovery skips; tests lint them explicitly
through ``lint_file`` with synthetic repo-relative paths so the
path-scoped rules see the directory layout they expect.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import pytest

from repro.analysis.baseline import (
    apply_baseline,
    load_baseline,
    save_baseline,
)
from repro.analysis.lint import lint_file, run_analysis

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures", "analysis")
REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _fixture_source(kind: str, name: str) -> str:
    with open(os.path.join(FIXTURES, kind, name), encoding="utf-8") as f:
        return f.read()


def _lint_fixture(kind: str, name: str, relpath: str, **kw):
    return lint_file(relpath, _fixture_source(kind, name), **kw)


# -- bad fixtures: every violation class fires ------------------------------

BAD_CASES = [
    ("rpr001_bad.py", "src/repro/kernels/fixture_mod.py", "RPR001",
     {"bare-int-literal", "uint32-division", "int32-mix"}),
    ("rpr002_bad.py", "src/repro/serving/fixture_mod.py", "RPR002",
     {"assign:self.count", "call:evict", "mutate:append",
      "call:ingest", "mutate:fill"}),
    # The band-store probe path (PR 10): ``probe_*`` reads on a store
    # class are held to the same purity contract as view probes.
    ("rpr002_store_bad.py", "src/repro/core/fixture_mod.py", "RPR002",
     {"assign:self.hits", "call:compact", "mutate:add",
      "assign:self.seq"}),
    ("rpr003_bad.py", "src/repro/serving/fixture_mod.py", "RPR003",
     {"unbucketed:compute_arrays", "unbucketed:compute_signatures"}),
    ("rpr004_bad.py", "src/repro/core/fixture_mod.py", "RPR004",
     {"off-scheme:run_query", "deprecated-call:ingest_arrays",
      "deprecated-attr:uf"}),
    ("rpr005_bad.py", "src/repro/kernels/fixture_mod.py", "RPR005",
     {"index-map-arity", "unclamped-dim:TL", "vmem-budget",
      "out-rank-mismatch"}),
    # The byte-shingle carry-tiling variant: same violation classes on
    # the revisited rank-1 carry-block idiom of kernels/byte_shingle.py.
    ("rpr005_byte_bad.py", "src/repro/kernels/fixture_mod.py", "RPR005",
     {"index-map-arity", "unclamped-dim:TLB", "vmem-budget",
      "out-rank-mismatch"}),
]


@pytest.mark.parametrize("name,relpath,rule,expected",
                         BAD_CASES, ids=[c[2] for c in BAD_CASES])
def test_bad_fixture_flagged(name, relpath, rule, expected):
    findings = _lint_fixture("bad", name, relpath)
    got = {f.symbol for f in findings if f.rule == rule}
    assert expected <= got, f"missing: {expected - got}"
    assert all(f.status == "new" for f in findings)


# -- good fixtures: zero findings, any rule ---------------------------------

GOOD_CASES = [
    ("rpr001_good.py", "src/repro/kernels/fixture_mod.py"),
    ("rpr002_good.py", "src/repro/serving/fixture_mod.py"),
    ("rpr002_store_good.py", "src/repro/core/fixture_mod.py"),
    ("rpr003_good.py", "src/repro/serving/fixture_mod.py"),
    ("rpr004_good.py", "src/repro/core/fixture_mod.py"),
    ("rpr005_good.py", "src/repro/kernels/fixture_mod.py"),
    ("rpr005_byte_good.py", "src/repro/kernels/fixture_mod.py"),
]


@pytest.mark.parametrize("name,relpath", GOOD_CASES,
                         ids=[c[0].split("_")[0].upper() for c in GOOD_CASES])
def test_good_fixture_clean(name, relpath):
    findings = _lint_fixture("good", name, relpath)
    assert findings == [], [f.render() for f in findings]


# -- suppression comments ---------------------------------------------------

def test_inline_suppression_same_line():
    src = _fixture_source("bad", "rpr001_bad.py").replace(
        "a = h * 31 ", "a = h * 31  # repro-lint: disable=RPR001")
    findings = lint_file("src/repro/kernels/fixture_mod.py", src)
    by_symbol = {f.symbol: f.status for f in findings}
    assert by_symbol["bare-int-literal"] == "suppressed"
    assert by_symbol["uint32-division"] == "new"  # others untouched


def test_inline_suppression_comment_above():
    src = _fixture_source("bad", "rpr001_bad.py").replace(
        "    b = h // 2 ",
        "    # repro-lint: disable=RPR001\n    b = h // 2 ")
    findings = lint_file("src/repro/kernels/fixture_mod.py", src)
    by_symbol = {f.symbol: f.status for f in findings}
    assert by_symbol["uint32-division"] == "suppressed"
    assert by_symbol["bare-int-literal"] == "new"


def test_inline_suppression_wrong_rule_does_not_apply():
    src = _fixture_source("bad", "rpr001_bad.py").replace(
        "a = h * 31 ", "a = h * 31  # repro-lint: disable=RPR002")
    findings = lint_file("src/repro/kernels/fixture_mod.py", src)
    by_symbol = {f.symbol: f.status for f in findings}
    assert by_symbol["bare-int-literal"] == "new"


def test_file_level_disable():
    src = ("# repro-lint: disable-file=RPR001\n"
           + _fixture_source("bad", "rpr001_bad.py"))
    findings = lint_file("src/repro/kernels/fixture_mod.py", src)
    assert [f for f in findings if f.rule == "RPR001"] == []


# -- baseline round-trip ----------------------------------------------------

def test_baseline_roundtrip(tmp_path):
    relpath = "src/repro/serving/fixture_mod.py"
    findings = _lint_fixture("bad", "rpr003_bad.py", relpath)
    assert findings and all(f.status == "new" for f in findings)

    bp = str(tmp_path / "baseline.json")
    save_baseline(bp, findings, {})
    baseline = load_baseline(bp)

    # Same findings, shifted line numbers (fingerprints are
    # line-insensitive): a leading comment moves every line by one.
    shifted = lint_file(
        relpath, "# a new leading comment\n"
        + _fixture_source("bad", "rpr003_bad.py"))
    apply_baseline(shifted, baseline)
    assert shifted and all(f.status == "baselined" for f in shifted)


def test_baseline_count_caps_matches(tmp_path):
    relpath = "src/repro/serving/fixture_mod.py"
    src = _fixture_source("bad", "rpr003_bad.py")
    findings = lint_file(relpath, src)
    bp = str(tmp_path / "baseline.json")
    save_baseline(bp, findings, {})

    # Duplicate one offending call inside the same function: the
    # fingerprint count (1) covers only the grandfathered instance.
    dup = src.replace(
        "    sig, bands = pipe.compute_arrays(token_lists)",
        "    pipe.compute_arrays(token_lists)\n"
        "    sig, bands = pipe.compute_arrays(token_lists)")
    grown = lint_file(relpath, dup)
    apply_baseline(grown, load_baseline(bp))
    arrays = [f for f in grown if f.symbol == "unbucketed:compute_arrays"]
    assert sorted(f.status for f in arrays) == ["baselined", "new"]


def test_baseline_preserves_reasons(tmp_path):
    relpath = "src/repro/serving/fixture_mod.py"
    findings = _lint_fixture("bad", "rpr003_bad.py", relpath)
    bp = str(tmp_path / "baseline.json")
    entries = save_baseline(bp, findings, {})
    fp = next(iter(entries))
    old = load_baseline(bp)
    old[fp]["reason"] = "one-shot driver"
    save_baseline(bp, findings, old)
    assert load_baseline(bp)[fp]["reason"] == "one-shot driver"


# -- the repo itself passes -------------------------------------------------

def test_repo_has_no_new_findings():
    report = run_analysis(root=REPO_ROOT)
    assert report["errors"] == []
    assert report["new"] == [], [f.render() for f in report["new"]]


def test_vmem_limit_is_configurable():
    # The clean RPR005 fixture trips when the ceiling drops below its
    # (tiny) resident-tile estimate: the knob is actually plumbed.
    findings = _lint_fixture(
        "good", "rpr005_good.py", "src/repro/kernels/fixture_mod.py",
        vmem_limit=256)
    assert any(f.symbol == "vmem-budget" for f in findings)


# -- CLI --------------------------------------------------------------------

def test_cli_json_output():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--format", "json"],
        cwd=REPO_ROOT, capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": os.path.join(REPO_ROOT, "src")},
        timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    report = json.loads(proc.stdout)
    assert report["new"] == []
    assert report["files_checked"] > 0


def test_cli_fails_on_new_findings(tmp_path):
    bad = tmp_path / "kernels"
    bad.mkdir()
    (bad / "mod.py").write_text(_fixture_source("bad", "rpr003_bad.py"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--root",
         str(tmp_path), "kernels"],
        cwd=REPO_ROOT, capture_output=True, text=True,
        env={**os.environ,
             "PYTHONPATH": os.path.join(REPO_ROOT, "src")},
        timeout=120)
    assert proc.returncode == 1
    assert "RPR003" in proc.stdout
