"""Streaming dedup (paper §12 two-phase mode) + continuous-batching engine."""
import numpy as np
import jax

from repro.core.pipeline import DedupConfig, DedupPipeline
from repro.core.streaming import StreamingDedup, merge_cluster_rounds
from repro.data import make_i2b2_like


def test_streaming_matches_batch_pipeline():
    notes = make_i2b2_like(80, seed=0)
    notes = notes + [notes[0]] * 3 + [notes[5]] * 2

    batch = DedupPipeline(DedupConfig()).run(notes)

    sd = StreamingDedup(DedupConfig(), chunk_docs=16)
    sd.ingest(notes)
    assert sd.n_docs == len(notes)
    uf, stats = sd.cluster()
    # identical exact-dup clusters
    sl = uf.components()
    assert (sl[80] == sl[0]) and (sl[81] == sl[0]) and (sl[82] == sl[0])
    assert (sl[83] == sl[5]) and (sl[84] == sl[5])
    # same number of duplicates found
    n_stream = len(notes) - len(set(sl.tolist()))
    assert n_stream == batch.num_duplicates_removed


def test_streaming_incremental_ingest_and_rethreshold():
    notes = make_i2b2_like(40, seed=1)
    sd = StreamingDedup(DedupConfig(), chunk_docs=8)
    sd.ingest(notes)
    n0 = sd.n_docs
    # late-arriving duplicates (the production stream case)
    sd.ingest([notes[3], notes[7]])
    assert sd.n_docs == n0 + 2
    uf, _ = sd.cluster()
    labels = uf.components()
    assert labels[n0] == labels[3]
    assert labels[n0 + 1] == labels[7]
    # phase 2 re-run at a different threshold without re-hashing
    uf2, _ = sd.cluster(edge_threshold=0.95)
    assert len(set(uf2.components().tolist())) >= len(
        set(labels.tolist()))


def test_second_round_merging():
    """Paper §10: a second round merges over-partitioned clusters."""
    from repro.core.unionfind import ThresholdUnionFind

    # 4 docs, all pairwise sim 0.9, but round 1 only saw edges (0,1), (2,3).
    sims = {(a, b): 0.9 for a in range(4) for b in range(4) if a < b}
    uf = ThresholdUnionFind(4, tree_threshold=0.4)
    uf.union(0, 1, 0.9)
    uf.union(2, 3, 0.9)
    assert uf.find(0) != uf.find(2)
    merges = merge_cluster_rounds(
        uf, lambda a, b: sims[(min(a, b), max(a, b))],
        edge_threshold=0.75)
    assert merges == 1
    assert uf.find(0) == uf.find(2)


def test_serve_engine_continuous_batching():
    from repro.configs import get_reduced
    from repro.serving import ServeEngine
    from repro.training.step import TrainConfig, init_state
    from repro import optim

    cfg = get_reduced("olmo-1b")
    state, _ = init_state(cfg, TrainConfig(adamw=optim.AdamWConfig()),
                          jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, state["params"], slots=4, cache_len=64,
                      eos_id=-1)  # no eos in random model
    rng = np.random.RandomState(0)
    for _ in range(10):
        eng.submit(rng.randint(2, cfg.vocab_size, size=rng.randint(4, 12)),
                   max_tokens=6)
    finished = eng.run_until_drained()
    assert len(finished) == 10
    assert all(len(r.out) == 6 for r in finished)
    # continuous batching actually batched: 10 requests, 4 slots, 6 toks
    # => at least ~60/4 = 15 decode steps, but far fewer than serial 60.
    assert eng.stats.steps < 40
    assert eng.stats.mean_occupancy > 0.5
    assert eng.stats.tokens_out == 60


def test_serve_engine_matches_offline_decode():
    """Engine output == straight greedy decode for a single request."""
    from repro.configs import get_reduced
    from repro.launch.serve import serve_batch
    from repro.serving import ServeEngine
    from repro.training.step import TrainConfig, init_state
    from repro import optim

    cfg = get_reduced("phi3-medium-14b")
    state, _ = init_state(cfg, TrainConfig(adamw=optim.AdamWConfig()),
                          jax.random.PRNGKey(1))
    prompt = np.random.RandomState(1).randint(2, cfg.vocab_size,
                                              size=8).astype(np.int32)
    toks_ref, _ = serve_batch(cfg, state["params"], prompt[None],
                              max_new=5, cache_len=32)
    eng = ServeEngine(cfg, state["params"], slots=2, cache_len=32,
                      eos_id=-1)
    eng.submit(prompt, max_tokens=5)
    (req,) = eng.run_until_drained()
    assert req.out == toks_ref[0].tolist(), (req.out, toks_ref[0])
