"""Pipeline parallelism: staged loss == single-device loss."""
import pytest

from tests.conftest import run_with_devices


def test_bubble_fraction():
    from repro.models.pipeline import bubble_fraction

    assert bubble_fraction(1, 4) == 0.0
    assert abs(bubble_fraction(2, 4) - 1 / 5) < 1e-9
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)


@pytest.mark.slow
@pytest.mark.xfail(
    not hasattr(__import__("jax"), "shard_map"),
    reason="jax<0.5 experimental shard_map cannot infer output replication "
    "through the fori_loop+ppermute schedule (_SpecError in grad); the "
    "promoted jax.shard_map handles it",
    strict=False,
)
def test_pipelined_loss_matches_reference():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.models import lm
        from repro.models.config import ModelConfig
        from repro.models.pipeline import make_pipelined_loss

        cfg = ModelConfig(name="pp", family="dense", n_layers=4,
                          d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                          vocab_size=128, param_dtype="float32",
                          compute_dtype="float32", remat="none")
        params, _ = lm.init(cfg, jax.random.PRNGKey(0))
        n_micro, B_mb, S = 4, 2, 16
        tokens = jax.random.randint(jax.random.PRNGKey(1),
                                    (n_micro, B_mb, S), 0, 128)

        # reference: mean loss over microbatches, unpipelined
        ref = jnp.mean(jnp.stack([
            lm.loss_fn(cfg, params, {"tokens": tokens[i]})[0]
            for i in range(n_micro)]))

        mesh = jax.make_mesh((2, 2), ("pod", "data"),
                             devices=jax.devices()[:4])
        fn = make_pipelined_loss(cfg, mesh, n_micro=n_micro,
                                 pp_axis="pod")
        got = jax.jit(fn)(params, {"tokens": tokens})
        assert abs(float(got) - float(ref)) < 2e-4, (got, ref)

        # gradients flow through the pipeline (ppermute transpose)
        g = jax.jit(jax.grad(lambda p: fn(p, {"tokens": tokens})))(params)
        gn = max(float(jnp.abs(x).max()) for x in jax.tree.leaves(g))
        assert np.isfinite(gn) and gn > 0
        print("pp ok", float(got), float(ref))
    """, n_devices=4, timeout=900)
