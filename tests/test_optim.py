"""Optimizer substrate: AdamW, int8 moments, schedules, compression."""
import numpy as np
import jax
import jax.numpy as jnp

from repro import optim
from repro.optim import compress
from repro.optim.adamw import (
    AdamWConfig, _dequantize_m, _dequantize_v, _quantize_m, _quantize_v,
)


def _rosenbrock_state():
    params = {"x": jnp.array([1.5, -0.5]), "y": jnp.array([[2.0, 0.1]])}
    def loss(p):
        return (jnp.sum((p["x"] - 1) ** 2)
                + jnp.sum(100 * (p["y"] - p["x"][None] ** 2) ** 2))
    return params, loss


def test_adamw_converges_fp32_and_int8():
    for md in ("float32", "int8"):
        cfg = AdamWConfig(lr=5e-2, weight_decay=0.0, moments_dtype=md)
        params, loss = _rosenbrock_state()
        state = optim.init(params, cfg)
        l0 = float(loss(params))
        for _ in range(300):
            g = jax.grad(loss)(params)
            params, state, _ = optim.apply(params, g, state, cfg)
        assert float(loss(params)) < 0.05 * l0, md


def test_moment_quantization_roundtrip_accuracy():
    rng = np.random.RandomState(0)
    x = (rng.randn(64, 512) * np.exp(rng.uniform(-8, 2, (64, 512)))
         ).astype(np.float32)
    qm = _quantize_m(jnp.asarray(x), 256)
    back = np.asarray(_dequantize_m(qm, x.shape))
    # linear absmax: block-relative error <= 1/127 of blockmax
    blockmax = np.abs(x.reshape(64, 2, 256)).max(-1, keepdims=True)
    err = np.abs(back - x).reshape(64, 2, 256)
    assert np.all(err <= blockmax / 127 + 1e-9)

    v = (x ** 2).astype(np.float32)
    qv = _quantize_v(jnp.asarray(v), 256)
    backv = np.asarray(_dequantize_v(qv, v.shape))
    nz = v > 1e-18
    rel = np.abs(backv[nz] - v[nz]) / v[nz]
    assert np.percentile(rel, 99) < 0.25   # log-affine: bounded rel error


def test_grad_clip_engages():
    cfg = AdamWConfig(lr=1e-3, clip_norm=1.0)
    params = {"w": jnp.ones((4,))}
    state = optim.init(params, cfg)
    huge = {"w": jnp.full((4,), 1e6)}
    new_p, _, metrics = optim.apply(params, huge, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5
    assert float(jnp.max(jnp.abs(new_p["w"] - params["w"]))) < 0.01


def test_schedules():
    from repro.optim.schedule import warmup_cosine

    s = warmup_cosine(jnp.arange(0, 1000), warmup=100, total=1000,
                      floor=0.1)
    s = np.asarray(s)
    assert s[0] == 0.0
    assert abs(s[100] - 1.0) < 0.02
    assert s[999] < 0.15
    assert np.all(np.diff(s[:100]) >= -1e-9)   # warmup monotone up
    assert np.all(np.diff(s[150:]) <= 1e-9)    # cosine monotone down


def test_error_feedback_compression_unbiased_over_time():
    rng = np.random.RandomState(0)
    true_g = rng.randn(1000).astype(np.float32)
    err = jnp.zeros(1000)
    acc = np.zeros(1000, dtype=np.float64)
    for _ in range(50):
        q, scale, err = compress.ef_compress(jnp.asarray(true_g), err)
        acc += np.asarray(compress.ef_decompress(q, scale),
                          dtype=np.float64)
    mean = acc / 50
    # error feedback: accumulated mean converges to the true gradient
    assert np.abs(mean - true_g).max() < 0.05 * np.abs(true_g).max()


def test_grad_compression_training_converges():
    import jax
    from repro.models.config import ModelConfig
    from repro.training.step import (TrainConfig, init_state,
                                     make_train_step)

    cfg = ModelConfig(name="gc", family="dense", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                      param_dtype="float32", compute_dtype="float32",
                      remat="none")
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (4, 16), 0, 128)}
    losses = {}
    for comp in (False, True):
        tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-2), warmup_steps=1,
                           grad_compression=comp)
        state, _ = init_state(cfg, tcfg, jax.random.PRNGKey(0))
        step = jax.jit(make_train_step(cfg, tcfg))
        ls = []
        for _ in range(10):
            state, m = step(state, batch)
            ls.append(float(m["loss"]))
        losses[comp] = ls
    assert losses[True][-1] < losses[True][0]
    # compressed path tracks the uncompressed trajectory closely
    assert abs(losses[True][-1] - losses[False][-1]) < 0.3
