"""Fused one-pass ingest kernel vs every staged reference, bit for bit.

The fused Pallas pass (shingle -> minhash -> band fold, no HBM
round-trip) must be bit-identical to the staged pallas chain, the
staged jnp ref, and the pure-numpy oracles — that parity is what lets
``fused_ingest=True`` drop into any session backend with zero drift.

Deterministic cases live here (no hypothesis dependency, so they run in
tier-1 everywhere); the randomized shape sweep rides in
``test_kernels.py`` under its hypothesis gate.
"""
import numpy as np
import jax.numpy as jnp

from repro.kernels import ops, ref


def _fused_numpy_oracle(tokens, lengths, seeds, n, r):
    """Pure-numpy staged chain (the slow-but-obvious oracle)."""
    from repro.core import lsh, minhash, shingle

    ng, valid = shingle.ngram_hashes_np(tokens, lengths, n=n)
    sig = minhash.signatures_np(ng, valid, seeds)
    return sig, lsh.band_values_np(sig, r), valid


def _staged_pallas(tj, lj, sj, n, r):
    ng, valid = ops.ngram_hashes(tj, lj, n=n)
    sig = ops.minhash_signatures(ng, valid, sj)
    return (np.asarray(sig), np.asarray(ops.band_values(sig, r)),
            np.asarray(valid))


def assert_fused_parity(tokens, lengths, seeds, n=8, r=2, **tiles):
    """Fused == staged pallas == jnp ref == numpy oracle, bit for bit."""
    tj, lj, sj = map(jnp.asarray, (tokens, lengths, seeds))
    sig_f, bands_f, valid_f = (np.asarray(x) for x in
                               ops.fused_ingest(tj, lj, sj, n=n, r=r,
                                                **tiles))
    sig_s, bands_s, valid_s = _staged_pallas(tj, lj, sj, n, r)
    sig_j, bands_j, valid_j = (np.asarray(x) for x in
                               ref.fused_ingest(tj, lj, sj, n=n, r=r))
    sig_n, bands_n, valid_n = _fused_numpy_oracle(tokens, lengths,
                                                  seeds, n, r)
    for sig, bands, valid in [(sig_s, bands_s, valid_s),
                              (sig_j, bands_j, valid_j),
                              (sig_n, bands_n, valid_n)]:
        assert np.array_equal(sig_f, sig)
        assert np.array_equal(bands_f, bands)
        assert np.array_equal(valid_f, valid)


def test_fused_ingest_random_batch():
    rng = np.random.RandomState(0)
    D, L, M = 24, 300, 50
    tokens = rng.randint(0, 2**32, size=(D, L), dtype=np.uint64
                         ).astype(np.uint32)
    lengths = rng.randint(0, L + 1, size=(D,)).astype(np.int32)
    seeds = rng.randint(0, 2**32, size=(M,), dtype=np.uint64
                        ).astype(np.uint32)
    assert_fused_parity(tokens, lengths, seeds, n=8, r=2)


def test_fused_ingest_edge_cases():
    """Empty docs, docs shorter than n, L < n batches, and lengths
    pinned to tile boundaries (127/128/129) all bit-match the oracles."""
    rng = np.random.RandomState(11)
    seeds = rng.randint(0, 2**32, size=(10,), dtype=np.uint64
                        ).astype(np.uint32)
    # Tile-boundary raggedness around tl=128.
    L = 160
    tokens = rng.randint(0, 2**32, size=(8, L), dtype=np.uint64
                         ).astype(np.uint32)
    lengths = np.array([0, 1, 5, 7, 127, 128, 129, L], dtype=np.int32)
    assert_fused_parity(tokens, lengths, seeds, n=8, r=2)
    # Whole batch narrower than the n-gram window (L < n).
    tokens = rng.randint(0, 2**32, size=(4, 5), dtype=np.uint64
                         ).astype(np.uint32)
    lengths = np.array([0, 2, 5, 3], dtype=np.int32)
    assert_fused_parity(tokens, lengths, seeds, n=8, r=2)
    # Zero documents.
    sig, bands, valid = ops.fused_ingest(
        jnp.zeros((0, 16), jnp.uint32), jnp.zeros((0,), jnp.int32),
        jnp.asarray(seeds), n=8, r=2)
    assert sig.shape == (0, 10) and bands.shape == (0, 5, 2)
    assert valid.shape == (0, 16)


def test_fused_ingest_nondefault_window_and_rows():
    """n != 8 and r != 2 (odd band width) still bit-match."""
    rng = np.random.RandomState(23)
    D, L, M = 9, 70, 15
    tokens = rng.randint(0, 2**32, size=(D, L), dtype=np.uint64
                         ).astype(np.uint32)
    lengths = rng.randint(0, L + 1, size=(D,)).astype(np.int32)
    seeds = rng.randint(0, 2**32, size=(M,), dtype=np.uint64
                        ).astype(np.uint32)
    assert_fused_parity(tokens, lengths, seeds, n=4, r=3)


def test_fused_ingest_tile_size_invariance():
    """Tiling is an implementation detail: every (td, tl, tm) choice
    yields the same bits (band folds never straddle M-tiles)."""
    rng = np.random.RandomState(5)
    D, L, M = 17, 150, 30
    tokens = rng.randint(0, 2**32, size=(D, L), dtype=np.uint64
                         ).astype(np.uint32)
    lengths = rng.randint(0, L + 1, size=(D,)).astype(np.int32)
    seeds = rng.randint(0, 2**32, size=(M,), dtype=np.uint64
                        ).astype(np.uint32)
    tj, lj, sj = map(jnp.asarray, (tokens, lengths, seeds))
    outs = [
        tuple(np.asarray(x) for x in
              ops.fused_ingest(tj, lj, sj, n=8, r=3,
                               td=td, tl=tl, tm=tm))
        for td, tl, tm in [(8, 128, 128), (4, 32, 9), (17, 150, 30),
                           (1, 8, 3)]
    ]
    for got in outs[1:]:
        for g, w in zip(got, outs[0]):
            assert np.array_equal(g, w)


def test_fused_pipeline_parity():
    """`DedupPipeline.compute_arrays` fused vs staged: same bits, and the
    fused path reports a single fused timing (bands_s folded to 0)."""
    from repro.core.pipeline import DedupConfig, DedupPipeline
    from repro.data import inject_near_duplicates, make_i2b2_like

    notes = make_i2b2_like(20, seed=0)
    notes, _ = inject_near_duplicates(notes, 6, frac_low=0.0,
                                      frac_high=0.005, seed=1)
    toks = DedupPipeline().tokenize(notes)
    staged = DedupPipeline(DedupConfig(fused_ingest=False))
    fused = DedupPipeline(DedupConfig(fused_ingest=True))
    sig_s, bands_s = staged.compute_arrays(toks)
    sig_f, bands_f = fused.compute_arrays(toks)
    assert np.array_equal(sig_s, sig_f)
    assert np.array_equal(bands_s, bands_f)
    assert fused.stage_timings["signature_s"] > 0
    assert fused.stage_timings["bands_s"] == 0.0
    assert staged.stage_timings["bands_s"] > 0


def test_pipeline_device_seeds_cached():
    """The seed vector uploads once per assignment, not per chunk."""
    from repro.core.pipeline import DedupPipeline

    pipe = DedupPipeline()
    dev = pipe.device_seeds()
    assert pipe.device_seeds() is dev  # cached, no re-upload
    pipe.seeds = pipe.seeds.copy()  # reassignment invalidates
    assert pipe.device_seeds() is not dev
    assert np.array_equal(np.asarray(pipe.device_seeds()),
                          np.asarray(pipe.seeds))
