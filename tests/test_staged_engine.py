"""Staged dedup engine: candidate sources agree, batched verifiers match
the per-pair numpy oracle, and the engine reproduces the scalar loop."""
import numpy as np

from repro.core import jaccard, lsh, shingle
from repro.core.bandstore import Design1Store, Design2Store
from repro.core.candidates import (
    BandMatrixSource, EdgeStreamSource, ShardedEdgeSource, StoreBandSource,
    candidate_pairs,
)
from repro.core.cluster import cluster_bands
from repro.core.engine import (
    ClusterAccumulator, cluster_source, merge_cluster_rounds,
)
from repro.core.pipeline import DedupConfig, DedupPipeline, DedupResult
from repro.core.streaming import StreamingDedup
from repro.core.unionfind import ThresholdUnionFind
from repro.core.verify import (
    CallbackVerifier, DeviceScoredEdgeVerifier, ExactJaccardVerifier,
    ShardedEdgeVerifier, SignatureVerifier,
)
from repro.data import inject_near_duplicates, make_i2b2_like


def _corpus(n=60, dups=40, seed=0):
    notes = make_i2b2_like(n, seed=seed)
    notes, _ = inject_near_duplicates(notes, dups, seed=seed + 1)
    return notes


def _random_pairs(rng, d, p):
    a = rng.randint(0, d, size=p)
    b = (a + 1 + rng.randint(0, d - 1, size=p)) % d
    lo, hi = np.minimum(a, b), np.maximum(a, b)
    return np.stack([lo, hi], axis=-1).astype(np.int64)


# -- verify layer ----------------------------------------------------------

def test_signature_verifier_backends_match_per_pair_oracle():
    rng = np.random.RandomState(0)
    sig = rng.randint(0, 50, size=(40, 100)).astype(np.uint32)
    pairs = _random_pairs(rng, 40, 500)
    oracle = np.array(
        [(sig[a] == sig[b]).mean() for a, b in pairs], dtype=np.float32)
    for backend in ("numpy", "jnp", "pallas"):
        v = SignatureVerifier(sig, backend=backend, batch_pairs=128)
        np.testing.assert_allclose(v(pairs), oracle, atol=1e-6,
                                   err_msg=backend)
        assert v.n_pairs == len(pairs)
        assert v.n_batches == -(-len(pairs) // 128)


def test_exact_verifier_matches_per_pair_oracle():
    notes = _corpus()
    toks = [shingle.tokenize(t) for t in notes]
    sets = [shingle.ngram_set(t, 8) for t in toks]
    rng = np.random.RandomState(1)
    pairs = _random_pairs(rng, len(notes), 400)
    oracle = np.array(
        [jaccard.exact_jaccard(sets[a], sets[b]) for a, b in pairs],
        dtype=np.float32)
    v = ExactJaccardVerifier.from_token_lists(toks, 8, batch_pairs=64)
    np.testing.assert_allclose(v(pairs), oracle, atol=1e-6)


def test_exact_verifier_empty_and_short_docs():
    v = ExactJaccardVerifier.from_token_lists(
        [[], [], ["a", "b"], ["a", "b"], ["c"]], n=8)
    sims = v(np.array([[0, 1], [0, 2], [2, 3], [2, 4]]))
    # empty vs empty = 1.0 (matches jaccard.exact_jaccard), empty vs
    # non-empty = 0, identical short docs = 1, disjoint = 0.
    np.testing.assert_allclose(sims, [1.0, 0.0, 1.0, 0.0], atol=1e-6)


# -- candidate layer -------------------------------------------------------

def test_three_candidate_sources_identical_pairs():
    notes = _corpus()
    pipe = DedupPipeline(DedupConfig())
    bands = pipe.compute_bands(
        pipe.compute_signatures(pipe.tokenize(notes)))
    d, b, _ = bands.shape

    mem_pairs = candidate_pairs(BandMatrixSource(bands))
    assert len(mem_pairs), "corpus with injected dups must have candidates"

    s1, s2 = Design1Store(), Design2Store(part_size=16)
    for i in range(d):
        s1.insert_document(i, bands[i])
        s2.insert_document(i, bands[i])
    s1.commit()
    s2.commit()
    p1 = candidate_pairs(StoreBandSource(s1, b, d))
    p2 = candidate_pairs(StoreBandSource(s2, b, d))

    sd = StreamingDedup(DedupConfig(), chunk_docs=16)
    sd.ingest(notes)
    p3 = candidate_pairs(sd.candidate_source())

    np.testing.assert_array_equal(mem_pairs, p1)
    np.testing.assert_array_equal(mem_pairs, p2)
    np.testing.assert_array_equal(mem_pairs, p3)
    # legacy entry points delegate to the same layer
    np.testing.assert_array_equal(mem_pairs, lsh.all_candidate_pairs(bands))


# -- engine ----------------------------------------------------------------

def test_engine_batched_matches_scalar_callback():
    notes = _corpus()
    pipe = DedupPipeline(DedupConfig())
    toks = pipe.tokenize(notes)
    bands = pipe.compute_bands(pipe.compute_signatures(toks))
    sets = [shingle.ngram_set(t, 8) for t in toks]

    uf_cb, st_cb, pairs_cb = cluster_bands(
        bands, lambda a, b: jaccard.exact_jaccard(sets[a], sets[b]),
        0.75, 0.40, True)
    uf_bv, st_bv, pairs_bv = cluster_bands(
        bands, ExactJaccardVerifier.from_token_lists(toks, 8),
        0.75, 0.40, True)

    np.testing.assert_array_equal(uf_cb.components(), uf_bv.components())
    assert st_cb.pairs_evaluated == st_bv.pairs_evaluated
    assert st_cb.pairs_excluded == st_bv.pairs_excluded
    assert st_cb.unions_done == st_bv.unions_done
    assert [(a, b) for a, b, _ in pairs_cb] == \
        [(a, b) for a, b, _ in pairs_bv]
    np.testing.assert_allclose(
        [s for _, _, s in pairs_cb], [s for _, _, s in pairs_bv],
        atol=1e-6)


def test_engine_band_batch_mode_still_clusters():
    notes = make_i2b2_like(40, seed=9)
    notes = notes + [notes[0]] * 3
    pipe = DedupPipeline(DedupConfig())
    toks = pipe.tokenize(notes)
    sig = pipe.compute_signatures(toks)
    bands = pipe.compute_bands(sig)
    uf, st, _ = cluster_source(
        BandMatrixSource(bands), SignatureVerifier(sig),
        0.75, 0.40, batch="band", max_batch_pairs=64)
    labels = uf.components()
    assert labels[40] == labels[0] == labels[41] == labels[42]
    # band mode may evaluate pairs the strict mode excludes, never fewer
    _, st_run, _ = cluster_source(
        BandMatrixSource(bands), SignatureVerifier(sig), 0.75, 0.40)
    assert st.pairs_evaluated >= st_run.pairs_evaluated


def test_streaming_cluster_uses_batched_verifier():
    notes = _corpus(40, 20, seed=3)
    sd = StreamingDedup(DedupConfig(), chunk_docs=8)
    sd.ingest(notes)
    uf_b, stats = sd.cluster()
    assert stats["verify_batches"] >= 1
    # scalar-callback compat path gives the identical clustering.
    # Rows come from wherever the configured tier keeps them: the host
    # phase-1 cache (memory) or the store's sigs table (sqlite).
    if hasattr(sd.store, "get_signature"):
        row = sd.store.get_signature
    else:
        row = sd._sig_cache.__getitem__
    uf_s, _ = sd.cluster(
        similarity_fn=lambda a, b: float(
            (row(a) == row(b)).mean()))
    np.testing.assert_array_equal(uf_b.components(), uf_s.components())


def test_merge_cluster_rounds_batched_matches_scalar():
    rng = np.random.RandomState(5)
    sims = {(a, b): float(rng.uniform(0.5, 1.0))
            for a in range(8) for b in range(8) if a < b}

    def build():
        uf = ThresholdUnionFind(8, 0.3)
        for a, b in ((0, 1), (2, 3), (4, 5), (6, 7)):
            uf.union(a, b, 0.95)
        return uf

    def fn(a, b):
        return sims[(min(a, b), max(a, b))]

    uf_scalar = build()
    m1 = merge_cluster_rounds(uf_scalar, fn, 0.75)
    uf_batched = build()
    m2 = merge_cluster_rounds(uf_batched, CallbackVerifier(fn), 0.75)
    assert m1 == m2
    np.testing.assert_array_equal(
        uf_scalar.components(), uf_batched.components())


# -- sharded path layers (host-side units; device path in
# tests/test_distributed.py) -----------------------------------------------

def test_sharded_edge_source_pairs_mask_and_pad_filtering():
    # Two device buffers of capacity 3 (num_shards=2): invalid slots,
    # masked-out slots, and edges touching pad docs (id >= num_docs)
    # must all be dropped.
    inv = np.uint32(0xFFFFFFFF)
    edges = np.array([
        [0, 1], [2, 3], [inv, inv],     # device 0: two valid, one unused
        [4, 9], [4, 5], [inv, inv],     # device 1: [4, 9] touches a pad
    ], dtype=np.uint32)
    mask = np.array([1, 1, 0, 1, 1, 0], dtype=bool)
    src = ShardedEdgeSource(edges, mask, num_docs=8, num_shards=2)
    assert src.num_docs == 8
    assert src.num_bands == 2
    assert src.num_edges == 3
    np.testing.assert_array_equal(
        candidate_pairs(src), [[0, 1], [2, 3], [4, 5]])
    # every run is a two-member group
    groups = [g.tolist() for br in src.iter_bands()
              for g in br.iter_groups()]
    assert groups == [[0, 1], [2, 3], [4, 5]]


def test_sharded_edge_verifier_matches_host_estimator():
    rng = np.random.RandomState(7)
    sig = rng.randint(0, 50, size=(40, 100)).astype(np.uint32)
    pairs = _random_pairs(rng, 40, 300)
    host = SignatureVerifier(sig, backend="numpy")
    oracle = host(pairs)
    for backend in ("numpy", "jnp", "pallas"):
        v = ShardedEdgeVerifier(sig, backend=backend, batch_pairs=128)
        np.testing.assert_allclose(v(pairs), oracle, atol=1e-6,
                                   err_msg=backend)
        # bit-identical to the host verifier on the SAME backend (pallas
        # multiplies by 1/M instead of dividing, so cross-backend
        # estimates agree only to float tolerance)
        assert v.drift_count(
            pairs, SignatureVerifier(sig, backend=backend)) == 0
    # from_step_output builds from the step's returned signatures
    v = ShardedEdgeVerifier.from_step_output({"sig": sig})
    np.testing.assert_allclose(v(pairs), oracle, atol=1e-6)


def test_sharded_edges_through_engine_match_band_source():
    # Star edges of every band run, fed through ShardedEdgeSource, must
    # cluster identically to the host BandMatrixSource on the engine.
    notes = _corpus()
    pipe = DedupPipeline(DedupConfig())
    sig = pipe.compute_signatures(pipe.tokenize(notes))
    bands = pipe.compute_bands(sig)
    uf_h, _, pairs_h = cluster_source(
        BandMatrixSource(bands), SignatureVerifier(sig), 0.75, 0.40)
    edges = []
    for br in BandMatrixSource(bands).iter_bands():
        for g in br.iter_groups():
            edges += [(g[0], m) for m in g[1:]]   # member -> run head
    src = ShardedEdgeSource(np.array(edges, dtype=np.int64),
                            num_docs=len(notes))
    uf_s, _, pairs_s = cluster_source(
        src, ShardedEdgeVerifier(sig), 0.75, 0.40)
    np.testing.assert_array_equal(uf_h.components(), uf_s.components())
    sims_h = dict(((a, b), s) for a, b, s in pairs_h)
    shared = [(a, b, s) for a, b, s in pairs_s if (a, b) in sims_h]
    assert shared
    assert all(s == sims_h[(a, b)] for a, b, s in shared)


def test_cluster_source_accumulates_into_existing_uf():
    # Overflow-retry shape: a partial edge source first, then the full
    # band source into the SAME union-find recovers the full clustering.
    notes = _corpus()
    pipe = DedupPipeline(DedupConfig())
    sig = pipe.compute_signatures(pipe.tokenize(notes))
    bands = pipe.compute_bands(sig)
    uf_full, _, _ = cluster_source(
        BandMatrixSource(bands), SignatureVerifier(sig), 0.75, 0.40)

    edges = []
    for br in BandMatrixSource(bands).iter_bands():
        for g in br.iter_groups():
            edges += [(g[0], m) for m in g[1:]]
    partial = ShardedEdgeSource(
        np.array(edges[: len(edges) // 3], dtype=np.int64),
        num_docs=len(notes))
    verifier = SignatureVerifier(sig)
    uf, st1, _ = cluster_source(partial, verifier, 0.75, 0.40)
    uf2, st2, _ = cluster_source(
        BandMatrixSource(bands), verifier, 0.75, 0.40, uf=uf)
    assert uf2 is uf
    np.testing.assert_array_equal(uf.components(), uf_full.components())
    # the retry pass re-verifies at most what a fresh run would
    _, st_fresh, _ = cluster_source(
        BandMatrixSource(bands), SignatureVerifier(sig), 0.75, 0.40)
    assert st2.pairs_evaluated <= st_fresh.pairs_evaluated


# -- doc-id integrity regressions ------------------------------------------

def test_design2_store_noncontiguous_doc_ids_round_trip():
    """Regression: Design 2 must persist explicit per-part doc ids.

    The historical blob stored only the values and *reconstructed* ids
    as arange(doc0, doc0 + d) — silently wrong whenever a part holds a
    non-contiguous id range (ragged chunks, resumed ingest with
    doc_offsets-style global ids, ids >= 2^31).
    """
    rng = np.random.RandomState(0)
    ids = [3, 100, 2**31 + 7, 11, 2**31 + 5]
    bands = {i: rng.randint(0, 2**31, size=(4, 2)).astype(np.uint32)
             for i in ids}
    s1, s2 = Design1Store(), Design2Store(part_size=3)
    for i in ids:
        s1.insert_document(i, bands[i])
        s2.insert_document(i, bands[i])
    s1.commit()
    s2.commit()
    for j in range(4):
        d2, v2 = s2.read_band(j)
        assert sorted(d2.tolist()) == sorted(ids)
        assert d2.dtype == np.int64
        for doc, val in zip(d2, v2):
            np.testing.assert_array_equal(val, bands[int(doc)][j])
        # both designs agree row-for-row
        d1, v1 = s1.read_band(j)
        o1, o2 = np.argsort(d1), np.argsort(d2)
        np.testing.assert_array_equal(d1[o1], d2[o2])
        np.testing.assert_array_equal(v1[o1], v2[o2])


def test_design2_store_reads_legacy_v1_blobs():
    """Pre-existing stores (raw value blobs) stay readable via doc0."""
    rng = np.random.RandomState(1)
    vals = rng.randint(0, 2**31, size=(5, 2)).astype(np.uint32)
    s2 = Design2Store()
    s2.conn.execute("INSERT INTO band2 VALUES (?,?,?,?)",
                    (0, 0, 10, vals.tobytes()))
    docs, got = s2.read_band(0)
    np.testing.assert_array_equal(docs, np.arange(10, 15))
    np.testing.assert_array_equal(got, vals)


def test_streaming_resumed_ingest_noncontiguous_ids():
    """Resumed ingest writes non-contiguous ids inside one band part.

    chunk A (ids 0..4) and chunk B (ids 42..46) share a part of size 8,
    so the part's id range is non-contiguous; the round-trip must keep
    the explicit ids and cluster a cross-chunk duplicate pair.
    """
    notes_a = make_i2b2_like(5, seed=11)
    notes_a[3] = notes_a[1]                 # in-chunk duplicate
    notes_b = make_i2b2_like(5, seed=12)
    notes_b[0] = notes_a[1]                 # cross-chunk duplicate (id 42)
    cfg = DedupConfig()
    sd = StreamingDedup(cfg, chunk_docs=8)
    sd.ingest(notes_a)
    sd.n_docs = 42                          # resume after a corpus gap
    sd.ingest(notes_b)
    assert sd.n_docs == 47
    docs0, _ = sd.store.read_band(0)
    assert sorted(docs0.tolist()) == [0, 1, 2, 3, 4, 42, 43, 44, 45, 46]

    # default verifier: signature matrix indexed by global id (gap rows
    # zero; gap ids have no store rows so they never become candidates)
    uf, _ = sd.cluster()
    labels = uf.components()
    assert labels[1] == labels[3] == labels[42], labels

    # doc_id_base makes resumed ingest first-class (fresh store).
    sd2 = StreamingDedup(cfg, chunk_docs=8, doc_id_base=1000)
    sd2.ingest(notes_a)
    docs0, _ = sd2.store.read_band(0)
    assert sorted(docs0.tolist()) == [1000, 1001, 1002, 1003, 1004]
    uf2, _ = sd2.cluster()                # default verifier works too
    labels2 = uf2.components()
    assert labels2[1001] == labels2[1003]


def test_pair_enumeration_int64_global_ids():
    """Regression: doc ids >= 2^31 must survive pair enumeration.

    The historical int32 downcast wrapped exactly the global ids that
    chunked corpora with doc_offsets produce.
    """
    big = 2**31
    vals = np.array([[1, 1], [1, 1], [2, 2], [2, 2]], dtype=np.uint32)
    docs = np.array([big + 9, big + 5, 7, big + 3], dtype=np.int64)
    from repro.core.candidates import make_band_runs, pairs_in_runs

    runs = make_band_runs(0, vals, docs)
    pairs = pairs_in_runs(runs.sorted_vals, runs.sorted_docs)
    assert pairs.dtype == np.int64
    assert sorted(map(tuple, pairs.tolist())) == \
        [(7, big + 3), (big + 5, big + 9)]
    # the source-agnostic dedup path and legacy entry point agree
    lp = lsh.enumerate_pairs_in_runs(runs.sorted_vals, runs.sorted_docs)
    assert lp.dtype == np.int64
    np.testing.assert_array_equal(np.sort(lp, axis=0),
                                  np.sort(pairs, axis=0))

    class _OneBand:
        num_docs = 0
        num_bands = 1

        def iter_bands(self):
            yield runs

    cp = candidate_pairs(_OneBand())
    assert cp.dtype == np.int64
    assert sorted(map(tuple, cp.tolist())) == \
        [(7, big + 3), (big + 5, big + 9)]


def test_merge_cluster_rounds_dispatch_count_pin():
    """The verified-sim cache is shared across blocks: a root pair that
    re-appears after a mid-sweep union is served from cache, never
    re-dispatched (historically each block re-verified it singleton)."""
    sims = {(0, 2): 0.9}

    def fn(a, b):
        return sims.get((min(a, b), max(a, b)), 0.6)

    def build():
        uf = ThresholdUnionFind(8, 0.3)
        for a, b in ((0, 1), (2, 3), (4, 5), (6, 7)):
            uf.union(a, b, 0.95)
        return uf

    uf = build()
    v = CallbackVerifier(fn)
    merges = merge_cluster_rounds(uf, v, 0.75, max_batch_pairs=2)
    assert merges == 1
    # 4 roots -> 6 root pairs in the sweep, but only 4 distinct pairs of
    # *current* roots exist once (0, 2) merges; every one is verified
    # exactly once.
    assert v.n_pairs == 4
    uf_big = build()
    merge_cluster_rounds(uf_big, fn, 0.75)  # single block reference
    np.testing.assert_array_equal(uf.components(), uf_big.components())


# -- band-group streaming layers (host-side; device path in
# tests/test_distributed.py) -----------------------------------------------

def test_edge_stream_source_lazy_groups_match_sharded_source():
    inv = np.uint32(0xFFFFFFFF)
    g1 = np.array([[0, 1], [2, 3], [inv, inv]], dtype=np.uint32)
    m1 = np.array([1, 1, 0], dtype=bool)
    g2 = np.array([[4, 9], [4, 5], [0, 2]], dtype=np.uint32)
    consumed = []

    def groups():
        consumed.append("g1")
        yield g1, m1
        consumed.append("g2")
        yield g2, None

    seen_cb = []
    src = EdgeStreamSource(groups(), num_docs=8, num_shards=1,
                           on_group=lambda g, e, m: seen_cb.append(g))
    it = src.iter_bands()
    first = next(it)
    assert consumed == ["g1"]       # group 2 not materialized yet
    assert [g.tolist() for g in first.iter_groups()] == [[0, 1], [2, 3]]
    rest = list(it)
    assert consumed == ["g1", "g2"] and seen_cb == [0, 1]
    groups_all = [g.tolist() for br in [first] + rest
                  for g in br.iter_groups()]
    assert groups_all == [[0, 1], [2, 3], [4, 5], [0, 2]]  # pad edge dropped
    assert src.num_edges == 4 and src.groups_consumed == 2

    # engine result == one ShardedEdgeSource over the concatenation
    sig = np.random.RandomState(3).randint(
        0, 4, size=(8, 100)).astype(np.uint32)
    uf_a, _, pairs_a = cluster_source(
        EdgeStreamSource([(g1, m1), (g2, None)], num_docs=8),
        SignatureVerifier(sig), 0.75, 0.40)
    uf_b, _, pairs_b = cluster_source(
        ShardedEdgeSource(np.concatenate([g1, g2]),
                          np.concatenate([m1, np.ones(3, bool)]),
                          num_docs=8),
        SignatureVerifier(sig), 0.75, 0.40)
    np.testing.assert_array_equal(uf_a.components(), uf_b.components())
    assert pairs_a == pairs_b


def test_cluster_accumulator_excludes_cross_feed_pairs():
    """A pair verified while feeding group g is excluded in group g+1."""
    sig = np.random.RandomState(4).randint(
        0, 50, size=(10, 100)).astype(np.uint32)   # all sims ~ tiny
    edges = np.array([[0, 1], [2, 3], [4, 5]], dtype=np.int64)
    verifier = SignatureVerifier(sig)
    acc = ClusterAccumulator(10, verifier, 0.75, 0.40)
    st1 = acc.feed(ShardedEdgeSource(edges, num_docs=10))
    assert st1.pairs_evaluated == 3
    st2 = acc.feed(ShardedEdgeSource(edges, num_docs=10))
    assert st2.pairs_evaluated == 0          # served from the shared cache
    assert st2.pairs_excluded == 3
    assert acc.stats.pairs_evaluated == 3
    assert len(acc.pairs) == 3


def test_device_scored_verifier_passthrough_and_stragglers():
    rng = np.random.RandomState(7)
    sig = rng.randint(0, 50, size=(40, 100)).astype(np.uint32)
    pairs = _random_pairs(rng, 40, 200)
    host = SignatureVerifier(sig, backend="numpy")
    oracle = host(pairs)
    v = DeviceScoredEdgeVerifier(sig, backend="numpy")
    # register device scores for the first half, swapped order included
    half = pairs[:100][:, ::-1]
    v.add_scores(half, oracle[:100])
    keys = {(min(a, b), max(a, b)) for a, b in half.tolist()}
    assert v.num_scores == len(keys)
    np.testing.assert_array_equal(v(pairs), oracle)
    served = sum(1 for a, b in pairs.tolist() if (a, b) in keys)
    assert v.n_passthrough == served > 0
    assert v.n_rescored == len(pairs) - served > 0


def test_masked_indexed_pair_estimate_matches_host():
    """Deterministic kernel check (the hypothesis sweep is CI-only):
    full-M agreement where valid — bit-identical to numpy — else 0."""
    import jax.numpy as jnp

    from repro.kernels import ops as kops

    rng = np.random.RandomState(9)
    sig = rng.randint(0, 4, size=(30, 100)).astype(np.uint32)
    a = rng.randint(-30, 60, size=(500,)).astype(np.int32)
    b = rng.randint(-30, 60, size=(500,)).astype(np.int32)
    valid = (a >= 0) & (a < 30) & (b >= 0) & (b < 30)
    got = np.asarray(kops.masked_indexed_pair_estimate(
        jnp.asarray(sig), jnp.asarray(a), jnp.asarray(b),
        jnp.asarray(valid)))
    want = np.where(
        valid,
        (sig[np.clip(a, 0, 29)] == sig[np.clip(b, 0, 29)]).mean(
            axis=-1, dtype=np.float32),
        np.float32(0.0)).astype(np.float32)
    assert np.array_equal(got, want)


def test_streamed_step_single_device_matches_end_of_step():
    """Band-group streaming (G=2, 5) and the device-resident stage 2
    reproduce the end-of-step path exactly on a 1-device mesh (where
    every edge is same-shard, so stage 2 passes fully through)."""
    import jax.numpy as jnp

    from repro.core import minhash
    from repro.core.dist_lsh import (DistLSHConfig, cluster_step_output,
                                     docs_mesh, make_dedup_step,
                                     make_streamed_dedup_step)

    rng = np.random.RandomState(0)
    vocab = [f"t{i}" for i in range(300)]
    docs = [list(rng.choice(vocab, size=48)) for _ in range(24)]
    docs[5] = docs[3]
    docs[17] = docs[3][:44] + docs[17][:4]
    packed = shingle.pack_documents(docs)
    seeds = jnp.asarray(minhash.default_seeds(20))

    def run(cfg, step_factory, **kw):
        step = step_factory(cfg, docs_mesh(), **kw)
        out = step(jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
                   seeds)
        return cluster_step_output(out, cfg, tree_threshold=0.40,
                                   num_docs=24, overflow_fallback=False)

    base = dict(ngram=4, num_hashes=20, verify_k=8, edge_capacity=256,
                edge_threshold=0.5, bucket_slack=16.0)
    ref = run(DistLSHConfig(**base), make_dedup_step)
    assert ref.num_edges > 0 and ref.overflow == 0
    sims = {(a, b): s for a, b, s in ref.pairs}
    for G in (2, 5):
        for stage2 in ("host", "device"):
            res = run(DistLSHConfig(**base, band_groups=G),
                      make_streamed_dedup_step, stage2=stage2)
            assert len(res.group_stats) == G
            np.testing.assert_array_equal(res.labels(), ref.labels())
            shared = [(a, b, s) for a, b, s in res.pairs
                      if (a, b) in sims]
            assert shared
            assert all(s == sims[(a, b)] for a, b, s in shared), stage2
            if stage2 == "device":
                # 1-device mesh: all first-evaluation pairs pass through
                assert res.device_scored > 0


# -- DedupResult.num_clusters (clusters of size >= 2) ----------------------

def test_num_clusters_counts_only_multidoc_clusters():
    labels = np.array([0, 0, 1, 2, 2, 2, 3])  # sizes 2, 1, 3, 1
    res = DedupResult(
        labels=labels,
        keep_mask=np.array([1, 0, 1, 1, 0, 0, 1], dtype=bool),
        pairs=[], stats=None, uf=None,
        signatures=np.zeros((7, 1), np.uint32),
        bands=np.zeros((7, 1, 2), np.uint32))
    assert res.num_clusters == 2
    assert res.num_duplicates_removed == 3
