"""Staged dedup engine: candidate sources agree, batched verifiers match
the per-pair numpy oracle, and the engine reproduces the scalar loop."""
import numpy as np

from repro.core import jaccard, lsh, shingle
from repro.core.bandstore import Design1Store, Design2Store
from repro.core.candidates import (
    BandMatrixSource, ShardedEdgeSource, StoreBandSource, candidate_pairs,
)
from repro.core.cluster import cluster_bands
from repro.core.engine import cluster_source, merge_cluster_rounds
from repro.core.pipeline import DedupConfig, DedupPipeline, DedupResult
from repro.core.streaming import StreamingDedup
from repro.core.unionfind import ThresholdUnionFind
from repro.core.verify import (
    CallbackVerifier, ExactJaccardVerifier, ShardedEdgeVerifier,
    SignatureVerifier,
)
from repro.data import inject_near_duplicates, make_i2b2_like


def _corpus(n=60, dups=40, seed=0):
    notes = make_i2b2_like(n, seed=seed)
    notes, _ = inject_near_duplicates(notes, dups, seed=seed + 1)
    return notes


def _random_pairs(rng, d, p):
    a = rng.randint(0, d, size=p)
    b = (a + 1 + rng.randint(0, d - 1, size=p)) % d
    lo, hi = np.minimum(a, b), np.maximum(a, b)
    return np.stack([lo, hi], axis=-1).astype(np.int64)


# -- verify layer ----------------------------------------------------------

def test_signature_verifier_backends_match_per_pair_oracle():
    rng = np.random.RandomState(0)
    sig = rng.randint(0, 50, size=(40, 100)).astype(np.uint32)
    pairs = _random_pairs(rng, 40, 500)
    oracle = np.array(
        [(sig[a] == sig[b]).mean() for a, b in pairs], dtype=np.float32)
    for backend in ("numpy", "jnp", "pallas"):
        v = SignatureVerifier(sig, backend=backend, batch_pairs=128)
        np.testing.assert_allclose(v(pairs), oracle, atol=1e-6,
                                   err_msg=backend)
        assert v.n_pairs == len(pairs)
        assert v.n_batches == -(-len(pairs) // 128)


def test_exact_verifier_matches_per_pair_oracle():
    notes = _corpus()
    toks = [shingle.tokenize(t) for t in notes]
    sets = [shingle.ngram_set(t, 8) for t in toks]
    rng = np.random.RandomState(1)
    pairs = _random_pairs(rng, len(notes), 400)
    oracle = np.array(
        [jaccard.exact_jaccard(sets[a], sets[b]) for a, b in pairs],
        dtype=np.float32)
    v = ExactJaccardVerifier.from_token_lists(toks, 8, batch_pairs=64)
    np.testing.assert_allclose(v(pairs), oracle, atol=1e-6)


def test_exact_verifier_empty_and_short_docs():
    v = ExactJaccardVerifier.from_token_lists(
        [[], [], ["a", "b"], ["a", "b"], ["c"]], n=8)
    sims = v(np.array([[0, 1], [0, 2], [2, 3], [2, 4]]))
    # empty vs empty = 1.0 (matches jaccard.exact_jaccard), empty vs
    # non-empty = 0, identical short docs = 1, disjoint = 0.
    np.testing.assert_allclose(sims, [1.0, 0.0, 1.0, 0.0], atol=1e-6)


# -- candidate layer -------------------------------------------------------

def test_three_candidate_sources_identical_pairs():
    notes = _corpus()
    pipe = DedupPipeline(DedupConfig())
    bands = pipe.compute_bands(
        pipe.compute_signatures(pipe.tokenize(notes)))
    d, b, _ = bands.shape

    mem_pairs = candidate_pairs(BandMatrixSource(bands))
    assert len(mem_pairs), "corpus with injected dups must have candidates"

    s1, s2 = Design1Store(), Design2Store(part_size=16)
    for i in range(d):
        s1.insert_document(i, bands[i])
        s2.insert_document(i, bands[i])
    s1.commit()
    s2.commit()
    p1 = candidate_pairs(StoreBandSource(s1, b, d))
    p2 = candidate_pairs(StoreBandSource(s2, b, d))

    sd = StreamingDedup(DedupConfig(), chunk_docs=16)
    sd.ingest(notes)
    p3 = candidate_pairs(sd.candidate_source())

    np.testing.assert_array_equal(mem_pairs, p1)
    np.testing.assert_array_equal(mem_pairs, p2)
    np.testing.assert_array_equal(mem_pairs, p3)
    # legacy entry points delegate to the same layer
    np.testing.assert_array_equal(mem_pairs, lsh.all_candidate_pairs(bands))


# -- engine ----------------------------------------------------------------

def test_engine_batched_matches_scalar_callback():
    notes = _corpus()
    pipe = DedupPipeline(DedupConfig())
    toks = pipe.tokenize(notes)
    bands = pipe.compute_bands(pipe.compute_signatures(toks))
    sets = [shingle.ngram_set(t, 8) for t in toks]

    uf_cb, st_cb, pairs_cb = cluster_bands(
        bands, lambda a, b: jaccard.exact_jaccard(sets[a], sets[b]),
        0.75, 0.40, True)
    uf_bv, st_bv, pairs_bv = cluster_bands(
        bands, ExactJaccardVerifier.from_token_lists(toks, 8),
        0.75, 0.40, True)

    np.testing.assert_array_equal(uf_cb.components(), uf_bv.components())
    assert st_cb.pairs_evaluated == st_bv.pairs_evaluated
    assert st_cb.pairs_excluded == st_bv.pairs_excluded
    assert st_cb.unions_done == st_bv.unions_done
    assert [(a, b) for a, b, _ in pairs_cb] == \
        [(a, b) for a, b, _ in pairs_bv]
    np.testing.assert_allclose(
        [s for _, _, s in pairs_cb], [s for _, _, s in pairs_bv],
        atol=1e-6)


def test_engine_band_batch_mode_still_clusters():
    notes = make_i2b2_like(40, seed=9)
    notes = notes + [notes[0]] * 3
    pipe = DedupPipeline(DedupConfig())
    toks = pipe.tokenize(notes)
    sig = pipe.compute_signatures(toks)
    bands = pipe.compute_bands(sig)
    uf, st, _ = cluster_source(
        BandMatrixSource(bands), SignatureVerifier(sig),
        0.75, 0.40, batch="band", max_batch_pairs=64)
    labels = uf.components()
    assert labels[40] == labels[0] == labels[41] == labels[42]
    # band mode may evaluate pairs the strict mode excludes, never fewer
    _, st_run, _ = cluster_source(
        BandMatrixSource(bands), SignatureVerifier(sig), 0.75, 0.40)
    assert st.pairs_evaluated >= st_run.pairs_evaluated


def test_streaming_cluster_uses_batched_verifier():
    notes = _corpus(40, 20, seed=3)
    sd = StreamingDedup(DedupConfig(), chunk_docs=8)
    sd.ingest(notes)
    uf_b, stats = sd.cluster()
    assert stats["verify_batches"] >= 1
    # scalar-callback compat path gives the identical clustering
    cache = sd._sig_cache
    uf_s, _ = sd.cluster(
        similarity_fn=lambda a, b: float(
            (cache[a] == cache[b]).mean()))
    np.testing.assert_array_equal(uf_b.components(), uf_s.components())


def test_merge_cluster_rounds_batched_matches_scalar():
    rng = np.random.RandomState(5)
    sims = {(a, b): float(rng.uniform(0.5, 1.0))
            for a in range(8) for b in range(8) if a < b}

    def build():
        uf = ThresholdUnionFind(8, 0.3)
        for a, b in ((0, 1), (2, 3), (4, 5), (6, 7)):
            uf.union(a, b, 0.95)
        return uf

    def fn(a, b):
        return sims[(min(a, b), max(a, b))]

    uf_scalar = build()
    m1 = merge_cluster_rounds(uf_scalar, fn, 0.75)
    uf_batched = build()
    m2 = merge_cluster_rounds(uf_batched, CallbackVerifier(fn), 0.75)
    assert m1 == m2
    np.testing.assert_array_equal(
        uf_scalar.components(), uf_batched.components())


# -- sharded path layers (host-side units; device path in
# tests/test_distributed.py) -----------------------------------------------

def test_sharded_edge_source_pairs_mask_and_pad_filtering():
    # Two device buffers of capacity 3 (num_shards=2): invalid slots,
    # masked-out slots, and edges touching pad docs (id >= num_docs)
    # must all be dropped.
    inv = np.uint32(0xFFFFFFFF)
    edges = np.array([
        [0, 1], [2, 3], [inv, inv],     # device 0: two valid, one unused
        [4, 9], [4, 5], [inv, inv],     # device 1: [4, 9] touches a pad
    ], dtype=np.uint32)
    mask = np.array([1, 1, 0, 1, 1, 0], dtype=bool)
    src = ShardedEdgeSource(edges, mask, num_docs=8, num_shards=2)
    assert src.num_docs == 8
    assert src.num_bands == 2
    assert src.num_edges == 3
    np.testing.assert_array_equal(
        candidate_pairs(src), [[0, 1], [2, 3], [4, 5]])
    # every run is a two-member group
    groups = [g.tolist() for br in src.iter_bands()
              for g in br.iter_groups()]
    assert groups == [[0, 1], [2, 3], [4, 5]]


def test_sharded_edge_verifier_matches_host_estimator():
    rng = np.random.RandomState(7)
    sig = rng.randint(0, 50, size=(40, 100)).astype(np.uint32)
    pairs = _random_pairs(rng, 40, 300)
    host = SignatureVerifier(sig, backend="numpy")
    oracle = host(pairs)
    for backend in ("numpy", "jnp", "pallas"):
        v = ShardedEdgeVerifier(sig, backend=backend, batch_pairs=128)
        np.testing.assert_allclose(v(pairs), oracle, atol=1e-6,
                                   err_msg=backend)
        # bit-identical to the host verifier on the SAME backend (pallas
        # multiplies by 1/M instead of dividing, so cross-backend
        # estimates agree only to float tolerance)
        assert v.drift_count(
            pairs, SignatureVerifier(sig, backend=backend)) == 0
    # from_step_output builds from the step's returned signatures
    v = ShardedEdgeVerifier.from_step_output({"sig": sig})
    np.testing.assert_allclose(v(pairs), oracle, atol=1e-6)


def test_sharded_edges_through_engine_match_band_source():
    # Star edges of every band run, fed through ShardedEdgeSource, must
    # cluster identically to the host BandMatrixSource on the engine.
    notes = _corpus()
    pipe = DedupPipeline(DedupConfig())
    sig = pipe.compute_signatures(pipe.tokenize(notes))
    bands = pipe.compute_bands(sig)
    uf_h, _, pairs_h = cluster_source(
        BandMatrixSource(bands), SignatureVerifier(sig), 0.75, 0.40)
    edges = []
    for br in BandMatrixSource(bands).iter_bands():
        for g in br.iter_groups():
            edges += [(g[0], m) for m in g[1:]]   # member -> run head
    src = ShardedEdgeSource(np.array(edges, dtype=np.int64),
                            num_docs=len(notes))
    uf_s, _, pairs_s = cluster_source(
        src, ShardedEdgeVerifier(sig), 0.75, 0.40)
    np.testing.assert_array_equal(uf_h.components(), uf_s.components())
    sims_h = dict(((a, b), s) for a, b, s in pairs_h)
    shared = [(a, b, s) for a, b, s in pairs_s if (a, b) in sims_h]
    assert shared
    assert all(s == sims_h[(a, b)] for a, b, s in shared)


def test_cluster_source_accumulates_into_existing_uf():
    # Overflow-retry shape: a partial edge source first, then the full
    # band source into the SAME union-find recovers the full clustering.
    notes = _corpus()
    pipe = DedupPipeline(DedupConfig())
    sig = pipe.compute_signatures(pipe.tokenize(notes))
    bands = pipe.compute_bands(sig)
    uf_full, _, _ = cluster_source(
        BandMatrixSource(bands), SignatureVerifier(sig), 0.75, 0.40)

    edges = []
    for br in BandMatrixSource(bands).iter_bands():
        for g in br.iter_groups():
            edges += [(g[0], m) for m in g[1:]]
    partial = ShardedEdgeSource(
        np.array(edges[: len(edges) // 3], dtype=np.int64),
        num_docs=len(notes))
    verifier = SignatureVerifier(sig)
    uf, st1, _ = cluster_source(partial, verifier, 0.75, 0.40)
    uf2, st2, _ = cluster_source(
        BandMatrixSource(bands), verifier, 0.75, 0.40, uf=uf)
    assert uf2 is uf
    np.testing.assert_array_equal(uf.components(), uf_full.components())
    # the retry pass re-verifies at most what a fresh run would
    _, st_fresh, _ = cluster_source(
        BandMatrixSource(bands), SignatureVerifier(sig), 0.75, 0.40)
    assert st2.pairs_evaluated <= st_fresh.pairs_evaluated


# -- DedupResult.num_clusters (clusters of size >= 2) ----------------------

def test_num_clusters_counts_only_multidoc_clusters():
    labels = np.array([0, 0, 1, 2, 2, 2, 3])  # sizes 2, 1, 3, 1
    res = DedupResult(
        labels=labels,
        keep_mask=np.array([1, 0, 1, 1, 0, 0, 1], dtype=bool),
        pairs=[], stats=None, uf=None,
        signatures=np.zeros((7, 1), np.uint32),
        bands=np.zeros((7, 1, 2), np.uint32))
    assert res.num_clusters == 2
    assert res.num_duplicates_removed == 3
