"""Bounded retained state (DESIGN.md §7): eviction, Bloom compaction,
free-slot pools, and the incremental second clustering round.

The load-bearing pin: a session with retention ON (rows evicted down to
cluster representatives + an LRU window) produces clusters and verified
sims IDENTICAL to the PR 4 append-only session — eviction is lossless
because the engine only ever verifies union-find roots, and roots are
always retained.  Band-index KEY compaction (the Bloom layer) is the
only lossy mechanism and is budget-gated + counted.
"""
import numpy as np
import pytest

from repro.core import (
    BandBloomFilter,
    DedupConfig,
    DedupPipeline,
    DedupSession,
    RetentionPolicy,
)
from repro.core.engine import merge_cluster_rounds
from repro.core.session import BandIndex
from repro.core.unionfind import ThresholdUnionFind
from repro.core.verify import (
    CallbackVerifier, ExactJaccardVerifier, SignatureVerifier,
)
from repro.data import inject_near_duplicates, make_i2b2_like


def _corpus(n=48, dups=32, seed=0):
    """Near-exact duplicate mass so unions (and evictions) happen."""
    notes = make_i2b2_like(n, seed=seed)
    notes, _ = inject_near_duplicates(notes, dups, frac_low=0.0,
                                      frac_high=0.005, seed=seed + 1)
    # Interleave so duplicates land in different chunks than sources.
    rng = np.random.RandomState(seed + 2)
    order = rng.permutation(len(notes))
    return [notes[i] for i in order]


def _chunks(notes, k):
    return [[notes[i] for i in idx]
            for idx in np.array_split(np.arange(len(notes)), k)]


def _assert_same_session_outcome(snap, ref_snap):
    np.testing.assert_array_equal(snap.labels, ref_snap.labels)
    assert snap.pairs == ref_snap.pairs   # bit-identical verified sims


TIGHT = RetentionPolicy(lru_window=10, band_key_budget=None)


# -- eviction == append-only, across backends ------------------------------

@pytest.mark.parametrize("exact", [True, False])
def test_host_evicted_session_matches_append_only(exact):
    notes = _corpus()
    cfg = DedupConfig(exact_verification=exact)
    chunks = _chunks(notes, 6)
    plain = DedupSession(cfg, backend="host")
    for c in chunks:
        ref_snap = plain.ingest(c)
    sess = DedupSession(cfg, backend="host", retention=TIGHT)
    for c in chunks:
        snap = sess.ingest(c)
    _assert_same_session_outcome(snap, ref_snap)
    assert snap.evicted > 0, "budget never exercised eviction"
    assert snap.retained_rows == snap.n_docs - snap.evicted
    assert snap.filter_only_hits == 0      # no key budget -> lossless
    # representatives are exactly the current roots
    roots = sorted({int(r) for r in snap.labels})
    assert snap.representatives.tolist() == roots


def test_streaming_evicted_session_matches_append_only():
    notes = _corpus(seed=3)
    cfg = DedupConfig(exact_verification=False)
    chunks = _chunks(notes, 5)
    plain = DedupSession(cfg, backend="streaming", chunk_docs=16)
    for c in chunks:
        ref_snap = plain.ingest(c)
    sess = DedupSession(cfg, backend="streaming", chunk_docs=16,
                        retention=TIGHT)
    for c in chunks:
        snap = sess.ingest(c)
    _assert_same_session_outcome(snap, ref_snap)
    assert snap.evicted > 0


@pytest.mark.parametrize("stage2", ["host", "device"])
def test_sharded_evicted_session_matches_append_only(stage2):
    from repro.core.dist_lsh import DistLSHConfig

    rng = np.random.RandomState(0)
    vocab = [f"t{i}" for i in range(300)]
    docs = [" ".join(rng.choice(vocab, size=48)) for _ in range(32)]
    docs[5] = docs[3]
    docs[21] = docs[3]          # cross-chunk duplicate
    docs[29] = docs[11]
    cfg = DedupConfig(ngram=4, num_hashes=20, edge_threshold=0.5,
                      exact_verification=False)
    dcfg = DistLSHConfig(ngram=4, num_hashes=20, verify_k=8,
                         edge_capacity=256, edge_threshold=0.5,
                         bucket_slack=16.0, band_groups=2,
                         stage2=stage2)
    chunks = _chunks(docs, 4)
    plain = DedupSession(cfg, backend="sharded", dist_config=dcfg)
    for c in chunks:
        ref_snap = plain.ingest(c)
    sess = DedupSession(cfg, backend="sharded", dist_config=dcfg,
                        retention=RetentionPolicy(lru_window=6))
    for c in chunks:
        snap = sess.ingest(c)
    _assert_same_session_outcome(snap, ref_snap)
    assert snap.evicted > 0
    assert snap.overflow == 0
    if stage2 == "device":
        # Eviction must not push device-scored edges onto the host
        # re-score path: the no-overflow pin survives retention.
        assert snap.host_rescored == 0, snap.host_rescored


def test_evicted_session_property_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=15)
    @given(seed=st.integers(0, 2**10), n_chunks=st.integers(1, 6),
           window=st.integers(1, 40))
    def prop(seed, n_chunks, window):
        notes = _corpus(30, 20, seed=seed)
        cfg = DedupConfig(exact_verification=False)
        chunks = _chunks(notes, n_chunks)
        plain = DedupSession(cfg, backend="host")
        for c in chunks:
            ref_snap = plain.ingest(c)
        ref_snap = plain.refine()
        sess = DedupSession(
            cfg, backend="host",
            retention=RetentionPolicy(lru_window=window))
        for c in chunks:
            sess.ingest(c)
        snap = sess.refine()
        _assert_same_session_outcome(snap, ref_snap)

    prop()


# -- bounded key budget: recurrence inside the window stays exact ----------

def test_key_budget_keeps_parity_for_recurring_duplicates():
    cfg = DedupConfig(exact_verification=False)
    rng = np.random.RandomState(7)
    chunks, recent = [], []
    for t in range(6):
        fresh = make_i2b2_like(12, seed=100 + t)
        chunk = list(fresh)
        if recent:
            pool = [n for c in recent[-2:] for n in c]
            picks = rng.choice(len(pool), size=4)
            dup, _ = inject_near_duplicates(
                [pool[i] for i in picks], 4, frac_low=0.0,
                frac_high=0.005, seed=200 + t)
            chunk.extend(dup[4:])
        recent.append(fresh)
        chunks.append(chunk)
    plain = DedupSession(cfg, backend="host")
    for c in chunks:
        ref_snap = plain.ingest(c)
    sess = DedupSession(cfg, backend="host",
                        retention=RetentionPolicy(lru_window=40,
                                                  band_key_budget=48))
    for c in chunks:
        snap = sess.ingest(c)
    # Compacted keys may drop sub-threshold cross-step PAIRS (that loss
    # is the counted recall trade) but duplicates recur within the
    # window, so the CLUSTERS are identical and every shared pair's sim
    # is bit-identical.
    np.testing.assert_array_equal(snap.labels, ref_snap.labels)
    ref_sims = {(a, b): s for a, b, s in ref_snap.pairs}
    shared = [(a, b, s) for a, b, s in snap.pairs if (a, b) in ref_sims]
    assert shared and all(s == ref_sims[(a, b)] for a, b, s in shared)
    assert sess.band_index.compacted_keys > 0, \
        "budget never compacted a key"
    assert snap.evicted > 0


def test_key_budget_is_lru_hot_key_survives_churn():
    """Regression: compaction must pop the least-recently-HIT key, not
    the least-recently-inserted one.  A template note duplicated every
    chunk keeps hitting its band keys; fresh-note churn far beyond the
    key budget must compact the cold keys, never the hot ones — under
    FIFO compaction the template's chunk-1 keys were evicted and its
    recurring duplicates stopped clustering."""
    cfg = DedupConfig(exact_verification=False)
    template = make_i2b2_like(1, seed=99)[0]
    chunks = []
    for t in range(10):
        dup, _ = inject_near_duplicates([template], 1, frac_low=0.0,
                                        frac_high=0.005, seed=300 + t)
        chunks.append(make_i2b2_like(12, seed=400 + t) + [dup[1]])
    plain = DedupSession(cfg, backend="host")
    for c in chunks:
        ref_snap = plain.ingest(c)
    sess = DedupSession(cfg, backend="host",
                        retention=RetentionPolicy(lru_window=30,
                                                  band_key_budget=64))
    for c in chunks:
        snap = sess.ingest(c)
    assert sess.band_index.compacted_keys > 0   # churn exceeded budget
    np.testing.assert_array_equal(snap.labels, ref_snap.labels)
    # all 10 template dups ended in ONE cluster (ids 12, 25, 38, ...)
    dup_ids = [13 * t + 12 for t in range(10)]
    assert len({int(snap.labels[i]) for i in dup_ids}) == 1


# -- BandIndex compaction + eviction units ---------------------------------

def test_band_index_evict_rewrites_onto_root():
    idx = BandIndex(1, track_entries=True)
    b = np.array([[[1, 1]], [[1, 1]], [[2, 2]]], dtype=np.uint32)
    idx.match_then_insert(b, 0)               # docs 0, 1, 2
    uf = ThresholdUnionFind(5, 0.3)
    uf.union(0, 1, 1.0)                       # 1 deposed under 0
    idx.evict([1], uf.find)
    # doc 3 matching (1, 1) pairs with retained docs only (root 0)
    edges = idx.match_then_insert(
        np.array([[[1, 1]]], dtype=np.uint32), 3)
    assert sorted(map(tuple, edges.tolist())) == [(0, 3)]
    assert idx.filter_only_hits == 0


def test_band_index_key_budget_compacts_into_bloom():
    idx = BandIndex(1, key_budget=2, track_entries=True)
    b = np.array([[[1, 1]], [[2, 2]], [[3, 3]]], dtype=np.uint32)
    idx.match_then_insert(b, 0)               # 3 keys > budget 2
    assert idx.compacted_keys == 1            # oldest key (1, 1) compacted
    # A later doc with the compacted value: partner unknown -> counted,
    # no edge.
    edges = idx.match_then_insert(
        np.array([[[1, 1]]], dtype=np.uint32), 3)
    assert len(edges) == 0
    assert idx.filter_only_hits == 1
    # Values still exact keep producing pairs.
    edges = idx.match_then_insert(
        np.array([[[3, 3]]], dtype=np.uint32), 4)
    assert sorted(map(tuple, edges.tolist())) == [(2, 4)]
    st = idx.stats()
    assert st["compacted_keys"] == idx.compacted_keys
    assert st["bloom_bytes"] > 0


def test_bloom_filter_membership():
    flt = BandBloomFilter(bits=1 << 12, num_hashes=4)
    rng = np.random.RandomState(0)
    added = rng.randint(0, 2**31, size=(100, 2))
    absent = rng.randint(2**31, 2**32, size=(100, 2), dtype=np.int64)
    keys = [(int(a), int(b)) for a, b in np.concatenate([added, absent])]
    for k in keys[:100]:
        flt.add(k)
    assert all(k in flt for k in keys[:100]), "no false negatives, ever"
    fp = sum(1 for k in keys[100:] if k in flt)
    assert fp < 30, f"false-positive rate implausibly high: {fp}/100"
    with pytest.raises(ValueError):
        BandBloomFilter(bits=1000)            # not a power of two


# -- verifier free-slot pools ----------------------------------------------

def test_signature_verifier_free_slot_pool():
    rng = np.random.RandomState(2)
    sig = rng.randint(0, 50, size=(12, 40)).astype(np.uint32)
    v = SignatureVerifier(sig[:8].copy())
    ref = SignatureVerifier(sig)
    v.release_rows([1, 4, 6])
    assert v.n_live_rows == 5
    cap_before = len(v._buf)
    v.extend_signatures(sig[8:11])            # docs 8..10 fill 3 slots
    assert len(v._buf) == cap_before, "free slots must be reused"
    v.extend_signatures(sig[11:12])           # doc 11 appends
    assert v.n_live_rows == 9
    live_pairs = np.array([(0, 8), (2, 9), (5, 10), (3, 11), (0, 2)],
                          dtype=np.int64)
    np.testing.assert_array_equal(v(live_pairs), ref(live_pairs))
    with pytest.raises(KeyError):
        v(np.array([[0, 4]]))                 # evicted doc
    with pytest.raises(KeyError):
        v.release_rows([4])                   # double release


def test_signature_verifier_slot_pool_jnp_backend():
    rng = np.random.RandomState(5)
    sig = rng.randint(0, 50, size=(10, 40)).astype(np.uint32)
    v = SignatureVerifier(sig[:8].copy(), backend="jnp")
    ref = SignatureVerifier(sig)
    v.release_rows([2, 5])
    v.extend_signatures(sig[8:])              # docs 8, 9 reuse slots
    pairs = np.array([(0, 8), (1, 9), (3, 8)], dtype=np.int64)
    np.testing.assert_array_equal(v(pairs), ref(pairs))


def test_exact_verifier_free_slot_pool():
    notes = _corpus(20, 10, seed=9)
    toks = [n.split() for n in notes]
    ref = ExactJaccardVerifier.from_token_lists(toks, 8)
    v = ExactJaccardVerifier.from_token_lists(toks[:14], 8)
    v.release_rows([3, 7, 11])
    assert v.n_live_rows == 11
    rows_before = len(v._rows)
    v.extend_token_lists(toks[14:17])         # docs 14..16 reuse slots
    assert len(v._rows) == rows_before
    v.extend_token_lists(toks[17:])           # docs 17..29 append
    assert v.n_live_rows == len(toks) - 3
    pairs = np.array([(0, 14), (2, 16), (5, 19), (1, 2)],
                     dtype=np.int64)
    np.testing.assert_array_equal(v(pairs), ref(pairs))
    with pytest.raises(KeyError):
        v(np.array([[0, 7]]))


def test_exact_verifier_slot_pool_survives_repad():
    """A longer-than-ever doc after eviction triggers the full re-pad;
    slots and sims must survive."""
    toks = [[f"w{i}{j}" for j in range(6)] for i in range(6)]
    v = ExactJaccardVerifier.from_token_lists(toks, 2)
    ref_rows = list(toks)
    v.release_rows([1, 3])
    long_doc = [f"x{j}" for j in range(40)]   # forces lmax growth
    v.extend_token_lists([long_doc])          # doc 6 reuses a slot
    ref_rows.append(long_doc)
    ref = ExactJaccardVerifier.from_token_lists(ref_rows, 2)
    pairs = np.array([(0, 6), (2, 4), (5, 6)], dtype=np.int64)
    np.testing.assert_array_equal(v(pairs), ref(pairs))


# -- deposed-root tracking -------------------------------------------------

def test_unionfind_deposed_tracking_and_drain():
    uf = ThresholdUnionFind(6, 0.3)
    uf.track_deposed = True
    uf.union(0, 1, 1.0)
    uf.union(2, 3, 1.0)
    uf.union(0, 2, 1.0)
    drained = uf.drain_deposed()
    assert len(drained) == 3
    assert set(drained) == {i for i in range(6) if uf.find(i) != i}
    assert uf.drain_deposed() == []           # drained exactly once
    uf.union(4, 5, 1.0)
    assert len(uf.drain_deposed()) == 1
    # untracked unions log nothing
    uf2 = ThresholdUnionFind(4, 0.3)
    uf2.union(0, 1, 1.0)
    assert uf2.drain_deposed() == []


# -- incremental second clustering round -----------------------------------

def _over_partitioned_uf():
    uf = ThresholdUnionFind(8, 0.3)
    for a, b in ((0, 1), (2, 3), (4, 5), (6, 7)):
        uf.union(a, b, 0.95)
    return uf


def test_merge_cluster_rounds_candidate_pairs_matches_full_sweep():
    sims = {(0, 2): 0.9, (4, 6): 0.85}

    def fn(a, b):
        return sims.get((min(a, b), max(a, b)), 0.5)

    uf_full = _over_partitioned_uf()
    m_full = merge_cluster_rounds(uf_full, fn, 0.75)
    uf_cand = _over_partitioned_uf()
    cand = np.array([(1, 3), (5, 7), (0, 4)], dtype=np.int64)
    m_cand = merge_cluster_rounds(uf_cand, fn, 0.75,
                                  candidate_pairs=cand)
    # candidate pairs are compressed to current roots, so member-level
    # pairs drive the same root merges the full sweep finds
    assert m_cand == m_full == 2
    np.testing.assert_array_equal(uf_full.components(),
                                  uf_cand.components())


def test_merge_cluster_rounds_shared_sim_cache_skips_dispatch():
    sims = {(0, 2): 0.9}

    def fn(a, b):
        return sims.get((min(a, b), max(a, b)), 0.5)

    uf = _over_partitioned_uf()
    cache = {(0, 2): 0.9, (0, 4): 0.5}        # pre-verified by a session
    v = CallbackVerifier(fn)
    merges = merge_cluster_rounds(uf, v, 0.75, roots=[0, 2, 4, 6],
                                  sim_cache=cache, max_batch_pairs=2)
    assert merges == 1
    # (0, 2) and (0, 4) served from cache; (2, 4)/(2, 6) collapse onto
    # cached root pairs after the (0, 2) merge — only (0, 6) and (4, 6)
    # ever reach the verifier.
    assert v.n_pairs == 2
    assert (0, 6) in cache and (4, 6) in cache  # results flow back


def test_session_refine_merges_at_lower_threshold():
    """refine() re-bands representatives and merges cluster pairs whose
    reps clear the (current) edge threshold — re-thresholding an
    already-ingested session without re-hashing."""
    from dataclasses import replace

    rng = np.random.RandomState(4)
    vocab = [f"t{i}" for i in range(120)]
    base_doc = list(rng.choice(vocab, size=60))
    near = list(base_doc)
    near[30] = "zz"         # one changed token: 8-gram Jaccard ~0.74

    # two exact-duplicate pairs whose clusters are ~0.74 similar to
    # each other — below the 0.9 ingest threshold, above 0.45
    docs = [" ".join(base_doc), " ".join(base_doc),
            " ".join(near), " ".join(near)]
    cfg = DedupConfig(exact_verification=False, edge_threshold=0.9,
                      tree_threshold=0.1)
    sess = DedupSession(cfg, backend="host")
    snap = sess.ingest(docs)
    assert snap.labels[0] == snap.labels[1]
    assert snap.labels[2] == snap.labels[3]
    assert snap.labels[0] != snap.labels[2]   # over-partitioned
    sess.config = replace(cfg, edge_threshold=0.45)
    snap = sess.refine()
    assert snap.refine_merges >= 1
    assert snap.labels[0] == snap.labels[2]


def test_refine_ignores_doc_id_base_gap_singletons():
    """Regression: gap ids below the session base have blank verifier
    rows; re-banding them would collide every gap with every other gap
    at sim 1.0 and weld them into one bogus cluster."""
    notes = _corpus(20, 10, seed=19)
    base = 7
    sess = DedupSession(DedupConfig(exact_verification=False),
                        backend="host", doc_id_base=base)
    sess.ingest(notes)
    snap = sess.refine()
    assert (snap.labels[:base] == np.arange(base)).all(), \
        "gap singletons must survive refine()"
    assert all(a >= base and b >= base for a, b, _ in snap.pairs)


def test_retention_preset_none_tracks_roots_without_evicting():
    """--retain-budget none + --refine-every: the auto-refine cadence
    runs but rows stay append-only (no eviction ever)."""
    notes = _corpus(24, 16, seed=23)
    sess = DedupSession(
        DedupConfig(exact_verification=False), backend="host",
        retention=RetentionPolicy.preset("none", refine_every=2))
    for c in _chunks(notes, 4):
        snap = sess.ingest(c)
    assert sess.refines_run == 2
    assert snap.evicted == 0
    assert snap.retained_rows == snap.n_docs
    assert snap.stats.unions_done > 0       # dups clustered...
    assert sess.retention.n_pending == 0    # ...but nothing queued
    roots = sorted({int(r) for r in snap.labels})
    assert snap.representatives.tolist() == roots


def test_session_refine_auto_trigger_cadence():
    notes = _corpus(24, 12, seed=11)
    cfg = DedupConfig(exact_verification=False)
    sess = DedupSession(
        cfg, backend="host",
        retention=RetentionPolicy(lru_window=8, refine_every=2))
    for c in _chunks(notes, 4):
        sess.ingest(c)
    assert sess.refines_run == 2              # steps 2 and 4


# -- tokenized threading (store/stream tokens exactly once) ----------------

def test_ingest_stream_tokenized_never_retokenizes(monkeypatch):
    notes = _corpus(24, 12, seed=13)
    cfg = DedupConfig(exact_verification=True)
    ref = DedupSession(cfg, backend="host")
    for c in _chunks(notes, 3):
        ref_snap = ref.ingest(c)

    from repro.core import shingle
    toks = [shingle.tokenize(t) for t in notes]
    tok_chunks = [[toks[i] for i in idx]
                  for idx in np.array_split(np.arange(len(notes)), 3)]

    def boom(text, do_stem=True):
        raise AssertionError("tokenize called on pre-tokenized ingest")

    monkeypatch.setattr(shingle, "tokenize", boom)
    sess = DedupSession(cfg, backend="host")
    for snap in sess.ingest_stream(tok_chunks, tokenized=True):
        pass
    np.testing.assert_array_equal(snap.labels, ref_snap.labels)
    assert snap.pairs == ref_snap.pairs


def test_streaming_session_stores_signatures_once():
    notes = _corpus(30, 15, seed=17)
    cfg = DedupConfig(exact_verification=False)
    plain = DedupSession(cfg, backend="streaming", chunk_docs=8)
    for c in _chunks(notes, 3):
        ref_snap = plain.ingest(c)
    # The session verifier owns the rows; the phase-1 cache must not
    # keep a second copy of every signature.
    assert len(plain._impl.sd._sig_cache) == 0
    assert plain._impl.sd.n_docs == len(notes)
    # ...and the clustering is unchanged vs the pipeline reference.
    ref = DedupPipeline(cfg).run(notes)

    def canon(lab):
        first = {}
        return [first.setdefault(int(r), i) for i, r in enumerate(lab)]

    assert canon(ref_snap.labels) == canon(ref.labels)
