"""Online dedup query service: the read path over a warm session.

Pins the PR 7 contract (DESIGN.md §9):

* query-after-ingest parity — every already-ingested doc queries back
  to its own cluster root with sim 1.0, and every candidate sim the
  query reports is bit-identical to the session's recorded pair sims;
* queries never mutate session state (labels / pairs / counters /
  band-index stats before == after, asserted);
* ``SessionView`` immutability — a view taken before an ingest keeps
  answering identically after it, and its arrays are read-only;
* Bloom-compacted-key fallback — a query hitting a compacted band key
  reports ``filter_only_hits`` without touching the session counter;
* microbatched serving == sequential queries, result for result.
"""

import numpy as np
import pytest

from repro.core import (
    DedupConfig,
    DedupPipeline,
    DedupQueryService,
    DedupSession,
    QueryResult,
    RetentionPolicy,
    query_view,
)
from repro.data import inject_near_duplicates, make_i2b2_like


def _corpus(n=40, dups=25, seed=0):
    notes = make_i2b2_like(n, seed=seed)
    notes, _ = inject_near_duplicates(notes, dups, frac_low=0.0,
                                      frac_high=0.005, seed=seed + 1)
    return notes


def _warm(notes, *, exact=False, retention=None, chunks=1):
    sess = DedupSession(DedupConfig(exact_verification=exact),
                        backend="host", retention=retention)
    for idx in np.array_split(np.arange(len(notes)), chunks):
        snap = sess.ingest([notes[i] for i in idx])
    return sess, snap


def _session_state(sess):
    """Everything a query could illegally touch."""
    return (
        sess.uf.components()[: sess.n_docs].tolist(),
        list(sess.acc.pairs),
        sess.n_docs,
        sess.steps_ingested,
        sess.acc.stats.pairs_evaluated,
        sess.acc.stats.unions_done,
        sess.band_index.stats(),
        sess.band_index.filter_only_hits,
    )


# -- query-after-ingest parity ---------------------------------------------

@pytest.mark.parametrize("exact", [False, True])
def test_every_ingested_doc_queries_to_own_root_with_sim_one(exact):
    notes = _corpus()
    sess, snap = _warm(notes, exact=exact, chunks=3)
    svc = DedupQueryService(sess)
    results = svc.query(notes)
    assert len(results) == len(notes)
    for i, r in enumerate(results):
        assert r.is_duplicate, f"doc {i} not recognised"
        assert r.best_sim == 1.0
        assert r.cluster_root == int(snap.labels[i])


@pytest.mark.parametrize("exact", [False, True])
def test_candidate_sims_bit_identical_to_recorded_pairs(exact):
    notes = _corpus()
    sess, snap = _warm(notes, exact=exact, chunks=2)
    recorded = {(a, b): s for a, b, s in snap.pairs}
    svc = DedupQueryService(sess)
    overlap = 0
    for i, r in enumerate(svc.query(notes)):
        for doc, sim in r.candidates:
            key = (min(doc, i), max(doc, i))
            if key in recorded:
                overlap += 1
                assert np.float32(sim) == recorded[key], (i, doc)
    assert overlap > 0, "queries must re-evaluate recorded pairs"


def test_queries_never_mutate_session_state():
    notes = _corpus()
    sess, snap = _warm(notes, chunks=2)
    svc = DedupQueryService(sess)
    before = _session_state(sess)
    labels_before = snap.labels.copy()
    svc.query(notes)
    svc.query(["utterly novel content " * 20])
    for r in [svc.submit(t) for t in notes[:7]]:
        pass
    svc.run_until_drained()
    assert _session_state(sess) == before
    np.testing.assert_array_equal(sess.snapshot().labels, labels_before)


# -- SessionView publication protocol --------------------------------------

def test_view_cached_until_mutation_and_versioned():
    notes = _corpus(30, 15)
    sess, _ = _warm(notes)
    v1 = sess.view()
    assert sess.view() is v1
    sess.ingest(notes[:5])
    v2 = sess.view()
    assert v2 is not v1 and v2.version == v1.version + 1
    assert v2.n_docs == v1.n_docs + 5


def test_old_view_answers_identically_after_interleaved_ingest():
    notes = _corpus()
    sess, _ = _warm(notes, chunks=2)
    view = sess.view()
    pipe = DedupPipeline(sess.config)
    pipe.seeds = sess.seeds
    toks = pipe.tokenize(notes[:10])
    sig, bands = pipe.compute_arrays(toks)
    before = query_view(view, bands, sig=sig)
    # Interleave: admit brand-new near-dups of the queried docs, which
    # mutates labels, band index, signature matrix.
    sess.ingest([n + " trailing edit" for n in notes[:10]])
    sess.ingest(notes[:10])
    after = query_view(view, bands, sig=sig)
    assert before == after
    # The fresh view DOES see the new docs.
    fresh = query_view(sess.view(), bands, sig=sig)
    assert fresh != before


def test_view_arrays_are_frozen():
    notes = _corpus(20, 10)
    sess, _ = _warm(notes)
    view = sess.view()
    with pytest.raises(ValueError):
        view.labels[0] = 99
    with pytest.raises(Exception):
        view.band_maps[0].clear() if not view.band_maps[0] else \
            view.band_maps[0].popitem()[1].append(123)


def test_streaming_backend_has_no_view():
    sess = DedupSession(DedupConfig(), backend="streaming")
    sess.ingest(_corpus(10, 5))
    with pytest.raises(ValueError, match="band store"):
        sess.view()


# -- retention: eviction + Bloom compaction --------------------------------

def test_query_after_eviction_finds_cluster_via_retained_root():
    notes = _corpus(60, 40)
    pol = RetentionPolicy(lru_window=8)
    sess, snap = _warm(notes, retention=pol, chunks=6)
    assert snap.evicted > 0, "test needs actual evictions"
    view = sess.view()
    assert view.slot_of is not None  # eviction layout reached
    svc = DedupQueryService(sess)
    evicted = [d for d in range(sess.n_docs)
               if d not in view.slot_of]
    assert evicted
    for d in evicted[:5]:
        r = svc.query([notes[d]])[0]
        assert r.is_duplicate
        assert r.cluster_root == int(snap.labels[d])
        # The matched doc must be retained (candidates were rewritten
        # onto roots at eviction time).
        assert r.matched_doc in view.slot_of


def test_bloom_compacted_key_query_fallback():
    notes = _corpus(60, 10, seed=7)
    pol = RetentionPolicy(lru_window=None, band_key_budget=4)
    sess, _ = _warm(notes, retention=pol, chunks=6)
    assert sess.band_index.compacted_keys > 0
    svc = DedupQueryService(sess)
    counter_before = sess.band_index.filter_only_hits
    results = svc.query(notes)
    # Early docs' band keys were compacted into the per-band Bloom
    # filters: the query still learns "seen before, partner unnameable".
    assert sum(r.filter_only_hits for r in results) > 0
    # ...but the SESSION's counter is untouched (pure read).
    assert sess.band_index.filter_only_hits == counter_before


# -- microbatching ----------------------------------------------------------

def test_microbatch_equals_sequential_queries():
    notes = _corpus()
    sess, _ = _warm(notes, chunks=2)
    svc = DedupQueryService(sess, max_batch=4)
    queries = notes[:13] + ["novel text " * 25]
    sequential = svc.query(queries)
    rids = [svc.submit(t) for t in queries]
    finished = svc.run_until_drained()
    assert svc.stats.microbatches >= len(queries) // 4
    by_rid = {r.rid: r for r in finished}
    assert [by_rid[rid].result for rid in rids] == sequential
    assert all(by_rid[rid].done and by_rid[rid].latency_s >= 0.0
               for rid in rids)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_device_backends_match_numpy(backend):
    notes = _corpus(30, 20)
    sess, _ = _warm(notes, chunks=2)
    queries = notes[:9] + ["something else entirely " * 20]
    ref = DedupQueryService(sess, backend="numpy").query(queries)
    got = DedupQueryService(sess, backend=backend).query(queries)
    assert got == ref


# -- admit (the write path) -------------------------------------------------

def test_admit_then_query_roundtrip():
    notes = _corpus(30, 15)
    sess, snap = _warm(notes)
    svc = DedupQueryService(sess)
    novel = "previously unseen admission note " * 10
    assert not svc.query([novel])[0].is_duplicate
    snap2 = svc.admit([novel])
    assert snap2.n_docs == snap.n_docs + 1
    r = svc.query([novel])[0]
    assert r.is_duplicate and r.best_sim == 1.0
    assert r.cluster_root == int(snap2.labels[snap.n_docs])
    assert svc.stats.admitted == snap2.n_docs


# -- deprecation shims ------------------------------------------------------

def test_snapshot_uf_is_deprecated_but_live():
    sess, snap = _warm(_corpus(20, 10))
    with pytest.deprecated_call():
        # The shim's own regression test calls it on purpose.
        uf = snap.uf  # repro-lint: disable=RPR004
    assert uf is sess.uf


def test_pipeline_ingest_arrays_is_deprecated_alias():
    pipe = DedupPipeline(DedupConfig())
    toks = pipe.tokenize(_corpus(6, 3))
    with pytest.deprecated_call():
        # The shim's own regression test calls it on purpose.
        old = pipe.ingest_arrays(toks)  # repro-lint: disable=RPR004
    new = pipe.compute_arrays(toks)
    assert np.array_equal(old[0], new[0])
    assert np.array_equal(old[1], new[1])


def test_public_api_surface():
    import repro.core as core

    for name in ("DedupSession", "ClusterSnapshot", "SessionView",
                 "DedupConfig", "DistLSHConfig", "RetentionPolicy",
                 "DedupQueryService", "QueryResult", "query_view"):
        assert hasattr(core, name), name
    from repro.serving import DedupQueryService as via_serving

    assert core.DedupQueryService is via_serving


# -- query result shape -----------------------------------------------------

def test_novel_query_result_shape():
    sess, _ = _warm(_corpus(20, 10))
    r = DedupQueryService(sess).query(["nothing like the corpus " * 15])[0]
    assert r == QueryResult(is_duplicate=False, cluster_root=None,
                            best_sim=0.0, matched_doc=None,
                            n_candidates=0, filter_only_hits=0,
                            candidates=())
    assert r.novel


def test_query_view_requires_matching_operands():
    sess, _ = _warm(_corpus(20, 10), exact=False)
    view = sess.view()
    pipe = DedupPipeline(sess.config)
    toks = pipe.tokenize(["x " * 40])
    _, bands = pipe.compute_arrays(toks)
    with pytest.raises(ValueError, match="sig"):
        query_view(view, bands)  # estimate view needs sig
    with pytest.raises(ValueError):
        query_view(view, np.zeros((1, 3, 2), np.uint32), sig=None)
