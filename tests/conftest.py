"""Shared test utilities.

NOTE: XLA_FLAGS / device-count forcing is NOT set here (the dry-run owns
that); multi-device tests spawn subprocesses via ``run_with_devices``.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int, timeout: int = 600):
    """Run ``code`` in a subprocess with n forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices}")
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr[-4000:]}")
    return proc.stdout


@pytest.fixture
def tmp_ckpt_dir(tmp_path):
    return str(tmp_path / "ckpt")
