"""DedupSession: incremental multi-step ingest over every backend.

Pins the session contract: snapshot-after-every-chunk converges on the
one-shot clustering with bit-identical per-edge sims, across the host,
streaming, and (single-device here; multi-device in
tests/test_distributed.py) sharded backends — plus the growth
primitives it stands on (uf.grow, verifier extension, BandIndex,
DocIdAllocator).
"""
import numpy as np
import pytest

from repro.core import DedupConfig, DedupPipeline, DedupSession
from repro.core.engine import ClusterAccumulator
from repro.core.session import BandIndex, DocIdAllocator
from repro.core.streaming import StreamingDedup
from repro.core.unionfind import ThresholdUnionFind
from repro.core.verify import (
    CallbackVerifier, ExactJaccardVerifier, SignatureVerifier,
)
from repro.data import inject_near_duplicates, make_i2b2_like


def _corpus(n=60, dups=40, seed=0):
    notes = make_i2b2_like(n, seed=seed)
    notes, _ = inject_near_duplicates(notes, dups, seed=seed + 1)
    return notes


def _chunks(notes, k):
    return [[notes[i] for i in idx]
            for idx in np.array_split(np.arange(len(notes)), k)]


def _assert_matches_reference(snap, ref_labels, ref_pairs):
    np.testing.assert_array_equal(snap.labels, ref_labels)
    sims = {(a, b): s for a, b, s in ref_pairs}
    shared = [(a, b, s) for a, b, s in snap.pairs if (a, b) in sims]
    assert shared, "paths must evaluate overlapping pairs"
    assert all(s == sims[(a, b)] for a, b, s in shared)


# -- host backend ----------------------------------------------------------

@pytest.mark.parametrize("exact", [True, False])
@pytest.mark.parametrize("n_chunks", [1, 3])
def test_host_session_chunked_matches_one_shot(exact, n_chunks):
    notes = _corpus()
    cfg = DedupConfig(exact_verification=exact)
    ref = DedupPipeline(cfg).run(notes)
    sess = DedupSession(cfg, backend="host")
    for i, chunk in enumerate(_chunks(notes, n_chunks)):
        snap = sess.ingest(chunk)
        assert snap.n_docs == sum(
            len(c) for c in _chunks(notes, n_chunks)[: i + 1])
    _assert_matches_reference(snap, ref.labels, ref.pairs)
    assert snap.num_duplicates == ref.num_duplicates_removed
    assert snap.num_clusters == ref.num_clusters
    assert sess.steps_ingested == n_chunks


def test_host_session_snapshots_are_cumulative_and_isolated():
    notes = _corpus(40, 20, seed=3)
    sess = DedupSession(DedupConfig(exact_verification=False),
                        backend="host")
    snap1 = sess.ingest(notes[:20])
    snap2 = sess.ingest(notes[20:])
    assert snap2.n_docs == len(notes) > snap1.n_docs
    assert snap2.stats.pairs_evaluated >= snap1.stats.pairs_evaluated
    # snapshot stats are copies: later ingest must not mutate snap1
    before = snap1.stats.pairs_evaluated
    sess.ingest(notes[:5])
    assert snap1.stats.pairs_evaluated == before


def test_host_ingest_stream_equals_sequential_ingest():
    notes = _corpus(40, 20, seed=5)
    cfg = DedupConfig(exact_verification=False)
    chunks = _chunks(notes, 4)
    seq = DedupSession(cfg, backend="host")
    seq_snaps = [seq.ingest(c) for c in chunks]
    stream = DedupSession(cfg, backend="host")
    stream_snaps = list(stream.ingest_stream(chunks))
    assert len(stream_snaps) == len(seq_snaps)
    for a, b in zip(seq_snaps, stream_snaps):
        assert a.n_docs == b.n_docs
        np.testing.assert_array_equal(a.labels, b.labels)
    assert seq_snaps[-1].pairs == stream_snaps[-1].pairs


@pytest.mark.parametrize("exact", [True, False])
def test_host_session_doc_id_base_resumed_ingest(exact):
    """Regression: a doc_id_base > 0 session must verify through global
    ids (the first verifier build once covered only the chunk's rows,
    so global ids indexed past the matrix — IndexError on numpy, silent
    clamped-gather sims on jnp/pallas)."""
    notes = _corpus(30, 20, seed=13)
    base = 100
    sess = DedupSession(DedupConfig(exact_verification=exact),
                        backend="host", doc_id_base=base)
    snap1 = sess.ingest(notes[:15])
    snap = sess.ingest(notes[15:] + [notes[0]])   # cross-chunk dup
    assert snap.n_docs == base + len(notes) + 1
    ref = DedupPipeline(DedupConfig(exact_verification=exact)).run(
        notes + [notes[0]])
    np.testing.assert_array_equal(snap.labels[base:] - base, ref.labels)
    assert (snap.labels[:base] == np.arange(base)).all()  # gap singletons
    sims = {(a, b): s for a, b, s in ref.pairs}
    shared = [(a - base, b - base, s) for a, b, s in snap.pairs
              if (a - base, b - base) in sims]
    assert shared
    assert all(s == sims[(a, b)] for a, b, s in shared)
    assert snap1.stats.pairs_evaluated <= snap.stats.pairs_evaluated


# -- streaming backend -----------------------------------------------------

@pytest.mark.parametrize("n_chunks", [1, 3])
def test_streaming_session_chunked_matches_one_shot(n_chunks):
    notes = _corpus()
    cfg = DedupConfig(exact_verification=False)
    ref = DedupPipeline(cfg).run(notes)
    sess = DedupSession(cfg, backend="streaming", chunk_docs=16)
    for chunk in _chunks(notes, n_chunks):
        snap = sess.ingest(chunk)
    _assert_matches_reference(snap, ref.labels, ref.pairs)
    # the store-rescan cache never re-verifies a pair
    assert snap.stats.pairs_evaluated <= ref.stats.pairs_evaluated + \
        snap.stats.pairs_above_edge


def test_streaming_cluster_adapter_session_stays_live():
    """StreamingDedup.cluster == session over_store snapshot, and the
    underlying machinery keeps accepting chunks afterwards."""
    notes = _corpus(40, 20, seed=7)
    sd = StreamingDedup(DedupConfig(), chunk_docs=8)
    sd.ingest(notes)
    uf, stats = sd.cluster()
    from repro.core.session import DedupSession as DS

    sess = DS.over_store(sd)
    np.testing.assert_array_equal(uf.components(),
                                  sess.uf.components())
    # live continuation: a duplicate of doc 0 ingested later joins it
    snap = sess.ingest([notes[0]])
    assert snap.n_docs == len(notes) + 1
    assert snap.labels[len(notes)] == snap.labels[0]


# -- sharded backend (single-device mesh; 8-device in
# tests/test_distributed.py) ------------------------------------------------

@pytest.mark.parametrize("stage2", ["host", "device"])
def test_sharded_session_single_device_matches_host(stage2):
    from repro.core.dist_lsh import DistLSHConfig

    rng = np.random.RandomState(0)
    vocab = [f"t{i}" for i in range(300)]
    docs = [" ".join(rng.choice(vocab, size=48)) for _ in range(24)]
    docs[5] = docs[3]
    docs[21] = docs[3]                        # cross-chunk duplicate
    cfg = DedupConfig(ngram=4, num_hashes=20, edge_threshold=0.5,
                      exact_verification=False)
    ref = DedupPipeline(cfg).run(docs)
    dcfg = DistLSHConfig(ngram=4, num_hashes=20, verify_k=8,
                         edge_capacity=256, edge_threshold=0.5,
                         bucket_slack=16.0, band_groups=2,
                         stage2=stage2)
    sess = DedupSession(cfg, backend="sharded", dist_config=dcfg)
    for chunk in _chunks(docs, 2):
        snap = sess.ingest(chunk)
    _assert_matches_reference(snap, ref.labels, ref.pairs)
    assert snap.overflow == 0
    lab = snap.labels
    assert lab[3] == lab[5] == lab[21]
    if stage2 == "device":
        # 1-device mesh: every within-chunk edge is same-shard
        assert snap.device_scored > 0


# -- growth primitives -----------------------------------------------------

def test_unionfind_grow_preserves_state():
    uf = ThresholdUnionFind(4, 0.3)
    uf.union(0, 1, 0.9)
    roots_before = uf.components().copy()
    ms_before = uf.min_score.copy()
    uf.grow(8)
    assert len(uf.parent) == 8
    np.testing.assert_array_equal(uf.components()[:4], roots_before)
    np.testing.assert_array_equal(uf.min_score[:4], ms_before)
    assert all(uf.find(i) == i for i in range(4, 8))
    uf.grow(6)                                # no-op shrink attempt
    assert len(uf.parent) == 8
    uf.union(2, 7, 0.95)
    assert uf.find(2) == uf.find(7)


def test_accumulator_grow_and_per_feed_verifier_override():
    from repro.core.candidates import ShardedEdgeSource

    sims_a = {(0, 1): 0.9}
    sims_b = {(2, 3): 0.8}
    acc = ClusterAccumulator(
        2, CallbackVerifier(lambda a, b: sims_a[(a, b)]), 0.75, 0.3)
    acc.feed(ShardedEdgeSource(np.array([[0, 1]]), num_docs=2))
    acc.grow(4)
    assert acc.num_docs == 4
    acc.feed(ShardedEdgeSource(np.array([[2, 3]]), num_docs=4),
             verifier=CallbackVerifier(lambda a, b: sims_b[(a, b)]))
    assert acc.evaluated == {(0, 1): np.float32(0.9),
                             (2, 3): np.float32(0.8)}
    assert acc.uf.find(0) == acc.uf.find(1)
    assert acc.uf.find(2) == acc.uf.find(3)


def test_signature_verifier_extension_matches_full_build():
    rng = np.random.RandomState(2)
    sig = rng.randint(0, 50, size=(30, 100)).astype(np.uint32)
    pairs = np.array([(a, b) for a in range(0, 30, 3)
                      for b in range(a + 1, 30, 7)], dtype=np.int64)
    full = SignatureVerifier(sig)
    for backend in ("numpy", "jnp"):
        v = SignatureVerifier(sig[:10], backend=backend)
        v.extend_signatures(sig[10:20])
        v.extend_signatures(sig[20:])
        np.testing.assert_array_equal(v(pairs), full(pairs))
    with pytest.raises(ValueError):
        full.extend_signatures(np.zeros((2, 7), dtype=np.uint32))


def test_exact_verifier_extension_matches_full_build():
    notes = _corpus(30, 15, seed=9)
    toks = [n.split() for n in notes]
    full = ExactJaccardVerifier.from_token_lists(toks, 8)
    v = ExactJaccardVerifier.from_token_lists(toks[:10], 8)
    v.extend_token_lists(toks[10:20])
    v.extend_token_lists(toks[20:])
    pairs = np.array([(a, b) for a in range(0, 30, 3)
                      for b in range(a + 1, 30, 7)], dtype=np.int64)
    np.testing.assert_array_equal(v(pairs), full(pairs))
    raw = ExactJaccardVerifier([np.array([1, 2, 3])])
    with pytest.raises(ValueError):
        raw.extend_token_lists([["a"]])       # no vocab to intern with


def test_doc_id_allocator_and_device_offsets():
    al = DocIdAllocator(100)
    assert al.allocate(8) == 100
    assert al.allocate(4) == 108
    assert al.n_docs == 112
    np.testing.assert_array_equal(
        DocIdAllocator.device_offsets(108, 2, 4),
        np.uint32([108, 110, 112, 114]))


def test_band_index_cross_step_edges():
    idx = BandIndex(2)
    b1 = np.array([[[1, 1], [9, 9]],
                   [[2, 2], [8, 8]]], dtype=np.uint32)   # docs 0, 1
    assert len(idx.match_then_insert(b1, 0)) == 0        # nothing retained
    # doc 2 collides with doc 0 in band 0 and doc 1 in band 1;
    # doc 3 collides with doc 0 in band 0 — its same-chunk collision
    # with doc 2 is NOT emitted (the within-chunk source owns those)
    b2 = np.array([[[1, 1], [8, 8]],
                   [[1, 1], [7, 7]]], dtype=np.uint32)   # docs 2, 3
    edges = idx.match_then_insert(b2, 2)
    assert sorted(map(tuple, edges.tolist())) == \
        [(0, 2), (0, 3), (1, 2)]
    # ...but doc 2 IS retained: a third chunk colliding with it matches
    b3 = np.array([[[1, 1], [0, 0]]], dtype=np.uint32)   # doc 4
    edges = idx.match_then_insert(b3, 4)
    assert sorted(map(tuple, edges.tolist())) == \
        [(0, 4), (2, 4), (3, 4)]
    with pytest.raises(ValueError):
        idx.match_then_insert(np.zeros((1, 3, 2), np.uint32), 9)


# -- order invariance of ClusterAccumulator --------------------------------

def _run_order_invariance(seed: int, n_docs: int, n_edges: int,
                          order_seed: int):
    """Same edge multiset, shuffled feed partitions/orders -> identical
    clusters, and identical sims for every pair either order evaluates.

    Doc-pair sims are deterministic and bimodal (exact duplicates at
    1.0 vs clear non-dups below 0.5), the regime the session's
    chunk-vs-one-shot equivalence relies on: the union guard never
    fires mid-band, so clustering is pure thresholded connectivity and
    must not depend on how the engine's feeds partition the edges.
    """
    from repro.core.candidates import ShardedEdgeSource

    rng = np.random.RandomState(seed)
    group_of = rng.randint(0, max(2, n_docs // 3), size=n_docs)

    def sim(a, b):
        return 1.0 if group_of[a] == group_of[b] else \
            0.1 + 0.4 * ((a * 31 + b * 17) % 10) / 10.0

    edges = rng.randint(0, n_docs, size=(n_edges, 2))
    edges = edges[edges[:, 0] != edges[:, 1]]

    def cluster(order_rng):
        e = edges[order_rng.permutation(len(edges))]
        acc = ClusterAccumulator(n_docs, CallbackVerifier(sim),
                                 0.75, 0.3)
        n_parts = order_rng.randint(1, 5)
        for part in np.array_split(e, n_parts):
            acc.feed(ShardedEdgeSource(part, num_docs=n_docs))
        first = {}
        canon = [first.setdefault(int(r), i)
                 for i, r in enumerate(acc.uf.components())]
        return canon, dict(acc.evaluated)

    canon_a, eval_a = cluster(np.random.RandomState(order_seed))
    canon_b, eval_b = cluster(np.random.RandomState(order_seed + 1))
    assert canon_a == canon_b
    common = set(eval_a) & set(eval_b)
    assert all(eval_a[k] == eval_b[k] for k in common)
    # every edge pair with sim > threshold was clustered in both
    for a, b in edges:
        if sim(int(a), int(b)) > 0.75:
            assert canon_a[a] == canon_a[b]


@pytest.mark.parametrize("seed", range(6))
def test_cluster_accumulator_order_invariance_deterministic(seed):
    """Deterministic sweep (the hypothesis exploration is CI-only)."""
    _run_order_invariance(seed, n_docs=10 + seed, n_edges=24,
                          order_seed=seed * 7 + 1)


def test_cluster_accumulator_order_invariance_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=40)
    @given(seed=st.integers(0, 2**20), n_docs=st.integers(4, 16),
           n_edges=st.integers(1, 40), order_seed=st.integers(0, 2**20))
    def prop(seed, n_docs, n_edges, order_seed):
        _run_order_invariance(seed, n_docs, n_edges, order_seed)

    prop()
