"""Pallas kernels vs pure-jnp refs: shape/dtype sweeps (hypothesis)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@given(st.integers(1, 40), st.integers(8, 300), st.integers(2, 8))
@settings(max_examples=12, deadline=None)
def test_ngram_kernel_sweep(d, l, n):
    rng = np.random.RandomState(d * 1000 + l)
    tokens = rng.randint(0, 2**32, size=(d, l), dtype=np.uint64
                         ).astype(np.uint32)
    lengths = rng.randint(0, l + 1, size=(d,)).astype(np.int32)
    hk, vk = ops.ngram_hashes(jnp.asarray(tokens), jnp.asarray(lengths),
                              n=n)
    hr, vr = ref.ngram_hashes(jnp.asarray(tokens), jnp.asarray(lengths),
                              n=n)
    assert np.array_equal(np.asarray(vk), np.asarray(vr))
    m = np.asarray(vk)
    assert np.array_equal(np.asarray(hk)[m], np.asarray(hr)[m])


@given(st.integers(1, 30), st.integers(4, 200), st.integers(1, 130))
@settings(max_examples=12, deadline=None)
def test_minhash_kernel_sweep(d, l, m):
    rng = np.random.RandomState(d + l + m)
    ng = rng.randint(0, 2**32, size=(d, l), dtype=np.uint64
                     ).astype(np.uint32)
    valid = rng.rand(d, l) < 0.8
    seeds = rng.randint(0, 2**32, size=(m,), dtype=np.uint64
                        ).astype(np.uint32)
    got = ops.minhash_signatures(jnp.asarray(ng), jnp.asarray(valid),
                                 jnp.asarray(seeds))
    want = ref.minhash_signatures(jnp.asarray(ng), jnp.asarray(valid),
                                  jnp.asarray(seeds))
    assert np.array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(1, 50), st.integers(1, 8), st.integers(1, 30))
@settings(max_examples=12, deadline=None)
def test_bandfold_kernel_sweep(d, r, b):
    rng = np.random.RandomState(d * 7 + r)
    sig = rng.randint(0, 2**32, size=(d, r * b), dtype=np.uint64
                      ).astype(np.uint32)
    got = ops.band_values(jnp.asarray(sig), r)
    want = ref.band_values(jnp.asarray(sig), r)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@given(st.integers(1, 300), st.integers(1, 128))
@settings(max_examples=12, deadline=None)
def test_sigjaccard_kernel_sweep(p, m):
    rng = np.random.RandomState(p + m)
    a = rng.randint(0, 4, size=(p, m)).astype(np.uint32)
    b = rng.randint(0, 4, size=(p, m)).astype(np.uint32)
    got = np.asarray(ops.pair_estimate(jnp.asarray(a), jnp.asarray(b)))
    want = np.asarray(ref.pair_estimate(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(got, want, atol=1e-6)


@given(st.integers(2, 60), st.integers(1, 300), st.integers(1, 128))
@settings(max_examples=12, deadline=None)
def test_sigjaccard_masked_indexed_sweep(d, p, m):
    """Masked fused gather+estimate == numpy mean where valid, 0 elsewhere.

    Bit-identical to the host estimator (float32 division), which is
    what lets the device-resident stage-2 scores pass through the host
    merge with zero drift; out-of-range indices under an invalid mask
    must be tolerated (the cross-shard straggler lanes).
    """
    rng = np.random.RandomState(d * 31 + p + m)
    sig = rng.randint(0, 4, size=(d, m)).astype(np.uint32)
    a = rng.randint(-d, 2 * d, size=(p,)).astype(np.int32)
    b = rng.randint(-d, 2 * d, size=(p,)).astype(np.int32)
    valid = (a >= 0) & (a < d) & (b >= 0) & (b < d) & (rng.rand(p) < 0.8)
    got = np.asarray(ops.masked_indexed_pair_estimate(
        jnp.asarray(sig), jnp.asarray(a), jnp.asarray(b),
        jnp.asarray(valid)))
    want = np.zeros(p, dtype=np.float32)
    for i in range(p):
        if valid[i]:
            want[i] = (sig[a[i]] == sig[b[i]]).mean(dtype=np.float32)
    assert np.array_equal(got, want)


@given(st.integers(1, 300), st.integers(1, 128))
@settings(max_examples=12, deadline=None)
def test_sigjaccard_masked_rows_sweep(p, m):
    """Pre-gathered-operand masked counts == exact agreement counts.

    The cross-shard straggler scoring gathers one operand from the
    local signature shard and the other from the exchanged row buffer,
    so the kernel takes (P, M) rows directly; counts must be exact
    integers where valid and 0 elsewhere.
    """
    rng = np.random.RandomState(p * 13 + m)
    a = rng.randint(0, 4, size=(p, m)).astype(np.uint32)
    b = rng.randint(0, 4, size=(p, m)).astype(np.uint32)
    valid = rng.rand(p) < 0.7
    got = np.asarray(ops.masked_pair_counts(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(valid)))
    want = np.where(valid, (a == b).sum(axis=1), 0).astype(np.float32)
    assert np.array_equal(got, want)


def test_kernel_tile_size_invariance():
    rng = np.random.RandomState(0)
    ng = rng.randint(0, 2**32, size=(17, 97), dtype=np.uint64
                     ).astype(np.uint32)
    valid = rng.rand(17, 97) < 0.9
    seeds = rng.randint(0, 2**32, size=(33,), dtype=np.uint64
                        ).astype(np.uint32)
    outs = [
        np.asarray(ops.minhash_signatures(
            jnp.asarray(ng), jnp.asarray(valid), jnp.asarray(seeds),
            td=td, tl=tl, tm=tm))
        for td, tl, tm in [(8, 128, 128), (4, 32, 16), (17, 97, 33)]
    ]
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[0], outs[2])


@given(st.integers(1, 24), st.integers(1, 200), st.integers(2, 10),
       st.integers(1, 3))
@settings(max_examples=12, deadline=None)
def test_fused_ingest_sweep(d, l, n, r):
    """Fused pass bit-matches every staged reference over random shapes,
    including ragged lengths (0..L) and docs shorter than the window.

    Deterministic fused-ingest cases (edge cases, tile invariance,
    pipeline wiring) live in ``test_fused_ingest.py`` so they run even
    without hypothesis installed.
    """
    from test_fused_ingest import assert_fused_parity

    rng = np.random.RandomState(d * 131 + l * 7 + n)
    m = r * rng.randint(1, 20)  # M must be a multiple of r
    tokens = rng.randint(0, 2**32, size=(d, l), dtype=np.uint64
                         ).astype(np.uint32)
    lengths = rng.randint(0, l + 1, size=(d,)).astype(np.int32)
    seeds = rng.randint(0, 2**32, size=(m,), dtype=np.uint64
                        ).astype(np.uint32)
    assert_fused_parity(tokens, lengths, seeds, n=n, r=r)


def test_flash_attention_vs_blockwise():
    import jax
    from repro.kernels.flash_attention import flash_attention
    from repro.models.attention import blockwise_attention

    rng = jax.random.PRNGKey(0)
    for B, Sq, H, Hkv, Dh, window in [
        (2, 64, 8, 2, 16, None),
        (1, 100, 4, 4, 8, None),
        (2, 96, 8, 2, 16, 24),
        (1, 37, 6, 2, 16, None),
    ]:
        q = jax.random.normal(rng, (B, Sq, H, Dh), jnp.float32)
        k = jax.random.normal(jax.random.fold_in(rng, 1),
                              (B, Sq, Hkv, Dh), jnp.float32)
        v = jax.random.normal(jax.random.fold_in(rng, 2),
                              (B, Sq, Hkv, Dh), jnp.float32)
        got = flash_attention(q, k, v, causal=True, window=window,
                              tq=32, tk=32)
        ref = blockwise_attention(q, k, v, causal=True, window=window,
                                  block_kv=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=3e-5)


def test_flash_attention_model_integration():
    import jax
    from repro.models import lm
    from repro.models.config import ModelConfig

    cfg = ModelConfig(name="flash_t", family="dense", n_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                      vocab_size=128, param_dtype="float32",
                      compute_dtype="float32", remat="none",
                      use_flash_attention=True)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                          (2, 32), 0, 128)}
    loss_f, _ = lm.loss_fn(cfg, params, batch)
    loss_b, _ = lm.loss_fn(cfg.with_(use_flash_attention=False),
                           params, batch)
    assert abs(float(loss_f) - float(loss_b)) < 1e-4
