"""Device byte-shingle chain vs the host tokenize path, bit for bit.

The zero-copy ingest contract (DESIGN.md §11): for no-stem
tokenization, ``bytes_to_bands`` over packed UTF-8 bytes is
bit-identical (``array_equal``, never allclose) to host
``tokenize(do_stem=False)`` + ``token_ids`` + ``pack_documents`` +
``fused_ingest`` — which is what lets ``byte_ingest=True`` drop into
any session backend and the serving read path with zero drift.

Deterministic cases live here (tier-1 everywhere); the randomized text
sweep at the bottom gates on hypothesis like the other kernel sweeps.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import shingle
from repro.kernels import ops

# Mixed corpus: ASCII clinical-ish text, case folding, digits,
# multi-byte UTF-8 (2/3/4-byte sequences), empties, punctuation runs.
CORPUS = [
    "CHIEF COMPLAINT : fever . Vitals BP 120/80 , HR 92 .",
    "patient denies chest pain; möglich über café naïve",
    "температура 38.5 градусов — прием 2x daily",
    "心电图 normal ECG 🚑 stat",
    "",
    "...",
    "a",
    "A" * 40 + " " + "b2" * 30,
    "x" * 300,
]


def _host_arrays(texts, seeds, n, r, pad_len=None):
    toks = [shingle.tokenize(t, do_stem=False) for t in texts]
    width = pad_len or shingle.pow2_bucket(
        max((len(t) for t in toks), default=1))
    packed = shingle.pack_documents(toks, width)
    sig, bands, _ = ops.fused_ingest(
        jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
        jnp.asarray(seeds), n=n, r=r)
    return np.asarray(sig), np.asarray(bands)


def _byte_arrays(texts, seeds, n, r, **tiles):
    blen = shingle.pow2_bucket(
        max((len(t.encode("utf-8")) for t in texts), default=0) + 1)
    packed = shingle.pack_bytes(texts, blen)
    sig, bands, _ = ops.bytes_to_bands(
        jnp.asarray(packed.data), jnp.asarray(packed.lengths),
        jnp.asarray(seeds), n=n, r=r, **tiles)
    return np.asarray(sig), np.asarray(bands)


def _seeds(m, seed=3):
    rng = np.random.RandomState(seed)
    return rng.randint(0, 2**32, size=(m,), dtype=np.uint64
                       ).astype(np.uint32)


# -- byte tokenizer oracle vs the host tokenizer -----------------------------

def test_byte_oracle_matches_host_tokenizer():
    """`byte_token_ids_np` == token_ids(tokenize(do_stem=False)):
    byte-level boundaries reproduce the host no-stem tokenizer exactly,
    including multi-byte UTF-8 (every byte >= 0x80 is a separator, so
    boundary detection can never split inside a sequence)."""
    for text in CORPUS:
        want = shingle.token_ids(shingle.tokenize(text, do_stem=False))
        got = shingle.byte_token_ids_np(text)
        assert np.array_equal(got, want), text


def test_byte_kernel_matches_numpy_oracle():
    """Kernel (tok, ends) matrices == `byte_token_hashes_np`, including
    garbage padding beyond each row's byte length."""
    rng = np.random.RandomState(7)
    D, LB = 6, 96
    data = rng.randint(0, 256, size=(D, LB)).astype(np.uint8)
    lengths = np.array([0, 1, 40, 95, 95, 17], dtype=np.int32)
    # Garbage beyond `lengths` must be masked by the position check.
    tok_np, ends_np = shingle.byte_token_hashes_np(data, lengths)
    tok_k, ends_k = ops.byte_token_hashes(
        jnp.asarray(data), jnp.asarray(lengths))
    assert np.array_equal(np.asarray(tok_k), tok_np)
    assert np.array_equal(np.asarray(ends_k), ends_np)


def test_byte_kernel_tile_boundaries():
    """Tokens straddling the L-tile edge exercise the FNV/prev carries:
    byte lengths pinned around tlb=128 bit-match the numpy oracle."""
    texts = ["ab " * 43 + "tail",            # 133 bytes, token at edge
             "c" * 126, "d" * 127, "e" * 128, "f" * 129,
             "g" * 127 + " h"]
    blen = 256
    packed = shingle.pack_bytes(texts, blen)
    tok_np, ends_np = shingle.byte_token_hashes_np(
        packed.data, packed.lengths)
    tok_k, ends_k = ops.byte_token_hashes(
        jnp.asarray(packed.data), jnp.asarray(packed.lengths),
        td=2, tlb=128)
    assert np.array_equal(np.asarray(tok_k), tok_np)
    assert np.array_equal(np.asarray(ends_k), ends_np)


def test_byte_kernel_tile_size_invariance():
    """Tiling is an implementation detail: every (td, tlb) choice
    yields the same bits (carries persist across L revisits)."""
    packed = shingle.pack_bytes(CORPUS, 512)
    dj, lj = jnp.asarray(packed.data), jnp.asarray(packed.lengths)
    outs = [tuple(np.asarray(x) for x in
                  ops.byte_token_hashes(dj, lj, td=td, tlb=tlb))
            for td, tlb in [(8, 256), (1, 512), (9, 64), (3, 101)]]
    for got in outs[1:]:
        for g, w in zip(got, outs[0]):
            assert np.array_equal(g, w)


# -- the fused bytes->bands chain --------------------------------------------

def test_bytes_to_bands_matches_host_chain():
    seeds = _seeds(20)
    sig_h, bands_h = _host_arrays(CORPUS, seeds, n=8, r=2)
    sig_b, bands_b = _byte_arrays(CORPUS, seeds, n=8, r=2)
    assert np.array_equal(sig_b, sig_h)
    assert np.array_equal(bands_b, bands_h)


def test_bytes_to_bands_short_docs_and_odd_bands():
    """Docs shorter than the shingle window (L < n) and a non-default
    (n, r) still bit-match the host chain."""
    texts = ["one two", "a b c", "", "solo", "🚑 🚑"]
    seeds = _seeds(15, seed=5)
    sig_h, bands_h = _host_arrays(texts, seeds, n=3, r=3)
    sig_b, bands_b = _byte_arrays(texts, seeds, n=3, r=3)
    assert np.array_equal(sig_b, sig_h)
    assert np.array_equal(bands_b, bands_h)


def test_bytes_to_bands_zero_docs():
    seeds = _seeds(10)
    sig, bands, toklen = ops.bytes_to_bands(
        jnp.zeros((0, 16), jnp.uint8), jnp.zeros((0,), jnp.int32),
        jnp.asarray(seeds), n=8, r=2)
    assert sig.shape == (0, 10) and bands.shape == (0, 5, 2)
    assert toklen.shape == (0,)


def test_pack_bytes_width_validation():
    """The matrix must be strictly wider than every byte length (the
    final-token emission column)."""
    with pytest.raises(ValueError):
        shingle.pack_bytes(["abcdef"], 6)
    packed = shingle.pack_bytes(["abcdef"], 7)
    assert packed.data.shape == (1, 7)
    assert packed.lengths.tolist() == [6]


# -- config / pipeline / session wiring --------------------------------------

def test_config_rejects_exact_verification():
    from repro.core.pipeline import DedupConfig

    with pytest.raises(ValueError):
        DedupConfig(byte_ingest=True, exact_verification=True)


def test_pipeline_byte_parity():
    from repro.core.pipeline import DedupConfig, DedupPipeline
    from repro.data import inject_near_duplicates, make_i2b2_like

    notes = make_i2b2_like(18, seed=0)
    notes, _ = inject_near_duplicates(notes, 5, frac_low=0.0,
                                      frac_high=0.005, seed=1)
    tok = DedupPipeline(DedupConfig(
        fused_ingest=True, exact_verification=False))
    byt = DedupPipeline(DedupConfig(
        byte_ingest=True, exact_verification=False))
    byt.seeds = tok.seeds
    toks = [shingle.tokenize(t, do_stem=False) for t in notes]
    tok_pad = shingle.pow2_bucket(max(len(t) for t in toks))
    sig_t, bands_t = tok.compute_arrays(toks, tok_pad)
    pad = shingle.pow2_bucket(
        max(len(t.encode("utf-8")) for t in notes) + 1)
    sig_b, bands_b = byt.compute_arrays_bytes(notes, pad)
    assert np.array_equal(sig_b, sig_t)
    assert np.array_equal(bands_b, bands_t)
    assert byt.stage_timings["signature_s"] > 0
    assert byt.stage_timings["bands_s"] == 0.0


@pytest.mark.parametrize("backend", ["host", "streaming"])
def test_session_byte_parity(backend):
    """Host/streaming byte sessions produce the same labels and pair
    sims as token sessions fed no-stem token lists."""
    from repro.core.pipeline import DedupConfig
    from repro.core.session import DedupSession
    from repro.data import inject_near_duplicates, make_i2b2_like

    notes = make_i2b2_like(40, seed=0)
    notes, _ = inject_near_duplicates(notes, 8, frac_low=0.0,
                                      frac_high=0.005, seed=1)
    kw = dict(exact_verification=False, edge_threshold=0.88)
    tok_sess = DedupSession(DedupConfig(**kw), backend=backend)
    byt_sess = DedupSession(DedupConfig(byte_ingest=True, **kw),
                            backend=backend)
    for lo in range(0, len(notes), 16):
        chunk = notes[lo:lo + 16]
        snap_t = tok_sess.ingest_tokens(
            [shingle.tokenize(t, do_stem=False) for t in chunk])
        snap_b = byt_sess.ingest(chunk)
    assert snap_b.labels.tolist() == snap_t.labels.tolist()
    assert snap_b.pairs == snap_t.pairs
    _, counts = np.unique(snap_b.labels, return_counts=True)
    assert (counts >= 2).sum() > 0  # the injected dups actually merged


def test_query_service_bytes():
    """`query_bytes` answers straight from UTF-8 against a byte
    session, bit-consistent with the microbatched token route."""
    from repro.core.pipeline import DedupConfig
    from repro.core.session import DedupSession
    from repro.data import make_i2b2_like
    from repro.serving.dedup_service import DedupQueryService

    notes = list(make_i2b2_like(30, seed=2))
    sess = DedupSession(DedupConfig(
        byte_ingest=True, exact_verification=False))
    sess.ingest(notes)
    svc = DedupQueryService(sess)
    dup = svc.query(notes[:6])
    assert all(r.is_duplicate and r.best_sim == 1.0 for r in dup)
    novel = svc.query(["entirely novel prose about nothing clinical"])
    assert not novel[0].is_duplicate
    # Microbatched submit/step path agrees bit for bit.
    for t in notes[:6]:
        svc.submit(t)
    svc.run_until_drained()
    assert svc.stats.duplicates_found >= 12


def test_probe_candidates_device_parity():
    """The device searchsorted band probe returns exactly what the
    host dict walk returns (candidates AND bloom filter hits)."""
    from repro.core.pipeline import DedupConfig, DedupPipeline
    from repro.core.query import _device_probe_index, probe_candidates
    from repro.core.session import DedupSession
    from repro.data import make_i2b2_like

    notes = list(make_i2b2_like(48, seed=4))
    sess = DedupSession(DedupConfig(
        byte_ingest=True, exact_verification=False))
    sess.ingest(notes)
    view = sess.view()
    # Query bands: half ingested docs (hits), half novel (misses).
    queries = notes[:24] + [f"novel text {i} zzz" for i in range(24)]
    pipe = DedupPipeline(sess.config)
    pipe.seeds = sess.seeds
    blen = shingle.pow2_bucket(
        max(len(t.encode("utf-8")) for t in queries) + 1)
    _, bands = pipe.compute_arrays_bytes(queries, blen)
    walk = probe_candidates(view, bands, device_min_batch=10**9)
    dev = probe_candidates(view, bands, device_min_batch=8)
    assert _device_probe_index(view) is not None  # index built+cached
    for got, want in zip(dev[0], walk[0]):
        assert np.array_equal(got, want)
    assert dev[1] == walk[1]
    assert any(len(c) for c in dev[0])  # probe actually hit something


# -- randomized sweep (hypothesis-gated, like the kernel sweeps) -------------

def test_byte_oracle_hypothesis_sweep():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.text(max_size=200))
    def check(text):
        want = shingle.token_ids(shingle.tokenize(text, do_stem=False))
        got = shingle.byte_token_ids_np(text)
        assert np.array_equal(got, want)

    check()
