"""Hash-family properties (paper §3.5: hashes as random permutations)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.hashing import (
    fmix32_inverse_np, fmix32_np, hash_u32, hash_u32_np,
    make_seeds,
)

u32s = st.integers(min_value=0, max_value=2**32 - 1)


@given(st.lists(u32s, min_size=1, max_size=200))
@settings(max_examples=50, deadline=None)
def test_fmix32_bijective(xs):
    x = np.array(xs, dtype=np.uint32)
    assert np.all(fmix32_inverse_np(fmix32_np(x)) == x)


@given(st.lists(u32s, min_size=1, max_size=100), u32s)
@settings(max_examples=30, deadline=None)
def test_jax_matches_numpy(xs, seed):
    x = np.array(xs, dtype=np.uint32)
    got = np.asarray(hash_u32(jnp.asarray(x), jnp.uint32(seed)))
    want = hash_u32_np(x, np.uint32(seed))
    assert np.array_equal(got, want)


def test_seeded_hashes_are_distinct_permutations():
    seeds = make_seeds(16)
    assert len(set(seeds.tolist())) == 16
    x = np.arange(1000, dtype=np.uint32)
    cols = [hash_u32_np(x, s) for s in seeds]
    for c in cols:
        assert len(np.unique(c)) == 1000   # injective on the sample
    # different seeds give (near-)independent orderings
    ranks = [np.argsort(c) for c in cols]
    agree = np.mean(ranks[0] == ranks[1])
    assert agree < 0.01


def test_hash_uniformity():
    x = np.arange(50_000, dtype=np.uint32)
    h = hash_u32_np(x, np.uint32(123))
    # Chi-square over 256 top-byte buckets: expect ~195 per bucket.
    counts = np.bincount(h >> np.uint32(24), minlength=256)
    chi2 = (((counts - counts.mean()) ** 2) / counts.mean()).sum()
    assert chi2 < 400   # 256 dof, generous bound
