"""Model-family correctness: every family trains, and incremental decode
matches teacher-forced forward logits exactly."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models import lm, whisper
from repro.models.config import MLACfg, ModelConfig, MoECfg, SSMCfg


def tiny(name, **kw):
    base = dict(name=name, family="dense", n_layers=4, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                param_dtype="float32", compute_dtype="float32",
                remat="none")
    base.update(kw)
    return ModelConfig(**base)


FAMILIES = [
    tiny("dense"),
    tiny("moe", family="moe",
         moe=MoECfg(n_experts=8, top_k=2, n_shared=1, d_expert=64)),
    tiny("dense_moe", family="moe",
         moe=MoECfg(n_experts=4, top_k=1, n_shared=1, d_expert=64,
                    every=2)),
    tiny("mla", family="moe", n_kv_heads=4,
         mla=MLACfg(kv_lora_rank=16, q_lora_rank=24, nope_head_dim=8,
                    rope_head_dim=4, v_head_dim=8),
         moe=MoECfg(n_experts=8, top_k=2, n_shared=2, d_expert=32)),
    tiny("ssm", family="ssm", mlp="none",
         ssm=SSMCfg(d_state=16, expand=2, head_dim=8, chunk=8)),
    tiny("hybrid", family="hybrid", shared_every=2,
         ssm=SSMCfg(d_state=8, expand=2, head_dim=8, chunk=8)),
    tiny("swa", sliding_window=8),
    tiny("nonparam", norm="nonparam_ln"),
    tiny("geglu", mlp="geglu", head_dim=16),
    tiny("vlm", family="vlm", n_patches=4),
]


@pytest.mark.parametrize("cfg", FAMILIES, ids=lambda c: c.name)
def test_family_train_prefill_decode(cfg):
    B, S = 2, 16
    params, axes = lm.init(cfg, jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        axes, is_leaf=lambda x: isinstance(x, tuple))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_patches, cfg.d_model))
    loss, metrics = lm.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    cache, _ = lm.make_cache(cfg, B, 32)
    cache, logits_p = lm.prefill(cfg, params, tokens, cache,
                                 patches=batch.get("patches"))
    assert np.isfinite(np.asarray(logits_p)).all()
    total = S + (cfg.n_patches or 0)
    tok = jnp.argmax(logits_p[:, -1], -1).astype(jnp.int32)
    logits_d, cache = lm.decode(cfg, params, cache, tok,
                                jnp.full((B,), total, jnp.int32))
    assert logits_d.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits_d)).all()


@pytest.mark.parametrize(
    "cfg", [tiny("dense_c", n_layers=2),
            tiny("swa_c", n_layers=2, sliding_window=8),
            tiny("ssm_c", family="ssm", mlp="none", n_layers=2,
                 ssm=SSMCfg(d_state=16, expand=2, head_dim=8, chunk=4))],
    ids=lambda c: c.name)
def test_decode_matches_teacher_forced(cfg):
    from repro.models.lm import _embed, _head, forward

    params, _ = lm.init(cfg, jax.random.PRNGKey(3))
    T = 12
    tokens = jax.random.randint(jax.random.PRNGKey(4), (1, T), 0,
                                cfg.vocab_size)
    x = _embed(cfg, params, tokens)
    pos = jnp.arange(T, dtype=jnp.int32)[None]
    xf, _, _ = forward(cfg, params, x, pos, mode="train")
    full_logits = _head(cfg, params, xf)

    cache, _ = lm.make_cache(cfg, 1, 16)
    cache, lp = lm.prefill(cfg, params, tokens[:, :8], cache)
    np.testing.assert_allclose(np.asarray(lp[:, -1]),
                               np.asarray(full_logits[:, 7]),
                               rtol=2e-4, atol=2e-4)
    for t in range(8, T):
        lg, cache = lm.decode(cfg, params, cache, tokens[:, t],
                              jnp.array([t], jnp.int32))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full_logits[:, t]),
                                   rtol=2e-4, atol=2e-4)


def test_ssd_chunked_equals_sequential():
    from repro.models.layers import Builder
    from repro.models.ssm import (make_ssm, ssd_decode, ssd_forward,
                                  ssm_cache_shape)

    cfg = tiny("ssm_eq", family="ssm", mlp="none",
               ssm=SSMCfg(d_state=16, expand=2, head_dim=8, chunk=8))
    b = Builder(jax.random.PRNGKey(0), jnp.float32)
    make_ssm(b, cfg)
    p = dict(b.params["ssm"])
    p["a_log"] = jnp.asarray(
        np.random.RandomState(0).uniform(-1, 0.5, p["a_log"].shape),
        jnp.float32)
    B, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model))
    out_chunked, cache = ssd_forward(p, cfg, x)
    shapes = ssm_cache_shape(cfg, B)
    c = {"state": jnp.zeros(shapes["state"], jnp.float32),
         "conv": jnp.zeros(shapes["conv"], jnp.float32)}
    outs = []
    for t in range(S):
        o, c = ssd_decode(p, cfg, x[:, t:t + 1], c)
        outs.append(o)
    seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(out_chunked), np.asarray(seq),
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(cache["state"]),
                               np.asarray(c["state"]), atol=1e-3)


def test_whisper_train_and_decode_consistency():
    cfg = ModelConfig(
        name="whisper_t", family="audio", n_layers=2, d_model=32,
        n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=96, mlp="gelu",
        norm="layernorm", encdec=True, n_dec_layers=2, dec_len=12,
        param_dtype="float32", compute_dtype="float32", remat="none")
    params, _ = whisper.init(cfg, jax.random.PRNGKey(0))
    B, Se, Sd = 2, 24, 12
    frames = jax.random.normal(jax.random.PRNGKey(1), (B, Se, cfg.d_model))
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, Sd), 0,
                                cfg.vocab_size)
    loss, _ = whisper.loss_fn(cfg, params,
                              {"frames": frames, "tokens": tokens})
    assert np.isfinite(float(loss))

    from repro.models.whisper import _decoder, cross_kv, encode

    enc_out = encode(cfg, params, frames)
    full_logits, _ = _decoder(cfg, params, tokens,
                              cross_kv(cfg, params, enc_out), mode="train")
    state, lp = whisper.prefill(cfg, params, frames, tokens[:, :6])
    pad = lambda a: jnp.pad(
        a, ((0, 0), (0, 0), (0, 16 - a.shape[2]), (0, 0), (0, 0)))
    state["cache"] = jax.tree.map(pad, state["cache"])
    np.testing.assert_allclose(np.asarray(lp[:, -1]),
                               np.asarray(full_logits[:, 5]),
                               rtol=3e-4, atol=3e-4)
    for t in range(6, Sd):
        lg, state = whisper.decode(cfg, params, state, tokens[:, t],
                                   jnp.full((B,), t, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(full_logits[:, t]),
                                   rtol=3e-4, atol=3e-4)


def test_blockwise_attention_vs_reference():
    from repro.models.attention import blockwise_attention, decode_attention

    B, Sq, H, Hkv, Dh = 2, 37, 8, 2, 16
    rng = jax.random.PRNGKey(0)
    q = jax.random.normal(rng, (B, Sq, H, Dh))
    k = jax.random.normal(jax.random.fold_in(rng, 1), (B, Sq, Hkv, Dh))
    v = jax.random.normal(jax.random.fold_in(rng, 2), (B, Sq, Hkv, Dh))

    def ref_attn(window=None):
        g = H // Hkv
        kk = jnp.repeat(k, g, axis=2)
        vv = jnp.repeat(v, g, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * Dh**-0.5
        qp = jnp.arange(Sq)
        kp = jnp.arange(Sq)
        m = kp[None, :] <= qp[:, None]
        if window:
            m = m & (kp[None, :] > qp[:, None] - window)
        s = jnp.where(m[None, None], s, -jnp.inf)
        return jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)

    for blk, window in [(16, None), (8, 9), (64, None)]:
        got = blockwise_attention(q, k, v, causal=True, window=window,
                                  block_kv=blk)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref_attn(window)),
                                   atol=2e-5)
    outd = decode_attention(q[:, -1], k, v, Sq)
    np.testing.assert_allclose(np.asarray(outd),
                               np.asarray(ref_attn())[:, -1], atol=2e-5)
