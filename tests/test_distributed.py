"""Multi-device integration (subprocesses with forced host devices):
distributed LSH, EP MoE, sharded train step, dry-run smoke."""
import pytest

from tests.conftest import run_with_devices


@pytest.mark.slow
def test_dist_lsh_cross_shard_duplicates():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp, networkx as nx
        from repro.core.dist_lsh import (DistLSHConfig, docs_mesh,
                                         make_dedup_step)
        from repro.core import shingle, minhash
        rng = np.random.RandomState(0)
        vocab = [f"t{i}" for i in range(400)]
        docs = [list(rng.choice(vocab, size=64)) for _ in range(64)]
        docs[5] = docs[3]; docs[41] = docs[3]
        docs[9] = docs[3][:60] + docs[9][:4]
        packed = shingle.pack_documents(docs)
        cfg = DistLSHConfig(edge_capacity=256, edge_threshold=0.5)
        step = make_dedup_step(cfg, docs_mesh())
        out = step(jnp.asarray(packed.tokens),
                   jnp.asarray(packed.lengths),
                   jnp.asarray(minhash.default_seeds(cfg.num_hashes)))
        em = np.asarray(out["edge_mask"])
        edges = np.asarray(out["edges"])[em]
        g = nx.Graph(); g.add_edges_from(map(tuple, edges.tolist()))
        comp = nx.node_connected_component(g, 3)
        assert {3, 5, 41} <= comp, comp
        assert 9 in comp
        print("dist lsh ok")
    """, n_devices=8)


@pytest.mark.slow
def test_sharded_engine_matches_host_pipeline():
    """Ported sharded path == host path on the shared engine.

    dist_lsh prescreened edges + ShardedEdgeSource -> cluster_source
    must produce the same clusters as DedupPipeline (estimate mode) on
    the same corpus, with identical per-edge similarity estimates for
    every pair both paths evaluate (both verify against the full
    signature matrix with the same estimator).
    """
    run_with_devices("""
        from collections import defaultdict
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.dist_lsh import (DistLSHConfig, cluster_step_output,
                                         docs_mesh, make_dedup_step)
        from repro.core.pipeline import DedupConfig, DedupPipeline
        from repro.core import shingle, minhash
        from repro.data import make_i2b2_like, inject_near_duplicates
        # Clean similarity margin: near-exact dups (J >= ~0.93) vs
        # template notes (J <= ~0.8); threshold 0.88 sits in the gap so
        # the verify_k=32 prefix prescreen (recall margin 0.15) cannot
        # drop a true edge.
        notes = make_i2b2_like(56, seed=0)
        notes, _ = inject_near_duplicates(notes, 8, frac_low=0.0,
                                          frac_high=0.005, seed=1)
        host = DedupPipeline(DedupConfig(
            edge_threshold=0.88, exact_verification=False,
            verify_backend="numpy")).run(notes)

        token_lists = [shingle.tokenize(t) for t in notes]
        packed = shingle.pack_documents(token_lists)
        # bucket_slack sized so no device bucket overflows: the pure
        # sharded edge path (no host fallback) must match on its own.
        cfg = DistLSHConfig(edge_capacity=4096, edge_threshold=0.88,
                            bucket_slack=16.0)
        step = make_dedup_step(cfg, docs_mesh())
        out = step(jnp.asarray(packed.tokens),
                   jnp.asarray(packed.lengths),
                   jnp.asarray(minhash.default_seeds(cfg.num_hashes)))
        # device and host signature matrices are bit-identical
        assert np.array_equal(np.asarray(out["sig"]), host.signatures)
        res = cluster_step_output(out, cfg, tree_threshold=0.40,
                                  num_docs=len(notes),
                                  overflow_fallback=False)
        assert res.overflow == 0, res.overflow
        assert res.num_edges > 0

        # identical per-edge similarity estimates on shared pairs
        host_sims = {(a, b): s for a, b, s in host.pairs}
        shared = [(a, b, s) for a, b, s in res.pairs
                  if (a, b) in host_sims]
        assert shared, "paths must evaluate overlapping pairs"
        assert all(s == host_sims[(a, b)] for a, b, s in shared)

        def comps(labels):
            d = defaultdict(list)
            for i, l in enumerate(labels):
                d[int(l)].append(i)
            return {frozenset(v) for v in d.values() if len(v) >= 2}
        assert comps(res.labels()) == comps(host.labels)
        print("sharded engine == host ok")
    """, n_devices=8)


@pytest.mark.slow
def test_dist_lsh_doc_offsets_chunked():
    """Regression: chunked invocations must not alias global doc ids.

    The historical ``dev * d_loc + arange(d_loc)`` assignment restarted
    at 0 for every step invocation, so edges from a second corpus chunk
    collided with chunk-one ids.  ``doc_offsets`` pins the global base.
    """
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.dist_lsh import (DistLSHConfig, docs_mesh,
                                         make_dedup_step)
        from repro.core import shingle, minhash
        rng = np.random.RandomState(0)
        vocab = [f"t{i}" for i in range(300)]
        docs = [list(rng.choice(vocab, size=48)) for _ in range(8)]
        docs[7] = docs[0]          # duplicate pair inside chunk B
        packed = shingle.pack_documents(docs)
        cfg = DistLSHConfig(edge_capacity=256, edge_threshold=0.5,
                            bucket_slack=16.0)
        step = make_dedup_step(cfg, docs_mesh())
        seeds = jnp.asarray(minhash.default_seeds(cfg.num_hashes))
        args = (jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
                seeds)
        # Default offsets: contiguous row ids (the old behaviour).
        out_a = step(*args)
        em = np.asarray(out_a["edge_mask"])
        ids_a = set(np.asarray(out_a["edges"])[em].flatten().tolist())
        assert ids_a and max(ids_a) < 8, ids_a
        # Chunk B of a larger corpus, global docs 16..23: every edge id
        # must land in [16, 24) — the old scheme returned 0..7 and
        # silently collided with chunk A.
        out_b = step(*args,
                     jnp.uint32(16) + jnp.arange(8, dtype=jnp.uint32))
        em = np.asarray(out_b["edge_mask"])
        ids_b = set(np.asarray(out_b["edges"])[em].flatten().tolist())
        assert ids_b and all(16 <= i < 24 for i in ids_b), ids_b
        assert {16, 23} <= ids_b   # the injected duplicate pair
        # The host merge composes with offsets: doc_id_base shifts the
        # global edge ids back onto the chunk-local signature rows.
        from repro.core.dist_lsh import cluster_step_output
        res = cluster_step_output(out_b, cfg, tree_threshold=0.4,
                                  num_docs=8, doc_id_base=16)
        assert res.num_edges > 0
        labels = res.labels()
        assert labels[0] == labels[7], labels   # global docs 16 and 23
        print("doc offsets ok")
    """, n_devices=8)


@pytest.mark.slow
def test_band_group_streaming_matches_end_of_step():
    """Band-group streaming == the PR 2 end-of-step path, any G.

    The streamed step emits one bounded verified-edge buffer per
    band-group and cluster_step_output consumes them incrementally
    (host merge of group g overlaps the device shuffle of group g+1);
    clusters and per-edge full-signature sims must be identical to the
    single end-of-step gather, with edge drift 0.
    """
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.dist_lsh import (DistLSHConfig, cluster_step_output,
                                         docs_mesh, make_dedup_step,
                                         make_streamed_dedup_step)
        from repro.core import shingle, minhash
        from repro.data import make_i2b2_like, inject_near_duplicates
        notes = make_i2b2_like(56, seed=0)
        notes, _ = inject_near_duplicates(notes, 8, frac_low=0.0,
                                          frac_high=0.005, seed=1)
        packed = shingle.pack_documents(
            [shingle.tokenize(t) for t in notes])
        seeds = jnp.asarray(minhash.default_seeds(100))
        args = (jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
                seeds)
        base = dict(edge_capacity=4096, edge_threshold=0.88,
                    bucket_slack=16.0)
        ref_step = make_dedup_step(DistLSHConfig(**base), docs_mesh())
        ref = cluster_step_output(ref_step(*args), DistLSHConfig(**base),
                                  tree_threshold=0.40, num_docs=len(notes),
                                  overflow_fallback=False)
        assert ref.overflow == 0 and ref.num_edges > 0
        sims = {(a, b): s for a, b, s in ref.pairs}
        for G in (2, 5, 10):
            cfg = DistLSHConfig(**base, band_groups=G)
            step = make_streamed_dedup_step(cfg, docs_mesh())
            res = cluster_step_output(step(*args), cfg,
                                      tree_threshold=0.40,
                                      num_docs=len(notes),
                                      overflow_fallback=False)
            assert res.overflow == 0
            assert res.num_edges == ref.num_edges, (G, res.num_edges)
            assert len(res.group_stats) == G
            np.testing.assert_array_equal(res.labels(), ref.labels())
            shared = [(a, b, s) for a, b, s in res.pairs
                      if (a, b) in sims]
            assert shared, G
            drift = sum(1 for a, b, s in shared if s != sims[(a, b)])
            assert drift == 0, (G, drift)
        print("band-group streaming ok")
    """, n_devices=8)


@pytest.mark.slow
def test_device_stage2_passthrough_and_stragglers():
    """Device-resident stage 2 == host stage 2, bit for bit.

    Same-shard edges are fully scored on the accelerator (the fused
    sigjaccard kernel under shard_map); cross-shard edges are scored
    there too via the bounded signature-row exchange inside the
    all_to_all (``sig_row_capacity``), so with ample capacity the host
    re-score path is pinned to ZERO — and with the exchange disabled
    (capacity 0) the historical straggler re-score recovers the same
    result.  Both kinds of edges are planted; clusters and per-edge
    sims must match the end-of-step host-verified path exactly
    (drift 0).
    """
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.dist_lsh import (DistLSHConfig, cluster_step_output,
                                         docs_mesh, make_dedup_step,
                                         make_streamed_dedup_step)
        from repro.core import shingle, minhash
        rng = np.random.RandomState(0)
        vocab = [f"t{i}" for i in range(400)]
        docs = [list(rng.choice(vocab, size=64)) for _ in range(64)]
        # 8 docs/device: same-shard dups (1,5) on dev0 and (17,20) on
        # dev2; near-dup (17,22) on dev2; cross-shard dup (3,41).
        docs[5] = docs[1]; docs[20] = docs[17]; docs[41] = docs[3]
        docs[22] = docs[17][:60] + docs[22][:4]
        packed = shingle.pack_documents(docs)
        seeds = jnp.asarray(minhash.default_seeds(100))
        args = (jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
                seeds)
        base = dict(edge_capacity=4096, edge_threshold=0.5,
                    bucket_slack=16.0)
        ref_step = make_dedup_step(DistLSHConfig(**base), docs_mesh())
        ref = cluster_step_output(ref_step(*args), DistLSHConfig(**base),
                                  num_docs=64, overflow_fallback=False)
        sims = {(a, b): s for a, b, s in ref.pairs}

        def run(rc):
            cfg = DistLSHConfig(**base, band_groups=5, stage2="device",
                                sig_row_capacity=rc)
            step = make_streamed_dedup_step(cfg, docs_mesh())
            out = step(*args)
            assert all("device_match_counts" in g for g in out["groups"])
            res = cluster_step_output(out, cfg, num_docs=64,
                                      overflow_fallback=False)
            assert res.overflow == 0
            np.testing.assert_array_equal(res.labels(), ref.labels())
            lab = res.labels()
            assert lab[1] == lab[5] and lab[17] == lab[20] == lab[22]
            assert lab[3] == lab[41]
            shared = [(a, b, s) for a, b, s in res.pairs
                      if (a, b) in sims]
            assert shared
            drift = sum(1 for a, b, s in shared if s != sims[(a, b)])
            assert drift == 0, drift
            assert res.device_scored > 0, "no edge served from device"
            return res

        # Exchange on: the cross-shard dup (3, 41) is scored on-device
        # by dev0 against dev5's exchanged row — host re-scores pinned
        # to row-buffer overflow, which is zero here.
        res = run(rc=1024)
        assert res.row_overflow == 0
        assert res.host_rescored == 0, res.host_rescored
        # Exchange off: historical straggler behaviour, same clusters.
        res = run(rc=0)
        assert res.host_rescored > 0, "straggler fallback not exercised"
        print("device stage2 ok")
    """, n_devices=8)


@pytest.mark.slow
def test_device_stage2_row_buffer_overflow_falls_back_to_host():
    """Cross-shard row exchange overflow: counted, host-recovered.

    With several cross-shard duplicate pairs whose member rows live on
    one device and ``sig_row_capacity=1``, the publisher cannot fit all
    straggler rows; the overflowed edges stay uncovered, the counter
    reports them, and the host re-score path restores exactly the
    end-of-step clustering (drift 0).
    """
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.dist_lsh import (DistLSHConfig, cluster_step_output,
                                         docs_mesh, make_dedup_step,
                                         make_streamed_dedup_step)
        from repro.core import shingle, minhash
        rng = np.random.RandomState(3)
        vocab = [f"t{i}" for i in range(400)]
        docs = [list(rng.choice(vocab, size=64)) for _ in range(64)]
        # 8 docs/device: heads 1..3 on dev0, members 41..43 on dev5 —
        # three distinct member rows compete for dev5's exchange buffer.
        docs[41] = docs[1]; docs[42] = docs[2]; docs[43] = docs[3]
        packed = shingle.pack_documents(docs)
        seeds = jnp.asarray(minhash.default_seeds(100))
        args = (jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
                seeds)
        base = dict(edge_capacity=4096, edge_threshold=0.5,
                    bucket_slack=16.0)
        ref_step = make_dedup_step(DistLSHConfig(**base), docs_mesh())
        ref = cluster_step_output(ref_step(*args), DistLSHConfig(**base),
                                  num_docs=64, overflow_fallback=False)
        cfg = DistLSHConfig(**base, stage2="device", sig_row_capacity=1)
        step = make_streamed_dedup_step(cfg, docs_mesh())
        res = cluster_step_output(step(*args), cfg, num_docs=64,
                                  overflow_fallback=False)
        assert res.overflow == 0
        assert res.row_overflow > 0, "row buffer should have overflowed"
        assert res.host_rescored > 0, "overflowed edges must re-score"
        np.testing.assert_array_equal(res.labels(), ref.labels())
        lab = res.labels()
        assert lab[1] == lab[41] and lab[2] == lab[42] \\
            and lab[3] == lab[43]
        sims = {(a, b): s for a, b, s in ref.pairs}
        shared = [(a, b, s) for a, b, s in res.pairs if (a, b) in sims]
        assert shared
        assert all(s == sims[(a, b)] for a, b, s in shared)
        print("row overflow ok")
    """, n_devices=8)


@pytest.mark.slow
def test_session_multistep_sharded_matches_single_step():
    """N-step chunked ingest through ONE DedupSession == single-step.

    The session feeds N streamed step invocations (chunked corpus,
    allocator-assigned ``doc_offsets``) into one ClusterAccumulator,
    generating cross-chunk candidates from the retained band index;
    clusters and per-edge sims must be identical / bit-identical to the
    PR 3 single-step path over the concatenated corpus, for N in
    {2, 4}, with and without the device-resident stage 2.  With the
    cross-shard row exchange on and no overflow anywhere, the device
    path's host re-scores stay pinned at zero (overflow-only).
    """
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import DedupConfig, DedupSession
        from repro.core.dist_lsh import (DistLSHConfig, cluster_step_output,
                                         docs_mesh, make_dedup_step)
        from repro.core import shingle, minhash
        from repro.data import make_i2b2_like, inject_near_duplicates
        notes = make_i2b2_like(56, seed=0)
        notes, _ = inject_near_duplicates(notes, 8, frac_low=0.0,
                                          frac_high=0.005, seed=1)
        packed = shingle.pack_documents(
            [shingle.tokenize(t) for t in notes])
        base = dict(edge_capacity=4096, edge_threshold=0.88,
                    bucket_slack=16.0)
        ref_step = make_dedup_step(DistLSHConfig(**base), docs_mesh())
        out = ref_step(jnp.asarray(packed.tokens),
                       jnp.asarray(packed.lengths),
                       jnp.asarray(minhash.default_seeds(100)))
        ref = cluster_step_output(out, DistLSHConfig(**base),
                                  tree_threshold=0.40,
                                  num_docs=len(notes),
                                  overflow_fallback=False)
        assert ref.overflow == 0 and ref.num_edges > 0
        sims = {(a, b): s for a, b, s in ref.pairs}
        cfg = DedupConfig(edge_threshold=0.88, exact_verification=False)
        for stage2 in ("host", "device"):
            for n_steps in (2, 4):
                dcfg = DistLSHConfig(**base, band_groups=5,
                                     stage2=stage2)
                sess = DedupSession(cfg, backend="sharded",
                                    dist_config=dcfg)
                chunks = [[notes[i] for i in idx] for idx in
                          np.array_split(np.arange(len(notes)),
                                         n_steps)]
                snaps = list(sess.ingest_stream(chunks))
                assert len(snaps) == n_steps
                assert [s.n_docs for s in snaps] == list(
                    np.cumsum([len(c) for c in chunks]))
                snap = snaps[-1]
                assert snap.overflow == 0 and snap.row_overflow == 0
                np.testing.assert_array_equal(snap.labels,
                                              ref.labels())
                shared = [(a, b, s) for a, b, s in snap.pairs
                          if (a, b) in sims]
                assert shared, (stage2, n_steps)
                drift = sum(1 for a, b, s in shared
                            if s != sims[(a, b)])
                assert drift == 0, (stage2, n_steps, drift)
                if stage2 == "device":
                    # cross-shard exchange on, nothing overflowed:
                    # host re-scores are overflow-only == 0.
                    assert snap.host_rescored == 0, snap.host_rescored
        print("session multistep ok")
    """, n_devices=8)


@pytest.mark.slow
def test_session_fused_ingest_matches_staged_sharded():
    """Fused one-pass device ingest == staged, through a sharded session.

    The fused shingle->minhash->band-fold kernel feeds the all_to_all
    shuffle in ``local_prepare``; with bit-identical signatures and band
    values the whole downstream pipeline (candidate shuffle, prescreen,
    stage 2, host merge) must produce identical clusters and
    bit-identical per-edge sims vs the staged chain — N-step ingest,
    stage2 host AND device, with the device path's host re-scores
    pinned at zero (overflow-only).  The device-stage2 cell runs once
    (n_steps=2, band_groups=1): interpret-mode device scoring costs
    minutes per session, and ingest parity is stage2-independent.
    """
    run_with_devices("""
        import numpy as np
        from repro.core import DedupConfig, DedupSession
        from repro.core.dist_lsh import DistLSHConfig
        from repro.data import make_i2b2_like, inject_near_duplicates
        notes = make_i2b2_like(56, seed=0)
        notes, _ = inject_near_duplicates(notes, 8, frac_low=0.0,
                                          frac_high=0.005, seed=1)
        base = dict(edge_capacity=4096, edge_threshold=0.88,
                    bucket_slack=16.0)
        cfg = DedupConfig(edge_threshold=0.88, exact_verification=False)
        for stage2, n_steps, groups in [("host", 1, 5), ("host", 3, 5),
                                        ("device", 2, 1)]:
            chunks = [[notes[i] for i in idx] for idx in
                      np.array_split(np.arange(len(notes)), n_steps)]
            snaps = {}
            for fused in (False, True):
                dcfg = DistLSHConfig(**base, stage2=stage2,
                                     band_groups=groups,
                                     fused_ingest=fused)
                sess = DedupSession(cfg, backend="sharded",
                                    dist_config=dcfg)
                for snap in sess.ingest_stream(chunks):
                    pass
                assert snap.overflow == 0
                snaps[fused] = snap
            a, b = snaps[False], snaps[True]
            np.testing.assert_array_equal(a.labels, b.labels)
            pa = {(x, y): s for x, y, s in a.pairs}
            pb = {(x, y): s for x, y, s in b.pairs}
            assert pa and pa == pb, (stage2, n_steps)
            if stage2 == "device":
                assert b.host_rescored == 0, b.host_rescored
        print("fused sharded parity ok")
    """, n_devices=8)


@pytest.mark.slow
def test_session_byte_ingest_matches_token_sharded():
    """Device bytes->bands == host no-stem tokenize, sharded N-step.

    A ``byte_ingest`` sharded session consumes raw UTF-8 texts (the
    zero-copy path: uint8 bytes are the only host->device transfer and
    tokenize/shingle/minhash/band-fold run in ``local_prepare`` on
    device); a fused token session consumes the matching
    ``tokenize(do_stem=False)`` lists.  Bit-identical signatures and
    band values mean the whole downstream pipeline must agree: labels
    identical, per-edge sims bit-identical, and the device-stage2
    cell's host re-scores pinned at zero (overflow-only), across
    N-step ingest.  Same cell set as the fused-vs-staged pin.
    """
    run_with_devices("""
        import numpy as np
        from repro.core import DedupConfig, DedupSession
        from repro.core.dist_lsh import DistLSHConfig
        from repro.core import shingle
        from repro.data import make_i2b2_like, inject_near_duplicates
        notes = make_i2b2_like(56, seed=0)
        notes, _ = inject_near_duplicates(notes, 8, frac_low=0.0,
                                          frac_high=0.005, seed=1)
        base = dict(edge_capacity=4096, edge_threshold=0.88,
                    bucket_slack=16.0)
        for stage2, n_steps, groups in [("host", 1, 5), ("host", 3, 5),
                                        ("device", 2, 1)]:
            idx_chunks = np.array_split(np.arange(len(notes)), n_steps)
            snaps = {}
            for byte in (False, True):
                dcfg = DistLSHConfig(**base, stage2=stage2,
                                     band_groups=groups,
                                     fused_ingest=not byte,
                                     byte_ingest=byte)
                cfg = DedupConfig(edge_threshold=0.88,
                                  exact_verification=False,
                                  byte_ingest=byte)
                sess = DedupSession(cfg, backend="sharded",
                                    dist_config=dcfg)
                if byte:
                    chunks = [[notes[i] for i in idx]
                              for idx in idx_chunks]
                    stream = sess.ingest_stream(chunks)
                else:
                    chunks = [[shingle.tokenize(notes[i], do_stem=False)
                               for i in idx] for idx in idx_chunks]
                    stream = sess.ingest_stream(chunks, tokenized=True)
                for snap in stream:
                    pass
                assert snap.overflow == 0 and snap.row_overflow == 0
                snaps[byte] = snap
            a, b = snaps[False], snaps[True]
            np.testing.assert_array_equal(a.labels, b.labels)
            pa = {(x, y): s for x, y, s in a.pairs}
            pb = {(x, y): s for x, y, s in b.pairs}
            assert pa and pa == pb, (stage2, n_steps)
            if stage2 == "device":
                assert b.host_rescored == 0, b.host_rescored
        print("byte sharded parity ok")
    """, n_devices=8)


@pytest.mark.slow
def test_session_eviction_multidevice_keeps_parity_and_device_scoring():
    """Bounded retention on the 8-device sharded backend.

    A tight LRU window evicts retained rows between steps (and between
    band-group merges, via the feed hook); clusters and per-edge sims
    must stay identical to the append-only session, and with
    stage2="device" + the sig-row exchange the host re-score path must
    stay pinned at ZERO on the no-overflow path — eviction never evicts
    a row the device-scoring merge still needs.
    """
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import DedupConfig, DedupSession, RetentionPolicy
        from repro.core.dist_lsh import DistLSHConfig, docs_mesh
        from repro.data import make_i2b2_like, inject_near_duplicates
        notes = make_i2b2_like(56, seed=0)
        notes, _ = inject_near_duplicates(notes, 8, frac_low=0.0,
                                          frac_high=0.005, seed=1)
        # Interleave so duplicate pairs complete in EARLY chunks —
        # their deposed roots age out of the LRU window and evict.
        order = np.random.RandomState(2).permutation(len(notes))
        notes = [notes[i] for i in order]
        cfg = DedupConfig(edge_threshold=0.88, exact_verification=False)
        base = dict(edge_capacity=4096, edge_threshold=0.88,
                    bucket_slack=16.0, band_groups=2)
        # Two equal-size chunks: one compiled step shape, four feeds.
        chunks = [[notes[i] for i in idx] for idx in
                  np.array_split(np.arange(len(notes)), 2)]
        for stage2 in ("host", "device"):
            dcfg = DistLSHConfig(**base, stage2=stage2)
            plain = DedupSession(cfg, backend="sharded",
                                 dist_config=dcfg)
            for c in chunks:
                ref = plain.ingest(c)
            sess = DedupSession(cfg, backend="sharded",
                                dist_config=dcfg,
                                retention=RetentionPolicy(lru_window=8))
            for c in chunks:
                snap = sess.ingest(c)
            assert snap.overflow == 0 and snap.row_overflow == 0
            assert snap.evicted > 0, "eviction never ran"
            np.testing.assert_array_equal(snap.labels, ref.labels)
            assert snap.pairs == ref.pairs
            if stage2 == "device":
                assert snap.device_scored > 0
                assert snap.host_rescored == 0, snap.host_rescored
        print("session eviction multidevice ok")
    """, n_devices=8)


@pytest.mark.slow
def test_dist_lsh_overflow_retry_through_engine():
    """Device buffer overflow falls back through the same engine.

    With a tiny edge buffer the device step drops prescreened edges
    (counted, never silent); cluster_step_output must detect the
    overflow and recover the full clustering by re-deriving candidates
    on the host from the step's own signatures.
    """
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.dist_lsh import (DistLSHConfig, cluster_step_output,
                                         docs_mesh, make_dedup_step)
        from repro.core import shingle, minhash
        rng = np.random.RandomState(1)
        vocab = [f"t{i}" for i in range(300)]
        docs = [list(rng.choice(vocab, size=48)) for _ in range(32)]
        for i in range(1, 10):
            docs[i] = docs[0]      # 10-way duplicate group
        packed = shingle.pack_documents(docs)
        cfg = DistLSHConfig(edge_capacity=2, edge_threshold=0.5,
                            bucket_slack=16.0)
        step = make_dedup_step(cfg, docs_mesh())
        out = step(jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
                   jnp.asarray(minhash.default_seeds(cfg.num_hashes)))
        res = cluster_step_output(out, cfg, tree_threshold=0.4,
                                  num_docs=32)
        assert res.overflow > 0 and res.retried
        labels = res.labels()
        assert len({int(labels[i]) for i in range(10)}) == 1, labels[:10]
        # without the fallback the dropped edges fragment the cluster
        res_no = cluster_step_output(out, cfg, tree_threshold=0.4,
                                     num_docs=32, overflow_fallback=False)
        assert not res_no.retried
        assert res_no.stats.unions_done <= res.stats.unions_done
        print("overflow retry ok")
    """, n_devices=8)


@pytest.mark.slow
def test_ep_moe_matches_global():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.models.config import ModelConfig, MoECfg
        from repro.models.layers import Builder
        from repro.models.moe import make_moe, moe_ffn
        from repro.models.moe_sharded import moe_ffn_ep
        from repro.models import sharding as shlib
        cfg = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                          n_heads=4, n_kv_heads=2, d_ff=64,
                          vocab_size=128,
                          moe=MoECfg(n_experts=8, top_k=2, n_shared=1,
                                     d_expert=48, capacity_factor=8.0),
                          param_dtype="float32",
                          compute_dtype="float32")
        b = Builder(jax.random.PRNGKey(0), jnp.float32)
        make_moe(b, cfg); p = b.params["moe"]
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        ref, _ = moe_ffn(p, cfg, x)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        with shlib.activate(mesh):
            out, _ = jax.jit(lambda p_, x_: moe_ffn_ep(p_, cfg, x_))(p, x)
            g1 = jax.jit(jax.grad(
                lambda p_: jnp.sum(moe_ffn_ep(p_, cfg, x)[0]**2)))(p)
        g0 = jax.grad(lambda p_: jnp.sum(moe_ffn(p_, cfg, x)[0]**2))(p)
        assert np.abs(np.asarray(out) - np.asarray(ref)).max() < 1e-4
        for k in g0:
            assert np.abs(np.asarray(g1[k]) - np.asarray(g0[k])).max() \
                < 1e-3, k
        print("ep moe ok")
    """, n_devices=4)


@pytest.mark.slow
def test_sharded_train_step_runs_and_matches_single_device():
    run_with_devices("""
        import numpy as np, jax, jax.numpy as jnp
        from repro import optim
        from repro.configs import get_reduced
        from repro.launch.mesh import make_test_mesh
        from repro.models.sharding import activate
        from repro.training.step import (TrainConfig, init_state,
                                         make_train_step,
                                         shard_train_step)
        cfg = get_reduced("olmo-1b")
        tcfg = TrainConfig(adamw=optim.AdamWConfig(lr=1e-3),
                           warmup_steps=1)
        state, axes = init_state(cfg, tcfg, jax.random.PRNGKey(0))
        batch = {"tokens": np.random.RandomState(0).randint(
            0, cfg.vocab_size, (4, 16)).astype(np.int32)}
        ref_state, ref_m = jax.jit(make_train_step(cfg, tcfg))(
            jax.tree.map(jnp.copy, state), dict(batch))
        mesh = make_test_mesh((2, 2), ("data", "model"))
        with activate(mesh):
            fn = shard_train_step(cfg, tcfg, mesh, axes, batch,
                                  donate=False)
            new_state, m = fn(state, batch)
        assert abs(float(m["loss"]) - float(ref_m["loss"])) < 1e-4
        d = jax.tree.map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))),
            new_state["params"], ref_state["params"])
        assert max(jax.tree.leaves(d)) < 1e-4
        print("sharded train ok")
    """, n_devices=4)


@pytest.mark.slow
def test_dryrun_reduced_all_cells_small_mesh():
    run_with_devices("""
        from repro.launch import dryrun
        for arch in ("olmo-1b", "deepseek-v2-236b", "mamba2-780m",
                     "zamba2-2.7b", "whisper-medium", "h2o-danube-1.8b"):
            for cell in ("train_4k", "prefill_32k", "decode_32k",
                         "long_500k"):
                rec = dryrun.run_cell(
                    arch, cell, multi_pod=False, reduced=True,
                    mesh_override=__import__(
                        "repro.launch.mesh",
                        fromlist=["make_test_mesh"]).make_test_mesh(
                            (2, 2), ("data", "model")))
                assert rec["status"] in ("ok",) or \
                    rec["status"].startswith("skip"), rec
        print("dryrun smoke ok")
    """, n_devices=4, timeout=1200)


def test_hlo_parse_trip_counts():
    import jax
    import jax.numpy as jnp

    from repro.launch.hlo_parse import analyze

    d = 128
    ws = jnp.zeros((10, d, d))
    x = jnp.zeros((d, d))

    def body(x, w):
        return x @ w, None

    def scanned(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y

    c = jax.jit(scanned).lower(x, ws).compile()
    st = analyze(c.as_text())
    assert abs(st.flops - 2 * 10 * d**3) / (2 * 10 * d**3) < 1e-6
