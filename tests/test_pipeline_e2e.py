"""End-to-end dedup behaviour on planted duplicates (replaces the
scaffold test_system placeholder)."""
import numpy as np

from repro.core.pipeline import DedupConfig, DedupPipeline
from repro.data.corpus import (
    accuracy_testset, inject_near_duplicates, make_i2b2_like, perturb,
)


def test_exact_duplicates_all_removed():
    notes = make_i2b2_like(50, seed=0)
    notes = notes + [notes[0]] * 4 + [notes[7]] * 2
    res = DedupPipeline(DedupConfig()).run(notes)
    labels = res.labels
    assert len({labels[0], labels[50], labels[51], labels[52],
                labels[53]}) == 1
    assert len({labels[7], labels[54], labels[55]}) == 1
    assert res.num_duplicates_removed >= 6
    assert res.keep_mask.sum() == len(notes) - res.num_duplicates_removed


def test_near_duplicates_recall_at_paper_settings():
    """Paper §9.1 protocol: 10%-perturbed notes; r=2 b=50; recall ~1."""
    notes, srcs = accuracy_testset(seed=1)
    # At 10% word change, 8-gram Jaccard is ~0.2-0.5 -> use edge 0.2.
    res = DedupPipeline(DedupConfig(
        edge_threshold=0.2, tree_threshold=0.15)).run(notes)
    labels = res.labels
    found = sum(
        1 for k, src in enumerate(srcs)
        if labels[521 + k] == labels[src])
    assert found >= 9, f"recall {found}/10"


def test_unrelated_notes_not_merged():
    notes = make_i2b2_like(80, seed=2)
    res = DedupPipeline(DedupConfig()).run(notes)
    # Template-heavy corpus may share boilerplate, but distinct notes at
    # threshold 0.75 should essentially all survive.
    assert res.num_duplicates_removed <= 2


def test_signature_estimate_verification_mode():
    notes = make_i2b2_like(40, seed=3)
    notes, _ = inject_near_duplicates(notes, 30, frac_low=0.0,
                                      frac_high=0.05, seed=4)
    exact = DedupPipeline(DedupConfig(exact_verification=True)).run(notes)
    est = DedupPipeline(DedupConfig(exact_verification=False)).run(notes)
    # estimated-Jaccard mode finds nearly the same duplicate set
    agree = (exact.keep_mask == est.keep_mask).mean()
    assert agree > 0.9


def test_pallas_path_matches_jnp_path():
    notes = make_i2b2_like(30, seed=7)
    notes = notes + [notes[0], perturb(notes[1], 0.02,
                                       np.random.RandomState(0))]
    a = DedupPipeline(DedupConfig(use_pallas=False)).run(notes)
    b = DedupPipeline(DedupConfig(use_pallas=True)).run(notes)
    assert np.array_equal(a.signatures, b.signatures)
    assert np.array_equal(a.keep_mask, b.keep_mask)
