"""Disjoint-set clustering invariants (paper §6) — the central guarantee:
every pair inside a cluster has Jaccard >= tree_threshold."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import jaccard, shingle
from repro.core.cluster import cluster_bands
from repro.core.unionfind import (
    ThresholdUnionFind, connected_components, cluster_min_score_audit,
)
from repro.data.corpus import make_i2b2_like, inject_near_duplicates


def test_triangle_inequality_property():
    """Jaccard distance is a metric (paper §6.1, Lipkus 1999)."""
    rng = np.random.RandomState(0)
    universe = list(range(50))
    for _ in range(200):
        a = set(rng.choice(universe, rng.randint(1, 40), replace=False))
        b = set(rng.choice(universe, rng.randint(1, 40), replace=False))
        c = set(rng.choice(universe, rng.randint(1, 40), replace=False))
        dab = jaccard.jaccard_distance(a, b)
        dbc = jaccard.jaccard_distance(b, c)
        dac = jaccard.jaccard_distance(a, c)
        assert dab + dbc >= dac - 1e-12


@given(st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_tree_threshold_guarantee(seed):
    """Any two documents in one cluster have exact Jaccard >= threshold."""
    rng = np.random.RandomState(seed)
    n = 24
    universe = list(range(60))
    sets = [set(rng.choice(universe, rng.randint(5, 50), replace=False))
            for _ in range(n)]
    tree_t = 0.4
    uf = ThresholdUnionFind(n, tree_t)
    # Union random pairs with their exact similarity, in random order.
    for _ in range(80):
        i, j = rng.randint(n), rng.randint(n)
        if i == j:
            continue
        ri, rj = uf.find(i), uf.find(j)
        if ri == rj:
            continue
        sim = jaccard.exact_jaccard(sets[ri], sets[rj])
        if sim > 0.5:   # edge threshold
            uf.union(i, j, sim)
    labels = uf.components()
    for i in range(n):
        for j in range(i + 1, n):
            if labels[i] == labels[j]:
                s = jaccard.exact_jaccard(sets[i], sets[j])
                assert s >= tree_t - 1e-9, (i, j, s)


def test_union_respects_threshold_rejection():
    uf = ThresholdUnionFind(3, tree_threshold=0.8)
    assert uf.union(0, 1, 0.9)
    # 0-1 bound now 0.9; adding 2 with sim 0.85 to the root gives
    # leaf-to-leaf 0.9 + 1.0 + 0.85 - 2 = 0.75 < 0.8 -> reject.
    assert not uf.union(1, 2, 0.85)
    assert uf.n_rejected == 1


def test_parallel_cc_matches_networkx():
    import networkx as nx

    rng = np.random.RandomState(3)
    n, e = 200, 300
    edges = rng.randint(0, n, size=(e, 2)).astype(np.int32)
    mask = rng.rand(e) < 0.7
    labels = np.asarray(connected_components(
        jnp.asarray(edges), jnp.asarray(mask), n))
    g = nx.Graph()
    g.add_nodes_from(range(n))
    g.add_edges_from(edges[mask])
    want = {}
    for comp in nx.connected_components(g):
        rep = min(comp)
        for v in comp:
            want[v] = rep
    got = {}
    for v in range(n):
        got.setdefault(labels[v], set()).add(v)
    comps_got = {frozenset(c) for c in got.values()}
    comps_want = {frozenset(c) for c in nx.connected_components(g)}
    assert comps_got == comps_want


def test_cluster_bands_excludes_pairs_and_matches_paper_shape():
    """§6.5: clustering reduces Jaccard evaluations vs no clustering."""
    from repro.core.pipeline import DedupConfig, DedupPipeline

    notes = make_i2b2_like(60, seed=5)
    notes, _ = inject_near_duplicates(notes, 60, seed=6)
    pipe = DedupPipeline(DedupConfig(edge_threshold=0.75))
    toks = pipe.tokenize(notes)
    sig = pipe.compute_signatures(toks)
    bands = pipe.compute_bands(sig)
    sets = [shingle.ngram_set(t, 8) for t in toks]
    simfn = lambda a, b: jaccard.exact_jaccard(sets[a], sets[b])

    uf_on, st_on, _ = cluster_bands(bands, simfn, 0.75, 0.4, True)
    uf_off, st_off, _ = cluster_bands(bands, simfn, 0.75, 0.4, False)
    assert st_on.pairs_evaluated <= st_off.pairs_evaluated
    assert st_on.pairs_excluded >= st_off.pairs_excluded
    # the guarantee on the resulting clusters
    labels = uf_on.components()
    for i in range(len(notes)):
        for j in range(i + 1, len(notes)):
            if labels[i] == labels[j]:
                assert simfn(i, j) >= 0.4 - 1e-9


def test_min_score_audit_on_cc_output():
    edges = np.array([[0, 1], [1, 2], [3, 4]], dtype=np.int32)
    sims = np.array([0.9, 0.85, 0.95])
    labels = np.array([0, 0, 0, 3, 3])
    audit = cluster_min_score_audit(labels, edges, sims, 0.4)
    assert audit["property_holds"]
    assert audit["n_clusters"] == 2
    # bound along 0-1-2 = 1 - (0.1 + 0.15) = 0.75
    assert abs(audit["min_bound"] - 0.75) < 1e-9
