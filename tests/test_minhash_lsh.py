"""MinHash + LSH core properties (paper §3-§4)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import jaccard, lsh, minhash, shingle


def _docs_with_overlap(n_shared, n_a, n_b, seed=0):
    rng = np.random.RandomState(seed)
    shared = [f"s{i}" for i in range(n_shared)]
    a = shared + [f"a{i}" for i in range(n_a)]
    b = shared + [f"b{i}" for i in range(n_b)]
    rng.shuffle(a)
    rng.shuffle(b)
    return a, b


@given(st.integers(0, 200), st.integers(0, 100), st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_minhash_estimates_jaccard(n_shared, n_a, n_b):
    """m/M -> Jaccard within sampling error (paper §3.3-3.4)."""
    a, b = _docs_with_overlap(n_shared, n_a, n_b)
    if len(a) < 1 or len(b) < 1:
        return
    n = 2   # short n-gram so overlap survives shuffling boundaries
    sa, sb = shingle.ngram_set(a, n), shingle.ngram_set(b, n)
    true_j = jaccard.exact_jaccard(sa, sb)
    packed = shingle.pack_documents([a, b])
    ng, valid = shingle.ngram_hashes(
        jnp.asarray(packed.tokens), jnp.asarray(packed.lengths), n=n)
    seeds = minhash.default_seeds(256)
    sig = np.asarray(minhash.signatures(ng, valid, jnp.asarray(seeds)))
    est = float((sig[0] == sig[1]).mean())
    tol = 4 * np.sqrt(max(true_j * (1 - true_j), 0.01) / 256) + 0.02
    assert abs(est - true_j) <= tol, (true_j, est)


def test_signature_oracle_agreement():
    rng = np.random.RandomState(0)
    tokens = rng.randint(0, 2**32, size=(13, 64), dtype=np.uint64
                         ).astype(np.uint32)
    lengths = rng.randint(1, 65, size=13).astype(np.int32)
    ng, valid = shingle.ngram_hashes_np(tokens, lengths, 8)
    ngj, validj = shingle.ngram_hashes(
        jnp.asarray(tokens), jnp.asarray(lengths), n=8)
    assert np.array_equal(np.asarray(validj), valid)
    assert np.array_equal(np.asarray(ngj)[valid], ng[valid])
    seeds = minhash.default_seeds(32)
    sig = minhash.signatures_np(ng, valid, seeds)
    sigj = np.asarray(minhash.signatures(
        jnp.asarray(ng), jnp.asarray(valid), jnp.asarray(seeds)))
    assert np.array_equal(sig, sigj)


@given(st.floats(0.05, 0.95))
@settings(max_examples=30, deadline=None)
def test_candidate_probability_monotone(s):
    """P = 1-(1-s^r)^b: increases with b, decreases with r (paper §4.4)."""
    p_b10 = float(lsh.candidate_probability(s, r=2, b=10))
    p_b50 = float(lsh.candidate_probability(s, r=2, b=50))
    p_r4 = float(lsh.candidate_probability(s, r=4, b=50))
    assert p_b50 >= p_b10 - 1e-9
    assert p_r4 <= p_b50 + 1e-9
    assert 0.0 <= p_b50 <= 1.0


def test_band_values_oracle_and_discrimination():
    rng = np.random.RandomState(1)
    sig = rng.randint(0, 2**32, size=(64, 100), dtype=np.uint64
                      ).astype(np.uint32)
    sig[10] = sig[3]   # identical signatures
    b = np.asarray(lsh.band_values(jnp.asarray(sig), 2))
    bn = lsh.band_values_np(sig, 2)
    assert np.array_equal(b, bn)
    assert b.shape == (64, 50, 2)
    assert np.array_equal(b[10], b[3])
    # distinct signatures should (whp) not collide in any band
    collisions = sum(
        np.all(b[i] == b[j], axis=-1).any()
        for i in range(20) for j in range(i + 1, 20) if (i, j) != (3, 10))
    assert collisions == 0


def test_star_edges_cover_runs():
    """Star edges give the same connectivity as all-pairs enumeration."""
    import networkx as nx

    rng = np.random.RandomState(2)
    vals = rng.randint(0, 4, size=(40, 2)).astype(np.uint32)  # many runs
    docs = np.arange(40, dtype=np.int32)
    order = np.lexsort((vals[:, 1], vals[:, 0]))
    sv, sd = vals[order], docs[order]
    pairs = lsh.enumerate_pairs_in_runs(sv, sd)
    edges, mask = lsh.star_edges(jnp.asarray(sv), jnp.asarray(sd))
    star = np.asarray(edges)[np.asarray(mask)]
    g_full, g_star = nx.Graph(), nx.Graph()
    g_full.add_nodes_from(range(40))
    g_star.add_nodes_from(range(40))
    g_full.add_edges_from(map(tuple, pairs))
    g_star.add_edges_from(map(tuple, star))
    comps_full = {frozenset(c) for c in nx.connected_components(g_full)}
    comps_star = {frozenset(c) for c in nx.connected_components(g_star)}
    assert comps_full == comps_star


@given(st.integers(1, 6))
@settings(max_examples=10, deadline=None)
def test_lsh_params(r):
    p = lsh.LSHParams(num_hashes=96, rows_per_band=r)
    if 96 % r == 0:
        assert p.num_bands == 96 // r
        assert 0 < p.threshold_estimate() < 1


def test_lsh_candidate_probability_matches_empirical():
    """Statistical check of the §4.4 S-curve: empirical candidate rate
    over many (document pair, hash seed-set) draws matches
    1-(1-s^r)^b within binomial CI."""
    r, b = 2, 10
    M = r * b
    n_trials = 60
    for target_s in (0.3, 0.6):
        hits = 0
        sims = []
        for t in range(n_trials):
            n_shared = 60
            n_extra = int(n_shared * (1 - target_s) / target_s)
            a, bdoc = _docs_with_overlap(n_shared, n_extra, n_extra,
                                         seed=1000 + t)
            sa, sb = shingle.ngram_set(a, 2), shingle.ngram_set(bdoc, 2)
            s = jaccard.exact_jaccard(sa, sb)
            sims.append(s)
            packed = shingle.pack_documents([a, bdoc])
            ng, valid = shingle.ngram_hashes(
                jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
                n=2)
            seeds = minhash.make_seeds(M, key=t)
            sig = np.asarray(minhash.signatures(
                ng, valid, jnp.asarray(seeds)))
            bands = lsh.band_values_np(sig, r)
            hits += int(np.any(np.all(bands[0] == bands[1], axis=-1)))
        p_pred = float(np.mean(
            [lsh.candidate_probability(s, r=r, b=b) for s in sims]))
        p_emp = hits / n_trials
        sigma = np.sqrt(max(p_pred * (1 - p_pred), 0.01) / n_trials)
        assert abs(p_emp - p_pred) < 4 * sigma + 0.05, (
            target_s, p_emp, p_pred)
