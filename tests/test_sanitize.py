"""REPRO_SANITIZE=1 runtime tripwires (core.sanitize)."""
from __future__ import annotations

import pytest

from repro.core import DedupConfig, DedupSession, query_view, sanitize
from repro.core.shingle import pow2_bucket


def _warm_session():
    notes = [f"note alpha beta gamma delta {i} epsilon zeta eta theta"
             for i in range(12)]
    sess = DedupSession(DedupConfig(exact_verification=False))
    sess.ingest(notes)
    return sess, notes


def _query_arrays(sess, notes):
    pipe = sess._impl.pipe
    toks = pipe.tokenize([notes[0]])
    return pipe.compute_arrays(
        toks, pad_len=pow2_bucket(len(toks[0])))


def test_disabled_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    assert not sanitize.enabled()
    assert sanitize.maybe_install() is False


def test_view_tripwire_catches_in_place_mutation(monkeypatch):
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    assert sanitize.enabled()
    sess, notes = _warm_session()
    view = sess.view()
    sig, bands = _query_arrays(sess, notes)

    # Clean pass: fingerprint recorded on entry, re-checked on exit.
    res = query_view(view, bands, sig=sig)[0]
    assert res.is_duplicate and res.best_sim == 1.0

    # Mutate the published labels in place — exactly what the
    # immutability contract (DESIGN.md §9, RPR002) forbids.
    view.labels.setflags(write=True)
    try:
        view.labels[0] += 1
        with pytest.raises(sanitize.SessionViewMutated):
            query_view(view, bands, sig=sig)
        view.labels[0] -= 1
    finally:
        view.labels.setflags(write=False)

    # Restored bytes: the same view object queries cleanly again.
    res = query_view(view, bands, sig=sig)[0]
    assert res.is_duplicate


def test_view_tripwire_is_noop_when_disabled(monkeypatch):
    monkeypatch.delenv("REPRO_SANITIZE", raising=False)
    sess, notes = _warm_session()
    view = sess.view()
    sig, bands = _query_arrays(sess, notes)
    view.labels.setflags(write=True)
    try:
        view.labels[0] += 1
        assert len(query_view(view, bands, sig=sig)) == 1  # no tripwire
        view.labels[0] -= 1
    finally:
        view.labels.setflags(write=False)


def test_maybe_install_flips_jax_debug_nans(monkeypatch):
    import jax

    before = jax.config.jax_debug_nans
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    try:
        assert sanitize.maybe_install() is True
        assert jax.config.jax_debug_nans is True
    finally:
        jax.config.update("jax_debug_nans", before)


def test_fingerprint_stable_and_content_sensitive():
    sess, _ = _warm_session()
    view = sess.view()
    fp = sanitize.view_fingerprint(view)
    assert sanitize.view_fingerprint(view) == fp  # pure function

    sess2, _ = _warm_session()
    notes_extra = ["an entirely different note about something else"]
    sess2.ingest(notes_extra)
    fp2 = sanitize.view_fingerprint(sess2.view())
    assert fp2 != fp  # different session content, different bytes

    view.labels.setflags(write=True)
    try:
        view.labels[0] += 1
        assert sanitize.view_fingerprint(view) != fp
        view.labels[0] -= 1
    finally:
        view.labels.setflags(write=False)
    assert sanitize.view_fingerprint(view) == fp
