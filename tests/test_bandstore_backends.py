"""Pluggable band-store backends (DESIGN.md §12).

The load-bearing pin: a ``DedupConfig(store="sqlite")`` session — band
index disk-resident behind Bloom-first lookups, signature rows gathered
off disk through an LRU row cache — produces cluster labels IDENTICAL
to and per-edge sims BIT-IDENTICAL to the in-memory tier, on the host,
streaming, and sharded paths, with and without retention/eviction.
Plus: the Bloom-first probe can never false-negative (hypothesis), the
legacy Design-2 blob schemas still decode through the backend
interface, and store compaction actually shrinks the store (the
ROADMAP "retention completeness" fix).
"""
import os
import sqlite3

import numpy as np
import pytest

from repro.core import (
    DedupConfig,
    DedupPipeline,
    DedupSession,
    RetentionPolicy,
)
from repro.core.bandstore import (
    BandStoreBackend,
    Design2Store,
    DiskSignatureVerifier,
    SqliteBandStore,
    _encode_part_v2,
    make_store,
)
from repro.core.query import query_view
from repro.core.session import BandIndex
from repro.core.unionfind import ThresholdUnionFind
from repro.data import inject_near_duplicates, make_i2b2_like


def _corpus(n=48, dups=32, seed=0):
    notes = make_i2b2_like(n, seed=seed)
    notes, _ = inject_near_duplicates(notes, dups, frac_low=0.0,
                                      frac_high=0.005, seed=seed + 1)
    rng = np.random.RandomState(seed + 2)
    order = rng.permutation(len(notes))
    return [notes[i] for i in order]


def _chunks(notes, k):
    return [[notes[i] for i in idx]
            for idx in np.array_split(np.arange(len(notes)), k)]


def _run_session(store, backend, chunks, *, retention=None, exact=False,
                 **kw):
    cfg = DedupConfig(exact_verification=exact, store=store, **kw.pop(
        "config_kw", {}))
    sess = DedupSession(cfg, backend=backend, retention=retention, **kw)
    for snap in sess.ingest_stream(chunks):
        pass
    return sess, snap


def _assert_parity(a, b):
    np.testing.assert_array_equal(a.labels, b.labels)
    assert a.pairs == b.pairs    # bit-identical verified sims
    assert a.filter_only_hits == b.filter_only_hits


# -- backend parity: sqlite == memory, all paths ----------------------------

@pytest.mark.parametrize("backend", ["host", "streaming"])
@pytest.mark.parametrize("retained", [False, True])
def test_sqlite_session_matches_memory(backend, retained):
    chunks = _chunks(_corpus(), 5)
    ret = (lambda: RetentionPolicy(lru_window=10)) if retained \
        else (lambda: None)
    _, a = _run_session("memory", backend, chunks, retention=ret())
    _, b = _run_session("sqlite", backend, chunks, retention=ret())
    _assert_parity(a, b)
    if retained:
        assert a.evicted == b.evicted > 0


def test_sqlite_host_exact_mode_matches_memory():
    chunks = _chunks(_corpus(seed=5), 4)
    _, a = _run_session("memory", "host", chunks, exact=True)
    _, b = _run_session("sqlite", "host", chunks, exact=True)
    _assert_parity(a, b)


def test_sqlite_matches_memory_under_key_budget_compaction():
    """The lossy path too: budget compaction order (LRU by last hit)
    and the filter-only-hit accounting must agree across tiers."""
    chunks = _chunks(_corpus(seed=7), 6)
    ret = lambda: RetentionPolicy(lru_window=10, band_key_budget=16,
                                  bloom_bits=1 << 16)
    sa, a = _run_session("memory", "host", chunks, retention=ret())
    sb, b = _run_session("sqlite", "host", chunks, retention=ret())
    _assert_parity(a, b)
    assert sa.band_index.compacted_keys == sb.band_index.compacted_keys
    assert sa.band_index.compacted_keys > 0
    assert a.filter_only_hits > 0


def test_sqlite_sharded_session_matches_memory():
    from repro.core.dist_lsh import DistLSHConfig

    rng = np.random.RandomState(0)
    vocab = [f"t{i}" for i in range(300)]
    docs = [" ".join(rng.choice(vocab, size=48)) for _ in range(32)]
    docs[5] = docs[3]
    docs[21] = docs[3]          # cross-chunk duplicate
    docs[29] = docs[11]
    chunks = _chunks(docs, 4)
    dcfg = lambda: DistLSHConfig(ngram=4, num_hashes=20, verify_k=8,
                                 edge_capacity=256, edge_threshold=0.5,
                                 bucket_slack=16.0, band_groups=2)
    kw = dict(config_kw=dict(ngram=4, num_hashes=20,
                             edge_threshold=0.5),
              retention=RetentionPolicy(lru_window=6))
    _, a = _run_session("memory", "sharded", chunks,
                        dist_config=dcfg(), **kw)
    _, b = _run_session("sqlite", "sharded", chunks,
                        dist_config=dcfg(), **kw)
    _assert_parity(a, b)
    assert a.evicted == b.evicted > 0


def test_query_view_parity_over_sqlite_view(tmp_path):
    """The read path over a disk-tier view: probes delegate to the
    store's pure Bloom-first ``probe_keys``; results (candidates, sims,
    verdicts, filter-only hits) equal the memory tier's dict walk.
    Small AND large batches — the memory tier's device probe path must
    agree with the store probe too."""
    notes = _corpus(seed=9)
    chunks = _chunks(notes, 4)
    sa, _ = _run_session("memory", "host", chunks)
    sb, _ = _run_session("sqlite", "host", chunks,
                         store_path=str(tmp_path / "bands.db"))
    pipe = DedupPipeline(DedupConfig(exact_verification=False))
    queries = notes[:40] + ["an entirely novel note text " * 6]
    toks = pipe.tokenize(queries)
    sig, bands = pipe.compute_arrays(toks)
    for q in (3, len(queries)):      # host walk + device-batch sizes
        ra = query_view(sa.view(), bands[:q], sig=sig[:q])
        rb = query_view(sb.view(), bands[:q], sig=sig[:q])
        assert ra == rb


# -- retention completeness: store compaction drops evicted rows ------------

def test_streaming_store_compaction_bounds_row_count():
    """Regression (ROADMAP "retention completeness"): the streaming
    band STORE rewrites evicted docs' rows onto their cluster roots, so
    its entry count tracks the retained set instead of growing with
    evicted history."""
    chunks = _chunks(_corpus(seed=11), 5)
    for store in ("memory", "sqlite"):
        plain, pl_snap = _run_session(store, "streaming", chunks,
                                      chunk_docs=16)
        sess, snap = _run_session(
            store, "streaming", chunks, chunk_docs=16,
            retention=RetentionPolicy(lru_window=10))
        _assert_parity(snap, pl_snap)
        assert snap.evicted > 0
        n_plain = plain._impl.sd.store.n_entries()
        n_kept = sess._impl.sd.store.n_entries()
        # Every evicted doc merged through at least one shared band key
        # whose other member maps to the same root — the keep-first
        # dedup drops those rows, so the compacted store is strictly
        # smaller.  (No per-band upper bound: a root legitimately sits
        # in every key its evicted members occupied.)
        assert n_kept < n_plain, (store, n_kept, n_plain)


def test_design2_compact_preserves_scan_order():
    """In-place root rewrite + keep-first dedup: the compacted store's
    run enumeration equals an uncompacted store over the same
    root-mapped rows (position stability is what keeps the engine feed
    order identical)."""
    store = Design2Store(part_size=3)
    rng = np.random.default_rng(3)
    bands = rng.integers(0, 4, size=(10, 2, 2), dtype=np.uint32)
    for d in range(10):
        store.insert_document(d, bands[d])
    store.commit()
    uf = ThresholdUnionFind(10, 0.3)
    uf.union(0, 7, 1.0)
    uf.union(2, 9, 1.0)
    evicted = [d for d in range(10) if uf.find(d) != d]
    store.compact(evicted, uf.find)
    for j in range(2):
        docs, vals = store.read_band(j)
        assert not np.isin(docs, evicted).any()
        # keep-first dedup: no (value, doc) entry appears twice
        seen = list(zip(map(tuple, vals.tolist()), docs.tolist()))
        assert len(seen) == len(set(seen))


# -- blob-schema continuity through the backend interface -------------------

def test_legacy_v1_and_v2_blobs_decode_through_interface(tmp_path):
    """Stores written under the v1 (raw values, contiguous-id) and v2
    (self-describing) part schemas keep reading identically through the
    new ``BandStoreBackend`` scan path."""
    path = str(tmp_path / "legacy.db")
    store = Design2Store(path, part_size=4)
    rng = np.random.default_rng(5)
    bands = rng.integers(0, 50, size=(8, 3, 2), dtype=np.uint32)
    for d in range(8):
        store.insert_document(d, bands[d])
    store.commit()
    ref = {j: store.read_band(j) for j in range(3)}

    # Rewrite every part as a v1 blob (raw uint32 values, ids implied
    # by doc0) — the pre-PR-3 on-disk format.
    from repro.core.bandstore import _decode_part

    conn = sqlite3.connect(path)
    rows = conn.execute(
        "SELECT band_id, part_id, doc0, vals FROM band2").fetchall()
    for band_id, part_id, doc0, blob in rows:
        _, vals = _decode_part(blob, doc0)
        conn.execute(
            "UPDATE band2 SET vals=? WHERE band_id=? AND part_id=?",
            (np.ascontiguousarray(vals, np.uint32).tobytes(),
             band_id, part_id))
    conn.commit()
    conn.close()

    legacy = Design2Store(path, part_size=4)
    for j in range(3):
        np.testing.assert_array_equal(legacy.read_band(j)[0], ref[j][0])
        np.testing.assert_array_equal(legacy.read_band(j)[1], ref[j][1])
    # ...and the interface-level scan agrees run for run.
    runs_ref = [(br.band_id, br.sorted_vals.tolist(),
                 br.sorted_docs.tolist())
                for br in store.iter_band_runs(3)]
    runs_leg = [(br.band_id, br.sorted_vals.tolist(),
                 br.sorted_docs.tolist())
                for br in legacy.iter_band_runs(3)]
    assert runs_leg == runs_ref


def test_v2_blob_roundtrips_noncontiguous_ids():
    store = Design2Store(part_size=3)
    ids = [5, 17, 900]            # resumed-ingest style gaps
    bands = np.array([[[i, i + 1]] for i in ids], dtype=np.uint32)
    for d, b in zip(ids, bands):
        store.insert_document(d, b)
    store.commit()
    docs, vals = store.read_band(0)
    assert docs.tolist() == ids
    blob = _encode_part_v2(np.array(ids, np.int64), vals)
    from repro.core.bandstore import _decode_part

    d2, v2 = _decode_part(blob, 0)
    assert d2.tolist() == ids
    np.testing.assert_array_equal(v2, vals)


# -- Bloom-first probe: false positives counted, false negatives never ------

def test_bloom_first_probe_never_misses_hypothesis():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(deadline=None, max_examples=25)
    @given(seed=st.integers(0, 2**10), n_docs=st.integers(1, 40),
           n_queries=st.integers(1, 8), n_bands=st.integers(1, 4),
           vocab=st.integers(2, 12))
    def prop(seed, n_docs, n_queries, n_bands, vocab):
        rng = np.random.default_rng(seed)
        bands = rng.integers(0, vocab, size=(n_docs, n_bands, 2),
                             dtype=np.uint32)
        qbands = rng.integers(0, vocab, size=(n_queries, n_bands, 2),
                              dtype=np.uint32)
        store = SqliteBandStore(num_bands=n_bands,
                                primary_bloom_bits=1 << 10)
        store.put_band_rows(np.arange(n_docs), bands)
        store.commit()
        got, _ = store.probe_keys(qbands)
        # The in-memory reference: the generic dict-walk over the same
        # rows (BandStoreBackend.probe_keys default implementation).
        want, _ = BandStoreBackend.probe_keys(store, qbands)
        for g, w in zip(got, want):
            assert g.tolist() == w.tolist()
        # Filter accounting is observable and sane: every probe is
        # either a bloom miss, a confirmed hit, or a counted FP.
        stats = store.probe_stats(qbands)
        assert stats["bloom_maybe"] == stats["disk_hits"] + \
            stats["bloom_fps"]
        assert stats["disk_hits"] <= stats["bloom_maybe"] <= \
            stats["probes"]

    prop()


def test_probe_keys_is_pure():
    """RPR002's dynamic half for the store: probing mutates nothing —
    no recency refresh, no counters, no disk writes."""
    rng = np.random.default_rng(1)
    bands = rng.integers(0, 8, size=(12, 4, 2), dtype=np.uint32)
    store = SqliteBandStore(num_bands=4, key_budget=64,
                            track_entries=True)
    store.match_then_insert(bands, 0)
    before = (store._seq, store.filter_only_hits, store.compacted_keys,
              store.n_writes, store.export_maps())
    store.probe_keys(bands)
    store.probe_stats(bands)
    after = (store._seq, store.filter_only_hits, store.compacted_keys,
             store.n_writes, store.export_maps())
    assert before == after


def test_sqlite_index_matches_bandindex_unit_semantics():
    """Unit-level mirror of ``session.BandIndex``: same edges, same LRU
    compaction victims, same filter-only-hit counts."""
    rng = np.random.default_rng(2)
    chunks = [rng.integers(0, 6, size=(6, 2, 2), dtype=np.uint32)
              for _ in range(4)]
    mem = BandIndex(2, key_budget=4, track_entries=True)
    dsk = SqliteBandStore(num_bands=2, key_budget=4, track_entries=True)
    uf = ThresholdUnionFind(64, 0.3)
    base = 0
    for t, bands in enumerate(chunks):
        ea = mem.match_then_insert(bands, base)
        eb = dsk.match_then_insert(bands, base)
        np.testing.assert_array_equal(ea, eb)
        if t == 1:
            for a, b in ea.tolist():
                uf.union(a, b, 1.0)
            evict = [d for d in range(base) if uf.find(d) != d]
            mem.evict(evict, uf.find)
            dsk.evict(evict, uf.find)
        base += len(bands)
    assert mem.export_maps() == dsk.export_maps()
    assert mem.compacted_keys == dsk.compacted_keys > 0
    assert mem.filter_only_hits == dsk.filter_only_hits
    ms, ds = mem.stats(), dsk.stats()
    for k in ("n_keys", "n_entries", "compacted_keys",
              "filter_only_hits"):
        assert ms[k] == ds[k], k


def test_sqlite_index_evict_requires_track_entries():
    dsk = SqliteBandStore(num_bands=1)
    with pytest.raises(ValueError, match="track_entries"):
        dsk.evict([0], lambda d: d)


# -- disk-resident signature rows -------------------------------------------

def test_disk_signature_verifier_bit_parity_and_cache():
    from repro.core.verify import SignatureVerifier

    rng = np.random.RandomState(2)
    sig = rng.randint(0, 50, size=(12, 40)).astype(np.uint32)
    store = SqliteBandStore(num_bands=1)
    store.put_signatures(np.arange(12), sig)
    v = DiskSignatureVerifier(store, 40, cache_rows=4)
    ref = SignatureVerifier(sig)
    pairs = np.array([(0, 8), (2, 9), (5, 10), (3, 11), (0, 2)],
                     dtype=np.int64)
    np.testing.assert_array_equal(v(pairs), ref(pairs))
    assert v(pairs).dtype == np.float32
    assert v.cache_hits > 0 and v.cache_misses > 0
    assert len(v._cache) <= 4                  # LRU bound holds
    assert v.n_live_rows == 12


def test_disk_signature_verifier_release_rows_bounds_disk():
    rng = np.random.RandomState(3)
    sig = rng.randint(0, 50, size=(8, 16)).astype(np.uint32)
    store = SqliteBandStore(num_bands=1)
    v = DiskSignatureVerifier(store, 16)
    v.extend_signatures(np.arange(8), sig)
    assert store.n_signatures() == 8
    v(np.array([[1, 4]]))                      # warm the cache
    v.release_rows([1, 4])
    assert store.n_signatures() == 6           # gone from DISK
    with pytest.raises(KeyError):
        v(np.array([[1, 5]]))                  # evicted doc raises
    got = v(np.array([[2, 3]]))
    assert got[0] == (sig[2] == sig[3]).mean(dtype=np.float32)


def test_streaming_sqlite_keeps_no_host_signature_matrix():
    """The disk tier's point: a streaming sqlite session verifies off
    the store's rows — no full host signature matrix is ever built."""
    chunks = _chunks(_corpus(seed=13), 3)
    sess, snap = _run_session("sqlite", "streaming", chunks,
                              chunk_docs=16)
    v = sess.verifier
    assert isinstance(v, DiskSignatureVerifier)
    assert len(sess._impl.sd._sig_cache) == 0
    assert v.n_live_rows == snap.n_docs
    assert snap.retained_rows == snap.n_docs


# -- store factory / misc ---------------------------------------------------

def test_make_store_factory(tmp_path):
    assert isinstance(make_store("memory"), Design2Store)
    assert isinstance(make_store("sqlite"), SqliteBandStore)
    with pytest.raises(ValueError, match="unknown store"):
        make_store("cassandra")
    with pytest.raises(ValueError, match="unknown store"):
        DedupConfig(store="cassandra")


def test_sqlite_store_reopens_from_file(tmp_path):
    """Primary Bloom filters, key counts, and the LRU clock rebuild
    from a persisted database (resume)."""
    path = str(tmp_path / "bands.db")
    rng = np.random.default_rng(4)
    bands = rng.integers(0, 10, size=(10, 3, 2), dtype=np.uint32)
    s1 = SqliteBandStore(path, num_bands=3)
    s1.put_band_rows(np.arange(10), bands)
    s1.commit()
    probe_ref = s1.probe_keys(bands[:4])
    s1.conn.close()
    s2 = SqliteBandStore(path, num_bands=3)
    got = s2.probe_keys(bands[:4])
    for g, w in zip(got[0], probe_ref[0]):
        assert g.tolist() == w.tolist()
    assert s2._key_counts == s1._key_counts
    assert s2._seq >= s1._seq
    assert s2.file_size_bytes() > 0


def test_iter_band_runs_matches_across_backends():
    rng = np.random.default_rng(6)
    bands = rng.integers(0, 4, size=(20, 3, 2), dtype=np.uint32)
    mem = make_store("memory", part_size=6)
    dsk = make_store("sqlite", num_bands=3)
    mem.put_band_rows(np.arange(20), bands)
    dsk.put_band_rows(np.arange(20), bands)
    mem.commit(), dsk.commit()
    runs_m = [(br.band_id, br.sorted_vals.tolist(),
               br.sorted_docs.tolist()) for br in mem.iter_band_runs(3)]
    runs_d = [(br.band_id, br.sorted_vals.tolist(),
               br.sorted_docs.tolist()) for br in dsk.iter_band_runs(3)]
    assert runs_m == runs_d
    assert mem.n_entries() == dsk.n_entries() == 60
