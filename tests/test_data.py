"""Data pipeline: corpus generation, dedup-integrated loader."""
import numpy as np

from repro.core.pipeline import DedupConfig
from repro.data import (
    build_clean_dataset, hash_tokenize, inject_near_duplicates,
    make_i2b2_like, synthetic_batch_fn,
)


def test_corpus_shape():
    notes = make_i2b2_like(100, seed=0)
    assert len(notes) == 100
    lens = [len(n.split()) for n in notes]
    assert min(lens) > 50   # "a few hundred words" (paper §7.1)
    assert len(set(notes)) == 100


def test_injection_provenance():
    notes = make_i2b2_like(50, seed=1)
    out, prov = inject_near_duplicates(notes, 20, seed=2)
    assert len(out) == 70 and len(prov) == 20
    for dup_idx, src_idx, frac in prov:
        a, b = out[dup_idx].split(), out[src_idx].split()
        same = sum(x == y for x, y in zip(a, b)) / max(len(a), 1)
        assert same >= 1 - frac - 0.02


def test_hash_tokenizer_stable_and_bounded():
    ids = hash_tokenize("the patient denies chest pain", 1000)
    ids2 = hash_tokenize("the patient denies chest pain", 1000)
    assert np.array_equal(ids, ids2)
    assert ids.min() >= 2 and ids.max() < 1000


def test_clean_dataset_removes_duplicates_and_batches():
    notes = make_i2b2_like(60, seed=3)
    notes = notes + [notes[0]] * 5
    ds = build_clean_dataset(notes, vocab_size=512,
                             dedup_cfg=DedupConfig())
    assert ds.num_docs_in == 65
    assert ds.num_docs_kept <= 60
    b1 = ds.batch_at(3, batch=2, seq=32)
    b2 = ds.batch_at(3, batch=2, seq=32)
    assert np.array_equal(b1["tokens"], b2["tokens"])   # pure in step
    assert b1["tokens"].shape == (2, 32)
    assert not np.array_equal(b1["tokens"],
                              ds.batch_at(4, 2, 32)["tokens"])


def test_synthetic_batch_fn_deterministic():
    fn = synthetic_batch_fn(100, 2, 8, seed=5)
    assert np.array_equal(fn(7)["tokens"], fn(7)["tokens"])
    assert not np.array_equal(fn(7)["tokens"], fn(8)["tokens"])
