"""Per-assigned-architecture smoke tests: REDUCED config of the same
family, one forward/train step on CPU, output shapes + no NaNs
(the assignment's smoke-test requirement)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.configs import ARCH_IDS, get_config, get_reduced, input_specs
from repro.models import lm
from repro.models.config import SHAPE_CELLS
from repro.training.step import TrainConfig, init_state, make_train_step


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_reduced(arch)
    tcfg = TrainConfig(adamw=optim.AdamWConfig(lr=1e-3), warmup_steps=1)
    state, axes = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    if cfg.encdec:
        batch = {
            "frames": np.random.RandomState(0).randn(
                B, 24, cfg.d_model).astype(np.float32),
            "tokens": np.random.RandomState(1).randint(
                0, cfg.vocab_size, (B, cfg.dec_len)).astype(np.int32),
        }
    else:
        batch = {"tokens": np.random.RandomState(1).randint(
            0, cfg.vocab_size, (B, S)).astype(np.int32)}
        if cfg.n_patches:
            batch["patches"] = np.zeros((B, cfg.n_patches, cfg.d_model),
                                        np.float32)
    step = jax.jit(make_train_step(cfg, tcfg))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    # params actually changed
    delta = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                           b.astype(jnp.float32)))),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(delta)) > 0, arch


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "whisper-medium"])
def test_reduced_decode_step(arch):
    cfg = get_reduced(arch)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    B = 2
    cache, _ = lm.make_cache(cfg, B, 16)
    tokens = np.random.RandomState(0).randint(
        0, cfg.vocab_size, (B, 8)).astype(np.int32)
    patches = (jnp.zeros((B, cfg.n_patches, cfg.d_model))
               if cfg.n_patches else None)
    cache, logits = lm.prefill(cfg, params, jnp.asarray(tokens), cache,
                               patches=patches)
    total = 8 + (cfg.n_patches or 0)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    lg, cache = lm.decode(cfg, params, cache, tok,
                          jnp.full((B,), total, jnp.int32))
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg)).all(), arch


def test_full_configs_match_assignment():
    """The exact numbers from the assignment table."""
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab_size) == (
        60, 5120, 128, 102_400)
    assert c.moe.n_experts == 160 and c.moe.top_k == 6
    assert c.moe.n_shared == 2 and c.mla.kv_lora_rank == 512

    c = get_config("llama4-maverick-400b-a17b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads,
            c.vocab_size) == (48, 5120, 40, 8, 202_048)
    assert c.moe.n_experts == 128 and c.moe.top_k == 1

    c = get_config("phi3-medium-14b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (40, 5120, 40, 10, 17920, 100_352)

    c = get_config("olmo-1b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (
        16, 2048, 8192, 50_304)
    assert c.norm == "nonparam_ln"

    c = get_config("h2o-danube-1.8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (24, 2560, 32, 8, 6912, 32_000)
    assert c.sliding_window == 4096

    c = get_config("gemma-7b")
    assert (c.n_layers, c.d_model, c.head_dim, c.d_ff, c.vocab_size) == (
        28, 3072, 256, 24576, 256_000)
    assert c.mlp == "geglu"

    c = get_config("whisper-medium")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab_size) == (
        24, 1024, 16, 4096, 51_865)
    assert c.encdec

    c = get_config("zamba2-2.7b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (
        54, 2560, 10240, 32_000)
    assert c.ssm.d_state == 64 and c.shared_every == 6

    c = get_config("mamba2-780m")
    assert (c.n_layers, c.d_model, c.vocab_size) == (48, 1536, 50_280)
    assert c.ssm.d_state == 128 and c.mlp == "none"

    c = get_config("internvl2-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (24, 2048, 16, 8, 8192, 92_553)


def test_param_counts_near_nameplate():
    from repro.launch.hlo_analysis import param_counts

    for arch, total_b, active_b, tol in [
        ("deepseek-v2-236b", 236e9, 21e9, 0.15),
        ("llama4-maverick-400b-a17b", 400e9, 17e9, 0.25),
        ("phi3-medium-14b", 14e9, 14e9, 0.15),
        ("olmo-1b", 1.2e9, 1.2e9, 0.25),
        ("mamba2-780m", 0.78e9, 0.78e9, 0.25),
    ]:
        counts = param_counts(get_config(arch))
        assert abs(counts["total"] - total_b) / total_b < tol, (
            arch, counts)
        assert abs(counts["active"] - active_b) / active_b < tol + 0.15, (
            arch, counts)


def test_input_specs_cover_all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for cell in SHAPE_CELLS:
            specs = input_specs(cfg, cell)
            assert all(hasattr(v, "shape") for v in specs.values())
