# repro-lint: scope=kernel
"""Intentionally-bad fixture: RPR001 dtype-discipline violations."""
import jax.numpy as jnp


def bad_mix(h):
    h = h.astype(jnp.uint32)
    a = h * 31             # bare int literal in uint32 arithmetic
    b = h // 2             # division on the hash domain
    c = h + jnp.int32(1)   # uint32/int32 promotion mix
    return a, b, c
