"""Intentionally-bad fixture: RPR005 on the byte-shingle carry tiling.

Every mistake here is one the real ``kernels/byte_shingle.py`` idiom
avoids: raw module-constant tile dims, a carry BlockSpec whose index
map ignores the L grid axis, a rank-1 carry block paired with a rank-2
out_shape, and tiles big enough to blow the VMEM ceiling.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TD, TLB = 64, 2048


def _byte_kernel(byte_ref, len_ref, tok_ref, h_ref):
    tok_ref[...] = byte_ref[...].astype(jnp.uint32)
    h_ref[...] = len_ref[...].astype(jnp.uint32)


def launch(data, lengths):
    D, LB = data.shape
    return pl.pallas_call(
        _byte_kernel,
        grid=(D // TD, LB // TLB),
        in_specs=[
            # TD/TLB are raw module constants: nothing clamps them to
            # the operand dims, and the (64, 2048) tiles are ~512 KiB
            # EACH — past the 1 MiB ceiling with the outputs counted.
            pl.BlockSpec((TD, TLB), lambda d, l: (d, l)),
            # carry index map takes 1 arg for a 2-axis grid
            pl.BlockSpec((TD,), lambda d: (d,)),
        ],
        out_specs=[
            pl.BlockSpec((TD, TLB), lambda d, l: (d, l)),
            # rank-1 carry block against a rank-2 out_shape
            pl.BlockSpec((TD,), lambda d, l: (d,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((D, LB), jnp.uint32),
            jax.ShapeDtypeStruct((D, 2), jnp.uint32),
        ],
    )(data, lengths)
