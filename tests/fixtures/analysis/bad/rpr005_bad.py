"""Intentionally-bad fixture: RPR005 pallas-spec violations."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TL = 2048


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def launch(x):
    return pl.pallas_call(
        _copy_kernel,
        grid=(4, 4),
        # index map takes 1 arg for a 2-axis grid; TL is unclamped
        in_specs=[pl.BlockSpec((TL, TL), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TL, TL), lambda i, j: (i, j)),
        # 2048x2048 f32 tiles: ~32 MiB resident, way past the ceiling
        out_shape=jax.ShapeDtypeStruct((8192, 8192), jnp.float32),
    )(x)


def launch_bad_rank(x):
    t = min(TL, 128)
    return pl.pallas_call(
        _copy_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((t,), lambda i: (i,))],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        # rank-1 block tuple against a rank-2 out_shape
        out_shape=jax.ShapeDtypeStruct((512, 4), jnp.float32),
    )(x)
