# repro-lint: scope=core
"""Intentionally-bad fixture: RPR004 naming/deprecation violations."""


def run_query(session, texts):        # off-scheme use of a reserved verb
    return session.query(texts)


def refresh(pipe, snap, toks):
    old = pipe.ingest_arrays(toks)    # deprecated shim call
    labels = snap.uf.components()     # deprecated snapshot attr
    return old, labels
