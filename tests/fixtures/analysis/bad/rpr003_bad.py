"""Intentionally-bad fixture: RPR003 recompilation hazards."""


def serve_batch(pipe, token_lists):
    sig, bands = pipe.compute_arrays(token_lists)   # no shape bucketing
    return sig, bands


def stream(pipe, chunks):
    for c in chunks:
        yield pipe.compute_signatures(c)            # recompiles per shape
