"""Intentionally-bad fixture: RPR002 purity violations on the
band-store probe read path (``probe_keys`` / ``probe_stats`` are
``probe_*`` names, so the rule holds them to the same mutation-free
contract a view probe gets)."""


class Store:
    def probe_keys(self, bands):
        self.hits = len(bands)            # assigns to self.*
        self.index.compact([1], int)      # mutating collaborator method
        out = []
        for j, key in enumerate(bands):
            self.seen.add(key)            # container mutator on self
            out.append(self.buckets.get(key, ()))
        return out

    def probe_stats(self, bands):
        self.seq += 1                     # recency refresh is a write
        return {"probes": len(bands)}
