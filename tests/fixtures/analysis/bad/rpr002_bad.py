"""Intentionally-bad fixture: RPR002 query-purity violations."""


class Service:
    def query_stats(self, batch):
        self.count = len(batch)        # assigns to self.*
        self.index.evict(3)            # mutating collaborator method
        self.seen.append(batch)        # container mutator on self
        return self.count

    def query_and_refresh(self, docs):
        self.session.ingest(docs)      # write-path entry point
        return self.session.view()


def probe_rows(session_view):
    session_view.labels.fill(0)        # container mutator on a view param
    return session_view.labels
