"""Clean fixture: pure read paths (RPR002)."""


class Service:
    def query_stats(self, batch):
        results = []                   # local accumulator is fine
        for b in batch:
            results.append(b)
        return len(results)

    def ingest_and_count(self, docs):  # write path may mutate freely
        self.count = len(docs)
        return self.count


def frozen_rows(view):
    rows = list(view.labels)
    rows.sort()                        # local sort, not view-rooted
    return rows
