"""Clean fixture: clamped, budgeted pallas_call (RPR005).

Mirrors the repo kernels' tiling idiom (DESIGN.md §8): tile dims that
vary with a grid axis are min/max-clamped locals, and the resident
tiles fit the 1 MiB default VMEM ceiling.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _copy_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def launch(x, tl: int = 128):
    D, L = x.shape
    tl_ = min(tl, max(1, L))
    return pl.pallas_call(
        _copy_kernel,
        grid=(D, -(-L // tl_)),
        in_specs=[pl.BlockSpec((1, tl_), lambda d, l: (d, l))],
        out_specs=pl.BlockSpec((1, tl_), lambda d, l: (d, l)),
        out_shape=jax.ShapeDtypeStruct((D, L), jnp.float32),
    )(x)
