"""Clean fixture: the byte-shingle carry-block tiling (RPR005).

Mirrors ``kernels/byte_shingle.py`` (DESIGN.md §11): grid-varying tile
dims are min-clamped locals, the FNV-state carry is a revisited rank-1
output block (same block for every L step, re-initialized at the first
L tile) whose out_shape rank matches, and the resident tiles stay far
under the VMEM ceiling.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _byte_kernel(byte_ref, len_ref, tok_ref, h_ref):
    l_idx = pl.program_id(1)

    @pl.when(l_idx == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    tok_ref[...] = byte_ref[...].astype(jnp.uint32)
    h_ref[...] = h_ref[...] + len_ref[...].astype(jnp.uint32)


def launch(data, lengths, td: int = 8, tlb: int = 256):
    D, LB = data.shape
    td_ = min(td, max(1, D))
    tlb_ = min(tlb, max(1, LB))
    return pl.pallas_call(
        _byte_kernel,
        grid=(-(-D // td_), -(-LB // tlb_)),
        in_specs=[
            pl.BlockSpec((td_, tlb_), lambda d, l: (d, l)),
            pl.BlockSpec((td_,), lambda d, l: (d,)),
        ],
        out_specs=[
            pl.BlockSpec((td_, tlb_), lambda d, l: (d, l)),
            pl.BlockSpec((td_,), lambda d, l: (d,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((D, LB), jnp.uint32),
            jax.ShapeDtypeStruct((D,), jnp.uint32),
        ],
    )(data, lengths)
