"""Clean fixture: a Bloom-first store probe that stays pure (RPR002).

Local accumulators carry all the accounting; the sqlite SELECT through
``self.conn`` is a read, and no LRU/seq state is refreshed.
"""


class Store:
    def probe_keys(self, bands):
        cands = [set() for _ in bands]    # local accumulators are fine
        filter_hits = [0] * len(bands)
        for i, key in enumerate(bands):
            if key not in self.primary:
                continue                  # definitive miss, no disk
            rows = self.conn.execute(
                "SELECT docs FROM bandkeys WHERE hi=?", (key,))
            for (docs,) in rows:
                cands[i].update(docs)     # local set, not self-rooted
            if not cands[i] and key in self.compaction_filter:
                filter_hits[i] += 1
        return [sorted(s) for s in cands], filter_hits

    def probe_stats(self, bands):
        maybe = sum(1 for key in bands if key in self.primary)
        return {"probes": len(bands), "bloom_maybe": maybe}

    def insert_document(self, doc_id, bands):  # write path mutates freely
        self.seq += 1
        self.seen.add(doc_id)
