"""Clean fixture: bucketed calls into jitted stages (RPR003)."""
from repro.core.shingle import pow2_bucket


def serve_batch(pipe, token_lists):
    lb = pow2_bucket(max(len(t) for t in token_lists))
    return pipe.compute_arrays(token_lists, pad_len=lb)


def stream(pipe, chunks, pad_len):
    for c in chunks:
        yield pipe.compute_signatures(c, pad_len=pad_len)
