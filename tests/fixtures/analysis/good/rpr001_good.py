# repro-lint: scope=kernel
"""Clean fixture: disciplined uint32 arithmetic (RPR001)."""
import jax.numpy as jnp
import numpy as np


def good_mix(h):
    h = h.astype(jnp.uint32)
    a = h * np.uint32(31)          # wrapped literal: no promotion
    b = h ^ (h >> np.uint32(16))   # shifts never promote
    rows = h.shape[0] // 2         # shape math leaves the hash domain
    c = jnp.uint32(h + 1)          # whole expression feeds a uint32 cast
    return a, b, rows, c
