# repro-lint: scope=core
"""Clean fixture: on-scheme names, no shim callers (RPR004)."""


def query_texts(session, texts):      # reserved verb as the scheme prefix
    return session.query(texts)


def compute_rows(pipe, toks):
    return pipe.compute_arrays(toks, pad_len=256)


def refresh(sess, snap):
    return sess.uf.components(), snap.labels   # live handle + frozen roots
