"""Online dedup query service demo: "is this note a duplicate?"

Ingests a clinical-note corpus into a warm ``DedupSession``, then
serves three kinds of queries through ``DedupQueryService`` — a known
duplicate (an already-ingested note), a near-duplicate (a lightly
perturbed copy), and a novel note — asserting the expected verdicts.
Queries never mutate the session; ``admit`` is the explicit write path.

  PYTHONPATH=src python examples/query_service.py
"""
from __future__ import annotations

import numpy as np

from repro.core import DedupConfig, DedupQueryService, DedupSession
from repro.data import inject_near_duplicates, make_i2b2_like

# 1. Warm session: ingest the corpus (estimate-mode verification, the
#    production configuration — exact_verification=True works too).
notes = make_i2b2_like(200, seed=0)
notes, _ = inject_near_duplicates(notes, 100, seed=1)
session = DedupSession(DedupConfig(exact_verification=False))
snap = session.ingest(notes)
print(f"warm session: {snap.n_docs} notes, {snap.num_clusters} clusters")

service = DedupQueryService(session)

# 2. Known duplicate: a note already in the session matches itself
#    with sim 1.0 and lands in its own cluster.
known = service.query([notes[17]])[0]
print(f"known-dup  : duplicate={known.is_duplicate} "
      f"sim={known.best_sim:.3f} cluster={known.cluster_root}")
assert known.is_duplicate and known.best_sim == 1.0
assert known.cluster_root == int(snap.labels[17])

# 3. Near-duplicate: perturb an ingested note slightly (the paper's
#    copy-paste-and-edit setting) — still above the 75% edge threshold.
words = notes[17].split()
words[len(words) // 2] = "perturbed"
near = service.query([" ".join(words)])[0]
print(f"near-dup   : duplicate={near.is_duplicate} "
      f"sim={near.best_sim:.3f} cluster={near.cluster_root}")
assert near.is_duplicate and 0.75 < near.best_sim < 1.0
assert near.cluster_root == int(snap.labels[17])

# 4. Novel note: nothing retained comes close.
novel = service.query(["entirely novel discharge narrative " * 12])[0]
print(f"novel      : duplicate={novel.is_duplicate} "
      f"candidates={novel.n_candidates}")
assert not novel.is_duplicate and novel.matched_doc is None

# 5. Queries are reads: session state is untouched...
assert np.array_equal(session.snapshot().labels, snap.labels)
assert session.n_docs == snap.n_docs

# ...and admit() is the write path: after admitting the near-dup it IS
# a known duplicate (of the same cluster).
service.admit([" ".join(words)])
readmitted = service.query([" ".join(words)])[0]
print(f"post-admit : duplicate={readmitted.is_duplicate} "
      f"sim={readmitted.best_sim:.3f}")
assert readmitted.best_sim == 1.0
assert readmitted.cluster_root == int(snap.labels[17])

# 6. Microbatched serving: enqueue single notes, one step verifies the
#    whole batch in one device dispatch — results identical to the
#    sequential queries above.
rids = [service.submit(t) for t in notes[:32]]
finished = service.run_until_drained()
assert all(r.result.is_duplicate for r in finished)
print(f"microbatch : {len(finished)} queries in "
      f"{service.stats.microbatches} batch(es), "
      f"mean occupancy {service.stats.mean_occupancy:.2f}")
print("all verdicts as expected")
