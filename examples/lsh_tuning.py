"""Reproduce the paper's (b, r) tuning analysis (Figs 1-3) interactively:
sweep bands/rows, print the FP/FN trade-off and the S-curve.

  PYTHONPATH=src python examples/lsh_tuning.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import jaccard, lsh, minhash, shingle
from repro.data import accuracy_testset

notes, srcs = accuracy_testset(seed=0)
token_lists = [shingle.tokenize(t) for t in notes]
sets = [shingle.ngram_set(t, 8) for t in token_lists]
packed = shingle.pack_documents(token_lists)
ng, valid = shingle.ngram_hashes(
    jnp.asarray(packed.tokens), jnp.asarray(packed.lengths), n=8)
seeds = minhash.default_seeds(512)

threshold = 0.3
truth = set()
for i in range(len(notes)):
    for j in range(i + 1, len(notes)):
        if jaccard.exact_jaccard(sets[i], sets[j]) > threshold:
            truth.add((i, j))
print(f"ground truth: {len(truth)} similar pairs at J>{threshold}")

print(f"{'b':>4} {'r':>3} {'P(cand|J=t)':>12} {'FP':>6} {'FN':>4}")
for r in (1, 2, 4):
    for b in (5, 10, 25, 50):
        sig = np.asarray(minhash.signatures(
            ng, valid, jnp.asarray(seeds[: b * r])))
        bands = np.asarray(lsh.band_values(jnp.asarray(sig), r))
        cand = set(map(tuple, lsh.all_candidate_pairs(bands)))
        fp = sum(
            1 for p in cand
            if jaccard.exact_jaccard(sets[p[0]], sets[p[1]]) <= threshold)
        fn = len(truth - cand)
        p_at_t = float(lsh.candidate_probability(threshold, r=r, b=b))
        print(f"{b:>4} {r:>3} {p_at_t:>12.3f} {fp:>6} {fn:>4}")

print("\npaper's operating point: r=2, b=50 (no false negatives)")
print("S-curve P(candidate) at r=2, b=50:")
for s in (0.05, 0.1, 0.2, 0.3, 0.5, 0.7, 0.9):
    print(f"  J={s:.2f}: P={float(lsh.candidate_probability(s, 2, 50)):.4f}")
