"""Incremental ingest demo: a chunked corpus through one DedupSession.

Feeds a clinical-note-like corpus chunk by chunk into a single
``DedupSession`` (the long-lived state: one union-find, one verified-sim
cache, global doc-id allocation, retained signatures + band index),
printing the cumulative snapshot after every chunk — and then checks
that the final snapshot equals one-shot host clustering of the whole
corpus, with bit-identical per-edge similarity estimates.

  PYTHONPATH=src python examples/incremental_ingest.py
  PYTHONPATH=src python examples/incremental_ingest.py --backend streaming
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core import DedupConfig, DedupPipeline, DedupSession
from repro.data import inject_near_duplicates, make_i2b2_like


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--notes", type=int, default=120)
    ap.add_argument("--dups", type=int, default=60)
    ap.add_argument("--chunks", type=int, default=5)
    ap.add_argument("--backend", default="host",
                    choices=("host", "streaming"),
                    help="session backend (the sharded backend needs a "
                         "multi-device mesh; see launch.dedup --sharded "
                         "--steps N)")
    args = ap.parse_args(argv)

    notes = make_i2b2_like(args.notes, seed=0)
    notes, _ = inject_near_duplicates(notes, args.dups, seed=1)
    cfg = DedupConfig(exact_verification=False)
    print(f"corpus: {len(notes)} notes, ingested in {args.chunks} chunks "
          f"({args.backend} backend)\n")

    sess = DedupSession(cfg, backend=args.backend)
    bounds = np.linspace(0, len(notes), args.chunks + 1).astype(int)
    for snap in sess.ingest_stream(
            notes[a:b] for a, b in zip(bounds, bounds[1:])):
        print(f"after {snap.n_docs:4d} docs: "
              f"{snap.num_clusters:3d} clusters, "
              f"{snap.num_duplicates:3d} duplicates, "
              f"{snap.stats.pairs_evaluated:4d} pairs verified "
              f"({snap.stats.pairs_excluded} excluded, "
              f"{snap.stats.verify_pairs_per_second:.0f} pairs/s)")

    # The point of the demo: incremental == one-shot, exactly.
    ref = DedupPipeline(cfg).run(notes)
    np.testing.assert_array_equal(snap.labels, ref.labels)
    ref_sims = {(a, b): s for a, b, s in ref.pairs}
    shared = [(a, b, s) for a, b, s in snap.pairs if (a, b) in ref_sims]
    assert shared and all(s == ref_sims[(a, b)] for a, b, s in shared)
    print(f"\nfinal snapshot == one-shot host clustering "
          f"({ref.num_clusters} clusters, {len(shared)} shared verified "
          f"pairs bit-identical)")

    # The session stays warm: the immutable SessionView is the read
    # path (DESIGN.md §9) — here, re-querying an ingested doc finds
    # its own cluster with sim 1.0.  (The streaming backend keeps its
    # retained state in the band store and has no view.)
    if args.backend == "host":
        view = sess.view()
        from repro.core import query_view
        from repro.core.shingle import pow2_bucket

        pipe = DedupPipeline(cfg)
        toks = pipe.tokenize([notes[0]])
        # pow2 pad_len keeps repeated queries on one jit compile
        # (RPR003; the query service does this internally).
        sig, bands = pipe.compute_arrays(
            toks, pad_len=pow2_bucket(len(toks[0])))
        res = query_view(view, bands, sig=sig)[0]
        print(f"view v{view.version}: query(notes[0]) -> "
              f"duplicate={res.is_duplicate} sim={res.best_sim:.2f} "
              f"cluster={res.cluster_root}")


if __name__ == "__main__":
    main()
