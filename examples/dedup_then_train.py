"""End-to-end driver: dedup a corpus, then train an LM on the clean data
with the fault-tolerant loop (checkpoints + resume).

This is the 'train ~100M model for a few hundred steps' example at a
CPU-sized scale; pass --scale full on a real pod.

  PYTHONPATH=src python examples/dedup_then_train.py --steps 120
"""
import argparse
import os

import jax

from repro import optim
from repro.configs import get_reduced, paper_dedup_config
from repro.data import (build_clean_dataset, inject_near_duplicates,
                        make_i2b2_like)
from repro.runtime import FTLoop, FTLoopConfig
from repro.training.step import TrainConfig, init_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--arch", default="olmo-1b")
ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
args = ap.parse_args()

# -- 1. corpus + dedup (the paper's pipeline feeding the data loader) ----
notes = make_i2b2_like(500, seed=0)
notes, _ = inject_near_duplicates(notes, 250, seed=1)
cfg = get_reduced(args.arch)
ds = build_clean_dataset(notes, cfg.vocab_size, paper_dedup_config())
print(f"dedup: {ds.num_docs_in} notes -> {ds.num_docs_kept} kept; "
      f"stats={ds.dedup_stats}")

# -- 2. fault-tolerant training on the clean token stream ----------------
tcfg = TrainConfig(adamw=optim.AdamWConfig(lr=3e-3),
                   warmup_steps=10, total_steps=args.steps)
state, _ = init_state(cfg, tcfg, jax.random.PRNGKey(0))
loop = FTLoop(
    config=FTLoopConfig(ckpt_dir=os.path.join(args.ckpt, cfg.name),
                        ckpt_every=50),
    train_step=jax.jit(make_train_step(cfg, tcfg)),
    batch_fn=lambda step: ds.batch_at(step, batch=8, seq=128),
)
state, history = loop.run(state, args.steps, log_every=20)
print(f"loss: {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f} "
      f"over {len(history)} steps "
      f"(resume-capable checkpoints in {args.ckpt})")
assert history[-1]["loss"] < history[0]["loss"]
