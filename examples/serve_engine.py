"""Continuous-batching serving example (the vLLM-style engine).

  PYTHONPATH=src python examples/serve_engine.py --requests 12
"""
import argparse
import time

import numpy as np
import jax

from repro import optim
from repro.configs import get_reduced
from repro.serving import ServeEngine
from repro.training.step import TrainConfig, init_state

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="olmo-1b")
ap.add_argument("--requests", type=int, default=12)
ap.add_argument("--slots", type=int, default=4)
args = ap.parse_args()

cfg = get_reduced(args.arch)
state, _ = init_state(cfg, TrainConfig(adamw=optim.AdamWConfig()),
                      jax.random.PRNGKey(0))
eng = ServeEngine(cfg, state["params"], slots=args.slots, cache_len=96,
                  eos_id=-1)
rng = np.random.RandomState(0)
t0 = time.perf_counter()
for _ in range(args.requests):
    eng.submit(rng.randint(2, cfg.vocab_size, size=rng.randint(6, 20)),
               max_tokens=rng.randint(4, 12))
finished = eng.run_until_drained()
dt = time.perf_counter() - t0
print(f"served {len(finished)} requests in {dt:.2f}s "
      f"({eng.stats.tokens_out} tokens, {eng.stats.steps} engine steps, "
      f"prefills={eng.stats.prefills}, "
      f"mean slot occupancy {eng.stats.mean_occupancy:.2f})")
for r in finished[:3]:
    print(f"  req {r.rid}: {len(r.out)} tokens -> {r.out[:8]}...")
