"""Whisper (enc-dec) training example: stub frame embeddings -> decoder CE.

  PYTHONPATH=src python examples/whisper_train.py --steps 40
"""
import argparse

import numpy as np
import jax

from repro import optim
from repro.configs import get_reduced
from repro.training.step import TrainConfig, init_state, make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=40)
args = ap.parse_args()

cfg = get_reduced("whisper-medium")
tcfg = TrainConfig(adamw=optim.AdamWConfig(lr=3e-3), warmup_steps=4,
                   total_steps=args.steps)
state, _ = init_state(cfg, tcfg, jax.random.PRNGKey(0))
step = jax.jit(make_train_step(cfg, tcfg))

rng = np.random.RandomState(0)
# one fixed "utterance batch": stub conv-frontend frames + transcripts
batch = {
    "frames": rng.randn(4, 48, cfg.d_model).astype(np.float32),
    "tokens": rng.randint(0, cfg.vocab_size,
                          (4, cfg.dec_len)).astype(np.int32),
}
first = None
for i in range(args.steps):
    state, m = step(state, batch)
    first = first or float(m["loss"])
    if i % 10 == 0:
        print(f"step {i}: loss={float(m['loss']):.4f}")
print(f"loss {first:.3f} -> {float(m['loss']):.3f}")
assert float(m["loss"]) < first
