"""Bounded-memory ingest demo: 50 chunks under a fixed retention budget.

Streams a long corpus (fresh notes + near-exact duplicates that recur
within the retention window) through one ``DedupSession`` with a
``RetentionPolicy``: signature rows evict down to one representative
per cluster plus an LRU window, and old band-index keys compact into
per-band Bloom filters — memory is O(clusters + window), not O(docs)
(DESIGN.md §7).  Prints the retained-row / peak-RSS curve and checks
cluster parity against a one-shot host run of the whole corpus.

  PYTHONPATH=src python examples/bounded_ingest.py
  PYTHONPATH=src python examples/bounded_ingest.py --budget medium
"""
from __future__ import annotations

import argparse
import resource
import sys


def rss_mb() -> float:
    ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    return ru / (1024.0 * 1024.0) if sys.platform == "darwin" \
        else ru / 1024.0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--chunks", type=int, default=50)
    ap.add_argument("--fresh-per-chunk", type=int, default=16)
    ap.add_argument("--dups-per-chunk", type=int, default=6)
    ap.add_argument("--budget", default="small",
                    choices=("small", "medium", "unlimited"))
    ap.add_argument("--refine-every", type=int, default=0,
                    help="auto-refine cadence; the parity check is "
                         "against a one-shot run WITHOUT a second "
                         "clustering round, so refine merges (if any) "
                         "would be a legitimate divergence — off by "
                         "default to keep the assert meaningful")
    args = ap.parse_args(argv)

    import numpy as np

    from repro.core import (DedupConfig, DedupPipeline, DedupSession,
                            RetentionPolicy)
    from repro.data import inject_near_duplicates, make_i2b2_like

    rng = np.random.RandomState(0)
    chunks, recent = [], []
    for t in range(args.chunks):
        fresh = make_i2b2_like(args.fresh_per_chunk, seed=1000 + t)
        chunk = list(fresh)
        pool = [n for c in recent[-2:] for n in c]
        if pool:
            picks = rng.choice(len(pool), size=args.dups_per_chunk)
            dup, _ = inject_near_duplicates(
                [pool[i] for i in picks], args.dups_per_chunk,
                frac_low=0.0, frac_high=0.005, seed=2000 + t)
            chunk.extend(dup[args.dups_per_chunk:])
        recent.append(fresh)
        chunks.append(chunk)
    n_total = sum(len(c) for c in chunks)
    policy = RetentionPolicy.preset(args.budget,
                                    refine_every=args.refine_every)
    print(f"corpus: {n_total} notes in {args.chunks} chunks, "
          f"budget={args.budget!r} (window {policy.lru_window}, "
          f"key budget {policy.band_key_budget}, "
          f"refine every {policy.refine_every})\n")

    cfg = DedupConfig(exact_verification=False)
    sess = DedupSession(cfg, backend="host", retention=policy)
    for snap in sess.ingest_stream(chunks):
        if snap.n_docs % (10 * len(chunks[0])) < len(chunks[0]):
            print(f"after {snap.n_docs:5d} docs: "
                  f"{snap.retained_rows:5d} rows retained "
                  f"({snap.evicted} evicted, "
                  f"{snap.filter_only_hits} filter-only hits, "
                  f"{snap.refine_merges} refine merges), "
                  f"{snap.num_clusters:4d} clusters, "
                  f"peak RSS {rss_mb():.0f}MB")
    peak = rss_mb()
    print(f"\nfinal: {snap.retained_rows} of {snap.n_docs} rows "
          f"retained ({100 * snap.retained_rows / snap.n_docs:.0f}%), "
          f"peak RSS {peak:.0f}MB")

    # The point of the demo: bounded ingest clusters the corpus exactly
    # like a one-shot run (duplicates recur within the window).  Root
    # identity can differ chunked-vs-one-shot, so compare partitions;
    # the one-shot reference never runs a second clustering round, so
    # the assert only holds when refine performed no extra merges.
    ref = DedupPipeline(cfg).run([n for c in chunks for n in c])
    if snap.refine_merges:
        print(f"refine merged {snap.refine_merges} cluster pair(s); "
              "skipping the one-shot parity assert (the one-shot "
              "reference has no second round)")
        return

    def canon(labels):
        first = {}
        return [first.setdefault(int(r), i)
                for i, r in enumerate(labels)]

    assert canon(snap.labels) == canon(ref.labels), \
        "bounded session drifted from the one-shot clustering"
    print(f"cluster parity vs one-shot: OK "
          f"({ref.num_clusters} duplicate clusters)")


if __name__ == "__main__":
    main()
