"""Batched serving example: prefill a batch of prompts, decode greedily.

  PYTHONPATH=src python examples/serve_batched.py --arch gemma-7b
(reduced configs on CPU; --full on a pod)
"""
import argparse

import numpy as np
import jax

from repro import optim
from repro.configs import get_reduced
from repro.launch.serve import serve_batch
from repro.training.step import TrainConfig, init_state

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma-7b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--tokens", type=int, default=24)
args = ap.parse_args()

cfg = get_reduced(args.arch)
state, _ = init_state(cfg, TrainConfig(adamw=optim.AdamWConfig()),
                      jax.random.PRNGKey(0))
prompts = np.random.RandomState(0).randint(
    2, cfg.vocab_size, size=(args.batch, 16)).astype(np.int32)
toks, stats = serve_batch(cfg, state["params"], prompts, args.tokens)
print(f"arch={cfg.name} decoded {toks.shape[0]}x{toks.shape[1]} tokens")
print(f"prefill: {stats['prefill_s']*1e3:.1f} ms; "
      f"decode: {stats['tok_per_s']:.1f} tok/s")
print("first sequence:", toks[0].tolist())
