"""Quickstart: dedup a clinical-note corpus with the paper's pipeline.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import DedupConfig, DedupPipeline
from repro.data import inject_near_duplicates, make_i2b2_like

# 1. A corpus with heavy duplication (the paper's setting: templates,
#    copy-paste, automated notes).
notes = make_i2b2_like(300, seed=0)
notes, provenance = inject_near_duplicates(notes, 150, seed=1)
print(f"corpus: {len(notes)} notes ({len(provenance)} injected dups)")

# 2. MinHash-LSH dedup with the paper's parameters (n=8, M=100, r=2,
#    b=50; edge threshold 75%, tree threshold 40%).
pipeline = DedupPipeline(DedupConfig())
result = pipeline.run(notes)

# 3. Results: clusters carry a GUARANTEE — every intra-cluster pair has
#    Jaccard >= tree_threshold (paper §6).
print(f"clusters (>=2 notes): {result.num_clusters}")
print(f"duplicates removed:   {result.num_duplicates_removed}")
print(f"Jaccard evaluations:  {result.stats.pairs_evaluated} "
      f"({result.stats.pairs_excluded} excluded by clustering)")
print(f"stage timings:        "
      f"{ {k: round(v, 3) for k, v in result.timings.items()} }")

clean = [n for n, keep in zip(notes, result.keep_mask) if keep]
print(f"clean corpus: {len(clean)} notes")
largest = np.bincount(result.labels).max()
print(f"largest cluster: {largest} notes")

# 4. The online form ("is this NEW note a duplicate?") is a warm
#    DedupSession behind a DedupQueryService — see
#    examples/query_service.py for the full read-path demo.
from repro.core import DedupQueryService, DedupSession  # noqa: E402

service = DedupQueryService(DedupSession(DedupConfig()))
service.admit(clean)
verdict = service.query([notes[0]])[0]
print(f"query(notes[0]): duplicate={verdict.is_duplicate} "
      f"sim={verdict.best_sim:.2f} cluster={verdict.cluster_root}")
