"""mamba2-780m [ssm] — attention-free, SSD (state-space duality).

48L d_model=1536 vocab=50280 ssm_state=128, no MLP
[arXiv:2405.21060; unverified].  O(1)-state decode => runs long_500k.
"""
from repro.models.config import ModelConfig, SSMCfg

ID = "mamba2-780m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="ssm",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,  # unused
        d_ff=0, vocab_size=50_280,
        ssm=SSMCfg(d_state=128, expand=2, head_dim=64, n_groups=1,
                   chunk=128),
        mlp="none", norm="rmsnorm", tie_embeddings=True,
        subquadratic=True,
    )


def reduced() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, vocab_size=256,
        ssm=SSMCfg(d_state=16, expand=2, head_dim=8, n_groups=1, chunk=8),
        param_dtype="float32", compute_dtype="float32", remat="none",
    )
