"""llama4-maverick-400b-a17b [moe] — MoE top-1 + shared, alternating layers.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, 128 experts top-1
[hf:meta-llama/Llama-4-*; unverified].  Early fusion: multimodal tokens
share the text embedding space — modality frontends are out of scope
(text path only; see DESIGN.md §4).
"""
from repro.models.config import ModelConfig, MoECfg

ID = "llama4-maverick-400b-a17b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab_size=202_048,
        moe=MoECfg(n_experts=128, top_k=1, n_shared=1, d_expert=8192,
                   every=2),
        mlp="swiglu", norm="rmsnorm", tie_embeddings=False,
        opt_moments_dtype="int8",
        subquadratic=False,
    )


def reduced() -> ModelConfig:
    return config().with_(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256,
        moe=MoECfg(n_experts=4, top_k=1, n_shared=1, d_expert=64, every=2),
        param_dtype="float32", compute_dtype="float32", remat="none",
        opt_moments_dtype="float32",
    )
