"""phi3-medium-14b [dense] — RoPE SwiGLU GQA.

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352
[arXiv:2404.14219; unverified]
"""
from repro.models.config import ModelConfig

ID = "phi3-medium-14b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=10,
        d_ff=17920, vocab_size=100_352,
        mlp="swiglu", norm="rmsnorm", tie_embeddings=False,
        subquadratic=False,
    )


def reduced() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, param_dtype="float32", compute_dtype="float32",
        remat="none",
    )
