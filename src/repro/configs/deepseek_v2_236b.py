"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.

60L d_model=5120 128H d_ff=1536(expert) vocab=102400 [arXiv:2405.04434; hf]
~236B total / ~21B active.
"""
from repro.models.config import MLACfg, ModelConfig, MoECfg

ID = "deepseek-v2-236b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="moe",
        n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
        d_ff=1536, vocab_size=102_400,
        mla=MLACfg(kv_lora_rank=512, q_lora_rank=1536,
                   nope_head_dim=128, rope_head_dim=64, v_head_dim=128),
        moe=MoECfg(n_experts=160, top_k=6, n_shared=2, d_expert=1536),
        mlp="swiglu", norm="rmsnorm", tie_embeddings=False,
        opt_moments_dtype="int8",   # 236B: fp32 moments would not fit
        subquadratic=False,
    )


def reduced() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
        vocab_size=256,
        mla=MLACfg(kv_lora_rank=16, q_lora_rank=24, nope_head_dim=8,
                   rope_head_dim=4, v_head_dim=8),
        moe=MoECfg(n_experts=8, top_k=2, n_shared=2, d_expert=32),
        param_dtype="float32", compute_dtype="float32", remat="none",
        opt_moments_dtype="float32",
    )
