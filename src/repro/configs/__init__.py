"""Architecture registry + per-cell input specs (ShapeDtypeStruct only).

``get_config(arch_id)`` / ``get_reduced(arch_id)`` resolve the 10 assigned
architectures; ``input_specs(cfg, cell)`` builds the allocation-free
stand-ins the dry-run lowers against (the shannon/kernels pattern).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, SHAPE_CELLS, cell_applicable
from repro.configs import (
    deepseek_v2_236b, gemma_7b, h2o_danube, internvl2_2b, llama4_maverick,
    mamba2_780m, olmo_1b, phi3_medium, whisper_medium, zamba2_2p7b,
)
from repro.core.pipeline import DedupConfig
from repro.core.dist_lsh import DistLSHConfig

_MODULES = [
    deepseek_v2_236b, llama4_maverick, phi3_medium, olmo_1b, h2o_danube,
    gemma_7b, whisper_medium, zamba2_2p7b, mamba2_780m, internvl2_2b,
]

REGISTRY = {m.ID: m for m in _MODULES}
ARCH_IDS = list(REGISTRY)


def get_config(arch_id: str) -> ModelConfig:
    return REGISTRY[arch_id].config()


def get_reduced(arch_id: str) -> ModelConfig:
    return REGISTRY[arch_id].reduced()


def paper_dedup_config() -> DedupConfig:
    """Paper §7/§9 defaults: n=8, M=100, r=2, b=50, thresholds 75/40."""
    return DedupConfig()


def paper_dist_lsh_config() -> DistLSHConfig:
    return DistLSHConfig()


# -- input specs ---------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ModelConfig, cell_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one cell.

    train/prefill: the batch dict.  decode: {"token", "kv_len"} — the
    cache spec comes from ``cache_specs``.
    """
    cell = SHAPE_CELLS[cell_name]
    B, S = cell.global_batch, cell.seq_len
    tok = jnp.int32
    if cfg.encdec:
        if cell.kind in ("train", "prefill"):
            return {
                "frames": _sds((B, S, cfg.d_model), cfg.cdtype),
                "tokens": _sds((B, cfg.dec_len), tok),
            }
        return {"token": _sds((B,), tok), "kv_len": _sds((B,), tok)}
    if cell.kind in ("train", "prefill"):
        batch = {"tokens": _sds((B, max(1, S - cfg.n_patches)), tok)}
        if cfg.n_patches:
            batch["patches"] = _sds((B, cfg.n_patches, cfg.d_model),
                                    cfg.cdtype)
        return batch
    return {"token": _sds((B,), tok), "kv_len": _sds((B,), tok)}


def cache_specs(cfg: ModelConfig, cell_name: str):
    """(ShapeDtypeStruct cache tree, logical axes tree) for decode cells."""
    from repro.models import lm, whisper

    cell = SHAPE_CELLS[cell_name]
    B, S = cell.global_batch, cell.seq_len
    seq_shard = cell_name == "long_500k"
    if cfg.encdec:
        def build():
            cache, _ = whisper.make_cache(cfg, B, dec_len=cfg.dec_len,
                                          enc_len=S)
            kc = jnp.zeros((cfg.n_dec_layers or cfg.n_layers, B, S,
                            cfg.n_kv_heads, cfg.resolved_head_dim),
                           cfg.cdtype)
            return {"enc_kv": (kc, kc), "cache": cache}

        _, axes = whisper.make_cache(cfg, 1, dec_len=2, enc_len=2)
        enc_ax = ("layers", "batch", None, "heads", None)
        full_axes = {"enc_kv": (enc_ax, enc_ax), "cache": axes}
        return jax.eval_shape(build), full_axes

    def build():
        cache, _ = lm.make_cache(cfg, B, S, seq_shard=seq_shard)
        return cache

    _, axes = lm.make_cache(cfg, 1, 2, seq_shard=seq_shard)
    return jax.eval_shape(build), axes


__all__ = [
    "REGISTRY", "ARCH_IDS", "get_config", "get_reduced",
    "paper_dedup_config", "paper_dist_lsh_config", "input_specs",
    "cache_specs",
]
