"""internvl2-2b [vlm] — InternViT (STUB) + InternLM2-1.8b backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553
[arXiv:2404.16821; hf].  input_specs() supplies 256 precomputed patch
embeddings (stub InternViT) prepended to the text sequence; loss masks
patch positions.
"""
from repro.models.config import ModelConfig

ID = "internvl2-2b"

N_PATCHES = 256


def config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="vlm",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8,
        d_ff=8192, vocab_size=92_553, n_patches=N_PATCHES,
        mlp="swiglu", norm="rmsnorm", tie_embeddings=True,
        subquadratic=False,
    )


def reduced() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, n_patches=4,
        param_dtype="float32", compute_dtype="float32", remat="none",
    )
