"""whisper-medium [audio] — encoder-decoder, conv frontend STUBBED.

24L enc + 24L dec, d_model=1024 16H d_ff=4096 vocab=51865
[arXiv:2212.04356; unverified].  input_specs() supplies precomputed frame
embeddings (the assignment's stub-frontend rule); seq_len cells size the
ENCODER, the decoder runs at dec_len=448 (DESIGN.md §4).
"""
from repro.models.config import ModelConfig

ID = "whisper-medium"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="audio",
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
        d_ff=4096, vocab_size=51_865,
        mlp="gelu", norm="layernorm", encdec=True, n_dec_layers=24,
        dec_len=448, tie_embeddings=True,
        subquadratic=False,
    )


def reduced() -> ModelConfig:
    return config().with_(
        n_layers=2, n_dec_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, dec_len=8,
        param_dtype="float32", compute_dtype="float32", remat="none",
    )
