"""gemma-7b [dense] — GeGLU, head_dim=256, embedding scaling.

28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000
[arXiv:2403.08295; hf]
"""
from repro.models.config import ModelConfig

ID = "gemma-7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="dense",
        n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
        head_dim=256, d_ff=24576, vocab_size=256_000,
        mlp="geglu", norm="rmsnorm", tie_embeddings=True,
        embed_scale=True,
        subquadratic=False,
    )


def reduced() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, param_dtype="float32",
        compute_dtype="float32", remat="none",
    )
