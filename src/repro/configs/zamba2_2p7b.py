"""zamba2-2.7b [hybrid] — Mamba2 stack + shared attention block.

54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000 ssm_state=64
[arXiv:2411.15242; hf].  The shared transformer block (one set of weights)
is applied every 6 mamba layers (9 applications); Zamba2's
concat-with-embedding input to the shared block is simplified to the
running hidden state (noted in DESIGN.md).  Hybrid => runs long_500k with
a sequence-sharded KV cache for the shared block.
"""
from repro.models.config import ModelConfig, SSMCfg

ID = "zamba2-2.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab_size=32_000,
        ssm=SSMCfg(d_state=64, expand=2, head_dim=64, n_groups=1,
                   chunk=128),
        shared_every=6,
        mlp="swiglu", norm="rmsnorm", tie_embeddings=True,
        subquadratic=True,
    )


def reduced() -> ModelConfig:
    return config().with_(
        n_layers=4, shared_every=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256,
        ssm=SSMCfg(d_state=8, expand=2, head_dim=8, n_groups=1, chunk=8),
        param_dtype="float32", compute_dtype="float32", remat="none",
    )
