"""olmo-1b [dense] — non-parametric LayerNorm.

16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304 [arXiv:2402.00838; hf]
"""
from repro.models.config import ModelConfig

ID = "olmo-1b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ID, family="dense",
        n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=8192, vocab_size=50_304,
        mlp="swiglu", norm="nonparam_ln", tie_embeddings=True,
        subquadratic=False,
    )


def reduced() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, param_dtype="float32", compute_dtype="float32",
        remat="none",
    )
