"""Sharded, atomic, optionally-async checkpointing (no orbax dependency).

Layout: <dir>/step_<N>/
  manifest.json        — leaf paths, dtypes, shapes, tree structure
  <leaf_id>.zst        — zstd-compressed raw array bytes (one per leaf)

Writes go to a tmp dir then os.replace -> atomic: a crash mid-save never
corrupts the latest checkpoint.  On multi-host deployments each host
writes its own leaf shards (shard_id in the manifest); in this container
there is one host, so shard_id is always 0 — the format is forward
compatible.
"""
from __future__ import annotations

import json
import os
import shutil
from concurrent.futures import Future, ThreadPoolExecutor

import numpy as np

import zlib

try:
    import zstandard
    _HAS_ZSTD = True
except ImportError:  # container without zstandard: zlib shim, same API
    _HAS_ZSTD = False
    class _ZlibCompressor:
        def __init__(self, level: int = 3):
            self.level = level

        def compress(self, data: bytes) -> bytes:
            return zlib.compress(data, self.level)

    class _ZlibDecompressor:
        def decompress(self, data: bytes) -> bytes:
            return zlib.decompress(data)

    class zstandard:  # type: ignore[no-redef]
        ZstdCompressor = _ZlibCompressor
        ZstdDecompressor = _ZlibDecompressor


_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _decompress(data: bytes) -> bytes:
    """Decompress a leaf written by either codec (zstd or zlib shim).

    Frames are sniffed by magic so checkpoints stay readable across
    environments with and without zstandard installed.
    """
    if data[:4] == _ZSTD_MAGIC:
        if not _HAS_ZSTD:
            raise RuntimeError(
                "checkpoint leaf is zstd-compressed but the zstandard "
                "module is unavailable in this environment")
        return zstandard.ZstdDecompressor().decompress(data)
    return zlib.decompress(data)

import jax


_EXEC = ThreadPoolExecutor(max_workers=2)


def _leaf_paths(tree, prefix=""):
    """Deterministic (path, leaf) pairs."""
    paths = []
    for kp, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        paths.append((key, leaf))
    return paths


def save(directory: str, step: int, tree, *, keep: int = 3,
         async_: bool = False) -> Future | None:
    """Checkpoint ``tree`` at ``step``.  Returns a Future if async."""
    # Materialize on host before handing to the writer thread.
    leaves = [(k, np.asarray(v)) for k, v in _leaf_paths(tree)]
    treedef = jax.tree.structure(tree)

    def _write():
        final = os.path.join(directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        cctx = zstandard.ZstdCompressor(level=3)
        manifest = {"step": step, "treedef": str(treedef), "leaves": []}
        for i, (key, arr) in enumerate(leaves):
            fn = f"leaf_{i:05d}.zst"
            with open(os.path.join(tmp, fn), "wb") as f:
                f.write(cctx.compress(np.ascontiguousarray(arr).tobytes()))
            manifest["leaves"].append(
                {"key": key, "file": fn, "dtype": str(arr.dtype),
                 "shape": list(arr.shape), "shard_id": 0})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        _gc(directory, keep)
        return final

    if async_:
        return _EXEC.submit(_write)
    _write()
    return None


def _gc(directory: str, keep: int):
    steps = sorted(all_steps(directory))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, name,
                                           "manifest.json")):
                out.append(int(name[5:]))
    return sorted(out)


def latest_step(directory: str) -> int | None:
    steps = all_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, like):
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {}
    for entry in manifest["leaves"]:
        with open(os.path.join(path, entry["file"]), "rb") as f:
            raw = _decompress(f.read())
        by_key[entry["key"]] = np.frombuffer(
            raw, dtype=np.dtype(entry["dtype"])
        ).reshape(entry["shape"])
    out_leaves = []
    for key, leaf in _leaf_paths(like):
        arr = by_key[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (
            key, arr.shape, leaf.shape)
        out_leaves.append(arr)
    return jax.tree.unflatten(jax.tree.structure(like), out_leaves)
