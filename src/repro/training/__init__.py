from repro.training.step import (
    TrainConfig, init_state, make_train_step, make_prefill_step,
    make_decode_step, shard_train_step, state_axes, batch_specs,
)

__all__ = ["TrainConfig", "init_state", "make_train_step",
           "make_prefill_step", "make_decode_step", "shard_train_step",
           "state_axes", "batch_specs"]
