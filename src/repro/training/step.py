"""Train / prefill / decode step builders (the units the dry-run lowers).

``make_train_step`` returns a pure (state, batch) -> (state, metrics)
function: fwd+bwd (remat per config), grad accumulation (microbatching),
AdamW (optionally int8 moments), warmup-cosine LR.  Sharding enters only
through in/out_shardings at jit time (``shard_train_step``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import optim
from repro.models import lm, whisper
from repro.models.config import ModelConfig
from repro.models.sharding import DEFAULT_RULES, tree_specs, spec_for
from repro.optim.schedule import warmup_cosine


@dataclass(frozen=True)
class TrainConfig:
    adamw: optim.AdamWConfig = optim.AdamWConfig()
    warmup_steps: int = 100
    total_steps: int = 10_000
    microbatches: int = 1          # gradient accumulation
    # int8 error-feedback gradient compression (optim/compress.py).
    # Numerics applied here (quantize->dequantize with carried error);
    # the on-wire byte reduction additionally needs the shard_map DP
    # reduction (optim.compress.compressed_psum) on a real pod.
    grad_compression: bool = False


def loss_for(cfg: ModelConfig):
    if cfg.encdec:
        return functools.partial(whisper.loss_fn, cfg)
    return functools.partial(lm.loss_fn, cfg)


def init_state(cfg: ModelConfig, tcfg: TrainConfig, key):
    if cfg.encdec:
        params, axes = whisper.init(cfg, key)
    else:
        params, axes = lm.init(cfg, key)
    opt = optim.init(params, tcfg.adamw)
    state = {"params": params, "opt": opt}
    if tcfg.grad_compression:
        from repro.optim import compress

        state["grad_error"] = compress.init_error(params)
    return state, axes


def state_axes(cfg: ModelConfig, tcfg: TrainConfig, params_axes):
    axes = {"params": params_axes,
            "opt": optim.state_axes(params_axes, tcfg.adamw)}
    if tcfg.grad_compression:
        axes["grad_error"] = params_axes
    return axes


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    loss_fn = loss_for(cfg)

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]

        def fwd(p, mb):
            loss, metrics = loss_fn(p, mb)
            return loss, metrics

        if tcfg.microbatches > 1:
            def split(x):
                b = x.shape[0]
                mb = tcfg.microbatches
                return x.reshape(mb, b // mb, *x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                g_acc, m_acc = carry
                (_, metrics), g = jax.value_and_grad(
                    fwd, has_aux=True)(params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                m_acc = jax.tree.map(jnp.add, m_acc, metrics)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mb0 = jax.tree.map(lambda x: x[0], mbs)
            (_, metrics0), _ = jax.value_and_grad(
                fwd, has_aux=True)(params, mb0)
            m0 = jax.tree.map(jnp.zeros_like, metrics0)
            (grads, msum), _ = jax.lax.scan(acc_fn, (g0, m0), mbs)
            grads = jax.tree.map(
                lambda g: g / tcfg.microbatches, grads)
            metrics = jax.tree.map(
                lambda m: m / tcfg.microbatches, msum)
        else:
            (_, metrics), grads = jax.value_and_grad(
                fwd, has_aux=True)(params, batch)

        # Schedule on the post-increment step (step 0 would give lr=0
        # and silently waste the first batch).
        new_state = {}
        if tcfg.grad_compression:
            from repro.optim import compress

            def comp(g, e):
                q, scale, new_e = compress.ef_compress(g, e)
                return compress.ef_decompress(q, scale), new_e

            pairs = jax.tree.map(comp, grads, state["grad_error"])
            grads = jax.tree.map(lambda pe: pe[0], pairs,
                                 is_leaf=lambda x: isinstance(x, tuple))
            new_state["grad_error"] = jax.tree.map(
                lambda pe: pe[1], pairs,
                is_leaf=lambda x: isinstance(x, tuple))

        lr_scale = warmup_cosine(
            opt["step"] + 1, warmup=tcfg.warmup_steps,
            total=tcfg.total_steps)
        new_params, new_opt, opt_metrics = optim.apply(
            params, grads, opt, tcfg.adamw, lr_scale=lr_scale)
        metrics = {**metrics, **opt_metrics}
        return {**new_state, "params": new_params, "opt": new_opt}, \
            metrics

    return train_step


# -- serving steps ---------------------------------------------------------------

def make_prefill_step(cfg: ModelConfig, *, seq_shard: bool = False):
    if cfg.encdec:
        def prefill_step(params, batch):
            state, logits = whisper.prefill(
                cfg, params, batch["frames"], batch["tokens"])
            return state, logits
        return prefill_step

    def prefill_step(params, batch):
        cache, logits = lm.prefill(
            cfg, params, batch["tokens"], None,
            patches=batch.get("patches"), seq_shard=seq_shard)
        return cache, logits

    return prefill_step


def make_decode_step(cfg: ModelConfig, *, seq_shard: bool = False):
    if cfg.encdec:
        def decode_step(params, state, token, kv_len):
            return whisper.decode(cfg, params, state, token, kv_len)
        return decode_step

    def decode_step(params, cache, token, kv_len):
        return lm.decode(cfg, params, cache, token, kv_len,
                         seq_shard=seq_shard)

    return decode_step


# -- sharded jit wrappers ----------------------------------------------------------

def batch_specs(cfg: ModelConfig, batch_tree, mesh: Mesh, rules=None):
    """P('pod','data') on the batch dim of every batch leaf."""
    def spec(x):
        axes = ("batch",) + (None,) * (len(x.shape) - 1)
        return spec_for(axes, mesh, rules or DEFAULT_RULES)

    return jax.tree.map(spec, batch_tree)


def shard_train_step(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh,
                     axes, batch_like, rules=None, donate: bool = True):
    """jit the train step with explicit in/out shardings for ``mesh``."""
    rules = rules or DEFAULT_RULES
    st_axes = state_axes(cfg, tcfg, axes)
    st_specs = tree_specs(st_axes, mesh, rules)
    st_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, s), st_specs,
        is_leaf=lambda x: isinstance(x, P))
    b_specs = batch_specs(cfg, batch_like, mesh, rules)
    b_shard = jax.tree.map(lambda s: NamedSharding(mesh, s), b_specs,
                           is_leaf=lambda x: isinstance(x, P))
    step = make_train_step(cfg, tcfg)
    return jax.jit(
        step,
        in_shardings=(st_shard, b_shard),
        out_shardings=(st_shard, None),
        donate_argnums=(0,) if donate else (),
    )
