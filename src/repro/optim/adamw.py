"""AdamW from scratch, with optional int8 block-quantized moments.

The int8 moments (per-256-block absmax scales, error-free requantization
each step) cut optimizer state from 8 to ~2.03 bytes/param — the
difference between deepseek-v2-236b fitting a 256-chip pod or not
(see EXPERIMENTS.md §Dry-run memory table).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moments_dtype: str = "float32"   # float32 | int8
    quant_block: int = 256


# -- int8 moment quantization -------------------------------------------------
#
# m (signed, zero-centered): per-block absmax linear int8.
# v (non-negative, huge dynamic range): per-block AFFINE code in LOG space
#   — linear int8 collapses small v to 0 and rsqrt explodes (observed:
#   training diverges within 5 steps); log-affine keeps ~10% relative
#   error across 20+ orders of magnitude, which AdamW tolerates.
#
# Blocks subdivide the LAST parameter axis and keep all leading axes, so
# quantized state inherits the parameter's sharding (a flat block layout
# forces a 75 GB f32 reshard per expert stack per step; EXPERIMENTS §Perf).

_V_FLOOR = 1e-20


def _block_size(last: int, block: int) -> int:
    if last % block == 0:
        return block
    return last   # one block per row for small/odd trailing dims


def _blocks(x: jnp.ndarray, block: int):
    x = x.reshape(x.shape if x.ndim else (1,))
    blk = _block_size(x.shape[-1], block)
    return x.reshape(*x.shape[:-1], x.shape[-1] // blk, blk)


def _unblocks(b: jnp.ndarray, shape) -> jnp.ndarray:
    return b.reshape(shape if shape else (1,)).reshape(shape)


def _quantize_m(x: jnp.ndarray, block: int):
    blocks = _blocks(x, block)
    scale = jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequantize_m(s, shape) -> jnp.ndarray:
    return _unblocks(s["q"].astype(jnp.float32) * s["scale"], shape)


def _quantize_v(x: jnp.ndarray, block: int):
    lx = jnp.log(jnp.maximum(_blocks(x, block), _V_FLOOR))
    mn = jnp.min(lx, axis=-1, keepdims=True)
    mx = jnp.max(lx, axis=-1, keepdims=True)
    scale = (mx - mn) / 254.0
    q = jnp.round((lx - mn) / jnp.maximum(scale, 1e-12)).astype(jnp.uint8)
    return {"q": q, "scale": scale.astype(jnp.float32),
            "min": mn.astype(jnp.float32)}


def _dequantize_v(s, shape) -> jnp.ndarray:
    lx = s["q"].astype(jnp.float32) * s["scale"] + s["min"]
    v = jnp.exp(lx)
    v = jnp.where(v <= _V_FLOOR * 1.01, 0.0, v)
    return _unblocks(v, shape)


def _moment_init(p, cfg: AdamWConfig, kind: str):
    z = jnp.zeros(p.shape, jnp.float32)
    if cfg.moments_dtype == "int8":
        return (_quantize_m if kind == "m" else _quantize_v)(
            z, cfg.quant_block)
    return z


def _moment_get(x, cfg: AdamWConfig, shape=None, kind: str = "m"):
    if cfg.moments_dtype != "int8":
        return x
    return (_dequantize_m if kind == "m" else _dequantize_v)(x, shape)


def _moment_put(x, cfg: AdamWConfig, kind: str = "m"):
    if cfg.moments_dtype != "int8":
        return x
    return (_quantize_m if kind == "m" else _quantize_v)(
        x, cfg.quant_block)


_IS_QUANT = lambda x: isinstance(x, dict) and "q" in x and "scale" in x


# -- optimizer ----------------------------------------------------------------

def init(params, cfg: AdamWConfig):
    return {
        "m": jax.tree.map(
            lambda p: _moment_init(p, cfg, "m"), params),
        "v": jax.tree.map(
            lambda p: _moment_init(p, cfg, "v"), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    sq = jax.tree.map(
        lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq, jnp.float32(0)))


def apply(params, grads, state, cfg: AdamWConfig, lr_scale=1.0):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else 1.0
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_f = _moment_get(m, cfg, p.shape, "m")
        v_f = _moment_get(v, cfg, p.shape, "v")
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        upd = (m_f / b1c) / (jnp.sqrt(v_f / b2c) + cfg.eps)
        new_p = (p.astype(jnp.float32)
                 - lr * (upd + cfg.weight_decay * p.astype(jnp.float32)))
        return (new_p.astype(p.dtype), _moment_put(m_f, cfg, "m"),
                _moment_put(v_f, cfg, "v"))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = jax.tree.flatten(state["m"], is_leaf=_IS_QUANT)[0]
    flat_v = jax.tree.flatten(state["v"], is_leaf=_IS_QUANT)[0]
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    mdef = jax.tree.structure(state["m"], is_leaf=_IS_QUANT)
    new_m = jax.tree.unflatten(mdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(mdef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}


def state_axes(params_axes, cfg: AdamWConfig):
    """Logical axes for the optimizer state (moments mirror params).

    int8 quantized moments are flattened blocks — replicated layout
    placeholder (they are per-device in the sharded step since the
    quantization happens after gradient resharding).
    """
    is_axes_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x)
    if cfg.moments_dtype == "int8":
        # Quantized blocks subdivide the last param axis: (lead..., nb,
        # blk).  The block dim nb inherits the last param axis' logical
        # name so moments shard EXACTLY like their parameter — replicated
        # or misaligned moments force full-stack f32 all-gathers at
        # update time (measured: 6 x 302 GB/step on deepseek-v2;
        # EXPERIMENTS.md §Perf).
        def qaxes(axes):
            return tuple(axes) + (None,)

        mom_m = jax.tree.map(
            lambda a: {"q": qaxes(a), "scale": qaxes(a)},
            params_axes, is_leaf=is_axes_leaf)
        mom_v = jax.tree.map(
            lambda a: {"q": qaxes(a), "scale": qaxes(a),
                       "min": qaxes(a)},
            params_axes, is_leaf=is_axes_leaf)
        return {"m": mom_m, "v": mom_v, "step": ()}
    return {"m": params_axes, "v": params_axes, "step": ()}
