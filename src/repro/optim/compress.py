"""Error-feedback int8 gradient compression (distributed-optimization trick).

In the DP all-reduce, gradients are quantized to int8 with per-tensor
absmax scales; the quantization residual is carried in an error-feedback
buffer so the bias vanishes over steps (1-bit-Adam-style).  The sum is
taken in int32 over the quantized values (exact), then dequantized — a
4x reduction in DP collective bytes at the cost of one extra abs-max
all-reduce per tensor (scales must agree across replicas).

``compressed_psum`` is the shard_map building block; ``ef_compress`` /
``ef_decompress`` are the pure parts used by the train step when
``grad_compression=True``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_compress(grad: jnp.ndarray, error: jnp.ndarray):
    """Quantize (grad + error) to int8; returns (q, scale, new_error)."""
    g = grad.astype(jnp.float32) + error
    scale = jnp.max(jnp.abs(g)) / 127.0
    q = jnp.clip(jnp.round(g / jnp.maximum(scale, 1e-12)),
                 -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return q, scale, g - deq


def ef_decompress(q: jnp.ndarray, scale) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum(q: jnp.ndarray, scale, axis_name: str):
    """psum of int8 grads inside shard_map: exact int32 sum of quants.

    Requires the scale to be made common first (max over replicas).
    """
    common = jax.lax.pmax(scale, axis_name)
    # Requantize to the common scale (cheap, int domain).
    ratio = scale / jnp.maximum(common, 1e-12)
    q32 = jnp.round(q.astype(jnp.float32) * ratio).astype(jnp.int32)
    total = jax.lax.psum(q32, axis_name)
    n = jax.lax.psum(jnp.int32(1), axis_name)
    return total.astype(jnp.float32) * common / n


def init_error(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
