from repro.optim.adamw import AdamWConfig, apply, init, state_axes, global_norm
from repro.optim.schedule import warmup_cosine, linear_warmup

__all__ = ["AdamWConfig", "apply", "init", "state_axes", "global_norm",
           "warmup_cosine", "linear_warmup"]
