"""Mamba2 blocks via SSD (state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: intra-chunk attention-like
quadratic blocks + inter-chunk linear state recurrence (lax.scan over
chunks).  Decode is the O(1) recurrent update.  State math in fp32.

Shapes: d_inner = expand*d_model, H = d_inner/head_dim heads, state N,
groups G (B/C shared per group).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, SSMCfg
from repro.models.layers import Builder, rmsnorm


def ssm_dims(cfg: ModelConfig):
    s: SSMCfg = cfg.ssm
    d_in = s.expand * cfg.d_model
    H = d_in // s.head_dim
    conv_ch = d_in + 2 * s.n_groups * s.d_state
    return d_in, H, conv_ch


def make_ssm(b: Builder, cfg: ModelConfig, stack: int = 0):
    s: SSMCfg = cfg.ssm
    d = cfg.d_model
    d_in, H, conv_ch = ssm_dims(cfg)
    sc = b.scope("ssm")
    # in_proj -> [z(d_in), xBC(conv_ch), dt(H)]
    sc.make("w_in", (d, 2 * d_in + 2 * s.n_groups * s.d_state + H),
            ("embed", "ssm_inner"), stack=stack)
    sc.make("conv_w", (s.conv_width, conv_ch), ("conv", "ssm_inner"),
            stack=stack, init="normal", fan_in=s.conv_width)
    sc.make("conv_b", (conv_ch,), ("ssm_inner",), init="zeros", stack=stack)
    sc.make("a_log", (H,), ("heads",), init="zeros", stack=stack,
            dtype=jnp.float32)
    sc.make("d_skip", (H,), ("heads",), init="ones", stack=stack,
            dtype=jnp.float32)
    sc.make("dt_bias", (H,), ("heads",), init="zeros", stack=stack,
            dtype=jnp.float32)
    sc.make("norm_scale", (d_in,), ("ssm_inner",), init="zeros",
            stack=stack)
    sc.make("w_out", (d_in, d), ("ssm_inner", "embed"), stack=stack)


def _split_proj(p, cfg, x):
    s: SSMCfg = cfg.ssm
    d_in, H, conv_ch = ssm_dims(cfg)
    proj = x @ p["w_in"]
    z = proj[..., :d_in]
    xbc = proj[..., d_in : d_in + conv_ch]
    dt = proj[..., d_in + conv_ch :]
    return z, xbc, dt


def _conv(p, xbc, conv_state=None):
    """Causal depthwise conv; xbc: (B, S, CC).  Returns (out, new_state)."""
    w = p["conv_w"]                     # (W, CC)
    W = w.shape[0]
    if conv_state is None:
        pad = jnp.zeros(xbc.shape[:1] + (W - 1,) + xbc.shape[2:], xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)
    out = jnp.zeros_like(xbc)
    for k in range(W):
        out = out + full[:, k : k + xbc.shape[1]] * w[k]
    out = jax.nn.silu(out + p["conv_b"])
    new_state = full[:, full.shape[1] - (W - 1) :]
    return out, new_state


def ssd_forward(p, cfg: ModelConfig, x, *, init_state=None,
                conv_state=None):
    """x: (B, S, d) -> (out (B, S, d), cache {state, conv}).

    Chunked SSD scan; S must be a multiple of cfg.ssm.chunk (pad upstream).
    """
    s: SSMCfg = cfg.ssm
    B_, S, _ = x.shape
    d_in, H, conv_ch = ssm_dims(cfg)
    G, N, P = s.n_groups, s.d_state, s.head_dim
    Q = min(s.chunk, S)
    assert S % Q == 0, (S, Q)
    NC = S // Q

    z, xbc, dt_raw = _split_proj(p, cfg, x)
    xbc, new_conv = _conv(p, xbc, conv_state)
    xs = xbc[..., :d_in]
    Bmat = xbc[..., d_in : d_in + G * N].reshape(B_, S, G, N)
    Cmat = xbc[..., d_in + G * N :].reshape(B_, S, G, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                     # (H,)
    xh = xs.reshape(B_, S, H, P).astype(jnp.float32)
    rep = H // G
    Bh = jnp.repeat(Bmat, rep, axis=2).astype(jnp.float32)  # (B,S,H,N)
    Ch = jnp.repeat(Cmat, rep, axis=2).astype(jnp.float32)

    def step(state, inp):
        xc, Bc, Cc, dtc = inp                        # (B,Q,...) one chunk
        dA = dtc * A                                 # (B,Q,H)
        t = jnp.cumsum(dA, axis=1)                   # inclusive
        # Intra-chunk (diagonal block).
        CB = jnp.einsum("bihn,bjhn->bhij", Cc, Bc)
        Ld = t[:, :, None, :] - t[:, None, :, :]     # t_i - t_j (B,Q,Q,H)
        mask = jnp.tril(jnp.ones((Q, Q), bool))
        Lmat = jnp.where(mask[None, :, :, None], jnp.exp(Ld), 0.0)
        M = CB * jnp.moveaxis(Lmat, 3, 1)            # (B,H,Q,Q)
        y = jnp.einsum("bhij,bjh,bjhp->bihp", M, dtc, xc)
        # Inter-chunk: contribution of incoming state.
        y = y + jnp.einsum("bihn,bhpn,bih->bihp", Cc, state,
                           jnp.exp(t))
        # State update.
        decay_out = jnp.exp(t[:, -1:, :] - t)        # (B,Q,H)
        new_state = state * jnp.exp(t[:, -1])[:, :, None, None] + jnp.einsum(
            "bjhn,bjh,bjhp->bhpn", Bc, dtc * decay_out, xc)
        return new_state, y

    def chunked(a):                                  # (B,S,...) -> (NC,B,Q,...)
        return jnp.moveaxis(
            a.reshape((B_, NC, Q) + a.shape[2:]), 1, 0)

    state = (jnp.zeros((B_, H, P, N), jnp.float32)
             if init_state is None else init_state.astype(jnp.float32))
    state, ys = jax.lax.scan(
        step, state, (chunked(xh), chunked(Bh), chunked(Ch), chunked(dt)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B_, S, H, P)  # (B,S,H,P)

    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(B_, S, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(y, p["norm_scale"])
    out = y.astype(x.dtype) @ p["w_out"]
    cache = {"state": state, "conv": new_conv}
    return out, cache


def ssd_decode(p, cfg: ModelConfig, x, cache):
    """Single-token recurrence.  x: (B, 1, d)."""
    s: SSMCfg = cfg.ssm
    B_, _, _ = x.shape
    d_in, H, conv_ch = ssm_dims(cfg)
    G, N, P = s.n_groups, s.d_state, s.head_dim

    z, xbc, dt_raw = _split_proj(p, cfg, x)
    # Roll conv state: conv over [state, new].
    xbc, new_conv = _conv(p, xbc, cache["conv"])
    xs = xbc[..., :d_in]
    Bmat = xbc[..., d_in : d_in + G * N].reshape(B_, G, N)
    Cmat = xbc[..., d_in + G * N :].reshape(B_, G, N)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xs.reshape(B_, H, P).astype(jnp.float32)
    rep = H // G
    Bh = jnp.repeat(Bmat, rep, axis=1).astype(jnp.float32)   # (B,H,N)
    Ch = jnp.repeat(Cmat, rep, axis=1).astype(jnp.float32)

    dA = jnp.exp(dt * A)                                      # (B,H)
    state = cache["state"] * dA[:, :, None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt, xh, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(B_, 1, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(y, p["norm_scale"])
    out = y.astype(x.dtype) @ p["w_out"]
    return out, {"state": state, "conv": new_conv}


def ssm_cache_shape(cfg: ModelConfig, batch: int):
    s: SSMCfg = cfg.ssm
    d_in, H, conv_ch = ssm_dims(cfg)
    return {
        "state": (batch, H, s.head_dim, s.d_state),
        "conv": (batch, s.conv_width - 1, conv_ch),
    }
