"""Decoder-only language models: dense / MoE / MLA / SSM / hybrid / VLM.

The layer stack is organized as scanned "units" (DESIGN: keeps the HLO a
single rolled loop — essential for compiling 48-60-layer models quickly
and for clean pipeline stages):

  dense, moe(every=1):  unit = 1 decoder layer,        n_units = n_layers
  moe(every=2, llama4): unit = dense layer + MoE layer, n_units = n_layers/2
  ssm (mamba2):         unit = 1 mamba layer,           n_units = n_layers
  hybrid (zamba2):      unit = shared_every mamba layers + 1 application
                        of the SHARED attention block,  n_units = n_layers/shared_every

Public entry points: init / loss_fn / prefill / decode / make_cache.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.config import ModelConfig
from repro.models.layers import Builder, apply_norm, cross_entropy, make_norm
from repro.models.sharding import constrain
from repro.models.ssm import ssm_cache_shape


# -- structure ----------------------------------------------------------------

def unit_layout(cfg: ModelConfig) -> tuple[str, int]:
    """Returns (unit_kind, n_units)."""
    if cfg.family in ("ssm",):
        return "ssm", cfg.n_layers
    if cfg.family == "hybrid":
        assert cfg.shared_every and cfg.n_layers % cfg.shared_every == 0
        return "hybrid", cfg.n_layers // cfg.shared_every
    if cfg.moe is not None and cfg.moe.every == 2:
        assert cfg.n_layers % 2 == 0
        return "dense_moe", cfg.n_layers // 2
    if cfg.moe is not None:
        return "moe", cfg.n_layers
    return "dense", cfg.n_layers


def init(cfg: ModelConfig, key, abstract: bool = False
         ) -> tuple[dict, dict]:
    """Build (params, logical_axes) pytrees.

    ``abstract=True`` returns ShapeDtypeStructs (dry-run: no allocation).
    """
    b = Builder(key, cfg.pdtype, abstract=abstract)
    b.make("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
           fan_in=cfg.d_model)
    if not cfg.tie_embeddings:
        b.make("head", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    make_norm(b, "ln_final", cfg.norm, cfg.d_model)

    kind, n_units = unit_layout(cfg)
    u = b.scope("units")
    if kind == "dense":
        blocks.make_decoder_layer(u, cfg, moe_layer=False, stack=n_units)
    elif kind == "moe":
        blocks.make_decoder_layer(u, cfg, moe_layer=True, stack=n_units)
    elif kind == "dense_moe":
        blocks.make_decoder_layer(u.scope("a"), cfg, moe_layer=False,
                                  stack=n_units)
        blocks.make_decoder_layer(u.scope("b"), cfg, moe_layer=True,
                                  stack=n_units)
    elif kind == "ssm":
        blocks.make_ssm_layer(u, cfg, stack=n_units)
    elif kind == "hybrid":
        for i in range(cfg.shared_every):
            blocks.make_ssm_layer(u.scope(f"ssm_{i}"), cfg, stack=n_units)
        # Shared attention block: parameters NOT stacked (shared).
        sh = b.scope("shared")
        blocks.make_decoder_layer(sh, cfg, moe_layer=False)
    return b.params, b.axes


# -- caches -------------------------------------------------------------------

def _attn_cache(cfg: ModelConfig, batch: int, seq: int, *, stack: int,
                seq_shard: bool, ring: bool, dtype):
    seq_ax = "seq_shard" if seq_shard else None
    if cfg.mla is not None:
        m = cfg.mla
        shapes = {
            "__mla_c": ((stack, batch, seq, m.kv_lora_rank),
                        ("layers", "batch", seq_ax, None)),
            "__mla_r": ((stack, batch, seq, m.rope_head_dim),
                        ("layers", "batch", seq_ax, None)),
        }
        vals = {k: jnp.zeros(s, dtype) for k, (s, _) in shapes.items()}
        axes = {k: a for k, (_, a) in shapes.items()}
        # packed as tuple (c, k_rope) by the layer code
        return (vals["__mla_c"], vals["__mla_r"]), (
            axes["__mla_c"], axes["__mla_r"])
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    cache = {
        "k": jnp.zeros((stack, batch, seq, hkv, dh), dtype),
        "v": jnp.zeros((stack, batch, seq, hkv, dh), dtype),
    }
    axes = {
        "k": ("layers", "batch", seq_ax, "heads", None),
        "v": ("layers", "batch", seq_ax, "heads", None),
    }
    if ring:
        cache["pos"] = jnp.full((stack, batch, seq), -1, jnp.int32)
        axes["pos"] = ("layers", "batch", seq_ax)
    return cache, axes


def _ssm_cache(cfg: ModelConfig, batch: int, stack: int, dtype):
    sh = ssm_cache_shape(cfg, batch)
    cache = {
        "state": jnp.zeros((stack,) + sh["state"], jnp.float32),
        "conv": jnp.zeros((stack,) + sh["conv"], dtype),
    }
    axes = {
        "state": ("layers", "batch", "heads", None, None),
        "conv": ("layers", "batch", None, "ssm_inner"),
    }
    return cache, axes


def make_cache(cfg: ModelConfig, batch: int, seq: int, *,
               seq_shard: bool = False, dtype=None):
    """Decode cache pytree + logical axes.  ``seq`` = max cache length.

    Sliding-window models get a ring buffer of size min(seq, window).
    """
    dtype = dtype or cfg.cdtype
    kind, n_units = unit_layout(cfg)
    ring = cfg.sliding_window is not None
    if ring:
        seq = min(seq, cfg.sliding_window)
    if kind in ("dense", "moe"):
        return _attn_cache(cfg, batch, seq, stack=n_units,
                           seq_shard=seq_shard, ring=ring, dtype=dtype)
    if kind == "dense_moe":
        ca, aa = _attn_cache(cfg, batch, seq, stack=n_units,
                             seq_shard=seq_shard, ring=ring, dtype=dtype)
        cb, ab = _attn_cache(cfg, batch, seq, stack=n_units,
                             seq_shard=seq_shard, ring=ring, dtype=dtype)
        return {"a": ca, "b": cb}, {"a": aa, "b": ab}
    if kind == "ssm":
        return _ssm_cache(cfg, batch, n_units, dtype)
    if kind == "hybrid":
        cache, axes = {}, {}
        for i in range(cfg.shared_every):
            cache[f"ssm_{i}"], axes[f"ssm_{i}"] = _ssm_cache(
                cfg, batch, n_units, dtype)
        cache["shared"], axes["shared"] = _attn_cache(
            cfg, batch, seq, stack=n_units, seq_shard=seq_shard,
            ring=False, dtype=dtype)
        return cache, axes
    raise ValueError(kind)


# -- unit forward ---------------------------------------------------------------

def _unit_fwd(cfg: ModelConfig, kind: str, unit_params, shared_params,
              x, positions, *, mode: str, cache=None, kv_len=None,
              seq_shard=False):
    window = cfg.sliding_window
    ring = window is not None and mode == "decode"
    aux = blocks.ZERO_AUX
    if kind in ("dense", "moe"):
        x, new_cache, aux = blocks.decoder_layer_fwd(
            unit_params, cfg, x, positions,
            moe_layer=(kind == "moe"), mode=mode, cache=cache,
            kv_len=kv_len, window=window, seq_shard=seq_shard, ring=ring)
    elif kind == "dense_moe":
        x, ca, aux_a = blocks.decoder_layer_fwd(
            unit_params["a"], cfg, x, positions, moe_layer=False,
            mode=mode, cache=None if cache is None else cache["a"],
            kv_len=kv_len, window=window, seq_shard=seq_shard, ring=ring)
        x, cb, aux_b = blocks.decoder_layer_fwd(
            unit_params["b"], cfg, x, positions, moe_layer=True,
            mode=mode, cache=None if cache is None else cache["b"],
            kv_len=kv_len, window=window, seq_shard=seq_shard, ring=ring)
        new_cache = None if mode == "train" else {"a": ca, "b": cb}
        aux = jax.tree.map(lambda p, q: p + q, aux_a, aux_b)
    elif kind == "ssm":
        x, new_cache, aux = blocks.ssm_layer_fwd(
            unit_params, cfg, x, mode=mode, cache=cache)
    elif kind == "hybrid":
        new_cache = {}
        for i in range(cfg.shared_every):
            x, c, _ = blocks.ssm_layer_fwd(
                unit_params[f"ssm_{i}"], cfg, x, mode=mode,
                cache=None if cache is None else cache[f"ssm_{i}"])
            new_cache[f"ssm_{i}"] = c
        x, c, aux = blocks.decoder_layer_fwd(
            shared_params, cfg, x, positions, moe_layer=False, mode=mode,
            cache=None if cache is None else cache["shared"],
            kv_len=kv_len, window=window, seq_shard=seq_shard, ring=False)
        new_cache["shared"] = c
        if mode == "train":
            new_cache = None
    else:
        raise ValueError(kind)
    return x, new_cache, aux


def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable
              if cfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


# -- stack forward ----------------------------------------------------------------

def _embed(cfg: ModelConfig, params, tokens):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x.astype(cfg.cdtype)


def _head(cfg: ModelConfig, params, x):
    x = apply_norm(cfg.norm, x, params.get("ln_final"))
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    else:
        logits = x @ params["head"]
    return constrain(logits, "batch", "seq", "vocab")


def forward(cfg: ModelConfig, params, x, positions, *, mode: str,
            cache=None, kv_len=None, seq_shard: bool = False):
    """Run the unit stack.  x: (B, S, d) embedded input."""
    kind, n_units = unit_layout(cfg)
    shared = params.get("shared")

    def unit(xc, unit_in):
        unit_params, unit_cache = unit_in
        h, new_cache, aux = _unit_fwd(
            cfg, kind, unit_params, shared, xc, positions, mode=mode,
            cache=unit_cache, kv_len=kv_len, seq_shard=seq_shard)
        return h, (new_cache, aux)

    unit = _remat_wrap(cfg, unit)

    if cfg.scan_layers:
        x, (new_cache, auxs) = jax.lax.scan(
            unit, x, (params["units"], cache))
        aux = jax.tree.map(lambda a: jnp.sum(a, axis=0), auxs)
    else:
        caches, auxs = [], []
        for i in range(n_units):
            up = jax.tree.map(lambda a: a[i], params["units"])
            uc = (None if cache is None
                  else jax.tree.map(lambda a: a[i], cache))
            x, (nc, aux) = unit(x, (up, uc))
            caches.append(nc)
            auxs.append(aux)
        new_cache = (None if caches[0] is None else
                     jax.tree.map(lambda *xs: jnp.stack(xs), *caches))
        aux = jax.tree.map(lambda *xs: sum(xs), *auxs)
    return x, new_cache, aux


# -- public API -----------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params, batch) -> tuple[jnp.ndarray, dict]:
    """batch: {"tokens": (B, S) int32, optional "patches": (B, P, d)}."""
    tokens = batch["tokens"]
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full_like(tokens[:, :1], -1)], axis=1)
    x = _embed(cfg, params, tokens)
    if cfg.n_patches and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        labels = jnp.concatenate(
            [jnp.full(tokens.shape[:1] + (cfg.n_patches,), -1,
                      labels.dtype), labels], axis=1)
    x = constrain(x, "batch", "seq", "act_embed")
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None]
    x, _, aux = forward(cfg, params, x, positions, mode="train")
    logits = _head(cfg, params, x)
    ce = cross_entropy(logits, labels)
    loss = ce + aux["lb_loss"] + aux["z_loss"]
    metrics = {"loss": loss, "ce": ce, **aux}
    return loss, metrics


def prefill(cfg: ModelConfig, params, tokens, cache, *, patches=None,
            seq_shard: bool = False):
    """Build a KV cache from a full prompt.  Returns (cache, last_logits).

    Note: for attention families the prefill-returned per-layer k/v have
    the prompt's length; they are written into the (longer) decode cache.
    """
    B, S = tokens.shape
    x = _embed(cfg, params, tokens)
    if cfg.n_patches and patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
        S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.int32)[None]
    x, new_cache, _ = forward(cfg, params, x, positions, mode="prefill",
                              seq_shard=seq_shard)
    logits = _head(cfg, params, x[:, -1:])
    cache = _merge_prefill_cache(cfg, cache, new_cache, S)
    return cache, logits


def _merge_prefill_cache(cfg: ModelConfig, cache, fresh, prompt_len: int):
    """Write prefill k/v (length S_p) into the decode cache buffers."""
    if cache is None:
        return fresh

    def write_pos(dst):
        S = dst.shape[-1]
        take = min(S, prompt_len)
        pos = jnp.arange(prompt_len - take, prompt_len, dtype=jnp.int32)
        upd = jnp.full_like(dst, -1)
        idx = pos % S
        return upd.at[:, :, idx].set(
            jnp.broadcast_to(pos, dst.shape[:2] + (take,)))

    def write_seq(dst, src):
        take = min(prompt_len, dst.shape[2])
        src_t = src[:, :, prompt_len - take : prompt_len].astype(dst.dtype)
        if cfg.sliding_window is not None:
            S = dst.shape[2]
            idx = (jnp.arange(prompt_len - take, prompt_len) % S)
            return dst.at[:, :, idx].set(src_t)
        return jax.lax.dynamic_update_slice_in_dim(dst, src_t, 0, axis=2)

    def merge(dst, src):
        if isinstance(dst, dict):
            return {
                k: (write_pos(dst[k]) if k == "pos" and (
                    not isinstance(src, dict) or k not in src)
                    else merge(dst[k], src[k]))
                for k in dst
            }
        if isinstance(dst, (tuple, list)):
            return type(dst)(merge(d, s) for d, s in zip(dst, src))
        if (dst.ndim >= 3 and src.ndim == dst.ndim
                and dst.shape[:2] == src.shape[:2]
                and dst.shape[3:] == src.shape[3:]
                and dst.shape[2] != src.shape[2]):
            return write_seq(dst, src)
        return src.astype(dst.dtype) if src.shape == dst.shape else dst

    return merge(cache, fresh)


def decode(cfg: ModelConfig, params, cache, token, kv_len, *,
           seq_shard: bool = False):
    """One decode step.  token: (B,) int32; kv_len: (B,) current lengths.

    Returns (logits (B, 1, V), new cache).
    """
    x = _embed(cfg, params, token[:, None])
    positions = jnp.asarray(kv_len, jnp.int32).reshape(-1, 1)
    x, new_cache, _ = forward(cfg, params, x, positions, mode="decode",
                              cache=cache, kv_len=kv_len,
                              seq_shard=seq_shard)
    logits = _head(cfg, params, x)
    return logits, new_cache
