"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

Train/prefill: the low-rank KV projection ``c = W_dkv x`` is up-projected
to per-head k_nope/v and run through the shared blockwise attention (MLA
is effectively MHA with per-head dim nope+rope and a rope component shared
across heads).

Decode: the **absorbed** form — W_uk is folded into the query and W_uv
into the output so attention runs directly against the compressed cache
(c_kv: kv_lora_rank + rope_head_dim per token).  The KV cache is 576
values/token instead of n_heads*(dh_k+dh_v) = 32768 — the architecture's
whole point, visible in the decode_32k roofline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import blockwise_attention
from repro.models.config import MLACfg, ModelConfig
from repro.models.layers import Builder, apply_rope, make_norm, apply_norm


def make_mla(b: Builder, cfg: ModelConfig, stack: int = 0):
    m: MLACfg = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qd = m.nope_head_dim + m.rope_head_dim
    s = b.scope("mla")
    if m.q_lora_rank:
        s.make("w_dq", (d, m.q_lora_rank), ("embed", "kv_lora"), stack=stack)
        s.make("w_uq", (m.q_lora_rank, H, qd),
               ("kv_lora", "heads", "qkv"), stack=stack)
        make_norm(s, "q_norm", "rmsnorm", m.q_lora_rank, stack=stack)
    else:
        s.make("w_q", (d, H, qd), ("embed", "heads", "qkv"), stack=stack)
    s.make("w_dkv", (d, m.kv_lora_rank), ("embed", "kv_lora"), stack=stack)
    s.make("w_kr", (d, m.rope_head_dim), ("embed", "qkv"), stack=stack)
    make_norm(s, "kv_norm", "rmsnorm", m.kv_lora_rank, stack=stack)
    s.make("w_uk", (m.kv_lora_rank, H, m.nope_head_dim),
           ("kv_lora", "heads", "qkv"), stack=stack)
    s.make("w_uv", (m.kv_lora_rank, H, m.v_head_dim),
           ("kv_lora", "heads", "qkv"), stack=stack)
    s.make("w_o", (H, m.v_head_dim, d), ("heads", "qkv", "embed"),
           stack=stack)


def _queries(p, cfg: ModelConfig, x, positions):
    m = cfg.mla
    if m.q_lora_rank:
        cq = x @ p["w_dq"]
        cq = apply_norm("rmsnorm", cq, p.get("q_norm"))
        q = jnp.einsum("bsr,rhd->bshd", cq, p["w_uq"])
    else:
        q = jnp.einsum("bsd,dhq->bshq", x, p["w_q"])
    q_nope = q[..., : m.nope_head_dim]
    q_rope = apply_rope(q[..., m.nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_prefill(p, cfg: ModelConfig, x, positions, *, block_kv: int = 512):
    """x: (B, S, d).  Returns (out (B, S, d), cache (c_kv, k_rope))."""
    m = cfg.mla
    q_nope, q_rope = _queries(p, cfg, x, positions)
    c = apply_norm("rmsnorm", x @ p["w_dkv"], p.get("kv_norm"))  # (B,S,r)
    k_rope = apply_rope(x @ p["w_kr"], positions, cfg.rope_theta)
    # Materialize per-head K/V (naive prefill — the standard choice: the
    # absorbed form costs kv_lora/(nope+rope) ≈ 2.7x more score FLOPs).
    k_nope = jnp.einsum("bsr,rhd->bshd", c, p["w_uk"])
    v = jnp.einsum("bsr,rhd->bshd", c, p["w_uv"])
    k = jnp.concatenate(
        [k_nope,
         jnp.broadcast_to(k_rope[:, :, None, :],
                          k_nope.shape[:3] + (m.rope_head_dim,))],
        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scale = (m.nope_head_dim + m.rope_head_dim) ** -0.5
    out = blockwise_attention(q, k, v, causal=True, block_kv=block_kv,
                              scale=scale)
    out = jnp.einsum("bshv,hvd->bsd", out, p["w_o"])
    return out, (c, k_rope)


def mla_decode(p, cfg: ModelConfig, x, cache, kv_len):
    """Absorbed single-token decode.

    x: (B, 1, d); cache: (c_kv (B, S, r), k_rope (B, S, dr)).
    Returns (out (B, 1, d), updated cache).
    """
    m = cfg.mla
    B = x.shape[0]
    c_cache, r_cache = cache
    S = c_cache.shape[1]
    pos = jnp.asarray(kv_len, jnp.int32).reshape(-1)  # (B,) insert position
    positions = pos[:, None]

    q_nope, q_rope = _queries(p, cfg, x, positions)   # (B,1,H,*)
    c_new = apply_norm("rmsnorm", x @ p["w_dkv"], p.get("kv_norm"))
    r_new = apply_rope(x @ p["w_kr"], positions, cfg.rope_theta)
    bidx = jnp.arange(B)
    c_cache = c_cache.at[bidx, pos].set(
        c_new[:, 0].astype(c_cache.dtype))
    r_cache = r_cache.at[bidx, pos].set(
        r_new[:, 0].astype(r_cache.dtype))

    # Absorb W_uk into q:  q_eff = q_nope @ W_uk  -> (B, H, r)
    q_eff = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0], p["w_uk"])
    s = jnp.einsum("bhr,bsr->bhs", q_eff, c_cache,
                   preferred_element_type=jnp.float32)
    s = s + jnp.einsum("bhd,bsd->bhs", q_rope[:, 0], r_cache,
                       preferred_element_type=jnp.float32)
    s = s * (m.nope_head_dim + m.rope_head_dim) ** -0.5
    valid = jnp.arange(S)[None, :] <= pos[:, None]
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", w.astype(c_cache.dtype), c_cache,
                     preferred_element_type=jnp.float32)
    out = jnp.einsum("bhr,rhv->bhv", ctx.astype(x.dtype), p["w_uv"])
    out = jnp.einsum("bhv,hvd->bd", out, p["w_o"])[:, None]
    return out, (c_cache, r_cache)
