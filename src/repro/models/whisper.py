"""Whisper-style encoder-decoder (arXiv:2212.04356) — backbone only.

The conv frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings (B, S_frames, d_model).  Encoder:
bidirectional attention + GELU MLP with sinusoidal positions.  Decoder:
causal self-attention + cross-attention to the encoder output.

Shape policy (DESIGN.md §4): seq_len drives the ENCODER length; the
decoder runs at cfg.dec_len for train/prefill and single-token for decode
(cross-attention cache = projected encoder states at 32k frames for
decode_32k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.config import ModelConfig
from repro.models.layers import (
    Builder, apply_norm, cross_entropy, make_norm, sinusoidal_positions,
)
from repro.models.sharding import constrain


def init(cfg: ModelConfig, key, abstract: bool = False
         ) -> tuple[dict, dict]:
    b = Builder(key, cfg.pdtype, abstract=abstract)
    b.make("embed", (cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
           fan_in=cfg.d_model)
    make_norm(b, "ln_enc_final", cfg.norm, cfg.d_model)
    make_norm(b, "ln_dec_final", cfg.norm, cfg.d_model)

    enc = b.scope("encoder")
    make_norm(enc, "ln_attn", cfg.norm, cfg.d_model, stack=cfg.n_layers)
    make_norm(enc, "ln_mlp", cfg.norm, cfg.d_model, stack=cfg.n_layers)
    blocks.make_attn(enc, cfg, stack=cfg.n_layers)
    blocks.make_mlp(enc, cfg, stack=cfg.n_layers)

    n_dec = cfg.n_dec_layers or cfg.n_layers
    dec = b.scope("decoder")
    make_norm(dec, "ln_self", cfg.norm, cfg.d_model, stack=n_dec)
    make_norm(dec, "ln_cross", cfg.norm, cfg.d_model, stack=n_dec)
    make_norm(dec, "ln_mlp", cfg.norm, cfg.d_model, stack=n_dec)
    sa = dec.scope("self_attn")
    blocks.make_attn(sa, cfg, stack=n_dec)
    ca = dec.scope("cross_attn")
    blocks.make_attn(ca, cfg, stack=n_dec)
    blocks.make_mlp(dec, cfg, stack=n_dec)
    return b.params, b.axes


def _enc_layer(p, cfg, x):
    h = apply_norm(cfg.norm, x, p.get("ln_attn"))
    a, _ = blocks.attn_fwd(p["attn"], cfg, h,
                           jnp.zeros((1, 1), jnp.int32),
                           causal=False, rope=False)
    x = x + a
    h = apply_norm(cfg.norm, x, p.get("ln_mlp"))
    return x + blocks.mlp_fwd(p["mlp"], cfg, h)


def encode(cfg: ModelConfig, params, frames):
    """frames: (B, S, d) stub embeddings -> encoder states (B, S, d)."""
    S = frames.shape[1]
    x = frames.astype(cfg.cdtype) + sinusoidal_positions(
        S, cfg.d_model).astype(cfg.cdtype)
    x = constrain(x, "batch", "seq", "act_embed")

    def unit(xc, p):
        return _enc_layer(p, cfg, xc), None

    if cfg.scan_layers:
        from repro.models.lm import _remat_wrap
        x, _ = jax.lax.scan(_remat_wrap(cfg, unit), x, params["encoder"])
    else:
        for i in range(cfg.n_layers):
            x, _ = unit(x, jax.tree.map(lambda a: a[i], params["encoder"]))
    return apply_norm(cfg.norm, x, params.get("ln_enc_final"))


def _dec_layer(p, cfg, x, positions, enc_kv, *, mode, cache, kv_len):
    h = apply_norm(cfg.norm, x, p.get("ln_self"))
    if mode == "decode":
        a, self_cache = blocks.attn_decode(
            p["self_attn"]["attn"], cfg, h, cache["self"], kv_len,
            rope=False)
    else:
        a, (k, v) = blocks.attn_fwd(p["self_attn"]["attn"], cfg, h,
                                    positions, causal=True, rope=False)
        self_cache = {"k": k, "v": v} if mode == "prefill" else None
    x = x + a
    h = apply_norm(cfg.norm, x, p.get("ln_cross"))
    if mode == "decode":
        a, _ = blocks.attn_decode(
            p["cross_attn"]["attn"], cfg, h,
            {"k": enc_kv[0], "v": enc_kv[1]},
            kv_len=enc_kv[0].shape[1], rope=False, cross=True)
    else:
        a, _ = blocks.attn_fwd(p["cross_attn"]["attn"], cfg, h, positions,
                               causal=False, rope=False, kv=enc_kv)
    x = x + a
    h = apply_norm(cfg.norm, x, p.get("ln_mlp"))
    x = x + blocks.mlp_fwd(p["mlp"], cfg, h)
    new_cache = None if mode == "train" else {"self": self_cache}
    return x, new_cache


def cross_kv(cfg, params, enc_out):
    """Project encoder states to per-layer cross K/V once (at prefill)."""
    kc = jnp.einsum("bsd,ldhk->lbshk", enc_out,
                    params["decoder"]["cross_attn"]["attn"]["wk"])
    vc = jnp.einsum("bsd,ldhk->lbshk", enc_out,
                    params["decoder"]["cross_attn"]["attn"]["wv"])
    return kc, vc


def _decoder(cfg, params, tokens, enc_kv, *, mode, cache=None,
             kv_len=None):
    B, S = tokens.shape
    x = params["embed"][tokens].astype(cfg.cdtype)
    if mode == "decode":
        pos0 = jnp.asarray(kv_len, jnp.int32).reshape(-1, 1)
        pe = sinusoidal_positions(cache["self"]["k"].shape[2] + 1,
                                  cfg.d_model).astype(cfg.cdtype)
        x = x + pe[pos0[:, 0]][:, None]
        positions = pos0
    else:
        x = x + sinusoidal_positions(S, cfg.d_model).astype(cfg.cdtype)[None]
        positions = jnp.arange(S, dtype=jnp.int32)[None]
    x = constrain(x, "batch", "seq", "act_embed")
    kc, vc = enc_kv

    def unit(xc, inp):
        p, kvl, unit_cache = inp
        h, c = _dec_layer(p, cfg, xc, positions, kvl, mode=mode,
                          cache=unit_cache, kv_len=kv_len)
        return h, c

    from repro.models.lm import _remat_wrap
    unit = _remat_wrap(cfg, unit)
    x, new_cache = jax.lax.scan(
        unit, x, (params["decoder"], (kc, vc), cache))
    x = apply_norm(cfg.norm, x, params.get("ln_dec_final"))
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return constrain(logits, "batch", "seq", "vocab"), new_cache


def loss_fn(cfg: ModelConfig, params, batch):
    """batch: {"frames": (B, S_enc, d), "tokens": (B, dec_len)}."""
    enc_out = encode(cfg, params, batch["frames"])
    tokens = batch["tokens"]
    labels = jnp.concatenate(
        [tokens[:, 1:], jnp.full_like(tokens[:, :1], -1)], axis=1)
    logits, _ = _decoder(cfg, params, tokens, cross_kv(cfg, params, enc_out),
                         mode="train")
    loss = cross_entropy(logits, labels)
    return loss, {"loss": loss, "ce": loss}


def prefill(cfg: ModelConfig, params, frames, tokens):
    """Encode + decoder prefill.  Returns (state, last_logits).

    state = {"enc_kv": (kc, vc), "cache": {"self": stacked k/v}} — the
    cross-attention K/V are projected ONCE here; decode reuses them.
    """
    enc_out = encode(cfg, params, frames)
    enc_kv = cross_kv(cfg, params, enc_out)
    logits, cache = _decoder(cfg, params, tokens, enc_kv, mode="prefill")
    return {"enc_kv": enc_kv, "cache": cache}, logits[:, -1:]


def decode(cfg: ModelConfig, params, state, token, kv_len):
    logits, new_cache = _decoder(
        cfg, params, token[:, None], state["enc_kv"], mode="decode",
        cache=state["cache"], kv_len=kv_len)
    return logits, dict(state, cache=new_cache)


def make_cache(cfg: ModelConfig, batch: int, dec_len: int, enc_len: int,
               dtype=None):
    dtype = dtype or cfg.cdtype
    n_dec = cfg.n_dec_layers or cfg.n_layers
    hkv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    cache = {"self": {
        "k": jnp.zeros((n_dec, batch, dec_len, hkv, dh), dtype),
        "v": jnp.zeros((n_dec, batch, dec_len, hkv, dh), dtype),
    }}
    axes = {"self": {
        "k": ("layers", "batch", None, "heads", None),
        "v": ("layers", "batch", None, "heads", None),
    }}
    return cache, axes
