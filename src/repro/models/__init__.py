from repro.models.config import (
    MLACfg, MoECfg, ModelConfig, SSMCfg, SHAPE_CELLS, ShapeCell,
    cell_applicable,
)

__all__ = ["ModelConfig", "MoECfg", "MLACfg", "SSMCfg", "SHAPE_CELLS",
           "ShapeCell", "cell_applicable"]
