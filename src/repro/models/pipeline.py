"""Pipeline parallelism: GPipe-style microbatched stages over a mesh axis.

Completes the parallelism matrix (DP/TP/EP/SP elsewhere; PP here).  The
layer stack is split into `n_stages` contiguous groups; each stage lives
on one slice of the `pp` mesh axis (the `pod` axis on the two-pod mesh).
Microbatches stream through stages with `jax.lax.ppermute` boundary
transfers in a fori loop — the standard GPipe schedule (fill, steady
state, drain) with bubble fraction (S-1)/(M+S-1).

Scope: forward-and-loss is staged (activations cross pods once per
microbatch); the backward pass is produced by jax.grad through the
ppermute (its transpose is the reverse permute), which yields the
symmetric backward schedule automatically.

Usage (demonstrated in tests/test_pipeline.py on 4 host devices):
    fwd = make_pipelined_forward(cfg, n_stages=2, n_micro=4,
                                 axis_name="pod")
    loss = fwd(params, batch)  # inside shard_map over the pp axis
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.jaxcompat import shard_map_compat

from repro.models import blocks
from repro.models.config import ModelConfig
from repro.models.layers import cross_entropy


def split_stages(cfg: ModelConfig, params: dict, n_stages: int):
    """Slice the scanned unit stack into per-stage stacks."""
    from repro.models.lm import unit_layout

    _, n_units = unit_layout(cfg)
    assert n_units % n_stages == 0, (n_units, n_stages)
    per = n_units // n_stages

    def slice_stage(s):
        return jax.tree.map(
            lambda a: a[s * per : (s + 1) * per], params["units"])

    return [slice_stage(s) for s in range(n_stages)], per


def make_pipelined_loss(cfg: ModelConfig, mesh: Mesh, *, n_micro: int,
                        pp_axis: str = "pod"):
    """Build a pipelined loss fn over ``pp_axis`` of ``mesh``.

    The returned function takes (params, batch) with params REPLICATED
    (each stage uses only its slice — the memory win comes from the
    optimizer/grad sharding, orthogonal here) and batch sharded over
    microbatches; it returns the mean loss.  Decoder-only dense/moe
    families (uniform units) are supported.
    """
    from repro.models.lm import _embed, _head, unit_layout

    n_stages = mesh.shape[pp_axis]
    kind, n_units = unit_layout(cfg)
    assert kind in ("dense", "moe"), "PP demo covers uniform decoders"
    assert n_units % n_stages == 0
    per = n_units // n_stages

    def stage_apply(stage_params, x, positions):
        def unit(xc, up):
            h, _, _ = blocks.decoder_layer_fwd(
                up, cfg, xc, positions, moe_layer=(kind == "moe"),
                mode="train", window=cfg.sliding_window)
            return h, None

        x, _ = jax.lax.scan(unit, x, stage_params)
        return x

    def local_fn(params, tokens, labels):
        # tokens: (n_micro_local..., B_mb, S) — each pp rank sees the SAME
        # microbatch stream; rank s processes stage s.
        stage_id = jax.lax.axis_index(pp_axis)
        my_stage = jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(
                a, stage_id * per, per, axis=0), params["units"])
        B_mb, S = tokens.shape[1], tokens.shape[2]
        positions = jnp.arange(S, dtype=jnp.int32)[None]
        d = cfg.d_model

        n_steps = n_micro + n_stages - 1
        buf = jnp.zeros((B_mb, S, d), cfg.cdtype)
        loss_acc = jnp.zeros((), jnp.float32)

        def step(i, carry):
            buf, loss_acc = carry
            mb_in = jnp.clip(i, 0, n_micro - 1)
            x0 = _embed(cfg, params, tokens[mb_in])
            # Stage 0 ingests microbatch i (when valid); others use buf.
            x = jnp.where(stage_id == 0, x0.astype(buf.dtype), buf)
            y = stage_apply(my_stage, x, positions)
            # Shift stage outputs forward one rank.
            perm = [(s, s + 1) for s in range(n_stages - 1)]
            shifted = jax.lax.ppermute(y, pp_axis, perm) \
                if n_stages > 1 else y
            # Last stage emits loss for microbatch (i - (S-1)).
            mb_out = i - (n_stages - 1)
            valid = (mb_out >= 0) & (stage_id == n_stages - 1)
            lbl = labels[jnp.clip(mb_out, 0, n_micro - 1)]
            logits = _head(cfg, params, y)
            mb_loss = cross_entropy(logits, lbl)
            loss_acc = loss_acc + jnp.where(valid, mb_loss, 0.0)
            return shifted, loss_acc

        buf, loss_acc = jax.lax.fori_loop(0, n_steps, step,
                                          (buf, loss_acc))
        # Broadcast the last stage's loss to every rank.
        total = jax.lax.psum(
            jnp.where(jax.lax.axis_index(pp_axis) == n_stages - 1,
                      loss_acc, 0.0), pp_axis)
        return total / n_micro

    def pipelined(params, batch):
        tokens = batch["tokens"]          # (n_micro, B_mb, S)
        labels = jnp.concatenate(
            [tokens[:, :, 1:], jnp.full_like(tokens[:, :, :1], -1)],
            axis=2)
        fn = shard_map_compat(
            local_fn, mesh=mesh,
            in_specs=(P(), P(), P()),
            out_specs=P(),
            check_replication=False,
        )
        return fn(params, tokens, labels)

    return pipelined


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """GPipe bubble: (S-1)/(M+S-1)."""
    return (n_stages - 1) / (n_micro + n_stages - 1)
