"""Model configuration schema for the 10-architecture zoo."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional

import jax.numpy as jnp


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_expert: int = 0            # expert hidden dim (per expert)
    every: int = 1               # MoE layer every k-th layer (llama4: 2)
    capacity_factor: float = 1.25
    router_z_coef: float = 1e-3
    lb_coef: float = 1e-2


@dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536      # 0 => dense q projection
    nope_head_dim: int = 128
    rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    conv_width: int = 4
    chunk: int = 128             # SSD chunk length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 => d_model // n_heads
    mlp: str = "swiglu"          # swiglu | geglu | none
    norm: str = "rmsnorm"        # rmsnorm | layernorm | nonparam_ln
    rope_theta: float = 10_000.0
    sliding_window: Optional[int] = None
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    ssm: Optional[SSMCfg] = None
    # hybrid (zamba2): shared attention block applied every `shared_every`
    # ssm layers; 0 disables.
    shared_every: int = 0
    # enc-dec (whisper)
    encdec: bool = False
    n_dec_layers: int = 0
    dec_len: int = 448
    # vlm: number of stub image patches prepended to the text sequence
    n_patches: int = 0
    tie_embeddings: bool = True
    embed_scale: bool = False    # gemma: scale embeddings by sqrt(d)
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # attention implementation: jnp blockwise (CPU-runnable) or the
    # Pallas flash kernel (TPU Mosaic; interpret-mode on CPU tests)
    use_flash_attention: bool = False
    # training policy
    remat: str = "full"          # full | dots | none
    scan_layers: bool = True
    opt_moments_dtype: str = "float32"   # float32 | int8
    # long-context serving
    subquadratic: bool = False   # True => may run long_500k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class ShapeCell:
    """One assigned input-shape cell."""

    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPE_CELLS = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


def cell_applicable(cfg: ModelConfig, cell: str) -> tuple[bool, str]:
    """Whether a shape cell applies to an architecture (DESIGN.md §4)."""
    if cell == "long_500k" and not cfg.subquadratic:
        return False, "skip(full-attn)"
    return True, ""
