"""Mixture-of-Experts with sort-based capacity dispatch (EP over 'model').

Top-k routing -> flatten (token, expert) assignments -> stable sort by
expert -> position-within-expert -> scatter into a per-expert capacity
buffer (E, C, d) -> batched expert FFN einsum -> weighted combine.
All shapes static; capacity overflow drops tokens (counted in metrics),
the standard TPU MoE formulation.  Experts shard over the ``model`` mesh
axis; the dispatch scatter/gather lowers to an all-to-all under SPMD.

Aux losses: switch-style load-balance + router z-loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoECfg
from repro.models.layers import Builder, glu_act
from repro.models.sharding import constrain


def make_moe(b: Builder, cfg: ModelConfig, stack: int = 0):
    m: MoECfg = cfg.moe
    d, e, h = cfg.d_model, m.n_experts, m.d_expert or cfg.d_ff
    s = b.scope("moe")
    s.make("router", (d, e), ("embed", "experts"), stack=stack,
           dtype=jnp.float32)
    s.make("w_gate", (e, d, h), ("experts", "embed", "expert_mlp"),
           stack=stack)
    s.make("w_up", (e, d, h), ("experts", "embed", "expert_mlp"),
           stack=stack)
    s.make("w_down", (e, h, d), ("experts", "expert_mlp", "embed"),
           stack=stack)
    if m.n_shared:
        s.make("ws_gate", (d, m.n_shared * h), ("embed", "mlp"), stack=stack)
        s.make("ws_up", (d, m.n_shared * h), ("embed", "mlp"), stack=stack)
        s.make("ws_down", (m.n_shared * h, d), ("mlp", "embed"), stack=stack)


def moe_ffn(p, cfg: ModelConfig, x):
    """x: (B, S, d) -> (out, aux) with aux = {lb_loss, z_loss, drop_frac}."""
    m: MoECfg = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = m.n_experts, m.top_k
    C = max(1, int(m.capacity_factor * T * K / E))
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)            # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Aux losses.
    density = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    lb_loss = E * jnp.sum(density * mean_probs) * m.lb_coef
    z_loss = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2) * m.router_z_coef

    # Flatten assignments and sort by expert (stable: ties keep token order).
    flat_e = expert_ids.reshape(-1)                            # (T*K,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    flat_g = gate_vals.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    idx = jnp.arange(T * K, dtype=jnp.int32)
    heads = jnp.concatenate([jnp.array([True]), se[1:] != se[:-1]])
    seg_start = jax.lax.cummax(jnp.where(heads, idx, 0), axis=0)
    pos = idx - seg_start
    keep = pos < C
    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))

    slot = jnp.where(keep, se * C + pos, E * C)                # OOB drop
    buf = jnp.zeros((E * C, d), x.dtype).at[slot].set(
        xt[st], mode="drop").reshape(E, C, d)
    buf = constrain(buf, "experts", None, None)

    h = glu_act(
        cfg.mlp if cfg.mlp != "none" else "swiglu",
        jnp.einsum("ecd,edh->ech", buf, p["w_gate"]),
        jnp.einsum("ecd,edh->ech", buf, p["w_up"]),
    )
    h = constrain(h, "experts", None, "act_mlp")
    out_buf = jnp.einsum("ech,ehd->ecd", h, p["w_down"]).reshape(E * C, d)

    gathered = out_buf.at[slot].get(mode="fill", fill_value=0)  # (T*K, d)
    contrib = gathered * jnp.where(keep, sg, 0.0)[:, None].astype(x.dtype)
    out = jnp.zeros((T, d), x.dtype).at[st].add(contrib)

    if m.n_shared:
        shared = glu_act(
            cfg.mlp if cfg.mlp != "none" else "swiglu",
            xt @ p["ws_gate"], xt @ p["ws_up"]) @ p["ws_down"]
        out = out + shared
    aux = {"lb_loss": lb_loss, "z_loss": z_loss, "drop_frac": drop_frac}
    return out.reshape(B, S, d), aux
