"""Logical-axis sharding: named weight/activation axes -> mesh axes.

Every parameter leaf is created together with a tuple of logical axis
names (see ``init.py``).  ``logical_to_mesh`` resolves those names through
a rules table into ``PartitionSpec``s for the target mesh.  This is the
MaxText-style scheme: change the rules, not the model code, to change the
parallelism layout.

Default rules implement:
  * FSDP/ZeRO-3 over the ``data`` axis (weights' embed/vocab dims),
  * tensor parallelism over ``model`` (heads / mlp / experts / vocab),
  * DP over (``pod`` × ``data``) for activation batch,
  * expert parallelism over ``model``.
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (or None = replicated, or tuple of mesh axes)
DEFAULT_RULES: dict[str, Any] = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "seq_shard": "data",        # sequence-parallel (long-context decode)
    "act_embed": None,
    "act_heads": "model",
    "act_mlp": "model",
    # weights
    "embed": "data",            # FSDP shard of the contraction dim
    "heads": "model",
    "qkv": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "vocab": "model",
    "kv_lora": None,
    "ssm_inner": "model",
    "ssm_state": None,
    "conv": None,
    "layers": None,             # stacked-scan layer axis: never sharded
    # flattened 1D state (e.g. int8 optimizer-moment blocks): shard over
    # every mesh axis — elementwise math, any even split is valid.
    "flat_shard": ("pod", "data", "model"),
    None: None,
}


# long_500k (global_batch=1): batch replicates, the KV-cache sequence axis
# takes the data dimension instead (sequence-parallel decode).
LONG_CONTEXT_RULES = dict(DEFAULT_RULES)
LONG_CONTEXT_RULES["batch"] = None
LONG_CONTEXT_RULES["seq_shard"] = "data"

# DP-heavy layout: batch over EVERY mesh axis (pure DP + per-layer FSDP
# weight gathers), no tensor parallelism except expert parallelism.
# Measured motivation (EXPERIMENTS.md §Perf): at TP=16 the per-layer
# row-parallel activation all-reduces dominate small/dense models
# (e.g. gemma-7b train_4k: 369 GB/step/dev), and GQA models whose
# n_kv_heads < TP degree (llama4: kv=8 < 16) hit GSPMD involuntary
# replication.  DP-heavy trades those for weight all-gathers
# (params x ~3 passes), a win whenever batch divides the device count.
DP_HEAVY_RULES = dict(DEFAULT_RULES)
DP_HEAVY_RULES.update({
    "batch": ("pod", "data", "model"),
    "act_heads": None,
    "act_mlp": None,
    "heads": None,
    "mlp": None,
    "vocab": ("data", "model"),
    "embed": ("data", "model"),
    "ssm_inner": None,
    # experts stay on "model" (EP); expert d_ff/d_model dims get FSDP
    "expert_mlp": "data",
})

RULES_PRESETS = {
    "tp": DEFAULT_RULES,
    "dp": DP_HEAVY_RULES,
    "long": LONG_CONTEXT_RULES,
}


def resolve_axis(rules: dict, name, mesh: Mesh):
    mesh_axes = rules.get(name, None)
    if mesh_axes is None:
        return None
    if isinstance(mesh_axes, str):
        return mesh_axes if mesh_axes in mesh.axis_names else None
    found = tuple(a for a in mesh_axes if a in mesh.axis_names)
    return found if found else None


def spec_for(axes: tuple, mesh: Mesh, rules: dict | None = None) -> P:
    rules = rules or DEFAULT_RULES
    parts = [resolve_axis(rules, a, mesh) for a in axes]
    # PartitionSpec cannot repeat a mesh axis; keep first occurrence.
    used: set = set()
    clean = []
    for p in parts:
        items = p if isinstance(p, tuple) else (p,) if p else ()
        keep = tuple(a for a in items if a not in used)
        used.update(keep)
        if not keep:
            clean.append(None)
        elif len(keep) == 1:
            clean.append(keep[0])
        else:
            clean.append(keep)
    return P(*clean)


def tree_specs(axes_tree, mesh: Mesh, rules: dict | None = None):
    """Map a pytree of logical-axis tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda axes: spec_for(axes, mesh, rules),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple)
        and all(isinstance(a, (str, type(None))) for a in x),
    )


def shardings_for(axes_tree, sds_tree, mesh: Mesh,
                  rules: dict | None = None):
    """NamedShardings with per-leaf divisibility pruning.

    A dim whose size does not divide its assigned mesh axes drops axes
    from the right until it does (jit in_shardings requires exact
    divisibility; e.g. a 20-block quantizer scale cannot shard 256-way).
    """
    specs = tree_specs(axes_tree, mesh, rules)

    def fix(sd, spec):
        parts = list(spec) + [None] * (len(sd.shape) - len(spec))
        out = []
        for size, part in zip(sd.shape, parts):
            axes = (part,) if isinstance(part, str) else (
                tuple(part) if part else ())
            while axes:
                n = 1
                for a in axes:
                    n *= mesh.shape[a]
                if size % n == 0:
                    break
                axes = axes[:-1]
            out.append(axes[0] if len(axes) == 1 else
                       (tuple(axes) if axes else None))
        return NamedSharding(mesh, P(*out))

    return jax.tree.map(
        fix, sds_tree,
        jax.tree.map(lambda s: s, specs,
                     is_leaf=lambda x: isinstance(x, P)),
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "dtype"))


def tree_shardings(axes_tree, mesh: Mesh, rules: dict | None = None):
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        tree_specs(axes_tree, mesh, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


# Active (mesh, rules) for logical constraints.  Set by the step builders
# around trace time (``with activate(mesh, rules): fn.lower(...)``); model
# code calls ``constrain`` with logical names only.  Without an active
# mesh, constrain is a no-op (single-device tests).
import contextlib
import threading

_ACTIVE = threading.local()


@contextlib.contextmanager
def activate(mesh: Mesh, rules: dict | None = None):
    prev = getattr(_ACTIVE, "ctx", None)
    _ACTIVE.ctx = (mesh, rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _ACTIVE.ctx = prev


def constrain(x, *axes, rules: dict | None = None):
    """with_sharding_constraint by logical axis names (no-op off-mesh)."""
    ctx = getattr(_ACTIVE, "ctx", None)
    if ctx is None:
        return x
    mesh, active_rules = ctx
    spec = spec_for(tuple(axes), mesh, rules or active_rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
