"""Attention: blockwise (flash-style) training/prefill path + decode path.

The blockwise path scans over KV blocks with an online softmax so the
(Sq x Skv) score matrix never materializes — mandatory for the 32k prefill
cells (a dense 32k x 32k score tensor would be ~PB-scale at batch 32).
Supports GQA/MQA (n_kv_heads <= n_heads), causal masking, and sliding
windows (h2o-danube).  Pure jnp + lax.scan: XLA fuses each block's matmul
chain; remat recomputes blocks in the backward pass.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.sharding import constrain


def _mask_bias(q_pos, k_pos, *, causal, window, kv_len=None):
    """(…, Sq, Tkv) additive bias from position masks."""
    m = k_pos[None, :] <= q_pos[:, None] if causal else (
        jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool))
    if window is not None:
        m = m & (k_pos[None, :] > q_pos[:, None] - window)
    if kv_len is not None:
        m = m & (k_pos[None, :] < kv_len)
    return jnp.where(m, 0.0, -jnp.inf).astype(jnp.float32)


def blockwise_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset=0,
    block_kv: int = 512,
    scale: float | None = None,
) -> jnp.ndarray:
    """q: (B, Sq, H, Dh); k: (B, Skv, Hkv, Dh); v: (B, Skv, Hkv, Dv).

    Returns (B, Sq, H, Dv).  H % Hkv == 0 (GQA groups).
    """
    B, Sq, H, Dh = q.shape
    _, Skv, Hkv, Dv = v.shape
    g = H // Hkv
    scale = scale if scale is not None else Dh**-0.5
    blk = min(block_kv, Skv)
    n_blk = -(-Skv // blk)
    pad = n_blk * blk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = q.reshape(B, Sq, Hkv, g, Dh)
    q_pos = q_offset + jnp.arange(Sq, dtype=jnp.int32)
    kb = k.reshape(B, n_blk, blk, Hkv, Dh)
    vb = v.reshape(B, n_blk, blk, Hkv, Dv)

    def step(carry, inputs):
        m_prev, l_prev, acc = carry
        kblk, vblk, bi = inputs                  # (B, blk, Hkv, Dh)
        k_pos = bi * blk + jnp.arange(blk, dtype=jnp.int32)
        s = jnp.einsum(
            "bqhgd,bkhd->bhgqk", qg, kblk,
            preferred_element_type=jnp.float32) * scale
        bias = _mask_bias(q_pos, k_pos, causal=causal, window=window,
                          kv_len=jnp.int32(Skv - 0) if pad else None)
        if pad:
            bias = jnp.where(k_pos[None, :] < Skv, bias, -jnp.inf)
        s = s + bias[None, None, None]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # Guard fully-masked rows (m == -inf).
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev),
                         jnp.exp(m_prev - m_safe), 0.0)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, g, Sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, g, Sq, Dv), jnp.float32)
    kbs = jnp.moveaxis(kb, 1, 0)
    vbs = jnp.moveaxis(vb, 1, 0)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0),
        (kbs, vbs, jnp.arange(n_blk, dtype=jnp.int32)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    kv_len,
    *,
    window: int | None = None,
    scale: float | None = None,
    seq_shard: bool = False,
) -> jnp.ndarray:
    """Single-token decode. q: (B, H, Dh); caches: (B, S, Hkv, D*).

    ``kv_len``: (B,) or scalar — number of valid cache positions; the new
    token attends to positions < kv_len.  ``seq_shard`` marks the cache as
    sequence-sharded over the 'data' mesh axis (long_500k): the softmax
    reduction over S then spans shards and the SPMD partitioner emits the
    distributed max/sum (log-sum-exp merge).
    """
    B, S, Hkv, Dh = k_cache.shape
    H = q.shape[1]
    g = H // Hkv
    Dv = v_cache.shape[-1]
    scale = scale if scale is not None else Dh**-0.5
    if seq_shard:
        k_cache = constrain(k_cache, "batch", "seq_shard", None, None)
        v_cache = constrain(v_cache, "batch", "seq_shard", None, None)
    qg = q.reshape(B, Hkv, g, Dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(S, dtype=jnp.int32)
    kv_len = jnp.asarray(kv_len, jnp.int32)
    valid = pos[None, :] < kv_len.reshape(-1, 1)
    if window is not None:
        valid = valid & (pos[None, :] >= kv_len.reshape(-1, 1) - window)
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, H, Dv).astype(q.dtype)
