"""Expert-parallel MoE dispatch via shard_map + all_to_all (the EP path).

Why this exists: the global sort-based dispatch in ``moe.py`` is correct
single-device but does NOT partition — GSPMD resolves its cross-shard
gathers by materializing (T*K, d) tensors with all-reduces (measured:
~16 TB/step/device collective traffic on deepseek-v2 train_4k).  The
production dispatch is explicit:

  per device (tokens sharded over pod x data, experts over model):
    1. local top-k routing,
    2. bucket tokens by OWNING EXPERT SHARD -> all_to_all over 'model',
    3. local second-stage dispatch (sort by local expert, capacity C),
    4. batched expert FFN einsum,
    5. reverse all_to_all, weighted combine at the source slots.

Token overflow at either stage is dropped-and-counted (standard capacity
semantics).  Differentiable end-to-end (all_to_all / take / scatter-add
have transposes); validated against the global path in tests.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.jaxcompat import shard_map_compat

from repro.models.config import ModelConfig, MoECfg
from repro.models.layers import glu_act
from repro.models import sharding as shlib


def _segment_positions(sorted_keys):
    """Position of each element within its equal-key run."""
    n = sorted_keys.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    heads = jnp.concatenate(
        [jnp.array([True]), sorted_keys[1:] != sorted_keys[:-1]])
    seg_start = jax.lax.cummax(jnp.where(heads, idx, 0), axis=0)
    return idx - seg_start


def _local_moe(x, router, w_gate, w_up, w_down, *, cfg: ModelConfig,
               ep_axis: str, n_ep: int, dp_axes):
    """Per-device body.  x: (T_loc, d); experts: (E_loc, d, h)."""
    m: MoECfg = cfg.moe
    T_loc, d = x.shape
    E, K = m.n_experts, m.top_k
    E_loc = E // n_ep

    logits = x.astype(jnp.float32) @ router.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)       # (T_loc, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # Aux losses (local shard contribution; caller pmeans).
    density = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
    mean_probs = jnp.mean(probs, axis=0)
    lb = E * jnp.sum(density * mean_probs) * m.lb_coef
    zl = jnp.mean(jax.nn.logsumexp(logits, -1) ** 2) * m.router_z_coef

    flat_e = expert_ids.reshape(-1).astype(jnp.int32)     # (T_loc*K,)
    flat_t = (jnp.arange(T_loc * K, dtype=jnp.int32) // K)
    flat_g = gate_vals.reshape(-1)
    dest = flat_e // E_loc                                # owning shard

    # --- stage 1: bucket by destination shard, all_to_all ------------------
    cap_send = max(1, math.ceil(T_loc * K * m.capacity_factor / n_ep))
    order = jnp.argsort(dest * jnp.int32(E) + flat_e, stable=True)
    s_dest = dest[order]
    s_tok = flat_t[order]
    s_exp = flat_e[order]
    pos = _segment_positions(s_dest)
    ok = pos < cap_send
    slot = jnp.where(ok, s_dest * cap_send + pos, n_ep * cap_send)
    drop1 = jnp.sum(~ok)

    send_x = jnp.zeros((n_ep * cap_send, d), x.dtype).at[slot].set(
        x[s_tok], mode="drop")
    send_le = jnp.full((n_ep * cap_send,), -1, jnp.int32).at[slot].set(
        s_exp % E_loc, mode="drop")
    if n_ep > 1:
        recv_x = jax.lax.all_to_all(
            send_x.reshape(n_ep, cap_send, d), ep_axis, 0, 0)
        recv_le = jax.lax.all_to_all(
            send_le.reshape(n_ep, cap_send), ep_axis, 0, 0)
    else:
        recv_x = send_x.reshape(1, cap_send, d)
        recv_le = send_le.reshape(1, cap_send)
    R = n_ep * cap_send
    recv_x = recv_x.reshape(R, d)
    recv_le = recv_le.reshape(R)

    # --- stage 2: local dispatch by local expert id -------------------------
    C = max(1, math.ceil(R * 1.0 / E_loc))
    key = jnp.where(recv_le >= 0, recv_le, E_loc)         # invalid last
    order2 = jnp.argsort(key, stable=True)
    s_le = key[order2]
    pos2 = _segment_positions(s_le)
    ok2 = (pos2 < C) & (s_le < E_loc)
    slot2 = jnp.where(ok2, s_le * C + pos2, E_loc * C)
    drop2 = jnp.sum((~ok2) & (s_le < E_loc))

    buf = jnp.zeros((E_loc * C, d), x.dtype).at[slot2].set(
        recv_x[order2], mode="drop").reshape(E_loc, C, d)
    h = glu_act(cfg.mlp if cfg.mlp != "none" else "swiglu",
                jnp.einsum("ecd,edh->ech", buf, w_gate),
                jnp.einsum("ecd,edh->ech", buf, w_up))
    out_buf = jnp.einsum("ech,ehd->ecd", h, w_down).reshape(E_loc * C, d)

    # Return to recv slots, reverse all_to_all, combine at source.
    back = jnp.zeros((R, d), x.dtype)
    back = back.at[order2].set(
        out_buf.at[slot2].get(mode="fill", fill_value=0))
    if n_ep > 1:
        ret = jax.lax.all_to_all(
            back.reshape(n_ep, cap_send, d), ep_axis, 0, 0)
    else:
        ret = back.reshape(1, cap_send, d)
    ret = ret.reshape(n_ep * cap_send, d)

    per_assign = ret.at[slot].get(mode="fill", fill_value=0)  # sorted order
    weights = jnp.where(ok, flat_g[order], 0.0).astype(x.dtype)
    out = jnp.zeros((T_loc, d), x.dtype).at[s_tok].add(
        per_assign * weights[:, None])

    drop_frac = (drop1 + drop2).astype(jnp.float32) / (T_loc * K)
    # Mean aux across all devices.
    all_axes = tuple(dp_axes) + (ep_axis,)
    lb = jax.lax.pmean(lb, all_axes)
    zl = jax.lax.pmean(zl, all_axes)
    drop_frac = jax.lax.pmean(drop_frac, all_axes)
    return out, lb, zl, drop_frac


def moe_ffn_ep(p, cfg: ModelConfig, x):
    """Expert-parallel MoE.  x: (B, S, d).  Needs an active mesh whose
    rules map 'experts' to a mesh axis; otherwise caller should use the
    dense-global fallback."""
    ctx = getattr(shlib._ACTIVE, "ctx", None)
    assert ctx is not None
    mesh, rules = ctx
    m = cfg.moe
    ep_axis = shlib.resolve_axis(rules, "experts", mesh)
    dp_axes = shlib.resolve_axis(rules, "batch", mesh) or ()
    if isinstance(dp_axes, str):
        dp_axes = (dp_axes,)
    # DP-heavy rules put the EP axis in "batch" too — dedup it here.
    dp_axes = tuple(a for a in dp_axes if a != ep_axis)
    n_ep = mesh.shape[ep_axis] if ep_axis else 1
    assert ep_axis and m.n_experts % n_ep == 0

    B, S, d = x.shape
    xt = x.reshape(B * S, d)
    # Tokens shard over DP axes AND the EP axis for dispatch (DP x EP
    # grid) — each device routes its OWN token slice.  Without the EP
    # axis every model-column would route identical tokens: measured 16x
    # redundant expert compute on the 16x16 mesh (EXPERIMENTS.md §Perf).
    # Decode-sized batches (T < n_devices) shard over the largest prefix
    # that divides T; the residual replication is cheap at decode FLOPs.
    T = B * S
    token_axes = tuple(dp_axes) + (ep_axis,)
    while token_axes:
        n = 1
        for a in token_axes:
            n *= mesh.shape[a]
        if T % n == 0:
            break
        token_axes = token_axes[:-1]
    if not token_axes:
        from repro.models.moe import moe_ffn
        return moe_ffn(p, cfg, x)   # tiny T: dense-global fallback
    body = lambda xt_, r_, wg_, wu_, wd_: _local_moe(
        xt_, r_, wg_, wu_, wd_, cfg=cfg, ep_axis=ep_axis, n_ep=n_ep,
        dp_axes=dp_axes)
    out, lb, zl, dropf = shard_map_compat(
        body, mesh=mesh,
        in_specs=(P(token_axes, None),
                  P(), P(ep_axis), P(ep_axis), P(ep_axis)),
        out_specs=(P(token_axes, None), P(), P(), P()),
        check_replication=False,
    )(xt, p["router"], p["w_gate"], p["w_up"], p["w_down"])

    out = out.reshape(B, S, d)
    if m.n_shared:
        shared = glu_act(
            cfg.mlp if cfg.mlp != "none" else "swiglu",
            xt @ p["ws_gate"], xt @ p["ws_up"]) @ p["ws_down"]
        out = out + shared.reshape(B, S, d)
    aux = {"lb_loss": lb, "z_loss": zl, "drop_frac": dropf}
    return out, aux
