"""Transformer block assembly: GQA attention blocks, MLPs, layer dispatch.

One "unit" is the scanned entity in the layer stack; a unit contains one
or more sub-blocks (e.g. llama4 alternates dense/MoE layers -> unit of 2;
zamba2 units are `shared_every` mamba layers + one shared attention block).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.attention import blockwise_attention, decode_attention
from repro.models.config import ModelConfig
from repro.models.layers import (
    Builder, apply_norm, apply_rope, glu_act, make_norm,
)
from repro.models.mla import make_mla, mla_decode, mla_prefill
from repro.models.moe import make_moe, moe_ffn
from repro.models.sharding import constrain
from repro.models.ssm import make_ssm, ssd_decode, ssd_forward


# -- GQA attention ----------------------------------------------------------

def make_attn(b: Builder, cfg: ModelConfig, stack: int = 0):
    d, H, Hkv, dh = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                     cfg.resolved_head_dim)
    s = b.scope("attn")
    s.make("wq", (d, H, dh), ("embed", "heads", "qkv"), stack=stack)
    s.make("wk", (d, Hkv, dh), ("embed", "heads", "qkv"), stack=stack)
    s.make("wv", (d, Hkv, dh), ("embed", "heads", "qkv"), stack=stack)
    s.make("wo", (H, dh, d), ("heads", "qkv", "embed"), stack=stack)


def attn_qkv(p, cfg: ModelConfig, x, positions, rope: bool = True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "act_heads", None)
    k = constrain(k, "batch", "seq", "act_heads", None)
    return q, k, v


def attn_fwd(p, cfg: ModelConfig, x, positions, *, causal=True,
             window=None, block_kv=512, rope=True, kv=None):
    """Full-sequence attention (train / prefill / encoder).

    Returns (out, (k, v)) — k/v returned for cache construction.
    ``kv``: externally supplied (k, v) for cross-attention.
    """
    if kv is None:
        q, k, v = attn_qkv(p, cfg, x, positions, rope)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
        if rope:
            q = apply_rope(q, positions, cfg.rope_theta)
        k, v = kv
    if cfg.use_flash_attention:
        from repro.kernels.flash_attention import flash_attention

        out = flash_attention(q, k, v, causal=causal, window=window)
    else:
        out = blockwise_attention(q, k, v, causal=causal, window=window,
                                  block_kv=block_kv)
    out = constrain(out, "batch", "seq", "act_heads", None)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, (k, v)


def attn_decode(p, cfg: ModelConfig, x, cache, kv_len, *, window=None,
                rope=True, seq_shard=False, ring=False, cross=False):
    """Single-token decode with cache update.

    cache: {"k": (B,S,Hkv,dh), "v": ..., optional "pos": (B,S)}.
    ``ring``: sliding-window ring buffer (slot = pos % S).
    ``cross``: cross-attention — cache is static, no update.
    """
    B = x.shape[0]
    pos = jnp.asarray(kv_len, jnp.int32).reshape(-1)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])     # (B, 1, H, dh)
    if rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
    q = q[:, 0]
    k_cache, v_cache = cache["k"], cache["v"]
    if not cross:
        k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        if rope:
            k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)
        S = k_cache.shape[1]
        slot = jnp.where(jnp.bool_(ring), pos % S, jnp.minimum(pos, S - 1))
        bidx = jnp.arange(B)
        k_cache = k_cache.at[bidx, slot].set(
            k_new[:, 0].astype(k_cache.dtype))
        v_cache = v_cache.at[bidx, slot].set(
            v_new[:, 0].astype(v_cache.dtype))
        cache = dict(cache, k=k_cache, v=v_cache)
        if "pos" in cache:
            cache["pos"] = cache["pos"].at[bidx, slot].set(pos)
    if "pos" in cache:
        pos_ids = cache["pos"]
        valid = (pos_ids >= 0) & (pos_ids <= pos[:, None])
        if window is not None:
            valid = valid & (pos_ids > pos[:, None] - window)
        out = _decode_masked(q, k_cache, v_cache, valid)
    else:
        out = decode_attention(q, k_cache, v_cache,
                               pos + (0 if cross else 1),
                               window=window, seq_shard=seq_shard)
    out = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
    return out, cache


def _decode_masked(q, k_cache, v_cache, valid):
    B, S, Hkv, Dh = k_cache.shape
    H = q.shape[1]
    g = H // Hkv
    qg = q.reshape(B, Hkv, g, Dh)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * Dh**-0.5
    s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", w.astype(v_cache.dtype), v_cache)
    return out.reshape(B, H, v_cache.shape[-1]).astype(q.dtype)


# -- dense MLP ----------------------------------------------------------------

def make_mlp(b: Builder, cfg: ModelConfig, stack: int = 0):
    d, ff = cfg.d_model, cfg.d_ff
    s = b.scope("mlp")
    if cfg.mlp != "gelu":
        s.make("w_gate", (d, ff), ("embed", "mlp"), stack=stack)
    s.make("w_up", (d, ff), ("embed", "mlp"), stack=stack)
    s.make("w_down", (ff, d), ("mlp", "embed"), stack=stack)


def mlp_fwd(p, cfg: ModelConfig, x):
    if cfg.mlp == "gelu":
        h = jax.nn.gelu(x @ p["w_up"], approximate=True)
    else:
        h = glu_act(cfg.mlp, x @ p["w_gate"], x @ p["w_up"])
    h = constrain(h, "batch", "seq", "act_mlp")
    return h @ p["w_down"]


# -- layer builders -----------------------------------------------------------

def make_decoder_layer(b: Builder, cfg: ModelConfig, *, moe_layer: bool,
                       stack: int = 0):
    make_norm(b, "ln_attn", cfg.norm, cfg.d_model, stack=stack)
    make_norm(b, "ln_mlp", cfg.norm, cfg.d_model, stack=stack)
    if cfg.mla is not None:
        make_mla(b, cfg, stack=stack)
    else:
        make_attn(b, cfg, stack=stack)
    if moe_layer:
        make_moe(b, cfg, stack=stack)
    else:
        make_mlp(b, cfg, stack=stack)


def make_ssm_layer(b: Builder, cfg: ModelConfig, stack: int = 0):
    make_norm(b, "ln_ssm", cfg.norm, cfg.d_model, stack=stack)
    make_ssm(b, cfg, stack=stack)


ZERO_AUX = {"lb_loss": jnp.float32(0), "z_loss": jnp.float32(0),
            "drop_frac": jnp.float32(0)}


def decoder_layer_fwd(p, cfg: ModelConfig, x, positions, *,
                      moe_layer: bool, mode: str, cache=None, kv_len=None,
                      window=None, seq_shard=False, ring=False):
    """One attention+ffn layer.  Returns (x, cache, aux)."""
    h = apply_norm(cfg.norm, x, p.get("ln_attn"))
    if cfg.mla is not None:
        if mode == "decode":
            a, new_cache = mla_decode(p["mla"], cfg, h, cache, kv_len)
        else:
            a, kvc = mla_prefill(p["mla"], cfg, h, positions)
            new_cache = kvc if mode == "prefill" else None
    else:
        if mode == "decode":
            a, new_cache = attn_decode(
                p["attn"], cfg, h, cache, kv_len, window=window,
                seq_shard=seq_shard, ring=ring)
        else:
            a, (k, v) = attn_fwd(p["attn"], cfg, h, positions,
                                 window=window)
            new_cache = {"k": k, "v": v} if mode == "prefill" else None
    x = x + a
    h = apply_norm(cfg.norm, x, p.get("ln_mlp"))
    if moe_layer:
        f, aux = _moe_dispatch(p["moe"], cfg, h)
    else:
        f, aux = mlp_fwd(p["mlp"], cfg, h), ZERO_AUX
    return x + f, new_cache, aux


def _moe_dispatch(p, cfg: ModelConfig, h):
    """Route to the expert-parallel shard_map path when a mesh is active
    and experts divide the EP axis; else the dense-global fallback."""
    from repro.models import sharding as shlib
    from repro.models.moe_sharded import moe_ffn_ep

    ctx = getattr(shlib._ACTIVE, "ctx", None)
    if ctx is not None:
        mesh, rules = ctx
        ep_axis = shlib.resolve_axis(rules, "experts", mesh)
        if ep_axis and cfg.moe.n_experts % mesh.shape[ep_axis] == 0:
            return moe_ffn_ep(p, cfg, h)
    return moe_ffn(p, cfg, h)


def ssm_layer_fwd(p, cfg: ModelConfig, x, *, mode: str, cache=None):
    h = apply_norm(cfg.norm, x, p.get("ln_ssm"))
    if mode == "decode":
        o, new_cache = ssd_decode(p["ssm"], cfg, h, cache)
    else:
        o, c = ssd_forward(p["ssm"], cfg, h)
        new_cache = c if mode == "prefill" else None
    return x + o, new_cache, ZERO_AUX
