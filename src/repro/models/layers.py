"""Shared model building blocks: param builder, norms, RoPE, losses."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Parameter builder: creates arrays and records logical sharding axes
# ---------------------------------------------------------------------------

class Builder:
    """Creates parameters and a parallel pytree of logical-axis tuples.

    Usable under ``jax.eval_shape`` (pure jnp inits) so the dry-run can
    build ShapeDtypeStruct param trees without allocating.
    """

    def __init__(self, key, dtype, path: str = "", abstract: bool = False):
        self.key = key
        self.dtype = dtype
        self.path = path
        self.abstract = abstract   # ShapeDtypeStructs only, no allocation
        self.params: dict[str, Any] = {}
        self.axes: dict[str, Any] = {}

    def scope(self, name: str) -> "Builder":
        sub = Builder(self.key, self.dtype, f"{self.path}/{name}",
                      self.abstract)
        self.params[name] = sub.params
        self.axes[name] = sub.axes
        return sub

    def make(
        self,
        name: str,
        shape: tuple[int, ...],
        axes: tuple,
        init: str = "normal",
        stack: int = 0,
        fan_in: int | None = None,
        dtype=None,
    ):
        full_shape = (stack,) + tuple(shape) if stack else tuple(shape)
        full_axes = (("layers",) + tuple(axes)) if stack else tuple(axes)
        assert len(full_shape) == len(full_axes), (name, full_shape, full_axes)
        dtype = dtype or self.dtype
        if self.abstract:
            arr = jax.ShapeDtypeStruct(full_shape, dtype)
            self.params[name] = arr
            self.axes[name] = full_axes
            return arr
        key = jax.random.fold_in(
            self.key, hash(f"{self.path}/{name}") & 0x7FFFFFFF
        )
        if init == "zeros":
            arr = jnp.zeros(full_shape, dtype)
        elif init == "ones":
            arr = jnp.ones(full_shape, dtype)
        elif init == "normal":
            fi = fan_in if fan_in is not None else (
                shape[-2] if len(shape) >= 2 else shape[-1]
            )
            std = 1.0 / math.sqrt(max(1, fi))
            arr = (jax.random.normal(key, full_shape, jnp.float32) * std
                   ).astype(dtype)
        else:
            raise ValueError(init)
        self.params[name] = arr
        self.axes[name] = full_axes
        return arr


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, weight=None, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if weight is not None:
        x = x * (1.0 + weight.astype(jnp.float32))
    return x.astype(dt)


def layernorm(x, weight=None, bias=None, eps: float = 1e-5):
    """LayerNorm; with weight=bias=None this is OLMo's non-parametric LN."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    if weight is not None:
        x = x * weight.astype(jnp.float32)
    if bias is not None:
        x = x + bias.astype(jnp.float32)
    return x.astype(dt)


def apply_norm(kind: str, x, params: dict | None):
    if kind == "rmsnorm":
        return rmsnorm(x, params["scale"] if params else None)
    if kind == "layernorm":
        return layernorm(
            x,
            params["scale"] if params else None,
            params.get("bias") if params else None,
        )
    if kind == "nonparam_ln":
        return layernorm(x, None, None)
    raise ValueError(kind)


def make_norm(b: Builder, name: str, kind: str, d: int, stack: int = 0):
    if kind == "nonparam_ln":
        return
    s = b.scope(name)
    if kind == "rmsnorm":
        s.make("scale", (d,), ("act_embed",), init="zeros", stack=stack)
    elif kind == "layernorm":
        s.make("scale", (d,), ("act_embed",), init="ones", stack=stack)
        s.make("bias", (d,), ("act_embed",), init="zeros", stack=stack)


def norm_params(params: dict, name: str):
    return params.get(name)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, Dh) or (..., S, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    if x.ndim == ang.ndim + 1:                          # heads axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                          axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int):
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10_000.0, dim / d)
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang))
    pe = pe.at[:, 1::2].set(jnp.cos(ang[:, : (d + 1) // 2]))
    return pe


# ---------------------------------------------------------------------------
# Activations / loss
# ---------------------------------------------------------------------------

def glu_act(kind: str, gate, up):
    if kind == "swiglu":
        return jax.nn.silu(gate) * up
    if kind == "geglu":
        return jax.nn.gelu(gate, approximate=True) * up
    raise ValueError(kind)


def cross_entropy(logits, labels, mask=None, z_coef: float = 0.0):
    """Softmax CE in fp32 with optional z-loss; labels < 0 are ignored.

    The label logit is extracted with a masked sum over the vocab axis
    (NOT take_along_axis): a gather over a tensor-parallel vocab dim does
    not partition and forces an all-gather of the full fp32 logits
    (measured: 429 GB/step on deepseek-v2 train_4k; EXPERIMENTS.md §Perf).
    The masked sum partitions as elementwise + local reduce + small psum.
    """
    logits = logits.astype(jnp.float32)
    valid = labels >= 0 if mask is None else mask & (labels >= 0)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                   logits.ndim - 1)
    ll = jnp.sum(jnp.where(col == safe[..., None], logits, 0.0), axis=-1)
    nll = lse - ll
    if z_coef:
        nll = nll + z_coef * lse**2
    nll = jnp.where(valid, nll, 0.0)
    denom = jnp.maximum(jnp.sum(valid), 1)
    return jnp.sum(nll) / denom
