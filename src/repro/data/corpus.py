"""Synthetic clinical-note corpus generator (mirrors the paper's data).

The paper's test sets (§9.1, §10) are i2b2/UTHealth notes plus synthetic
near-duplicates made by randomly changing 0-20% of a note's words.  We
can't ship i2b2 (restricted), so ``make_i2b2_like`` generates
clinical-note-shaped documents from templated sections (the pervasive
templates are exactly WHY clinical corpora are duplicate-heavy, paper §1)
and ``inject_near_duplicates`` reproduces the paper's perturbation
protocol exactly.
"""
from __future__ import annotations


import numpy as np

_SECTIONS = [
    "CHIEF COMPLAINT : {complaint} .",
    "HISTORY OF PRESENT ILLNESS : The patient is a {age} year old "
    "{sex} presenting with {complaint} for the past {num} days . "
    "Symptoms include {sym1} and {sym2} . Denies {sym3} .",
    "PAST MEDICAL HISTORY : {pmh1} , {pmh2} , status post {procedure} "
    "in {year} .",
    "MEDICATIONS : {med1} {dose1} mg daily , {med2} {dose2} mg twice "
    "daily , {med3} as needed .",
    "ALLERGIES : {allergy} .",
    "PHYSICAL EXAM : Vital signs temperature {temp} pulse {pulse} "
    "blood pressure {bp1} over {bp2} . {exam} .",
    "ASSESSMENT AND PLAN : {assessment} . Will start {med1} and follow "
    "up in {num} weeks . Patient counseled on {counsel} .",
    "LABS : sodium {lab1} potassium {lab2} creatinine {lab3} glucose "
    "{lab4} white count {lab5} .",
]

_VOCAB = {
    "complaint": ["chest pain", "shortness of breath", "abdominal pain",
                  "headache", "dizziness", "fatigue", "back pain",
                  "palpitations", "fever", "cough"],
    "sex": ["male", "female"],
    "sym1": ["nausea", "vomiting", "diaphoresis", "chills", "weakness"],
    "sym2": ["radiation to the left arm", "photophobia", "orthopnea",
             "dysuria", "myalgias"],
    "sym3": ["fever", "chills", "weight loss", "night sweats", "syncope"],
    "pmh1": ["hypertension", "diabetes mellitus type 2", "asthma",
             "atrial fibrillation", "hyperlipidemia"],
    "pmh2": ["chronic kidney disease", "coronary artery disease",
             "obstructive sleep apnea", "hypothyroidism", "anemia"],
    "procedure": ["appendectomy", "cholecystectomy", "cabg",
                  "total knee replacement", "hernia repair"],
    "med1": ["lisinopril", "metformin", "atorvastatin", "amlodipine",
             "metoprolol"],
    "med2": ["aspirin", "omeprazole", "levothyroxine", "gabapentin",
             "furosemide"],
    "med3": ["acetaminophen", "ibuprofen", "ondansetron", "albuterol"],
    "allergy": ["no known drug allergies", "penicillin", "sulfa drugs",
                "codeine", "latex"],
    "exam": ["lungs clear to auscultation bilaterally",
             "regular rate and rhythm no murmurs",
             "abdomen soft nontender nondistended",
             "no lower extremity edema",
             "alert and oriented times three"],
    "assessment": ["acute coronary syndrome ruled out",
                   "community acquired pneumonia",
                   "urinary tract infection",
                   "exacerbation of chronic condition",
                   "dehydration with electrolyte abnormalities"],
    "counsel": ["medication compliance", "smoking cessation",
                "dietary modification", "warning signs requiring return"],
}


def make_i2b2_like(n_notes: int = 521, seed: int = 0) -> list[str]:
    """Clinical-note-shaped documents, a few hundred words each (paper §7.1)."""
    rng = np.random.RandomState(seed)
    notes = []
    for _ in range(n_notes):
        parts = []
        for sec in _SECTIONS:
            fills = {k: rng.choice(v) for k, v in _VOCAB.items()}
            fills.update(
                age=rng.randint(18, 95), num=rng.randint(1, 14),
                year=rng.randint(1990, 2016), dose1=rng.choice([5, 10, 20, 40]),
                dose2=rng.choice([25, 50, 100]), temp=rng.randint(97, 103),
                pulse=rng.randint(55, 120), bp1=rng.randint(95, 180),
                bp2=rng.randint(55, 110), lab1=rng.randint(130, 148),
                lab2=round(rng.uniform(3.2, 5.4), 1),
                lab3=round(rng.uniform(0.6, 3.0), 1),
                lab4=rng.randint(70, 260), lab5=round(rng.uniform(4, 15), 1),
            )
            parts.append(sec.format(**fills))
            # Repeat some sections to pad to a few hundred words.
        note = " ".join(parts)
        # Duplicate the HPI/plan with tiny edits (template copy-paste).
        notes.append(note + " " + parts[1] + " " + parts[-2])
    return notes


def perturb(text: str, frac: float, rng) -> str:
    """Randomly change ``frac`` of the words (paper §9.1/§10 protocol)."""
    words = text.split()
    n = int(len(words) * frac)
    if n:
        idx = rng.choice(len(words), size=n, replace=False)
        pool = [w for v in _VOCAB.values() for w in v]
        for i in idx:
            words[i] = rng.choice(pool).split()[0]
    return " ".join(words)


def inject_near_duplicates(
    notes: list[str], n_dups: int, *, frac_low=0.0, frac_high=0.2,
    seed: int = 1,
) -> tuple[list[str], list[tuple[int, int, float]]]:
    """Paper §10: pick random notes, change 0-20%% of words, append.

    Returns (augmented notes, provenance [(dup_idx, src_idx, frac)]).
    """
    rng = np.random.RandomState(seed)
    out = list(notes)
    prov = []
    for _ in range(n_dups):
        src = rng.randint(len(notes))
        frac = rng.uniform(frac_low, frac_high)
        out.append(perturb(notes[src], frac, rng))
        prov.append((len(out) - 1, src, frac))
    return out, prov


def accuracy_testset(seed: int = 0):
    """Paper §9.1: 521 notes + 10 near-duplicates (10% words changed)."""
    notes = make_i2b2_like(521, seed=seed)
    rng = np.random.RandomState(seed + 1)
    srcs = rng.choice(len(notes), size=10, replace=False)
    dups = [perturb(notes[s], 0.10, rng) for s in srcs]
    return notes + dups, list(srcs)


def clustering_testset(seed: int = 0):
    """Paper §10: same base + 500 near-duplicates at 0-20%."""
    notes = make_i2b2_like(521, seed=seed)
    return inject_near_duplicates(notes, 500, seed=seed + 1)
