"""Dedup-integrated LM data pipeline.

texts -> DedupPipeline (keep representatives) -> hash-tokenize -> one flat
token stream -> step-indexed batches.  ``batch_at(step)`` is a pure
function of step, which makes the FT loop resumable by construction.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.pipeline import DedupConfig, DedupPipeline


def hash_tokenize(text: str, vocab_size: int, seed: int = 17) -> np.ndarray:
    """Word-hash tokenizer (no vocab file; stable across runs)."""
    ids = []
    for w in text.lower().split():
        h = 2166136261
        for ch in w.encode():
            h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
        h = (h * 0x9E3779B9 + seed) & 0xFFFFFFFF
        ids.append(h % (vocab_size - 2) + 2)   # 0=pad, 1=eos
    return np.array(ids, dtype=np.int32)


@dataclass
class CleanDataset:
    tokens: np.ndarray            # flat stream, eos-separated
    num_docs_in: int
    num_docs_kept: int
    dedup_stats: dict

    def batch_at(self, step: int, batch: int, seq: int) -> dict:
        need = batch * (seq + 1)
        start = (step * need) % max(1, len(self.tokens) - need - 1)
        window = self.tokens[start : start + need]
        window = window.reshape(batch, seq + 1)
        return {"tokens": window[:, :-1].copy()}


def build_clean_dataset(
    texts: list[str], vocab_size: int,
    dedup_cfg: DedupConfig | None = None,
) -> CleanDataset:
    pipe = DedupPipeline(dedup_cfg or DedupConfig())
    res = pipe.run(texts)
    kept = [t for t, k in zip(texts, res.keep_mask) if k]
    streams = []
    for t in kept:
        streams.append(hash_tokenize(t, vocab_size))
        streams.append(np.array([1], dtype=np.int32))   # eos
    tokens = (np.concatenate(streams) if streams
              else np.zeros((0,), np.int32))
    return CleanDataset(
        tokens=tokens,
        num_docs_in=len(texts),
        num_docs_kept=len(kept),
        dedup_stats={
            "pairs_evaluated": res.stats.pairs_evaluated,
            "pairs_excluded": int(res.stats.pairs_excluded),
            "duplicates_removed": res.num_duplicates_removed,
        },
    )


def synthetic_batch_fn(vocab_size: int, batch: int, seq: int,
                       seed: int = 0):
    """Pure random-batch function (for tests without a corpus)."""
    def fn(step: int) -> dict:
        rng = np.random.RandomState((seed * 1_000_003 + step) & 0x7FFFFFFF)
        return {"tokens": rng.randint(
            2, vocab_size, size=(batch, seq)).astype(np.int32)}
    return fn
