from repro.data.corpus import (
    accuracy_testset, clustering_testset, inject_near_duplicates,
    make_i2b2_like, perturb,
)
from repro.data.loader import (
    CleanDataset, build_clean_dataset, hash_tokenize, synthetic_batch_fn,
)

__all__ = [
    "make_i2b2_like", "perturb", "inject_near_duplicates",
    "accuracy_testset", "clustering_testset", "CleanDataset",
    "build_clean_dataset", "hash_tokenize", "synthetic_batch_fn",
]
