"""End-to-end training driver (CPU-runnable at reduced scale).

Pipeline: synthetic clinical corpus -> MinHash-LSH dedup (the paper) ->
hash-tokenize -> fault-tolerant train loop with checkpoints.

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 50
"""
from __future__ import annotations

import argparse
import os

import jax

from repro import optim
from repro.configs import get_config, get_reduced, paper_dedup_config
from repro.data import build_clean_dataset, make_i2b2_like, \
    inject_near_duplicates, synthetic_batch_fn
from repro.runtime import FTLoop, FTLoopConfig
from repro.training.step import TrainConfig, init_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs a real pod)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--no-dedup", action="store_true")
    ap.add_argument("--corpus-notes", type=int, default=400)
    ap.add_argument("--corpus-dups", type=int, default=200)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    if cfg.encdec:
        raise SystemExit("use examples/whisper_train.py for enc-dec")
    tcfg = TrainConfig(
        adamw=optim.AdamWConfig(lr=args.lr,
                                moments_dtype=cfg.opt_moments_dtype),
        warmup_steps=max(1, args.steps // 10), total_steps=args.steps)

    if args.no_dedup:
        batch_fn = synthetic_batch_fn(cfg.vocab_size, args.batch, args.seq)
        print("data: synthetic random tokens")
    else:
        notes = make_i2b2_like(args.corpus_notes)
        notes, _ = inject_near_duplicates(notes, args.corpus_dups)
        ds = build_clean_dataset(notes, cfg.vocab_size,
                                 paper_dedup_config())
        print(f"data: {ds.num_docs_in} notes -> {ds.num_docs_kept} kept "
              f"({ds.dedup_stats})")

        def batch_fn(step: int):
            b = ds.batch_at(step, args.batch, args.seq)
            if cfg.n_patches:
                import numpy as np
                b["patches"] = np.zeros(
                    (args.batch, cfg.n_patches, cfg.d_model), "float32")
            return b

    state, _ = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    loop = FTLoop(
        config=FTLoopConfig(ckpt_dir=os.path.join(args.ckpt_dir, cfg.name),
                            ckpt_every=args.ckpt_every),
        train_step=step_fn, batch_fn=batch_fn)
    state, history = loop.run(state, args.steps, log_every=10)
    print(f"final loss: {history[-1]['loss']:.4f} "
          f"(first {history[0]['loss']:.4f}); "
          f"stragglers flagged: {loop.detector.num_flagged}")


if __name__ == "__main__":
    main()
