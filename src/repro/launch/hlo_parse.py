"""Trip-count-aware HLO analyzer (the dry-run 'profiler').

XLA's ``cost_analysis()`` visits while-loop bodies ONCE — a scan over 60
layers undercounts flops/bytes/collectives by 60x (verified in-repo).
This module re-derives the roofline inputs from the partitioned,
optimized HLO text with loop trip counts multiplied through:

  * dot FLOPs from operand shapes (per-computation symbol table) +
    contracting dims,
  * collective bytes (all-gather/all-reduce/reduce-scatter/all-to-all/
    collective-permute) from result shapes,
  * a memory-traffic proxy: sum of non-trivial op result bytes (an upper
    bound on HBM traffic — fusion lowers real traffic; see EXPERIMENTS.md).

Trip counts come from the ``backend_config={"known_trip_count":{"n":..}}``
annotation XLA attaches to canonical counted loops (jax scans), with a
condition-parse fallback.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops whose result buffers we exclude from the memory proxy (no real
# HBM write, or bookkeeping)
_NO_TRAFFIC = {"parameter", "get-tuple-element", "tuple", "bitcast",
               "constant", "iota", "copy", "while", "conditional",
               "after-all", "partition-id", "replica-id"}

# fused-ideal memory model: ops that MUST touch HBM on TPU even under
# perfect fusion.  dot counts lhs+rhs+out; the others count in+out
# (2x result).  Pure elementwise/layout ops fuse away (the CPU backend
# fuses far less than TPU, so counting every top-level result
# overestimates TPU traffic several-fold — both proxies are recorded).
_MEM_IO2 = {"scatter", "gather", "sort", "reduce", "reduce-window",
            "dynamic-update-slice", "dynamic-slice", "concatenate",
            "pad", "convolution", "select-and-scatter",
            "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
            "collective-permute"}
# fusion outputs count 1x (write only): inputs come fused from their
# producers; counting them 2x double-charges every fusion chain (the CPU
# backend emits MANY small chained fusions where TPU emits few).
_MEM_IO1 = {"fusion"}

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*"
    r"((?:\([^;]*?\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\(")

_CALL_RE = re.compile(
    r"(body|computation|condition|branch_computations|to_apply|calls)="
    r"\{?(%[\w.\-]+(?:,\s*%[\w.\-]+)*)\}?")

_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _shape_list(sig: str):
    out = []
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _bytes_of(sig: str) -> int:
    total = 0
    for dt, shape in _shape_list(sig):
        n = 1
        for d in shape:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _f32_bytes_of(sig: str, floor: int = 1 << 20) -> int:
    """f32 bytes in shapes above ``floor`` — candidates for the CPU
    bf16->f32 normalization artifact (TPU would keep these bf16)."""
    total = 0
    for dt, shape in _shape_list(sig):
        if dt != "f32":
            continue
        n = 1
        for d in shape:
            n *= d
        if n * 4 >= floor:
            total += n * 4
    return total


@dataclass
class OpInfo:
    kind: str
    result_sig: str
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_f32_bytes: float = 0.0   # f32 share (CPU bf16-upcast artifact)
    traffic_bytes: float = 0.0
    traffic_f32_bytes: float = 0.0
    mem_bytes: float = 0.0        # fused-ideal HBM traffic
    mem_f32_bytes: float = 0.0
    children: tuple = ()
    trip: int | None = None
    body_child: str | None = None


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)


def parse_module(text: str) -> tuple[dict, str | None]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    symbols: dict[str, str] = {}
    pending: list[tuple] = []

    def finish():
        nonlocal pending
        for info, line in pending:
            if info.kind == "dot":
                info.flops = _dot_flops(line, symbols)
                opnames = re.findall(r"dot\((%[\w.\-]+),\s*(%[\w.\-]+)", line)
                io = _bytes_of(info.result_sig)
                io_f32 = _f32_bytes_of(info.result_sig)
                if opnames:
                    for nm in opnames[0]:
                        sig_ = symbols.get(nm, "")
                        io += _bytes_of(sig_)
                        io_f32 += _f32_bytes_of(sig_)
                info.mem_bytes = io
                info.mem_f32_bytes = io_f32
        pending = []

    for line in text.splitlines():
        s = line.rstrip()
        if cur is None:
            header = re.match(
                r"^\s*(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$", s)
            if header:
                cur = Computation(name=header.group(2))
                comps[cur.name] = cur
                if header.group(1):
                    entry = cur.name
                symbols = {}
            continue
        if s.strip() == "}":
            finish()
            cur = None
            continue
        m = _OP_RE.match(s)
        if not m:
            continue
        name, sig, op = m.groups()
        symbols[name] = sig
        children = []
        body_child = None
        for cm in _CALL_RE.finditer(s):
            kids = [c.strip().lstrip("%") for c in cm.group(2).split(",")]
            if cm.group(1) == "body" and kids:
                body_child = kids[0]
            children.extend(kids)
        info = OpInfo(kind=op, result_sig=sig, children=tuple(children))
        info.body_child = body_child
        if op == "while":
            tm = _TRIP_RE.search(s)
            info.trip = int(tm.group(1)) if tm else None
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES:
            factor = 2 if base == "all-reduce" else 1
            info.coll_bytes = _bytes_of(sig) * factor
            info.coll_f32_bytes = _f32_bytes_of(sig) * factor
            info.kind = base
        if op == "dot":
            pending.append((info, s))
        if base not in _NO_TRAFFIC and not op.endswith("-done"):
            info.traffic_bytes = _bytes_of(sig)
            info.traffic_f32_bytes = _f32_bytes_of(sig)
        if base in _MEM_IO2 and not op.endswith("-done"):
            info.mem_bytes = 2.0 * _bytes_of(sig)
            info.mem_f32_bytes = 2.0 * _f32_bytes_of(sig)
        elif base in _MEM_IO1 and not op.endswith("-done"):
            info.mem_bytes = float(_bytes_of(sig))
            info.mem_f32_bytes = float(_f32_bytes_of(sig))
        cur.ops.append(info)
    finish()
    return comps, entry


_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERANDS_RE = re.compile(r"\(((?:%[\w.\-]+(?:,\s*)?)+)\)")


def _dot_flops(line: str, symbols: dict) -> float:
    m = _OP_RE.match(line)
    if not m:
        return 0.0
    result_shapes = _shape_list(m.group(2))
    if not result_shapes:
        return 0.0
    _, rshape = result_shapes[0]
    out_elems = 1
    for d in rshape:
        out_elems *= d
    # First operand after "dot(": either "%name" or, on newer XLA text,
    # "f32[128,128]{1,0} %name" with the type inline.
    om = re.search(
        r"dot\((?:(\w+\[[\d,]*\](?:\{[^}]*\})?)\s+)?(%[\w.\-]+)", line)
    cm = _LHS_CONTRACT_RE.search(line)
    if not om or not cm:
        return 2.0 * out_elems
    lhs_sig = om.group(1) or symbols.get(om.group(2))
    if not lhs_sig:
        return 2.0 * out_elems
    shapes = _shape_list(lhs_sig)
    if not shapes:
        return 2.0 * out_elems
    _, lhs_shape = shapes[0]
    k = 1
    for idx in cm.group(1).split(","):
        if idx and int(idx) < len(lhs_shape):
            k *= lhs_shape[int(idx)]
    return 2.0 * out_elems * k


@dataclass
class HLOStats:
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_f32_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=dict)
    traffic_bytes: float = 0.0
    traffic_f32_bytes: float = 0.0
    mem_bytes: float = 0.0
    mem_f32_bytes: float = 0.0
    top_collectives: list = field(default_factory=list)
    top_mem: list = field(default_factory=list)

    @property
    def mem_bytes_bf16corr(self) -> float:
        return self.mem_bytes - 0.5 * self.mem_f32_bytes

    @property
    def coll_bytes_bf16corr(self) -> float:
        """TPU estimate: large f32 payloads are CPU bf16-upcasts (verified
        against the StableHLO, which carries bf16) — halve them."""
        return self.coll_bytes - 0.5 * self.coll_f32_bytes

    @property
    def traffic_bytes_bf16corr(self) -> float:
        return self.traffic_bytes - 0.5 * self.traffic_f32_bytes

    def as_dict(self):
        return {
            "flops": self.flops, "coll_bytes": self.coll_bytes,
            "coll_by_kind": {k: v for k, v in sorted(
                self.coll_by_kind.items())},
            "traffic_bytes": self.traffic_bytes,
            "top_collectives": [
                {"bytes": b, "kind": k, "mult": mu, "sig": sg}
                for b, k, mu, sg in self.top_collectives[:20]],
        }


def analyze(text: str) -> HLOStats:
    comps, entry = parse_module(text)
    stats = HLOStats()

    def walk(name: str, mult: float, depth: int = 0,
             in_fusion: bool = False, body_trips: int = 0):
        comp = comps.get(name)
        if comp is None or depth > 16:
            return
        for op in comp.ops:
            # Loop-invariant heuristic: an op inside a counted loop whose
            # result's LEADING dim equals the trip count is (almost
            # always) the full stacked scan-xs array hoisted into the
            # body — it exists once, not once per iteration.  Verified on
            # mamba2 prefill: the (NC, B, Q, H, P) chunk reshape was
            # charged NC x too much (9.9 TB -> 39 GB).
            op_mult = mult
            if body_trips > 1:
                shapes = _shape_list(op.result_sig)
                if shapes and shapes[0][1] and                         shapes[0][1][0] == body_trips:
                    op_mult = mult / body_trips
            if op.flops:
                stats.flops += op.flops * op_mult
            if op.coll_bytes:
                stats.coll_bytes += op.coll_bytes * op_mult
                stats.coll_f32_bytes += op.coll_f32_bytes * op_mult
                stats.coll_by_kind[op.kind] = stats.coll_by_kind.get(
                    op.kind, 0.0) + op.coll_bytes * op_mult
                stats.top_collectives.append(
                    (op.coll_bytes * op_mult, op.kind, op_mult,
                     op.result_sig[:120]))
            # Memory proxy: count each op's result write ONCE at the level
            # where it hits HBM — ops inside fusion bodies share the fused
            # kernel's output buffer, so only the fusion's own result
            # counts (otherwise a 30-op fused elementwise chain counts
            # 30x its tensor size).
            if not in_fusion:
                stats.traffic_bytes += op.traffic_bytes * op_mult
                stats.traffic_f32_bytes += op.traffic_f32_bytes * op_mult
                if op.mem_bytes:
                    stats.mem_bytes += op.mem_bytes * op_mult
                    stats.mem_f32_bytes += op.mem_f32_bytes * op_mult
                    stats.top_mem.append(
                        (op.mem_bytes * op_mult, op.kind, op_mult,
                         op.result_sig[:100]))
            if op.kind == "while" and op.children:
                names = list(op.children)
                body = getattr(op, "body_child", None) or names[0]
                trips = op.trip if op.trip else 1
                walk(body, mult * trips, depth + 1, in_fusion,
                     body_trips=trips)
                for other in names:
                    if other != body:
                        walk(other, mult, depth + 1, in_fusion)
            elif op.children:
                child_fused = in_fusion or op.kind in (
                    "fusion", "call", "map", "reduce", "reduce-window",
                    "scatter", "sort", "custom-call")
                for child in op.children:
                    walk(child, op_mult, depth + 1, child_fused,
                         body_trips)

    if entry:
        walk(entry, 1.0)
    stats.top_collectives.sort(key=lambda t: -t[0])
    stats.top_mem.sort(key=lambda t: -t[0])
    del stats.top_mem[40:]
    return stats
