"""Launchers: mesh builders, multi-pod dry-run, train/serve/dedup drivers.

NOTE: do not import ``repro.launch.dryrun`` from library code — importing
it sets XLA_FLAGS for 512 host devices (it is a __main__ entry point).
"""
from repro.launch.mesh import make_production_mesh, make_test_mesh

__all__ = ["make_production_mesh", "make_test_mesh"]
