"""Batched serving driver: prefill a batch of prompts, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --tokens 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.models import lm
from repro.training.step import TrainConfig, init_state


def serve_batch(cfg, params, prompts: np.ndarray, max_new: int,
                cache_len: int | None = None):
    """prompts: (B, S_p) int32.  Greedy-decodes max_new tokens."""
    B, S = prompts.shape
    cache_len = cache_len or (S + max_new)
    cache, _ = lm.make_cache(cfg, B, cache_len)
    patches = (jnp.zeros((B, cfg.n_patches, cfg.d_model), cfg.cdtype)
               if cfg.n_patches else None)
    prefill = jax.jit(
        lambda p, t: lm.prefill(cfg, p, t, cache, patches=patches))
    decode = jax.jit(
        lambda p, c, t, k: lm.decode(cfg, p, c, t, k))

    t0 = time.perf_counter()
    cache_f, logits = prefill(params, jnp.asarray(prompts))
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    total0 = S + (cfg.n_patches or 0)
    out = []
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(max_new):
        out.append(np.asarray(tok))
        kv_len = jnp.full((B,), total0 + i, jnp.int32)
        logits, cache_f = decode(params, cache_f, tok, kv_len)
        tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0
    return (np.stack(out, axis=1),
            {"prefill_s": t_prefill, "decode_s": t_decode,
             "tok_per_s": B * max_new / max(t_decode, 1e-9)})


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch) if args.full else get_reduced(args.arch)
    if cfg.encdec:
        raise SystemExit("encoder-decoder serving: examples/whisper_serve")
    from repro import optim
    from repro.training.step import init_state
    state, _ = init_state(
        cfg, TrainConfig(adamw=optim.AdamWConfig()), jax.random.PRNGKey(0))
    prompts = np.random.RandomState(0).randint(
        2, cfg.vocab_size, size=(args.batch, args.prompt_len)
    ).astype(np.int32)
    toks, stats = serve_batch(cfg, state["params"], prompts, args.tokens)
    print(f"decoded {toks.shape} tokens; "
          f"prefill {stats['prefill_s']*1e3:.1f} ms, "
          f"{stats['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
