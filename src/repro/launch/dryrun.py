import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ These two lines MUST run before any jax import — jax locks the device
#   count at first init (the assignment's placeholder-device requirement).

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh (16x16 or 2x16x16),
  2. builds ShapeDtypeStruct stand-ins for state/batch/cache (no HBM),
  3. jit(...).lower(...).compile() with explicit in_shardings,
  4. records memory_analysis / cost_analysis / collective bytes ->
     experiments/dryrun/<arch>__<cell>__<mesh>.json  (EXPERIMENTS.md
     §Dry-run and §Roofline read these artifacts).

Usage:
  python -m repro.launch.dryrun                        # all cells, 1 pod
  python -m repro.launch.dryrun --multi-pod            # all cells, 2 pods
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
  python -m repro.launch.dryrun --reduced --mesh 2x2   # CI smoke
"""

import argparse
import json
import time
import traceback

import numpy as np
import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs import (
    ARCH_IDS, cache_specs, get_config, get_reduced, input_specs,
)
from repro.launch.hlo_analysis import cost_terms, model_flops, param_counts
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.models import lm, whisper
from repro.models.config import SHAPE_CELLS, cell_applicable
from repro.models.sharding import (DEFAULT_RULES,
                                   LONG_CONTEXT_RULES, RULES_PRESETS,
                                   activate, shardings_for, spec_for,
                                   tree_specs)
from repro.training.step import (
    TrainConfig, batch_specs, make_decode_step, make_prefill_step,
    make_train_step,
)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def _shardings(axes_tree, mesh, rules=None, sds_tree=None):
    if sds_tree is not None:
        return shardings_for(axes_tree, sds_tree, mesh,
                             rules or DEFAULT_RULES)
    specs = tree_specs(axes_tree, mesh, rules or DEFAULT_RULES)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def _abstract_state(cfg, tcfg):
    mod = whisper if cfg.encdec else lm
    params_sds, axes = mod.init(cfg, jax.random.PRNGKey(0), abstract=True)
    opt_sds = jax.eval_shape(lambda p: optim.init(p, tcfg.adamw),
                             params_sds)
    state_sds = {"params": params_sds, "opt": opt_sds}
    opt_axes = optim.state_axes(axes, tcfg.adamw)
    state_axes = {"params": axes, "opt": opt_axes}
    return state_sds, state_axes, params_sds, axes


def lower_cell(cfg, cell_name: str, mesh, *, donate: bool = True,
               rules_name: str | None = None):
    """Lower + compile one cell on ``mesh``.  Returns the record dict."""
    cell = SHAPE_CELLS[cell_name]
    if cell_name == "long_500k":
        rules, rules_name = LONG_CONTEXT_RULES, "long"
    elif rules_name:
        rules = RULES_PRESETS[rules_name]
    else:
        rules, rules_name = DEFAULT_RULES, "tp"
    chips = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    tcfg = TrainConfig(adamw=optim.AdamWConfig(
        moments_dtype=cfg.opt_moments_dtype))
    batch_sds = input_specs(cfg, cell_name)
    t0 = time.perf_counter()

    if cell.kind == "train":
        state_sds, st_axes, _, _ = _abstract_state(cfg, tcfg)
        st_shard = _shardings(st_axes, mesh, rules, state_sds)
        b_shard = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            batch_specs(cfg, batch_sds, mesh), is_leaf=lambda x:
            isinstance(x, P))
        fn = jax.jit(make_train_step(cfg, tcfg),
                     in_shardings=(st_shard, b_shard),
                     out_shardings=(st_shard, None),
                     donate_argnums=(0,) if donate else ())
        with mesh, activate(mesh, rules):
            lowered = fn.lower(state_sds, batch_sds)
    elif cell.kind == "prefill":
        _, _, params_sds, p_axes = _abstract_state(cfg, tcfg)
        p_shard = _shardings(p_axes, mesh, rules, params_sds)
        b_shard = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            batch_specs(cfg, batch_sds, mesh), is_leaf=lambda x:
            isinstance(x, P))
        seq_shard = cell_name == "long_500k"
        fn = jax.jit(make_prefill_step(cfg, seq_shard=seq_shard),
                     in_shardings=(p_shard, b_shard))
        with mesh, activate(mesh, rules):
            lowered = fn.lower(params_sds, batch_sds)
    else:  # decode
        _, _, params_sds, p_axes = _abstract_state(cfg, tcfg)
        p_shard = _shardings(p_axes, mesh, rules, params_sds)
        cache_sds, c_axes = cache_specs(cfg, cell_name)
        c_shard = _shardings(c_axes, mesh, rules, cache_sds)
        tok_shard = NamedSharding(mesh, spec_for(("batch",), mesh, rules))
        seq_shard = cell_name == "long_500k"
        fn = jax.jit(make_decode_step(cfg, seq_shard=seq_shard),
                     in_shardings=(p_shard, c_shard, tok_shard,
                                   tok_shard),
                     donate_argnums=(1,) if donate else ())
        with mesh, activate(mesh, rules):
            lowered = fn.lower(params_sds, cache_sds,
                               batch_sds["token"], batch_sds["kv_len"])

    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover
        mem_rec = {"error": str(e)}

    roof = cost_terms(compiled, chips, model_flops(cfg, cell))
    counts = param_counts(cfg)
    extra = {}
    if cell.kind == "decode":
        # Decode is bandwidth-bound by construction: the meaningful
        # efficiency metric is useful bytes (weights once + cache once)
        # vs the HBM traffic proxy.
        cache_sds_, _ = cache_specs(cfg, cell_name)
        cache_bytes = sum(
            x.size * x.dtype.itemsize
            for x in jax.tree.leaves(cache_sds_))
        useful = (2.0 * counts["total"] + cache_bytes) / chips
        extra["useful_bytes_per_dev"] = useful
        extra["hbm_fraction"] = (
            useful / roof.hbm_bytes if roof.hbm_bytes else 0.0)
    return {
        **extra,
        "rules": rules_name,
        "arch": cfg.name, "cell": cell_name,
        "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
        "chips": chips,
        "params_total": counts["total"], "params_active": counts["active"],
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem_rec,
        "roofline": roof.as_dict(),
        "status": "ok",
    }


def run_cell(arch_id: str, cell_name: str, *, multi_pod: bool,
             reduced: bool = False, mesh_override=None,
             rules_name: str | None = None) -> dict:
    cfg = get_reduced(arch_id) if reduced else get_config(arch_id)
    ok, reason = cell_applicable(cfg, cell_name)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": cfg.name, "cell": cell_name, "mesh": mesh_tag,
                "status": reason}
    mesh = mesh_override or make_production_mesh(multi_pod=multi_pod)
    try:
        return lower_cell(cfg, cell_name, mesh, rules_name=rules_name)
    except Exception as e:
        return {"arch": cfg.name, "cell": cell_name, "mesh": mesh_tag,
                "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}


def run_dedup_cell(*, multi_pod: bool, docs_per_dev: int = 4096,
                   max_len: int = 512, mesh_override=None,
                   cfg=None) -> dict:
    """Dry-run the paper's dedup step itself on the production mesh.

    Docs shard over all devices ('docs' view); the step is the full
    minhash -> band -> all_to_all shuffle -> verify pipeline
    (core.dist_lsh).  This is the 'most representative of the paper's
    technique' roofline cell.
    """
    from repro.core.dist_lsh import (
        DistLSHConfig, dedup_input_specs, docs_mesh, make_dedup_step,
    )

    base = mesh_override or make_production_mesh(multi_pod=multi_pod)
    mesh_tag = "x".join(str(base.shape[a]) for a in base.axis_names)
    chips = int(np.prod([base.shape[a] for a in base.axis_names]))
    mesh = docs_mesh(base.devices)
    cfg = cfg or DistLSHConfig()
    n_docs = docs_per_dev * chips
    specs = dedup_input_specs(cfg, n_docs, max_len)
    cell_name = f"docs{n_docs}x{max_len}"
    try:
        t0 = time.perf_counter()
        step = make_dedup_step(cfg, mesh)
        lowered = step.lower(specs["tokens"], specs["lengths"],
                             specs["seeds"])
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        # "Useful work" for the dedup step: M seeded hashes per valid
        # n-gram position (~5 int ops each ~ flop-equivalents).
        useful = 5.0 * n_docs * max_len * cfg.num_hashes
        roof = cost_terms(compiled, chips, useful)
        return {
            "arch": "dedup-pipeline", "cell": cell_name,
            "mesh": mesh_tag, "chips": chips,
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "roofline": roof.as_dict(), "status": "ok",
        }
    except Exception as e:
        return {"arch": "dedup-pipeline", "cell": cell_name,
                "mesh": mesh_tag, "status": "error",
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default all)")
    ap.add_argument("--shape", default=None, help="one cell (default all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--reduced", action="store_true", help="CI smoke")
    ap.add_argument("--dedup", action="store_true",
                    help="dry-run the dedup-pipeline step instead")
    ap.add_argument("--rules", default=None, choices=["tp", "dp"],
                    help="sharding-rules preset override")
    ap.add_argument("--mesh", default=None,
                    help="override, e.g. 2x2 (uses host devices)")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args(argv)

    arches = [args.arch] if args.arch else ARCH_IDS
    cells = [args.shape] if args.shape else list(SHAPE_CELLS)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)

    mesh_override = None
    if args.mesh:
        shape = tuple(int(x) for x in args.mesh.split("x"))
        names = ("data", "model") if len(shape) == 2 else (
            "pod", "data", "model")
        mesh_override = make_test_mesh(shape, names)

    if args.dedup:
        for multi_pod in meshes:
            t0 = time.perf_counter()
            rec = run_dedup_cell(multi_pod=multi_pod,
                                 mesh_override=mesh_override)
            dt = time.perf_counter() - t0
            tag = f"dedup-pipeline__{rec['cell']}__{rec['mesh']}"
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
            if rec["status"] == "ok":
                r = rec["roofline"]
                print(f"[ok   {dt:6.1f}s] {tag} "
                      f"bottleneck={r['bottleneck']} "
                      f"step={r['step_s']*1e3:.2f}ms")
            else:
                print(f"[FAIL {dt:6.1f}s] {tag}: {rec['error']}")
                raise SystemExit(1)
        return

    failures = 0
    for multi_pod in meshes:
        for arch in arches:
            for cell in cells:
                t0 = time.perf_counter()
                # auto policy (measured, EXPERIMENTS §Perf): DP-heavy
                # wins train cells (batch 256 divides the mesh) EXCEPT
                # zamba2 (hybrid SSD: measured 0.071 tp vs 0.053 dp);
                # TP remains best for prefill/decode (small batches).
                rules_name = args.rules or (
                    "dp" if cell == "train_4k"
                    and arch != "zamba2-2.7b" else None)
                rec = run_cell(arch, cell, multi_pod=multi_pod,
                               reduced=args.reduced,
                               mesh_override=mesh_override,
                               rules_name=rules_name)
                dt = time.perf_counter() - t0
                tag = f"{arch}__{cell}__{rec['mesh']}"
                path = os.path.join(args.out, tag + ".json")
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                if status == "error":
                    failures += 1
                    print(f"[FAIL {dt:6.1f}s] {tag}: {rec['error']}")
                elif status.startswith("skip"):
                    print(f"[skip       ] {tag}: {status}")
                else:
                    r = rec["roofline"]
                    print(f"[ok   {dt:6.1f}s] {tag} "
                          f"bottleneck={r['bottleneck']} "
                          f"step={r['step_s']*1e3:.2f}ms "
                          f"frac={r['roofline_fraction']:.3f}")
    if failures:
        raise SystemExit(f"{failures} cells failed")
    print("dry-run complete")


if __name__ == "__main__":
    main()
