"""Production mesh builders (functions, never module-level constants —
importing this module must not touch jax device state)."""
from __future__ import annotations

import numpy as np
import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips).

    Uses the first prod(shape) available devices so a 512-device host
    platform can build the single-pod mesh too.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == need:
        return jax.make_mesh(shape, axes)
    assert len(devices) >= need, (
        f"need {need} devices, have {len(devices)} — run under "
        f"XLA_FLAGS=--xla_force_host_platform_device_count=512")
    return jax.make_mesh(shape, axes, devices=devices[:need])


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many host devices tests forced."""
    need = int(np.prod(shape))
    return jax.make_mesh(shape, axes, devices=jax.devices()[:need])
