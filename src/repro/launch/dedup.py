"""Dedup driver: host, streaming (out-of-core), or sharded execution.

All three modes drive ONE ``core.session.DedupSession`` — the corpus is
split into ``--steps`` chunks and ingested incrementally (the sharded
backend pipelines: the host merge of step t overlaps the device shuffle
of step t+1) — and report cumulative session stats through one shared
helper.

  PYTHONPATH=src python -m repro.launch.dedup --notes 500 --dups 300
  PYTHONPATH=src python -m repro.launch.dedup --backend jnp --batch band
  PYTHONPATH=src python -m repro.launch.dedup --streaming --chunk 128
  PYTHONPATH=src python -m repro.launch.dedup --sharded --devices 8
  PYTHONPATH=src python -m repro.launch.dedup --sharded --steps 4
  PYTHONPATH=src python -m repro.launch.dedup --estimate --query 8
"""
from __future__ import annotations

import argparse
import os
import time


def report_session(mode: str, snap, seconds: float, extra: str = ""):
    """The one cumulative report every execution mode prints.

    ``snap`` is a ``core.session.ClusterSnapshot``; the line carries the
    session-level counters (docs ingested, duplicate clusters,
    duplicates, verify throughput) so the three modes are comparable at
    a glance.
    """
    retain = ""
    if snap.evicted or snap.refine_merges or snap.filter_only_hits:
        retain = (f", {snap.retained_rows} rows retained "
                  f"({snap.evicted} evicted, "
                  f"{snap.filter_only_hits} filter-only hits, "
                  f"{snap.refine_merges} refine merges)")
    print(f"{mode}: {snap.n_docs} docs ingested, "
          f"{snap.num_clusters} clusters, "
          f"{snap.num_duplicates} duplicates, "
          f"{snap.stats.pairs_evaluated} pairs verified "
          f"({snap.stats.pairs_excluded} excluded) in "
          f"{snap.stats.verify_batches} batches "
          f"({snap.stats.verify_pairs_per_second:.0f} pairs/s)"
          f"{extra}{retain}, {seconds:.2f}s total")


def run_query_demo(sess, notes, n: int):
    """Read-path demo: re-query ``n`` ingested notes + one novel note.

    Stands up a ``DedupQueryService`` over the warm session and prints
    one summary line.  Queries never mutate the session — the snapshot
    the caller just reported stays valid.  Modes whose session cannot
    publish a ``SessionView`` (streaming: no cross-step band index;
    stage2=device: external verifier callback) are reported and
    skipped rather than failed.
    """
    from repro.serving.dedup_service import DedupQueryService

    try:
        view = sess.view()
    except ValueError as e:
        print(f"query demo skipped: {e}")
        return
    svc = DedupQueryService(sess)
    n = min(n, len(notes))
    novel = "entirely unrelated query text " * 12
    t0 = time.perf_counter()
    results = svc.query(list(notes[:n]) + [novel])
    dt = time.perf_counter() - t0
    hits = sum(r.is_duplicate for r in results[:n])
    best = max((r.best_sim for r in results[:n]), default=0.0)
    print(f"query[view v{view.version}]: {hits}/{n} re-queried notes "
          f"matched their clusters (best sim {best:.2f}), novel note "
          f"{'came back novel' if results[-1].novel else 'MATCHED (!)'}"
          f", {n + 1} queries in {dt * 1e3:.1f} ms")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--notes", type=int, default=500)
    ap.add_argument("--dups", type=int, default=300)
    ap.add_argument("--edge-threshold", type=float, default=0.75)
    ap.add_argument("--tree-threshold", type=float, default=0.40)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--fused-ingest", action="store_true",
                    help="one-pass device ingest: shingle -> minhash -> "
                         "band fold in a single fused Pallas kernel "
                         "(bit-identical to the staged path)")
    ap.add_argument("--byte-ingest", action="store_true",
                    help="zero-copy device ingest: raw UTF-8 bytes are "
                         "the only host->device transfer; tokenize + "
                         "shingle + minhash + band fold all run on "
                         "device (no-stem tokenization; implies "
                         "--estimate, since no host token lists exist)")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "numpy", "jnp", "pallas"),
                    help="estimate-mode verification backend")
    ap.add_argument("--batch", default="run", choices=("run", "band"),
                    help="engine batch granularity (band = max throughput)")
    ap.add_argument("--estimate", action="store_true",
                    help="signature-estimate verification (vs exact)")
    ap.add_argument("--streaming", action="store_true",
                    help="two-phase out-of-core mode over a band store")
    ap.add_argument("--chunk", type=int, default=128,
                    help="streaming ingest chunk size")
    ap.add_argument("--store", default=None,
                    choices=("memory", "sqlite"),
                    help="band-store tier: memory (in-RAM index / "
                         "Design-2 blob store) or sqlite (disk-resident "
                         "band + signature rows behind Bloom-first "
                         "lookups; identical clusters either way). "
                         "Default: $REPRO_STORE_BACKEND or memory")
    ap.add_argument("--store-path", default=":memory:",
                    help="sqlite database path for the store tier "
                         "(default :memory:)")
    ap.add_argument("--sharded", action="store_true",
                    help="run the shard_map dedup step")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host device count (sharded mode)")
    ap.add_argument("--band-groups", type=int, default=1,
                    help="stream the sharded step's verified-edge "
                         "buffers per band-group (G bounded buffers of "
                         "b/G bands; host merge overlaps device shuffle)")
    ap.add_argument("--stage2", default="host", choices=("host", "device"),
                    help="full-signature verify placement: host merge "
                         "or TPU-resident (fused sigjaccard kernel "
                         "under shard_map; cross-shard edges scored "
                         "via the exchanged row buffers, host "
                         "re-scores only on row-buffer overflow)")
    ap.add_argument("--steps", type=int, default=1,
                    help="split the corpus into N chunks and ingest "
                         "them incrementally through one DedupSession "
                         "(sharded mode pipelines: merge of step t "
                         "overlaps the shuffle of step t+1)")
    ap.add_argument("--retain-budget", default="none",
                    choices=("none", "small", "medium", "unlimited"),
                    help="retained-state eviction policy: evict "
                         "signature/token rows down to cluster "
                         "representatives + an LRU window and compact "
                         "old band-index keys into per-band Bloom "
                         "filters (none = PR 4 append-only retention)")
    ap.add_argument("--refine-every", type=int, default=0,
                    help="auto-run the incremental second clustering "
                         "round (DedupSession.refine) every K ingest "
                         "steps (0 = off)")
    ap.add_argument("--query", type=int, default=0, metavar="N",
                    help="after ingest, stand up a DedupQueryService "
                         "over the warm session and re-query N ingested "
                         "notes plus one novel note (read path demo; "
                         "host/sharded modes only — streaming has no "
                         "band index to publish a view over)")
    args = ap.parse_args(argv)

    if args.sharded and args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import numpy as np
    import jax
    from repro.core import DedupConfig, DedupSession, RetentionPolicy
    from repro.data import inject_near_duplicates, make_i2b2_like

    retention = None
    if args.retain_budget != "none" or args.refine_every:
        # "none" + --refine-every keeps rows append-only (no eviction)
        # while still tracking roots for the auto-refine cadence.
        retention = RetentionPolicy.preset(
            args.retain_budget, refine_every=args.refine_every)

    notes = make_i2b2_like(args.notes)
    notes, prov = inject_near_duplicates(notes, args.dups)
    print(f"corpus: {len(notes)} notes ({args.dups} injected near-dups), "
          f"{args.steps} ingest step(s)")

    bounds = np.linspace(0, len(notes), max(1, args.steps) + 1).astype(int)
    chunks = [notes[a:b] for a, b in zip(bounds, bounds[1:])]

    cfg = DedupConfig(
        edge_threshold=args.edge_threshold,
        tree_threshold=args.tree_threshold,
        use_pallas=args.use_pallas,
        fused_ingest=args.fused_ingest,
        byte_ingest=args.byte_ingest,
        exact_verification=not (args.estimate or args.byte_ingest),
        verify_backend=args.backend,
        verify_batch=args.batch,
        # None falls back to the field default ($REPRO_STORE_BACKEND).
        **({"store": args.store} if args.store else {}))

    if args.sharded:
        from repro.core import DistLSHConfig

        ndev = len(jax.devices())
        dcfg = DistLSHConfig(edge_threshold=args.edge_threshold,
                             edge_capacity=8192,
                             band_groups=args.band_groups,
                             stage2=args.stage2,
                             fused_ingest=args.fused_ingest,
                             byte_ingest=args.byte_ingest)
        from dataclasses import replace

        # Sharded verification is estimate-shaped by construction; the
        # session's verifier is the same full-signature estimator the
        # host path uses (or the device-score registry for stage2
        # device).
        sess = DedupSession(replace(cfg, exact_verification=False),
                            backend="sharded", dist_config=dcfg,
                            store_path=args.store_path,
                            retention=retention)
        t0 = time.perf_counter()
        for snap in sess.ingest_stream(chunks):
            pass
        dt = time.perf_counter() - t0
        extra = (f", {snap.overflow} overflow"
                 f"{' (host fallback ran)' if snap.retried else ''}")
        if args.stage2 == "device":
            extra += (f", stage2=device {snap.device_scored} "
                      f"device-scored / {snap.host_rescored} "
                      f"host-rescored / {snap.row_overflow} row-overflow")
        report_session(
            f"sharded[{ndev} devices x {dcfg.band_groups} band-group(s) "
            f"x {args.steps} step(s)]", snap, dt, extra)
        if args.query:
            run_query_demo(sess, notes, args.query)
        return

    if args.streaming:
        from repro.core.shingle import tokenize
        from repro.core.verify import ExactJaccardVerifier

        verifier = None
        if cfg.byte_ingest:
            # Byte configs stream raw texts — tokenization happens on
            # device, so there is nothing to pre-tokenize (and no token
            # lists for an exact verifier; config validation enforces
            # estimate mode).
            stream_chunks = (notes[a:b]
                             for a, b in zip(bounds, bounds[1:]))
            tokenized = False
        else:
            # Tokenize once; the chunks are ingested pre-tokenized so
            # the exact verifier (built over the same token lists — the
            # streaming backend's native verifier is the signature
            # estimate, so exact_verification is honoured explicitly)
            # does not pay a second tokenize pass.
            toks = [tokenize(t) for t in notes]
            if cfg.exact_verification:
                verifier = ExactJaccardVerifier.from_token_lists(
                    toks, cfg.ngram)
            stream_chunks = (toks[a:b]
                             for a, b in zip(bounds, bounds[1:]))
            tokenized = True
        sess = DedupSession(cfg, backend="streaming",
                            chunk_docs=args.chunk, verifier=verifier,
                            store_path=args.store_path,
                            retention=retention)
        t0 = time.perf_counter()
        # Pre-tokenized chunks stream with the tokenized flag threaded
        # through, so nothing downstream re-tokenizes or re-stores them.
        for snap in sess.ingest_stream(stream_chunks,
                                       tokenized=tokenized):
            pass
        dt = time.perf_counter() - t0
        report_session(f"streaming[{args.steps} step(s)]", snap, dt)
        if args.query:
            run_query_demo(sess, notes, args.query)
        return

    sess = DedupSession(cfg, backend="host",
                        store_path=args.store_path, retention=retention)
    t0 = time.perf_counter()
    for chunk in chunks:
        snap = sess.ingest(chunk)
    dt = time.perf_counter() - t0
    report_session(f"host[{args.steps} step(s)]", snap, dt)
    if args.query:
        run_query_demo(sess, notes, args.query)


if __name__ == "__main__":
    main()
