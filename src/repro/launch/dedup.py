"""Dedup driver: host, streaming (out-of-core), or sharded execution.

All three modes are thin drivers over the staged engine
(``CandidateSource -> BatchVerifier -> ThresholdUnionFind``; see
``repro.core.engine``), with a selectable verification backend.

  PYTHONPATH=src python -m repro.launch.dedup --notes 500 --dups 300
  PYTHONPATH=src python -m repro.launch.dedup --backend jnp --batch band
  PYTHONPATH=src python -m repro.launch.dedup --streaming --chunk 128
  PYTHONPATH=src python -m repro.launch.dedup --sharded --devices 8
"""
from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--notes", type=int, default=500)
    ap.add_argument("--dups", type=int, default=300)
    ap.add_argument("--edge-threshold", type=float, default=0.75)
    ap.add_argument("--tree-threshold", type=float, default=0.40)
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "numpy", "jnp", "pallas"),
                    help="estimate-mode verification backend")
    ap.add_argument("--batch", default="run", choices=("run", "band"),
                    help="engine batch granularity (band = max throughput)")
    ap.add_argument("--estimate", action="store_true",
                    help="signature-estimate verification (vs exact)")
    ap.add_argument("--streaming", action="store_true",
                    help="two-phase out-of-core mode over a band store")
    ap.add_argument("--chunk", type=int, default=128,
                    help="streaming ingest chunk size")
    ap.add_argument("--sharded", action="store_true",
                    help="run the shard_map dedup step")
    ap.add_argument("--devices", type=int, default=0,
                    help="force host device count (sharded mode)")
    ap.add_argument("--band-groups", type=int, default=1,
                    help="stream the sharded step's verified-edge "
                         "buffers per band-group (G bounded buffers of "
                         "b/G bands; host merge overlaps device shuffle)")
    ap.add_argument("--stage2", default="host", choices=("host", "device"),
                    help="full-signature verify placement: host merge "
                         "or TPU-resident (fused sigjaccard kernel "
                         "under shard_map; host re-scores only "
                         "cross-shard stragglers)")
    args = ap.parse_args(argv)

    if args.sharded and args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import DedupConfig, DedupPipeline
    from repro.data import inject_near_duplicates, make_i2b2_like

    notes = make_i2b2_like(args.notes)
    notes, prov = inject_near_duplicates(notes, args.dups)
    print(f"corpus: {len(notes)} notes ({args.dups} injected near-dups)")

    cfg = DedupConfig(
        edge_threshold=args.edge_threshold,
        tree_threshold=args.tree_threshold,
        use_pallas=args.use_pallas,
        exact_verification=not args.estimate,
        verify_backend=args.backend,
        verify_batch=args.batch)

    if args.sharded:
        from repro.core import (DistLSHConfig, cluster_step_output,
                                docs_mesh, make_streamed_dedup_step)
        from repro.core import minhash
        from repro.core.shingle import pack_documents, tokenize

        token_lists = [tokenize(t) for t in notes]
        ndev = len(jax.devices())
        pad = (-len(token_lists)) % ndev
        token_lists += [["pad"]] * pad
        packed = pack_documents(token_lists)
        dcfg = DistLSHConfig(edge_threshold=args.edge_threshold,
                             edge_capacity=8192,
                             band_groups=args.band_groups,
                             stage2=args.stage2)
        mesh = docs_mesh()
        step = make_streamed_dedup_step(dcfg, mesh)
        t0 = time.perf_counter()
        out = step(jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
                   jnp.asarray(minhash.default_seeds(dcfg.num_hashes)))
        t_dispatch = time.perf_counter() - t0
        # Streamed merge through the shared staged engine: group g's
        # host merge overlaps the device shuffle of group g+1; with
        # --stage2 device the edges arrive already fully scored and the
        # host only re-scores cross-shard stragglers.
        t0 = time.perf_counter()
        res = cluster_step_output(
            out, dcfg, tree_threshold=args.tree_threshold,
            backend=cfg.resolved_backend(), batch=args.batch,
            num_docs=len(notes))
        t_merge = time.perf_counter() - t0
        labels = res.labels()
        n_dup = len(notes) - len(set(labels.tolist()))
        dev_stats = res.device_stats.sum(axis=0)
        stage2_note = (
            f", stage2=device {res.device_scored} device-scored / "
            f"{res.host_rescored} host-rescored"
            if args.stage2 == "device" else "")
        print(f"sharded over {ndev} devices x {dcfg.band_groups} "
              f"band-group(s): {res.num_edges} prescreened edges "
              f"({dev_stats[1]} candidates, overflow={res.overflow}"
              f"{', retried via host fallback' if res.retried else ''}), "
              f"{n_dup} duplicates, "
              f"{res.stats.pairs_evaluated} full-signature verifies in "
              f"{res.stats.verify_batches} batches "
              f"({res.stats.verify_pairs_per_second:.0f} pairs/s"
              f"{stage2_note}), "
              f"dispatch {t_dispatch:.2f}s merge+overlap {t_merge:.2f}s")
        return

    if args.streaming:
        from repro.core.shingle import tokenize
        from repro.core.streaming import StreamingDedup
        from repro.core.verify import ExactJaccardVerifier

        sd = StreamingDedup(cfg, chunk_docs=args.chunk)
        token_lists = [tokenize(t) for t in notes]
        t0 = time.perf_counter()
        sd.ingest_tokens(token_lists)
        t_ingest = time.perf_counter() - t0
        # StreamingDedup's own default verifier is the signature
        # estimate; honour exact_verification like the host path does.
        verifier = None
        if cfg.exact_verification:
            verifier = ExactJaccardVerifier.from_token_lists(
                token_lists, cfg.ngram)
        t0 = time.perf_counter()
        uf, stats = sd.cluster(similarity_fn=verifier)
        t_cluster = time.perf_counter() - t0
        labels = uf.components()
        n_dup = len(notes) - len(set(labels.tolist()))
        thr = (stats["pairs_evaluated"] / stats["verify_seconds"]
               if stats["verify_seconds"] > 0 else 0.0)
        print(f"streaming pipeline: {n_dup} duplicates, "
              f"{stats['pairs_evaluated']} pairs verified in "
              f"{stats['verify_batches']} batches "
              f"({thr:.0f} pairs/s), "
              f"ingest {t_ingest:.2f}s cluster {t_cluster:.2f}s")
        return

    pipe = DedupPipeline(cfg)
    t0 = time.perf_counter()
    res = pipe.run(notes)
    dt = time.perf_counter() - t0
    print(f"host pipeline: {res.num_clusters} clusters, "
          f"{res.num_duplicates_removed} duplicates removed, "
          f"{res.stats.pairs_evaluated} Jaccard evals "
          f"({res.stats.pairs_excluded} excluded; "
          f"{res.stats.verify_batches} batches, "
          f"{res.stats.verify_pairs_per_second:.0f} pairs/s), {dt:.2f}s")
    print("timings:", {k: round(v, 3) for k, v in res.timings.items()})


if __name__ == "__main__":
    main()
