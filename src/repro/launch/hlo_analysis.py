"""HLO artifact analysis: collective-byte accounting + roofline terms.

Sources (ROOFLINE ANALYSIS spec):
  * ``compiled.cost_analysis()`` -> HLO_FLOPs, HLO_bytes.
  * ``compiled.as_text()`` (the per-device SPMD-partitioned module) ->
    per-device collective bytes: operand sizes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute ops.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.  The three terms are each "seconds if this resource were the only
bottleneck"; the max is the roofline step time.

Note on normalization: the partitioned HLO is the program of ONE device,
so summed operand bytes are already per-device; collective_term =
per_device_bytes / link_bw (algebraically identical to the global
formula collective_bytes_global / (chips x link_bw)).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
ICI_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(sig: str) -> int:
    """Sum byte sizes of every shape literal in an HLO type signature."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Per-device collective bytes from a partitioned HLO module."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        s = line.strip()
        if not s or "=" not in s:
            continue
        # Result type is between '=' and the op name.
        m = re.search(
            r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))\s+"
            r"([\w-]+)", s)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-start"):
            op = op[: -len("-start")]
        if op not in _COLLECTIVES:
            continue
        size = _shape_bytes(m.group(1))
        # all-reduce moves ~2x operand bytes (reduce-scatter+all-gather
        # decomposition); others ~1x of the larger of operand/result.
        factor = 2 if op == "all-reduce" else 1
        stats.bytes_by_kind[op] = stats.bytes_by_kind.get(op, 0) + (
            size * factor)
        stats.count_by_kind[op] = stats.count_by_kind.get(op, 0) + 1
    return stats


@dataclass
class Roofline:
    """All byte/flop quantities are PER-DEVICE (the partitioned HLO is one
    device's program); ``model_flops`` is the global analytic count."""

    flops: float               # per-device HLO dot flops (trip-corrected)
    hbm_bytes: float           # per-device traffic proxy (upper bound)
    coll_bytes_per_dev: float  # per-device collective bytes
    chips: int
    model_flops: float = 0.0   # 6*N*D (analytic, global)
    xla_flops: float = 0.0     # raw cost_analysis (scan-undercounted)
    xla_bytes: float = 0.0
    raw_hbm_bytes: float = 0.0   # before bf16 CPU-upcast correction
    raw_coll_bytes: float = 0.0
    coll_by_kind: dict = None
    top_collectives: list = None
    top_mem: list = None

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_dev / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / roofline step time (the perf score)."""
        if self.step_s == 0:
            return 0.0
        useful = self.model_flops / (self.chips * PEAK_FLOPS)
        return useful / self.step_s

    @property
    def flops_efficiency(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_dev": self.flops, "hbm_bytes_per_dev": self.hbm_bytes,
            "coll_bytes_per_dev": self.coll_bytes_per_dev,
            "chips": self.chips, "model_flops": self.model_flops,
            "xla_flops_raw": self.xla_flops,
            "xla_bytes_raw": self.xla_bytes,
            "hbm_bytes_uncorrected": self.raw_hbm_bytes,
            "coll_bytes_uncorrected": self.raw_coll_bytes,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck, "step_s": self.step_s,
            "roofline_fraction": self.roofline_fraction,
            "flops_efficiency": self.flops_efficiency,
            "coll_by_kind": self.coll_by_kind or {},
            "top_collectives": self.top_collectives or [],
            "top_mem": self.top_mem or [],
        }


def cost_terms(compiled, chips: int, model_flops: float = 0.0) -> Roofline:
    from repro.launch.hlo_parse import analyze

    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    stats = analyze(compiled.as_text())
    return Roofline(
        flops=stats.flops, hbm_bytes=stats.mem_bytes_bf16corr,
        coll_bytes_per_dev=stats.coll_bytes_bf16corr, chips=chips,
        model_flops=model_flops,
        xla_flops=float(ca.get("flops", 0.0)),
        xla_bytes=float(ca.get("bytes accessed", 0.0)),
        raw_hbm_bytes=stats.traffic_bytes,
        raw_coll_bytes=stats.coll_bytes,
        top_mem=[{"bytes": b, "kind": k, "mult": mu, "sig": sg}
                 for b, k, mu, sg in stats.top_mem[:12]],
        coll_by_kind={k: v for k, v in sorted(
            stats.coll_by_kind.items())},
        top_collectives=[
            {"bytes": b, "kind": k, "mult": mu, "sig": sg}
            for b, k, mu, sg in stats.top_collectives[:12]],
    )


# -- analytic model FLOPs -------------------------------------------------------

def param_counts(cfg) -> dict:
    """Total and active parameter counts from the config (no allocation)."""
    import jax
    from repro.models import lm as _lm
    from repro.models import whisper as _whisper

    mod = _whisper if cfg.encdec else _lm
    shapes = jax.eval_shape(
        lambda: mod.init(cfg, jax.random.PRNGKey(0))[0])
    total = sum(
        int(x.size) for x in jax.tree.leaves(shapes))
    active = total
    if cfg.moe is not None:
        m = cfg.moe
        h = m.d_expert or cfg.d_ff
        per_expert = 3 * cfg.d_model * h
        n_moe_layers = cfg.n_layers // m.every
        inactive = (m.n_experts - m.top_k) * per_expert * n_moe_layers
        active = total - inactive
    return {"total": total, "active": active}


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS per step: 6*N*D (train) / 2*N*D (forward-only),
    N = active params (MoE), D = processed tokens."""
    counts = param_counts(cfg)
    n = counts["active"]
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * cell.global_batch
