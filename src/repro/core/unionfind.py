"""Disjoint sets with a Jaccard lower-bound guarantee (paper §6).

Faithful implementation of the paper's extended union-find: every tree
carries ``min_score`` — the minimum triangle-inequality lower bound on
Jaccard similarity between the root and any leaf.  A union of two trees is
admitted only when the implied leaf-to-leaf bound

    leaf_to_leaf = x.min_score + y.min_score + sim(xRoot, yRoot) - 2

stays >= ``tree_threshold`` (paper §6.4).  This guarantees that *every*
pair of documents inside one cluster has exact Jaccard >= tree_threshold
without evaluating all pairs.

Also provides ``connected_components``: a parallel pointer-doubling
connected-components solver in pure JAX (lax.while_loop) — the
TPU-friendly alternative for the scalable path (DESIGN.md §2).
"""
from __future__ import annotations


import numpy as np
import jax
import jax.numpy as jnp


class ThresholdUnionFind:
    """Paper §6.4 extended disjoint sets (host-side, numpy-backed)."""

    def __init__(self, n: int, tree_threshold: float):
        self.parent = np.arange(n, dtype=np.int64)
        self.rank = np.zeros(n, dtype=np.int32)
        # min lower bound on Jaccard between node (as root) and its leaves.
        self.min_score = np.ones(n, dtype=np.float64)
        self.tree_threshold = float(tree_threshold)
        self.n_unions = 0
        self.n_rejected = 0
        # Root-representative tracking (retention layer, DESIGN.md §7):
        # every doc starts as the root of its own tree and loses
        # roothood AT MOST ONCE — ``parent[d]`` changes away from ``d``
        # only inside ``union`` where ``d`` is the losing root (path
        # compression only rewires already-deposed nodes).  With
        # ``track_deposed`` on, each union logs the deposed root, so an
        # eviction policy can discover newly non-representative docs
        # incrementally (O(unions drained), never an O(all docs) scan).
        self.track_deposed = False
        self.deposed: list[int] = []

    def grow(self, n: int) -> None:
        """Extend the forest to cover ``n`` docs (new ids are singletons).

        Incremental ingest (``core.session.DedupSession``) allocates doc
        ids chunk by chunk; growing keeps every existing root, rank, and
        ``min_score`` untouched, so clustering state accumulated so far
        is preserved exactly.
        """
        old = len(self.parent)
        if n <= old:
            return
        self.parent = np.concatenate(
            [self.parent, np.arange(old, n, dtype=np.int64)])
        self.rank = np.concatenate(
            [self.rank, np.zeros(n - old, dtype=np.int32)])
        self.min_score = np.concatenate(
            [self.min_score, np.ones(n - old, dtype=np.float64)])

    def find(self, x: int) -> int:
        root = x
        while self.parent[root] != root:
            root = self.parent[root]
        # Path compression (does not change root min_score semantics:
        # min_score is only meaningful at roots).
        while self.parent[x] != root:
            self.parent[x], x = root, self.parent[x]
        return int(root)

    def union(self, x: int, y: int, sim: float) -> bool:
        """Union by rank, guarded by the lower-bound threshold property.

        ``sim`` must be the *exact* (or verified-estimate) Jaccard
        similarity between the two current roots' documents — the paper
        computes sim(xRoot, yRoot) at union time.
        Returns True iff the union was performed.
        """
        x_root, y_root = self.find(x), self.find(y)
        if x_root == y_root:
            return False
        leaf_to_leaf = (
            self.min_score[x_root] + self.min_score[y_root] + sim - 2.0
        )
        if leaf_to_leaf < self.tree_threshold:
            self.n_rejected += 1
            return False
        if self.rank[x_root] < self.rank[y_root]:
            x_root, y_root = y_root, x_root
        # Attach y under x.
        self.parent[y_root] = x_root
        if self.track_deposed:
            self.deposed.append(int(y_root))
        if self.rank[x_root] == self.rank[y_root]:
            self.rank[x_root] += 1
        self.min_score[x_root] = min(
            self.min_score[x_root], self.min_score[y_root] - (1.0 - sim)
        )
        self.n_unions += 1
        return True

    def drain_deposed(self) -> list[int]:
        """Return (and clear) the roots deposed since the last drain.

        Each doc appears at most once across ALL drains (roothood is
        lost at most once), so a retention sweep can treat the drained
        list as the exact set of newly eviction-eligible documents.
        Requires ``track_deposed`` to have been on while the unions ran.
        """
        out, self.deposed = self.deposed, []
        return out

    def components(self) -> np.ndarray:
        """Root label for every node (fully compressed)."""
        return np.array([self.find(i) for i in range(len(self.parent))])

    def clusters(self, min_size: int = 2) -> list[list[int]]:
        roots = self.components()
        groups: dict[int, list[int]] = {}
        for i, r in enumerate(roots):
            groups.setdefault(int(r), []).append(i)
        return [v for v in groups.values() if len(v) >= min_size]


# ---------------------------------------------------------------------------
# Parallel connected components (pointer doubling) — pure JAX
# ---------------------------------------------------------------------------

import functools


@functools.partial(jax.jit, static_argnames=("num_nodes",))
def connected_components(
    edges: jnp.ndarray, mask: jnp.ndarray, num_nodes: int
) -> jnp.ndarray:
    """Label connected components given an edge list.

    edges: (E, 2) int32, mask: (E,) bool (invalid edges ignored).
    Returns (num_nodes,) int32 labels — the minimum node id reachable.

    Algorithm: iterative min-label propagation (hooking) + pointer
    doubling (shortcutting), O(log N) rounds inside lax.while_loop.
    TPU-friendly: only scatter-min / gather ops, static shapes.
    """
    u = jnp.where(mask, edges[:, 0], 0).astype(jnp.int32)
    v = jnp.where(mask, edges[:, 1], 0).astype(jnp.int32)
    labels0 = jnp.arange(num_nodes, dtype=jnp.int32)

    def cond(state):
        labels, changed, it = state
        return changed & (it < 64)

    def body(state):
        labels, _, it = state
        lu = labels[u]
        lv = labels[v]
        m = jnp.minimum(lu, lv)
        new = labels
        # Hook: each endpoint's label drops to the edge minimum.
        new = new.at[u].min(jnp.where(mask, m, jnp.int32(2**31 - 1)))
        new = new.at[v].min(jnp.where(mask, m, jnp.int32(2**31 - 1)))
        # Shortcut: pointer double twice.
        new = new[new]
        new = new[new]
        changed = jnp.any(new != labels)
        return new, changed, it + 1

    labels, _, _ = jax.lax.while_loop(
        cond, body, (labels0, jnp.array(True), jnp.int32(0))
    )
    return labels


def cluster_min_score_audit(
    labels: np.ndarray,
    edges: np.ndarray,
    sims: np.ndarray,
    tree_threshold: float,
) -> dict:
    """Post-hoc audit of the lower-bound property for parallel CC output.

    Builds a spanning tree per cluster from the verified edges and checks
    the triangle-inequality bound along tree paths (DESIGN.md §2: the
    guarantee is audited rather than enforced in the parallel path).
    Returns {n_clusters, n_audited_pairs, min_bound, property_holds}.
    """
    import networkx as nx

    g = nx.Graph()
    g.add_nodes_from(range(len(labels)))
    for (a, b), s in zip(edges, sims):
        a, b = int(a), int(b)
        if a != b:
            if not g.has_edge(a, b) or g[a][b]["sim"] < s:
                g.add_edge(a, b, sim=float(s), dist=1.0 - float(s))
    min_bound = 1.0
    n_pairs = 0
    holds = True
    for comp in nx.connected_components(g):
        comp = list(comp)
        if len(comp) < 2:
            continue
        sub = g.subgraph(comp)
        # Max-similarity spanning tree gives the tightest bound.
        tree = nx.minimum_spanning_tree(sub, weight="dist")
        ecc_dist = dict(
            nx.all_pairs_dijkstra_path_length(tree, weight="dist")
        )
        for a in comp:
            for b in comp:
                if a < b:
                    bound = 1.0 - ecc_dist[a][b]
                    min_bound = min(min_bound, bound)
                    n_pairs += 1
                    if bound < tree_threshold - 1e-9:
                        holds = False
    return {
        "n_clusters": sum(
            1 for c in nx.connected_components(g) if len(c) >= 2
        ),
        "n_audited_pairs": n_pairs,
        "min_bound": min_bound,
        "property_holds": holds,
    }
