"""Shingling: documents -> word n-gram hash sets (paper §2.2, §7.2).

Host side: text -> stemmed word tokens -> token ids (hash vocabulary).
Device side: padded token-id matrices -> rolling polynomial n-gram hashes.

The paper uses word 8-grams with stemming.  Stemming here is a light
suffix-stripping stemmer (Porter-lite) — adequate for equating inflected
forms, dependency-free.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.hashing import (
    FNV_OFFSET32,
    FNV_PRIME32,
    NGRAM_BASE,
    fmix32,
    fmix32_np,
    hash_u32_np,
)

_WORD_RE = re.compile(r"[A-Za-z0-9]+")

_SUFFIXES = (
    "ational", "iveness", "fulness", "ousness",
    "ication", "izations", "ization",
    "ingly", "edly", "ings",
    "ing", "ies", "ied", "ely", "es", "ed", "ly", "s",
)


def stem(word: str) -> str:
    """Suffix-strip stemmer (keeps >=3 chars of stem)."""
    w = word.lower()
    for suf in _SUFFIXES:
        if w.endswith(suf) and len(w) - len(suf) >= 3:
            return w[: -len(suf)]
    return w


def tokenize(text: str, do_stem: bool = True) -> list[str]:
    toks = _WORD_RE.findall(text)
    if do_stem:
        return [stem(t) for t in toks]
    return [t.lower() for t in toks]


def token_ids(tokens: list[str], seed: int = 0x7045) -> np.ndarray:
    """Hash words to uint32 ids (hash vocabulary; no lookup table needed)."""
    out = np.empty(len(tokens), dtype=np.uint32)
    for i, t in enumerate(tokens):
        h = 2166136261
        for ch in t.encode("utf-8"):
            h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
        out[i] = h
    if len(tokens):
        out = hash_u32_np(out, np.uint32(seed))
    return out


def ngram_set(tokens: list[str], n: int = 8) -> set[tuple[str, ...]]:
    """Exact n-gram set (oracle for exact Jaccard)."""
    if len(tokens) < n:
        return {tuple(tokens)} if tokens else set()
    return {tuple(tokens[i : i + n]) for i in range(len(tokens) - n + 1)}


# ---------------------------------------------------------------------------
# Byte-level tokenization (device ingest path; host oracles)
# ---------------------------------------------------------------------------
#
# The byte path reproduces ``token_ids(tokenize(text, do_stem=False))``
# directly from UTF-8 bytes: tokens are maximal runs of ASCII
# alphanumerics (``_WORD_RE`` only matches ASCII), A-Z folds to a-z by
# +32, and *every* other byte — including all bytes >= 0x80, i.e. every
# byte of a multi-byte UTF-8 sequence — is a separator.  Because an
# ASCII token's UTF-8 encoding is the token's bytes themselves, the
# per-token FNV-1a over folded bytes is bit-identical to ``token_ids``;
# multi-byte safety falls out for free (a boundary can never split a
# token, because no token byte is ever part of a multi-byte sequence).


@dataclass(frozen=True)
class PackedBytes:
    """A batch of documents as a padded UTF-8 byte matrix."""

    data: np.ndarray  # (D, LB) uint8, zero-padded rows
    lengths: np.ndarray  # (D,) int32 byte lengths

    @property
    def num_docs(self) -> int:
        return self.data.shape[0]


def pack_bytes(docs: list[str | bytes], max_len: int | None = None) -> PackedBytes:
    """Pack documents into a zero-padded uint8 matrix.

    The matrix width must strictly exceed every document's byte length:
    the byte tokenizer terminates a token at the first non-alnum byte,
    so a token running to the last byte of a document needs one trailing
    zero column to emit.  ``max_len`` (a pow2 bucket at jitted call
    sites) is validated against that; when omitted the width is
    ``max length + 1``.
    """
    raw = [d if isinstance(d, bytes) else d.encode("utf-8") for d in docs]
    lengths = np.array([len(b) for b in raw], dtype=np.int32)
    need = int(lengths.max(initial=0)) + 1
    L = int(max_len) if max_len is not None else max(need, 1)
    if L < need:
        raise ValueError(
            f"pack_bytes width {L} < max doc bytes + 1 ({need}); a token "
            "ending at the last column would be lost"
        )
    data = np.zeros((len(raw), L), dtype=np.uint8)
    for i, b in enumerate(raw):
        data[i, : len(b)] = np.frombuffer(b, dtype=np.uint8)
    return PackedBytes(data=data, lengths=lengths)


def _alnum_fold_np(data: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """(is_alnum, case-folded) masks for a uint8 byte array."""
    b = data.astype(np.uint32)
    upper = (b >= 65) & (b <= 90)
    alnum = upper | ((b >= 97) & (b <= 122)) | ((b >= 48) & (b <= 57))
    folded = np.where(upper, b + np.uint32(32), b).astype(np.uint32)
    return alnum, folded


def byte_token_ids_np(text: str | bytes, seed: int = 0x7045) -> np.ndarray:
    """Numpy oracle: token ids straight from UTF-8 bytes.

    Bit-identical to ``token_ids(tokenize(text, do_stem=False), seed)``
    for any unicode text (see the parity argument above).
    """
    raw = text if isinstance(text, bytes) else text.encode("utf-8")
    data = np.frombuffer(raw, dtype=np.uint8)
    alnum, folded = _alnum_fold_np(data)
    out = []
    h = FNV_OFFSET32
    prev = False
    with np.errstate(over="ignore"):
        for i in range(data.shape[0]):
            if alnum[i]:
                h0 = h if prev else FNV_OFFSET32
                h = np.uint32((h0 ^ folded[i]) * FNV_PRIME32)
            elif prev:
                out.append(h)
            prev = bool(alnum[i])
        if prev:
            out.append(h)
    ids = np.array(out, dtype=np.uint32)
    if len(ids):
        ids = hash_u32_np(ids, np.uint32(seed))
    return ids


def byte_token_hashes_np(
    data: np.ndarray, lengths: np.ndarray, seed: int = 0x7045
) -> tuple[np.ndarray, np.ndarray]:
    """Numpy oracle mirroring the byte kernel's per-position outputs.

    data: (D, LB) uint8; lengths: (D,) int32.  Returns ``(tok, ends)``
    of shape (D, LB): ``ends[d, i]`` is 1 iff a token ends at position i
    (exclusive), and ``tok[d, i]`` is its hashed id (0 elsewhere).
    Positions at or beyond ``lengths[d]`` are treated as separators, so
    garbage padding never leaks into tokens.
    """
    data = np.ascontiguousarray(data, dtype=np.uint8)
    D, LB = data.shape
    lengths = lengths.astype(np.int32)
    alnum, folded = _alnum_fold_np(data)
    pos = np.arange(LB, dtype=np.int32)[None, :]
    alnum = alnum & (pos < lengths[:, None])
    tok = np.zeros((D, LB), dtype=np.uint32)
    ends = np.zeros((D, LB), dtype=np.int32)
    with np.errstate(over="ignore"):
        h = np.full((D,), FNV_OFFSET32, dtype=np.uint32)
        prev = np.zeros((D,), dtype=bool)
        for i in range(LB):
            cur = alnum[:, i]
            h0 = np.where(prev, h, FNV_OFFSET32)
            h_new = np.where(
                cur, ((h0 ^ folded[:, i]) * FNV_PRIME32).astype(np.uint32), h
            ).astype(np.uint32)
            end = prev & ~cur
            tok[:, i] = np.where(end, hash_u32_np(h, np.uint32(seed)), 0)
            ends[:, i] = end
            h, prev = h_new, cur
    return tok, ends


# ---------------------------------------------------------------------------
# Padded-matrix n-gram hashing (device path)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PackedDocs:
    """A batch of documents as a padded token-id matrix."""

    tokens: np.ndarray  # (D, L) uint32
    lengths: np.ndarray  # (D,) int32

    @property
    def num_docs(self) -> int:
        return self.tokens.shape[0]


def pow2_bucket(n: int, floor: int = 256) -> int:
    """Smallest power-of-two >= max(n, floor).

    The shared shape-bucketing helper (DESIGN.md §9/§10): every call
    site that feeds varying-length batches into a jitted stage
    (``compute_arrays`` / ``compute_signatures`` / ``fused_ingest``)
    routes its padded length through this so the compile set stays
    bounded — lengths bucket to {floor, 2*floor, 4*floor, ...} instead
    of one compile per novel (D, L).  Signatures are invariant to the
    padding (validity masks come from real lengths), so bucketing is
    bit-transparent.  RPR003 (``python -m repro.analysis``) flags call
    sites that skip it.
    """
    b = max(1, int(floor))
    while b < n:
        b *= 2
    return b


def pack_documents(
    docs: list[list[str]], max_len: int | None = None
) -> PackedDocs:
    lengths = np.array([len(d) for d in docs], dtype=np.int32)
    L = int(max_len or max(1, lengths.max(initial=1)))
    toks = np.zeros((len(docs), L), dtype=np.uint32)
    for i, d in enumerate(docs):
        ids = token_ids(d[:L])
        toks[i, : len(ids)] = ids
        lengths[i] = min(lengths[i], L)
    return PackedDocs(tokens=toks, lengths=lengths)


def ngram_hashes(
    tokens: jnp.ndarray, lengths: jnp.ndarray, n: int = 8
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Rolling polynomial hash of every length-n token window.

    tokens: (D, L) uint32; lengths: (D,) int32.
    Returns (hashes (D, L) uint32, valid (D, L) bool).  Position i hashes
    tokens[i:i+n]; valid iff i + n <= length.  Documents shorter than n
    hash their full prefix (paper §12 saw notes with <4 words; we keep
    them rather than crash).

    h(i) = fmix32( sum_k base^(n-1-k) * t[i+k] )   (mod 2^32)

    Windows never wrap: tokens are zero-padded by n on the right so
    position i always reads tokens[i:i+n] with zero fill (matches the
    Pallas kernel's halo semantics).
    """
    tokens = tokens.astype(jnp.uint32)
    D, L = tokens.shape
    padded = jnp.pad(tokens, ((0, 0), (0, n)))
    acc = jnp.zeros((D, L), dtype=jnp.uint32)
    base = jnp.uint32(NGRAM_BASE)
    for k in range(n):
        acc = acc * base + jax.lax.dynamic_slice_in_dim(padded, k, L, axis=1)
    acc = fmix32(acc)
    pos = jnp.arange(L, dtype=jnp.int32)[None, :]
    lengths = lengths.astype(jnp.int32)[:, None]
    valid = pos + n <= lengths
    # Short docs: single shingle at position 0 covering the whole doc.
    short = (lengths < n) & (pos == 0) & (lengths > 0)
    valid = valid | short
    return acc, valid


def ngram_hashes_np(tokens: np.ndarray, lengths: np.ndarray, n: int = 8):
    """Numpy oracle mirroring :func:`ngram_hashes`."""
    tokens = tokens.astype(np.uint32)
    D, L = tokens.shape
    padded = np.pad(tokens, ((0, 0), (0, n)))
    acc = np.zeros((D, L), dtype=np.uint32)
    with np.errstate(over="ignore"):
        for k in range(n):
            acc = (acc * NGRAM_BASE + padded[:, k : k + L]).astype(np.uint32)
    acc = fmix32_np(acc)
    pos = np.arange(L, dtype=np.int32)[None, :]
    lengths = lengths.astype(np.int32)[:, None]
    valid = pos + n <= lengths
    short = (lengths < n) & (pos == 0) & (lengths > 0)
    return acc, valid | short
