"""Shingling: documents -> word n-gram hash sets (paper §2.2, §7.2).

Host side: text -> stemmed word tokens -> token ids (hash vocabulary).
Device side: padded token-id matrices -> rolling polynomial n-gram hashes.

The paper uses word 8-grams with stemming.  Stemming here is a light
suffix-stripping stemmer (Porter-lite) — adequate for equating inflected
forms, dependency-free.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.hashing import (
    NGRAM_BASE,
    fmix32,
    fmix32_np,
    hash_u32_np,
)

_WORD_RE = re.compile(r"[A-Za-z0-9]+")

_SUFFIXES = (
    "ational", "iveness", "fulness", "ousness",
    "ication", "izations", "ization",
    "ingly", "edly", "ings",
    "ing", "ies", "ied", "ely", "es", "ed", "ly", "s",
)


def stem(word: str) -> str:
    """Suffix-strip stemmer (keeps >=3 chars of stem)."""
    w = word.lower()
    for suf in _SUFFIXES:
        if w.endswith(suf) and len(w) - len(suf) >= 3:
            return w[: -len(suf)]
    return w


def tokenize(text: str, do_stem: bool = True) -> list[str]:
    toks = _WORD_RE.findall(text)
    if do_stem:
        return [stem(t) for t in toks]
    return [t.lower() for t in toks]


def token_ids(tokens: list[str], seed: int = 0x7045) -> np.ndarray:
    """Hash words to uint32 ids (hash vocabulary; no lookup table needed)."""
    out = np.empty(len(tokens), dtype=np.uint32)
    for i, t in enumerate(tokens):
        h = 2166136261
        for ch in t.encode("utf-8"):
            h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
        out[i] = h
    if len(tokens):
        out = hash_u32_np(out, np.uint32(seed))
    return out


def ngram_set(tokens: list[str], n: int = 8) -> set[tuple[str, ...]]:
    """Exact n-gram set (oracle for exact Jaccard)."""
    if len(tokens) < n:
        return {tuple(tokens)} if tokens else set()
    return {tuple(tokens[i : i + n]) for i in range(len(tokens) - n + 1)}


# ---------------------------------------------------------------------------
# Padded-matrix n-gram hashing (device path)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PackedDocs:
    """A batch of documents as a padded token-id matrix."""

    tokens: np.ndarray  # (D, L) uint32
    lengths: np.ndarray  # (D,) int32

    @property
    def num_docs(self) -> int:
        return self.tokens.shape[0]


def pow2_bucket(n: int, floor: int = 256) -> int:
    """Smallest power-of-two >= max(n, floor).

    The shared shape-bucketing helper (DESIGN.md §9/§10): every call
    site that feeds varying-length batches into a jitted stage
    (``compute_arrays`` / ``compute_signatures`` / ``fused_ingest``)
    routes its padded length through this so the compile set stays
    bounded — lengths bucket to {floor, 2*floor, 4*floor, ...} instead
    of one compile per novel (D, L).  Signatures are invariant to the
    padding (validity masks come from real lengths), so bucketing is
    bit-transparent.  RPR003 (``python -m repro.analysis``) flags call
    sites that skip it.
    """
    b = max(1, int(floor))
    while b < n:
        b *= 2
    return b


def pack_documents(
    docs: list[list[str]], max_len: int | None = None
) -> PackedDocs:
    lengths = np.array([len(d) for d in docs], dtype=np.int32)
    L = int(max_len or max(1, lengths.max(initial=1)))
    toks = np.zeros((len(docs), L), dtype=np.uint32)
    for i, d in enumerate(docs):
        ids = token_ids(d[:L])
        toks[i, : len(ids)] = ids
        lengths[i] = min(lengths[i], L)
    return PackedDocs(tokens=toks, lengths=lengths)


def ngram_hashes(
    tokens: jnp.ndarray, lengths: jnp.ndarray, n: int = 8
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Rolling polynomial hash of every length-n token window.

    tokens: (D, L) uint32; lengths: (D,) int32.
    Returns (hashes (D, L) uint32, valid (D, L) bool).  Position i hashes
    tokens[i:i+n]; valid iff i + n <= length.  Documents shorter than n
    hash their full prefix (paper §12 saw notes with <4 words; we keep
    them rather than crash).

    h(i) = fmix32( sum_k base^(n-1-k) * t[i+k] )   (mod 2^32)

    Windows never wrap: tokens are zero-padded by n on the right so
    position i always reads tokens[i:i+n] with zero fill (matches the
    Pallas kernel's halo semantics).
    """
    tokens = tokens.astype(jnp.uint32)
    D, L = tokens.shape
    padded = jnp.pad(tokens, ((0, 0), (0, n)))
    acc = jnp.zeros((D, L), dtype=jnp.uint32)
    base = jnp.uint32(NGRAM_BASE)
    for k in range(n):
        acc = acc * base + jax.lax.dynamic_slice_in_dim(padded, k, L, axis=1)
    acc = fmix32(acc)
    pos = jnp.arange(L, dtype=jnp.int32)[None, :]
    lengths = lengths.astype(jnp.int32)[:, None]
    valid = pos + n <= lengths
    # Short docs: single shingle at position 0 covering the whole doc.
    short = (lengths < n) & (pos == 0) & (lengths > 0)
    valid = valid | short
    return acc, valid


def ngram_hashes_np(tokens: np.ndarray, lengths: np.ndarray, n: int = 8):
    """Numpy oracle mirroring :func:`ngram_hashes`."""
    tokens = tokens.astype(np.uint32)
    D, L = tokens.shape
    padded = np.pad(tokens, ((0, 0), (0, n)))
    acc = np.zeros((D, L), dtype=np.uint32)
    with np.errstate(over="ignore"):
        for k in range(n):
            acc = (acc * NGRAM_BASE + padded[:, k : k + L]).astype(np.uint32)
    acc = fmix32_np(acc)
    pos = np.arange(L, dtype=np.int32)[None, :]
    lengths = lengths.astype(np.int32)[:, None]
    valid = pos + n <= lengths
    short = (lengths < n) & (pos == 0) & (lengths > 0)
    return acc, valid | short
