"""Streaming (out-of-core) dedup — the paper's §12 production mode.

The 10M-note corpus never fits memory: the paper streams notes, writes
band signatures to Cassandra (75 h), then reads band-major and clusters
(24 h).  This module reproduces that *two-phase* shape:

  Phase 1 (write): stream document chunks -> signatures (JAX/Pallas) ->
    band values -> a Design-2 band store (sqlite stand-in; on the pod
    this is the all_to_all reshard in core.dist_lsh).
  Phase 2 (read): band-major scan over the store via the staged engine
    (``candidates.StoreBandSource`` -> batched ``verify`` ->
    ``ThresholdUnionFind``; see ``core.engine``).

Incremental by construction: Phase 1 can be appended to (new notes
arrive), and Phase 2 can be re-run at different edge thresholds without
recomputing signatures — exactly the property the paper calls out
("the second step ... can be repeated for different edge thresholds").

Also implements the paper's §10 suggestion of a SECOND clustering round:
merge clusters whose representatives are highly similar (the disjoint-set
pass can over-partition; see Table 7's 56 diff-set-high pairs) — batched
through the same verifier layer (``engine.merge_cluster_rounds``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable

import numpy as np
import jax.numpy as jnp

from repro.core import lsh, minhash, shingle
from repro.core.bandstore import DiskSignatureVerifier, make_store
from repro.core.candidates import StoreBandSource
from repro.core.engine import merge_cluster_rounds as _merge_rounds
from repro.core.pipeline import DedupConfig
from repro.core.unionfind import ThresholdUnionFind
from repro.core.verify import BatchVerifier, SignatureVerifier, as_verifier


@dataclass
class StreamingDedup:
    """Two-phase streaming dedup over a Design-2 band store.

    ``doc_id_base`` assigns global doc ids starting at that base —
    resumed ingest of a chunked corpus (the ``doc_offsets`` convention
    of the sharded path) writes non-contiguous per-part id ranges into
    the store, which the Design-2 schema persists explicitly.
    """

    config: DedupConfig = field(default_factory=DedupConfig)
    store_path: str = ":memory:"
    chunk_docs: int = 512
    doc_id_base: int = 0

    def __post_init__(self):
        # The store tier comes from the config (DESIGN.md §12):
        # "memory" is the historical Design-2 blob store, "sqlite" the
        # key-level disk tier with Bloom-first lookups and
        # disk-resident signature rows.
        self.store = make_store(self.config.store, self.store_path,
                                part_size=self.chunk_docs,
                                num_bands=self.config.num_bands)
        self.seeds = minhash.default_seeds(self.config.num_hashes)
        self.n_docs = int(self.doc_id_base)
        self.n_ingested = 0
        self._sig_cache: dict[int, np.ndarray] = {}
        self._seeds_dev = None
        self._seeds_src = None

    def _device_seeds(self) -> jnp.ndarray:
        """Seeds as a cached device array (one upload per assignment,
        not one per flushed chunk)."""
        if self._seeds_dev is None or self._seeds_src is not self.seeds:
            self._seeds_dev = jnp.asarray(self.seeds)
            self._seeds_src = self.seeds
        return self._seeds_dev

    # -- phase 1 -----------------------------------------------------------

    def ingest(self, texts: Iterable[str], keep_signatures: bool = True):
        """Stream documents into the band store, chunk by chunk."""
        if self.config.byte_ingest:
            # Zero-copy phase 1: texts are buffered raw and shipped to
            # the device as UTF-8 bytes — no host tokenize pass.
            self.ingest_tokens(texts, keep_signatures)
            return
        self.ingest_tokens(
            (shingle.tokenize(t) for t in texts), keep_signatures)

    def ingest_tokens(self, token_lists: Iterable[list[str]],
                      keep_signatures: bool = True):
        """Ingest pre-tokenized documents (avoids re-tokenizing when the
        caller already has token lists, e.g. to build an exact verifier)."""
        buf: list[list[str]] = []
        for toks in token_lists:
            buf.append(toks)
            if len(buf) == self.chunk_docs:
                self._flush(buf, keep_signatures)
                buf = []
        if buf:
            self._flush(buf, keep_signatures)
        self.store.commit()

    def _flush(self, token_lists, keep_signatures):
        # Bucket the padded token dim to a power of two: full chunks
        # share one jit compile regardless of each chunk's longest
        # document, instead of recompiling the fused/staged stages per
        # novel (D, L) (signatures are padding-invariant).
        if self.config.byte_ingest:
            # Byte configs buffer raw texts (see ``ingest``): pack their
            # UTF-8 bytes and run the whole chain on device.
            from repro.kernels.byte_shingle import bytes_to_bands

            pad_len = shingle.pow2_bucket(
                max((len(t if isinstance(t, bytes) else
                         t.encode("utf-8")) for t in token_lists),
                    default=0) + 1)
            packed_b = shingle.pack_bytes(token_lists, pad_len)
            sig_j, bands_j, _ = bytes_to_bands(
                jnp.asarray(packed_b.data), jnp.asarray(packed_b.lengths),
                self._device_seeds(), n=self.config.ngram,
                r=self.config.rows_per_band)
            self._store_chunk(np.asarray(sig_j), np.asarray(bands_j),
                              len(token_lists), keep_signatures)
            return
        pad_len = shingle.pow2_bucket(
            max((len(t) for t in token_lists), default=1))
        packed = shingle.pack_documents(token_lists, pad_len)
        if self.config.fused_ingest:
            # Phase 1 on the fused device pass: signatures AND band
            # values come back from one Pallas dispatch (bit-identical
            # to the staged chain below).
            from repro.kernels.fused_ingest import fused_ingest

            sig_j, bands_j, _ = fused_ingest(
                jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
                self._device_seeds(), n=self.config.ngram,
                r=self.config.rows_per_band)
            sig, bands = np.asarray(sig_j), np.asarray(bands_j)
        else:
            ng, valid = shingle.ngram_hashes(
                jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
                n=self.config.ngram)
            sig = np.asarray(minhash.signatures(ng, valid,
                                                self._device_seeds()))
            bands = np.asarray(lsh.band_values(
                jnp.asarray(sig), self.config.rows_per_band))
        self._store_chunk(sig, bands, len(token_lists), keep_signatures)

    def _store_chunk(self, sig, bands, n, keep_signatures):
        """Write one flushed chunk's band rows (+ signature rows) to the
        store.  Signature routing is the tier split: stores with
        disk-resident signature rows take them directly (the
        ``DiskSignatureVerifier`` path); the memory tier keeps the
        host-side phase-1 cache."""
        for i in range(n):
            self.store.insert_document(self.n_docs + i, bands[i])
        if keep_signatures:
            if hasattr(self.store, "put_signatures"):
                self.store.put_signatures(
                    np.arange(self.n_docs, self.n_docs + n), sig[:n])
            else:
                for i in range(n):
                    self._sig_cache[self.n_docs + i] = sig[i]
        self.n_docs += n
        self.n_ingested += n

    # -- phase 2 -----------------------------------------------------------

    def candidate_source(self) -> StoreBandSource:
        """The staged-engine candidate source over the band store."""
        return StoreBandSource(self.store, self.config.num_bands,
                               self.n_docs)

    def default_verifier(self) -> BatchVerifier:
        """Signature-agreement verifier over the phase-1 rows.

        Disk-tier stores hold their signature rows on disk, so the
        verifier gathers rows through the store's LRU row cache
        (``bandstore.DiskSignatureVerifier`` — same estimate expression,
        bit-identical sims).  The memory tier builds the full matrix
        from the host cache, indexed by global doc id (rows below
        ``doc_id_base`` or inside a resumed-ingest gap stay zero — those
        ids have no band-store rows, so they can never reach the
        verifier as candidates).
        """
        if hasattr(self.store, "put_signatures"):
            if self.store.n_signatures() < self.n_ingested:
                raise ValueError(
                    f"store holds {self.store.n_signatures()} of "
                    f"{self.n_ingested} ingested docs' signature rows — "
                    "ingest with keep_signatures=True or pass an "
                    "explicit similarity_fn / verifier to cluster()")
            return DiskSignatureVerifier(self.store,
                                         self.config.num_hashes)
        if len(self._sig_cache) < self.n_ingested:
            raise ValueError(
                f"signature cache holds {len(self._sig_cache)} of "
                f"{self.n_ingested} ingested docs — ingest with "
                "keep_signatures=True or pass an explicit "
                "similarity_fn / verifier to cluster()")
        sig = np.zeros((self.n_docs, self.config.num_hashes),
                       dtype=np.uint32)
        for i, row in self._sig_cache.items():
            sig[i] = row
        return SignatureVerifier(
            sig, backend=self.config.resolved_backend())

    def cluster(self, edge_threshold: float | None = None,
                tree_threshold: float | None = None,
                similarity_fn: Callable[[int, int], float]
                | BatchVerifier | None = None):
        """Band-major read -> candidates -> batched verify -> union-find.

        A thin adapter over ``session.DedupSession.over_store``: the
        phase-2 scan runs through a session accumulator (one union-find
        + verified-sim cache), which is the same machinery incremental
        multi-chunk ingest uses — ``cluster`` is simply the one-shot
        snapshot of it.  ``similarity_fn`` may be a
        ``verify.BatchVerifier`` or a scalar callable; it defaults to
        batched signature agreement over the phase-1 cache.
        Re-runnable at different thresholds without re-hashing (paper
        §12).
        """
        from dataclasses import replace

        from repro.core.session import DedupSession

        cfg = self.config
        edge_t = edge_threshold if edge_threshold is not None else \
            cfg.edge_threshold
        tree_t = tree_threshold if tree_threshold is not None else \
            cfg.tree_threshold
        verifier = (None if similarity_fn is None
                    else as_verifier(similarity_fn))
        sess = DedupSession.over_store(
            self, config=replace(cfg, edge_threshold=edge_t,
                                 tree_threshold=tree_t),
            verifier=verifier)
        snap = sess.snapshot()
        return sess.uf, {"pairs_evaluated": snap.stats.pairs_evaluated,
                         "pairs_excluded": snap.stats.pairs_excluded,
                         "verify_batches": snap.stats.verify_batches,
                         "verify_seconds": snap.stats.verify_seconds}


def merge_cluster_rounds(
    uf: ThresholdUnionFind,
    similarity_fn: Callable[[int, int], float] | BatchVerifier,
    edge_threshold: float,
) -> int:
    """Paper §10's second clustering round (see
    ``engine.merge_cluster_rounds``): root-pair similarities are computed
    in one batched dispatch instead of an O(roots^2) scalar loop.
    Returns #merges performed."""
    return _merge_rounds(uf, similarity_fn, edge_threshold)
