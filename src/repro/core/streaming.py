"""Streaming (out-of-core) dedup — the paper's §12 production mode.

The 10M-note corpus never fits memory: the paper streams notes, writes
band signatures to Cassandra (75 h), then reads band-major and clusters
(24 h).  This module reproduces that *two-phase* shape:

  Phase 1 (write): stream document chunks -> signatures (JAX/Pallas) ->
    band values -> a Design-2 band store (sqlite stand-in; on the pod
    this is the all_to_all reshard in core.dist_lsh).
  Phase 2 (read): band-major scan over the store -> candidate pairs ->
    lazy exact/estimated verification -> ThresholdUnionFind clusters.

Incremental by construction: Phase 1 can be appended to (new notes
arrive), and Phase 2 can be re-run at different edge thresholds without
recomputing signatures — exactly the property the paper calls out
("the second step ... can be repeated for different edge thresholds").

Also implements the paper's §10 suggestion of a SECOND clustering round:
merge clusters whose representatives are highly similar (the disjoint-set
pass can over-partition; see Table 7's 56 diff-set-high pairs).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable, Iterator

import numpy as np
import jax.numpy as jnp

from repro.core import jaccard as jac
from repro.core import lsh, minhash, shingle
from repro.core.bandstore import Design2Store, candidate_pairs_from_store
from repro.core.pipeline import DedupConfig
from repro.core.unionfind import ThresholdUnionFind


@dataclass
class StreamingDedup:
    """Two-phase streaming dedup over a Design-2 band store."""

    config: DedupConfig = field(default_factory=DedupConfig)
    store_path: str = ":memory:"
    chunk_docs: int = 512

    def __post_init__(self):
        self.store = Design2Store(self.store_path,
                                  part_size=self.chunk_docs)
        self.seeds = minhash.default_seeds(self.config.num_hashes)
        self.n_docs = 0
        self._sig_cache: dict[int, np.ndarray] = {}

    # -- phase 1 -----------------------------------------------------------

    def ingest(self, texts: Iterable[str], keep_signatures: bool = True):
        """Stream documents into the band store, chunk by chunk."""
        buf: list[list[str]] = []
        for t in texts:
            buf.append(shingle.tokenize(t))
            if len(buf) == self.chunk_docs:
                self._flush(buf, keep_signatures)
                buf = []
        if buf:
            self._flush(buf, keep_signatures)
        self.store.commit()

    def _flush(self, token_lists, keep_signatures):
        packed = shingle.pack_documents(token_lists)
        ng, valid = shingle.ngram_hashes(
            jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
            n=self.config.ngram)
        sig = np.asarray(minhash.signatures(ng, valid,
                                            jnp.asarray(self.seeds)))
        bands = np.asarray(lsh.band_values(
            jnp.asarray(sig), self.config.rows_per_band))
        for i in range(len(token_lists)):
            doc_id = self.n_docs + i
            self.store.insert_document(doc_id, bands[i])
            if keep_signatures:
                self._sig_cache[doc_id] = sig[i]
        self.n_docs += len(token_lists)

    # -- phase 2 -----------------------------------------------------------

    def cluster(self, edge_threshold: float | None = None,
                tree_threshold: float | None = None,
                similarity_fn: Callable[[int, int], float] | None = None):
        """Band-major read -> candidates -> verify -> union-find.

        ``similarity_fn`` defaults to signature agreement (phase-1 cache);
        pass an exact-Jaccard closure for oracle verification.
        Re-runnable at different thresholds without re-hashing (paper §12).
        """
        cfg = self.config
        edge_t = edge_threshold if edge_threshold is not None else \
            cfg.edge_threshold
        tree_t = tree_threshold if tree_threshold is not None else \
            cfg.tree_threshold
        if similarity_fn is None:
            def similarity_fn(a, b):
                return float(
                    (self._sig_cache[a] == self._sig_cache[b]).mean())

        uf = ThresholdUnionFind(self.n_docs, tree_t)
        evaluated: dict[tuple, float] = {}
        n_excluded = 0
        for j in range(cfg.num_bands):
            docs, vals = self.store.read_band(j)
            if len(docs) < 2:
                continue
            order = np.lexsort((vals[:, 1], vals[:, 0]))
            sv, sd = vals[order], docs[order].astype(np.int64)
            heads = np.ones(len(sd), dtype=bool)
            heads[1:] = np.any(sv[1:] != sv[:-1], axis=-1)
            starts = np.flatnonzero(heads)
            ends = np.append(starts[1:], len(sd))
            for s, e in zip(starts, ends):
                if e - s < 2:
                    continue
                roots = np.unique(
                    [uf.find(int(d)) for d in sd[s:e]])
                if len(roots) < 2:
                    n_excluded += (e - s) * (e - s - 1) // 2
                    continue
                for ii in range(len(roots)):
                    for jj in range(ii + 1, len(roots)):
                        key = (int(roots[ii]), int(roots[jj]))
                        if key in evaluated:
                            n_excluded += 1
                            continue
                        sim = similarity_fn(*key)
                        evaluated[key] = sim
                        if sim > edge_t:
                            uf.union(*key, sim)
        return uf, {"pairs_evaluated": len(evaluated),
                    "pairs_excluded": n_excluded}


def merge_cluster_rounds(
    uf: ThresholdUnionFind,
    similarity_fn: Callable[[int, int], float],
    edge_threshold: float,
) -> int:
    """Paper §10's second clustering round: compare cluster REPRESENTATIVES
    and merge clusters whose reps are highly similar (fixes the
    over-partitioning the disjoint-set pass can produce — Table 7's 56
    'diff-set high-similarity' pairs).  Returns #merges performed.
    """
    roots = sorted({uf.find(i) for i in range(len(uf.parent))})
    merges = 0
    for i in range(len(roots)):
        for j in range(i + 1, len(roots)):
            a, b = uf.find(roots[i]), uf.find(roots[j])
            if a == b:
                continue
            sim = similarity_fn(a, b)
            if sim > edge_threshold and uf.union(a, b, sim):
                merges += 1
    return merges
