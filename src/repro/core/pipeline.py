"""End-to-end deduplication pipeline (the paper, assembled).

text docs -> tokenize/stem -> pack -> n-gram hashes -> minhash signatures
-> band matrix -> candidate pairs -> verified similarities -> threshold
union-find clusters -> keep-list (one representative per cluster).

Execution styles, all thin drivers over the staged engine
(``CandidateSource -> BatchVerifier -> ThresholdUnionFind``, see
``core.engine``):

* ``DedupPipeline.run`` — host-orchestrated, paper-faithful; candidate
  generation via ``candidates.BandMatrixSource``, verification via the
  batched ``verify`` layer (exact Jaccard or signature estimate on a
  selectable ``numpy`` / ``jnp`` / ``pallas`` backend).
* ``StreamingDedup`` in ``core.streaming`` — out-of-core two-phase mode
  over a band store (``candidates.StoreBandSource``), same engine.
* ``dedup_step`` in ``core.dist_lsh`` — sharded step for the production
  mesh: on-device candidate shuffle + prefix prescreen, then the host
  merge (``dist_lsh.cluster_step_output``) drives this same engine.
"""
from __future__ import annotations

import os
import time
from dataclasses import dataclass, field

import numpy as np
import jax.numpy as jnp

from repro.core import lsh
from repro.core import minhash
from repro.core import shingle
from repro.core.engine import ClusterStats
from repro.core.unionfind import ThresholdUnionFind
from repro.core.verify import ExactJaccardVerifier, SignatureVerifier


@dataclass(frozen=True)
class DedupConfig:
    """Paper defaults: n=8, M=100, r=2 (=> b=50), thresholds from §9-10."""

    ngram: int = 8
    num_hashes: int = 100
    rows_per_band: int = 2
    edge_threshold: float = 0.75
    tree_threshold: float = 0.40
    use_disjoint_sets: bool = True
    exact_verification: bool = True  # exact Jaccard vs signature estimate
    use_pallas: bool = False  # route signature computation through kernels
    fused_ingest: bool = False  # one-pass Pallas shingle->minhash->fold
    byte_ingest: bool = False  # device bytes->bands (no-stem, zero-copy)
    verify_backend: str = "auto"  # estimate mode: numpy | jnp | pallas
    verify_batch: str = "run"  # engine batch granularity: run | band
    seed: int = 0x5EED
    # Band-store tier (core.bandstore, DESIGN.md §12): "memory" keeps
    # the historical in-RAM layout; "sqlite" puts band rows + signature
    # rows on disk behind Bloom-first lookups.  Identical clusters and
    # bit-identical per-edge sims either way (pinned in tests); the env
    # default lets the CI store matrix flip the whole suite per cell.
    store: str = field(default_factory=lambda: os.environ.get(
        "REPRO_STORE_BACKEND", "memory"))

    def __post_init__(self):
        if self.byte_ingest and self.exact_verification:
            raise ValueError(
                "byte_ingest never materializes host token lists, so "
                "exact Jaccard verification is impossible; set "
                "exact_verification=False (signature-estimate mode)")
        if self.store not in ("memory", "sqlite"):
            raise ValueError(
                f"unknown store backend {self.store!r}; "
                "one of ('memory', 'sqlite')")

    @property
    def num_bands(self) -> int:
        return self.num_hashes // self.rows_per_band

    def resolved_backend(self) -> str:
        if self.verify_backend != "auto":
            return self.verify_backend
        return "pallas" if self.use_pallas else "numpy"


@dataclass
class DedupResult:
    labels: np.ndarray  # (D,) cluster root per doc
    keep_mask: np.ndarray  # (D,) bool — True for cluster representatives
    pairs: list  # evaluated (a, b, sim)
    stats: ClusterStats
    uf: ThresholdUnionFind
    signatures: np.ndarray  # (D, M) uint32
    bands: np.ndarray  # (D, b, 2) uint32
    timings: dict = field(default_factory=dict)

    @property
    def num_clusters(self) -> int:
        """Number of duplicate clusters, i.e. components of size >= 2."""
        _, counts = np.unique(self.labels, return_counts=True)
        return int((counts >= 2).sum())

    @property
    def num_duplicates_removed(self) -> int:
        return int((~self.keep_mask).sum())


class DedupPipeline:
    def __init__(self, config: DedupConfig | None = None):
        self.config = config or DedupConfig()
        self.seeds = minhash.default_seeds(self.config.num_hashes)
        self._seeds_dev = None
        self._seeds_src = None
        # Per-stage wall times of the LAST compute call (cumulative
        # ``_s`` keys); chunked ingest (``core.session``) sums these
        # across chunks, so the kops and fused paths time their device
        # work (block-until-transfer) the same way the numpy path does.
        self.stage_timings: dict[str, float] = {}

    def device_seeds(self) -> jnp.ndarray:
        """The seed vector as a cached device array.

        Uploaded once per ``seeds`` assignment instead of re-running
        ``jnp.asarray`` on every chunk (the old per-chunk host->device
        copy was pure overhead in multi-step sessions).
        """
        if self._seeds_dev is None or self._seeds_src is not self.seeds:
            self._seeds_dev = jnp.asarray(self.seeds)
            self._seeds_src = self.seeds
        return self._seeds_dev

    # -- stages ------------------------------------------------------------

    def tokenize(self, texts: list[str]) -> list[list[str]]:
        return [shingle.tokenize(t) for t in texts]

    def compute_signatures(self, token_lists: list[list[str]],
                           pad_len: int | None = None) -> np.ndarray:
        t0 = time.perf_counter()
        packed = shingle.pack_documents(token_lists, pad_len)
        if self.config.use_pallas or self.config.fused_ingest:
            from repro.kernels import ops as kops

            if self.config.fused_ingest:
                sig, _, _ = kops.fused_ingest(
                    jnp.asarray(packed.tokens),
                    jnp.asarray(packed.lengths),
                    self.device_seeds(),
                    n=self.config.ngram,
                    r=self.config.rows_per_band,
                )
            else:
                ng, valid = kops.ngram_hashes(
                    jnp.asarray(packed.tokens),
                    jnp.asarray(packed.lengths),
                    n=self.config.ngram,
                )
                sig = kops.minhash_signatures(ng, valid,
                                              self.device_seeds())
        else:
            ng, valid = shingle.ngram_hashes(
                jnp.asarray(packed.tokens),
                jnp.asarray(packed.lengths),
                n=self.config.ngram,
            )
            sig = minhash.signatures(ng, valid, self.device_seeds())
        # np.asarray blocks on the device work, so the kops/fused paths
        # record the same wall semantics as the numpy path.
        sig = np.asarray(sig)
        self.stage_timings["signature_s"] = time.perf_counter() - t0
        return sig

    def compute_bands(self, sig: np.ndarray) -> np.ndarray:
        t0 = time.perf_counter()
        bands = np.asarray(
            lsh.band_values(jnp.asarray(sig), self.config.rows_per_band)
        )
        self.stage_timings["bands_s"] = time.perf_counter() - t0
        return bands

    def compute_arrays(
        self, token_lists: list[list[str]],
        pad_len: int | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """One chunk's (signatures, band values) — the ingest hot path.

        With ``config.fused_ingest`` both arrays come out of ONE
        device-resident Pallas pass (no intermediate n-gram/signature
        HBM round-trip and no separate band dispatch); otherwise the
        staged ``compute_signatures`` -> ``compute_bands`` chain runs.
        Outputs are bit-identical either way.

        ``pad_len`` (>= the longest document) widens the packed token
        matrix; signatures are invariant to padding (the validity mask
        comes from real lengths), so callers with many small batches —
        the query service — bucket shapes to bound jit recompiles.

        Named ``compute_*`` (not ``ingest_*``) per the public naming
        scheme (``repro.core`` docstring): this is a pure stage
        computation — only ``ingest*`` entry points add documents to
        long-lived dedup state.
        """
        if not self.config.fused_ingest:
            sig = self.compute_signatures(token_lists, pad_len)
            return sig, self.compute_bands(sig)
        from repro.kernels import ops as kops

        t0 = time.perf_counter()
        packed = shingle.pack_documents(token_lists, pad_len)
        sig, bands, _ = kops.fused_ingest(
            jnp.asarray(packed.tokens),
            jnp.asarray(packed.lengths),
            self.device_seeds(),
            n=self.config.ngram,
            r=self.config.rows_per_band,
        )
        sig, bands = np.asarray(sig), np.asarray(bands)
        self.stage_timings["signature_s"] = time.perf_counter() - t0
        self.stage_timings["bands_s"] = 0.0  # fused into the one pass
        return sig, bands

    def compute_arrays_bytes(
        self, docs: list[str | bytes],
        pad_len: int | None = None,
    ) -> tuple[np.ndarray, np.ndarray]:
        """One chunk's (signatures, band values) straight from UTF-8 bytes.

        The ``byte_ingest`` hot path: tokenization never happens on the
        host — raw bytes are the only host->device transfer (uint8, ~4x
        less traffic than the padded int32 token matrix) and the
        ``bytes_to_bands`` kernel chain produces both arrays in one
        device-resident sweep.  Bit-identical to
        ``compute_arrays(tokenize(text, do_stem=False))``.

        ``pad_len`` buckets the byte-matrix width (must exceed the
        longest document's byte length; see ``shingle.pack_bytes``).
        """
        from repro.kernels import ops as kops

        t0 = time.perf_counter()
        packed = shingle.pack_bytes(docs, pad_len)
        sig, bands, _ = kops.bytes_to_bands(
            jnp.asarray(packed.data),
            jnp.asarray(packed.lengths),
            self.device_seeds(),
            n=self.config.ngram,
            r=self.config.rows_per_band,
        )
        sig, bands = np.asarray(sig), np.asarray(bands)
        self.stage_timings["signature_s"] = time.perf_counter() - t0
        self.stage_timings["bands_s"] = 0.0  # fused into the one pass
        return sig, bands

    def ingest_arrays(
        self, token_lists: list[list[str]]
    ) -> tuple[np.ndarray, np.ndarray]:
        """Deprecated spelling of :meth:`compute_arrays`.

        The old name collided with the session-layer ``ingest*`` verbs,
        which add documents to long-lived dedup state; this method never
        did (it is a pure stage computation).
        """
        import warnings

        warnings.warn(
            "DedupPipeline.ingest_arrays is deprecated; use "
            "compute_arrays (same signature, same outputs). 'ingest*' "
            "names are reserved for entry points that add documents to "
            "long-lived dedup state.",
            DeprecationWarning, stacklevel=2)
        return self.compute_arrays(token_lists)

    def make_verifier(self, token_lists: list[list[str]],
                      sig: np.ndarray):
        """The batched pair verifier for this config (``verify`` layer)."""
        cfg = self.config
        if cfg.exact_verification:
            return ExactJaccardVerifier.from_token_lists(
                token_lists, cfg.ngram)
        return SignatureVerifier(sig, backend=cfg.resolved_backend())

    # -- end to end ----------------------------------------------------------

    def run(self, texts: list[str]) -> DedupResult:
        """One-shot host dedup — a single-chunk ``DedupSession`` ingest.

        The session layer (``core.session``) owns the engine wiring;
        this adapter keeps the paper-shaped stage timings and the
        ``DedupResult`` contract (including the explicit verifier
        choice of ``make_verifier``).
        """
        from repro.core.session import DedupSession

        cfg = self.config
        timings = {}
        if cfg.byte_ingest:
            # Zero-copy path: no host tokenize; the engine only needs
            # per-doc placeholders (estimate mode never reads tokens).
            token_lists = [[] for _ in texts]
            timings["tokenize_s"] = 0.0
            pad_len = shingle.pow2_bucket(
                max((len(t.encode("utf-8")) for t in texts), default=0) + 1)
            sig, bands = self.compute_arrays_bytes(texts, pad_len)
        else:
            t0 = time.perf_counter()
            token_lists = self.tokenize(texts)
            timings["tokenize_s"] = time.perf_counter() - t0

            sig, bands = self.compute_arrays(token_lists)
        timings["signatures_s"] = self.stage_timings["signature_s"]
        timings["bands_s"] = self.stage_timings["bands_s"]

        t0 = time.perf_counter()
        verifier = self.make_verifier(token_lists, sig)
        timings["verifier_build_s"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        sess = DedupSession(cfg, backend="host", verifier=verifier)
        snap = sess._merge_precomputed(token_lists, sig, bands)
        uf, stats, pairs = sess.uf, snap.stats, snap.pairs
        timings["cluster_s"] = time.perf_counter() - t0
        timings["verify_s"] = stats.verify_seconds

        labels = snap.labels
        keep = np.zeros(len(texts), dtype=bool)
        seen: set[int] = set()
        for i, r in enumerate(labels):
            if int(r) not in seen:
                seen.add(int(r))
                keep[i] = True
        return DedupResult(
            labels=labels,
            keep_mask=keep,
            pairs=pairs,
            stats=stats,
            uf=uf,
            signatures=sig,
            bands=bands,
            timings=timings,
        )
