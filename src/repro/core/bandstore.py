"""Pluggable out-of-core band-matrix storage (paper §5, LSHBloom-scale).

The paper uses Apache Cassandra; this container has no Cassandra, so the
designs are realized over sqlite3 (stdlib) with the exact same schemas and
access patterns — the *comparative* behaviour (Design 2's fewer, larger
writes winning on write volume; band-major reads) is what the paper
measures, and that transfers.

Design 1: one row per band-matrix cell      (band_id, doc_id, value)
Design 2: one row per (band, doc-part) slice (band_id, part_id, values[])

PR 10 abstracts the store behind ``BandStoreBackend`` so sessions can
pick a tier (``DedupConfig.store``):

* ``"memory"`` — the historical layout: ``Design2Store`` blobs for the
  streaming phase-1 store, an in-memory ``session.BandIndex`` dict for
  the cross-step index.  Fastest, bounded by one host's RAM.
* ``"sqlite"`` — ``SqliteBandStore``, a key-level disk tier with
  **Bloom-first lookups** (DESIGN.md §12): PR 5's ``BandBloomFilter``
  promoted from eviction fallback to the *primary* index — one filter
  per band holds every key ever inserted, so a band probe touches disk
  only on filter hits (no false negatives: a miss is answered from
  memory in O(hashes)).  Signature rows live disk-resident too
  (``DiskSignatureVerifier``), gathered through a small LRU row cache.

Both tiers produce identical clusters and bit-identical per-edge sims
(``tests/test_bandstore_backends.py``); the disk tier trades probe
latency for an index that no longer has to fit in RAM.

On the TPU pod these map to band-major resharding vs doc-major band_parts
(DESIGN.md §2); this module is the literal single-machine reproduction.
"""
from __future__ import annotations

import sqlite3
from collections import OrderedDict
from typing import Iterator

import numpy as np

from repro.core.retention import BandBloomFilter
from repro.core.verify import BatchVerifier

STORE_KINDS = ("memory", "sqlite")


class BandStoreBackend:
    """Interface every band-store tier implements (DESIGN.md §12).

    Write path: ``put_band_rows`` / ``insert_document`` + ``commit``.
    Scan path: ``read_band`` (the paper's "select * where band_id = j")
    and ``iter_band_runs`` (sorted equal-value runs, the staged engine's
    candidate structure).  Probe path: ``probe_keys`` — a PURE read
    (never mutates store state; RPR002 holds it to that) mapping query
    band values to retained doc ids.  Retention: ``compact`` rewrites
    evicted docs' band rows onto their cluster roots so the store stops
    growing with evicted history (the ROADMAP "retention completeness"
    fix; clustering-neutral because the engine path-compresses every
    candidate to union-find roots before verification).
    """

    kind = "abstract"
    conn: sqlite3.Connection

    # -- write path --------------------------------------------------------

    def insert_document(self, doc_id: int, band_sig: np.ndarray) -> None:
        raise NotImplementedError

    def put_band_rows(self, doc_ids, bands: np.ndarray) -> None:
        """Insert a chunk: ``doc_ids`` (D,) int, ``bands`` (D, b, 2)."""
        bands = np.asarray(bands)
        for i, doc in enumerate(doc_ids):
            self.insert_document(int(doc), bands[i])

    def commit(self) -> None:
        raise NotImplementedError

    # -- scan path ---------------------------------------------------------

    def read_band(self, band_id: int):
        raise NotImplementedError

    def iter_band_runs(self, num_bands: int) -> Iterator:
        """Per-band sorted equal-value runs (``candidates.BandRuns``)."""
        from repro.core.candidates import make_band_runs

        for j in range(int(num_bands)):
            docs, vals = self.read_band(j)
            yield make_band_runs(j, vals, docs)

    # -- probe path (pure) -------------------------------------------------

    def probe_keys(self, bands: np.ndarray):
        """(Q, b, 2) query bands -> (per-query sorted unique int64 doc-id
        arrays, per-query compacted-key filter-only hit counts).

        Pure read: implementations must not mutate any store state (no
        LRU refresh, no counter bumps — returned values carry all the
        accounting), so a published ``SessionView`` can delegate its
        probe here without breaking the RPR002 purity contract.

        The generic implementation walks ``read_band`` with a host dict
        per band — the in-memory reference the Bloom-first tier is
        benchmarked against (``benchmarks/designs.py``).
        """
        bands = np.asarray(bands)
        q = len(bands)
        cands: list[set[int]] = [set() for _ in range(q)]
        for j in range(bands.shape[1]):
            docs, vals = self.read_band(j)
            lookup: dict[tuple[int, int], list[int]] = {}
            for d, (hi, lo) in zip(docs.tolist(), vals.tolist()):
                lookup.setdefault((int(hi), int(lo)), []).append(int(d))
            col = bands[:, j, :]
            for i in range(q):
                olds = lookup.get((int(col[i, 0]), int(col[i, 1])))
                if olds is not None:
                    cands[i].update(olds)
        return ([np.array(sorted(s), dtype=np.int64) for s in cands],
                [0] * q)

    # -- retention ---------------------------------------------------------

    def compact(self, doc_ids, root_of) -> None:
        raise NotImplementedError

    def n_entries(self) -> int:
        """Total (band, value, doc) entries currently stored."""
        raise NotImplementedError

    # -- accounting --------------------------------------------------------

    def file_size_bytes(self) -> int:
        """Current database size (page_count * page_size; works for
        ``:memory:`` connections too — the soak disk-plateau gate)."""
        (pages,) = self.conn.execute("PRAGMA page_count").fetchone()
        (size,) = self.conn.execute("PRAGMA page_size").fetchone()
        return int(pages) * int(size)


def make_store(kind: str, path: str = ":memory:", *,
               part_size: int = 50, num_bands: int = 50):
    """Factory behind ``DedupConfig.store`` (``"memory" | "sqlite"``)."""
    if kind == "memory":
        return Design2Store(path, part_size=part_size)
    if kind == "sqlite":
        return SqliteBandStore(path, num_bands=num_bands)
    raise ValueError(f"unknown store kind {kind!r}; one of {STORE_KINDS}")


class Design1Store(BandStoreBackend):
    """One database row per band-matrix cell."""

    kind = "design1"

    def __init__(self, path: str = ":memory:"):
        self.conn = sqlite3.connect(path)
        self.conn.execute(
            "CREATE TABLE IF NOT EXISTS band1 ("
            " band_id INTEGER, doc_id INTEGER,"
            " hi INTEGER, lo INTEGER,"
            " PRIMARY KEY (band_id, doc_id))")
        self.n_writes = 0
        self.write_bytes = 0

    def insert_document(self, doc_id: int, band_sig: np.ndarray):
        """band_sig: (b, 2) uint32 — the doc's band-matrix column."""
        rows = [(int(j), int(doc_id), int(band_sig[j, 0]),
                 int(band_sig[j, 1])) for j in range(len(band_sig))]
        self.conn.executemany(
            "INSERT OR REPLACE INTO band1 VALUES (?,?,?,?)", rows)
        self.n_writes += len(rows)
        self.write_bytes += len(rows) * 16   # 32+32+64 bits (paper §8)

    def read_band(self, band_id: int):
        """'select * from table where band_id = id' (paper §5.2.1)."""
        cur = self.conn.execute(
            "SELECT doc_id, hi, lo FROM band1 WHERE band_id=?",
            (int(band_id),))
        rows = cur.fetchall()
        if not rows:
            return (np.zeros(0, np.int64), np.zeros((0, 2), np.uint32))
        arr = np.array(rows, dtype=np.int64)
        return arr[:, 0], arr[:, 1:].astype(np.uint32)

    def n_entries(self) -> int:
        (n,) = self.conn.execute("SELECT COUNT(*) FROM band1").fetchone()
        return int(n)

    def commit(self):
        self.conn.commit()


# Design-2 blob schema v2: explicit per-part doc ids travel inside the
# blob (header magic + version + count, then int64 doc ids, then uint32
# band values).  v1 blobs were the raw value array alone and doc ids
# were *reconstructed* as arange(doc0, doc0 + d) — silently wrong for
# any non-contiguous ingest (ragged chunks, resumed ingest with
# doc_offsets-style global ids).
_BLOB_MAGIC = np.uint32(0x42443253)   # "BD2S"
_BLOB_VERSION = np.uint32(2)


def _encode_part_v2(doc_ids: np.ndarray, vals: np.ndarray) -> bytes:
    """Pack one (band, part) slice: header + int64 ids + uint32 values."""
    d = len(doc_ids)
    header = np.array([_BLOB_MAGIC, _BLOB_VERSION, d], dtype=np.uint32)
    return (header.tobytes()
            + np.ascontiguousarray(doc_ids, dtype=np.int64).tobytes()
            + np.ascontiguousarray(vals, dtype=np.uint32).tobytes())


def _decode_part(blob: bytes, doc0: int):
    """Decode a part blob, accepting both schema versions.

    v2 is self-describing (magic/version/count header); anything else is
    a v1 raw value array whose doc ids are reconstructed from ``doc0``
    (the legacy contiguous assumption — kept only so pre-existing stores
    stay readable).
    """
    if len(blob) >= 12:
        header = np.frombuffer(blob[:12], dtype=np.uint32)
        d = int(header[2])
        if (header[0] == _BLOB_MAGIC and header[1] == _BLOB_VERSION
                and len(blob) == 12 + d * 8 + d * 8):
            docs = np.frombuffer(blob[12 : 12 + d * 8], dtype=np.int64)
            vals = np.frombuffer(blob[12 + d * 8 :],
                                 dtype=np.uint32).reshape(d, 2)
            return docs, vals
    vals = np.frombuffer(blob, dtype=np.uint32).reshape(-1, 2)
    return np.arange(doc0, doc0 + len(vals), dtype=np.int64), vals


class Design2Store(BandStoreBackend):
    """One database row per (band, band_part) slice of d documents."""

    kind = "memory"

    def __init__(self, path: str = ":memory:", part_size: int = 50):
        self.conn = sqlite3.connect(path)
        self.conn.execute(
            "CREATE TABLE IF NOT EXISTS band2 ("
            " band_id INTEGER, part_id INTEGER, doc0 INTEGER,"
            " vals BLOB, PRIMARY KEY (band_id, part_id))")
        self.part_size = part_size
        self.n_writes = 0
        self.write_bytes = 0
        self._buffer: list[tuple[int, np.ndarray]] = []
        self._next_part = 0

    def insert_document(self, doc_id: int, band_sig: np.ndarray):
        self._buffer.append((doc_id, band_sig.astype(np.uint32)))
        if len(self._buffer) >= self.part_size:
            self.flush_part()

    def flush_part(self):
        if not self._buffer:
            return
        doc0 = self._buffer[0][0]
        doc_ids = np.array([d for d, _ in self._buffer], dtype=np.int64)
        stack = np.stack([b for _, b in self._buffer])   # (d, b, 2)
        b = stack.shape[1]
        rows = []
        for j in range(b):
            blob = _encode_part_v2(doc_ids, stack[:, j, :])
            rows.append((j, self._next_part, doc0, blob))
            self.write_bytes += 8 + len(blob)   # 32+32 bits + blob
        self.conn.executemany(
            "INSERT OR REPLACE INTO band2 VALUES (?,?,?,?)", rows)
        self.n_writes += len(rows)
        self._next_part += 1
        self._buffer = []

    def read_band(self, band_id: int):
        """Retrieve all band parts, append (paper §5.2.2)."""
        cur = self.conn.execute(
            "SELECT part_id, doc0, vals FROM band2 WHERE band_id=? "
            "ORDER BY part_id", (int(band_id),))
        docs, vals = [], []
        for part_id, doc0, blob in cur.fetchall():
            d, v = _decode_part(blob, doc0)
            docs.append(d)
            vals.append(v)
        if not docs:
            return (np.zeros(0, np.int64), np.zeros((0, 2), np.uint32))
        return np.concatenate(docs), np.concatenate(vals)

    def _band_ids(self) -> list[int]:
        cur = self.conn.execute(
            "SELECT DISTINCT band_id FROM band2 ORDER BY band_id")
        return [int(j) for (j,) in cur.fetchall()]

    def compact(self, doc_ids, root_of) -> None:
        """Rewrite evicted docs' band rows onto their cluster roots.

        Per band: decode every part, map each evicted doc id to
        ``root_of(doc)`` IN PLACE (positions of surviving entries are
        preserved, so the stable lexsort in the scan path enumerates
        runs in the same order an un-evicted store would), then drop
        exact (value, doc) duplicates keeping the first occurrence —
        the engine compresses candidates to roots before verification,
        so the rewrite changes no clustering outcome and no ledger
        entry, it only stops the store growing with evicted history.
        """
        self.flush_part()
        ev = {int(d): int(root_of(int(d))) for d in doc_ids}
        if not ev:
            return
        for j in self._band_ids():
            docs, vals = self.read_band(j)
            if len(docs) == 0 or not np.isin(docs, list(ev)).any():
                continue
            mapped = np.array([ev.get(int(d), int(d)) for d in docs],
                              dtype=np.int64)
            seen: set[tuple[int, int, int]] = set()
            keep = np.ones(len(mapped), dtype=bool)
            for i in range(len(mapped)):
                key = (int(vals[i, 0]), int(vals[i, 1]), int(mapped[i]))
                if key in seen:
                    keep[i] = False
                else:
                    seen.add(key)
            new_docs, new_vals = mapped[keep], vals[keep]
            self.conn.execute("DELETE FROM band2 WHERE band_id=?", (j,))
            rows = []
            for p, s in enumerate(range(0, len(new_docs),
                                        self.part_size)):
                ids = new_docs[s : s + self.part_size]
                blob = _encode_part_v2(ids, new_vals[s : s + self.part_size])
                rows.append((j, p, int(ids[0]), blob))
            if rows:
                self.conn.executemany(
                    "INSERT INTO band2 VALUES (?,?,?,?)", rows)
        self.conn.commit()

    def n_entries(self) -> int:
        self.flush_part()
        return sum(len(self.read_band(j)[0]) for j in self._band_ids())

    def commit(self):
        self.flush_part()
        self.conn.commit()


class SqliteBandStore(BandStoreBackend):
    """Key-level disk tier with Bloom-first lookups (DESIGN.md §12).

    Layout: one row per retained band KEY —

      ``bandkeys(band_id, hi, lo, docs BLOB, seq)``  PK (band_id, hi, lo)

    where ``docs`` is the key's bucket as an insertion-ordered int64
    array and ``seq`` is a monotone last-touch counter (the LRU clock a
    ``band_key_budget`` compacts by).  ``docentries(doc_id, band_id,
    hi, lo)`` is the per-doc reverse map eviction rewrites through, and
    ``sigs(doc_id, row)`` holds disk-resident signature rows for
    ``DiskSignatureVerifier``.

    Two Bloom filter sets per band, both ``retention.BandBloomFilter``:

    * the PRIMARY filter holds every key ever inserted — probes and
      inserts consult it first and touch disk only on filter hits (no
      false negatives, so a filter miss is a definitive store miss
      answered in O(hashes) host work; a false positive costs one empty
      SELECT);
    * the COMPACTION filter holds only budget-evicted keys, with
      exactly ``session.BandIndex``'s semantics: a later miss that hits
      it counts as ``filter_only_hits`` (the LSHBloom recall trade).

    The class implements BOTH roles a session needs: the
    ``BandStoreBackend`` scan/probe/compact interface (streaming
    phase-2, read-path probes) and the ``session.BandIndex`` API
    (``match_then_insert`` / ``evict`` / ``export_*`` / ``stats``) so a
    ``DedupSession`` can retain its cross-step index on disk unchanged.
    Cluster labels and per-edge sims are bit-identical to the memory
    tier (pinned in ``tests/test_bandstore_backends.py``).
    """

    kind = "sqlite"

    def __init__(self, path: str = ":memory:", num_bands: int = 50, *,
                 key_budget: int | None = None,
                 bloom_bits: int = 1 << 17, bloom_hashes: int = 4,
                 primary_bloom_bits: int = 1 << 20,
                 track_entries: bool = False):
        self.conn = sqlite3.connect(path)
        self.conn.execute(
            "CREATE TABLE IF NOT EXISTS bandkeys ("
            " band_id INTEGER, hi INTEGER, lo INTEGER,"
            " docs BLOB, seq INTEGER,"
            " PRIMARY KEY (band_id, hi, lo))")
        self.conn.execute(
            "CREATE TABLE IF NOT EXISTS docentries ("
            " doc_id INTEGER, band_id INTEGER,"
            " hi INTEGER, lo INTEGER)")
        self.conn.execute(
            "CREATE INDEX IF NOT EXISTS docentries_doc"
            " ON docentries (doc_id)")
        self.conn.execute(
            "CREATE TABLE IF NOT EXISTS sigs ("
            " doc_id INTEGER PRIMARY KEY, row BLOB)")
        self._num_bands = int(num_bands)
        self._key_budget = key_budget
        self._bloom_bits = int(bloom_bits)
        self._bloom_hashes = int(bloom_hashes)
        self._track_entries = bool(track_entries)
        self._primary = [BandBloomFilter(primary_bloom_bits, bloom_hashes)
                         for _ in range(self._num_bands)]
        self._filters: list[BandBloomFilter | None] = \
            [None] * self._num_bands
        self._key_counts = [0] * self._num_bands
        self._seq = 0
        self.filter_only_hits = 0
        self.compacted_keys = 0
        self.n_writes = 0
        self.write_bytes = 0
        # Reopening an existing file: rebuild the primary filters, key
        # counts, and LRU clock from the persisted rows.  (Compaction
        # filters are NOT reconstructible — their keys are gone by
        # definition; a reopened store starts them empty.)
        cur = self.conn.execute(
            "SELECT band_id, hi, lo, seq FROM bandkeys")
        for j, hi, lo, seq in cur.fetchall():
            self._primary[int(j)].add((int(hi), int(lo)))
            self._key_counts[int(j)] += 1
            self._seq = max(self._seq, int(seq) + 1)

    # -- small helpers -----------------------------------------------------

    @property
    def num_bands(self) -> int:
        return self._num_bands

    def _filter(self, j: int) -> BandBloomFilter:
        if self._filters[j] is None:
            self._filters[j] = BandBloomFilter(
                self._bloom_bits, self._bloom_hashes)
        return self._filters[j]

    def _next_seq(self) -> int:
        self._seq += 1
        return self._seq

    @staticmethod
    def _pack_docs(docs: list[int]) -> bytes:
        return np.asarray(docs, dtype=np.int64).tobytes()

    @staticmethod
    def _unpack_docs(blob: bytes) -> list[int]:
        return np.frombuffer(blob, dtype=np.int64).tolist()

    def _select_keys(self, j: int, keys: list[tuple[int, int]]) -> dict:
        """Fetch existing buckets for ``keys`` (already filter-hit) in
        one statement; returns {key: [doc ids]}."""
        if not keys:
            return {}
        out: dict[tuple[int, int], list[int]] = {}
        # Chunk the IN list to stay under sqlite's host-parameter cap.
        for s in range(0, len(keys), 400):
            part = keys[s : s + 400]
            sql = ("SELECT hi, lo, docs FROM bandkeys WHERE band_id=? "
                   "AND (hi, lo) IN (VALUES "
                   + ",".join(["(?,?)"] * len(part)) + ")")
            args = [int(j)]
            for hi, lo in part:
                args.extend((int(hi), int(lo)))
            for hi, lo, blob in self.conn.execute(sql, args):
                out[(int(hi), int(lo))] = self._unpack_docs(blob)
        return out

    # -- BandIndex API: cross-step candidate generation ---------------------

    def match_then_insert(self, bands: np.ndarray,
                          doc_id_base: int) -> np.ndarray:
        """(C, b, 2) chunk bands -> (E, 2) int64 cross-step edges.

        Semantics mirror ``session.BandIndex.match_then_insert`` line
        for line (same edge emission order, same LRU recency refresh on
        hits, same budget compaction into the per-band filter) — the
        memory-vs-sqlite parity pin depends on it.  The disk twist is
        Bloom-first: a key absent from the band's primary filter is a
        definitive new key, so only filter hits pay a SELECT.
        """
        bands = np.asarray(bands)
        if bands.ndim != 3 or bands.shape[1] != self._num_bands:
            raise ValueError(
                f"expected (C, {self._num_bands}, 2) bands, "
                f"got {bands.shape}")
        edges: list[tuple[int, int]] = []
        for j in range(self._num_bands):
            col = bands[:, j, :]
            chunk_keys = [(int(col[i, 0]), int(col[i, 1]))
                          for i in range(len(col))]
            primary = self._primary[j]
            maybe = sorted({k for k in chunk_keys if k in primary})
            buckets = self._select_keys(j, maybe)
            preexisting = set(buckets)
            seq_of: dict[tuple[int, int], int] = {}
            entries: list[tuple[int, int, int, int]] = []
            flt = self._filters[j]
            for i, key in enumerate(chunk_keys):
                new_id = doc_id_base + i
                olds = buckets.get(key)
                if olds is not None:
                    edges.extend((old, new_id) for old in olds
                                 if old < doc_id_base)
                    olds.append(new_id)
                else:
                    if flt is not None and key in flt:
                        # Seen before, partner compacted away: the pair
                        # can no longer be exactly re-verified.
                        self.filter_only_hits += 1
                    buckets[key] = [new_id]
                # Refresh recency on every touch (hit or insert): the
                # budget sweep deletes min-seq keys, so a hot key must
                # keep moving to the top of the clock exactly like the
                # dict move-to-end in BandIndex.
                seq_of[key] = self._next_seq()
                if self._track_entries:
                    entries.append((new_id, j, key[0], key[1]))
            updates, inserts = [], []
            for key, docs in buckets.items():
                blob = self._pack_docs(docs)
                self.write_bytes += len(blob)
                if key in preexisting:
                    updates.append((blob, seq_of[key], j,
                                    key[0], key[1]))
                else:
                    inserts.append((j, key[0], key[1], blob,
                                    seq_of[key]))
                    primary.add(key)
                    self._key_counts[j] += 1
            if updates:
                self.conn.executemany(
                    "UPDATE bandkeys SET docs=?, seq=? "
                    "WHERE band_id=? AND hi=? AND lo=?", updates)
            if inserts:
                self.conn.executemany(
                    "INSERT INTO bandkeys VALUES (?,?,?,?,?)", inserts)
            self.n_writes += len(updates) + len(inserts)
            if entries:
                self.conn.executemany(
                    "INSERT INTO docentries VALUES (?,?,?,?)", entries)
            if self._key_budget is not None and \
                    self._key_counts[j] > self._key_budget:
                excess = self._key_counts[j] - self._key_budget
                victims = self.conn.execute(
                    "SELECT hi, lo FROM bandkeys WHERE band_id=? "
                    "ORDER BY seq LIMIT ?", (j, excess)).fetchall()
                self.conn.executemany(
                    "DELETE FROM bandkeys WHERE band_id=? AND hi=? "
                    "AND lo=?", [(j, hi, lo) for hi, lo in victims])
                for hi, lo in victims:
                    self._filter(j).add((int(hi), int(lo)))
                    self.compacted_keys += 1
                self._key_counts[j] -= len(victims)
        if not edges:
            return np.zeros((0, 2), dtype=np.int64)
        return np.array(edges, dtype=np.int64)

    def evict(self, doc_ids, root_of) -> None:
        """Rewrite evicted docs' bucket entries onto their cluster root
        (``session.BandIndex.evict`` semantics, disk-resident)."""
        if not self._track_entries:
            raise ValueError(
                "SqliteBandStore was built without track_entries; "
                "eviction needs the per-doc reverse map")
        for d in doc_ids:
            d = int(d)
            rows = self.conn.execute(
                "SELECT band_id, hi, lo FROM docentries WHERE doc_id=? "
                "ORDER BY rowid", (d,)).fetchall()
            if not rows:
                continue
            self.conn.execute(
                "DELETE FROM docentries WHERE doc_id=?", (d,))
            for j, hi, lo in rows:
                got = self.conn.execute(
                    "SELECT docs FROM bandkeys WHERE band_id=? AND "
                    "hi=? AND lo=?", (j, hi, lo)).fetchone()
                if got is None:
                    continue               # key already compacted
                docs = self._unpack_docs(got[0])
                if d not in docs:
                    continue               # key was compacted + re-seen
                docs.remove(d)
                r = int(root_of(d))
                if r not in docs:
                    docs.append(r)
                    self.conn.execute(
                        "INSERT INTO docentries VALUES (?,?,?,?)",
                        (r, j, hi, lo))
                self.conn.execute(
                    "UPDATE bandkeys SET docs=? WHERE band_id=? AND "
                    "hi=? AND lo=?",
                    (self._pack_docs(docs), j, hi, lo))

    def export_maps(self) -> tuple:
        """Frozen per-band bucket maps ({key: (doc ids,)} dicts) — the
        in-memory view shape, materialized from disk.  Store-backed
        sessions normally publish a live ``probe_keys`` handle instead
        (``SessionView.band_store``); this export exists for parity
        tests and introspection."""
        maps: list[dict] = [dict() for _ in range(self._num_bands)]
        cur = self.conn.execute(
            "SELECT band_id, hi, lo, docs FROM bandkeys")
        for j, hi, lo, blob in cur.fetchall():
            maps[int(j)][(int(hi), int(lo))] = tuple(
                self._unpack_docs(blob))
        return tuple(maps)

    def export_filters(self) -> tuple:
        """Frozen per-band compaction Bloom filters (copies)."""
        return tuple(f.copy() if f is not None else None
                     for f in self._filters)

    def stats(self) -> dict:
        """Memory/recall/disk accounting (superset of BandIndex.stats)."""
        (tracked,) = self.conn.execute(
            "SELECT COUNT(DISTINCT doc_id) FROM docentries").fetchone()
        return {
            "n_keys": sum(self._key_counts),
            "n_entries": self.n_entries(),
            "n_docs_tracked": int(tracked),
            "compacted_keys": self.compacted_keys,
            "filter_only_hits": self.filter_only_hits,
            "bloom_bytes": sum(f.memory_bytes for f in self._filters
                               if f is not None),
            "primary_bloom_bytes": sum(f.memory_bytes
                                       for f in self._primary),
            "file_bytes": self.file_size_bytes(),
        }

    # -- BandStoreBackend API ----------------------------------------------

    def insert_document(self, doc_id: int, band_sig: np.ndarray) -> None:
        """Streaming phase-1 write: one doc's (b, 2) band column."""
        band_sig = np.asarray(band_sig)
        doc_id = int(doc_id)
        for j in range(len(band_sig)):
            key = (int(band_sig[j, 0]), int(band_sig[j, 1]))
            docs = None
            if key in self._primary[j]:
                got = self.conn.execute(
                    "SELECT docs FROM bandkeys WHERE band_id=? AND "
                    "hi=? AND lo=?", (j, key[0], key[1])).fetchone()
                if got is not None:
                    docs = self._unpack_docs(got[0])
            if docs is not None:
                docs.append(doc_id)
                blob = self._pack_docs(docs)
                self.conn.execute(
                    "UPDATE bandkeys SET docs=?, seq=? WHERE band_id=? "
                    "AND hi=? AND lo=?",
                    (blob, self._next_seq(), j, key[0], key[1]))
            else:
                blob = self._pack_docs([doc_id])
                self.conn.execute(
                    "INSERT INTO bandkeys VALUES (?,?,?,?,?)",
                    (j, key[0], key[1], blob, self._next_seq()))
                self._primary[j].add(key)
                self._key_counts[j] += 1
            self.n_writes += 1
            self.write_bytes += len(blob)

    def read_band(self, band_id: int):
        """All (doc, value) entries of one band, key-major.

        Keys come back value-sorted and each bucket insertion-ordered;
        the scan path lexsorts by value anyway (stably), so equal-value
        runs enumerate docs in the same order a ``Design2Store`` scan
        would — the cross-tier ledger-parity pin depends on that.
        """
        cur = self.conn.execute(
            "SELECT hi, lo, docs FROM bandkeys WHERE band_id=? "
            "ORDER BY hi, lo", (int(band_id),))
        docs, vals = [], []
        for hi, lo, blob in cur.fetchall():
            ids = np.frombuffer(blob, dtype=np.int64)
            docs.append(ids)
            v = np.empty((len(ids), 2), dtype=np.uint32)
            v[:, 0], v[:, 1] = np.uint32(hi), np.uint32(lo)
            vals.append(v)
        if not docs:
            return (np.zeros(0, np.int64), np.zeros((0, 2), np.uint32))
        return np.concatenate(docs), np.concatenate(vals)

    def probe_keys(self, bands: np.ndarray):
        """Bloom-first pure probe (see ``BandStoreBackend.probe_keys``).

        Per query key: primary-filter miss -> definitive store miss (no
        disk touched); filter hit -> one batched SELECT confirms (a
        false positive just comes back empty).  Store misses that hit
        the band's COMPACTION filter count as filter-only hits, exactly
        like the in-memory view walk.  Never mutates store state —
        recency is NOT refreshed (probes are reads, not ingests).
        """
        bands = np.asarray(bands)
        if bands.ndim != 3 or bands.shape[1] != self._num_bands:
            raise ValueError(
                f"expected (Q, {self._num_bands}, 2) bands, "
                f"got {bands.shape}")
        q = len(bands)
        cands: list[set[int]] = [set() for _ in range(q)]
        filter_hits = [0] * q
        for j in range(self._num_bands):
            col = bands[:, j, :]
            keys = [(int(col[i, 0]), int(col[i, 1])) for i in range(q)]
            primary = self._primary[j]
            maybe = sorted({k for k in keys if k in primary})
            buckets = self._select_keys(j, maybe)
            flt = self._filters[j]
            for i, key in enumerate(keys):
                olds = buckets.get(key)
                if olds is not None:
                    cands[i].update(olds)
                elif flt is not None and key in flt:
                    filter_hits[i] += 1
        return ([np.array(sorted(s), dtype=np.int64) for s in cands],
                filter_hits)

    def probe_stats(self, bands: np.ndarray) -> dict:
        """Pure probe-path accounting for one query batch: how often the
        primary filter said "maybe", how many of those the disk
        confirmed, and the filter false-positive rate (the Bloom-first
        bench row).  Mutates nothing."""
        bands = np.asarray(bands)
        q = len(bands)
        probes = q * self._num_bands
        bloom_maybe = 0
        disk_hits = 0
        for j in range(self._num_bands):
            col = bands[:, j, :]
            keys = [(int(col[i, 0]), int(col[i, 1])) for i in range(q)]
            primary = self._primary[j]
            maybe = [k for k in keys if k in primary]
            bloom_maybe += len(maybe)
            buckets = self._select_keys(j, sorted(set(maybe)))
            disk_hits += sum(1 for k in maybe if k in buckets)
        return {
            "probes": probes,
            "bloom_maybe": bloom_maybe,
            "disk_hits": disk_hits,
            "bloom_fps": bloom_maybe - disk_hits,
            "fp_rate": ((bloom_maybe - disk_hits) / probes
                        if probes else 0.0),
        }

    def compact(self, doc_ids, root_of) -> None:
        """Drop evicted docs' band rows on rewrite (streaming-store
        retention; same in-place + keep-first-dedup contract as
        ``Design2Store.compact``)."""
        ev = {int(d): int(root_of(int(d))) for d in doc_ids}
        if not ev:
            return
        updates = []
        cur = self.conn.execute(
            "SELECT band_id, hi, lo, docs FROM bandkeys")
        for j, hi, lo, blob in cur.fetchall():
            docs = self._unpack_docs(blob)
            if not any(d in ev for d in docs):
                continue
            mapped, seen = [], set()
            for d in docs:
                m = ev.get(d, d)
                if m not in seen:
                    seen.add(m)
                    mapped.append(m)
            updates.append((self._pack_docs(mapped), j, hi, lo))
        if updates:
            self.conn.executemany(
                "UPDATE bandkeys SET docs=? WHERE band_id=? AND hi=? "
                "AND lo=?", updates)
        if self._track_entries and ev:
            self.conn.executemany(
                "DELETE FROM docentries WHERE doc_id=?",
                [(d,) for d in ev])
        self.conn.commit()

    def n_entries(self) -> int:
        total = 0
        for (blob,) in self.conn.execute("SELECT docs FROM bandkeys"):
            total += len(blob) // 8
        return total

    def commit(self) -> None:
        self.conn.commit()

    # -- disk-resident signature rows ---------------------------------------

    def put_signatures(self, doc_ids, rows: np.ndarray) -> None:
        """Store (D, M) uint32 signature rows for ``doc_ids``."""
        rows = np.ascontiguousarray(rows, dtype=np.uint32)
        self.conn.executemany(
            "INSERT OR REPLACE INTO sigs VALUES (?,?)",
            [(int(d), rows[i].tobytes())
             for i, d in enumerate(doc_ids)])

    def get_signature(self, doc_id: int) -> np.ndarray | None:
        got = self.conn.execute(
            "SELECT row FROM sigs WHERE doc_id=?",
            (int(doc_id),)).fetchone()
        if got is None:
            return None
        return np.frombuffer(got[0], dtype=np.uint32)

    def n_signatures(self) -> int:
        (n,) = self.conn.execute("SELECT COUNT(*) FROM sigs").fetchone()
        return int(n)

    def release_signatures(self, doc_ids) -> None:
        self.conn.executemany(
            "DELETE FROM sigs WHERE doc_id=?",
            [(int(d),) for d in doc_ids])


class DiskSignatureVerifier(BatchVerifier):
    """Signature-agreement verifier over disk-resident rows.

    The sqlite tier's replacement for holding the full (n_docs, M)
    signature matrix in RAM: rows live in ``SqliteBandStore.sigs`` and
    are gathered through a bounded LRU row cache.  The estimate itself
    is the same expression ``SignatureVerifier`` evaluates —
    ``(a == b).mean(axis=-1, dtype=np.float32)`` over the gathered
    uint32 rows — so sims are bit-identical to the in-memory tier.

    ``release_rows`` deletes rows from DISK as well as the cache (the
    retention hook: bounded sessions get bounded disk, not just bounded
    RAM); a verify against a released doc raises ``KeyError`` exactly
    like ``SignatureVerifier._slot_index``.
    """

    def __init__(self, store: SqliteBandStore, num_hashes: int,
                 cache_rows: int = 4096):
        super().__init__()
        self.store = store
        self.num_hashes = int(num_hashes)
        self.cache_rows = int(cache_rows)
        self._cache: OrderedDict[int, np.ndarray] = OrderedDict()
        self.cache_hits = 0
        self.cache_misses = 0

    @property
    def n_live_rows(self) -> int:
        return self.store.n_signatures()

    def _row(self, doc: int) -> np.ndarray:
        doc = int(doc)
        row = self._cache.get(doc)
        if row is not None:
            self._cache.move_to_end(doc)
            self.cache_hits += 1
            return row
        row = self.store.get_signature(doc)
        if row is None:
            raise KeyError(
                f"doc {doc} has no retained signature row (evicted by "
                "the retention policy, or never ingested)")
        self.cache_misses += 1
        self._cache[doc] = row
        while len(self._cache) > self.cache_rows:
            self._cache.popitem(last=False)
        return row

    def rows_for(self, doc_ids) -> np.ndarray:
        ids = np.asarray(doc_ids, dtype=np.int64).ravel()
        out = np.empty((len(ids), self.num_hashes), dtype=np.uint32)
        for i, d in enumerate(ids):
            out[i] = self._row(int(d))
        return out

    def extend_signatures(self, doc_ids, sig: np.ndarray) -> None:
        """Append a chunk's rows (write-through; keeps ``sigs`` the one
        authoritative copy)."""
        self.store.put_signatures(doc_ids, sig)

    def release_rows(self, doc_ids) -> None:
        """Retention hook: drop evicted docs' rows from disk + cache."""
        self.store.release_signatures(doc_ids)
        for d in doc_ids:
            self._cache.pop(int(d), None)

    def _verify_batch(self, pairs: np.ndarray) -> np.ndarray:
        pairs = np.asarray(pairs)
        a = self.rows_for(pairs[:, 0])
        b = self.rows_for(pairs[:, 1])
        return (a == b).mean(axis=-1, dtype=np.float32)


def candidate_pairs_from_store(store, num_bands: int,
                               max_pairs_per_band=None):
    """Band-major candidate generation over any band store backend.

    Delegates to the shared staged-engine candidate layer
    (``candidates.StoreBandSource``); ``num_docs`` is not needed for
    pair enumeration, so 0 is passed.
    """
    from repro.core.candidates import StoreBandSource, candidate_pairs

    return candidate_pairs(
        StoreBandSource(store, num_bands, 0), max_pairs_per_band)
