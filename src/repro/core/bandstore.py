"""Out-of-core band-matrix storage — the paper's two database designs (§5).

The paper uses Apache Cassandra; this container has no Cassandra, so the
designs are realized over sqlite3 (stdlib) with the exact same schemas and
access patterns — the *comparative* behaviour (Design 2's fewer, larger
writes winning on write volume; band-major reads) is what the paper
measures, and that transfers.

Design 1: one row per band-matrix cell      (band_id, doc_id, value)
Design 2: one row per (band, doc-part) slice (band_id, part_id, values[])

On the TPU pod these map to band-major resharding vs doc-major band_parts
(DESIGN.md §2); this module is the literal single-machine reproduction.
"""
from __future__ import annotations

import sqlite3

import numpy as np


class Design1Store:
    """One database row per band-matrix cell."""

    def __init__(self, path: str = ":memory:"):
        self.conn = sqlite3.connect(path)
        self.conn.execute(
            "CREATE TABLE IF NOT EXISTS band1 ("
            " band_id INTEGER, doc_id INTEGER,"
            " hi INTEGER, lo INTEGER,"
            " PRIMARY KEY (band_id, doc_id))")
        self.n_writes = 0
        self.write_bytes = 0

    def insert_document(self, doc_id: int, band_sig: np.ndarray):
        """band_sig: (b, 2) uint32 — the doc's band-matrix column."""
        rows = [(int(j), int(doc_id), int(band_sig[j, 0]),
                 int(band_sig[j, 1])) for j in range(len(band_sig))]
        self.conn.executemany(
            "INSERT OR REPLACE INTO band1 VALUES (?,?,?,?)", rows)
        self.n_writes += len(rows)
        self.write_bytes += len(rows) * 16   # 32+32+64 bits (paper §8)

    def read_band(self, band_id: int):
        """'select * from table where band_id = id' (paper §5.2.1)."""
        cur = self.conn.execute(
            "SELECT doc_id, hi, lo FROM band1 WHERE band_id=?",
            (int(band_id),))
        rows = cur.fetchall()
        if not rows:
            return (np.zeros(0, np.int64), np.zeros((0, 2), np.uint32))
        arr = np.array(rows, dtype=np.int64)
        return arr[:, 0], arr[:, 1:].astype(np.uint32)

    def commit(self):
        self.conn.commit()


# Design-2 blob schema v2: explicit per-part doc ids travel inside the
# blob (header magic + version + count, then int64 doc ids, then uint32
# band values).  v1 blobs were the raw value array alone and doc ids
# were *reconstructed* as arange(doc0, doc0 + d) — silently wrong for
# any non-contiguous ingest (ragged chunks, resumed ingest with
# doc_offsets-style global ids).
_BLOB_MAGIC = np.uint32(0x42443253)   # "BD2S"
_BLOB_VERSION = np.uint32(2)


def _encode_part_v2(doc_ids: np.ndarray, vals: np.ndarray) -> bytes:
    """Pack one (band, part) slice: header + int64 ids + uint32 values."""
    d = len(doc_ids)
    header = np.array([_BLOB_MAGIC, _BLOB_VERSION, d], dtype=np.uint32)
    return (header.tobytes()
            + np.ascontiguousarray(doc_ids, dtype=np.int64).tobytes()
            + np.ascontiguousarray(vals, dtype=np.uint32).tobytes())


def _decode_part(blob: bytes, doc0: int):
    """Decode a part blob, accepting both schema versions.

    v2 is self-describing (magic/version/count header); anything else is
    a v1 raw value array whose doc ids are reconstructed from ``doc0``
    (the legacy contiguous assumption — kept only so pre-existing stores
    stay readable).
    """
    if len(blob) >= 12:
        header = np.frombuffer(blob[:12], dtype=np.uint32)
        d = int(header[2])
        if (header[0] == _BLOB_MAGIC and header[1] == _BLOB_VERSION
                and len(blob) == 12 + d * 8 + d * 8):
            docs = np.frombuffer(blob[12 : 12 + d * 8], dtype=np.int64)
            vals = np.frombuffer(blob[12 + d * 8 :],
                                 dtype=np.uint32).reshape(d, 2)
            return docs, vals
    vals = np.frombuffer(blob, dtype=np.uint32).reshape(-1, 2)
    return np.arange(doc0, doc0 + len(vals), dtype=np.int64), vals


class Design2Store:
    """One database row per (band, band_part) slice of d documents."""

    def __init__(self, path: str = ":memory:", part_size: int = 50):
        self.conn = sqlite3.connect(path)
        self.conn.execute(
            "CREATE TABLE IF NOT EXISTS band2 ("
            " band_id INTEGER, part_id INTEGER, doc0 INTEGER,"
            " vals BLOB, PRIMARY KEY (band_id, part_id))")
        self.part_size = part_size
        self.n_writes = 0
        self.write_bytes = 0
        self._buffer: list[tuple[int, np.ndarray]] = []
        self._next_part = 0

    def insert_document(self, doc_id: int, band_sig: np.ndarray):
        self._buffer.append((doc_id, band_sig.astype(np.uint32)))
        if len(self._buffer) >= self.part_size:
            self.flush_part()

    def flush_part(self):
        if not self._buffer:
            return
        doc0 = self._buffer[0][0]
        doc_ids = np.array([d for d, _ in self._buffer], dtype=np.int64)
        stack = np.stack([b for _, b in self._buffer])   # (d, b, 2)
        b = stack.shape[1]
        rows = []
        for j in range(b):
            blob = _encode_part_v2(doc_ids, stack[:, j, :])
            rows.append((j, self._next_part, doc0, blob))
            self.write_bytes += 8 + len(blob)   # 32+32 bits + blob
        self.conn.executemany(
            "INSERT OR REPLACE INTO band2 VALUES (?,?,?,?)", rows)
        self.n_writes += len(rows)
        self._next_part += 1
        self._buffer = []

    def read_band(self, band_id: int):
        """Retrieve all band parts, append (paper §5.2.2)."""
        cur = self.conn.execute(
            "SELECT part_id, doc0, vals FROM band2 WHERE band_id=? "
            "ORDER BY part_id", (int(band_id),))
        docs, vals = [], []
        for part_id, doc0, blob in cur.fetchall():
            d, v = _decode_part(blob, doc0)
            docs.append(d)
            vals.append(v)
        if not docs:
            return (np.zeros(0, np.int64), np.zeros((0, 2), np.uint32))
        return np.concatenate(docs), np.concatenate(vals)

    def commit(self):
        self.flush_part()
        self.conn.commit()


def candidate_pairs_from_store(store, num_bands: int,
                               max_pairs_per_band=None):
    """Band-major candidate generation over either store design.

    Delegates to the shared staged-engine candidate layer
    (``candidates.StoreBandSource``); ``num_docs`` is not needed for
    pair enumeration, so 0 is passed.
    """
    from repro.core.candidates import StoreBandSource, candidate_pairs

    return candidate_pairs(
        StoreBandSource(store, num_bands, 0), max_pairs_per_band)
