"""Batched pair verification layer of the staged dedup engine.

Staged-engine architecture (see also ``candidates.py`` and
``engine.py``)::

    CandidateSource  ->  BatchVerifier  ->  ThresholdUnionFind

A ``BatchVerifier`` maps a (P, 2) int array of candidate doc pairs to a
(P,) float32 similarity vector in device-sized batches, replacing the
per-pair Python ``similarity_fn(a, b)`` callbacks the three execution
paths used to carry.  Backends:

===================  =====================================================
verifier             computes
===================  =====================================================
SignatureVerifier    signature-agreement estimate m/M (paper §3.4) over
                     gathered signature rows; backend ``numpy`` (host),
                     ``jnp`` (``minhash.estimate_jaccard`` under jit) or
                     ``pallas`` (``kernels.sigjaccard.pair_estimate``)
ExactJaccardVerifier exact set Jaccard (paper §2.1) vectorized over
                     pre-sorted n-gram id arrays (merge-count, no
                     Python set ops on the hot path)
ShardedEdgeVerifier  full-signature re-verify of the ``dist_lsh``
                     prefix-prescreen survivors (stage 2 of the sharded
                     path's two-stage verify); same estimator/backends
                     as SignatureVerifier by construction
DeviceScoredEdge-    pass-through for the device-resident stage-2 mode:
Verifier             serves scores the ``kernels.sigjaccard`` shard_map
                     kernel already computed, re-scores only cross-shard
                     stragglers
CallbackVerifier     compat shim around a scalar ``fn(a, b) -> float``
===================  =====================================================

All verifiers record ``n_batches`` / ``n_pairs`` / ``seconds`` so
drivers and benchmarks can report batched-verification throughput.
"""
from __future__ import annotations

import time
from typing import Callable

import numpy as np
import jax

from repro.core import minhash


class BatchVerifier:
    """Base class: ``verifier(pairs (P, 2)) -> sims (P,) float32``.

    Subclasses implement ``_verify_batch``; ``__call__`` handles
    batching, empty input, and throughput accounting.
    """

    batch_pairs: int = 8192

    def __init__(self):
        self.n_batches = 0
        self.n_pairs = 0
        self.seconds = 0.0

    def _verify_batch(self, pairs: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def __call__(self, pairs: np.ndarray) -> np.ndarray:
        pairs = np.asarray(pairs)
        if pairs.size == 0:
            return np.zeros((0,), dtype=np.float32)
        pairs = pairs.reshape(-1, 2)
        t0 = time.perf_counter()
        out = np.empty(len(pairs), dtype=np.float32)
        for s in range(0, len(pairs), self.batch_pairs):
            chunk = pairs[s : s + self.batch_pairs]
            out[s : s + len(chunk)] = np.asarray(
                self._verify_batch(chunk), dtype=np.float32
            )[: len(chunk)]
            self.n_batches += 1
        self.n_pairs += len(pairs)
        self.seconds += time.perf_counter() - t0
        return out

    @property
    def pairs_per_second(self) -> float:
        return self.n_pairs / self.seconds if self.seconds > 0 else 0.0


class CallbackVerifier(BatchVerifier):
    """Wrap a scalar ``similarity_fn(a, b) -> float`` (compat path)."""

    def __init__(self, fn: Callable[[int, int], float]):
        super().__init__()
        self.fn = fn

    def _verify_batch(self, pairs: np.ndarray) -> np.ndarray:
        return np.array(
            [self.fn(int(a), int(b)) for a, b in pairs], dtype=np.float32
        )


class SignatureVerifier(BatchVerifier):
    """Signature-agreement estimate over gathered signature rows.

    ``backend``:
      * ``"numpy"`` — host vectorized ``(sig[a] == sig[b]).mean(-1)``.
      * ``"jnp"``   — jitted gather + ``minhash.estimate_jaccard`` on
        device; batches are padded to power-of-two buckets so the jit
        cache stays small.
      * ``"pallas"`` — ``kernels.sigjaccard.pair_estimate`` TPU kernel
        (interpret mode on CPU), same shape bucketing.
    """

    def __init__(self, signatures: np.ndarray, backend: str = "numpy",
                 batch_pairs: int = 8192):
        super().__init__()
        if backend not in ("numpy", "jnp", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        self.backend = backend
        self.batch_pairs = int(batch_pairs)
        self._set_signatures(np.asarray(signatures))

    def _set_signatures(self, sig: np.ndarray):
        # The matrix is adopted as the growth buffer; extensions write
        # past ``_n_rows`` after a capacity-doubling copy, so repeated
        # chunk appends are amortized O(chunk), and the device copy
        # (jnp/pallas backends) is refreshed lazily at the next verify.
        # Row i holds doc i until the first ``release_rows`` call, which
        # switches the verifier to an explicit doc -> slot map with a
        # free-slot pool (retention layer, DESIGN.md §7).
        self._buf = sig
        self._n_rows = len(sig)
        self.signatures = sig
        self._dev_dirty = True
        self._slot_of: dict[int, int] | None = None
        self._free: list[int] = []
        self._n_docs = len(sig)
        # Bumped on every mutation (extend/release/reset) so a sharing
        # view (``adopt_layout``) can invalidate its device copy only
        # when the matrix actually changed.
        self._mutations = getattr(self, "_mutations", 0) + 1

    # -- retention (free-slot pool) ----------------------------------------

    @property
    def n_live_rows(self) -> int:
        """Rows currently holding a retained document's signature."""
        if self._slot_of is None:
            return self._n_rows
        return len(self._slot_of)

    def _slot_index(self, ids: np.ndarray) -> np.ndarray:
        """Translate global doc ids to physical row slots."""
        if self._slot_of is None:
            return ids
        so = self._slot_of
        try:
            return np.fromiter((so[int(i)] for i in ids.ravel()),
                               dtype=np.int64,
                               count=ids.size).reshape(ids.shape)
        except KeyError as e:
            raise KeyError(
                f"doc {e.args[0]} has no retained signature row (evicted "
                "by the retention policy); only union-find roots and the "
                "LRU window are verifiable") from None

    def release_rows(self, doc_ids) -> int:
        """Evict docs' signature rows into the free-slot pool.

        The first call switches the verifier from the implicit
        ``row i == doc i`` layout to an explicit doc -> slot map; freed
        slots are reused by later ``extend_signatures`` calls, so the
        matrix stops growing once eviction keeps pace with ingest
        (memory O(live rows), not O(docs ever ingested)).  Releasing an
        unknown / already-released doc raises.
        """
        if self._slot_of is None:
            self._slot_of = {i: i for i in range(self._n_rows)}
        released = 0
        for d in doc_ids:
            d = int(d)
            try:
                slot = self._slot_of.pop(d)
            except KeyError:
                raise KeyError(f"doc {d} has no retained row to release")
            self._free.append(slot)
            released += 1
        self._mutations += 1
        return released

    def adopt_layout(self, other: "SignatureVerifier") -> None:
        """Share ``other``'s retained matrix and slot layout (zero-copy).

        The session keeps a plain-estimator view over a
        ``DeviceScoredEdgeVerifier``'s matrix for host-generated edges;
        eviction mutates rows in place, so the view must re-adopt the
        owner's buffer/slot state before each use.
        """
        if self.signatures is not other.signatures:
            self._buf = other._buf
            self._n_rows = other._n_rows
            self.signatures = other.signatures
        self._slot_of = other._slot_of
        self._free = other._free
        self._n_docs = other._n_docs
        # Slot reuse rewrites rows without replacing the array object,
        # so object identity alone cannot tell whether the device copy
        # is stale — the owner's mutation counter can (and it spares
        # jnp/pallas backends a full re-upload on every adopt).
        if getattr(self, "_adopted_mutations", None) != other._mutations:
            self._dev_dirty = True
            self._adopted_mutations = other._mutations

    def rows_for(self, doc_ids) -> np.ndarray:
        """Retained signature rows for ``doc_ids`` (eviction-aware)."""
        ids = np.asarray(doc_ids, dtype=np.int64)
        if ids.size == 0:
            return np.zeros((0,) + self.signatures.shape[1:],
                            dtype=self.signatures.dtype)
        return self.signatures[self._slot_index(ids)]

    def frozen_rows(self) -> tuple[np.ndarray, dict | None]:
        """(signatures, doc->slot) safe against later session mutation.

        Read-path snapshot for ``core.session.SessionView``.  In the
        append-only layout later extensions only ever write past this
        view's row bound or reallocate into a fresh buffer, so the
        current row-slice object is already immutable — shared
        zero-copy.  In the eviction layout (``_slot_of`` set) freed
        slots are rewritten in place by later chunks, so the live rows
        — bounded O(clusters + LRU window) by the retention invariant —
        are copied together with the doc->slot map.
        """
        if self._slot_of is None:
            return self.signatures, None
        return self.signatures.copy(), dict(self._slot_of)

    def _device_signatures(self):
        import jax.numpy as jnp

        if self._dev_dirty:
            self._sig_dev = jnp.asarray(self.signatures)
            self._dev_dirty = False
        return self._sig_dev

    def extend_signatures(self, rows: np.ndarray) -> None:
        """Append signature rows for newly ingested docs.

        Incremental ingest (``core.session.DedupSession``) allocates
        global doc ids chunk by chunk; the verifier's row i must stay
        doc i's signature, so each chunk's rows are appended in
        allocation order.  Throughput counters (and, for
        ``DeviceScoredEdgeVerifier``, the registered device scores)
        survive the extension — the session keeps ONE verifier alive
        across every chunk.
        """
        rows = np.asarray(rows)
        if rows.size == 0:
            return
        if self.signatures.size == 0:
            self._set_signatures(rows)
            return
        if rows.shape[-1] != self.signatures.shape[-1]:
            raise ValueError(
                f"signature width {rows.shape[-1]} != existing "
                f"{self.signatures.shape[-1]}")
        if self._slot_of is not None:
            # Retention mode: fill freed slots before growing the
            # matrix — new docs take the next sequential global ids.
            n_append = max(0, len(rows) - len(self._free))
            n_new = self._n_rows + n_append
            if n_new > len(self._buf):
                cap = max(n_new, 2 * max(1, len(self._buf)))
                buf = np.empty((cap, self._buf.shape[1]),
                               dtype=self._buf.dtype)
                buf[: self._n_rows] = self._buf[: self._n_rows]
                self._buf = buf
            for row in rows:
                if self._free:
                    slot = self._free.pop()
                else:
                    slot = self._n_rows
                    self._n_rows += 1
                self._buf[slot] = row
                self._slot_of[self._n_docs] = slot
                self._n_docs += 1
            self.signatures = self._buf[: self._n_rows]
            self._dev_dirty = True
            self._mutations += 1
            return
        n_new = self._n_rows + len(rows)
        if n_new > len(self._buf):
            cap = max(n_new, 2 * max(1, len(self._buf)))
            buf = np.empty((cap, self._buf.shape[1]),
                           dtype=self._buf.dtype)
            buf[: self._n_rows] = self._buf[: self._n_rows]
            self._buf = buf
        self._buf[self._n_rows : n_new] = rows
        self._n_rows = n_new
        self._n_docs = n_new
        self.signatures = self._buf[: self._n_rows]
        self._dev_dirty = True
        self._mutations += 1

    def _verify_batch(self, pairs: np.ndarray) -> np.ndarray:
        pairs = self._slot_index(np.asarray(pairs))
        a_idx, b_idx = pairs[:, 0], pairs[:, 1]
        if self.backend == "numpy":
            a = self.signatures[a_idx]
            b = self.signatures[b_idx]
            return (a == b).mean(axis=-1, dtype=np.float32)
        import jax.numpy as jnp

        # Pad to the next power-of-two bucket (>= 256): stable, bounded
        # set of jit shapes without padding every run-sized batch to the
        # full batch_pairs.
        p = len(pairs)
        bucket = 256
        while bucket < p:
            bucket *= 2
        a_idx = jnp.asarray(np.pad(a_idx, (0, bucket - p)))
        b_idx = jnp.asarray(np.pad(b_idx, (0, bucket - p)))
        sig_dev = self._device_signatures()
        if self.backend == "jnp":
            est = _gather_estimate_jit(sig_dev, a_idx, b_idx)
        else:
            from repro.kernels import ops as kops

            est = kops.indexed_pair_estimate(sig_dev, a_idx, b_idx)
        return np.asarray(est)[:p]


@jax.jit
def _gather_estimate_jit(sig, a_idx, b_idx):
    """Fused gather + agreement estimate (one dispatch per bucket)."""
    return minhash.estimate_jaccard(sig[a_idx], sig[b_idx])


class ShardedEdgeVerifier(SignatureVerifier):
    """Stage 2 of the sharded path's two-stage verify (``dist_lsh``).

    Stage 1 is the cheap on-device prescreen inside the all_to_all: each
    band run compares only the exchanged ``verify_k``-prefix of the
    signatures and keeps edges whose prefix estimate clears
    ``edge_threshold - prescreen_margin``.  The surviving edges land in
    per-device buffers; this verifier re-scores them on the host side
    against the **full** (D, M) signature matrix using the exact same
    estimator and backends (numpy / jnp / ``kernels.sigjaccard``) as the
    host path's ``SignatureVerifier`` — so edge thresholds and estimate
    semantics cannot drift between the sharded and host engines.

    Build it from a dedup-step output with ``from_step_output`` (the step
    returns the signatures it computed, keeping device and host views
    bit-identical).
    """

    @classmethod
    def from_step_output(cls, out, backend: str = "numpy",
                         batch_pairs: int = 8192) -> "ShardedEdgeVerifier":
        return cls(np.asarray(out["sig"]), backend=backend,
                   batch_pairs=batch_pairs)

    def drift_count(self, pairs: np.ndarray,
                    reference: BatchVerifier) -> int:
        """#pairs whose estimate differs from ``reference``'s (expect 0)."""
        pairs = np.asarray(pairs).reshape(-1, 2)
        if pairs.size == 0:
            return 0
        return int(np.sum(self(pairs) != reference(pairs)))


class DeviceScoredEdgeVerifier(ShardedEdgeVerifier):
    """Pass-through stage 2 for the device-resident verify mode.

    When ``dist_lsh`` runs its stage-2 verify on the accelerator
    (``stage2="device"``: the ``kernels.sigjaccard`` fused gather +
    full-M-estimate kernel under ``shard_map``), edges whose two
    endpoints live on one device's signature shard arrive at the host
    merge already fully scored.  ``add_scores`` registers those scores;
    ``_verify_batch`` then serves a pair from the registry when present
    and falls back to the parent full-signature re-verify only for the
    *cross-shard stragglers* (edge endpoints on different shards) and
    for root pairs the engine synthesizes after unions.

    The device kernel computes the identical estimator (full-M
    agreement, float32 division), so registry hits and host re-scores
    are bit-interchangeable — drift stays 0 by construction.

    ``n_passthrough`` / ``n_rescored`` count how the split landed.
    """

    def __init__(self, signatures: np.ndarray, backend: str = "numpy",
                 batch_pairs: int = 8192):
        super().__init__(signatures, backend=backend,
                         batch_pairs=batch_pairs)
        self._scores: dict[tuple[int, int], float] = {}
        self.n_passthrough = 0
        self.n_rescored = 0

    def add_scores(self, pairs: np.ndarray, sims: np.ndarray):
        """Register device-computed full-signature scores for pairs.

        ``pairs`` (P, 2) int doc ids in any order; keys are canonicalized
        to (min, max) to match the engine's root-pair convention.
        """
        pairs = np.asarray(pairs).reshape(-1, 2).astype(np.int64)
        sims = np.asarray(sims).reshape(-1)
        for (a, b), s in zip(pairs, sims):
            a, b = int(a), int(b)
            self._scores[(min(a, b), max(a, b))] = float(s)

    @property
    def num_scores(self) -> int:
        return len(self._scores)

    def clear_scores(self) -> None:
        """Drop the device-score registry (counters survive).

        A registered edge is dead once its step's buffers have been fed:
        every raw edge either landed in the engine's verified-sim cache
        or its endpoints were already co-clustered (and unions never
        split, so the pair can never reach the verifier again).
        ``dist_lsh.feed_step_groups`` clears after each step so a
        long-lived incremental session doesn't accumulate one registry
        entry per device-scored edge forever.
        """
        self._scores.clear()

    def _verify_batch(self, pairs: np.ndarray) -> np.ndarray:
        out = np.empty(len(pairs), dtype=np.float32)
        missing = []
        missing_at = []
        for i, (a, b) in enumerate(pairs):
            s = self._scores.get((int(a), int(b)))
            if s is None:
                missing.append((int(a), int(b)))
                missing_at.append(i)
            else:
                out[i] = s
        self.n_passthrough += len(pairs) - len(missing)
        if missing:
            self.n_rescored += len(missing)
            out[missing_at] = super()._verify_batch(
                np.array(missing, dtype=np.int64))
        return out


class ExactJaccardVerifier(BatchVerifier):
    """Vectorized exact Jaccard over pre-sorted n-gram id arrays.

    Each document's n-gram set is interned to integer ids once
    (``from_token_lists``); a batch of P pairs is then verified by
    concatenating the two padded id rows, sorting each row, and counting
    adjacent equal values — |A ∩ B| by merge, no Python set ops.  Padding
    slots carry globally unique sentinels so they can never collide.
    Matches ``jaccard.exact_jaccard`` on n-gram sets exactly (interning
    is collision-free by construction).
    """

    def __init__(self, id_rows: list[np.ndarray], batch_pairs: int = 2048,
                 *, _vocab: dict | None = None, _ngram: int | None = None):
        super().__init__()
        self.batch_pairs = int(batch_pairs)
        self._rows: list[np.ndarray] = [
            np.asarray(r, dtype=np.int64) for r in id_rows]
        self._vocab = _vocab        # n-gram -> id (None: raw-id rows only)
        self._ngram = _ngram
        # Retention: None = implicit "row i == doc i"; first
        # release_rows switches to a doc -> slot map + free pool (same
        # protocol as SignatureVerifier).
        self._slot_of: dict[int, int] | None = None
        self._free: list[int] = []
        self._n_docs = len(self._rows)
        self._rebuild()

    def _pad_rows(self, rows: list[np.ndarray], row0: int,
                  lmax: int) -> np.ndarray:
        """Pad id rows to (len(rows), lmax).

        Pad slot (row0 + i, j) carries the globally unique NEGATIVE
        sentinel ``-(1 + (row0 + i) * lmax + j)``: real interned ids
        are >= 0, so pads can never match a real id nor another pad —
        and, unlike a max-id-derived sentinel base, they stay valid
        when later chunks grow the vocab, which is what makes
        ``extend_id_rows`` append-only.
        """
        d = len(rows)
        out = -(1 + np.int64(row0) * lmax
                + np.arange(d * lmax, dtype=np.int64).reshape(d, lmax))
        for i, row in enumerate(rows):
            out[i, : len(row)] = row
        return out

    def _rebuild(self):
        self._n_rows = len(self._rows)
        self._len_buf = np.array([len(r) for r in self._rows],
                                 dtype=np.int64)
        self._lmax = int(max(1, self._len_buf.max(initial=1)))
        self._ids_buf = self._pad_rows(self._rows, 0, self._lmax)
        self.lengths = self._len_buf
        self.ids = self._ids_buf

    def extend_id_rows(self, id_rows: list[np.ndarray]) -> None:
        """Append pre-interned sorted id rows for newly ingested docs.

        Ids must come from the same interning namespace as the existing
        rows (intersection counts — and therefore exact Jaccard values —
        depend only on id equality, so chunked interning with a shared
        vocab is bit-identical to one-shot interning).  Appending is
        amortized O(chunk) — capacity-doubling row buffers, like
        ``SignatureVerifier.extend_signatures`` — while the new rows
        fit the current row width; only a chunk containing a longer
        document than any before re-pads the whole matrix.  In
        retention mode (after a ``release_rows`` call) freed slots are
        reused before the buffers grow.
        """
        if not id_rows:
            return
        new = [np.asarray(r, dtype=np.int64) for r in id_rows]
        if self._slot_of is not None:
            self._extend_into_slots(new)
            return
        n0 = self._n_rows
        n1 = n0 + len(new)
        self._rows.extend(new)
        self._n_docs = n1
        if max((len(r) for r in new), default=1) > self._lmax:
            self._rebuild()
            return
        if n1 > len(self._ids_buf):
            cap = max(n1, 2 * max(1, len(self._ids_buf)))
            ids_buf = np.empty((cap, self._lmax), dtype=np.int64)
            ids_buf[:n0] = self._ids_buf[:n0]
            len_buf = np.empty((cap,), dtype=np.int64)
            len_buf[:n0] = self._len_buf[:n0]
            self._ids_buf, self._len_buf = ids_buf, len_buf
        self._ids_buf[n0:n1] = self._pad_rows(new, n0, self._lmax)
        self._len_buf[n0:n1] = [len(r) for r in new]
        self._n_rows = n1
        self.ids = self._ids_buf[:n1]
        self.lengths = self._len_buf[:n1]

    def _extend_into_slots(self, new: list[np.ndarray]) -> None:
        """Retention-mode extension: fill freed slots, then append."""
        slots = []
        for row in new:
            if self._free:
                slot = self._free.pop()
                self._rows[slot] = row
            else:
                slot = len(self._rows)
                self._rows.append(row)
            slots.append(slot)
            self._slot_of[self._n_docs] = slot
            self._n_docs += 1
        if max((len(r) for r in new), default=1) > self._lmax:
            self._rebuild()            # one full re-pad at the new width
            return
        n1 = len(self._rows)
        if n1 > len(self._ids_buf):
            n0 = self._n_rows
            cap = max(n1, 2 * max(1, len(self._ids_buf)))
            ids_buf = np.empty((cap, self._lmax), dtype=np.int64)
            ids_buf[:n0] = self._ids_buf[:n0]
            len_buf = np.empty((cap,), dtype=np.int64)
            len_buf[:n0] = self._len_buf[:n0]
            self._ids_buf, self._len_buf = ids_buf, len_buf
        for slot, row in zip(slots, new):
            self._ids_buf[slot] = self._pad_rows([row], slot,
                                                 self._lmax)[0]
            self._len_buf[slot] = len(row)
        self._n_rows = n1
        self.ids = self._ids_buf[:n1]
        self.lengths = self._len_buf[:n1]

    # -- retention (free-slot pool) ----------------------------------------

    @property
    def n_live_rows(self) -> int:
        """Rows currently holding a retained document's n-gram ids."""
        if self._slot_of is None:
            return self._n_rows
        return len(self._slot_of)

    def _slot_index(self, ids: np.ndarray) -> np.ndarray:
        if self._slot_of is None:
            return ids
        so = self._slot_of
        try:
            return np.fromiter((so[int(i)] for i in ids.ravel()),
                               dtype=np.int64,
                               count=ids.size).reshape(ids.shape)
        except KeyError as e:
            raise KeyError(
                f"doc {e.args[0]} has no retained token row (evicted by "
                "the retention policy); only union-find roots and the "
                "LRU window are verifiable") from None

    def release_rows(self, doc_ids) -> int:
        """Evict docs' interned-id rows into the free-slot pool.

        Frees the per-doc id array immediately (the dominant token-store
        memory); the fixed-width padded row is reused by the next
        extension.
        """
        if self._slot_of is None:
            self._slot_of = {i: i for i in range(self._n_rows)}
        released = 0
        for d in doc_ids:
            d = int(d)
            try:
                slot = self._slot_of.pop(d)
            except KeyError:
                raise KeyError(f"doc {d} has no retained row to release")
            self._rows[slot] = np.zeros((0,), dtype=np.int64)
            self._len_buf[slot] = 0
            self._free.append(slot)
            released += 1
        return released

    def frozen_rows(self) -> tuple[np.ndarray, np.ndarray, dict | None]:
        """(ids, lengths, doc->slot) safe against later session mutation
        (same snapshot protocol as ``SignatureVerifier.frozen_rows``:
        zero-copy while append-only, copied under the eviction layout
        where slot reuse rewrites rows in place)."""
        if self._slot_of is None:
            return self.ids, self.lengths, None
        return self.ids.copy(), self.lengths.copy(), dict(self._slot_of)

    def extend_token_lists(self, token_lists: list[list[str]]) -> None:
        """Intern + append new documents using the persistent vocab.

        Only verifiers built with ``from_token_lists`` /
        ``from_ngram_sets`` carry the vocab needed to intern new docs.
        """
        if self._vocab is None or self._ngram is None:
            raise ValueError(
                "verifier was built from raw id rows (no vocab); use "
                "extend_id_rows with consistently interned rows")
        self.extend_id_rows(
            _intern_rows(self._vocab,
                         (_ngram_set_of(toks, self._ngram)
                          for toks in token_lists)))

    @classmethod
    def from_token_lists(cls, token_lists: list[list[str]], n: int = 8,
                         batch_pairs: int = 2048) -> "ExactJaccardVerifier":
        """Intern every document's n-gram set to sorted int64 id rows."""
        vocab: dict[tuple, int] = {}
        rows = _intern_rows(
            vocab, (_ngram_set_of(toks, n) for toks in token_lists))
        return cls(rows, batch_pairs=batch_pairs, _vocab=vocab, _ngram=n)

    @classmethod
    def from_ngram_sets(cls, ngram_sets: list[set], batch_pairs: int = 2048,
                        n: int | None = None) -> "ExactJaccardVerifier":
        """Intern pre-built n-gram sets.  Pass ``n`` (the width the sets
        were built with) to enable ``extend_token_lists``; without it
        the verifier cannot know the width and extension by token lists
        is refused rather than silently mixing n-gram widths."""
        vocab: dict = {}
        rows = _intern_rows(vocab, ngram_sets)
        return cls(rows, batch_pairs=batch_pairs, _vocab=vocab, _ngram=n)

    def _verify_batch(self, pairs: np.ndarray) -> np.ndarray:
        pairs = self._slot_index(np.asarray(pairs))
        a_idx, b_idx = pairs[:, 0], pairs[:, 1]
        merged = np.concatenate(
            [self.ids[a_idx], self.ids[b_idx]], axis=1
        )
        merged.sort(axis=1)
        inter = np.sum(merged[:, 1:] == merged[:, :-1], axis=1)
        la = self.lengths[a_idx]
        lb = self.lengths[b_idx]
        union = la + lb - inter
        # Two empty sets have Jaccard 1.0 (matches jaccard.exact_jaccard).
        return np.where(
            union > 0, inter / np.maximum(union, 1), 1.0
        ).astype(np.float32)


def _ngram_set_of(toks: list[str], n: int):
    from repro.core.shingle import ngram_set

    return ngram_set(toks, n)


def _intern_rows(vocab: dict, ngram_sets) -> list[np.ndarray]:
    """Intern n-gram sets to sorted int64 id rows via a shared vocab."""
    rows = []
    for s in ngram_sets:
        ids = {vocab.setdefault(g, len(vocab)) for g in s}
        rows.append(np.sort(np.fromiter(ids, dtype=np.int64,
                                        count=len(ids))))
    return rows


def as_verifier(obj) -> BatchVerifier:
    """Coerce a BatchVerifier or scalar ``fn(a, b)`` into a verifier."""
    if isinstance(obj, BatchVerifier):
        return obj
    if callable(obj):
        return CallbackVerifier(obj)
    raise TypeError(f"not a verifier or similarity fn: {obj!r}")
