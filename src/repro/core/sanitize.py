"""``REPRO_SANITIZE=1``: opt-in runtime tripwires for debugging.

Two checks, both free when the knob is off:

* ``jax_debug_nans`` — jax raises at the first NaN any jitted stage
  produces instead of propagating garbage through the hash chain
  (``maybe_install`` flips the config once, at ``repro.core`` import);
* a **SessionView mutation tripwire** — the read path's whole
  concurrency story (DESIGN.md §9) is that a published view is frozen;
  RPR002 enforces it statically for this repo's code, and this hook
  enforces it dynamically against *anything* (user code, a buggy
  verifier, an aliased buffer mutated by a later ingest):
  ``query_view`` fingerprints the view's arrays on first use and
  re-checks the fingerprint at entry and exit of every query, raising
  ``SessionViewMutated`` the moment the bytes differ.

The env var is read per call, so tests can flip it with monkeypatch;
the fingerprint cache is keyed by ``(id(view), view.version)`` and
bounded, so long-running services can leave the knob on.
"""
from __future__ import annotations

import hashlib
import os
from collections import OrderedDict

import numpy as np

_MAX_TRACKED_VIEWS = 64
_fingerprints: OrderedDict[tuple[int, int], str] = OrderedDict()


class SessionViewMutated(RuntimeError):
    """A published (immutable) SessionView changed underneath a query."""


def enabled() -> bool:
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


def maybe_install() -> bool:
    """Turn on ``jax_debug_nans`` when the knob is set; idempotent."""
    if not enabled():
        return False
    import jax

    jax.config.update("jax_debug_nans", True)
    return True


def view_fingerprint(view) -> str:
    """Content hash of a view's query-visible arrays."""
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((view.version, view.n_docs, view.edge_threshold,
                   view.num_bands, view.rows_per_band)).encode())
    h.update(np.ascontiguousarray(view.labels).tobytes())
    h.update(np.ascontiguousarray(view.signatures).tobytes())
    if view.slot_of is not None:
        h.update(np.ascontiguousarray(view.slot_of).tobytes())
    if view.exact is not None:
        h.update(np.ascontiguousarray(view.exact.ids).tobytes())
        h.update(np.ascontiguousarray(view.exact.lengths).tobytes())
    for m in view.band_maps:
        h.update(str(len(m)).encode())
    return h.hexdigest()


def check_view(view, where: str) -> None:
    """Record-or-compare the view's fingerprint (no-op when disabled)."""
    if not enabled():
        return
    key = (id(view), view.version)
    fp = view_fingerprint(view)
    stored = _fingerprints.get(key)
    if stored is None:
        _fingerprints[key] = fp
        while len(_fingerprints) > _MAX_TRACKED_VIEWS:
            _fingerprints.popitem(last=False)
        return
    _fingerprints.move_to_end(key)
    if stored != fp:
        raise SessionViewMutated(
            f"SessionView v{view.version} content changed ({where}): "
            "published views are immutable (DESIGN.md §9) — a writer "
            "mutated labels/signatures/rows in place instead of "
            "publishing a new view (REPRO_SANITIZE tripwire)")
