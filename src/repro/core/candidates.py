"""Candidate generation layer of the staged dedup engine (paper §3.6/§4).

Staged-engine architecture (see also ``verify.py`` and ``engine.py``)::

    CandidateSource  ->  BatchVerifier  ->  ThresholdUnionFind
    (band runs)          (batched sims)     (guarded unions)

Every execution path — the in-memory host pipeline, the out-of-core
band stores, and the streaming two-phase mode — produces the same
structure: per band, a lexicographically sorted ``(band_value, doc)``
sequence whose equal-value runs are the candidate groups (the paper's
sort-based method, §3.6 method 2).  This module is the single home of
that sort -> equal-runs logic; ``CandidateSource`` implementations only
differ in where the band values come from:

* ``BandMatrixSource`` — a dense in-memory ``(D, b, 2)`` band matrix
  (the ``DedupPipeline`` host path).
* ``StoreBandSource`` — any out-of-core band store exposing
  ``read_band(j) -> (doc_ids, values)`` (``bandstore.Design1Store``,
  ``bandstore.Design2Store``), which is also how streamed chunks are
  consumed in ``StreamingDedup`` phase 2.
* ``ShardedEdgeSource`` — the per-device prescreened-edge buffers the
  ``dist_lsh`` all_to_all step emits; each surviving edge is a
  two-member run, so the host-side merge of the sharded path drives the
  very same engine.
* ``EdgeStreamSource`` — the streaming variant over the band-group
  buffers of the streamed step: each group's buffer is materialized
  lazily so the host merge overlaps the device shuffle of later groups.

The engine in ``engine.py`` drives any source through batched
verification; ``candidate_pairs`` below is the source-agnostic
enumeration used by benchmarks and tuning tools.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Protocol, runtime_checkable

import numpy as np


@dataclass(frozen=True)
class BandRuns:
    """One band's sorted values/docs plus its equal-value run boundaries.

    ``sorted_vals``: (N, 2) uint32 band values, lexicographically sorted;
    ``sorted_docs``: (N,) int64 doc ids in the same order;
    ``run_starts``/``run_ends``: index ranges of equal-value runs
    (every position belongs to exactly one run; singleton runs included).
    """

    band_id: int
    sorted_vals: np.ndarray
    sorted_docs: np.ndarray
    run_starts: np.ndarray
    run_ends: np.ndarray

    def iter_groups(self) -> Iterator[np.ndarray]:
        """Yield the doc-id array of every run with >= 2 members."""
        for s, e in zip(self.run_starts, self.run_ends):
            if e - s >= 2:
                yield self.sorted_docs[s:e]


def lexsort_band(vals: np.ndarray, docs: np.ndarray):
    """Sort one band's (value, doc) pairs by (hi, lo) value lanes."""
    order = np.lexsort((vals[:, 1], vals[:, 0]))
    return vals[order], docs[order]


def run_boundaries(sorted_vals: np.ndarray):
    """Equal-value run (starts, ends) of a sorted (N, 2) value array."""
    n = len(sorted_vals)
    if n == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z
    heads = np.ones(n, dtype=bool)
    heads[1:] = np.any(sorted_vals[1:] != sorted_vals[:-1], axis=-1)
    starts = np.flatnonzero(heads)
    ends = np.append(starts[1:], n)
    return starts, ends


def make_band_runs(band_id: int, vals: np.ndarray,
                   docs: np.ndarray) -> BandRuns:
    """Sort one band and find its runs (the shared sort->runs step)."""
    sv, sd = lexsort_band(np.asarray(vals), np.asarray(docs, dtype=np.int64))
    starts, ends = run_boundaries(sv)
    return BandRuns(band_id=band_id, sorted_vals=sv, sorted_docs=sd,
                    run_starts=starts, run_ends=ends)


@runtime_checkable
class CandidateSource(Protocol):
    """Anything that can yield per-band sorted run structures."""

    @property
    def num_docs(self) -> int: ...

    @property
    def num_bands(self) -> int: ...

    def iter_bands(self) -> Iterator[BandRuns]: ...


class BandMatrixSource:
    """In-memory (D, b, 2) band matrix (the host-pipeline source).

    ``doc_id_base`` maps row i to global doc id ``doc_id_base + i`` —
    the chunk-ingest convention of ``core.session.DedupSession`` (a
    chunk's band matrix is row-local but clusters into a global
    union-find), matching ``doc_offsets``/``doc_id_base`` elsewhere.
    """

    def __init__(self, bands: np.ndarray, doc_id_base: int = 0):
        bands = np.asarray(bands)
        assert bands.ndim == 3 and bands.shape[-1] == 2, bands.shape
        self.bands = bands
        self.doc_id_base = int(doc_id_base)
        self._doc_ids = self.doc_id_base + np.arange(
            bands.shape[0], dtype=np.int64)

    @property
    def num_docs(self) -> int:
        return self.doc_id_base + self.bands.shape[0]

    @property
    def num_bands(self) -> int:
        return self.bands.shape[1]

    def iter_bands(self) -> Iterator[BandRuns]:
        for j in range(self.num_bands):
            yield make_band_runs(j, self.bands[:, j, :], self._doc_ids)


class StoreBandSource:
    """Out-of-core source over a band store (Design 1 or Design 2).

    ``store`` needs only ``read_band(j) -> (doc_ids, values)`` — the
    paper's "select * where band_id = j" access pattern (§5.2).  This is
    the source the streaming two-phase mode reads in phase 2.
    """

    def __init__(self, store, num_bands: int, num_docs: int):
        self.store = store
        self._num_bands = int(num_bands)
        self._num_docs = int(num_docs)

    @property
    def num_docs(self) -> int:
        return self._num_docs

    @property
    def num_bands(self) -> int:
        return self._num_bands

    def iter_bands(self) -> Iterator[BandRuns]:
        for j in range(self._num_bands):
            docs, vals = self.store.read_band(j)
            yield make_band_runs(j, vals, docs)


class ShardedEdgeSource:
    """Source over the per-device verified-edge buffers of ``dist_lsh``.

    The sharded step's stage-1 prescreen emits bounded ``(head_doc,
    member_doc)`` edge buffers, one per device (shape ``(n_dev * e_cap,
    2)`` after the shard_map gather, with a matching validity mask).
    Each surviving edge becomes a two-member run; ``iter_bands`` yields
    one ``BandRuns`` per device buffer so the engine's run/band batching
    maps onto device shards.  Driving this source through
    ``engine.cluster_source`` gives the sharded path the same batched
    stage-2 verification, exclusion accounting, and threshold union-find
    as the host path.

    Edges touching doc ids outside ``[0, num_docs)`` — padding documents
    appended to make the corpus divisible by the device count — are
    dropped here so they can never union with real documents.
    """

    def __init__(self, edges: np.ndarray, edge_mask: np.ndarray | None = None,
                 *, num_docs: int, num_shards: int = 1):
        edges = np.asarray(edges).reshape(-1, 2)
        if edge_mask is None:
            mask = np.ones(len(edges), dtype=bool)
        else:
            mask = np.asarray(edge_mask).reshape(-1).astype(bool)
        assert len(mask) == len(edges), (edges.shape, mask.shape)
        self._num_docs = int(num_docs)
        self._shards: list[np.ndarray] = []
        for e, m in zip(np.array_split(edges, num_shards),
                        np.array_split(mask, num_shards)):
            e = e[m].astype(np.int64)
            e = e[(e >= 0).all(axis=-1) & (e < self._num_docs).all(axis=-1)]
            self._shards.append(e)

    @classmethod
    def from_device_buffers(cls, edges, edge_mask=None, *, num_docs: int,
                            num_shards: int = 1,
                            edge_offset: int = 0) -> "ShardedEdgeSource":
        """Materialize device edge buffers into a source.

        ``np.asarray`` blocks on the buffers' device computation (and
        nothing else — later band-groups keep shuffling); ``edge_offset``
        shifts global ids back to chunk-local rows (the ``doc_id_base``
        convention).  This is the single home of that conversion, shared
        by the streamed host merge and ``EdgeStreamSource``.
        """
        edges = np.asarray(edges).astype(np.int64) - int(edge_offset)
        if edge_mask is not None:
            edge_mask = np.asarray(edge_mask)
        return cls(edges, edge_mask, num_docs=num_docs,
                   num_shards=num_shards)

    @property
    def num_docs(self) -> int:
        return self._num_docs

    @property
    def num_bands(self) -> int:
        return len(self._shards)

    @property
    def num_edges(self) -> int:
        return sum(len(e) for e in self._shards)

    def iter_bands(self) -> Iterator[BandRuns]:
        for i, e in enumerate(self._shards):
            n = len(e)
            # Synthetic per-edge band value: run j is the doc pair of
            # edge j, so the shared runs machinery sees each edge as a
            # two-member candidate group.
            vals = np.zeros((2 * n, 2), dtype=np.uint32)
            vals[:, 0] = np.repeat(np.arange(n, dtype=np.uint32), 2)
            starts = 2 * np.arange(n, dtype=np.int64)
            yield BandRuns(band_id=i, sorted_vals=vals,
                           sorted_docs=e.reshape(-1),
                           run_starts=starts, run_ends=starts + 2)


class EdgeStreamSource:
    """Streaming variant of ``ShardedEdgeSource`` over per-group buffers.

    The band-group streamed ``dist_lsh`` step emits one (edges, mask)
    buffer per band-group, each still resident on the device when the
    host merge starts.  This source materializes group g's buffer only
    when the engine reaches it — ``np.asarray`` blocks on *that group's*
    computation alone, so (JAX dispatch being asynchronous) the host
    merge of group g overlaps the device shuffle of groups g+1..G-1.

    ``groups`` is an iterable of ``(edges, mask)`` tuples (device or
    host arrays; mask may be None).  ``edge_offset`` is subtracted from
    edge ids before the range filter — the ``doc_id_base`` shift of
    chunked corpora.  ``on_group(g, edges, mask)`` runs right after
    group g is materialized (before its edges are fed), which is where
    the device-resident stage 2 registers its pre-computed scores.
    """

    def __init__(self, groups, *, num_docs: int, num_shards: int = 1,
                 edge_offset: int = 0, on_group=None):
        self._groups = groups
        self._num_docs = int(num_docs)
        self._num_shards = int(num_shards)
        self._edge_offset = int(edge_offset)
        self._on_group = on_group
        self.num_edges = 0
        self.groups_consumed = 0

    @property
    def num_docs(self) -> int:
        return self._num_docs

    @property
    def num_bands(self) -> int:
        """#BandRuns yielded so far (groups consumed x device shards)."""
        return self.groups_consumed * self._num_shards

    def iter_bands(self) -> Iterator[BandRuns]:
        for g, (edges, mask) in enumerate(self._groups):
            src = ShardedEdgeSource.from_device_buffers(
                edges, mask, num_docs=self._num_docs,
                num_shards=self._num_shards,
                edge_offset=self._edge_offset)   # blocks on group g only
            if self._on_group is not None:
                self._on_group(g, edges, mask)
            self.num_edges += src.num_edges
            self.groups_consumed += 1
            yield from src.iter_bands()


# ---------------------------------------------------------------------------
# Pair enumeration (paper-faithful all-pairs within runs)
# ---------------------------------------------------------------------------

def pairs_in_runs(
    sorted_vals: np.ndarray,
    sorted_docs: np.ndarray,
    max_pairs: int | None = None,
) -> np.ndarray:
    """All-pairs within equal runs of one sorted band (O(run^2)).

    Returns (P, 2) int64 candidate pairs with a < b by doc id; bounded
    by ``max_pairs`` when given.  This is the enumeration behind
    ``lsh.enumerate_pairs_in_runs`` and the store-backed path.  Doc ids
    stay int64 end-to-end: chunked corpora assign global ids via
    ``doc_offsets`` and can exceed 2^31, which the historical int32
    downcast silently wrapped.
    """
    starts, ends = run_boundaries(np.asarray(sorted_vals))
    pairs = []
    total = 0
    for s, e in zip(starts, ends):
        k = e - s
        if k < 2:
            continue
        docs = np.sort(np.asarray(sorted_docs[s:e], dtype=np.int64))
        ii, jj = np.triu_indices(k, k=1)
        p = np.stack([docs[ii], docs[jj]], axis=-1)
        pairs.append(p)
        total += len(p)
        if max_pairs is not None and total >= max_pairs:
            break
    if not pairs:
        return np.zeros((0, 2), dtype=np.int64)
    out = np.concatenate(pairs)
    return out[:max_pairs] if max_pairs is not None else out


def candidate_pairs(
    source: CandidateSource, max_pairs_per_band: int | None = None
) -> np.ndarray:
    """All candidate pairs of a source, deduplicated across bands.

    Returns a sorted (P, 2) int64 array — the source-agnostic
    replacement for ``lsh.all_candidate_pairs`` and
    ``bandstore.candidate_pairs_from_store`` (int64 so global doc ids
    >= 2^31 from chunked ``doc_offsets`` corpora survive).
    """
    seen: set[tuple[int, int]] = set()
    for br in source.iter_bands():
        pairs = pairs_in_runs(br.sorted_vals, br.sorted_docs,
                              max_pairs_per_band)
        seen.update(map(tuple, pairs.tolist()))
    if not seen:
        return np.zeros((0, 2), dtype=np.int64)
    return np.array(sorted(seen), dtype=np.int64)
