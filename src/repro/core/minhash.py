"""MinHash signatures (paper §3).

``signatures``: for each document d and each of M seeded hash functions,
sig[d, m] = min over the doc's n-gram hashes x of h_m(x).  The estimate of
Jaccard(A, B) is then mean_m[ sig_A[m] == sig_B[m] ]  (paper §3.3-3.4).

Pure-jnp implementation here; the Pallas kernel in
``repro.kernels.minhash`` computes the same function with explicit VMEM
tiling and is validated against this module.
"""
from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.hashing import GOLDEN32, U32_MAX, fmix32, make_seeds


@functools.partial(jax.jit, static_argnames=("m_chunk",))
def signatures(
    ngrams: jnp.ndarray,
    valid: jnp.ndarray,
    seeds: jnp.ndarray,
    m_chunk: int = 16,
) -> jnp.ndarray:
    """MinHash signature matrix.

    ngrams: (D, L) uint32 n-gram hashes; valid: (D, L) bool; seeds: (M,).
    Returns (D, M) uint32.  Invalid positions contribute U32_MAX.
    Memory is bounded by chunking over seeds: peak extra (D, L, m_chunk).
    """
    ngrams = ngrams.astype(jnp.uint32)
    seeds = seeds.astype(jnp.uint32)
    M = seeds.shape[0]
    pad = (-M) % m_chunk
    seeds_p = jnp.pad(seeds, (0, pad)).reshape(-1, m_chunk)
    masked_max = jnp.uint32(U32_MAX)

    def one_chunk(chunk_seeds):
        # (D, L, 1) x (1, 1, C) -> (D, L, C)
        h = fmix32(ngrams[:, :, None] * GOLDEN32 + chunk_seeds[None, None, :])
        h = jnp.where(valid[:, :, None], h, masked_max)
        return jnp.min(h, axis=1)  # (D, C)

    sig = jax.lax.map(one_chunk, seeds_p.astype(jnp.uint32))  # (M/C, D, C)
    sig = jnp.moveaxis(sig, 0, 1).reshape(ngrams.shape[0], -1)
    return sig[:, :M]


def signatures_np(
    ngrams: np.ndarray, valid: np.ndarray, seeds: np.ndarray
) -> np.ndarray:
    """Numpy oracle."""
    from repro.core.hashing import hash_u32_np

    D, L = ngrams.shape
    M = seeds.shape[0]
    out = np.full((D, M), U32_MAX, dtype=np.uint32)
    for m in range(M):
        h = hash_u32_np(ngrams, seeds[m])
        h = np.where(valid, h, np.uint32(U32_MAX))
        out[:, m] = h.min(axis=1)
    return out


def estimate_jaccard(sig_a: jnp.ndarray, sig_b: jnp.ndarray) -> jnp.ndarray:
    """Signature-agreement Jaccard estimate (paper §3.4): m/M.

    sig_a, sig_b: (..., M) uint32.
    """
    return jnp.mean((sig_a == sig_b).astype(jnp.float32), axis=-1)


def minhash_from_tokens(
    tokens: jnp.ndarray,
    lengths: jnp.ndarray,
    seeds: jnp.ndarray,
    n: int = 8,
) -> jnp.ndarray:
    """Fused convenience path: token matrix -> signatures."""
    from repro.core.shingle import ngram_hashes

    ngrams, valid = ngram_hashes(tokens, lengths, n=n)
    return signatures(ngrams, valid, seeds)


def default_seeds(m: int = 100) -> np.ndarray:
    return make_seeds(m)
