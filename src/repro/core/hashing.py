"""32-bit-native hash families for TPU minhashing.

The paper uses MurmurHash with M random seeds as its approximate random
permutations (paper §3.5, §7.3).  TPUs are 32-bit-native, so we build the
family from the Murmur3 *finalizer* ``fmix32`` — a bijection on uint32 —
seeded by xor/multiply mixing.  A bijection composed with per-seed mixing
gives a well-spread hash family; this is the same family `datasketch`-style
minhash libraries use in 32-bit mode.

Two independent lanes (different seed streams) give ~64-bit discrimination
where the paper uses 64-bit values (band values, exact-dup keys).

Everything here is pure jnp on uint32 and is safe inside Pallas kernels
(only xor / shift / 32-bit multiply).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

# Murmur3 constants.
_FMIX_C1 = np.uint32(0x85EBCA6B)
_FMIX_C2 = np.uint32(0xC2B2AE35)
# Knuth multiplicative constant (odd -> bijective multiply mod 2^32).
GOLDEN32 = np.uint32(0x9E3779B9)
# Polynomial base for rolling n-gram hashes (odd).
NGRAM_BASE = np.uint32(0x01000193)  # FNV prime.
NGRAM_BASE2 = np.uint32(0x0001F7B7)  # independent odd base for lane 2.

U32_MAX = np.uint32(0xFFFFFFFF)

# FNV-1a parameters (the host token-id hash; the byte-shingle kernel
# reproduces it on device, so the constants live in the shared family).
FNV_OFFSET32 = np.uint32(2166136261)
FNV_PRIME32 = np.uint32(16777619)


def fmix32(x: jnp.ndarray) -> jnp.ndarray:
    """Murmur3 finalizer: bijective avalanche on uint32."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * _FMIX_C1
    x = x ^ (x >> 13)
    x = x * _FMIX_C2
    x = x ^ (x >> 16)
    return x


def hash_u32(x: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """Seeded hash: h_seed(x) = fmix32(x * GOLDEN + seed).

    For a fixed seed this is a bijection on uint32 (odd multiply, xor-shift
    avalanche), i.e. a legitimate "random permutation" stand-in for
    minhashing (paper §3.5).
    """
    x = x.astype(jnp.uint32)
    seed = seed.astype(jnp.uint32)
    return fmix32(x * GOLDEN32 + seed)


def make_seeds(m: int, key: int = 0x5EED) -> np.ndarray:
    """M deterministic 32-bit seeds (paper: default RNG -> M seeds)."""
    rng = np.random.RandomState(key & 0x7FFFFFFF)
    return rng.randint(0, 2**32, size=(m,), dtype=np.uint64).astype(np.uint32)


def fmix32_np(x: np.ndarray) -> np.ndarray:
    """Numpy oracle for fmix32 (uint32, wraparound semantics)."""
    x = x.astype(np.uint32)
    with np.errstate(over="ignore"):
        x = x ^ (x >> np.uint32(16))
        x = (x * _FMIX_C1).astype(np.uint32)
        x = x ^ (x >> np.uint32(13))
        x = (x * _FMIX_C2).astype(np.uint32)
        x = x ^ (x >> np.uint32(16))
    return x


def hash_u32_np(x: np.ndarray, seed) -> np.ndarray:
    x = x.astype(np.uint32)
    with np.errstate(over="ignore"):
        return fmix32_np((x * GOLDEN32).astype(np.uint32) + np.uint32(seed))


def fmix32_inverse_np(x: np.ndarray) -> np.ndarray:
    """Inverse of fmix32 (proves bijectivity; used by property tests)."""
    def unshift(v, s):
        # invert v ^= v >> s for uint32
        r = v.copy()
        for _ in range(0, 32, s):
            r = v ^ (r >> np.uint32(s))
        return r

    inv_c1 = np.uint32(pow(int(_FMIX_C1), -1, 2**32))
    inv_c2 = np.uint32(pow(int(_FMIX_C2), -1, 2**32))
    x = x.astype(np.uint32)
    with np.errstate(over="ignore"):
        x = unshift(x, 16)
        x = (x * inv_c2).astype(np.uint32)
        x = unshift(x, 13)
        x = (x * inv_c1).astype(np.uint32)
        x = unshift(x, 16)
    return x
