"""Banded Locality-Sensitive Hashing (paper §4).

The (M x D) signature matrix is split into b bands of r rows.  Each band's
r values are folded into one compact value per document ("band matrix",
paper §4.3 — the paper folds to a 64-bit integer; we use two independent
32-bit lanes, see DESIGN.md §2/§5).  Candidate pairs are documents sharing
a band value in at least one band:  P(candidate) = 1 - (1 - s^r)^b.

Candidate generation follows the paper's sort-based method (§3.6 method 2):
sort (band_value, doc) pairs, find equal runs.  Two enumeration modes:

* ``enumerate_pairs_in_runs`` — all pairs within a run (paper-faithful,
  O(run^2); bounded by ``max_pairs`` for static shapes).
* star edges (each doc paired with its run head) — O(run) edges; preserves
  connectivity for clustering and attacks the paper's "too many candidate
  pairs" problem (beyond-paper; see DESIGN.md).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.hashing import fmix32, GOLDEN32

# Per-lane fold seeds (arbitrary distinct constants).
_LANE_SEEDS = (np.uint32(0x2545F491), np.uint32(0x9E3779B9))


def candidate_probability(s, r: int, b: int):
    """P(candidate | Jaccard=s) = 1 - (1 - s^r)^b  (paper §4.4)."""
    s = jnp.asarray(s, dtype=jnp.float64 if jax.config.jax_enable_x64 else jnp.float32)
    return 1.0 - (1.0 - s**r) ** b


@functools.partial(jax.jit, static_argnames=("r",))
def band_values(sig: jnp.ndarray, r: int) -> jnp.ndarray:
    """Fold the signature matrix into the band matrix.

    sig: (D, M) uint32, M = b*r.  Returns (D, b, 2) uint32 — two 32-bit
    lanes per band value (~64-bit discrimination, paper §4.3).
    Fold: h <- fmix32(h * GOLDEN + sig_row), chained over the r rows,
    one chain per lane seed.
    """
    D, M = sig.shape
    assert M % r == 0, f"M={M} not divisible by r={r}"
    b = M // r
    sig = sig.astype(jnp.uint32).reshape(D, b, r)
    lanes = []
    for lane_seed in _LANE_SEEDS:
        h = jnp.full((D, b), lane_seed, dtype=jnp.uint32)
        for k in range(r):
            h = fmix32(h * GOLDEN32 + sig[:, :, k])
        lanes.append(h)
    return jnp.stack(lanes, axis=-1)  # (D, b, 2)


def band_values_np(sig: np.ndarray, r: int) -> np.ndarray:
    from repro.core.hashing import fmix32_np

    D, M = sig.shape
    b = M // r
    sig = sig.astype(np.uint32).reshape(D, b, r)
    lanes = []
    with np.errstate(over="ignore"):
        for lane_seed in _LANE_SEEDS:
            h = np.full((D, b), lane_seed, dtype=np.uint32)
            for k in range(r):
                h = fmix32_np((h * GOLDEN32).astype(np.uint32) + sig[:, :, k])
            lanes.append(h)
    return np.stack(lanes, axis=-1)


# ---------------------------------------------------------------------------
# Sort-based candidate generation (static shapes throughout)
# ---------------------------------------------------------------------------

@jax.jit
def sort_band(vals: jnp.ndarray, doc_ids: jnp.ndarray):
    """Lexicographic sort of one band's (value_hi, value_lo, doc) triples.

    vals: (D, 2) uint32; doc_ids: (D,) int32.
    Returns sorted (vals (D,2), docs (D,)).
    """
    hi, lo = vals[:, 0], vals[:, 1]
    hi_s, lo_s, doc_s = jax.lax.sort((hi, lo, doc_ids), num_keys=2)
    return jnp.stack([hi_s, lo_s], axis=-1), doc_s


@jax.jit
def run_heads(sorted_vals: jnp.ndarray) -> jnp.ndarray:
    """Boolean mask: position starts a new equal-value run."""
    same = jnp.all(sorted_vals[1:] == sorted_vals[:-1], axis=-1)
    return jnp.concatenate([jnp.array([True]), ~same])


@jax.jit
def star_edges(sorted_vals: jnp.ndarray, sorted_docs: jnp.ndarray):
    """Candidate edges (doc -> run head) for one sorted band.

    Returns (edges (D, 2) int32, mask (D,) bool).  Edge i connects
    sorted_docs[i] to the first doc of its run; mask is False for run
    heads themselves (no self edge).  O(D) edges; connectivity-equivalent
    to the paper's O(run^2) enumeration for clustering purposes.
    """
    heads = run_heads(sorted_vals)
    idx = jnp.arange(sorted_docs.shape[0])
    head_idx = jax.lax.cummax(jnp.where(heads, idx, 0), axis=0)
    head_doc = sorted_docs[head_idx]
    edges = jnp.stack([head_doc, sorted_docs], axis=-1).astype(jnp.int32)
    mask = ~heads
    return edges, mask


def enumerate_pairs_in_runs(
    sorted_vals: np.ndarray, sorted_docs: np.ndarray, max_pairs: int | None = None
) -> np.ndarray:
    """Paper-faithful all-pairs within equal runs (host path, ragged).

    Returns (P, 2) int64 array of candidate pairs (a < b by doc id;
    int64 end-to-end so chunked global ids >= 2^31 cannot wrap).
    Delegates to the shared staged-engine layer (``candidates.py``).
    """
    from repro.core.candidates import pairs_in_runs

    return pairs_in_runs(sorted_vals, sorted_docs, max_pairs)


@dataclass(frozen=True)
class LSHParams:
    """Paper defaults: M=100, r=2, b=50, n=8 (paper §7.2, §9.1)."""

    num_hashes: int = 100
    rows_per_band: int = 2
    ngram: int = 8

    @property
    def num_bands(self) -> int:
        return self.num_hashes // self.rows_per_band

    def threshold_estimate(self) -> float:
        """Approximate similarity threshold (1/b)^(1/r)."""
        return float((1.0 / self.num_bands) ** (1.0 / self.rows_per_band))


def all_candidate_pairs(
    bands: np.ndarray, max_pairs_per_band: int | None = None
) -> np.ndarray:
    """All candidate pairs across bands (host path; dedups across bands).

    bands: (D, b, 2) uint32.  Delegates to the shared staged-engine
    candidate layer (``candidates.BandMatrixSource``).
    """
    from repro.core.candidates import BandMatrixSource, candidate_pairs

    return candidate_pairs(BandMatrixSource(bands), max_pairs_per_band)
