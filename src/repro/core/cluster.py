"""Clustering driver: the paper §6.5 ``find_candidate_pairs`` procedure.

For each band: sort, find equal runs, path-compress members to their set
roots, evaluate Jaccard only for pairs not already co-clustered, and Union
when sim > edge_threshold.  Pairs whose endpoints already share a root are
*excluded* from Jaccard evaluation — the paper's headline saving
(Table 5: ~53% of evaluations eliminated at edge threshold 75%).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from repro.core.unionfind import ThresholdUnionFind


@dataclass
class ClusterStats:
    pairs_generated: int = 0
    pairs_evaluated: int = 0
    pairs_excluded: int = 0  # skipped Jaccard computations (paper Table 5)
    pairs_above_edge: int = 0
    unions_done: int = 0
    unions_rejected: int = 0


def cluster_bands(
    bands: np.ndarray,
    similarity_fn: Callable[[int, int], float],
    edge_threshold: float,
    tree_threshold: float,
    use_disjoint_sets: bool = True,
) -> tuple[ThresholdUnionFind, ClusterStats, list[tuple[int, int, float]]]:
    """Run paper §6.5 over all bands.

    bands: (D, b, 2) uint32 band matrix.
    similarity_fn(a_doc, b_doc) -> exact Jaccard (evaluated lazily).
    Returns (union-find, stats, evaluated_pairs [(a, b, sim), ...]).

    With ``use_disjoint_sets=False`` every candidate pair is evaluated
    (the paper's non-clustered baseline used for Table 5's "6388 pairs").
    """
    D, b, _ = bands.shape
    uf = ThresholdUnionFind(D, tree_threshold)
    stats = ClusterStats()
    evaluated: dict[tuple[int, int], float] = {}
    doc_ids = np.arange(D, dtype=np.int64)

    for j in range(b):
        order = np.lexsort((bands[:, j, 1], bands[:, j, 0]))
        vals = bands[order, j, :]
        docs = doc_ids[order]
        heads = np.ones(D, dtype=bool)
        heads[1:] = np.any(vals[1:] != vals[:-1], axis=-1)
        starts = np.flatnonzero(heads)
        ends = np.append(starts[1:], D)
        for s, e in zip(starts, ends):
            if e - s < 2:
                continue
            members = docs[s:e]
            if use_disjoint_sets:
                # "replace D with D.find()" — compress to current roots.
                roots = np.array([uf.find(int(d)) for d in members])
                uniq = np.unique(roots)
            else:
                uniq = np.sort(members)
            k = len(uniq)
            stats.pairs_generated += (e - s) * (e - s - 1) // 2
            if k < 2:
                # All members already co-clustered: every pair excluded.
                stats.pairs_excluded += (e - s) * (e - s - 1) // 2
                continue
            # Pairs collapsed by prior clustering are excluded too.
            stats.pairs_excluded += (
                (e - s) * (e - s - 1) // 2 - k * (k - 1) // 2
            )
            for ii in range(k):
                for jj in range(ii + 1, k):
                    a, c = int(uniq[ii]), int(uniq[jj])
                    key = (min(a, c), max(a, c))
                    if key in evaluated:
                        stats.pairs_excluded += 1
                        continue
                    sim = float(similarity_fn(*key))
                    evaluated[key] = sim
                    stats.pairs_evaluated += 1
                    if sim > edge_threshold:
                        stats.pairs_above_edge += 1
                        if use_disjoint_sets:
                            before = uf.n_unions
                            uf.union(a, c, sim)
                            if uf.n_unions > before:
                                stats.unions_done += 1
                            else:
                                stats.unions_rejected += 1
    pairs = [(a, b_, s) for (a, b_), s in sorted(evaluated.items())]
    return uf, stats, pairs


def modularity(
    labels: np.ndarray, pairs: list[tuple[int, int, float]]
) -> float:
    """Weighted modularity Q (paper §10, Newman 2006) of a clustering.

    Edge weights are the Jaccard similarities of the evaluated pairs.
    """
    import networkx as nx

    g = nx.Graph()
    g.add_nodes_from(range(len(labels)))
    for a, b, s in pairs:
        if s > 0:
            g.add_edge(a, b, weight=s)
    if g.number_of_edges() == 0:
        return 0.0
    comms: dict[int, set] = {}
    for i, l in enumerate(labels):
        comms.setdefault(int(l), set()).add(i)
    return nx.community.modularity(g, list(comms.values()), weight="weight")
