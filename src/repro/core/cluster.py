"""Clustering driver: the paper §6.5 ``find_candidate_pairs`` procedure.

Thin driver over the staged engine (``engine.cluster_source``):
``CandidateSource -> BatchVerifier -> ThresholdUnionFind``.  For each
band: sort, find equal runs, path-compress members to their set roots,
batch-verify Jaccard only for pairs not already co-clustered, and Union
when sim > edge_threshold.  Pairs whose endpoints already share a root
are *excluded* from Jaccard evaluation — the paper's headline saving
(Table 5: ~53% of evaluations eliminated at edge threshold 75%).

``ClusterStats`` lives in ``engine`` and is re-exported here for
backward compatibility.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from repro.core.candidates import BandMatrixSource
from repro.core.engine import ClusterStats, cluster_source
from repro.core.unionfind import ThresholdUnionFind
from repro.core.verify import BatchVerifier

__all__ = ["ClusterStats", "cluster_bands", "modularity"]


def cluster_bands(
    bands: np.ndarray,
    similarity_fn: Callable[[int, int], float] | BatchVerifier,
    edge_threshold: float,
    tree_threshold: float,
    use_disjoint_sets: bool = True,
    *,
    batch: str = "run",
    max_batch_pairs: int = 8192,
) -> tuple[ThresholdUnionFind, ClusterStats, list[tuple[int, int, float]]]:
    """Run paper §6.5 over an in-memory band matrix.

    bands: (D, b, 2) uint32 band matrix.
    similarity_fn: a ``verify.BatchVerifier`` (batched, preferred) or a
    scalar ``fn(a_doc, b_doc) -> exact Jaccard`` callable (wrapped).
    Returns (union-find, stats, evaluated_pairs [(a, b, sim), ...]).

    With ``use_disjoint_sets=False`` every candidate pair is evaluated
    (the paper's non-clustered baseline used for Table 5's "6388 pairs").
    See ``engine.cluster_source`` for the ``batch`` granularity knob.
    """
    return cluster_source(
        BandMatrixSource(bands),
        similarity_fn,
        edge_threshold,
        tree_threshold,
        use_disjoint_sets=use_disjoint_sets,
        batch=batch,
        max_batch_pairs=max_batch_pairs,
    )


def modularity(
    labels: np.ndarray, pairs: list[tuple[int, int, float]]
) -> float:
    """Weighted modularity Q (paper §10, Newman 2006) of a clustering.

    Edge weights are the Jaccard similarities of the evaluated pairs.
    """
    import networkx as nx

    g = nx.Graph()
    g.add_nodes_from(range(len(labels)))
    for a, b, s in pairs:
        if s > 0:
            g.add_edge(a, b, weight=s)
    if g.number_of_edges() == 0:
        return 0.0
    comms: dict[int, set] = {}
    for i, l in enumerate(labels):
        comms.setdefault(int(l), set()).add(i)
    return nx.community.modularity(g, list(comms.values()), weight="weight")
