"""Distributed LSH dedup step (shard_map; the production-mesh path).

Maps the paper's database designs onto a TPU pod (DESIGN.md §2):

* Docs are sharded over every mesh device ("docs" view of the mesh) —
  each device holds a *band_part* (its doc slice × all bands), i.e. the
  paper's Cassandra **Design 2** layout.
* Candidate generation per band is a bucket-by-value ``all_to_all``
  (value-range partitioning — the "select * where band_id = id" query
  becomes an ICI shuffle) followed by a local lexicographic sort and run
  detection — the paper's sort-based method (§3.6 method 2).
* Star edges (member -> run head) + on-device signature-prefix
  verification produce bounded, statically-shaped verified-edge buffers.

Everything is static-shape: buckets have fixed capacity with overflow
*counted* (never silently dropped — callers re-salt and retry or fall back
to the host path for the overflow docs).

This is the sharded sibling of the staged engine in ``core.engine``
(CandidateSource -> BatchVerifier -> ThresholdUnionFind): candidate
generation is the on-device all_to_all + sort, verification is the
on-device signature-prefix compare.  ROADMAP "Open items" tracks porting
this path onto the shared ``verify.py`` layer.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.jaxcompat import shard_map_compat

from repro.core.hashing import GOLDEN32, U32_MAX, fmix32
from repro.core.lsh import band_values
from repro.core.minhash import signatures
from repro.core.shingle import ngram_hashes

INVALID = jnp.uint32(U32_MAX)


@dataclass(frozen=True)
class DistLSHConfig:
    ngram: int = 8
    num_hashes: int = 100
    rows_per_band: int = 2
    verify_k: int = 32          # signature prefix length exchanged for verify
    edge_threshold: float = 0.75
    bucket_slack: float = 2.0   # capacity = slack * D_local / n_dev
    edge_capacity: int = 4096   # verified-edge buffer per device
    m_chunk: int = 16

    @property
    def num_bands(self) -> int:
        return self.num_hashes // self.rows_per_band


def docs_mesh(devices=None) -> Mesh:
    """Flat 'docs' view over all devices (same devices as the prod mesh)."""
    devices = np.asarray(devices if devices is not None else jax.devices())
    return Mesh(devices.reshape(-1), ("docs",))


def _bucket_scatter(entries: jnp.ndarray, bucket: jnp.ndarray,
                    n_dev: int, cap: int):
    """Scatter entries (D_loc, F) into (n_dev, cap, F) by bucket id.

    Returns (out, overflow_count).  Overflow entries are dropped from the
    buffer but counted.
    """
    d_loc, f = entries.shape
    order = jnp.argsort(bucket)              # stable
    sb = bucket[order]
    se = entries[order]
    idx = jnp.arange(d_loc, dtype=jnp.int32)
    heads = jnp.concatenate([jnp.array([True]), sb[1:] != sb[:-1]])
    seg_start = jax.lax.cummax(jnp.where(heads, idx, 0), axis=0)
    pos = idx - seg_start
    ok = pos < cap
    overflow = jnp.sum(~ok)
    out = jnp.full((n_dev * cap, f), INVALID, dtype=jnp.uint32)
    flat_idx = jnp.where(ok, sb * cap + pos, n_dev * cap)  # OOB drop
    out = out.at[flat_idx].set(se, mode="drop")
    return out.reshape(n_dev, cap, f), overflow


def _band_exchange_and_edges(band_hi, band_lo, doc_ids, sig_k, cfg,
                             axis_name: str, n_dev: int, cap: int):
    """One band: bucket -> all_to_all -> sort -> star edges -> verify.

    All inputs are per-device locals:
      band_hi/lo: (D_loc,) uint32; doc_ids: (D_loc,) uint32 global ids;
      sig_k: (D_loc, k) uint32.
    Returns (edges (n_dev*cap, 2) uint32, sims (n_dev*cap,) f32,
             edge_mask, n_candidates, overflow).
    """
    k = cfg.verify_k
    shift = 32 - max(1, int(np.log2(n_dev))) if n_dev > 1 else 32
    bucket = (band_hi >> shift).astype(jnp.int32) if n_dev > 1 else (
        jnp.zeros_like(band_hi, dtype=jnp.int32))
    entries = jnp.concatenate(
        [band_hi[:, None], band_lo[:, None], doc_ids[:, None], sig_k],
        axis=-1,
    ).astype(jnp.uint32)                      # (D_loc, 3 + k)
    boxed, overflow = _bucket_scatter(entries, bucket, n_dev, cap)
    if n_dev > 1:
        boxed = jax.lax.all_to_all(boxed, axis_name, 0, 0, tiled=False)
    recv = boxed.reshape(n_dev * cap, 3 + k)

    hi, lo, doc = recv[:, 0], recv[:, 1], recv[:, 2]
    sig = recv[:, 3:]
    valid = doc != INVALID
    # Sort invalids to the end: key (valid desc, hi, lo).
    inv_key = (~valid).astype(jnp.uint32)
    iota = jnp.arange(hi.shape[0], dtype=jnp.uint32)
    inv_s, hi_s, lo_s, doc_s, perm = jax.lax.sort(
        (inv_key, hi, lo, doc, iota), num_keys=3)
    sig_s = sig[perm]
    valid_s = inv_s == 0

    same = (hi_s[1:] == hi_s[:-1]) & (lo_s[1:] == lo_s[:-1]) & valid_s[1:]
    heads = jnp.concatenate([jnp.array([True]), ~same])
    idx = jnp.arange(hi_s.shape[0], dtype=jnp.int32)
    head_idx = jax.lax.cummax(jnp.where(heads, idx, 0), axis=0)
    head_doc = doc_s[head_idx]
    head_sig = sig_s[head_idx]
    cand_mask = (~heads) & valid_s            # member of a run
    est = jnp.mean((sig_s == head_sig).astype(jnp.float32), axis=-1)
    edge_mask = cand_mask & (est >= cfg.edge_threshold)
    edges = jnp.stack([head_doc, doc_s], axis=-1)
    return edges, est, edge_mask, jnp.sum(cand_mask), overflow


def make_dedup_step(cfg: DistLSHConfig, mesh: Mesh):
    """Build the jit-able sharded dedup step for ``mesh`` ('docs' axis).

    Signature: (tokens (D, L) uint32, lengths (D,) int32, seeds (M,))
      -> dict(edges (n_dev*E_cap, 2), sims, edge_mask, stats)
    """
    n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    axis = mesh.axis_names[0]

    def local_step(tokens, lengths, seeds):
        # tokens: (D_loc, L) local shard.
        d_loc = tokens.shape[0]
        cap = max(1, int(np.ceil(cfg.bucket_slack * d_loc / n_dev)))
        ng, valid = ngram_hashes(tokens, lengths, n=cfg.ngram)
        sig = signatures(ng, valid, seeds, m_chunk=cfg.m_chunk)
        bands = band_values(sig, cfg.rows_per_band)  # (D_loc, b, 2)
        dev = jax.lax.axis_index(axis).astype(jnp.uint32)
        doc_ids = dev * jnp.uint32(d_loc) + jnp.arange(
            d_loc, dtype=jnp.uint32)
        sig_k = sig[:, : cfg.verify_k]

        e_cap = cfg.edge_capacity

        def per_band(carry, j):
            buf, buf_sim, count, tot_cand, tot_ovf = carry
            edges, est, emask, n_cand, ovf = _band_exchange_and_edges(
                bands[:, j, 0], bands[:, j, 1], doc_ids, sig_k,
                cfg, axis, n_dev, cap)
            # Append masked edges into the fixed buffer.
            offs = jnp.cumsum(emask.astype(jnp.int32)) - 1
            dst = jnp.where(emask, count + offs, e_cap)  # OOB drop
            buf = buf.at[dst].set(edges, mode="drop")
            buf_sim = buf_sim.at[dst].set(est, mode="drop")
            new_count = jnp.minimum(count + jnp.sum(emask), e_cap)
            dropped = count + jnp.sum(emask) - new_count
            return (buf, buf_sim, new_count, tot_cand + n_cand,
                    tot_ovf + ovf + dropped), None

        buf0 = jnp.full((e_cap, 2), INVALID, dtype=jnp.uint32)
        sim0 = jnp.zeros((e_cap,), dtype=jnp.float32)
        (buf, buf_sim, count, n_cand, ovf), _ = jax.lax.scan(
            per_band, (buf0, sim0, jnp.int32(0), jnp.int32(0),
                       jnp.int32(0)),
            jnp.arange(cfg.num_bands))
        emask = jnp.arange(e_cap) < count
        stats = jnp.stack(
            [count, n_cand, ovf]).astype(jnp.int32)[None]  # (1, 3)
        return buf, buf_sim, emask, stats

    sharded = shard_map_compat(
        local_step,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P()),
        out_specs=(P(axis), P(axis), P(axis), P(axis)),
        check_replication=False,
    )

    @jax.jit
    def dedup_step(tokens, lengths, seeds):
        edges, sims, emask, stats = sharded(tokens, lengths, seeds)
        return {
            "edges": edges, "sims": sims, "edge_mask": emask,
            "stats": stats,
        }

    return dedup_step


def dedup_input_specs(cfg: DistLSHConfig, num_docs: int, max_len: int):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    return {
        "tokens": jax.ShapeDtypeStruct((num_docs, max_len), jnp.uint32),
        "lengths": jax.ShapeDtypeStruct((num_docs,), jnp.int32),
        "seeds": jax.ShapeDtypeStruct((cfg.num_hashes,), jnp.uint32),
    }
