"""Distributed LSH dedup step (shard_map; the production-mesh path).

Maps the paper's database designs onto a TPU pod (DESIGN.md §2):

* Docs are sharded over every mesh device ("docs" view of the mesh) —
  each device holds a *band_part* (its doc slice × all bands), i.e. the
  paper's Cassandra **Design 2** layout.
* Candidate generation per band is a bucket-by-value ``all_to_all``
  (value-range partitioning — the "select * where band_id = id" query
  becomes an ICI shuffle) followed by a local lexicographic sort and run
  detection — the paper's sort-based method (§3.6 method 2).
* Star edges (member -> run head) go through a **two-stage verify**:

  1. *On-device prefix prescreen* (inside the all_to_all): each run
     member is compared to its run head over the exchanged
     ``verify_k``-signature prefix; edges whose prefix estimate clears
     ``edge_threshold - prescreen_margin`` survive into bounded,
     statically-shaped per-device edge buffers.  The margin keeps the
     prescreen high-recall: a k-row prefix is a noisy estimate of the
     full M-row agreement, so the final thresholding is NOT done here.
  2. *Batched full-signature verify on the host merge*: the step also
     returns the full (D, M) signature matrix it computed, and
     ``cluster_step_output`` drives the surviving edges through the
     shared staged engine — ``candidates.ShardedEdgeSource`` ->
     ``verify.ShardedEdgeVerifier`` (numpy / jnp /
     ``kernels.sigjaccard`` backends) -> ``engine.cluster_source`` ->
     ``ThresholdUnionFind`` — the exact same estimator, thresholds,
     exclusion stats, and union-find semantics as the host and
     streaming paths.

Everything is static-shape: buckets and edge buffers have fixed capacity
with overflow *counted* (never silently dropped) — when any device
overflowed, ``cluster_step_output`` falls back through the SAME engine
over a host ``BandMatrixSource`` built from the step's own signatures,
accumulating into the same union-find, so no candidate is ever lost.

Global doc ids come from a per-device ``doc_offsets`` input (default:
the contiguous row offsets), so chunked or ragged corpora can assign
collision-free ids across multiple step invocations.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.jaxcompat import shard_map_compat

from repro.core.hashing import GOLDEN32, U32_MAX, fmix32
from repro.core.lsh import band_values
from repro.core.minhash import signatures
from repro.core.shingle import ngram_hashes

INVALID = jnp.uint32(U32_MAX)


@dataclass(frozen=True)
class DistLSHConfig:
    ngram: int = 8
    num_hashes: int = 100
    rows_per_band: int = 2
    verify_k: int = 32          # signature prefix length exchanged for verify
    edge_threshold: float = 0.75
    prescreen_margin: float = 0.15  # stage-1 keeps est >= edge_t - margin
    bucket_slack: float = 2.0   # capacity = slack * D_local / n_dev
    edge_capacity: int = 4096   # prescreened-edge buffer per device
    m_chunk: int = 16

    @property
    def num_bands(self) -> int:
        return self.num_hashes // self.rows_per_band

    @property
    def prescreen_threshold(self) -> float:
        """Stage-1 on-device prefix-prescreen keep threshold."""
        return max(0.0, self.edge_threshold - self.prescreen_margin)


def docs_mesh(devices=None) -> Mesh:
    """Flat 'docs' view over all devices (same devices as the prod mesh)."""
    devices = np.asarray(devices if devices is not None else jax.devices())
    return Mesh(devices.reshape(-1), ("docs",))


def _bucket_scatter(entries: jnp.ndarray, bucket: jnp.ndarray,
                    n_dev: int, cap: int):
    """Scatter entries (D_loc, F) into (n_dev, cap, F) by bucket id.

    Returns (out, overflow_count).  Overflow entries are dropped from the
    buffer but counted.
    """
    d_loc, f = entries.shape
    order = jnp.argsort(bucket)              # stable
    sb = bucket[order]
    se = entries[order]
    idx = jnp.arange(d_loc, dtype=jnp.int32)
    heads = jnp.concatenate([jnp.array([True]), sb[1:] != sb[:-1]])
    seg_start = jax.lax.cummax(jnp.where(heads, idx, 0), axis=0)
    pos = idx - seg_start
    ok = pos < cap
    overflow = jnp.sum(~ok)
    out = jnp.full((n_dev * cap, f), INVALID, dtype=jnp.uint32)
    flat_idx = jnp.where(ok, sb * cap + pos, n_dev * cap)  # OOB drop
    out = out.at[flat_idx].set(se, mode="drop")
    return out.reshape(n_dev, cap, f), overflow


def _band_exchange_and_edges(band_hi, band_lo, doc_ids, sig_k, cfg,
                             axis_name: str, n_dev: int, cap: int):
    """One band: bucket -> all_to_all -> sort -> star edges -> prescreen.

    All inputs are per-device locals:
      band_hi/lo: (D_loc,) uint32; doc_ids: (D_loc,) uint32 global ids;
      sig_k: (D_loc, k) uint32.
    Returns (edges (n_dev*cap, 2) uint32, prefix ests (n_dev*cap,) f32,
             edge_mask, n_candidates, overflow).  ``edge_mask`` marks
    stage-1 survivors (prefix estimate >= prescreen threshold); the
    final ``edge_threshold`` decision happens in stage 2 on the host
    merge with full signatures (``cluster_step_output``).
    """
    k = cfg.verify_k
    shift = 32 - max(1, int(np.log2(n_dev))) if n_dev > 1 else 32
    bucket = (band_hi >> shift).astype(jnp.int32) if n_dev > 1 else (
        jnp.zeros_like(band_hi, dtype=jnp.int32))
    entries = jnp.concatenate(
        [band_hi[:, None], band_lo[:, None], doc_ids[:, None], sig_k],
        axis=-1,
    ).astype(jnp.uint32)                      # (D_loc, 3 + k)
    boxed, overflow = _bucket_scatter(entries, bucket, n_dev, cap)
    if n_dev > 1:
        boxed = jax.lax.all_to_all(boxed, axis_name, 0, 0, tiled=False)
    recv = boxed.reshape(n_dev * cap, 3 + k)

    hi, lo, doc = recv[:, 0], recv[:, 1], recv[:, 2]
    sig = recv[:, 3:]
    valid = doc != INVALID
    # Sort invalids to the end: key (valid desc, hi, lo).
    inv_key = (~valid).astype(jnp.uint32)
    iota = jnp.arange(hi.shape[0], dtype=jnp.uint32)
    inv_s, hi_s, lo_s, doc_s, perm = jax.lax.sort(
        (inv_key, hi, lo, doc, iota), num_keys=3)
    sig_s = sig[perm]
    valid_s = inv_s == 0

    same = (hi_s[1:] == hi_s[:-1]) & (lo_s[1:] == lo_s[:-1]) & valid_s[1:]
    heads = jnp.concatenate([jnp.array([True]), ~same])
    idx = jnp.arange(hi_s.shape[0], dtype=jnp.int32)
    head_idx = jax.lax.cummax(jnp.where(heads, idx, 0), axis=0)
    head_doc = doc_s[head_idx]
    head_sig = sig_s[head_idx]
    cand_mask = (~heads) & valid_s            # member of a run
    est = jnp.mean((sig_s == head_sig).astype(jnp.float32), axis=-1)
    edge_mask = cand_mask & (est >= cfg.prescreen_threshold)
    edges = jnp.stack([head_doc, doc_s], axis=-1)
    return edges, est, edge_mask, jnp.sum(cand_mask), overflow


def make_dedup_step(cfg: DistLSHConfig, mesh: Mesh):
    """Build the jit-able sharded dedup step for ``mesh`` ('docs' axis).

    Signature: (tokens (D, L) uint32, lengths (D,) int32, seeds (M,),
                doc_offsets (n_dev,) uint32 | None)
      -> dict(edges (n_dev*E_cap, 2), prescreen_sims, edge_mask,
              sig (D, M), stats (n_dev, 3))

    ``doc_offsets[i]`` is the global doc id of device i's first row;
    it defaults to the contiguous row offsets ``i * D_loc``.  Callers
    that process a ragged corpus in several chunks MUST pass offsets so
    ids from different invocations cannot collide (the historical
    ``dev * d_loc + arange(d_loc)`` assignment restarted at 0 for every
    chunk and silently aliased distinct documents in the merged edges).
    """
    n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    axis = mesh.axis_names[0]

    def local_step(tokens, lengths, seeds, doc_offset):
        # tokens: (D_loc, L) local shard; doc_offset: (1,) global base id.
        d_loc = tokens.shape[0]
        cap = max(1, int(np.ceil(cfg.bucket_slack * d_loc / n_dev)))
        ng, valid = ngram_hashes(tokens, lengths, n=cfg.ngram)
        sig = signatures(ng, valid, seeds, m_chunk=cfg.m_chunk)
        bands = band_values(sig, cfg.rows_per_band)  # (D_loc, b, 2)
        doc_ids = doc_offset[0].astype(jnp.uint32) + jnp.arange(
            d_loc, dtype=jnp.uint32)
        sig_k = sig[:, : cfg.verify_k]

        e_cap = cfg.edge_capacity

        def per_band(carry, j):
            buf, buf_sim, count, tot_cand, tot_ovf = carry
            edges, est, emask, n_cand, ovf = _band_exchange_and_edges(
                bands[:, j, 0], bands[:, j, 1], doc_ids, sig_k,
                cfg, axis, n_dev, cap)
            # Append masked edges into the fixed buffer.
            offs = jnp.cumsum(emask.astype(jnp.int32)) - 1
            dst = jnp.where(emask, count + offs, e_cap)  # OOB drop
            buf = buf.at[dst].set(edges, mode="drop")
            buf_sim = buf_sim.at[dst].set(est, mode="drop")
            new_count = jnp.minimum(count + jnp.sum(emask), e_cap)
            dropped = count + jnp.sum(emask) - new_count
            return (buf, buf_sim, new_count, tot_cand + n_cand,
                    tot_ovf + ovf + dropped), None

        buf0 = jnp.full((e_cap, 2), INVALID, dtype=jnp.uint32)
        sim0 = jnp.zeros((e_cap,), dtype=jnp.float32)
        (buf, buf_sim, count, n_cand, ovf), _ = jax.lax.scan(
            per_band, (buf0, sim0, jnp.int32(0), jnp.int32(0),
                       jnp.int32(0)),
            jnp.arange(cfg.num_bands))
        emask = jnp.arange(e_cap) < count
        stats = jnp.stack(
            [count, n_cand, ovf]).astype(jnp.int32)[None]  # (1, 3)
        return buf, buf_sim, emask, sig, stats

    sharded = shard_map_compat(
        local_step,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(), P(axis)),
        out_specs=(P(axis), P(axis), P(axis), P(axis), P(axis)),
        check_replication=False,
    )

    @jax.jit
    def dedup_step(tokens, lengths, seeds, doc_offsets=None):
        if doc_offsets is None:
            d_loc = tokens.shape[0] // n_dev
            doc_offsets = jnp.uint32(d_loc) * jnp.arange(
                n_dev, dtype=jnp.uint32)
        edges, sims, emask, sig, stats = sharded(
            tokens, lengths, seeds, doc_offsets.astype(jnp.uint32))
        return {
            "edges": edges, "prescreen_sims": sims, "edge_mask": emask,
            "sig": sig, "stats": stats,
        }

    return dedup_step


def dedup_input_specs(cfg: DistLSHConfig, num_docs: int, max_len: int):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    return {
        "tokens": jax.ShapeDtypeStruct((num_docs, max_len), jnp.uint32),
        "lengths": jax.ShapeDtypeStruct((num_docs,), jnp.int32),
        "seeds": jax.ShapeDtypeStruct((cfg.num_hashes,), jnp.uint32),
    }


# ---------------------------------------------------------------------------
# Host-side merge: stage-2 verify + clustering through the shared engine
# ---------------------------------------------------------------------------

@dataclass
class ShardedClusterResult:
    """Outcome of ``cluster_step_output`` (sharded path, host merge)."""

    uf: "ThresholdUnionFind"
    stats: "ClusterStats"
    pairs: list  # evaluated (a, b, sim) with full-signature sims
    num_edges: int          # stage-1 survivors fed into the engine
    overflow: int           # device bucket/edge-buffer overflow count
    retried: bool           # True when the overflow fallback pass ran
    device_stats: np.ndarray  # (n_dev, 3) [edge_count, candidates, ovf]

    def labels(self) -> np.ndarray:
        return self.uf.components()


def cluster_step_output(
    out: dict,
    cfg: DistLSHConfig,
    *,
    tree_threshold: float = 0.40,
    backend: str = "numpy",
    batch: str = "run",
    num_docs: int | None = None,
    doc_id_base: int = 0,
    overflow_fallback: bool = True,
    batch_pairs: int = 8192,
) -> ShardedClusterResult:
    """Stage 2 of the sharded path: batched full-signature verify + merge.

    Drives the step's prescreened per-device edge buffers through the
    shared staged engine — ``ShardedEdgeSource`` ->
    ``ShardedEdgeVerifier`` (full (D, M) signatures, same
    numpy/jnp/pallas backends as the host path) ->
    ``engine.cluster_source`` — so edge thresholds, estimator semantics,
    and exclusion stats are identical to ``DedupPipeline``.

    ``num_docs`` bounds real documents: edges touching padding rows
    (appended for divisibility by the device count) are dropped.

    ``doc_id_base`` must echo the base passed to the step via
    ``doc_offsets`` when a chunk of a larger corpus was processed: edge
    ids are global (``doc_id_base + row``) while ``sig`` rows are
    chunk-local, so the merge shifts edges back before verification.
    All returned ids (uf labels, pairs) are chunk-local row indices;
    add ``doc_id_base`` to map them back into the global corpus.

    If any device overflowed a bucket or its edge buffer, prescreen
    edges were lost on device; with ``overflow_fallback`` the merge
    re-derives candidates on the host from the step's own signatures
    (``BandMatrixSource`` over ``lsh.band_values``) and accumulates them
    through the SAME engine into the same union-find, so no candidate
    is silently dropped.
    """
    from repro.core.candidates import BandMatrixSource, ShardedEdgeSource
    from repro.core.engine import cluster_source
    from repro.core.verify import ShardedEdgeVerifier

    sig = np.asarray(out["sig"])
    num_docs = sig.shape[0] if num_docs is None else int(num_docs)
    device_stats = np.asarray(out["stats"])
    overflow = int(device_stats[:, 2].sum())

    verifier = ShardedEdgeVerifier(sig[:num_docs], backend=backend,
                                   batch_pairs=batch_pairs)
    # Shift global edge ids back to chunk-local rows; ids outside
    # [0, num_docs) after the shift (padding, INVALID slots, other
    # chunks' docs) are dropped by the source's range filter.
    edges = np.asarray(out["edges"]).astype(np.int64) - int(doc_id_base)
    source = ShardedEdgeSource(edges,
                               np.asarray(out["edge_mask"]),
                               num_docs=num_docs,
                               num_shards=device_stats.shape[0])
    uf, stats, pairs = cluster_source(
        source, verifier, cfg.edge_threshold, tree_threshold, batch=batch)

    retried = False
    if overflow > 0 and overflow_fallback:
        retried = True
        bands = np.asarray(
            band_values(jnp.asarray(sig[:num_docs]), cfg.rows_per_band))
        _, stats2, pairs2 = cluster_source(
            BandMatrixSource(bands), verifier, cfg.edge_threshold,
            tree_threshold, batch=batch, uf=uf)
        stats.add(stats2)
        merged = {(a, b): s for a, b, s in pairs}
        merged.update({(a, b): s for a, b, s in pairs2})
        pairs = [(a, b, s) for (a, b), s in sorted(merged.items())]

    return ShardedClusterResult(
        uf=uf, stats=stats, pairs=pairs, num_edges=source.num_edges,
        overflow=overflow, retried=retried, device_stats=device_stats)
