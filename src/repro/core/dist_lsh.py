"""Distributed LSH dedup step (shard_map; the production-mesh path).

Maps the paper's database designs onto a TPU pod (DESIGN.md §2):

* Docs are sharded over every mesh device ("docs" view of the mesh) —
  each device holds a *band_part* (its doc slice × all bands), i.e. the
  paper's Cassandra **Design 2** layout.
* Candidate generation per band is a bucket-by-value ``all_to_all``
  (value-range partitioning — the "select * where band_id = id" query
  becomes an ICI shuffle) followed by a local lexicographic sort and run
  detection — the paper's sort-based method (§3.6 method 2).
* Star edges (member -> run head) go through a **two-stage verify**:

  1. *On-device prefix prescreen* (inside the all_to_all): each run
     member is compared to its run head over the exchanged
     ``verify_k``-signature prefix; edges whose prefix estimate clears
     ``edge_threshold - prescreen_margin`` survive into bounded,
     statically-shaped per-device edge buffers.  The margin keeps the
     prescreen high-recall: a k-row prefix is a noisy estimate of the
     full M-row agreement, so the final thresholding is NOT done here.
  2. *Batched full-signature verify on the merge*: either on the host
     (``stage2="host"``: ``cluster_step_output`` drives the surviving
     edges through the shared staged engine —
     ``candidates.ShardedEdgeSource`` -> ``verify.ShardedEdgeVerifier``
     (numpy / jnp / ``kernels.sigjaccard`` backends) ->
     ``engine.cluster_source`` -> ``ThresholdUnionFind``) or resident
     on the accelerator (``stage2="device"``: the
     ``kernels.sigjaccard.masked_indexed_pair_counts`` fused gather +
     full-M kernel runs under the same shard_map over each device's own
     signature shard; cross-shard edges are scored there too by
     exchanging a bounded per-device buffer of straggler signature rows
     inside the same collective round — see ``sig_row_capacity`` — so
     edges arrive at the merge already fully scored and
     ``verify.DeviceScoredEdgeVerifier`` is a pass-through whose host
     re-score path handles only row-buffer *overflow*).
     Thresholds, estimator semantics, exclusion stats, and union-find
     semantics are identical to the host and streaming paths either way.

**Band-group streaming** (DESIGN.md §3): the step's b bands are split
into ``band_groups`` groups of b/G bands, each emitting its *own*
bounded per-device edge buffer + overflow counter instead of one
end-of-step gather.  ``make_streamed_dedup_step`` dispatches every
group's shuffle asynchronously and ``cluster_step_output`` consumes the
buffers as a stream (``engine.ClusterAccumulator``): the host merge of
group g materializes only group g's buffer, so it overlaps the device
shuffle of groups g+1..G-1.

Everything is static-shape: buckets and edge buffers have fixed capacity
with overflow *counted* (never silently dropped) — when any device
overflowed, ``cluster_step_output`` falls back through the SAME engine
over a host ``BandMatrixSource`` built from the step's own signatures,
accumulating into the same union-find, so no candidate is ever lost.

Global doc ids come from a per-device ``doc_offsets`` input (default:
the contiguous row offsets), so chunked or ragged corpora can assign
collision-free ids across multiple step invocations.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.jaxcompat import shard_map_compat

from repro.core.hashing import U32_MAX
from repro.core.lsh import band_values
from repro.core.minhash import signatures
from repro.core.shingle import ngram_hashes

INVALID = jnp.uint32(U32_MAX)

STAGE2_MODES = ("host", "device")


@dataclass(frozen=True)
class DistLSHConfig:
    ngram: int = 8
    num_hashes: int = 100
    rows_per_band: int = 2
    verify_k: int = 32          # signature prefix length exchanged for verify
    edge_threshold: float = 0.75
    prescreen_margin: float = 0.15  # stage-1 keeps est >= edge_t - margin
    bucket_slack: float = 2.0   # capacity = slack * D_local / n_dev
    edge_capacity: int = 4096   # prescreened-edge buffer per device/group
    m_chunk: int = 16
    band_groups: int = 1        # G bounded buffers of b/G bands each
    stage2: str = "host"        # full-signature verify: "host" | "device"
    sig_row_capacity: int = 1024  # cross-shard published-row buffer (0: off)
    fused_ingest: bool = False  # one-pass Pallas shingle->minhash->fold
    byte_ingest: bool = False   # step inputs are uint8 bytes, not tokens

    @property
    def num_bands(self) -> int:
        return self.num_hashes // self.rows_per_band

    @property
    def prescreen_threshold(self) -> float:
        """Stage-1 on-device prefix-prescreen keep threshold."""
        return max(0.0, self.edge_threshold - self.prescreen_margin)

    @property
    def bands_per_group(self) -> int:
        if self.num_bands % self.band_groups != 0:
            raise ValueError(
                f"band_groups={self.band_groups} does not divide "
                f"num_bands={self.num_bands}")
        return self.num_bands // self.band_groups


def docs_mesh(devices=None) -> Mesh:
    """Flat 'docs' view over all devices (same devices as the prod mesh)."""
    devices = np.asarray(devices if devices is not None else jax.devices())
    return Mesh(devices.reshape(-1), ("docs",))


def _bucket_scatter(entries: jnp.ndarray, bucket: jnp.ndarray,
                    n_dev: int, cap: int):
    """Scatter entries (D_loc, F) into (n_dev, cap, F) by bucket id.

    Returns (out, overflow_count).  Overflow entries are dropped from the
    buffer but counted.
    """
    d_loc, f = entries.shape
    order = jnp.argsort(bucket)              # stable
    sb = bucket[order]
    se = entries[order]
    idx = jnp.arange(d_loc, dtype=jnp.int32)
    heads = jnp.concatenate([jnp.array([True]), sb[1:] != sb[:-1]])
    seg_start = jax.lax.cummax(jnp.where(heads, idx, 0), axis=0)
    pos = idx - seg_start
    ok = pos < cap
    overflow = jnp.sum(~ok)
    out = jnp.full((n_dev * cap, f), INVALID, dtype=jnp.uint32)
    flat_idx = jnp.where(ok, sb * cap + pos, n_dev * cap)  # OOB drop
    out = out.at[flat_idx].set(se, mode="drop")
    return out.reshape(n_dev, cap, f), overflow


def _band_exchange_and_edges(band_hi, band_lo, doc_ids, sig_k, cfg,
                             axis_name: str, n_dev: int, cap: int):
    """One band: bucket -> all_to_all -> sort -> star edges -> prescreen.

    All inputs are per-device locals:
      band_hi/lo: (D_loc,) uint32; doc_ids: (D_loc,) uint32 global ids;
      sig_k: (D_loc, k) uint32.
    Returns (edges (n_dev*cap, 2) uint32, prefix ests (n_dev*cap,) f32,
             edge_mask, n_candidates, overflow).  ``edge_mask`` marks
    stage-1 survivors (prefix estimate >= prescreen threshold); the
    final ``edge_threshold`` decision happens in stage 2 with full
    signatures (device-resident or on the host merge).
    """
    k = cfg.verify_k
    shift = 32 - max(1, int(np.log2(n_dev))) if n_dev > 1 else 32
    bucket = (band_hi >> shift).astype(jnp.int32) if n_dev > 1 else (
        jnp.zeros_like(band_hi, dtype=jnp.int32))
    entries = jnp.concatenate(
        [band_hi[:, None], band_lo[:, None], doc_ids[:, None], sig_k],
        axis=-1,
    ).astype(jnp.uint32)                      # (D_loc, 3 + k)
    boxed, overflow = _bucket_scatter(entries, bucket, n_dev, cap)
    if n_dev > 1:
        boxed = jax.lax.all_to_all(boxed, axis_name, 0, 0, tiled=False)
    recv = boxed.reshape(n_dev * cap, 3 + k)

    hi, lo, doc = recv[:, 0], recv[:, 1], recv[:, 2]
    sig = recv[:, 3:]
    valid = doc != INVALID
    # Sort invalids to the end: key (valid desc, hi, lo).
    inv_key = (~valid).astype(jnp.uint32)
    iota = jnp.arange(hi.shape[0], dtype=jnp.uint32)
    inv_s, hi_s, lo_s, doc_s, perm = jax.lax.sort(
        (inv_key, hi, lo, doc, iota), num_keys=3)
    sig_s = sig[perm]
    valid_s = inv_s == 0

    same = (hi_s[1:] == hi_s[:-1]) & (lo_s[1:] == lo_s[:-1]) & valid_s[1:]
    heads = jnp.concatenate([jnp.array([True]), ~same])
    idx = jnp.arange(hi_s.shape[0], dtype=jnp.int32)
    head_idx = jax.lax.cummax(jnp.where(heads, idx, 0), axis=0)
    head_doc = doc_s[head_idx]
    head_sig = sig_s[head_idx]
    cand_mask = (~heads) & valid_s            # member of a run
    est = jnp.mean((sig_s == head_sig).astype(jnp.float32), axis=-1)
    edge_mask = cand_mask & (est >= cfg.prescreen_threshold)
    edges = jnp.stack([head_doc, doc_s], axis=-1)
    return edges, est, edge_mask, jnp.sum(cand_mask), overflow


def _prescreen_scan(bands_g, doc_ids, sig_k, cfg, axis: str,
                    n_dev: int, cap: int):
    """Scan one band-group's bands into a bounded per-device edge buffer.

    bands_g: (D_loc, bg, 2) local band slice.  Returns
    (buf (e_cap, 2), buf_sim (e_cap,), emask (e_cap,), stats (1, 3))
    where stats rows are [edge_count, candidates, overflow].
    """
    e_cap = cfg.edge_capacity
    bg = bands_g.shape[1]

    def per_band(carry, j):
        buf, buf_sim, count, tot_cand, tot_ovf = carry
        edges, est, emask, n_cand, ovf = _band_exchange_and_edges(
            bands_g[:, j, 0], bands_g[:, j, 1], doc_ids, sig_k,
            cfg, axis, n_dev, cap)
        # Append masked edges into the fixed buffer.
        offs = jnp.cumsum(emask.astype(jnp.int32)) - 1
        dst = jnp.where(emask, count + offs, e_cap)  # OOB drop
        buf = buf.at[dst].set(edges, mode="drop")
        buf_sim = buf_sim.at[dst].set(est, mode="drop")
        new_count = jnp.minimum(count + jnp.sum(emask), e_cap)
        dropped = count + jnp.sum(emask) - new_count
        return (buf, buf_sim, new_count, tot_cand + n_cand,
                tot_ovf + ovf + dropped), None

    buf0 = jnp.full((e_cap, 2), INVALID, dtype=jnp.uint32)
    sim0 = jnp.zeros((e_cap,), dtype=jnp.float32)
    (buf, buf_sim, count, n_cand, ovf), _ = jax.lax.scan(
        per_band, (buf0, sim0, jnp.int32(0), jnp.int32(0), jnp.int32(0)),
        jnp.arange(bg))
    emask = jnp.arange(e_cap) < count
    stats = jnp.stack([count, n_cand, ovf]).astype(jnp.int32)[None]
    return buf, buf_sim, emask, stats


def make_streamed_dedup_step(cfg: DistLSHConfig, mesh: Mesh, *,
                             stage2: str | None = None):
    """Build the band-group streamed sharded dedup step for ``mesh``.

    Signature: (tokens (D, L) uint32, lengths (D,) int32, seeds (M,),
                doc_offsets (n_dev,) uint32 | None)
      -> dict(sig (D, M), stage2,
              groups=[dict(edges (n_dev*E_cap, 2), prescreen_sims,
                           edge_mask, stats (n_dev, 3), band_start,
                           [device_sims, device_covered]), ...])

    Every group's shuffle is dispatched before the function returns
    (JAX async dispatch): converting group g's buffers to numpy blocks
    on group g alone, which is how ``cluster_step_output`` overlaps the
    host merge of group g with the device shuffle of group g+1.

    With ``stage2="device"`` each group additionally carries
    ``device_match_counts``/``device_covered``/``row_overflow``: full-M
    agreement counts computed on the accelerator by the
    ``kernels.sigjaccard`` fused kernels under shard_map — each device
    scores the gathered group edges whose two endpoints fall in its own
    signature shard, cross-shard edges are scored by the head
    endpoint's owner against the member row exchanged through a bounded
    per-device row buffer (``cfg.sig_row_capacity``; overflow counted),
    and a psum combines the disjoint contributions.  Only edges whose
    member row overflowed the exchange buffer stay uncovered and fall
    back to the host re-score path
    (``verify.DeviceScoredEdgeVerifier`` stragglers).

    ``doc_offsets[i]`` is the global doc id of device i's first row;
    it defaults to the contiguous row offsets ``i * D_loc``.  Callers
    that process a ragged corpus in several chunks MUST pass offsets so
    ids from different invocations cannot collide (the historical
    ``dev * d_loc + arange(d_loc)`` assignment restarted at 0 for every
    chunk and silently aliased distinct documents in the merged edges).
    """
    stage2 = cfg.stage2 if stage2 is None else stage2
    if stage2 not in STAGE2_MODES:
        raise ValueError(f"unknown stage2 mode {stage2!r}")
    n_dev = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    axis = mesh.axis_names[0]
    G = cfg.band_groups
    bg = cfg.bands_per_group

    def local_prepare(tokens, lengths, seeds):
        if cfg.byte_ingest:
            # Zero-copy shard prepare: ``tokens`` is a (D_loc, LB) uint8
            # byte matrix (see ``shingle.pack_bytes``) and the whole
            # tokenize -> shingle -> minhash -> fold chain runs in one
            # device-resident pass feeding the all_to_all directly.
            # Shapes are pow2-bucketed at the session dispatch layer
            # (pack_bytes width), the same contract as the fused branch.
            from repro.kernels.byte_shingle import bytes_to_bands

            # repro-lint: disable=RPR003 — widths bucketed by callers
            sig, bands, _ = bytes_to_bands(
                tokens, lengths, seeds, n=cfg.ngram,
                r=cfg.rows_per_band)
            return sig, bands
        if cfg.fused_ingest:
            # One device-resident Pallas pass per shard: n-gram hashes
            # and the minhash cube never leave VMEM, and the all_to_all
            # below is fed directly — signatures never round-trip
            # through the host.  Bit-identical to the staged branch.
            from repro.kernels.fused_ingest import fused_ingest

            sig, bands, _ = fused_ingest(
                tokens, lengths, seeds, n=cfg.ngram,
                r=cfg.rows_per_band)
            return sig, bands
        ng, valid = ngram_hashes(tokens, lengths, n=cfg.ngram)
        sig = signatures(ng, valid, seeds, m_chunk=cfg.m_chunk)
        bands = band_values(sig, cfg.rows_per_band)  # (D_loc, b, 2)
        return sig, bands

    prepare = jax.jit(shard_map_compat(
        local_prepare,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P()),
        out_specs=(P(axis), P(axis)),
        check_replication=False,
    ))

    def local_group(bands_g, sig, doc_offset):
        # bands_g: (D_loc, bg, 2); sig: (D_loc, M); doc_offset: (1,).
        d_loc = sig.shape[0]
        cap = max(1, int(np.ceil(cfg.bucket_slack * d_loc / n_dev)))
        doc_ids = doc_offset[0].astype(jnp.uint32) + jnp.arange(
            d_loc, dtype=jnp.uint32)
        sig_k = sig[:, : cfg.verify_k]
        buf, buf_sim, emask, stats = _prescreen_scan(
            bands_g, doc_ids, sig_k, cfg, axis, n_dev, cap)
        if stage2 != "device":
            return buf, buf_sim, emask, stats
        # Device-resident stage 2: gather the group's edge buffers from
        # every device, score the edges whose two endpoints live in THIS
        # device's signature shard with the fused full-M kernel, and
        # psum the disjoint masked contributions into a replicated
        # (n_dev * e_cap,) vector (ordering matches the P(axis) gather
        # of the buffers themselves).  The kernel emits exact agreement
        # *counts*; the /M division happens on the host merge in numpy
        # so the scores are bit-identical to the host estimator.
        from repro.kernels import sigjaccard

        all_edges = jax.lax.all_gather(buf, axis, axis=0, tiled=False)
        all_emask = jax.lax.all_gather(emask, axis, axis=0, tiled=False)
        # int32 wraparound arithmetic is exact mod 2^32, so the shard
        # range test below is correct over the full uint32 id space
        # (INVALID slots are masked out via the edge mask).
        flat = all_edges.reshape(-1, 2).astype(jnp.int32)
        off = doc_offset[0].astype(jnp.int32)
        a_loc = flat[:, 0] - off
        b_loc = flat[:, 1] - off
        mask_flat = all_emask.reshape(-1)
        a_in = (a_loc >= 0) & (a_loc < d_loc)
        b_in = (b_loc >= 0) & (b_loc < d_loc)
        local = mask_flat & a_in & b_in
        counts = sigjaccard.masked_indexed_pair_counts(
            sig, a_loc, b_loc, local)
        covered = local
        row_ovf = jnp.zeros((1,), dtype=jnp.int32)
        rc = cfg.sig_row_capacity
        if n_dev > 1 and rc > 0:
            # Cross-shard straggler scoring: exchange a BOUNDED buffer
            # of signature rows inside the same collective round so
            # cross-shard edges are scored on-accelerator too.  An edge
            # (head, member) with endpoints on different shards is
            # scored by the HEAD's owner, which needs the member's row:
            # each device publishes the (deduplicated) member rows it
            # owns for head-remote edges, capacity ``sig_row_capacity``
            # with overflow counted — overflowed rows simply leave those
            # edges uncovered, and the host merge re-scores exactly that
            # overflow remainder (``DeviceScoredEdgeVerifier``).
            publish = mask_flat & b_in & (~a_in)
            need = jnp.where(publish, b_loc, d_loc)
            s = jnp.sort(need)
            uniq = jnp.concatenate(
                [jnp.array([True]), s[1:] != s[:-1]]) & (s < d_loc)
            pos = jnp.cumsum(uniq.astype(jnp.int32)) - 1
            n_pub = jnp.sum(uniq)
            dst = jnp.where(uniq & (pos < rc), pos, rc)  # OOB drop
            row_ids = jnp.full((rc,), INVALID, dtype=jnp.uint32)
            rows = jnp.zeros((rc, sig.shape[1]), dtype=jnp.uint32)
            glob = doc_offset[0].astype(jnp.uint32) + s.astype(jnp.uint32)
            row_ids = row_ids.at[dst].set(glob, mode="drop")
            rows = rows.at[dst].set(
                sig[jnp.clip(s, 0, d_loc - 1)].astype(jnp.uint32),
                mode="drop")
            row_ovf = jnp.maximum(n_pub - rc, 0).astype(jnp.int32)[None]
            tbl_ids = jax.lax.all_gather(
                row_ids, axis, axis=0, tiled=False).reshape(-1)
            tbl_rows = jax.lax.all_gather(
                rows, axis, axis=0, tiled=False).reshape(-1, sig.shape[1])
            # Score the cross edges whose head lives in my shard: look
            # the member row up in the exchanged table by global id
            # (published ids are unique — one owner, deduplicated).
            score_mine = mask_flat & a_in & (~b_in)
            order = jnp.argsort(tbl_ids)
            sorted_ids = tbl_ids[order]
            member_glob = all_edges.reshape(-1, 2)[:, 1]
            pos_b = jnp.clip(jnp.searchsorted(sorted_ids, member_glob),
                             0, sorted_ids.shape[0] - 1)
            hit = (sorted_ids[pos_b] == member_glob) & score_mine
            a_rows = sig[jnp.clip(a_loc, 0, d_loc - 1)]
            b_rows = tbl_rows[order[pos_b]]
            counts = counts + sigjaccard.masked_pair_counts(
                a_rows, b_rows, hit)
            covered = covered | hit
        dev_counts = jax.lax.psum(counts, axis)
        dev_cov = jax.lax.psum(covered.astype(jnp.int32), axis) > 0
        return buf, buf_sim, emask, stats, dev_counts, dev_cov, row_ovf

    group_out_specs = (P(axis), P(axis), P(axis), P(axis))
    if stage2 == "device":
        group_out_specs = group_out_specs + (P(), P(), P(axis))
    group_step = jax.jit(shard_map_compat(
        local_group,
        mesh=mesh,
        in_specs=(P(axis), P(axis), P(axis)),
        out_specs=group_out_specs,
        check_replication=False,
    ))

    def step(tokens, lengths, seeds, doc_offsets=None):
        tokens = jnp.asarray(tokens)
        if doc_offsets is None:
            d_loc = tokens.shape[0] // n_dev
            doc_offsets = jnp.uint32(d_loc) * jnp.arange(
                n_dev, dtype=jnp.uint32)
        doc_offsets = jnp.asarray(doc_offsets).astype(jnp.uint32)
        sig, bands = prepare(tokens, jnp.asarray(lengths),
                             jnp.asarray(seeds))
        groups = []
        for g in range(G):
            bands_g = jax.lax.slice_in_dim(bands, g * bg, (g + 1) * bg,
                                           axis=1)
            outs = group_step(bands_g, sig, doc_offsets)
            gout = {
                "edges": outs[0], "prescreen_sims": outs[1],
                "edge_mask": outs[2], "stats": outs[3],
                "band_start": g * bg,
            }
            if stage2 == "device":
                gout["device_match_counts"] = outs[4]
                gout["device_covered"] = outs[5]
                gout["row_overflow"] = outs[6]
            groups.append(gout)
        return {"sig": sig, "groups": groups, "stage2": stage2}

    return step


def make_dedup_step(cfg: DistLSHConfig, mesh: Mesh):
    """Build the jit-able sharded dedup step for ``mesh`` ('docs' axis).

    Signature: (tokens (D, L) uint32, lengths (D,) int32, seeds (M,),
                doc_offsets (n_dev,) uint32 | None)
      -> dict(edges (G*n_dev*E_cap, 2), prescreen_sims, edge_mask,
              sig (D, M), stats (G*n_dev, 3))

    This is the end-of-step view over the band-group machinery: the
    per-group bounded buffers (G = ``cfg.band_groups``, default 1) are
    concatenated into one edge array whose shard rows are the (group,
    device) buffers in group-major order.  Use
    ``make_streamed_dedup_step`` to consume the groups as a stream
    (overlapped host merge) or for the device-resident stage 2.
    """
    streamed = make_streamed_dedup_step(cfg, mesh, stage2="host")

    @jax.jit
    def dedup_step(tokens, lengths, seeds, doc_offsets=None):
        out = streamed(tokens, lengths, seeds, doc_offsets)
        gs = out["groups"]
        return {
            "edges": jnp.concatenate([g["edges"] for g in gs]),
            "prescreen_sims": jnp.concatenate(
                [g["prescreen_sims"] for g in gs]),
            "edge_mask": jnp.concatenate([g["edge_mask"] for g in gs]),
            "sig": out["sig"],
            "stats": jnp.concatenate([g["stats"] for g in gs]),
        }

    return dedup_step


def dedup_input_specs(cfg: DistLSHConfig, num_docs: int, max_len: int):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    return {
        "tokens": jax.ShapeDtypeStruct((num_docs, max_len), jnp.uint32),
        "lengths": jax.ShapeDtypeStruct((num_docs,), jnp.int32),
        "seeds": jax.ShapeDtypeStruct((cfg.num_hashes,), jnp.uint32),
    }


# ---------------------------------------------------------------------------
# Host-side merge: stage-2 verify + clustering through the shared engine
# ---------------------------------------------------------------------------

@dataclass
class ShardedClusterResult:
    """Outcome of ``cluster_step_output`` (sharded path, host merge)."""

    uf: "ThresholdUnionFind"
    stats: "ClusterStats"
    pairs: list  # evaluated (a, b, sim) with full-signature sims
    num_edges: int          # stage-1 survivors fed into the engine
    overflow: int           # device bucket/edge-buffer overflow count
    retried: bool           # True when the overflow fallback pass ran
    device_stats: np.ndarray  # (n_dev, 3) [edge_count, candidates, ovf]
    group_stats: list = field(default_factory=list)  # per-band-group
    device_scored: int = 0  # stage-2 pairs served from device scores
    host_rescored: int = 0  # stage-2 pairs re-scored on the host
    row_overflow: int = 0   # cross-shard row-buffer overflow (stage2=device)

    def labels(self) -> np.ndarray:
        return self.uf.components()


@dataclass
class StepFeed:
    """Outcome of ``feed_step_groups`` (one step fed into an accumulator)."""

    num_edges: int
    overflow: int
    row_overflow: int
    device_stats: np.ndarray
    group_stats: list


def _resolve_stream(stream: bool | None) -> bool:
    """Measured-win heuristic for the overlapped band-group merge.

    A committed ``BENCH_smoke.json`` once showed the overlapped merge
    LOSING to the serialized one (``saved_us=-58703``); re-measuring
    with best-of-N timing (single-shot smoke timings on a shared 2-vCPU
    runner swing by tens of ms) shows the overlap reliably *winning*
    ~20-25% even on a 2-core CPU host — the merge is numpy/GIL-bound
    while the shuffle runs on XLA's own thread pool, so the two really
    do overlap.  The auto policy therefore streams everywhere except
    the one configuration that cannot overlap by construction: a
    single-core host running the CPU backend (device compute and host
    merge share the only core, so blocking up front is free and avoids
    per-group sync round-trips).  ``stream=True/False`` forces either
    mode — results are identical — and
    ``benchmarks/designs.run_band_group_overlap`` reports ``saved_us``
    for both forced modes plus this auto policy.
    """
    if stream is not None:
        return bool(stream)
    import os

    if jax.default_backend() != "cpu":
        return True
    return (os.cpu_count() or 1) > 1


def feed_step_groups(
    acc,
    out: dict,
    cfg: DistLSHConfig,
    *,
    num_docs: int,
    edge_offset: int = 0,
    verifier=None,
    stream: bool | None = None,
    on_group_merged=None,
) -> StepFeed:
    """Feed one (streamed) dedup-step output into a ``ClusterAccumulator``.

    The single home of the sharded host-merge plumbing, shared by
    ``cluster_step_output`` (fresh per-step accumulator, chunk-local
    ids) and ``session.DedupSession`` (one long-lived accumulator,
    global ids): per band-group, materialize the bounded edge buffer
    (in stream mode this blocks on THAT group's shuffle only, so the
    merge of group g overlaps the shuffle of group g+1), register
    device-computed stage-2 scores with the verifier, and feed the
    group through the accumulator.  Edge ids are shifted by
    ``edge_offset`` and range-filtered to ``[0, num_docs)``.

    ``on_group_merged`` (if given) runs after each group's feed — the
    session's retention layer sweeps evictions here so memory stays
    bounded even WITHIN a giant step.  The sweep is safe mid-step: it
    only releases rows of docs that lost union-find roothood outside
    its protection window, while the remaining groups' edges — and the
    stage-2 device-score / sig-row-exchange re-score path — reference
    only this step's own (protected) rows and current roots.

    Returns the step's edge/overflow accounting; the overflow fallback
    stays with the caller (it needs the right band source for the ids
    in play).
    """
    from repro.core.candidates import ShardedEdgeSource

    groups = out.get("groups")
    if groups is None:
        # End-of-step view: one (G*n_dev, 3) stats array whose rows are
        # the (group, device) buffers; treat it as a single group.
        groups = [out]
    device_scored = out.get("stage2") == "device"
    if not _resolve_stream(stream):
        jax.block_until_ready([g["edges"] for g in groups])
    m = out["sig"].shape[1]

    num_edges = 0
    row_overflow = 0
    group_stats = []
    device_stats_parts = []
    for g_out in groups:
        # Materializing this group's buffers blocks on ITS shuffle only;
        # later groups keep running on the device meanwhile.  Ids
        # outside [0, num_docs) after the edge_offset shift (padding,
        # INVALID slots, other chunks' docs) are dropped by the
        # source's range filter.
        g_stats = np.asarray(g_out["stats"])
        device_stats_parts.append(g_stats)
        source = ShardedEdgeSource.from_device_buffers(
            g_out["edges"], g_out["edge_mask"], num_docs=num_docs,
            num_shards=g_stats.shape[0], edge_offset=edge_offset)
        if device_scored and hasattr(verifier, "add_scores"):
            # Host-side /M of the device match counts: numpy float32
            # division is correctly rounded, so these scores are
            # bit-identical to the host estimator.  ``covered`` spans
            # same-shard edges plus the cross-shard edges scored via
            # the exchanged row buffers; only row-buffer overflow is
            # left for the host re-score path.
            edges = np.asarray(g_out["edges"]).astype(np.int64) - int(
                edge_offset)
            mask = np.asarray(g_out["edge_mask"])
            sims = (np.asarray(g_out["device_match_counts"])
                    / np.float32(m))
            covered = np.asarray(g_out["device_covered"])
            reg = (mask & covered
                   & (edges >= 0).all(axis=-1)
                   & (edges < num_docs).all(axis=-1))
            verifier.add_scores(edges[reg], sims[reg])
            row_overflow += int(
                np.asarray(g_out.get("row_overflow", 0)).sum())
        num_edges += source.num_edges
        group_stats.append(acc.feed(source, verifier=verifier))
        if on_group_merged is not None:
            on_group_merged()

    if device_scored and hasattr(verifier, "clear_scores"):
        # Registered scores are dead once their edges have been fed
        # (sim cache / co-clustering make re-lookup impossible); keep
        # the long-lived session registry from growing per step.
        verifier.clear_scores()

    device_stats = np.concatenate(device_stats_parts)
    return StepFeed(
        num_edges=num_edges,
        overflow=int(device_stats[:, 2].sum()),
        row_overflow=row_overflow,
        device_stats=device_stats,
        group_stats=group_stats)


def cluster_step_output(
    out: dict,
    cfg: DistLSHConfig,
    *,
    tree_threshold: float = 0.40,
    backend: str = "numpy",
    batch: str = "run",
    num_docs: int | None = None,
    doc_id_base: int = 0,
    overflow_fallback: bool = True,
    batch_pairs: int = 8192,
    stream: bool | None = None,
) -> ShardedClusterResult:
    """Stage 2 of the sharded path: batched full-signature verify + merge.

    Accepts either the end-of-step output of ``make_dedup_step`` or the
    band-group stream of ``make_streamed_dedup_step`` (a ``"groups"``
    key).  In stream mode each group's buffers are materialized only
    when the engine reaches them and fed incrementally through one
    ``engine.ClusterAccumulator`` — the host merge of group g overlaps
    the device shuffle of group g+1, and a pair verified for group g is
    excluded (never re-verified) when group g+1 emits it again.

    Drives the prescreened edges through the shared staged engine —
    ``ShardedEdgeSource`` -> ``ShardedEdgeVerifier`` (full (D, M)
    signatures, same numpy/jnp/pallas backends as the host path) ->
    ``engine.cluster_source`` — so edge thresholds, estimator semantics,
    and exclusion stats are identical to ``DedupPipeline``.  For
    ``stage2="device"`` step outputs the verifier is a
    ``DeviceScoredEdgeVerifier``: same-shard edges were already scored
    on the accelerator and pass straight through; only cross-shard
    stragglers (and post-union root pairs) hit the host estimator.

    ``num_docs`` bounds real documents: edges touching padding rows
    (appended for divisibility by the device count) are dropped.

    ``doc_id_base`` must echo the base passed to the step via
    ``doc_offsets`` when a chunk of a larger corpus was processed: edge
    ids are global (``doc_id_base + row``) while ``sig`` rows are
    chunk-local, so the merge shifts edges back before verification.
    All returned ids (uf labels, pairs) are chunk-local row indices;
    add ``doc_id_base`` to map them back into the global corpus.

    If any device overflowed a bucket or its edge buffer, prescreen
    edges were lost on device; with ``overflow_fallback`` the merge
    re-derives candidates on the host from the step's own signatures
    (``BandMatrixSource`` over ``lsh.band_values``) and accumulates them
    through the SAME engine into the same union-find, so no candidate
    is silently dropped.

    ``stream`` controls whether groups are consumed lazily (overlapped
    merge) or after blocking on every buffer; the default defers to the
    measured-win heuristic (see ``_resolve_stream``) — results are
    identical either way.

    This is the one-shot adapter over the session-grade merge plumbing
    (``feed_step_groups``); incremental multi-step ingest goes through
    ``core.session.DedupSession`` instead, which feeds many step
    outputs into ONE accumulator.
    """
    from repro.core.candidates import BandMatrixSource
    from repro.core.engine import ClusterAccumulator
    from repro.core.verify import (DeviceScoredEdgeVerifier,
                                   ShardedEdgeVerifier)

    sig = np.asarray(out["sig"])
    num_docs = sig.shape[0] if num_docs is None else int(num_docs)

    cls = (DeviceScoredEdgeVerifier if out.get("stage2") == "device"
           else ShardedEdgeVerifier)
    verifier = cls(sig[:num_docs], backend=backend,
                   batch_pairs=batch_pairs)
    acc = ClusterAccumulator(
        num_docs, verifier, cfg.edge_threshold, tree_threshold,
        batch=batch)

    feed = feed_step_groups(
        acc, out, cfg, num_docs=num_docs, edge_offset=doc_id_base,
        verifier=verifier, stream=stream)

    retried = False
    if feed.overflow > 0 and overflow_fallback:
        retried = True
        bands = np.asarray(
            band_values(jnp.asarray(sig[:num_docs]), cfg.rows_per_band))
        acc.feed(BandMatrixSource(bands))

    return ShardedClusterResult(
        uf=acc.uf, stats=acc.stats, pairs=acc.pairs,
        num_edges=feed.num_edges, overflow=feed.overflow,
        retried=retried, device_stats=feed.device_stats,
        group_stats=feed.group_stats,
        device_scored=getattr(verifier, "n_passthrough", 0),
        host_rescored=getattr(verifier, "n_rescored", 0),
        row_overflow=feed.row_overflow)
