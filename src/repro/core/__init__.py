"""Core library: MinHash-LSH deduplication (the paper's contribution).

The dedup hot path is a staged engine (``engine.cluster_source``)::

    CandidateSource  ->  BatchVerifier  ->  ThresholdUnionFind
    (candidates.py)      (verify.py)        (unionfind.py)

with three thin drivers: ``DedupPipeline`` (host, in-memory),
``StreamingDedup`` (out-of-core band store) and ``dist_lsh`` (sharded,
on-device).
"""
from repro.core.pipeline import DedupConfig, DedupPipeline, DedupResult
from repro.core.lsh import LSHParams, candidate_probability
from repro.core.unionfind import ThresholdUnionFind, connected_components
from repro.core.dist_lsh import DistLSHConfig, make_dedup_step, docs_mesh
from repro.core.candidates import (
    BandMatrixSource,
    CandidateSource,
    StoreBandSource,
    candidate_pairs,
)
from repro.core.engine import ClusterStats, cluster_source
from repro.core.verify import (
    BatchVerifier,
    CallbackVerifier,
    ExactJaccardVerifier,
    SignatureVerifier,
)

__all__ = [
    "DedupConfig",
    "DedupPipeline",
    "DedupResult",
    "LSHParams",
    "candidate_probability",
    "ThresholdUnionFind",
    "connected_components",
    "DistLSHConfig",
    "make_dedup_step",
    "docs_mesh",
    "BandMatrixSource",
    "CandidateSource",
    "StoreBandSource",
    "candidate_pairs",
    "ClusterStats",
    "cluster_source",
    "BatchVerifier",
    "CallbackVerifier",
    "ExactJaccardVerifier",
    "SignatureVerifier",
]
