"""Core library: MinHash-LSH deduplication (the paper's contribution)."""
from repro.core.pipeline import DedupConfig, DedupPipeline, DedupResult
from repro.core.lsh import LSHParams, candidate_probability
from repro.core.unionfind import ThresholdUnionFind, connected_components
from repro.core.dist_lsh import DistLSHConfig, make_dedup_step, docs_mesh

__all__ = [
    "DedupConfig",
    "DedupPipeline",
    "DedupResult",
    "LSHParams",
    "candidate_probability",
    "ThresholdUnionFind",
    "connected_components",
    "DistLSHConfig",
    "make_dedup_step",
    "docs_mesh",
]
