"""Core library: MinHash-LSH deduplication (the paper's contribution).

The dedup hot path is a staged engine (``engine.cluster_source``)::

    CandidateSource  ->  BatchVerifier  ->  ThresholdUnionFind
    (candidates.py)      (verify.py)        (unionfind.py)

with three thin drivers: ``DedupPipeline`` (host, in-memory),
``StreamingDedup`` (out-of-core band store) and ``dist_lsh`` (sharded,
on-device) — all adapters over ``DedupSession`` (``session.py``), the
long-lived incremental-ingest layer (one accumulator, global doc-id
allocation, retained signatures; chunked corpora cluster across steps).

Public API surface (PR 7)
-------------------------
This package IS the blessed import surface — ``from repro.core import
DedupSession, DedupConfig, ...`` — deep module paths stay importable
but are not API-stable.  The blessed names:

* write path — ``DedupSession`` (+ ``DedupConfig``, ``DistLSHConfig``,
  ``RetentionPolicy``), returning pure-value ``ClusterSnapshot``s;
* read path — ``SessionView`` (``DedupSession.view()``),
  ``QueryResult`` / ``query_view`` (``core.query``), and the serving
  shell ``DedupQueryService`` (``serving.dedup_service``, re-exported
  here lazily so importing ``repro.core`` never pulls the serving
  stack).

Naming scheme for ingest-shaped entry points: a method is named
``ingest*`` iff it ADDS DOCUMENTS to long-lived dedup state —
``DedupSession.ingest`` / ``ingest_tokens`` / ``ingest_stream`` and
``StreamingDedup.ingest`` (its store is retained state).  Pure stage
computations are ``compute_*`` (``DedupPipeline.compute_signatures`` /
``compute_bands`` / ``compute_arrays``); reads are ``query*`` / ``view``
and never mutate.  Old spellings (``DedupPipeline.ingest_arrays``,
``ClusterSnapshot.uf``) survive as ``DeprecationWarning`` shims.

Running the linter
------------------
The scheme above — plus the uint32 bit-parity discipline, read-path
purity, jit shape bucketing, and Pallas BlockSpec/VMEM budgets — is
machine-checked by the repo's own static-analysis pass::

    PYTHONPATH=src python -m repro.analysis            # text report
    PYTHONPATH=src python -m repro.analysis --format json

Rules RPR001-RPR005 are documented in DESIGN.md §10; grandfathered
findings live in ``.repro-lint-baseline.json`` and intentional
exceptions carry ``# repro-lint: disable=RPR00x`` comments.  CI runs
the pass (plus ruff) as the ``lint`` job before tier-1.  Set
``REPRO_SANITIZE=1`` for the runtime tripwires (``core.sanitize``):
``jax_debug_nans`` and the SessionView mutation check in query paths.
"""
from repro.core import sanitize as _sanitize
from repro.core.pipeline import DedupConfig, DedupPipeline, DedupResult
from repro.core.lsh import LSHParams, candidate_probability
from repro.core.unionfind import ThresholdUnionFind, connected_components
from repro.core.dist_lsh import (
    DistLSHConfig,
    ShardedClusterResult,
    StepFeed,
    cluster_step_output,
    docs_mesh,
    feed_step_groups,
    make_dedup_step,
    make_streamed_dedup_step,
)
from repro.core.retention import (
    BandBloomFilter,
    RetentionManager,
    RetentionPolicy,
)
from repro.core.session import (
    BandIndex,
    ClusterSnapshot,
    DedupSession,
    DocIdAllocator,
    SessionView,
)
from repro.core.query import QueryResult, query_view
from repro.core.candidates import (
    BandMatrixSource,
    CandidateSource,
    EdgeStreamSource,
    ShardedEdgeSource,
    StoreBandSource,
    candidate_pairs,
)
from repro.core.engine import ClusterAccumulator, ClusterStats, cluster_source
from repro.core.verify import (
    BatchVerifier,
    CallbackVerifier,
    DeviceScoredEdgeVerifier,
    ExactJaccardVerifier,
    ShardedEdgeVerifier,
    SignatureVerifier,
)

__all__ = [
    "DedupConfig",
    "DedupPipeline",
    "DedupResult",
    "LSHParams",
    "candidate_probability",
    "ThresholdUnionFind",
    "connected_components",
    "DistLSHConfig",
    "ShardedClusterResult",
    "StepFeed",
    "cluster_step_output",
    "feed_step_groups",
    "make_dedup_step",
    "make_streamed_dedup_step",
    "docs_mesh",
    "BandBloomFilter",
    "RetentionManager",
    "RetentionPolicy",
    "BandIndex",
    "ClusterSnapshot",
    "DedupSession",
    "DedupQueryService",
    "DocIdAllocator",
    "SessionView",
    "QueryResult",
    "query_view",
    "BandMatrixSource",
    "CandidateSource",
    "EdgeStreamSource",
    "ShardedEdgeSource",
    "StoreBandSource",
    "candidate_pairs",
    "ClusterAccumulator",
    "ClusterStats",
    "cluster_source",
    "BatchVerifier",
    "CallbackVerifier",
    "DeviceScoredEdgeVerifier",
    "ExactJaccardVerifier",
    "ShardedEdgeVerifier",
    "SignatureVerifier",
]

# REPRO_SANITIZE=1 flips jax_debug_nans once, at import (the view
# tripwire in core.query reads the env per call and needs no install).
_sanitize.maybe_install()


def __getattr__(name: str):
    # Lazy re-export: the serving shell lives in repro.serving (its
    # package pulls the model stack), so it is resolved on first
    # access instead of at `import repro.core` time.
    if name == "DedupQueryService":
        from repro.serving.dedup_service import DedupQueryService

        return DedupQueryService
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
