"""Core library: MinHash-LSH deduplication (the paper's contribution).

The dedup hot path is a staged engine (``engine.cluster_source``)::

    CandidateSource  ->  BatchVerifier  ->  ThresholdUnionFind
    (candidates.py)      (verify.py)        (unionfind.py)

with three thin drivers: ``DedupPipeline`` (host, in-memory),
``StreamingDedup`` (out-of-core band store) and ``dist_lsh`` (sharded,
on-device) — all adapters over ``DedupSession`` (``session.py``), the
long-lived incremental-ingest layer (one accumulator, global doc-id
allocation, retained signatures; chunked corpora cluster across steps).
"""
from repro.core.pipeline import DedupConfig, DedupPipeline, DedupResult
from repro.core.lsh import LSHParams, candidate_probability
from repro.core.unionfind import ThresholdUnionFind, connected_components
from repro.core.dist_lsh import (
    DistLSHConfig,
    ShardedClusterResult,
    StepFeed,
    cluster_step_output,
    docs_mesh,
    feed_step_groups,
    make_dedup_step,
    make_streamed_dedup_step,
)
from repro.core.retention import (
    BandBloomFilter,
    RetentionManager,
    RetentionPolicy,
)
from repro.core.session import (
    BandIndex,
    ClusterSnapshot,
    DedupSession,
    DocIdAllocator,
)
from repro.core.candidates import (
    BandMatrixSource,
    CandidateSource,
    EdgeStreamSource,
    ShardedEdgeSource,
    StoreBandSource,
    candidate_pairs,
)
from repro.core.engine import ClusterAccumulator, ClusterStats, cluster_source
from repro.core.verify import (
    BatchVerifier,
    CallbackVerifier,
    DeviceScoredEdgeVerifier,
    ExactJaccardVerifier,
    ShardedEdgeVerifier,
    SignatureVerifier,
)

__all__ = [
    "DedupConfig",
    "DedupPipeline",
    "DedupResult",
    "LSHParams",
    "candidate_probability",
    "ThresholdUnionFind",
    "connected_components",
    "DistLSHConfig",
    "ShardedClusterResult",
    "StepFeed",
    "cluster_step_output",
    "feed_step_groups",
    "make_dedup_step",
    "make_streamed_dedup_step",
    "docs_mesh",
    "BandBloomFilter",
    "RetentionManager",
    "RetentionPolicy",
    "BandIndex",
    "ClusterSnapshot",
    "DedupSession",
    "DocIdAllocator",
    "BandMatrixSource",
    "CandidateSource",
    "EdgeStreamSource",
    "ShardedEdgeSource",
    "StoreBandSource",
    "candidate_pairs",
    "ClusterAccumulator",
    "ClusterStats",
    "cluster_source",
    "BatchVerifier",
    "CallbackVerifier",
    "DeviceScoredEdgeVerifier",
    "ExactJaccardVerifier",
    "ShardedEdgeVerifier",
    "SignatureVerifier",
]
