"""Incremental multi-step ingest: one ``DedupSession`` over every path.

The paper's pipeline is batch-shaped (shingle -> MinHash -> LSH ->
verify -> disjoint sets) but the corpus it targets is continuously fed:
10M+ notes arrive in chunks.  ``DedupSession`` owns the long-lived
clustering state —

* ONE ``engine.ClusterAccumulator`` (union-find + verified-sim cache +
  cumulative ``ClusterStats``),
* global doc-id allocation (``DocIdAllocator`` — the single home of the
  ``doc_id_base`` / ``doc_offsets`` arithmetic the three drivers used
  to re-implement by hand),
* retained per-doc signature rows (one growing verifier), and
* a retained band index for cross-step candidate generation,

and exposes host, streaming, and sharded **backends** behind the same
``ingest(chunk) -> ClusterSnapshot`` API (DESIGN.md §6).  Each chunk
contributes two candidate families:

* *within-chunk*: the backend's native source — host band matrix,
  Design-2 store scan, or the sharded step's prescreened edge buffers;
* *cross-step*: band collisions of the chunk's band values against the
  retained index (same doc re-shingled, near-dups split across chunks)
  become explicit edges verified through the same engine.

The candidate-pair SET over N chunks equals the one-shot run over the
concatenated corpus (band collision is chunk-independent); only the
feed order differs, and ``ClusterAccumulator`` is order-invariant over
an edge set (pinned by the hypothesis test in
``tests/test_staged_engine.py``), so snapshot-after-every-chunk ends at
the one-shot clustering with bit-identical per-edge sims.

The sharded backend feeds several ``make_streamed_dedup_step``
invocations into the one accumulator; ``ingest_stream`` keeps a
one-chunk lookahead so the host merge of step t overlaps the device
shuffle of step t+1 (the same overlap the band groups give WITHIN a
step, lifted across steps).

The historical drivers are thin adapters over this layer:
``pipeline.DedupPipeline.run`` is a one-shot host ingest,
``streaming.StreamingDedup.cluster`` snapshots a session over its own
band store, and ``dist_lsh.cluster_step_output`` is the one-step
sharded merge (both call ``dist_lsh.feed_step_groups``).
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass, field, replace
from typing import Iterable, Iterator

import numpy as np
import jax.numpy as jnp

from repro.core import lsh, minhash, shingle
from repro.core.bandstore import SqliteBandStore
from repro.core.candidates import BandMatrixSource, ShardedEdgeSource
from repro.core.engine import (
    ClusterAccumulator,
    ClusterStats,
    merge_cluster_rounds,
)
from repro.core.pipeline import DedupConfig
from repro.core.retention import (
    BandBloomFilter,
    RetentionManager,
    RetentionPolicy,
)
from repro.core.unionfind import ThresholdUnionFind
from repro.core.verify import (
    BatchVerifier,
    DeviceScoredEdgeVerifier,
    ExactJaccardVerifier,
    SignatureVerifier,
    as_verifier,
)

BACKENDS = ("host", "streaming", "sharded")


class DocIdAllocator:
    """Global doc-id allocation for chunked ingest (one home for the
    ``doc_id_base`` arithmetic).

    ``allocate(n)`` hands out the next contiguous block and returns its
    base; ``device_offsets(base, d_loc, n_dev)`` is the per-device
    ``doc_offsets`` convention of the sharded step (device i's first
    row is ``base + i * d_loc``).  Padding rows a backend appends for
    divisibility live ABOVE the allocated block (ids >= base + n), so
    they can never alias a later chunk's ids — they are range-filtered
    before any of them reaches the engine.
    """

    def __init__(self, base: int = 0):
        self.base = int(base)
        self.next = int(base)

    @property
    def n_docs(self) -> int:
        """Exclusive upper bound of allocated ids (gap ids included)."""
        return self.next

    def allocate(self, n: int) -> int:
        base = self.next
        self.next += int(n)
        return base

    @staticmethod
    def device_offsets(base: int, d_loc: int, n_dev: int) -> np.ndarray:
        return np.uint32(base) + np.uint32(d_loc) * np.arange(
            n_dev, dtype=np.uint32)


class BandIndex:
    """Retained band values of every ingested doc, keyed for collision.

    ``match_then_insert`` is the cross-step candidate generator: the
    chunk's band values are looked up against the retained state —
    every (band, value) hit against an EARLIER chunk emits an
    (old_doc, new_doc) edge — and then inserted, so a later chunk can
    collide with this one.  Same-chunk collisions are never emitted
    (the backend's within-chunk source owns those); old-vs-old pairs
    were emitted when the old chunk arrived.

    Bounded retained state (DESIGN.md §7): with ``track_entries`` the
    index keeps a per-doc reverse map so ``evict`` can rewrite an
    evicted doc's bucket entries onto its cluster root — membership
    hits keep producing candidate pairs against *retained* docs, and
    the engine compresses to roots anyway, so eviction alone changes no
    clustering outcome.  The unbounded dimension is the KEY count
    (every unique band value ever seen); ``key_budget`` caps it per
    band by compacting the least-recently-HIT keys into a per-band
    ``BandBloomFilter`` (hits refresh recency — a true LRU, so a hot
    key recurring every chunk is never compacted).  A later hit on a
    compacted key is counted in
    ``filter_only_hits`` — the value was seen before, but by a doc the
    index can no longer name, so the pair cannot be re-verified (the
    LSHBloom recall trade).
    """

    def __init__(self, num_bands: int, *, key_budget: int | None = None,
                 bloom_bits: int = 1 << 17, bloom_hashes: int = 4,
                 track_entries: bool = False):
        self._maps: list[dict[tuple[int, int], list[int]]] = [
            {} for _ in range(num_bands)]
        self._key_budget = key_budget
        self._bloom_bits = int(bloom_bits)
        self._bloom_hashes = int(bloom_hashes)
        self._filters: list[BandBloomFilter | None] = [None] * num_bands
        self._entries: dict[int, list] | None = (
            {} if track_entries else None)
        self.filter_only_hits = 0
        self.compacted_keys = 0

    @property
    def num_bands(self) -> int:
        return len(self._maps)

    def _filter(self, j: int) -> BandBloomFilter:
        if self._filters[j] is None:
            self._filters[j] = BandBloomFilter(
                self._bloom_bits, self._bloom_hashes)
        return self._filters[j]

    def match_then_insert(self, bands: np.ndarray,
                          doc_id_base: int) -> np.ndarray:
        """(C, b, 2) chunk bands -> (E, 2) int64 cross-step edges."""
        bands = np.asarray(bands)
        if bands.ndim != 3 or bands.shape[1] != self.num_bands:
            raise ValueError(
                f"expected (C, {self.num_bands}, 2) bands, "
                f"got {bands.shape}")
        edges: list[tuple[int, int]] = []
        for j, m in enumerate(self._maps):
            col = bands[:, j, :]
            flt = self._filters[j]
            for i in range(len(col)):
                key = (int(col[i, 0]), int(col[i, 1]))
                new_id = doc_id_base + i
                olds = m.get(key)
                if olds is not None:
                    edges.extend((old, new_id) for old in olds
                                 if old < doc_id_base)
                    olds.append(new_id)
                    # Refresh recency: the budget sweep pops from the
                    # FRONT of the dict, so a hit must move its key to
                    # the end or a HOT key (a duplicate recurring every
                    # chunk) would be compacted by insertion age and
                    # break the within-window parity invariant.
                    m[key] = m.pop(key)
                else:
                    if flt is not None and key in flt:
                        # Seen before, partner compacted away: the pair
                        # can no longer be exactly re-verified.
                        self.filter_only_hits += 1
                    m[key] = [new_id]
                if self._entries is not None:
                    self._entries.setdefault(new_id, []).append((j, key))
            if self._key_budget is not None:
                while len(m) > self._key_budget:
                    old_key = next(iter(m))
                    del m[old_key]
                    self._filter(j).add(old_key)
                    self.compacted_keys += 1
        if not edges:
            return np.zeros((0, 2), dtype=np.int64)
        return np.array(edges, dtype=np.int64)

    def evict(self, doc_ids, root_of) -> None:
        """Rewrite evicted docs' bucket entries onto their cluster root.

        ``root_of`` maps a doc id to its current union-find root (the
        retained representative).  The root inherits the evicted doc's
        (band, key) entries — re-homed in the reverse map so a later
        eviction of a deposed root keeps working — and is inserted into
        the bucket at most once, so bucket lists shrink onto the
        retained set instead of growing with cluster size.
        """
        if self._entries is None:
            raise ValueError(
                "BandIndex was built without track_entries; eviction "
                "needs the per-doc reverse map")
        for d in doc_ids:
            d = int(d)
            for j, key in self._entries.pop(d, ()):
                olds = self._maps[j].get(key)
                if olds is None:
                    continue               # key already compacted
                try:
                    olds.remove(d)
                except ValueError:
                    continue               # key was compacted + re-seen
                r = int(root_of(d))
                if r not in olds:
                    olds.append(r)
                    self._entries.setdefault(r, []).append((j, key))

    def export_maps(self) -> tuple:
        """Frozen per-band bucket maps for a ``SessionView``.

        Each band's ``{(hi, lo): [doc ids]}`` dict is copied with its
        bucket lists frozen to tuples, so a published view's probe
        results can never be changed by a later ``match_then_insert``
        or ``evict`` (DESIGN.md §9).  Pure read — recency (the LRU
        compaction order) is NOT refreshed.
        """
        return tuple({k: tuple(v) for k, v in m.items()}
                     for m in self._maps)

    def export_filters(self) -> tuple:
        """Frozen per-band Bloom filters for a ``SessionView`` (copies;
        a concurrent compaction's ``add`` cannot flip bits mid-probe)."""
        return tuple(f.copy() if f is not None else None
                     for f in self._filters)

    def stats(self) -> dict:
        """Memory/recall accounting for reports and the soak benchmark."""
        return {
            "n_keys": sum(len(m) for m in self._maps),
            "n_entries": sum(len(v) for m in self._maps
                             for v in m.values()),
            "n_docs_tracked": (len(self._entries)
                               if self._entries is not None else 0),
            "compacted_keys": self.compacted_keys,
            "filter_only_hits": self.filter_only_hits,
            "bloom_bytes": sum(f.memory_bytes for f in self._filters
                               if f is not None),
        }


@dataclass(frozen=True)
class ClusterSnapshot:
    """Cluster state after an ``ingest`` call — a pure VALUE object.

    Every public field is a copy (``labels`` is frozen read-only,
    ``stats`` is a counter copy, ``pairs`` is a fresh list built from
    the verified-sim cache) or an immutable scalar: holding a snapshot
    never pins live session state, and later ingests cannot change what
    a snapshot already reported.  The LIVE handles moved off the public
    surface in PR 7 — ``DedupSession.uf`` is the live union-find, and
    the read path goes through the immutable ``SessionView``
    (``DedupSession.view``, DESIGN.md §9).  The deprecated ``uf``
    property still serves old call sites via the private ``_uf`` handle.
    """

    n_docs: int                 # docs ingested so far (id upper bound)
    labels: np.ndarray          # (n_docs,) cluster root per doc (frozen)
    stats: ClusterStats         # cumulative engine counters (a copy)
    pairs: list                 # every evaluated (a, b, sim) so far (a copy)
    overflow: int = 0           # sharded: device buffer overflow so far
    retried: int = 0            # sharded: overflow fallback passes run
    device_scored: int = 0      # sharded stage2=device: pass-throughs
    host_rescored: int = 0      # sharded stage2=device: host re-scores
    row_overflow: int = 0       # sharded: cross-shard row-buffer overflow
    # Retained-state view (bounded-memory sessions, DESIGN.md §7):
    retained_rows: int = 0      # live verifier rows (== n_docs unevicted)
    evicted: int = 0            # rows released by the retention policy
    filter_only_hits: int = 0   # band hits whose partner was compacted
    refine_merges: int = 0      # second-round merges so far
    representatives: np.ndarray | None = None  # retained roots (sorted)
    _uf: ThresholdUnionFind | None = field(default=None, repr=False,
                                           compare=False)

    @property
    def uf(self) -> ThresholdUnionFind | None:
        """Deprecated: the LIVE union-find (not part of the snapshot's
        value semantics).  Use ``DedupSession.uf`` for live clustering
        state, or ``labels`` for the frozen per-doc roots."""
        warnings.warn(
            "ClusterSnapshot.uf is deprecated: snapshots are pure value "
            "objects; use DedupSession.uf for the live union-find or "
            "ClusterSnapshot.labels for the frozen roots",
            DeprecationWarning, stacklevel=2)
        return self._uf

    @property
    def num_clusters(self) -> int:
        """Duplicate clusters, i.e. components of size >= 2."""
        _, counts = np.unique(self.labels, return_counts=True)
        return int((counts >= 2).sum())

    @property
    def num_duplicates(self) -> int:
        """Docs that are non-representative members of some cluster."""
        return self.n_docs - len(set(self.labels.tolist()))

    def clusters(self, min_size: int = 2) -> list[list[int]]:
        groups: dict[int, list[int]] = {}
        for i, r in enumerate(self.labels):
            groups.setdefault(int(r), []).append(i)
        return [v for v in groups.values() if len(v) >= min_size]


@dataclass(frozen=True)
class ExactRowsView:
    """Frozen exact-verifier rows inside a ``SessionView`` (host
    exact-verification sessions).

    ``vocab`` is shared with the live verifier BY REFERENCE: interning
    is append-only (an n-gram's id never changes once assigned), so
    read-only lookups stay valid across later ingests; the read path
    must only ever ``get`` from it, never ``setdefault``.
    """

    ids: np.ndarray             # (R, lmax) padded sorted n-gram id rows
    lengths: np.ndarray         # (R,) real row lengths
    slot_of: dict | None        # doc -> row (eviction layout; None = id)
    vocab: dict                 # n-gram -> id (append-only, shared)
    ngram: int

    def row_for(self, doc: int) -> np.ndarray:
        slot = doc if self.slot_of is None else self.slot_of[doc]
        return self.ids[slot][: int(self.lengths[slot])]


@dataclass(frozen=True)
class SessionView:
    """Immutable read-path handle over a ``DedupSession`` (DESIGN.md §9).

    Published atomically (one attribute swap on the session) at the end
    of an ingest: a query running against a view can never race a
    concurrent ingest or retention sweep, because everything it touches
    is either a frozen copy (labels, band maps, Bloom filters, the
    eviction-mode row matrix) or an append-only buffer whose visible
    rows are never rewritten (the unevicted signature/token matrices —
    see ``SignatureVerifier.frozen_rows``).  Two consecutive views share
    those append-only buffers, so publication is O(band-index entries),
    not O(corpus).

    ``core.query`` implements probe/verify over a view;
    ``serving.dedup_service.DedupQueryService`` serves it.
    """

    version: int                # monotone publication counter
    n_docs: int                 # docs covered (labels bound)
    edge_threshold: float       # the engine's duplicate threshold
    num_bands: int
    rows_per_band: int
    labels: np.ndarray          # (n_docs,) cluster root per doc (frozen)
    band_maps: tuple            # per band: {(hi, lo): (doc ids,)}
    band_filters: tuple         # per band: BandBloomFilter | None
    signatures: np.ndarray      # retained rows (estimate sessions)
    slot_of: dict | None        # doc -> signature row (eviction layout)
    exact: ExactRowsView | None = None   # exact-verification sessions
    # Disk-tier sessions (DedupConfig.store="sqlite", DESIGN.md §12):
    # the live SqliteBandStore the read path delegates probes to (its
    # ``probe_keys`` is a pure Bloom-first read) instead of exporting
    # the whole on-disk index into host dicts per publication.
    # ``band_maps``/``band_filters`` are empty then.  The trade: probe
    # results reflect the store at QUERY time, so a stale view held
    # across later ingests can see newer entries (bounded to its own
    # ``n_docs`` coverage by the probe's id filter) — the memory tier
    # keeps strict frozen-at-publication semantics.
    band_store: SqliteBandStore | None = None
    # Device-probe index cache (``core.query``): derived read-only from
    # the frozen band maps, built lazily on the first large query batch
    # and reused for the view's lifetime.  Excluded from eq/repr — it
    # is a cache, not state.
    _probe_cache: dict = field(default_factory=dict, repr=False,
                               compare=False)

    @property
    def mode(self) -> str:
        return "exact" if self.exact is not None else "estimate"

    def root_of(self, doc: int) -> int:
        return int(self.labels[doc])

    def slot_index(self, ids: np.ndarray) -> np.ndarray:
        """Global doc ids -> physical signature rows (eviction-aware)."""
        ids = np.asarray(ids, dtype=np.int64)
        if self.slot_of is None:
            return ids
        so = self.slot_of
        return np.fromiter((so[int(i)] for i in ids.ravel()),
                           dtype=np.int64,
                           count=ids.size).reshape(ids.shape)

    def rows_for(self, doc_ids) -> np.ndarray:
        """Retained signature rows for ``doc_ids`` at publication time."""
        ids = np.asarray(doc_ids, dtype=np.int64)
        if ids.size == 0:
            return np.zeros((0,) + self.signatures.shape[1:],
                            dtype=self.signatures.dtype)
        return self.signatures[self.slot_index(ids)]


class DedupSession:
    """Long-lived incremental dedup over host/streaming/sharded backends.

    ``ingest(chunk)`` clusters one chunk of documents into the session
    and returns a cumulative ``ClusterSnapshot``; ``ingest_stream``
    pipelines a sequence of chunks (sharded backend: the host merge of
    step t overlaps the device shuffle of step t+1).

    Backends:

    * ``"host"`` — in-memory band matrix per chunk; verification is
      exact Jaccard or the signature estimate per
      ``config.exact_verification`` (same semantics as
      ``DedupPipeline``).
    * ``"streaming"`` — chunks are written to a Design-2 band store
      (``StreamingDedup`` phase 1); each ingest re-scans the store
      band-major (the paper's phase 2) through the accumulator, whose
      verified-sim cache makes the re-scan cheap (no pair is ever
      re-verified).
    * ``"sharded"`` — each chunk runs one
      ``dist_lsh.make_streamed_dedup_step`` invocation with
      ``doc_offsets`` from the allocator; the band-group buffers feed
      the session accumulator via ``dist_lsh.feed_step_groups``, and
      ``stage2="device"`` scores (incl. cross-shard, via the exchanged
      row buffers) register with the session's long-lived
      ``DeviceScoredEdgeVerifier``.

    All backends share the cross-step ``BandIndex`` pass except
    streaming, whose store re-scan already covers cross-chunk
    collisions (the store IS the retained state there).
    """

    def __init__(
        self,
        config: DedupConfig | None = None,
        backend: str = "host",
        *,
        dist_config=None,
        mesh=None,
        store_path: str = ":memory:",
        chunk_docs: int = 512,
        doc_id_base: int = 0,
        verifier: BatchVerifier | None = None,
        stream: bool | None = None,
        retention: RetentionPolicy | None = None,
        _adopt_streaming=None,
    ):
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; "
                             f"one of {BACKENDS}")
        self.config = config or DedupConfig()
        self.backend = backend
        self.allocator = DocIdAllocator(doc_id_base)
        self._verifier = as_verifier(verifier) if verifier is not None \
            else None
        self._external_verifier = verifier is not None
        self.acc = ClusterAccumulator(
            int(doc_id_base), _NullVerifier(), self.config.edge_threshold,
            self.config.tree_threshold,
            use_disjoint_sets=self.config.use_disjoint_sets,
            batch=self.config.verify_batch)
        self.retention = (RetentionManager(retention)
                          if retention is not None else None)
        if self.retention is not None:
            # Incremental root-representative tracking: each union logs
            # its deposed root so eviction sweeps never scan all docs.
            self.acc.uf.track_deposed = True
        # Cross-step band index tier (DESIGN.md §12): "memory" keeps the
        # host dict index; "sqlite" retains it disk-resident behind
        # Bloom-first lookups (same match/insert/evict semantics — the
        # cross-tier parity pins depend on it).  The streaming backend's
        # retained state is its band STORE, so its (unused) index stays
        # in memory regardless.
        index_cls = (SqliteBandStore
                     if self.config.store == "sqlite"
                     and backend != "streaming" else BandIndex)
        index_kw = {"path": store_path} if index_cls is SqliteBandStore \
            else {}
        self.band_index = index_cls(
            num_bands=self.config.num_bands,
            key_budget=(retention.band_key_budget
                        if retention is not None else None),
            bloom_bits=(retention.bloom_bits if retention is not None
                        else 1 << 17),
            bloom_hashes=(retention.bloom_hashes
                          if retention is not None else 4),
            track_entries=retention is not None, **index_kw)
        self.seeds = minhash.default_seeds(self.config.num_hashes)
        self.overflow = 0
        self.retried = 0
        self.row_overflow = 0
        self.steps_ingested = 0
        self.refine_merges = 0
        self.refines_run = 0
        # Docs whose merge has completed — snapshots cover these.  With
        # ingest_stream's one-chunk lookahead the allocator runs ahead
        # of the merges, so the two counters differ transiently.
        self.n_merged = int(doc_id_base)
        self._finalized = False
        # Read-path publication state (SessionView, DESIGN.md §9).
        self._view_cache: SessionView | None = None
        self._view_key = None
        self._view_version = 0
        if backend == "host":
            self._impl = _HostBackend(self)
        elif backend == "streaming":
            self._impl = _StreamingBackend(self, store_path=store_path,
                                           chunk_docs=chunk_docs,
                                           adopt=_adopt_streaming)
        else:
            self._impl = _ShardedBackend(self, dist_config=dist_config,
                                         mesh=mesh, stream=stream)

    @classmethod
    def over_store(cls, sd, *, config: DedupConfig | None = None,
                   verifier: BatchVerifier | None = None) -> "DedupSession":
        """Adopt an already-populated ``StreamingDedup`` (store + sig
        cache) and cluster its contents as one pre-ingested step.

        This is the adapter behind ``StreamingDedup.cluster``: the
        band-major phase-2 scan runs through a session accumulator, and
        the returned session stays live — further ``ingest`` calls
        append to the same store and union-find.  ``sd.n_docs`` may
        exceed the contiguous allocation (resumed-ingest gaps); gap ids
        have no store rows, so they stay singletons.
        """
        sess = cls(config=config or sd.config, backend="streaming",
                   verifier=verifier, _adopt_streaming=sd)
        sess.allocator.next = sd.n_docs
        sess.n_merged = sd.n_docs
        if verifier is None and sd.n_ingested:
            # Full (n_docs, M) global-id matrix, gap rows zero — keeps
            # "row i == doc i" for the adopted docs and later ingests.
            sess._verifier = sd.default_verifier()
        sess.acc.grow(sd.n_docs)
        sess.acc.feed(sd.candidate_source(), verifier=sess._verifier)
        sess.steps_ingested += 1
        return sess

    # -- state -------------------------------------------------------------

    @property
    def n_docs(self) -> int:
        """Docs fully ingested (merged) so far — snapshot coverage."""
        return self.n_merged

    @property
    def stats(self) -> ClusterStats:
        return self.acc.stats

    @property
    def uf(self) -> ThresholdUnionFind:
        return self.acc.uf

    @property
    def verifier(self) -> BatchVerifier | None:
        return self._verifier

    @property
    def signatures(self) -> np.ndarray:
        """The retained signature matrix, row i == doc i until the
        retention policy evicts a row (``verifier.rows_for`` is the
        eviction-aware accessor).

        Owned by the session's verifier (one copy, grown in place);
        empty for exact-mode or external-verifier sessions, which do
        not verify through signatures.
        """
        sig = getattr(self._verifier, "signatures", None)
        if sig is None:
            return np.zeros((0, self.config.num_hashes), dtype=np.uint32)
        return sig

    def snapshot(self) -> ClusterSnapshot:
        v = self._verifier
        retained = getattr(v, "n_live_rows", None)
        labels = self.uf.components()[: self.n_docs]
        labels.setflags(write=False)
        return ClusterSnapshot(
            n_docs=self.n_docs,
            labels=labels,
            stats=replace(self.acc.stats),
            pairs=self.acc.pairs,
            _uf=self.uf,
            overflow=self.overflow,
            retried=self.retried,
            device_scored=getattr(v, "n_passthrough", 0),
            host_rescored=getattr(v, "n_rescored", 0),
            row_overflow=self.row_overflow,
            retained_rows=(retained if retained is not None
                           else self.n_docs),
            evicted=(self.retention.n_evicted
                     if self.retention is not None else 0),
            filter_only_hits=self.band_index.filter_only_hits,
            refine_merges=self.refine_merges,
            representatives=(np.array(self.retention.representatives(),
                                      dtype=np.int64)
                             if self.retention is not None else None),
        )

    # -- read path (SessionView publication, DESIGN.md §9) -------------------

    def _view_state_key(self) -> tuple:
        """Covers every mutation that can change a view's contents."""
        return (self.steps_ingested, self.n_merged, self.refines_run,
                self.acc.stats.unions_done,
                self.retention.n_evicted if self.retention is not None
                else 0,
                self.band_index.compacted_keys)

    def view(self) -> SessionView:
        """The current immutable read-path handle over this session.

        Built on first read after a mutation and cached — the cache
        swap is the atomic publication, and the publication key covers
        every state-mutating counter (ingest steps, merges, unions,
        refines, evictions, band compaction), so the SAME object comes
        back until the session actually changes.  Queries holding an
        older view keep working unchanged across later ingests: their
        frozen copies never see them (see ``SessionView``).

        The streaming backend keeps its retained state in the band
        store, not the cross-step ``BandIndex``, so it has nothing to
        probe; use a host or sharded session for the query service.
        """
        if self.backend == "streaming":
            raise ValueError(
                "SessionView needs a backend that maintains the "
                "cross-step BandIndex (host or sharded); the streaming "
                "backend's retained state is its band store")
        key = self._view_state_key()
        if self._view_cache is not None and self._view_key == key:
            return self._view_cache
        labels = self.uf.components()[: self.n_docs]
        labels.setflags(write=False)
        cfg = self.config
        v = self._verifier
        empty_sig = np.zeros((0, cfg.num_hashes), dtype=np.uint32)
        exact = None
        sig, slot_of = empty_sig, None
        if isinstance(v, ExactJaccardVerifier):
            if v._vocab is None or v._ngram is None:
                raise ValueError(
                    "exact verifier was built from raw id rows (no "
                    "vocab/ngram); the read path cannot intern query "
                    "documents — build it with from_token_lists")
            ids, lengths, slot = v.frozen_rows()
            exact = ExactRowsView(ids=ids, lengths=lengths, slot_of=slot,
                                  vocab=v._vocab, ngram=v._ngram)
        elif isinstance(v, SignatureVerifier):
            sig, slot_of = v.frozen_rows()
        elif v is not None and self.n_docs > self.allocator.base:
            raise ValueError(
                "SessionView needs retained signature or token rows; "
                "external callback verifiers keep neither — pass a "
                "SignatureVerifier/ExactJaccardVerifier instead")
        if isinstance(self.band_index, SqliteBandStore):
            # Disk tier: don't haul the whole on-disk index into host
            # dicts per publication — the view probes the store's pure
            # Bloom-first read path instead (see SessionView.band_store).
            band_maps, band_filters = (), ()
            band_store = self.band_index
        else:
            band_maps = self.band_index.export_maps()
            band_filters = self.band_index.export_filters()
            band_store = None
        view = SessionView(
            version=self._view_version + 1,
            n_docs=self.n_docs,
            edge_threshold=cfg.edge_threshold,
            num_bands=cfg.num_bands,
            rows_per_band=cfg.rows_per_band,
            labels=labels,
            band_maps=band_maps,
            band_filters=band_filters,
            signatures=sig,
            slot_of=slot_of,
            exact=exact,
            band_store=band_store,
        )
        # The one sanctioned read-path mutation: this cache swap IS the
        # atomic single-writer publication protocol (DESIGN.md §9) —
        # same key, same object; queries never observe a half-built view.
        # repro-lint: disable=RPR002
        self._view_version = view.version
        self._view_cache, self._view_key = view, key  # repro-lint: disable=RPR002
        return view

    # -- ingest ------------------------------------------------------------

    def _check_live(self):
        if self._finalized:
            raise ValueError(
                "this session was finalized by a one-shot ingest "
                "(DedupPipeline.run adapter) and skipped the cross-step "
                "index; start a fresh DedupSession for chunked ingest")

    def ingest(self, texts: Iterable[str]) -> ClusterSnapshot:
        """Cluster one chunk of documents; returns a cumulative snapshot."""
        self._check_live()
        pending = self._impl.dispatch(list(texts))
        self._impl.merge(pending)
        self._post_merge()
        return self.snapshot()

    def ingest_tokens(self,
                      token_lists: list[list[str]]) -> ClusterSnapshot:
        """``ingest`` over pre-tokenized documents."""
        self._check_live()
        pending = self._impl.dispatch(list(token_lists), tokenized=True)
        self._impl.merge(pending)
        self._post_merge()
        return self.snapshot()

    def ingest_stream(
        self, chunks: Iterable[list], *, tokenized: bool = False,
    ) -> Iterator[ClusterSnapshot]:
        """Pipelined multi-chunk ingest: one-chunk dispatch lookahead.

        Chunk t+1's device work (sharded backend: signature compute +
        every band-group's all_to_all shuffle) is dispatched BEFORE
        chunk t's host merge runs, so the merge of step t overlaps the
        shuffle of step t+1.  Yields the cumulative snapshot after each
        chunk, in order; results are identical to sequential ``ingest``
        calls (dispatch only allocates ids and launches device work —
        the merges still run in chunk order against the same
        accumulator and retained index).

        ``tokenized=True`` streams pre-tokenized chunks (lists of token
        lists) — the flag is threaded through to the backend dispatch
        so already-tokenized documents are never re-tokenized.
        """
        self._check_live()
        pending = None
        for chunk in chunks:
            nxt = self._impl.dispatch(list(chunk), tokenized=tokenized)
            if pending is not None:
                self._impl.merge(pending)
                self._post_merge()
                yield self.snapshot()
            pending = nxt
        if pending is not None:
            self._impl.merge(pending)
            self._post_merge()
            yield self.snapshot()

    def _merge_precomputed(self, token_lists, sig,
                           bands) -> ClusterSnapshot:
        """Host-backend ingest of a chunk whose tokenize/signature/band
        stages the caller already ran (the ``DedupPipeline.run`` timing
        adapter).  One-shot by construction: the cross-step band index
        is skipped entirely (a single chunk has no earlier chunk to
        collide with, and indexing every (doc, band) would be pure
        overhead at corpus scale), so the session is finalized — it
        cannot accept further chunks."""
        if self.backend != "host":
            raise ValueError("precomputed ingest is a host-backend path")
        if self._finalized:
            raise ValueError("one-shot session already finalized")
        base = self.allocator.allocate(len(token_lists))
        self._impl.merge((base, token_lists, np.asarray(sig),
                          np.asarray(bands)), index=False)
        self._finalized = True
        return self.snapshot()

    # -- bounded retained state (DESIGN.md §7) ------------------------------

    def _post_merge(self) -> None:
        """Retention sweep + auto-refine cadence after a chunk merge."""
        if self.retention is None:
            return
        self.retention.sweep(self)
        every = self.retention.policy.refine_every
        if every and self.steps_ingested % every == 0:
            self.refine()

    def _release_rows(self, doc_ids) -> None:
        """Evict docs' rows from the session verifier (retention hook).

        External verifiers without a ``release_rows`` API keep their
        rows (the policy still bounds the band index and logs roots).
        """
        v = self._verifier
        if v is not None and hasattr(v, "release_rows"):
            v.release_rows(doc_ids)

    def _compact_band_store(self, doc_ids, root_of) -> None:
        """Rewrite evicted docs' band-STORE rows onto their cluster
        roots (retention hook; streaming backend only — the other
        backends' retained band state is the ``band_index``, which the
        sweep's ``evict`` call already rewrote).  Keeps the phase-1
        store bounded instead of growing with evicted history (the
        ROADMAP "retention completeness" fix); clustering-neutral, see
        ``bandstore.Design2Store.compact``.
        """
        compact = getattr(self._impl, "compact_store", None)
        if compact is not None:
            compact(doc_ids, root_of)

    def _representatives(self) -> list[int]:
        """Sorted current union-find roots (the retained-rep view).

        Gap ids below the session's base (``doc_id_base`` sessions)
        are excluded: they have no real document behind them — their
        verifier rows are blank, so re-banding them would collide every
        gap with every other gap at a bogus similarity of 1.0.
        """
        if self.retention is not None:
            self.retention.sweep(self)   # sync roots with recent unions
            return self.retention.representatives()
        base = self.allocator.base
        lab = self.uf.components()[: self.n_docs]
        return sorted({int(r) for r in lab[base:]} if base else
                      {int(r) for r in lab})

    def _rep_band_pairs(self, reps: list[int],
                        est: SignatureVerifier) -> np.ndarray:
        """Re-band representatives, return their collision pairs.

        The second clustering round's candidate generator: band values
        are deterministic in the signature rows, so representative
        collisions are exactly the original LSH collisions restricted
        to the current root set — no O(reps^2) sweep.
        """
        rows = est.rows_for(reps)
        bands = np.asarray(lsh.band_values(
            jnp.asarray(rows), self.config.rows_per_band))
        pairs: list[tuple[int, int]] = []
        for j in range(bands.shape[1]):
            seen: dict[tuple[int, int], list[int]] = {}
            col = bands[:, j, :]
            for i, rep in enumerate(reps):
                key = (int(col[i, 0]), int(col[i, 1]))
                olds = seen.get(key)
                if olds is None:
                    seen[key] = [rep]
                else:
                    pairs.extend((old, rep) for old in olds)
                    olds.append(rep)
        if not pairs:
            return np.zeros((0, 2), dtype=np.int64)
        return np.array(pairs, dtype=np.int64)

    def refine(self) -> ClusterSnapshot:
        """Incremental second clustering round (paper §10) over the
        retained representatives.

        Re-bands only the current cluster representatives and drives
        their collision pairs through ``engine.merge_cluster_rounds``
        with the accumulator's verified-sim cache — sims the session
        already verified are served from cache, and second-round sims
        become visible to later feeds.  Merges clusters whose
        representatives clear ``edge_threshold`` (the over-partitioning
        fix the paper runs as a batch pass; here it is incremental and
        auto-triggered every ``RetentionPolicy.refine_every`` steps).

        Verifiers without retained signatures (exact / callback
        sessions) fall back to the full representative-pair sweep.
        """
        self._check_live()
        reps = self._representatives()
        merges = 0
        if len(reps) >= 2 and self._verifier is not None:
            est = self._estimate_verifier()
            cand = None
            if isinstance(est, SignatureVerifier) and \
                    est.signatures.size:
                cand = self._rep_band_pairs(reps, est)
            merges = merge_cluster_rounds(
                self.uf, est, self.config.edge_threshold,
                roots=reps, candidate_pairs=cand,
                sim_cache=self.acc.evaluated)
        self.refine_merges += merges
        self.refines_run += 1
        if self.retention is not None and merges:
            # Second-round unions deposed roots; evict their rows.
            self.retention.sweep(self)
        return self.snapshot()

    # -- shared backend plumbing -------------------------------------------

    def _retain(self, token_lists, sig: np.ndarray) -> None:
        """Grow the session verifier with one chunk's docs.

        The verifier owns the retained state ("row i == doc i"): the
        first chunk builds it — padded with blank rows for any ids
        below the chunk's base (``doc_id_base`` sessions; those ids
        have no band rows, so they can never become candidates) — and
        later chunks extend it in place.
        """
        if self._external_verifier:
            return
        sig = np.asarray(sig)
        cfg = self.config
        if self._verifier is None:
            gap = self.n_merged  # ids below the first chunk's base
            if self._wants_exact():
                self._verifier = ExactJaccardVerifier.from_token_lists(
                    [[]] * gap + list(token_lists), cfg.ngram)
                return
            full = sig if gap == 0 else np.concatenate(
                [np.zeros((gap, sig.shape[1]), dtype=sig.dtype), sig])
            cls = (DeviceScoredEdgeVerifier
                   if self.backend == "sharded"
                   and self._impl.stage2 == "device"
                   else SignatureVerifier)
            self._verifier = cls(full, backend=cfg.resolved_backend())
        elif self._wants_exact():
            self._verifier.extend_token_lists(token_lists)
        else:
            self._verifier.extend_signatures(sig)

    def _wants_exact(self) -> bool:
        return self.backend == "host" and self.config.exact_verification

    def _estimate_verifier(self) -> BatchVerifier:
        """Plain signature-estimate view for cross-step host edges.

        For ``stage2="device"`` sessions the main verifier counts
        registry pass-throughs vs host re-scores; host-generated
        cross-step edges must not inflate ``n_rescored`` (the
        overflow-only pin), so they verify through a shared plain
        estimator over the same retained matrix — bit-identical scores,
        same accumulator cache.
        """
        if not isinstance(self._verifier, DeviceScoredEdgeVerifier):
            return self._verifier
        if not hasattr(self, "_est_verifier"):
            self._est_verifier = SignatureVerifier(
                self._verifier.signatures,
                backend=self.config.resolved_backend())
        # Re-adopt buffer + slot layout every use: chunk extensions
        # regrow the matrix and retention sweeps rewrite rows in place.
        self._est_verifier.adopt_layout(self._verifier)
        return self._est_verifier

    def _feed_cross_step(self, bands: np.ndarray, base: int) -> None:
        """Cross-step candidates: chunk bands vs the retained index."""
        edges = self.band_index.match_then_insert(bands, base)
        if len(edges):
            self.acc.feed(
                ShardedEdgeSource(edges, num_docs=self.n_docs),
                verifier=self._estimate_verifier())


class _NullVerifier(BatchVerifier):
    """Placeholder until the first chunk builds the real verifier (the
    accumulator is constructed before any signatures exist)."""

    def _verify_batch(self, pairs: np.ndarray) -> np.ndarray:
        raise RuntimeError("session verifier not initialised — "
                           "ingest a chunk first")


class _HostBackend:
    """In-memory per-chunk band matrix (the ``DedupPipeline`` shape)."""

    def __init__(self, sess: DedupSession):
        self.sess = sess
        from repro.core.pipeline import DedupPipeline

        self.pipe = DedupPipeline(sess.config)
        self.pipe.seeds = sess.seeds

    def dispatch(self, chunk, tokenized: bool = False):
        sess = self.sess
        if sess.config.byte_ingest:
            # Zero-copy path: raw UTF-8 bytes go to device untokenized.
            # Pre-tokenized chunks re-join with spaces — tokens are
            # alnum-only, so the byte tokenizer recovers them exactly.
            docs = ([" ".join(t) for t in chunk] if tokenized
                    else list(chunk))
            base = sess.allocator.allocate(len(docs))
            if not docs:
                return (base, docs, None, None)
            pad = shingle.pow2_bucket(
                max(len(d.encode("utf-8")) for d in docs) + 1)
            sig, bands = self.pipe.compute_arrays_bytes(docs, pad_len=pad)
            return (base, docs, sig, bands)
        toks = chunk if tokenized else self.pipe.tokenize(chunk)
        base = sess.allocator.allocate(len(toks))
        if not toks:
            return (base, toks, None, None)
        # Fused-ingest configs compute both arrays in one Pallas pass.
        # The token dim buckets to a power of two so repeated chunked
        # ingests reuse a bounded jit-compile set instead of paying one
        # recompile per novel max-document-length (the PR 7 serving
        # bug, on the write path); signatures are padding-invariant.
        pad = shingle.pow2_bucket(max((len(t) for t in toks), default=1))
        sig, bands = self.pipe.compute_arrays(toks, pad_len=pad)
        return (base, toks, sig, bands)

    def merge(self, pending, index: bool = True):
        base, toks, sig, bands = pending
        if sig is None:
            return
        sess = self.sess
        sess._retain(toks, sig)
        sess.n_merged = base + len(toks)
        sess.acc.grow(sess.n_docs)
        sess.acc.feed(BandMatrixSource(bands, doc_id_base=base),
                      verifier=sess._verifier)
        if index:
            sess._feed_cross_step(bands, base)
        sess.steps_ingested += 1


class _StreamingBackend:
    """Design-2 band store phase 1 + band-major re-scan phase 2.

    Owns (or adopts) a ``streaming.StreamingDedup`` for the store
    writes and signature cache; each merge re-scans the store through
    the session accumulator — the verified-sim cache turns the re-scan
    into pure candidate re-enumeration (no re-verification), which is
    the paper's "repeat phase 2" made incremental.  The store is the
    retained state here, so no separate ``BandIndex`` is kept.
    """

    def __init__(self, sess: DedupSession, *, store_path: str,
                 chunk_docs: int, adopt=None):
        self.sess = sess
        self._owned = adopt is None
        if adopt is not None:
            self.sd = adopt
        else:
            from repro.core.streaming import StreamingDedup

            self.sd = StreamingDedup(sess.config, store_path=store_path,
                                     chunk_docs=chunk_docs,
                                     doc_id_base=sess.allocator.base)
            self.sd.seeds = sess.seeds

    def dispatch(self, chunk, tokenized: bool = False):
        # The store write is host-side work with nothing to overlap, so
        # it happens at merge time — a lookahead dispatch must not leak
        # chunk t+1's rows into the band-major scan that merges chunk t.
        if self.sess.config.byte_ingest:
            # Byte configs buffer raw texts; StreamingDedup._flush
            # routes them through the bytes_to_bands kernel.
            toks = ([" ".join(t) for t in chunk] if tokenized
                    else list(chunk))
        else:
            toks = chunk if tokenized else [shingle.tokenize(t)
                                            for t in chunk]
        return (self.sess.allocator.allocate(len(toks)), toks)

    def merge(self, pending):
        base, toks = pending
        sess = self.sess
        assert base == self.sd.n_docs, (base, self.sd.n_docs)
        if toks:
            self.sd.ingest_tokens(toks)
            if hasattr(self.sd.store, "put_signatures"):
                # Disk tier (DedupConfig.store="sqlite"): the flush
                # already wrote the chunk's signature rows into the
                # store — the session verifies straight off disk
                # through the store's LRU-cached row gather, so there
                # is no host matrix to grow and nothing cached to pop.
                if sess._verifier is None and \
                        not sess._external_verifier:
                    sess._verifier = self.sd.default_verifier()
            else:
                sig = np.stack([self.sd._sig_cache[base + i]
                                for i in range(len(toks))])
                sess._retain(toks, sig)
                if self._owned:
                    # The rows now live in the session verifier;
                    # keeping them in the phase-1 cache too would store
                    # every signature twice.  (Adopted StreamingDedups
                    # keep their cache — ``default_verifier`` may
                    # rebuild from it.)
                    for i in range(len(toks)):
                        self.sd._sig_cache.pop(base + i, None)
        sess.n_merged = max(sess.n_merged, base + len(toks))
        sess.acc.grow(sess.n_docs)
        sess.acc.feed(self.sd.candidate_source(),
                      verifier=sess._verifier)
        sess.steps_ingested += 1

    def compact_store(self, doc_ids, root_of):
        """Retention hook: drop evicted docs' band-store rows on
        rewrite (``DedupSession._compact_band_store``)."""
        self.sd.store.compact(doc_ids, root_of)


class _ShardedBackend:
    """One streamed ``dist_lsh`` step invocation per chunk, one
    accumulator across all of them."""

    def __init__(self, sess: DedupSession, *, dist_config, mesh,
                 stream: bool | None):
        from repro.core.dist_lsh import DistLSHConfig, docs_mesh

        self.sess = sess
        cfg = sess.config
        self.dcfg = dist_config or DistLSHConfig(
            ngram=cfg.ngram, num_hashes=cfg.num_hashes,
            rows_per_band=cfg.rows_per_band,
            edge_threshold=cfg.edge_threshold,
            fused_ingest=cfg.fused_ingest,
            byte_ingest=cfg.byte_ingest)
        # The session's retained state (seeds, signature width, band
        # index shape) is derived from DedupConfig while the device
        # step runs the DistLSHConfig — they must describe the same
        # hash space or the first dispatch/merge corrupts the session.
        # ``byte_ingest`` joins the check because it flips the step's
        # INPUT contract (uint8 byte matrix vs uint32 token matrix).
        for f in ("ngram", "num_hashes", "rows_per_band", "byte_ingest"):
            if getattr(cfg, f) != getattr(self.dcfg, f):
                raise ValueError(
                    f"DedupConfig.{f}={getattr(cfg, f)} does not match "
                    f"DistLSHConfig.{f}={getattr(self.dcfg, f)}; the "
                    "session's retained signatures/bands must share the "
                    "sharded step's hash parameters")
        self.mesh = mesh if mesh is not None else docs_mesh()
        self.stream = stream
        self._step = None
        self.n_dev = int(np.prod([self.mesh.shape[a]
                                  for a in self.mesh.axis_names]))

    @property
    def stage2(self) -> str:
        return self.dcfg.stage2

    def _get_step(self):
        if self._step is None:
            from repro.core.dist_lsh import make_streamed_dedup_step

            self._step = make_streamed_dedup_step(self.dcfg, self.mesh)
        return self._step

    def dispatch(self, chunk, tokenized: bool = False):
        sess = self.sess
        if self.dcfg.byte_ingest:
            return self._dispatch_bytes(chunk, tokenized)
        toks = chunk if tokenized else [shingle.tokenize(t)
                                        for t in chunk]
        n_real = len(toks)
        base = sess.allocator.allocate(n_real)
        if n_real == 0:
            return (base, toks, 0, None)
        # Pad for device-count divisibility; pad ids live above the
        # allocated block and are range-filtered at the merge.
        pad = (-n_real) % self.n_dev
        padded = toks + [["pad"]] * pad
        packed = shingle.pack_documents(padded)
        d_loc = len(padded) // self.n_dev
        offsets = DocIdAllocator.device_offsets(base, d_loc, self.n_dev)
        out = self._get_step()(
            jnp.asarray(packed.tokens), jnp.asarray(packed.lengths),
            jnp.asarray(sess.seeds), jnp.asarray(offsets))
        return (base, toks, n_real, out)

    def _dispatch_bytes(self, chunk, tokenized: bool):
        """Byte-ingest dispatch: ship raw UTF-8 bytes, not token ids.

        Same step contract otherwise; the padding doc is the literal
        text ``"pad"`` so its signature matches the token path's
        ``["pad"]`` row bit-for-bit (it is range-filtered regardless).
        """
        sess = self.sess
        docs = ([" ".join(t) for t in chunk] if tokenized
                else list(chunk))
        n_real = len(docs)
        base = sess.allocator.allocate(n_real)
        if n_real == 0:
            return (base, docs, 0, None)
        pad = (-n_real) % self.n_dev
        padded = docs + ["pad"] * pad
        blen = shingle.pow2_bucket(
            max(len(d.encode("utf-8")) for d in padded) + 1)
        packed = shingle.pack_bytes(padded, blen)
        d_loc = len(padded) // self.n_dev
        offsets = DocIdAllocator.device_offsets(base, d_loc, self.n_dev)
        out = self._get_step()(
            jnp.asarray(packed.data), jnp.asarray(packed.lengths),
            jnp.asarray(sess.seeds), jnp.asarray(offsets))
        return (base, docs, n_real, out)

    def merge(self, pending):
        from repro.core.dist_lsh import feed_step_groups

        base, toks, n_real, out = pending
        if out is None:
            return
        sess = self.sess
        sig = np.asarray(out["sig"])[:n_real]
        sess._retain(toks, sig)
        sess.n_merged = base + n_real
        sess.acc.grow(sess.n_docs)
        on_group = None
        if sess.retention is not None:
            # Intra-step eviction between band-group merges: a giant
            # chunk's own rows are shielded (protect_from=base) — the
            # remaining groups and the sig-row-exchange re-score path
            # only ever touch this chunk's rows and retained roots.
            on_group = lambda: sess.retention.sweep(
                sess, protect_from=base)
        feed = feed_step_groups(
            sess.acc, out, self.dcfg, num_docs=base + n_real,
            edge_offset=0, verifier=sess._verifier, stream=self.stream,
            on_group_merged=on_group)
        sess.overflow += feed.overflow
        sess.row_overflow += feed.row_overflow
        bands = np.asarray(lsh.band_values(jnp.asarray(sig),
                                           self.dcfg.rows_per_band))
        if feed.overflow > 0:
            # Device buffers dropped prescreened edges for THIS chunk:
            # re-derive its candidates on the host and accumulate them
            # through the same engine (cross-step edges are host-side
            # and unbounded, so only the within-chunk family can lose).
            sess.retried += 1
            sess.acc.feed(BandMatrixSource(bands, doc_id_base=base),
                          verifier=sess._estimate_verifier())
        sess._feed_cross_step(bands, base)
        sess.steps_ingested += 1
