"""Bounded retained state for long-lived dedup sessions (DESIGN.md §7).

``core.session.DedupSession`` (PR 4) retains three things forever: the
signature matrix (one row per doc), the exact-verifier token store, and
the ``BandIndex`` bucket lists — so memory grows O(docs) over unbounded
ingest.  This module is the policy layer that caps all three at
O(clusters + recency window):

* **Row eviction is lossless.**  The staged engine path-compresses every
  candidate to its union-find root before verification, so the only
  signature/token rows a future chunk can ever read are the rows of
  *current roots* (cluster representatives — SEDD, arXiv 2501.01046,
  makes the same observation for accelerator-side verification).  A doc
  that loses roothood (``ThresholdUnionFind.track_deposed``) can have
  its row released once it ages out of a small LRU window; the window
  exists so the sharded backend's in-flight step and very recent merges
  never race an eviction.

* **Band-index compaction is the only lossy mechanism.**  Bucket lists
  are first rewritten onto retained docs (an evicted member is replaced
  by its cluster root, so membership hits still produce candidate pairs
  against retained docs); the *number of keys* is what grows O(docs·b),
  and once a band exceeds ``band_key_budget`` its oldest keys are
  compacted into a per-band Bloom-style filter (LSHBloom,
  arXiv 2411.04257).  A later chunk hitting a compacted key learns that
  the value was seen but not by whom — counted as ``filter_only_hits``,
  the recall cost of the compaction.  Duplicates that recur within the
  retention window always hit exact keys, so clustering is identical to
  the unbounded session there (the CI soak pins this).

``RetentionPolicy`` is the configuration; ``RetentionManager`` drives
the sweep (drain deposed roots -> release verifier rows -> rewrite /
compact the band index) and keeps the incremental root set the session's
``refine()`` second clustering round re-bands.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

# Distinct 32-bit odd mixing constants (murmur3 / splitmix tails).
_MIX1 = 0x9E3779B1
_MIX2 = 0x85EBCA77
_MIX3 = 0xC2B2AE3D
_U32 = 0xFFFFFFFF


def _mix32(hi: int, lo: int, salt: int) -> int:
    """Host-side 32-bit avalanche of a (hi, lo) band key + salt."""
    x = (hi * _MIX1 + lo * _MIX2 + salt * _MIX3 + 0x27D4EB2F) & _U32
    x ^= x >> 16
    x = (x * 0x7FEB352D) & _U32
    x ^= x >> 15
    x = (x * 0x846CA68B) & _U32
    x ^= x >> 16
    return x


class BandBloomFilter:
    """Compact membership filter for compacted (hi, lo) band keys.

    One per band; holds the keys whose exact bucket lists were dropped.
    No false negatives (a compacted key always hits), false positives at
    the classic Bloom rate — a false positive only inflates the
    ``filter_only_hits`` counter, it can never create a wrong edge.
    """

    def __init__(self, bits: int = 1 << 17, num_hashes: int = 4):
        if bits <= 0 or bits & (bits - 1):
            raise ValueError(f"bits must be a power of two, got {bits}")
        self.bits = int(bits)
        self.num_hashes = int(num_hashes)
        self._words = np.zeros(self.bits // 32, dtype=np.uint32)
        self.n_added = 0

    def _indices(self, hi: int, lo: int):
        mask = self.bits - 1
        for salt in range(self.num_hashes):
            yield _mix32(hi, lo, salt) & mask

    def add(self, key: tuple[int, int]) -> None:
        hi, lo = int(key[0]), int(key[1])
        for i in self._indices(hi, lo):
            self._words[i >> 5] |= np.uint32(1 << (i & 31))
        self.n_added += 1

    def __contains__(self, key: tuple[int, int]) -> bool:
        hi, lo = int(key[0]), int(key[1])
        return all(
            self._words[i >> 5] & np.uint32(1 << (i & 31))
            for i in self._indices(hi, lo))

    @property
    def memory_bytes(self) -> int:
        return self._words.nbytes

    def copy(self) -> "BandBloomFilter":
        """Independent copy (read-path views freeze the filter state so
        a concurrent ingest's ``add`` can never flip a bit mid-probe)."""
        out = BandBloomFilter(self.bits, self.num_hashes)
        out._words = self._words.copy()
        out.n_added = self.n_added
        return out


@dataclass(frozen=True)
class RetentionPolicy:
    """Bounded-memory configuration for a ``DedupSession``.

    ``lru_window``    — most recent docs are never evicted even when
                        non-root (protects in-flight sharded steps and
                        gives recurring duplicates an exact match
                        window).  ``None`` disables row eviction
                        entirely (append-only retention) while keeping
                        the incremental root tracking — the cheap way
                        to get the auto-``refine`` cadence without
                        opting into eviction.
    ``band_key_budget`` — max exact (band-value -> docs) keys retained
                        per band; beyond it the oldest keys compact into
                        the band's Bloom filter.  ``None`` = unlimited
                        (row eviction stays on and stays lossless).
    ``bloom_bits`` / ``bloom_hashes`` — per-band filter geometry.
    ``refine_every``  — auto-run ``DedupSession.refine()`` (the
                        incremental second clustering round) every K
                        ingest steps; 0 disables the auto-trigger
                        (explicit ``refine()`` calls always work).
    """

    lru_window: int | None = 512
    band_key_budget: int | None = None
    bloom_bits: int = 1 << 17
    bloom_hashes: int = 4
    refine_every: int = 0

    PRESETS = ("small", "medium", "unlimited", "none")

    @classmethod
    def preset(cls, name: str, *, refine_every: int = 0) -> "RetentionPolicy":
        """Named budgets for drivers/CI (``--retain-budget``)."""
        if name == "small":
            return cls(lru_window=128, band_key_budget=2048,
                       bloom_bits=1 << 16, refine_every=refine_every)
        if name == "medium":
            return cls(lru_window=1024, band_key_budget=1 << 16,
                       refine_every=refine_every)
        if name == "unlimited":
            return cls(lru_window=512, band_key_budget=None,
                       refine_every=refine_every)
        if name == "none":
            # Append-only rows + unlimited keys: retention machinery
            # only maintains the root set (for the refine cadence).
            return cls(lru_window=None, band_key_budget=None,
                       refine_every=refine_every)
        raise ValueError(f"unknown retention preset {name!r}; "
                         f"one of {cls.PRESETS}")


class RetentionManager:
    """Drives eviction sweeps for one ``DedupSession``.

    Tracks the incremental root set (fed by
    ``ThresholdUnionFind.drain_deposed``) plus the deposed-but-still-
    protected backlog, and on each sweep releases verifier rows and
    rewrites band-index buckets for every doc that is (a) no longer a
    root and (b) older than the LRU window / explicit protection bound.
    """

    def __init__(self, policy: RetentionPolicy):
        self.policy = policy
        self.roots: set[int] = set()
        self._pending: list[int] = []
        self._seen = None  # first sweep learns the session's base
        self.n_evicted = 0

    @property
    def n_pending(self) -> int:
        return len(self._pending)

    def representatives(self) -> list[int]:
        """Sorted current roots (every one has a retained row)."""
        return sorted(self.roots)

    def sweep(self, session, protect_from: int | None = None) -> int:
        """One eviction pass; returns #docs evicted.

        ``protect_from`` additionally shields ids >= that bound (the
        sharded backend passes its in-flight chunk base so mid-step
        group merges can evict old state but never the step's own rows).
        """
        uf = session.uf
        if self._seen is None:
            self._seen = int(session.allocator.base)
        n_merged = int(session.n_merged)
        if n_merged > self._seen:
            self.roots.update(range(self._seen, n_merged))
            self._seen = n_merged
        drained = uf.drain_deposed()
        if drained:
            self.roots.difference_update(drained)
            if self.policy.lru_window is not None:
                self._pending.extend(drained)
        if self.policy.lru_window is None:
            return 0                 # append-only rows, roots tracked
        cutoff = n_merged - self.policy.lru_window
        if protect_from is not None:
            cutoff = min(cutoff, int(protect_from))
        evict = [d for d in self._pending if d < cutoff]
        if not evict:
            return 0
        self._pending = [d for d in self._pending if d >= cutoff]
        session._release_rows(evict)
        session.band_index.evict(evict, uf.find)
        # Streaming sessions also rewrite the evicted docs' band-STORE
        # rows onto their roots (no-op for the other backends) — the
        # phase-1 store stops growing with evicted history.
        session._compact_band_store(evict, uf.find)
        self.n_evicted += len(evict)
        return len(evict)
