"""Online dedup read path: probe + verify over a ``SessionView``.

The batch pipeline answers "which notes in the corpus are duplicates";
the north-star workload also needs the online form — given ONE incoming
note, is it a (near-)duplicate of anything already ingested, and of
which cluster?  This module is that read path (DESIGN.md §9), built
entirely over the immutable ``core.session.SessionView``:

    query texts -> fused ingest (signatures + band values, the SAME
    ``DedupPipeline.compute_arrays`` stage the write path runs)
    -> band probe against the view's frozen bucket maps (LSHBloom-style:
    a compacted key still answers "seen before" via the Bloom filter)
    -> batched verify of (retained doc, query) candidate pairs
    -> threshold at the engine's edge threshold.

Estimator parity is load-bearing: the verify step reuses the engine's
exact estimators bit-for-bit (``(a == b).mean`` in float32 for
signature sessions — host numpy, or the fused
``kernels.sigjaccard.indexed_pair_estimate`` gather kernel on device —
and the merge-count exact Jaccard for exact sessions), so querying an
already-ingested document reproduces the session's recorded pair sims
exactly.  Queries NEVER mutate session state: probes run over the
view's frozen copies, and exact-mode interning only ``get``s from the
shared append-only vocab.

``serving.dedup_service.DedupQueryService`` wraps this over a warm
session and adds the microbatching loop.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core import sanitize
from repro.core.hashing import GOLDEN32, U32_MAX, fmix32_np
from repro.core.session import SessionView
from repro.core.shingle import pow2_bucket

# Query batches at least this large probe on device (sorted-band-key
# searchsorted) instead of walking the host band dicts; smaller batches
# stay on the host, where the dict walk wins on latency.
PROBE_DEVICE_MIN_BATCH = 32


@dataclass(frozen=True)
class QueryResult:
    """Verdict for one query document against a ``SessionView``.

    ``is_duplicate`` uses the engine's edge semantics
    (``sim > edge_threshold``); ``cluster_root`` / ``matched_doc`` are
    ``None`` for novel documents.  ``candidates`` keeps every verified
    (retained doc, sim) pair, best first, for callers that want the
    full ranking; ``filter_only_hits`` counts band keys that hit a
    compacted Bloom filter — "seen before, but by a doc the index can
    no longer name" (the LSHBloom recall trade, DESIGN.md §7).
    """

    is_duplicate: bool
    cluster_root: int | None
    best_sim: float
    matched_doc: int | None
    n_candidates: int = 0
    filter_only_hits: int = 0
    candidates: tuple = ()

    @property
    def novel(self) -> bool:
        return not self.is_duplicate


def _band_key32(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    """Mix a band's (hi, lo) 2-lane value into one 32-bit probe key.

    x64 is disabled on the accelerator, so the device index stores one
    mixed uint32 per (hi, lo) pair instead of the 64-bit concatenation.
    A collision only ever costs a confirming host ``dict.get`` (the
    probe is one-sided: every true key is found).
    """
    with np.errstate(over="ignore"):
        x = (fmix32_np(hi.astype(np.uint32)) ^ lo.astype(np.uint32))
        return fmix32_np((x * GOLDEN32).astype(np.uint32))


_PROBE_JIT = None


def _get_probe_jit():
    global _PROBE_JIT
    if _PROBE_JIT is None:
        import jax
        import jax.numpy as jnp

        @jax.jit
        def probe(keys, counts, qkeys):
            # keys (b, K) sorted uint32 (U32_MAX padded); counts (b,)
            # int32 real sizes; qkeys (b, Q) uint32.
            idx = jax.vmap(jnp.searchsorted)(keys, qkeys)
            idx_c = jnp.minimum(idx, keys.shape[1] - 1)
            found = jnp.take_along_axis(keys, idx_c, axis=1) == qkeys
            return found & (idx < counts[:, None])

        _PROBE_JIT = probe
    return _PROBE_JIT


def _device_probe_index(view: SessionView):
    """Lazily build (and cache on the view) the device band-key index.

    Per band: the sorted unique mixed keys of every dict entry, padded
    with ``U32_MAX`` to one shared pow2 width.  The view is immutable,
    so the index is valid for its whole lifetime.  Returns ``None``
    when the view has no band entries (nothing to probe on device).
    """
    cached = view._probe_cache.get("band_keys")
    if cached is not None:
        return cached
    import jax.numpy as jnp

    per_band = []
    n_max = 0
    for m in view.band_maps:
        if m:
            ks = np.array(list(m.keys()), dtype=np.uint32)  # (n, 2)
            uniq = np.unique(_band_key32(ks[:, 0], ks[:, 1]))
        else:
            uniq = np.zeros((0,), dtype=np.uint32)
        per_band.append(uniq)
        n_max = max(n_max, len(uniq))
    if n_max == 0:
        return None
    k_bucket = pow2_bucket(n_max, floor=128)
    keys = np.full((len(per_band), k_bucket), U32_MAX, dtype=np.uint32)
    counts = np.zeros((len(per_band),), dtype=np.int32)
    for j, uniq in enumerate(per_band):
        keys[j, : len(uniq)] = uniq
        counts[j] = len(uniq)
    index = (jnp.asarray(keys), jnp.asarray(counts))
    view._probe_cache["band_keys"] = index
    return index


def _probe_device(view: SessionView, bands: np.ndarray,
                  index) -> tuple[list[np.ndarray], list[int]]:
    """Device-resident band probe, dict-walk parity by construction.

    The searchsorted membership test has no false negatives (every true
    key's mix is in the sorted index), so a device miss IS a dict miss;
    device hits are confirmed against the host dict, so 32-bit mix
    collisions cannot add candidates.  Bloom fall-through for misses
    matches the walk exactly.
    """
    import jax.numpy as jnp

    keys_dev, counts_dev = index
    q = len(bands)
    qkeys = _band_key32(bands[:, :, 0], bands[:, :, 1])  # (Q, b)
    # Bucket the query dim so repeated batch sizes share jit compiles.
    q_bucket = pow2_bucket(q, floor=PROBE_DEVICE_MIN_BATCH)
    qk = np.zeros((q_bucket, qkeys.shape[1]), dtype=np.uint32)
    qk[:q] = qkeys
    hits = np.asarray(_get_probe_jit()(
        keys_dev, counts_dev, jnp.asarray(qk.T))).T[:q]  # (Q, b)
    cands: list[set[int]] = [set() for _ in range(q)]
    filter_hits = [0] * q
    for j, m in enumerate(view.band_maps):
        col = bands[:, j, :]
        flt = view.band_filters[j]
        hj = hits[:, j]
        for i in range(q):
            key = (int(col[i, 0]), int(col[i, 1]))
            if hj[i]:
                olds = m.get(key)
                if olds is not None:
                    cands[i].update(olds)
                    continue
            if flt is not None and key in flt:
                filter_hits[i] += 1
    out = [np.array(sorted(s), dtype=np.int64) for s in cands]
    return out, filter_hits


def probe_candidates(
    view: SessionView, bands: np.ndarray, *,
    device_min_batch: int = PROBE_DEVICE_MIN_BATCH,
) -> tuple[list[np.ndarray], list[int]]:
    """Band-probe query band values against a view's frozen maps.

    ``bands`` is the (Q, b, 2) query band matrix (same layout the write
    path inserts).  Returns per-query sorted unique candidate doc-id
    arrays plus per-query compacted-key (Bloom-only) hit counts.  Pure
    read: unlike ``BandIndex.match_then_insert`` nothing is inserted
    and no LRU recency moves — which is exactly why it runs over the
    view's exported copies rather than the live index.

    Batches of ``device_min_batch`` or more route through a
    device-resident sorted-band-key ``searchsorted`` probe (the index
    is built once per view and cached); results are identical to the
    host dict walk — device hits are dict-confirmed, and the probe has
    no false negatives (see ``_probe_device``).
    """
    bands = np.asarray(bands)
    if bands.ndim != 3 or bands.shape[1] != view.num_bands:
        raise ValueError(
            f"expected (Q, {view.num_bands}, 2) bands, got {bands.shape}")
    q = len(bands)
    if view.band_store is not None:
        # Disk-tier view (DESIGN.md §12): delegate to the store's pure
        # Bloom-first probe — a primary-filter miss never touches disk,
        # a hit pays one batched SELECT.  Candidates are clipped to the
        # view's publication coverage so docs ingested after this view
        # was published stay invisible to it.
        cands, filter_hits = view.band_store.probe_keys(bands)
        return [c[c < view.n_docs] for c in cands], filter_hits
    if q >= device_min_batch:
        index = _device_probe_index(view)
        if index is not None:
            return _probe_device(view, bands, index)
    cands: list[set[int]] = [set() for _ in range(q)]
    filter_hits = [0] * q
    for j, m in enumerate(view.band_maps):
        col = bands[:, j, :]
        flt = view.band_filters[j]
        for i in range(q):
            key = (int(col[i, 0]), int(col[i, 1]))
            olds = m.get(key)
            if olds is not None:
                cands[i].update(olds)
            elif flt is not None and key in flt:
                filter_hits[i] += 1
    out = [np.array(sorted(s), dtype=np.int64) for s in cands]
    return out, filter_hits


class ViewVerifier:
    """Batched (retained doc, query) estimator over one view.

    The signature-session analogue of ``verify.SignatureVerifier``,
    specialised to mixed operands: one side gathers from the view's
    frozen retained rows, the other from the query batch.  Backends
    match the write path — ``numpy`` host estimate, or ``jnp`` /
    ``pallas`` via the fused gather kernel over a device-resident
    ``[retained rows; query rows]`` stack (the view's rows upload ONCE
    per verifier and are reused across every microbatch; only the
    small query block re-uploads).  All backends produce bit-identical
    float32 sims (pinned by the engine's backend-parity tests), so the
    query pin — sims bit-equal to the session's recorded pairs — holds
    on any backend.
    """

    batch_pairs = 8192

    def __init__(self, view: SessionView, backend: str = "numpy"):
        if backend not in ("numpy", "jnp", "pallas"):
            raise ValueError(f"unknown backend {backend!r}")
        if view.mode != "estimate":
            raise ValueError("ViewVerifier needs an estimate-mode view; "
                             "use ExactViewVerifier for exact sessions")
        self.view = view
        self.backend = backend
        self._dev_sig = None           # retained rows, uploaded once
        self.n_pairs = 0
        self.n_batches = 0

    def _device_retained(self):
        import jax.numpy as jnp

        if self._dev_sig is None:
            self._dev_sig = jnp.asarray(self.view.signatures)
        return self._dev_sig

    def sims(self, q_sigs: np.ndarray, cand_ids: np.ndarray,
             q_idx: np.ndarray) -> np.ndarray:
        """sims[p] = estimate(retained row of cand_ids[p], q_sigs[q_idx[p]])."""
        cand_ids = np.asarray(cand_ids, dtype=np.int64)
        q_idx = np.asarray(q_idx, dtype=np.int64)
        if cand_ids.size == 0:
            return np.zeros((0,), dtype=np.float32)
        out = np.empty(len(cand_ids), dtype=np.float32)
        for s in range(0, len(cand_ids), self.batch_pairs):
            c = cand_ids[s : s + self.batch_pairs]
            qi = q_idx[s : s + self.batch_pairs]
            out[s : s + len(c)] = self._sims_batch(q_sigs, c, qi)
            self.n_batches += 1
        self.n_pairs += len(cand_ids)
        return out

    def _sims_batch(self, q_sigs, cand_ids, q_idx) -> np.ndarray:
        view = self.view
        if self.backend == "numpy":
            a = view.rows_for(cand_ids)
            b = np.asarray(q_sigs)[q_idx]
            return (a == b).mean(axis=-1, dtype=np.float32)
        import jax.numpy as jnp

        retained = self._device_retained()
        n_ret = retained.shape[0]
        stack = jnp.concatenate([retained, jnp.asarray(q_sigs)], axis=0)
        a_np = view.slot_index(cand_ids)
        b_np = n_ret + q_idx
        # Same power-of-two index bucketing as SignatureVerifier: a
        # stable, bounded set of jit shapes across microbatch sizes.
        p = len(cand_ids)
        bucket = 256
        while bucket < p:
            bucket *= 2
        a_dev = jnp.asarray(np.pad(a_np, (0, bucket - p)))
        b_dev = jnp.asarray(np.pad(b_np, (0, bucket - p)))
        if self.backend == "jnp":
            from repro.core.verify import _gather_estimate_jit

            est = _gather_estimate_jit(stack, a_dev, b_dev)
        else:
            from repro.kernels import ops as kops

            est = kops.indexed_pair_estimate(stack, a_dev, b_dev)
        return np.asarray(est)[:p]


class ExactViewVerifier:
    """Exact-Jaccard query verifier over a view's frozen token rows.

    Query n-grams are interned READ-ONLY against the session's shared
    vocab (``dict.get`` only — the write path's ``setdefault`` is what
    assigns new ids, and queries must not mutate session state).  A
    query n-gram the vocab has never seen cannot intersect any stored
    row, so it contributes to the union count only; intersections are
    exact merge-counts against the stored sorted id rows, and the final
    ``inter / union`` is computed with the same float64-divide +
    float32-cast as ``verify.ExactJaccardVerifier`` for bit parity.
    """

    def __init__(self, view: SessionView):
        if view.exact is None:
            raise ValueError("view has no exact token rows; "
                             "use ViewVerifier for estimate sessions")
        self.view = view
        self.n_pairs = 0
        self.n_batches = 0

    def intern_queries(
        self, token_lists: list[list[str]]
    ) -> tuple[list[np.ndarray], np.ndarray]:
        """Per-query (known-id row, total n-gram count incl. unknown)."""
        from repro.core.shingle import ngram_set

        ex = self.view.exact
        vocab = ex.vocab
        rows, totals = [], []
        for toks in token_lists:
            grams = ngram_set(toks, ex.ngram)
            ids = [vocab.get(g) for g in grams]
            known = np.sort(np.array(
                [i for i in ids if i is not None], dtype=np.int64))
            rows.append(known)
            totals.append(len(grams))
        return rows, np.asarray(totals, dtype=np.int64)

    def sims(self, q_rows: list[np.ndarray], q_totals: np.ndarray,
             cand_ids: np.ndarray, q_idx: np.ndarray) -> np.ndarray:
        ex = self.view.exact
        cand_ids = np.asarray(cand_ids, dtype=np.int64)
        q_idx = np.asarray(q_idx, dtype=np.int64)
        if cand_ids.size == 0:
            return np.zeros((0,), dtype=np.float32)
        inter = np.empty(len(cand_ids), dtype=np.int64)
        la = np.empty(len(cand_ids), dtype=np.int64)
        for p, (doc, qi) in enumerate(zip(cand_ids, q_idx)):
            stored = ex.row_for(int(doc))
            la[p] = len(stored)
            inter[p] = np.intersect1d(
                stored, q_rows[int(qi)], assume_unique=True).size
        union = la + q_totals[q_idx] - inter
        self.n_pairs += len(cand_ids)
        self.n_batches += 1
        # Two empty sets have Jaccard 1.0 (matches ExactJaccardVerifier).
        return np.where(
            union > 0, inter / np.maximum(union, 1), 1.0
        ).astype(np.float32)


def _flatten(cands: list[np.ndarray]) -> tuple[np.ndarray, np.ndarray]:
    """Per-query candidate lists -> flat (cand_ids, q_idx) pair arrays."""
    if not any(len(c) for c in cands):
        e = np.zeros((0,), dtype=np.int64)
        return e, e
    cand_ids = np.concatenate([c for c in cands if len(c)])
    q_idx = np.concatenate([np.full(len(c), i, dtype=np.int64)
                            for i, c in enumerate(cands) if len(c)])
    return cand_ids, q_idx


def query_view(
    view: SessionView,
    bands: np.ndarray,
    *,
    sig: np.ndarray | None = None,
    token_lists: list[list[str]] | None = None,
    backend: str = "numpy",
    verifier=None,
) -> list[QueryResult]:
    """Probe + verify one query batch against a view.

    ``bands`` (Q, b, 2) drives the probe; verification needs ``sig``
    (Q, M) for estimate-mode views or ``token_lists`` for exact-mode
    views (both come out of the same write-path stages —
    ``DedupPipeline.compute_arrays`` / ``tokenize``).  Pass a cached
    ``ViewVerifier`` / ``ExactViewVerifier`` via ``verifier`` to reuse
    its device-resident retained rows across calls (the service does).

    With ``REPRO_SANITIZE=1`` the view's arrays are fingerprinted and
    re-checked on entry and exit (``sanitize.SessionViewMutated`` on
    drift) — the dynamic half of the RPR002 purity contract.
    """
    sanitize.check_view(view, "query entry")
    cands, filter_hits = probe_candidates(view, bands)
    cand_ids, q_idx = _flatten(cands)
    if view.mode == "estimate":
        if sig is None:
            raise ValueError("estimate-mode query needs sig (Q, M)")
        v = verifier if verifier is not None else ViewVerifier(
            view, backend=backend)
        sims = v.sims(sig, cand_ids, q_idx)
    else:
        if token_lists is None:
            raise ValueError("exact-mode query needs token_lists")
        v = verifier if verifier is not None else ExactViewVerifier(view)
        q_rows, q_totals = v.intern_queries(token_lists)
        sims = v.sims(q_rows, q_totals, cand_ids, q_idx)

    out: list[QueryResult] = []
    start = 0
    for i, c in enumerate(cands):
        s = sims[start : start + len(c)]
        start += len(c)
        if len(c) == 0:
            out.append(QueryResult(
                is_duplicate=False, cluster_root=None, best_sim=0.0,
                matched_doc=None, n_candidates=0,
                filter_only_hits=filter_hits[i]))
            continue
        order = np.lexsort((c, -s.astype(np.float64)))
        ranked = tuple((int(c[k]), float(s[k])) for k in order)
        best_doc, best_sim = ranked[0]
        # Engine edge semantics: an edge merges iff sim > threshold
        # (float32 sim against the raw config float, same promotion as
        # ClusterAccumulator's flush).
        dup = bool(s[order[0]] > view.edge_threshold)
        out.append(QueryResult(
            is_duplicate=dup,
            cluster_root=view.root_of(best_doc) if dup else None,
            best_sim=best_sim,
            matched_doc=best_doc if dup else None,
            n_candidates=len(c),
            filter_only_hits=filter_hits[i],
            candidates=ranked))
    sanitize.check_view(view, "query exit")
    return out
