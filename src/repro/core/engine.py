"""The staged dedup engine: CandidateSource -> BatchVerifier -> UnionFind.

This is the single implementation of the paper's §6.5
``find_candidate_pairs`` procedure that all three execution paths drive:

* host in-memory      — ``pipeline.DedupPipeline`` (``BandMatrixSource``)
* out-of-core / streaming — ``streaming.StreamingDedup``
  (``StoreBandSource`` over a Design-1/2 band store)
* sharded (shard_map) — ``dist_lsh`` prescreens edges on-device with a
  signature-prefix compare inside the all_to_all, then its host-side
  merge drives this engine over a ``ShardedEdgeSource`` with a
  full-signature ``ShardedEdgeVerifier`` (``dist_lsh.cluster_step_output``),
  so thresholds and verify semantics match the other paths exactly.

For each band the engine walks equal-value runs, path-compresses run
members to their current union-find roots, and collects not-yet-evaluated
root pairs into a batch buffer that is flushed through the verifier in
device-sized dispatches — the scalar ``similarity_fn(a, b)`` inner loop
of the previous three copies is gone.

``batch`` granularity:

* ``"run"``  (default) — flush at every run boundary.  Bit-identical to
  the historical scalar loop: unions from one run are visible to the
  next run's root compression, so the exclusion statistics (paper
  Table 5) and the union-find lower-bound guarantee are unchanged.
* ``"band"`` — flush at band boundaries (or when the buffer reaches
  ``max_batch_pairs``).  Larger dispatches, maximum throughput; pairs
  that a same-band union would have excluded may be evaluated, and a
  union's ``sim`` is the one measured against collection-time roots, so
  the tree-threshold guarantee becomes approximate (audit with
  ``unionfind.cluster_min_score_audit`` if it matters).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.candidates import CandidateSource
from repro.core.unionfind import ThresholdUnionFind
from repro.core.verify import as_verifier


@dataclass
class ClusterStats:
    """Engine counters (superset of the paper's Table 5 accounting)."""

    pairs_generated: int = 0
    pairs_evaluated: int = 0
    pairs_excluded: int = 0  # skipped Jaccard computations (paper Table 5)
    pairs_above_edge: int = 0
    unions_done: int = 0
    unions_rejected: int = 0
    verify_batches: int = 0
    verify_seconds: float = 0.0

    @property
    def verify_pairs_per_second(self) -> float:
        if self.verify_seconds <= 0:
            return 0.0
        return self.pairs_evaluated / self.verify_seconds

    def add(self, other: "ClusterStats") -> "ClusterStats":
        """Accumulate another pass's counters (multi-source clustering)."""
        for f in (
            "pairs_generated", "pairs_evaluated", "pairs_excluded",
            "pairs_above_edge", "unions_done", "unions_rejected",
            "verify_batches", "verify_seconds",
        ):
            setattr(self, f, getattr(self, f) + getattr(other, f))
        return self


class ClusterAccumulator:
    """Incremental multi-source clustering: one union-find, shared caches.

    ``feed`` drives one candidate source through batched verification
    into the accumulator's union-find; feeding several sources in
    sequence is the engine-level mechanism behind the sharded path's
    *streamed* host merge — ``dist_lsh`` emits one edge buffer per
    band-group and ``cluster_step_output`` feeds each group as it
    arrives off the device, so the merge of group g overlaps the device
    shuffle of group g+1.  The verified-sim cache carries across feeds:
    a pair evaluated while merging group g is counted as *excluded*
    (never re-verified) when group g+1 — or the overflow fallback pass —
    emits it again, exactly like re-occurrences within a single source.

    ``stats`` holds the totals across every feed; each ``feed`` call
    also returns that source's own ``ClusterStats``.

    ``grow`` extends the union-find to cover newly allocated doc ids —
    the incremental-ingest mechanism behind ``core.session.DedupSession``
    (docs arrive chunk by chunk, one accumulator clusters them all) —
    and ``feed(source, verifier=...)`` lets one accumulator mix
    verification strategies per feed (e.g. device-registered scores for
    the sharded step's own edges, the plain host estimator for
    cross-step candidates against retained signatures) while the
    verified-sim cache and union-find stay shared.
    """

    def __init__(
        self,
        num_docs: int,
        verifier,
        edge_threshold: float,
        tree_threshold: float,
        *,
        use_disjoint_sets: bool = True,
        batch: str = "run",
        max_batch_pairs: int = 8192,
        uf: ThresholdUnionFind | None = None,
    ):
        if batch not in ("run", "band"):
            raise ValueError(f"unknown batch granularity {batch!r}")
        self.verifier = as_verifier(verifier)
        if uf is None:
            uf = ThresholdUnionFind(num_docs, tree_threshold)
        else:
            if len(uf.parent) < num_docs:
                raise ValueError(
                    f"existing uf covers {len(uf.parent)} docs, source "
                    f"has {num_docs}")
            if uf.tree_threshold != tree_threshold:
                raise ValueError(
                    f"tree_threshold {tree_threshold} does not match the "
                    f"existing uf's {uf.tree_threshold}; unions are "
                    "guarded by the uf's own threshold")
        self.uf = uf
        self.edge_threshold = float(edge_threshold)
        self.use_disjoint_sets = bool(use_disjoint_sets)
        self.batch = batch
        self.max_batch_pairs = int(max_batch_pairs)
        self.stats = ClusterStats()
        self.evaluated: dict[tuple[int, int], float] = {}

    @property
    def pairs(self) -> list[tuple[int, int, float]]:
        """Every evaluated (a, b, sim), sorted, across all feeds."""
        return [(a, b, s) for (a, b), s in sorted(self.evaluated.items())]

    @property
    def num_docs(self) -> int:
        return len(self.uf.parent)

    def grow(self, num_docs: int) -> None:
        """Extend the union-find to cover ``num_docs`` ids (no-op if it
        already does).  New ids start as singletons."""
        self.uf.grow(num_docs)

    def feed(self, source: CandidateSource,
             verifier=None) -> ClusterStats:
        """Cluster one source into the accumulator; returns its stats.

        ``verifier`` overrides the accumulator's verifier for THIS feed
        only (same shared sim cache / union-find / stats).
        """
        if len(self.uf.parent) < source.num_docs:
            raise ValueError(
                f"accumulator covers {len(self.uf.parent)} docs, source "
                f"has {source.num_docs}")
        uf = self.uf
        verifier = (self.verifier if verifier is None
                    else as_verifier(verifier))
        evaluated = self.evaluated
        # Snapshot the verifier's lifetime counters so stats report THIS
        # feed's batches/seconds even when the verifier instance is
        # reused (e.g. re-clustering at a second threshold).
        batches0, seconds0 = verifier.n_batches, verifier.seconds
        stats = ClusterStats()
        pending: list[tuple[int, int]] = []
        pending_set: set[tuple[int, int]] = set()

        def flush():
            if not pending:
                return
            sims = verifier(np.array(pending, dtype=np.int64))
            for (a, c), sim in zip(pending, sims):
                sim = float(sim)
                evaluated[(a, c)] = sim
                stats.pairs_evaluated += 1
                if sim > self.edge_threshold:
                    stats.pairs_above_edge += 1
                    if self.use_disjoint_sets:
                        before = uf.n_unions
                        uf.union(a, c, sim)
                        if uf.n_unions > before:
                            stats.unions_done += 1
                        else:
                            stats.unions_rejected += 1
            pending.clear()
            pending_set.clear()

        for band_runs in source.iter_bands():
            for members in band_runs.iter_groups():
                m = len(members)
                stats.pairs_generated += m * (m - 1) // 2
                if self.use_disjoint_sets:
                    # "replace D with D.find()" — compress to roots.
                    uniq = np.unique([uf.find(int(d)) for d in members])
                else:
                    uniq = np.sort(members)
                k = len(uniq)
                if k < 2:
                    # All members already co-clustered: all excluded.
                    stats.pairs_excluded += m * (m - 1) // 2
                    continue
                # Pairs collapsed by prior clustering are excluded too.
                stats.pairs_excluded += m * (m - 1) // 2 - k * (k - 1) // 2
                for ii in range(k):
                    for jj in range(ii + 1, k):
                        key = (int(uniq[ii]), int(uniq[jj]))
                        if key in evaluated or key in pending_set:
                            stats.pairs_excluded += 1
                            continue
                        pending.append(key)
                        pending_set.add(key)
                if self.batch == "run" or \
                        len(pending) >= self.max_batch_pairs:
                    flush()
            if self.batch == "band":
                flush()
        flush()

        stats.verify_batches = verifier.n_batches - batches0
        stats.verify_seconds = verifier.seconds - seconds0
        self.stats.add(stats)
        return stats


def cluster_source(
    source: CandidateSource,
    verifier,
    edge_threshold: float,
    tree_threshold: float,
    *,
    use_disjoint_sets: bool = True,
    batch: str = "run",
    max_batch_pairs: int = 8192,
    uf: ThresholdUnionFind | None = None,
) -> tuple[ThresholdUnionFind, ClusterStats, list[tuple[int, int, float]]]:
    """Run the staged engine over a candidate source.

    ``verifier`` is a ``verify.BatchVerifier`` or a scalar
    ``fn(a, b) -> float`` (wrapped via ``verify.as_verifier``).
    Returns (union-find, stats, evaluated_pairs [(a, b, sim), ...]) —
    the same contract the historical ``cluster_bands`` had.

    With ``use_disjoint_sets=False`` every candidate pair is evaluated
    (the paper's non-clustered baseline behind Table 5's "6388 pairs").

    Passing an existing ``uf`` accumulates this source's clustering into
    it instead of starting fresh — the retry path for the sharded step's
    overflow fallback: docs already co-clustered by a previous pass are
    excluded up front, only the remainder is re-verified.  For feeding
    several sources with a shared verified-sim cache (the streamed
    per-band-group merge), use ``ClusterAccumulator`` directly.
    """
    acc = ClusterAccumulator(
        source.num_docs, verifier, edge_threshold, tree_threshold,
        use_disjoint_sets=use_disjoint_sets, batch=batch,
        max_batch_pairs=max_batch_pairs, uf=uf)
    stats = acc.feed(source)
    return acc.uf, stats, acc.pairs


def merge_cluster_rounds(
    uf: ThresholdUnionFind,
    verifier,
    edge_threshold: float,
    *,
    max_batch_pairs: int = 8192,
    roots=None,
    candidate_pairs=None,
    sim_cache: dict | None = None,
) -> int:
    """Paper §10's second clustering round, batch-verified.

    Compares cluster REPRESENTATIVES and merges clusters whose reps are
    highly similar (fixes the over-partitioning the disjoint-set pass can
    produce — Table 7's 56 'diff-set high-similarity' pairs).  The (i, j)
    sweep is processed in blocks of ``max_batch_pairs``: each block's
    still-distinct current-root pairs go through the verifier in one
    dispatch, then the block's merges are applied in sweep order (rare
    pairs whose roots changed mid-block fall back to a singleton
    dispatch).  The verified-sim cache (``sim_at``) is shared across
    blocks: a doc pair's similarity is deterministic, so a root pair
    that re-appears in a later block — mid-sweep unions redirect
    ``find`` onto roots scored earlier — reuses the cached value instead
    of a redundant singleton dispatch.  Semantics match the historical
    O(roots^2) scalar loop — sims are always between *current* roots at
    union time — with O(block) memory for the batch buffer.  Returns
    #merges.

    Incremental-session hooks (``DedupSession.refine``, DESIGN.md §7):

    * ``roots`` — explicit representative candidates (any docs; each is
      compressed to its current root).  Skips the O(all docs) root scan
      — the retention layer already knows the live root set.
    * ``candidate_pairs`` — (E, 2) doc-id pairs to sweep INSTEAD of the
      full (i, j) cross product (e.g. band collisions among re-banded
      representatives); each endpoint is compressed to its current root
      at processing time, so chained merges behave exactly like the
      full sweep restricted to those pairs.
    * ``sim_cache`` — external ``{(a, b): sim}`` dict shared with the
      caller (the accumulator's verified-sim cache): sims the session
      already verified are never re-dispatched, and sims this round
      computes become visible to later feeds.
    """
    verifier = as_verifier(verifier)
    if candidate_pairs is not None:
        cand = np.asarray(candidate_pairs, dtype=np.int64).reshape(-1, 2)
        if len(cand) == 0:
            return 0
        sweep = [(int(a), int(b)) for a, b in cand]
    else:
        if roots is None:
            roots = range(len(uf.parent))
        roots = sorted({uf.find(int(r)) for r in roots})
        if len(roots) < 2:
            return 0
        sweep = None  # generated lazily below (O(R^2) pairs)

    def blocks():
        block = []
        if sweep is not None:
            for a, b in sweep:
                block.append((a, b))
                if len(block) >= max_batch_pairs:
                    yield block
                    block = []
        else:
            for i in range(len(roots)):
                for j in range(i + 1, len(roots)):
                    block.append((roots[i], roots[j]))
                    if len(block) >= max_batch_pairs:
                        yield block
                        block = []
        if block:
            yield block

    merges = 0
    sim_at = sim_cache if sim_cache is not None else {}
    for block in blocks():
        want = []
        want_set = set()
        for x, y in block:
            a, b = uf.find(x), uf.find(y)
            key = (min(a, b), max(a, b))
            if a != b and key not in sim_at and key not in want_set:
                want_set.add(key)
                want.append(key)
        if want:
            for key, s in zip(want, verifier(np.array(want,
                                                      dtype=np.int64))):
                sim_at[key] = float(s)
        for x, y in block:
            a, b = uf.find(x), uf.find(y)
            if a == b:
                continue
            key = (min(a, b), max(a, b))
            sim = sim_at.get(key)
            if sim is None:
                # Roots changed due to a union earlier in this block.
                sim = float(verifier(np.array([key], dtype=np.int64))[0])
                sim_at[key] = sim
            if sim > edge_threshold and uf.union(a, b, sim):
                merges += 1
    return merges
