"""Jaccard similarity: exact (oracle) and signature-estimated (paper §2.1, §3.3)."""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp


def exact_jaccard(a: set, b: set) -> float:
    """Exact set Jaccard |A∩B| / |A∪B| (paper §2.1)."""
    if not a and not b:
        return 1.0
    inter = len(a & b)
    union = len(a) + len(b) - inter
    return inter / union if union else 0.0


def exact_jaccard_docs(tokens_a: list[str], tokens_b: list[str], n: int = 8) -> float:
    from repro.core.shingle import ngram_set

    return exact_jaccard(ngram_set(tokens_a, n), ngram_set(tokens_b, n))


def jaccard_distance(a: set, b: set) -> float:
    """1 - Jaccard; a metric (triangle inequality holds, paper §6.1)."""
    return 1.0 - exact_jaccard(a, b)


@jax.jit
def pairwise_estimate(sig: jnp.ndarray, pairs: jnp.ndarray) -> jnp.ndarray:
    """Signature-agreement estimate for candidate pairs.

    sig: (D, M) uint32; pairs: (P, 2) int32.  Returns (P,) float32.
    """
    a = sig[pairs[:, 0]]
    b = sig[pairs[:, 1]]
    return jnp.mean((a == b).astype(jnp.float32), axis=-1)


def pairwise_estimate_np(sig: np.ndarray, pairs: np.ndarray) -> np.ndarray:
    if len(pairs) == 0:
        return np.zeros((0,), dtype=np.float32)
    a = sig[pairs[:, 0]]
    b = sig[pairs[:, 1]]
    return (a == b).mean(axis=-1).astype(np.float32)


def exact_jaccard_matrix(ngram_sets: list[set]) -> np.ndarray:
    """Dense exact Jaccard matrix — the paper's O(N^2 w) baseline (§7.5.1)."""
    n = len(ngram_sets)
    out = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        out[i, i] = 1.0
        for j in range(i + 1, n):
            s = exact_jaccard(ngram_sets[i], ngram_sets[j])
            out[i, j] = out[j, i] = s
    return out
