"""Pallas TPU kernel: MinHash signature matrix.

sig[d, m] = min over valid n-gram positions l of fmix32(ng[d,l]*G + seed[m])

Tiling (DESIGN.md §2): grid (D/TD, M/TM, L/TL).  The L axis is the
innermost (sequential on TPU) grid dimension so the output block (TD, TM)
is revisited and min-accumulated in VMEM — the (TD, TL, TM) hash cube
never leaves registers/VMEM.  Block sizes keep the cube ≈ 0.5 MiB and the
M tile a multiple of 128 lanes for the VPU.

This kernel is the paper's dominant cost (its production run spent 75 of
99 hours producing signatures, §12).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import GOLDEN32, U32_MAX

# Default tile sizes: (TD, TL, TM) cube = 8*128*128*4B = 512 KiB in VMEM.
TD, TL, TM = 8, 128, 128


def _minhash_kernel(ng_ref, valid_ref, seeds_ref, out_ref):
    l_idx = pl.program_id(2)
    ng = ng_ref[...].astype(jnp.uint32)          # (TD, TL)
    valid = valid_ref[...]                        # (TD, TL) uint32 0/1
    seeds = seeds_ref[...].astype(jnp.uint32)     # (TM,)

    x = ng[:, :, None] * GOLDEN32 + seeds[None, None, :]
    # fmix32 inline (Murmur3 finalizer) — 32-bit ops only.
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    x = jnp.where(valid[:, :, None] != 0, x, jnp.uint32(U32_MAX))
    part = jnp.min(x, axis=1)                     # (TD, TM)

    @pl.when(l_idx == 0)
    def _init():
        out_ref[...] = part

    @pl.when(l_idx > 0)
    def _acc():
        out_ref[...] = jnp.minimum(out_ref[...], part)


@functools.partial(
    jax.jit, static_argnames=("td", "tl", "tm", "interpret")
)
def minhash_signatures(
    ngrams: jnp.ndarray,
    valid: jnp.ndarray,
    seeds: jnp.ndarray,
    *,
    td: int = TD,
    tl: int = TL,
    tm: int = TM,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(D, L) uint32 n-gram hashes + (D, L) validity -> (D, M) signatures."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    D, L = ngrams.shape
    M = seeds.shape[0]
    td = min(td, max(1, D))
    tl = min(tl, max(1, L))
    tm = min(tm, max(1, M))
    Dp, Lp, Mp = -(-D // td) * td, -(-L // tl) * tl, -(-M // tm) * tm
    ng = jnp.pad(ngrams.astype(jnp.uint32), ((0, Dp - D), (0, Lp - L)))
    vd = jnp.pad(valid.astype(jnp.uint32), ((0, Dp - D), (0, Lp - L)))
    sd = jnp.pad(seeds.astype(jnp.uint32), (0, Mp - M))

    out = pl.pallas_call(
        _minhash_kernel,
        grid=(Dp // td, Mp // tm, Lp // tl),
        in_specs=[
            pl.BlockSpec((td, tl), lambda d, m, l: (d, l)),
            pl.BlockSpec((td, tl), lambda d, m, l: (d, l)),
            pl.BlockSpec((tm,), lambda d, m, l: (m,)),
        ],
        out_specs=pl.BlockSpec((td, tm), lambda d, m, l: (d, m)),
        out_shape=jax.ShapeDtypeStruct((Dp, Mp), jnp.uint32),
        interpret=interpret,
    )(ng, vd, sd)
    return out[:D, :M]
