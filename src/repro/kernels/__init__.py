"""Pallas TPU kernels for the dedup hot path (see EXAMPLE.md contract)."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
