"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function computes exactly what the corresponding kernel computes;
tests sweep shapes/dtypes and assert_allclose kernel-vs-ref.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import lsh as _lsh
from repro.core import minhash as _minhash
from repro.core import shingle as _shingle


def ngram_hashes(tokens, lengths, n: int = 8):
    return _shingle.ngram_hashes(tokens, lengths, n=n)


def minhash_signatures(ngrams, valid, seeds):
    return _minhash.signatures(ngrams, valid, seeds)


def band_values(sig, r: int):
    return _lsh.band_values(sig, r)


def pair_estimate(sig_a, sig_b):
    return jnp.mean((sig_a == sig_b).astype(jnp.float32), axis=-1)


def fused_ingest(tokens, lengths, seeds, *, n: int = 8, r: int = 2):
    """Staged-jnp oracle of the fused pass: shingle -> minhash -> fold."""
    ng, valid = _shingle.ngram_hashes(tokens, lengths, n=n)
    sig = _minhash.signatures(ng, valid, seeds)
    return sig, _lsh.band_values(sig, r), valid
