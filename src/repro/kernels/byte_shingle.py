"""Pallas TPU kernel: device-resident byte-level shingling.

Completes the zero-copy ingest path (DESIGN.md §11): raw UTF-8 bytes are
the only host->device transfer, and tokenize + token-hash + shingle +
minhash + band-fold all run on device as one ``bytes_to_bands`` pass.

Tokenization contract (bit-identical to the host no-stem path): a token
is a maximal run of ASCII alphanumerics, A-Z folds to a-z (+32), and
every other byte — including every byte >= 0x80 of a multi-byte UTF-8
sequence — is a separator.  ``core.shingle._WORD_RE`` only matches
ASCII, and an ASCII token's UTF-8 encoding is its own bytes, so the
per-token FNV-1a over folded bytes reproduces
``token_ids(tokenize(text, do_stem=False))`` exactly; multi-byte safety
is structural (no token byte can sit inside a multi-byte sequence).

FNV-1a is sequential per token, so the kernel scans byte columns with a
``jax.lax.scan`` carrying (FNV state, prev-byte-was-alnum) per document
row.  The carries persist across L tiles as revisited rank-1 output
blocks (the ``fused_ingest`` signature-accumulator idiom: the grid's
last axis is sequential on TPU, so the (TD,) carry block stays resident
in VMEM across the L revisits) and are re-initialized at the first L
tile.  Zero padding is a separator, so a token ending at the last byte
of a document emits at the following zero column — callers must keep
matrix width strictly greater than every byte length (``pack_bytes``
enforces this; ``bytes_to_bands`` also pads one extra column).

Grid (D/TD, LB/TLB), L innermost.  VMEM per step is one (TD, TLB) uint8
byte tile + the uint32 token/end tiles + two (TD,) carries — well under
budget; nothing per-token ever reaches HBM except the compacted token
matrix handed to ``fused_ingest``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import FNV_OFFSET32, FNV_PRIME32, GOLDEN32
from repro.kernels.fused_ingest import fused_ingest

# Default seed of core.shingle.token_ids (the hash-vocabulary seed).
TOKEN_SEED = 0x7045

# Default tiles: (TD, TLB) uint8 + uint32 outputs ~ 18 KiB VMEM.
TD, TLB = 8, 256


def _fmix(x):
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _byte_kernel(byte_ref, len_ref, tok_ref, end_ref, h_ref, p_ref, *,
                 td: int, tlb: int, seed: int):
    l_idx = pl.program_id(1)

    @pl.when(l_idx == 0)
    def _init():
        h_ref[...] = jnp.full((td,), jnp.uint32(FNV_OFFSET32),
                              dtype=jnp.uint32)
        p_ref[...] = jnp.zeros((td,), dtype=jnp.uint32)

    cols = byte_ref[...].astype(jnp.uint32).T      # (TLB, TD)
    lens = len_ref[...].astype(jnp.int32)          # (TD,)
    # Positions at or beyond a document's byte length are separators, so
    # garbage padding never leaks into tokens.
    pos = l_idx * tlb + jax.lax.broadcasted_iota(jnp.int32, (td, tlb), 1)
    in_doc = (pos < lens[:, None]).T               # (TLB, TD)

    def step(carry, xs):
        h, prev = carry
        b, live = xs
        upper = (b >= jnp.uint32(65)) & (b <= jnp.uint32(90))
        alnum = (upper
                 | ((b >= jnp.uint32(97)) & (b <= jnp.uint32(122)))
                 | ((b >= jnp.uint32(48)) & (b <= jnp.uint32(57)))) & live
        folded = jnp.where(upper, b + jnp.uint32(32), b)
        # A run restarts from the FNV offset basis at its first byte.
        h0 = jnp.where(prev > jnp.uint32(0), h, jnp.uint32(FNV_OFFSET32))
        h_new = jnp.where(alnum, (h0 ^ folded) * jnp.uint32(FNV_PRIME32), h)
        end = (prev > jnp.uint32(0)) & jnp.logical_not(alnum)
        tok = jnp.where(end, _fmix(h * GOLDEN32 + jnp.uint32(seed)),
                        jnp.uint32(0))
        return (h_new, alnum.astype(jnp.uint32)), (tok, end.astype(jnp.int32))

    (h_fin, p_fin), (toks, ends) = jax.lax.scan(
        step, (h_ref[...], p_ref[...]), (cols, in_doc))
    tok_ref[...] = toks.T
    end_ref[...] = ends.T
    h_ref[...] = h_fin
    p_ref[...] = p_fin


@functools.partial(
    jax.jit, static_argnames=("td", "tlb", "id_seed", "interpret"))
def byte_token_hashes(
    data: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    td: int = TD,
    tlb: int = TLB,
    id_seed: int = TOKEN_SEED,
    interpret: bool | None = None,
):
    """(D, LB) uint8 bytes + (D,) byte lengths ->
    (token ids (D, LB) uint32, token ends (D, LB) int32).

    ``ends[d, i]`` is 1 iff a token ends at byte position i (exclusive)
    and ``tok[d, i]`` is its hashed id.  Matches
    ``core.shingle.byte_token_hashes_np`` bit-for-bit.  The matrix width
    must exceed every byte length (a token touching the last column
    would have nowhere to emit) — ``pack_bytes`` guarantees this.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    data = data.astype(jnp.uint8)
    lengths = lengths.astype(jnp.int32)
    D, LB = data.shape
    if D == 0:
        return (jnp.zeros((0, LB), jnp.uint32),
                jnp.zeros((0, LB), jnp.int32))
    td_ = min(td, max(1, D))
    tlb_ = min(tlb, max(1, LB))
    Dp = -(-D // td_) * td_
    Lp = -(-LB // tlb_) * tlb_
    buf = jnp.pad(data, ((0, Dp - D), (0, Lp - LB)))
    ln = jnp.pad(lengths, (0, Dp - D))

    tok, ends, _, _ = pl.pallas_call(
        functools.partial(_byte_kernel, td=td_, tlb=tlb_, seed=id_seed),
        grid=(Dp // td_, Lp // tlb_),
        in_specs=[
            pl.BlockSpec((td_, tlb_), lambda d, l: (d, l)),
            pl.BlockSpec((td_,), lambda d, l: (d,)),
        ],
        out_specs=[
            pl.BlockSpec((td_, tlb_), lambda d, l: (d, l)),
            pl.BlockSpec((td_, tlb_), lambda d, l: (d, l)),
            # FNV-state / prev-alnum carries: revisited rank-1 blocks,
            # VMEM-resident across the sequential L axis.
            pl.BlockSpec((td_,), lambda d, l: (d,)),
            pl.BlockSpec((td_,), lambda d, l: (d,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Dp, Lp), jnp.uint32),
            jax.ShapeDtypeStruct((Dp, Lp), jnp.int32),
            jax.ShapeDtypeStruct((Dp,), jnp.uint32),
            jax.ShapeDtypeStruct((Dp,), jnp.uint32),
        ],
        interpret=interpret,
    )(buf, ln)
    return tok[:D, :LB], ends[:D, :LB]


@functools.partial(
    jax.jit,
    static_argnames=("n", "r", "td", "tlb", "id_seed", "interpret"))
def bytes_to_bands(
    data: jnp.ndarray,
    lengths: jnp.ndarray,
    seeds: jnp.ndarray,
    *,
    n: int = 8,
    r: int = 2,
    td: int = TD,
    tlb: int = TLB,
    id_seed: int = TOKEN_SEED,
    interpret: bool | None = None,
):
    """(D, LB) uint8 bytes + (D,) byte lengths + (M,) seeds ->
    ((D, M) signatures, (D, M//r, 2) band values, (D,) token counts).

    The full zero-copy ingest: byte shingle kernel -> on-device token
    compaction (cumsum/scatter; dropped positions go out of bounds) ->
    ``fused_ingest``.  Bit-identical to host tokenize(do_stem=False) +
    ``token_ids`` + ``pack_documents`` + ``fused_ingest``.  Callers feed
    pow2-bucketed widths (``pack_bytes`` + ``pow2_bucket``) so the
    compile set stays bounded — RPR003 audits call sites.
    """
    data = data.astype(jnp.uint8)
    lengths = lengths.astype(jnp.int32)
    D, LB = data.shape
    M = seeds.shape[0]
    assert M % r == 0, f"M={M} not divisible by r={r}"
    if D == 0:
        return (jnp.zeros((0, M), jnp.uint32),
                jnp.zeros((0, M // r, 2), jnp.uint32),
                jnp.zeros((0,), jnp.int32))
    # One extra zero column so a token ending at the last byte of a
    # full-width row still emits (zero padding is a separator).
    buf = jnp.pad(data, ((0, 0), (0, 1)))
    tok, ends = byte_token_hashes(
        buf, lengths, td=td, tlb=tlb, id_seed=id_seed, interpret=interpret)

    # Compact sparse per-position emissions into a dense token matrix.
    # Capacity: token ends are >= 2 bytes apart, so ceil((LB+1)/2) is a
    # hard cap; the width is derived from the bucketed LB, keeping the
    # downstream fused_ingest compile set bounded too.
    lt_bucket = (LB + 1) // 2 + 1
    tidx = jnp.cumsum(ends, axis=1) - 1
    dst = jnp.where(ends > 0, tidx, lt_bucket)  # non-ends dropped (OOB)
    row = jnp.arange(D, dtype=jnp.int32)[:, None]
    tokens = jnp.zeros((D, lt_bucket), jnp.uint32)
    tokens = tokens.at[row, dst].set(tok, mode="drop")
    tok_lengths = jnp.sum(ends, axis=1).astype(jnp.int32)

    sig, bands, _ = fused_ingest(
        tokens, tok_lengths, seeds, n=n, r=r, interpret=interpret)
    return sig, bands, tok_lengths
