"""Pallas TPU kernel: fused device-resident ingest.

One pass computes the whole signature-production chain the staged path
runs as three dispatches (``kernels/ngram.py`` -> ``kernels/minhash.py``
-> ``kernels/bandfold.py``):

    packed (tokens, lengths, seeds) -> (signatures, band_values, valid)

Grid (D/TD, M/TM, L/TL) with L innermost (sequential on TPU), exactly
the minhash tiling (DESIGN.md §2/§8):

* The rolling n-gram hash is recomputed per token tile from the tile
  plus its L-halo (the ``kernels/ngram.py`` idiom: two in_specs over the
  same operand with shifted index maps) — the (TD, TL) hash tile lives
  only in VMEM and is never written to HBM.
* The seeded (TD, TL, TM) hash cube is min-accumulated into the output
  signature block, which Pallas keeps resident in VMEM across the L
  revisits (the ``kernels/minhash.py`` accumulation).
* At the LAST L tile the signature block is final, so the 2-lane band
  fold (``kernels/bandfold.py``) runs on it in-register and writes the
  (TD, TM/r, 2) band block — signatures are read back out of VMEM, not
  HBM.  ``tm`` is clamped to a multiple of ``r`` so every band's r rows
  live inside one M tile.

Bit-parity contract: every op is exact uint32 arithmetic (wraparound
multiply / xor / shift), so outputs are bit-identical to the staged
kernels AND to the pure-jnp refs (``core.shingle`` / ``core.minhash`` /
``core.lsh``) — drift = 0 is pinned by tests and the bench gate.

``interpret=None`` auto-selects interpreter mode on CPU so the fused
path runs (and is parity-checked in CI) without a TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import GOLDEN32, NGRAM_BASE, U32_MAX

_LANE_SEEDS = (0x2545F491, 0x9E3779B9)

# Defaults match kernels/minhash.py: (TD, TL, TM) cube = 512 KiB VMEM.
TD, TL, TM = 8, 128, 128


def _fmix(x):
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _fused_kernel(tok_ref, halo_ref, len_ref, seeds_ref, sig_ref,
                  band_ref, *, n: int, r: int, td: int, tl: int,
                  tm: int, n_l: int):
    l_idx = pl.program_id(2)
    tok = tok_ref[...].astype(jnp.uint32)     # (TD, TL)
    halo = halo_ref[...].astype(jnp.uint32)   # (TD, TL) next tile (clamped)
    lens = len_ref[...].astype(jnp.int32)     # (TD,)
    seeds = seeds_ref[...].astype(jnp.uint32)  # (TM,)

    # --- shingle: rolling n-gram polynomial hash over the halo'd tile.
    cat = jnp.concatenate([tok, halo], axis=1)
    acc = jnp.zeros_like(tok)
    base = jnp.uint32(NGRAM_BASE)
    for k in range(n):
        acc = acc * base + jax.lax.dynamic_slice_in_dim(cat, k, tl, axis=1)
    ng = _fmix(acc)                            # (TD, TL), VMEM-only

    # Validity of each window position (incl. the short-doc single
    # shingle at position 0), from lengths alone — no mask operand.
    pos = l_idx * tl + jax.lax.broadcasted_iota(jnp.int32, (td, tl), 1)
    ln = lens[:, None]
    valid = (pos + n <= ln) | ((ln < n) & (pos == 0) & (ln > 0))

    # --- minhash: seeded cube, min-accumulate into the resident block.
    x = _fmix(ng[:, :, None] * GOLDEN32 + seeds[None, None, :])
    x = jnp.where(valid[:, :, None], x, jnp.uint32(U32_MAX))
    part = jnp.min(x, axis=1)                  # (TD, TM)

    @pl.when(l_idx == 0)
    def _init():
        sig_ref[...] = part

    @pl.when(l_idx > 0)
    def _acc():
        sig_ref[...] = jnp.minimum(sig_ref[...], part)

    # --- band fold: the signature block is final on the last L tile;
    # fold its bands in-register (tm % r == 0 by construction).
    @pl.when(l_idx == n_l - 1)
    def _fold():
        s3 = sig_ref[...].reshape(td, tm // r, r)
        for lane, seed in enumerate(_LANE_SEEDS):
            h = jnp.full((td, tm // r), jnp.uint32(seed),
                         dtype=jnp.uint32)
            for k in range(r):
                h = _fmix(h * GOLDEN32 + s3[:, :, k])
            band_ref[:, :, lane] = h


@functools.partial(
    jax.jit, static_argnames=("n", "r", "td", "tl", "tm", "interpret"))
def fused_ingest(
    tokens: jnp.ndarray,
    lengths: jnp.ndarray,
    seeds: jnp.ndarray,
    *,
    n: int = 8,
    r: int = 2,
    td: int = TD,
    tl: int = TL,
    tm: int = TM,
    interpret: bool | None = None,
):
    """(D, L) uint32 tokens + (D,) lengths + (M,) seeds ->
    ((D, M) signatures, (D, M//r, 2) band values, (D, L) validity).

    One device-resident pass; n-gram hashes and the minhash cube never
    leave VMEM.  Matches the staged kernels and the jnp refs bit-for-
    bit.  Unlike the staged ngram kernel, batches whose padded width is
    shorter than ``n`` are handled (the tile length is clamped up to
    ``n`` and the zero right-padding reproduces the short-doc rule).
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    tokens = tokens.astype(jnp.uint32)
    lengths = lengths.astype(jnp.int32)
    seeds = seeds.astype(jnp.uint32)
    D, L = tokens.shape
    M = seeds.shape[0]
    assert M % r == 0, f"M={M} not divisible by r={r}"
    b = M // r
    if D == 0:
        return (jnp.zeros((0, M), jnp.uint32),
                jnp.zeros((0, b, 2), jnp.uint32),
                jnp.zeros((0, L), jnp.bool_))
    td_ = min(td, max(1, D))
    # The halo read needs tl >= n (a window crosses at most one tile
    # boundary); clamping up also absorbs batches with L < n.
    tl_ = max(min(tl, max(1, L)), n)
    # Every band's r rows must fall inside one M tile.
    tm_ = min(tm, max(1, M))
    tm_ = max(r, (tm_ // r) * r)
    Dp = -(-D // td_) * td_
    Lp = -(-L // tl_) * tl_
    Mp = -(-M // tm_) * tm_
    tok = jnp.pad(tokens, ((0, Dp - D), (0, Lp - L)))
    ln = jnp.pad(lengths, (0, Dp - D))
    sd = jnp.pad(seeds, (0, Mp - M))
    n_l = Lp // tl_

    sig, bands = pl.pallas_call(
        functools.partial(_fused_kernel, n=n, r=r, td=td_, tl=tl_,
                          tm=tm_, n_l=n_l),
        grid=(Dp // td_, Mp // tm_, Lp // tl_),
        in_specs=[
            pl.BlockSpec((td_, tl_), lambda d, m, l: (d, l)),
            # Halo: next L tile, clamped at the edge (edge positions
            # are invalid by construction there).
            pl.BlockSpec(
                (td_, tl_),
                lambda d, m, l: (d, jnp.minimum(l + 1, n_l - 1))),
            pl.BlockSpec((td_,), lambda d, m, l: (d,)),
            pl.BlockSpec((tm_,), lambda d, m, l: (m,)),
        ],
        out_specs=[
            pl.BlockSpec((td_, tm_), lambda d, m, l: (d, m)),
            pl.BlockSpec((td_, tm_ // r, 2), lambda d, m, l: (d, m, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Dp, Mp), jnp.uint32),
            jax.ShapeDtypeStruct((Dp, Mp // r, 2), jnp.uint32),
        ],
        interpret=interpret,
    )(tok, tok, ln, sd)

    pos = jnp.arange(L, dtype=jnp.int32)[None, :]
    ln2 = lengths[:, None]
    valid = (pos + n <= ln2) | ((ln2 < n) & (pos == 0) & (ln2 > 0))
    return sig[:D, :M], bands[:D, :b], valid
