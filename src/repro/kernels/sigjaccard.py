"""Pallas TPU kernel: signature-agreement Jaccard estimate for pairs.

Given pre-gathered signature rows for P candidate pairs, computes
est[p] = mean_m( a[p, m] == b[p, m] )  (paper §3.4's m/M estimator).
Memory-bound; tiled (TP, M) so both operands stream through VMEM once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TP = 256


def _sigjac_kernel(a_ref, b_ref, out_ref, *, m: int):
    a = a_ref[...]
    b = b_ref[...]
    eq = (a == b).astype(jnp.float32)
    out_ref[...] = jnp.sum(eq, axis=1) * (1.0 / m)


def _estimate(sig_a, sig_b, tp: int, interpret: bool | None):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    P, M = sig_a.shape
    tp_ = min(tp, max(1, P))
    Pp = -(-P // tp_) * tp_
    a = jnp.pad(sig_a.astype(jnp.uint32), ((0, Pp - P), (0, 0)))
    b = jnp.pad(sig_b.astype(jnp.uint32), ((0, Pp - P), (0, 0)))
    # Make padded rows disagree so padding can't look like a match.
    if Pp > P:
        row = jnp.arange(Pp)[:, None] >= P
        b = jnp.where(row, b + jnp.uint32(1), b)

    out = pl.pallas_call(
        functools.partial(_sigjac_kernel, m=M),
        grid=(Pp // tp_,),
        in_specs=[
            pl.BlockSpec((tp_, M), lambda p: (p, 0)),
            pl.BlockSpec((tp_, M), lambda p: (p, 0)),
        ],
        out_specs=pl.BlockSpec((tp_,), lambda p: (p,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:P]


@functools.partial(jax.jit, static_argnames=("tp", "interpret"))
def pair_estimate(
    sig_a: jnp.ndarray,
    sig_b: jnp.ndarray,
    *,
    tp: int = TP,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(P, M), (P, M) uint32 -> (P,) float32 agreement fraction."""
    return _estimate(sig_a, sig_b, tp, interpret)


@functools.partial(jax.jit, static_argnames=("tp", "interpret"))
def indexed_pair_estimate(
    sig: jnp.ndarray,
    a_idx: jnp.ndarray,
    b_idx: jnp.ndarray,
    *,
    tp: int = TP,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused gather + pair estimate: one dispatch per index batch.

    sig (D, M) uint32, a_idx/b_idx (P,) int -> (P,) float32.  The row
    gather runs on device inside the same jit as the kernel, so
    verifiers never materialize the gathered operands on the host.
    """
    return _estimate(sig[a_idx], sig[b_idx], tp, interpret)
