"""Pallas TPU kernel: signature-agreement Jaccard estimate for pairs.

Given pre-gathered signature rows for P candidate pairs, computes
est[p] = mean_m( a[p, m] == b[p, m] )  (paper §3.4's m/M estimator).
Memory-bound; tiled (TP, M) so both operands stream through VMEM once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TP = 256


def _sigjac_kernel(a_ref, b_ref, out_ref, *, m: int):
    a = a_ref[...]
    b = b_ref[...]
    eq = (a == b).astype(jnp.float32)
    out_ref[...] = jnp.sum(eq, axis=1) * (1.0 / m)


def _estimate(sig_a, sig_b, tp: int, interpret: bool | None):
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    P, M = sig_a.shape
    tp_ = min(tp, max(1, P))
    Pp = -(-P // tp_) * tp_
    a = jnp.pad(sig_a.astype(jnp.uint32), ((0, Pp - P), (0, 0)))
    b = jnp.pad(sig_b.astype(jnp.uint32), ((0, Pp - P), (0, 0)))
    # Make padded rows disagree so padding can't look like a match.
    if Pp > P:
        row = jnp.arange(Pp)[:, None] >= P
        b = jnp.where(row, b + jnp.uint32(1), b)

    out = pl.pallas_call(
        functools.partial(_sigjac_kernel, m=M),
        grid=(Pp // tp_,),
        in_specs=[
            pl.BlockSpec((tp_, M), lambda p: (p, 0)),
            pl.BlockSpec((tp_, M), lambda p: (p, 0)),
        ],
        out_specs=pl.BlockSpec((tp_,), lambda p: (p,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), jnp.float32),
        interpret=interpret,
    )(a, b)
    return out[:P]


@functools.partial(jax.jit, static_argnames=("tp", "interpret"))
def pair_estimate(
    sig_a: jnp.ndarray,
    sig_b: jnp.ndarray,
    *,
    tp: int = TP,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(P, M), (P, M) uint32 -> (P,) float32 agreement fraction."""
    return _estimate(sig_a, sig_b, tp, interpret)


@functools.partial(jax.jit, static_argnames=("tp", "interpret"))
def indexed_pair_estimate(
    sig: jnp.ndarray,
    a_idx: jnp.ndarray,
    b_idx: jnp.ndarray,
    *,
    tp: int = TP,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused gather + pair estimate: one dispatch per index batch.

    sig (D, M) uint32, a_idx/b_idx (P,) int -> (P,) float32.  The row
    gather runs on device inside the same jit as the kernel, so
    verifiers never materialize the gathered operands on the host.
    """
    return _estimate(sig[a_idx], sig[b_idx], tp, interpret)


def _sigjac_masked_kernel(a_ref, b_ref, v_ref, out_ref):
    a = a_ref[...]
    b = b_ref[...]
    eq = (a == b).astype(jnp.float32)
    out_ref[...] = jnp.where(v_ref[...] != 0, jnp.sum(eq, axis=1), 0.0)


def _masked_counts_rows(sig_a, sig_b, valid, tp: int,
                        interpret: bool | None):
    """Masked agreement counts over PRE-GATHERED (P, M) row operands."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    P, M = sig_a.shape
    tp_ = min(tp, max(1, P))
    Pp = -(-P // tp_) * tp_
    a = jnp.pad(sig_a.astype(jnp.uint32), ((0, Pp - P), (0, 0)))
    b = jnp.pad(sig_b.astype(jnp.uint32), ((0, Pp - P), (0, 0)))
    v = jnp.pad(valid.astype(jnp.int32), (0, Pp - P))

    out = pl.pallas_call(
        _sigjac_masked_kernel,
        grid=(Pp // tp_,),
        in_specs=[
            pl.BlockSpec((tp_, M), lambda p: (p, 0)),
            pl.BlockSpec((tp_, M), lambda p: (p, 0)),
            pl.BlockSpec((tp_,), lambda p: (p,)),
        ],
        out_specs=pl.BlockSpec((tp_,), lambda p: (p,)),
        out_shape=jax.ShapeDtypeStruct((Pp,), jnp.float32),
        interpret=interpret,
    )(a, b, v)
    return out[:P]


def _masked_counts(sig, a_idx, b_idx, valid, tp: int,
                   interpret: bool | None):
    D = sig.shape[0]
    a_idx = jnp.clip(a_idx, 0, D - 1)
    b_idx = jnp.clip(b_idx, 0, D - 1)
    return _masked_counts_rows(sig[a_idx], sig[b_idx], valid, tp,
                               interpret)


@functools.partial(jax.jit, static_argnames=("tp", "interpret"))
def masked_pair_counts(
    sig_a: jnp.ndarray,
    sig_b: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    tp: int = TP,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Masked full-M agreement *count* over pre-gathered row operands.

    sig_a/sig_b (P, M) uint32, valid (P,) bool -> (P,) float32 exact
    agreement counts where ``valid``, 0.0 elsewhere.  The pre-gathered
    variant of ``masked_indexed_pair_counts`` for operands that do NOT
    both live in one local matrix — the cross-shard straggler scoring
    of the sharded dedup path gathers one side from the device's own
    signature shard and the other from the bounded row buffer exchanged
    inside the all_to_all, then scores the pair here.  Same
    count-not-estimate contract: the /M division happens on the host so
    scores stay bit-identical to the host estimator.
    """
    return _masked_counts_rows(sig_a, sig_b, valid, tp, interpret)


@functools.partial(jax.jit, static_argnames=("tp", "interpret"))
def masked_indexed_pair_counts(
    sig: jnp.ndarray,
    a_idx: jnp.ndarray,
    b_idx: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    tp: int = TP,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused gather + full-M agreement *count* with a validity mask.

    sig (D, M) uint32, a_idx/b_idx (P,) int, valid (P,) bool ->
    (P,) float32: #agreeing signature rows (an exact integer value)
    where ``valid``, 0.0 elsewhere.  Indices are clipped to the local
    row range before the gather, so callers can pass raw shard-relative
    indices whose invalid lanes (cross-shard edges, empty buffer slots)
    point outside the shard — this is the device-resident stage-2
    verify of the sharded dedup path, run under ``shard_map`` over each
    device's own signature shard with a ``psum`` combining the
    per-shard masked contributions.

    Returning the raw count (instead of the m/M estimate) keeps the
    kernel output exact: XLA rewrites division by the compile-time
    constant M into a multiply by its reciprocal, which lands 1 ulp off
    the host numpy estimator — so the division is done by the consumer
    (``masked_indexed_pair_estimate`` eagerly, or the host merge in
    numpy), where it is correctly rounded and drift against the host
    verifier stays 0.
    """
    return _masked_counts(sig, a_idx, b_idx, valid, tp, interpret)


def masked_indexed_pair_estimate(
    sig: jnp.ndarray,
    a_idx: jnp.ndarray,
    b_idx: jnp.ndarray,
    valid: jnp.ndarray,
    *,
    tp: int = TP,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Masked fused gather + full-M estimate: counts / M.

    Bit-identical to the numpy estimator when called eagerly (the
    division executes as a standalone correctly-rounded op).  Inside a
    larger jit XLA may fold the division into a reciprocal multiply —
    use ``masked_indexed_pair_counts`` there and divide on the host.
    """
    counts = masked_indexed_pair_counts(
        sig, a_idx, b_idx, valid, tp=tp, interpret=interpret)
    return counts / jnp.float32(sig.shape[1])
