"""Jit'd public wrappers over the Pallas kernels.

``interpret=None`` auto-selects: interpret mode on CPU (validation), real
Mosaic lowering on TPU.  These are the entry points the pipeline uses when
``DedupConfig.use_pallas`` is set.
"""
from __future__ import annotations

from repro.kernels.minhash import minhash_signatures
from repro.kernels.ngram import ngram_hashes
from repro.kernels.bandfold import band_values
from repro.kernels.fused_ingest import fused_ingest
from repro.kernels.byte_shingle import byte_token_hashes, bytes_to_bands
from repro.kernels.sigjaccard import (
    indexed_pair_estimate,
    masked_indexed_pair_counts,
    masked_indexed_pair_estimate,
    masked_pair_counts,
    pair_estimate,
)
from repro.kernels.flash_attention import flash_attention

__all__ = [
    "minhash_signatures",
    "ngram_hashes",
    "band_values",
    "fused_ingest",
    "byte_token_hashes",
    "bytes_to_bands",
    "pair_estimate",
    "indexed_pair_estimate",
    "masked_indexed_pair_counts",
    "masked_indexed_pair_estimate",
    "masked_pair_counts",
    "flash_attention",
]
