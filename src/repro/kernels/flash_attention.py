"""Pallas TPU flash attention (beyond-paper optimization, §Perf H1/H2).

Motivation (measured in the dry-run roofline): the pure-jnp blockwise
attention materializes per-KV-block score tensors to HBM — they dominate
the memory term of every attention-heavy train/prefill cell (e.g.
deepseek-v2 train_4k: score-shaped fusions are the top HBM traffic).
This kernel keeps Q*K^T, the mask, and the online-softmax (m, l, acc)
state entirely in VMEM scratch: HBM traffic collapses to Q/K/V/O.

Grid: (batch*kv_heads, q_tiles, kv_tiles) with the KV dimension innermost
(sequential on TPU) so the VMEM scratch accumulates across KV tiles and
the output tile is written once at the last KV step.  GQA is handled by
folding the per-kv-head query group into the q-tile rows.

Validated in interpret mode against models.attention.blockwise_attention
(tests/test_kernels.py); compiles via Mosaic on real TPUs — the CPU
dry-run keeps the jnp path and EXPERIMENTS.md reports the adjusted
memory term.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TQ, TK = 128, 128


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "tq", "tk", "interpret"))
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    tq: int = TQ,
    tk: int = TK,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """q: (B, Sq, H, Dh); k/v: (B, Skv, Hkv, D*) -> (B, Sq, H, Dv).

    GQA: the g = H/Hkv query heads of one kv head fold into the q rows.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    B, Sq, H, Dh = q.shape
    _, Skv, Hkv, Dv = v.shape
    g = H // Hkv
    scale = scale if scale is not None else Dh**-0.5

    tq_ = min(tq, Sq)
    tk_ = min(tk, Skv)
    pad_q = (-Sq) % tq_
    pad_k = (-Skv) % tk_
    Sqp, Skp = Sq + pad_q, Skv + pad_k

    # Layout (B*Hkv, g, Sqp, Dh): one grid row = one (batch, kv head).
    qr = q.reshape(B, Sq, Hkv, g, Dh).transpose(0, 2, 3, 1, 4)
    if pad_q:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, 0), (0, pad_q), (0, 0)))
    qr = qr.reshape(B * Hkv, g * Sqp, Dh)
    kr = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, Dh)
    vr = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, Dv)
    if pad_k:
        kr = jnp.pad(kr, ((0, 0), (0, pad_k), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, pad_k), (0, 0)))
    n_q = (g * Sqp) // tq_
    n_k = Skp // tk_

    def kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref):
        kv_idx = pl.program_id(2)
        q_idx = pl.program_id(1)

        @pl.when(kv_idx == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        qt = q_ref[0]                    # (TQ, Dh)
        kt = k_ref[0]                    # (TK, Dh)
        vt = v_ref[0]                    # (TK, Dv)
        s = jax.lax.dot_general(
            qt, kt, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

        row = q_idx * tq_ + jax.lax.broadcasted_iota(
            jnp.int32, (tq_, tk_), 0)
        q_pos = row % Sqp                # fold group -> seq position
        k_pos = kv_idx * tk_ + jax.lax.broadcasted_iota(
            jnp.int32, (tq_, tk_), 1)
        mask = (q_pos < Sq) & (k_pos < Skv)
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window is not None:
            mask = mask & (k_pos > q_pos - window)
        s = jnp.where(mask, s, -jnp.inf)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe)
        p = jnp.where(jnp.isfinite(s), p, 0.0)
        corr = jnp.where(jnp.isfinite(m_prev),
                         jnp.exp(m_prev - m_safe), 0.0)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc_ref[...] * corr + jax.lax.dot_general(
            p.astype(vt.dtype), vt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new
        acc_ref[...] = acc

        @pl.when(kv_idx == n_k - 1)
        def _finish():
            o_ref[0] = (acc / jnp.maximum(l_new, 1e-30)).astype(
                o_ref.dtype)

    out = pl.pallas_call(
        kernel,
        grid=(B * Hkv, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, tq_, Dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, tk_, Dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, tk_, Dv), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq_, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hkv, g * Sqp, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((tq_, 1), jnp.float32),
            pltpu.VMEM((tq_, 1), jnp.float32),
            pltpu.VMEM((tq_, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    out = out.reshape(B, Hkv, g, Sqp, Dv)[:, :, :, :Sq]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv)
