"""Pallas TPU kernel: fold signature rows into 2-lane band values.

(D, b, r) uint32 -> (D, b, 2) uint32: per band, chained
h <- fmix32(h * GOLDEN + sig_k) over the r rows, one chain per lane seed
(paper §4.3 folds r values to one 64-bit integer; two 32-bit lanes here,
see DESIGN.md §2/§5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import GOLDEN32

_LANE_SEEDS = (0x2545F491, 0x9E3779B9)
TD, TB = 64, 64


def _fmix(x):
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    return x


def _bandfold_kernel(sig_ref, out_ref, *, r: int):
    sig = sig_ref[...].astype(jnp.uint32)       # (TD, TB, r)
    for lane, seed in enumerate(_LANE_SEEDS):
        h = jnp.full(sig.shape[:2], jnp.uint32(seed), dtype=jnp.uint32)
        for k in range(r):
            h = _fmix(h * GOLDEN32 + sig[:, :, k])
        out_ref[:, :, lane] = h


@functools.partial(jax.jit, static_argnames=("r", "td", "tb", "interpret"))
def band_values(
    sig: jnp.ndarray,
    r: int,
    *,
    td: int = TD,
    tb: int = TB,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """(D, M) uint32 signatures -> (D, b, 2) uint32 band values."""
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    D, M = sig.shape
    assert M % r == 0
    b = M // r
    td_ = min(td, max(1, D))
    tb_ = min(tb, max(1, b))
    Dp, Bp = -(-D // td_) * td_, -(-b // tb_) * tb_
    s3 = sig.astype(jnp.uint32).reshape(D, b, r)
    s3 = jnp.pad(s3, ((0, Dp - D), (0, Bp - b), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_bandfold_kernel, r=r),
        grid=(Dp // td_, Bp // tb_),
        in_specs=[pl.BlockSpec((td_, tb_, r), lambda d, j: (d, j, 0))],
        out_specs=pl.BlockSpec((td_, tb_, 2), lambda d, j: (d, j, 0)),
        out_shape=jax.ShapeDtypeStruct((Dp, Bp, 2), jnp.uint32),
        interpret=interpret,
    )(s3)
    return out[:D, :b]
