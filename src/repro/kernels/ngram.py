"""Pallas TPU kernel: rolling n-gram polynomial hash with halo blocks.

Each output position l hashes tokens[l : l+n].  The window crosses tile
boundaries, so the kernel reads its own (TD, TL) token tile plus the next
tile along L (halo) — two in_specs over the same operand with shifted
index maps (the standard Pallas halo idiom; BlockSpecs cannot overlap).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.hashing import NGRAM_BASE

TD, TL = 8, 256


def _ngram_kernel(tok_ref, halo_ref, out_ref, *, n: int, tl: int):
    tok = tok_ref[...].astype(jnp.uint32)    # (TD, TL)
    halo = halo_ref[...].astype(jnp.uint32)  # (TD, TL) — next tile (clamped)
    cat = jnp.concatenate([tok, halo], axis=1)
    acc = jnp.zeros_like(tok)
    base = jnp.uint32(NGRAM_BASE)
    for k in range(n):
        acc = acc * base + jax.lax.dynamic_slice_in_dim(cat, k, tl, axis=1)
    # fmix32
    x = acc
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x85EBCA6B)
    x = x ^ (x >> 13)
    x = x * jnp.uint32(0xC2B2AE35)
    x = x ^ (x >> 16)
    out_ref[...] = x


@functools.partial(jax.jit, static_argnames=("n", "td", "tl", "interpret"))
def ngram_hashes(
    tokens: jnp.ndarray,
    lengths: jnp.ndarray,
    n: int = 8,
    *,
    td: int = TD,
    tl: int = TL,
    interpret: bool | None = None,
):
    """(D, L) uint32 tokens -> ((D, L) hashes, (D, L) validity).

    Matches ``repro.core.shingle.ngram_hashes`` (the ref oracle), including
    the short-document single-shingle rule.
    """
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    D, L = tokens.shape
    td_ = min(td, max(1, D))
    # Clamp the L tile UP to n: a batch narrower than the window pads to
    # one n-wide tile whose zero fill reproduces the oracle's zero-padded
    # prefix hash (the short-document single-shingle rule).
    tl_ = max(min(tl, max(1, L)), n)
    Dp, Lp = -(-D // td_) * td_, -(-L // tl_) * tl_
    tok = jnp.pad(tokens.astype(jnp.uint32), ((0, Dp - D), (0, Lp - L)))
    n_l = Lp // tl_

    out = pl.pallas_call(
        functools.partial(_ngram_kernel, n=n, tl=tl_),
        grid=(Dp // td_, n_l),
        in_specs=[
            pl.BlockSpec((td_, tl_), lambda d, l: (d, l)),
            # Halo: next L tile, clamped at the edge (edge outputs are
            # invalid by construction: l + n > length there).
            pl.BlockSpec(
                (td_, tl_), lambda d, l: (d, jnp.minimum(l + 1, n_l - 1))
            ),
        ],
        out_specs=pl.BlockSpec((td_, tl_), lambda d, l: (d, l)),
        out_shape=jax.ShapeDtypeStruct((Dp, Lp), jnp.uint32),
        interpret=interpret,
    )(tok, tok)
    out = out[:D, :L]
    pos = jnp.arange(L, dtype=jnp.int32)[None, :]
    ln = lengths.astype(jnp.int32)[:, None]
    valid = pos + n <= ln
    short = (ln < n) & (pos == 0) & (ln > 0)
    # Short docs hash their full prefix: recompute position 0 with the
    # actual (clamped) window — handled on the host side of the kernel.
    return out, valid | short
