"""Finding model shared by the lint driver, rules, and baseline."""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Finding:
    """One rule violation at one source location.

    ``fingerprint`` is the line-number-insensitive identity used for
    baseline matching: a file can be edited above a grandfathered
    finding without un-grandfathering it, but moving the construct to
    another function (or changing what it does) produces a fresh
    fingerprint that must be fixed or re-baselined.
    """

    rule: str                   # "RPR001" .. "RPR005"
    path: str                   # repo-relative posix path
    line: int                   # 1-indexed
    col: int                    # 0-indexed
    message: str
    symbol: str = ""            # short stable slug for the construct
    qualname: str = ""          # enclosing scope, e.g. "DedupSession.view"
    status: str = field(default="new", compare=False)
    # "new" | "baselined" | "suppressed"

    @property
    def fingerprint(self) -> str:
        return f"{self.rule}::{self.path}::{self.qualname}::{self.symbol}"

    def render(self) -> str:
        scope = f" [{self.qualname}]" if self.qualname else ""
        return (f"{self.path}:{self.line}:{self.col + 1}: "
                f"{self.rule} {self.message}{scope}")

    def to_json(self) -> dict:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "col": self.col,
            "message": self.message,
            "symbol": self.symbol,
            "qualname": self.qualname,
            "fingerprint": self.fingerprint,
            "status": self.status,
        }
