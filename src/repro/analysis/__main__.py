"""``python -m repro.analysis`` entry point."""
from repro.analysis.lint import main

raise SystemExit(main())
