"""repro.analysis — repo-specific static analysis (the lint pass).

The engine's correctness story is a set of hand-enforced invariants:
exact uint32 wraparound arithmetic in the kernel chain (bit parity is
the paper's exact-dedup contract), queries that never mutate session
state, jit entry points fed shape-stable operands, the blessed
``ingest*/compute_*/query*`` naming scheme, and Pallas BlockSpec tiling
that stays inside the documented VMEM budget.  Until this package,
nothing checked any of that until a test happened to trip it.

``python -m repro.analysis`` runs five AST rules over the repo
(DESIGN.md §10 documents each invariant):

* **RPR001 dtype-discipline** — uint32 wraparound arithmetic in
  ``kernels/*`` and ``core/hashing.py`` / ``core/minhash.py`` must not
  mix in bare int literals, true/floor division, or int32 operands.
* **RPR002 query-purity** — ``query*`` / ``view`` / ``probe_*`` /
  ``frozen_*`` functions must not assign to ``self.*``, call
  ``ingest*`` / ``admit*`` or mutating index/union-find methods, or
  mutate view state.
* **RPR003 recompilation-hazard** — calls into the jitted signature
  stages (``compute_arrays`` / ``compute_signatures`` /
  ``fused_ingest``) must route shape-bearing args through ``pad_len``
  / pow2 bucketing (the PR 7 ~350 ms-p50 recompile bug, DESIGN.md §9).
* **RPR004 naming/deprecation** — no new calls to the
  ``DeprecationWarning`` shims (``ingest_arrays``,
  ``ClusterSnapshot.uf``); new public defs in ``core/`` follow the
  naming scheme.
* **RPR005 pallas-spec** — ``pl.pallas_call`` sites: BlockSpec
  index-map arity must match the grid rank, block ranks must match the
  operand/out_shape ranks, tile dims must be clamped/padded per the
  documented TL/TM rules, and the static VMEM estimate must stay under
  the configured ceiling (DESIGN.md §8's ~530 KiB budget, checked).

Findings are suppressible per line (``# repro-lint: disable=RPR00x``)
or grandfathered via the committed baseline
(``.repro-lint-baseline.json``; regenerate with ``--write-baseline``).
The CI ``lint`` job runs this pass plus ``ruff`` before tier-1.
"""
from repro.analysis.findings import Finding
from repro.analysis.lint import main, run_analysis

__all__ = ["Finding", "main", "run_analysis"]
