"""RPR002 query-purity: the read path must never mutate session state.

The blessed naming scheme (ROADMAP "API stability", ``repro.core``
docstring) reserves ``query*`` / ``view`` / ``probe_*`` / ``frozen_*``
for reads: DESIGN.md §9's whole concurrency story — a query can never
race a concurrent ingest — rests on those functions touching only
frozen copies.  A stray ``self.x = ...`` or a call into a write-path
verb inside one of them is a torn-state bug waiting for load.

Flagged inside functions matching the read-path naming (test functions
are exempt — a ``test_query_*`` exercising ``admit`` is the point of
the test):

* assignments (plain, augmented, annotated, ``del``) whose target is
  rooted at ``self`` or at a ``view`` parameter;
* calls to ``ingest*`` / ``admit*`` entry points;
* calls to known-mutating ``BandIndex`` / union-find / verifier /
  store methods (``match_then_insert``, ``union``, ``evict``, ...);
* mutating container-method calls (``append`` / ``update`` /
  ``setdefault`` / ...) on receivers rooted at ``self`` or a ``view``
  parameter — local accumulators stay allowed.

Benign memoization (e.g. ``DedupSession.view``'s atomic cache swap,
service stats counters) is declared with an inline
``# repro-lint: disable=RPR002`` carrying its justification.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.findings import Finding
from repro.analysis.rules.base import (
    FileContext,
    Rule,
    attr_root,
    callee_name,
    iter_scopes,
)

READ_NAME = re.compile(r"^(query\w*|view|probe_\w+|frozen_\w+)$")

# Known-mutating methods on session collaborators (BandIndex,
# ThresholdUnionFind, verifiers, stores, allocator).
MUTATOR_METHODS = {
    "match_then_insert", "evict", "union", "grow", "drain_deposed",
    "release_rows", "extend_signatures", "extend_id_rows",
    "extend_token_lists", "allocate", "adopt_layout", "refine",
    "feed", "merge", "sweep", "compact",
}

# Container mutators — only flagged on self/view-rooted receivers.
CONTAINER_MUTATORS = {
    "append", "extend", "insert", "remove", "pop", "popleft", "clear",
    "update", "add", "discard", "setdefault", "sort", "reverse",
    "appendleft", "setflags", "fill", "resize", "put",
}


def _target_roots(node: ast.AST):
    if isinstance(node, (ast.Tuple, ast.List)):
        for elt in node.elts:
            yield from _target_roots(elt)
    elif isinstance(node, (ast.Attribute, ast.Subscript)):
        yield attr_root(node), node
    elif isinstance(node, ast.Starred):
        yield from _target_roots(node.value)


class QueryPurity(Rule):
    rule_id = "RPR002"
    name = "query-purity"

    def check(self, ctx: FileContext) -> list[Finding]:
        out: list[Finding] = []
        for fn, qual in iter_scopes(ctx.tree):
            if not READ_NAME.match(fn.name) or fn.name.startswith("test"):
                continue
            if ctx.is_test:
                continue
            view_params = {
                a.arg for a in (fn.args.posonlyargs + fn.args.args
                                + fn.args.kwonlyargs)
                if a.arg == "view" or a.arg.endswith("_view")}
            guarded = {"self", "cls"} | view_params
            out.extend(self._check_body(ctx, fn, qual, guarded))
        return out

    def _check_body(self, ctx, fn, qual, guarded) -> list[Finding]:
        out: list[Finding] = []
        for node in ast.walk(fn):
            if isinstance(node, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for t in targets:
                    for root, tnode in _target_roots(t):
                        if root in guarded:
                            out.append(self.finding(
                                ctx, node,
                                f"read-path function `{fn.name}` assigns "
                                f"to `{ast.unparse(tnode)}`; query*/view/"
                                "probe_*/frozen_* must not mutate state",
                                symbol=f"assign:{ast.unparse(tnode)}",
                                qualname=qual))
            elif isinstance(node, ast.Delete):
                for t in node.targets:
                    for root, tnode in _target_roots(t):
                        if root in guarded:
                            out.append(self.finding(
                                ctx, node,
                                f"read-path function `{fn.name}` deletes "
                                f"`{ast.unparse(tnode)}`",
                                symbol=f"del:{ast.unparse(tnode)}",
                                qualname=qual))
            elif isinstance(node, ast.Call):
                out.extend(self._check_call(ctx, fn, node, qual, guarded))
        return out

    def _check_call(self, ctx, fn, call, qual, guarded) -> list[Finding]:
        name = callee_name(call)
        if name is None:
            return []
        if name.startswith("ingest") or name.startswith("admit"):
            return [self.finding(
                ctx, call,
                f"read-path function `{fn.name}` calls write-path entry "
                f"point `{name}`", symbol=f"call:{name}", qualname=qual)]
        if name in MUTATOR_METHODS and isinstance(call.func,
                                                  ast.Attribute):
            return [self.finding(
                ctx, call,
                f"read-path function `{fn.name}` calls mutating method "
                f"`{name}`", symbol=f"call:{name}", qualname=qual)]
        if name in CONTAINER_MUTATORS and isinstance(call.func,
                                                     ast.Attribute):
            root = attr_root(call.func.value)
            if root in guarded:
                return [self.finding(
                    ctx, call,
                    f"read-path function `{fn.name}` mutates "
                    f"`{ast.unparse(call.func.value)}` via `.{name}()`",
                    symbol=f"mutate:{name}", qualname=qual)]
        return []
