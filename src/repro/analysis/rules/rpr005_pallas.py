"""RPR005 pallas-spec: BlockSpec/grid coherence + static VMEM budget.

Pallas mistakes in this repo fail late (Mosaic compile error on real
TPUs, or silent garbage from a mis-indexed block) because CI runs the
kernels in interpret mode.  Four properties ARE statically checkable
at every ``pl.pallas_call`` site, and this rule checks them:

* **index-map arity** — every BlockSpec's ``lambda`` must take exactly
  one argument per grid axis;
* **out rank** — each out_spec block tuple must have the same rank as
  its paired ``ShapeDtypeStruct`` shape;
* **tile clamping** — a block dim that *varies* with a grid axis (its
  index-map element is a bare grid parameter) must be a clamped local
  (the ``t_ = min(t, max(1, X))`` / ``max(r, (t // r) * r)`` idiom that
  guarantees the padded operand dim divides, DESIGN.md §8) — a raw
  parameter or hardcoded literal tile (other than 1) can stop dividing
  the operand the moment a caller passes a new shape;
* **VMEM budget** — a static upper-bound estimate per kernel: all
  resolvable block tiles + ``scratch_shapes`` + (for rank-3 grids) the
  broadcast cube over the distinct tile symbols, the dominant term of
  the minhash-family kernels.  DESIGN.md §8's ~530 KiB budget becomes
  a checked number with a configurable ceiling (``--vmem-limit``,
  default 1 MiB).  Dims resolve through locals, param defaults, and
  module constants; unresolvable dims make the estimate partial, which
  can still *exceed* the ceiling (sound) but never pass a kernel that
  a full resolution would fail.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.rules.base import FileContext, Rule, iter_scopes

_DTYPE_BYTES = {
    "uint32": 4, "int32": 4, "float32": 4, "int64": 8, "float64": 8,
    "uint64": 8, "uint8": 1, "int8": 1, "bool_": 1, "bfloat16": 2,
    "float16": 2, "uint16": 2, "int16": 2,
}


def _is_minmax(node: ast.AST) -> bool:
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("min", "max"))


class _Resolver:
    """Upper-bound integer resolution through locals/params/constants."""

    def __init__(self, module: ast.Module, fn: ast.FunctionDef):
        self.env: dict[str, int] = {}
        self.clamped: set[str] = set()
        for node in module.body:
            self._learn_assign(node, module_level=True)
        args = fn.args
        pos = args.posonlyargs + args.args
        for a, d in zip(pos[len(pos) - len(args.defaults):],
                        args.defaults):
            v = self.eval(d)
            if v is not None:
                self.env[a.arg] = v
        for a, d in zip(args.kwonlyargs, args.kw_defaults):
            if d is not None:
                v = self.eval(d)
                if v is not None:
                    self.env[a.arg] = v
        for node in ast.walk(fn):
            self._learn_assign(node)

    def _learn_assign(self, node: ast.AST, module_level: bool = False):
        if not isinstance(node, ast.Assign):
            return
        targets, values = [], []
        if len(node.targets) == 1 and isinstance(node.targets[0],
                                                 ast.Tuple):
            tgt = node.targets[0]
            if isinstance(node.value, ast.Tuple) and \
                    len(node.value.elts) == len(tgt.elts):
                targets, values = tgt.elts, node.value.elts
        else:
            targets = [t for t in node.targets]
            values = [node.value] * len(targets)
        for t, v in zip(targets, values):
            if not isinstance(t, ast.Name):
                continue
            if _is_minmax(v):
                self.clamped.add(t.id)
            val = self.eval(v)
            if val is not None:
                self.env[t.id] = val
            elif not module_level:
                self.env.pop(t.id, None)

    def eval(self, node: ast.AST) -> int | None:
        """Upper bound of an int expression; None if unresolvable."""
        if isinstance(node, ast.Constant) and type(node.value) is int:
            return node.value
        if isinstance(node, ast.Name):
            return self.env.get(node.id)
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
            v = self.eval(node.operand)
            return -v if v is not None else None
        if isinstance(node, ast.BinOp):
            le, ri = self.eval(node.left), self.eval(node.right)
            if le is None or ri is None:
                return None
            if isinstance(node.op, ast.Mult):
                return le * ri
            if isinstance(node.op, ast.Add):
                return le + ri
            if isinstance(node.op, ast.Sub):
                return le - ri
            if isinstance(node.op, ast.FloorDiv) and ri != 0:
                return le // ri
            return None
        if _is_minmax(node):
            vals = [self.eval(a) for a in node.args]
            known = [v for v in vals if v is not None]
            if not known:
                return None
            if node.func.id == "min":
                return min(known)  # min <= every arg: sound upper bound
            # max over a partial set is NOT an upper bound: an
            # unresolved operand usually carries the runtime dim
            # (max(1, L)); downstream min() clamps recover the bound.
            return max(known) if len(known) == len(vals) else None
        return None


class PallasSpec(Rule):
    rule_id = "RPR005"
    name = "pallas-spec"

    def applies(self, ctx: FileContext) -> bool:
        src = "\n".join(ctx.lines)
        return "pallas_call" in src

    def check(self, ctx: FileContext) -> list[Finding]:
        out: list[Finding] = []
        for fn, qual in iter_scopes(ctx.tree):
            calls = [n for n in ast.walk(fn)
                     if isinstance(n, ast.Call)
                     and isinstance(n.func, ast.Attribute)
                     and n.func.attr == "pallas_call"]
            for call in calls:
                out.extend(self._check_site(ctx, fn, call, qual))
        return out

    # -- one pallas_call site ------------------------------------------------

    def _check_site(self, ctx, fn, call, qual) -> list[Finding]:
        out: list[Finding] = []
        kw = {k.arg: k.value for k in call.keywords if k.arg}
        grid = kw.get("grid")
        grid_rank = (len(grid.elts)
                     if isinstance(grid, ast.Tuple) else None)
        in_specs = self._spec_list(kw.get("in_specs"))
        out_specs = self._spec_list(kw.get("out_specs"))
        out_shapes = self._shape_list(kw.get("out_shape"))
        res = _Resolver(ctx.tree, fn)

        for spec in in_specs + out_specs:
            out.extend(self._check_spec(ctx, spec, grid_rank, res, qual))

        if len(out_specs) == len(out_shapes):
            for spec, shp in zip(out_specs, out_shapes):
                block = self._block_tuple(spec)
                shape = self._sds_shape(shp)
                if block is not None and shape is not None and \
                        len(block.elts) != len(shape.elts):
                    out.append(self.finding(
                        ctx, spec,
                        f"out_spec block rank {len(block.elts)} != "
                        f"out_shape rank {len(shape.elts)}",
                        symbol="out-rank-mismatch", qualname=qual))

        est, partial = self._vmem_estimate(
            in_specs, out_specs, out_shapes, kw.get("scratch_shapes"),
            grid_rank, res)
        if est > ctx.vmem_limit:
            kib = est / 1024
            out.append(self.finding(
                ctx, call,
                f"static VMEM estimate ~{kib:.0f} KiB exceeds the "
                f"{ctx.vmem_limit // 1024} KiB ceiling"
                + (" (partial resolution: true usage is higher)"
                   if partial else "")
                + "; shrink the tile dims or raise --vmem-limit with a "
                  "DESIGN.md §8 budget note",
                symbol="vmem-budget", qualname=qual))
        return out

    def _check_spec(self, ctx, spec, grid_rank, res, qual):
        out = []
        block = self._block_tuple(spec)
        lam = self._index_map(spec)
        if lam is not None and grid_rank is not None:
            arity = len(lam.args.posonlyargs + lam.args.args)
            if arity != grid_rank:
                out.append(self.finding(
                    ctx, spec,
                    f"BlockSpec index map takes {arity} args but the "
                    f"grid has {grid_rank} axes",
                    symbol="index-map-arity", qualname=qual))
        if block is None or lam is None or \
                not isinstance(lam.body, ast.Tuple):
            return out
        params = {a.arg for a in (lam.args.posonlyargs + lam.args.args)}
        for i, (dim, idx) in enumerate(zip(block.elts, lam.body.elts)):
            varies = isinstance(idx, ast.Name) and idx.id in params
            if not varies:
                continue
            if isinstance(dim, ast.Constant) and dim.value == 1:
                continue  # block of 1 divides everything
            if isinstance(dim, ast.Name) and dim.id in res.clamped:
                continue
            if isinstance(dim, ast.BinOp):
                # derived dims (tm_ // r): require the base clamped
                names = [n.id for n in ast.walk(dim)
                         if isinstance(n, ast.Name)]
                if any(n in res.clamped for n in names):
                    continue
            out.append(self.finding(
                ctx, dim if hasattr(dim, "lineno") else spec,
                f"tile dim {ast.unparse(dim)!r} varies with a grid axis "
                "but is not clamped to the operand bounds (use the "
                "`t_ = min(t, max(1, X))` / ceil-pad idiom, DESIGN.md "
                "§8) — an unpadded operand dim it does not divide "
                "mis-tiles the kernel",
                symbol=f"unclamped-dim:{ast.unparse(dim)}",
                qualname=qual))
        return out

    # -- VMEM estimate -------------------------------------------------------

    def _vmem_estimate(self, in_specs, out_specs, out_shapes, scratch,
                       grid_rank, res) -> tuple[int, bool]:
        total, partial = 0, False
        dtype_by_spec = {}
        if len(out_specs) == len(out_shapes):
            for spec, shp in zip(out_specs, out_shapes):
                dtype_by_spec[id(spec)] = self._sds_dtype_bytes(shp)
        # Per grid axis, the widest tile extent indexed along it: their
        # product bounds the broadcast cube a rank-3 kernel can build
        # (the (TD, TL, TM) seeded-hash intermediate of the minhash
        # family, DESIGN.md §8 — the dominant VMEM term).
        axis_extent: dict[str, int] = {}
        axis_unresolved = False
        for spec in in_specs + out_specs:
            block = self._block_tuple(spec)
            if block is None:
                continue
            nbytes = dtype_by_spec.get(id(spec), 4)
            size = 1
            ok = True
            lam = self._index_map(spec)
            idx_elts = (lam.body.elts
                        if lam is not None
                        and isinstance(lam.body, ast.Tuple)
                        else [])
            params = ({a.arg for a in (lam.args.posonlyargs
                                       + lam.args.args)}
                      if lam is not None else set())
            for i, dim in enumerate(block.elts):
                v = res.eval(dim)
                if v is None:
                    ok = False
                else:
                    size *= v
                if i < len(idx_elts) and isinstance(
                        idx_elts[i], ast.Name) and \
                        idx_elts[i].id in params:
                    if v is None:
                        axis_unresolved = True
                    else:
                        axis_extent[idx_elts[i].id] = max(
                            axis_extent.get(idx_elts[i].id, 1), v)
            if ok:
                total += size * nbytes
            else:
                partial = True
        if isinstance(scratch, (ast.List, ast.Tuple)):
            for s in scratch.elts:
                v = self._scratch_bytes(s, res)
                if v is None:
                    partial = True
                else:
                    total += v
        if grid_rank is not None and grid_rank >= 3 and axis_extent:
            if axis_unresolved:
                partial = True
            else:
                cube = 1
                for v in axis_extent.values():
                    cube *= v
                total += cube * 4
        return total, partial

    def _scratch_bytes(self, node, res) -> int | None:
        if not (isinstance(node, ast.Call) and node.args):
            return None
        shape = node.args[0]
        if not isinstance(shape, ast.Tuple):
            return None
        size = 1
        for dim in shape.elts:
            v = res.eval(dim)
            if v is None:
                return None
            size *= v
        nbytes = 4
        if len(node.args) > 1 and isinstance(node.args[1], ast.Attribute):
            nbytes = _DTYPE_BYTES.get(node.args[1].attr, 4)
        return size * nbytes

    # -- AST plumbing --------------------------------------------------------

    @staticmethod
    def _spec_list(node) -> list[ast.Call]:
        if node is None:
            return []
        items = node.elts if isinstance(node, (ast.List, ast.Tuple)) \
            else [node]
        return [n for n in items
                if isinstance(n, ast.Call)
                and ((isinstance(n.func, ast.Attribute)
                      and n.func.attr == "BlockSpec")
                     or (isinstance(n.func, ast.Name)
                         and n.func.id == "BlockSpec"))]

    @staticmethod
    def _shape_list(node) -> list[ast.Call]:
        if node is None:
            return []
        items = node.elts if isinstance(node, (ast.List, ast.Tuple)) \
            else [node]
        return [n for n in items if isinstance(n, ast.Call)]

    @staticmethod
    def _block_tuple(spec: ast.Call) -> ast.Tuple | None:
        if spec.args and isinstance(spec.args[0], ast.Tuple):
            return spec.args[0]
        for k in spec.keywords:
            if k.arg == "block_shape" and isinstance(k.value, ast.Tuple):
                return k.value
        return None

    @staticmethod
    def _index_map(spec: ast.Call) -> ast.Lambda | None:
        if len(spec.args) > 1 and isinstance(spec.args[1], ast.Lambda):
            return spec.args[1]
        for k in spec.keywords:
            if k.arg == "index_map" and isinstance(k.value, ast.Lambda):
                return k.value
        return None

    @staticmethod
    def _sds_shape(sds: ast.Call) -> ast.Tuple | None:
        if sds.args and isinstance(sds.args[0], ast.Tuple):
            return sds.args[0]
        for k in sds.keywords:
            if k.arg == "shape" and isinstance(k.value, ast.Tuple):
                return k.value
        return None

    def _sds_dtype_bytes(self, sds: ast.Call) -> int:
        node = None
        if len(sds.args) > 1:
            node = sds.args[1]
        for k in sds.keywords:
            if k.arg == "dtype":
                node = k.value
        if isinstance(node, ast.Attribute):
            return _DTYPE_BYTES.get(node.attr, 4)
        return 4
