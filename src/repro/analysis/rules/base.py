"""Shared rule plumbing: file context, scope walking, AST helpers."""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field

from repro.analysis.findings import Finding

# File-level scope pragma: lets a file outside the path-scoped
# directories opt into scoped rules (fixtures use this; so can a new
# kernel module that lives elsewhere):  # repro-lint: scope=kernel
_SCOPE_RE = re.compile(r"#\s*repro-lint:\s*scope=([\w,\- ]+)")


@dataclass
class FileContext:
    """One parsed file handed to every rule."""

    relpath: str                # repo-relative posix path
    tree: ast.Module
    lines: list[str]            # raw source lines (0-indexed)
    vmem_limit: int = 1 << 20   # RPR005 ceiling, bytes
    scopes: set[str] = field(default_factory=set)
    is_test: bool = False

    @classmethod
    def parse(cls, relpath: str, source: str, *,
              vmem_limit: int = 1 << 20) -> "FileContext":
        lines = source.splitlines()
        scopes: set[str] = set()
        for ln in lines[:15]:
            m = _SCOPE_RE.search(ln)
            if m:
                scopes.update(s.strip() for s in m.group(1).split(","))
        name = relpath.rsplit("/", 1)[-1]
        is_test = relpath.startswith("tests/") or name.startswith("test_")
        return cls(relpath=relpath, tree=ast.parse(source, relpath),
                   lines=lines, vmem_limit=vmem_limit, scopes=scopes,
                   is_test=is_test)


class Rule:
    """Base class: subclasses set ``rule_id`` and implement ``check``."""

    rule_id = "RPR000"
    name = "base"

    def applies(self, ctx: FileContext) -> bool:
        return True

    def check(self, ctx: FileContext) -> list[Finding]:
        raise NotImplementedError

    def finding(self, ctx: FileContext, node: ast.AST, message: str,
                symbol: str, qualname: str = "") -> Finding:
        return Finding(
            rule=self.rule_id, path=ctx.relpath,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0),
            message=message, symbol=symbol, qualname=qualname)


def iter_scopes(tree: ast.Module):
    """Yield (func_node, qualname) for every def, including nested."""

    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield child, q
                yield from walk(child, f"{q}.")
            elif isinstance(child, ast.ClassDef):
                yield from walk(child, f"{prefix}{child.name}.")
            else:
                yield from walk(child, prefix)

    yield from walk(tree, "")


def enclosing_qualname(tree: ast.Module, node: ast.AST) -> str:
    """Qualname of the innermost def containing ``node`` ("" if none)."""
    best = ""
    for fn, q in iter_scopes(tree):
        if (fn.lineno <= node.lineno <= max(
                getattr(fn, "end_lineno", fn.lineno), fn.lineno)):
            best = q  # scopes yield outer-first; last hit is innermost
    return best


def attr_root(node: ast.AST) -> str | None:
    """Base Name of an Attribute/Subscript/Call chain, else None."""
    while isinstance(node, (ast.Attribute, ast.Subscript, ast.Call)):
        node = node.func if isinstance(node, ast.Call) else node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def callee_name(call: ast.Call) -> str | None:
    """Final name of the callee: ``a.b.c(...)`` -> "c", ``f(...)`` -> "f"."""
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return None


def is_int_literal(node: ast.AST) -> bool:
    if isinstance(node, ast.Constant) and type(node.value) is int:
        return True
    return (isinstance(node, ast.UnaryOp)
            and isinstance(node.op, (ast.USub, ast.UAdd, ast.Invert))
            and is_int_literal(node.operand))


def build_parents(tree: ast.Module) -> dict[ast.AST, ast.AST]:
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents
