"""RPR001 dtype-discipline: exact uint32 wraparound arithmetic.

Bit parity is the exact-dedup contract (PAPER.md; SEDD in PAPERS.md
shows how fragile GPU dedup parity is to dtype/promotion drift): every
hash value in the kernel chain is uint32 with wraparound multiply /
xor / shift, and the same source expression must produce the same bits
on the numpy oracle, the jnp ref, and the Pallas kernels.  Three
things silently break that:

* a bare Python int literal in a binary op — jax weak types usually
  forgive it, numpy sometimes promotes to int64, and the two disagree;
* ``/`` or ``//`` on hash values — division is not part of the
  wraparound algebra and rounds differently across backends;
* mixing an int32 operand into uint32 arithmetic — promotion rules
  differ between numpy and jnp.

The rule runs a small per-function taint pass: names become
"uint32-tainted" when assigned from ``*.astype(jnp.uint32)`` /
``jnp.uint32(...)`` / ``np.uint32(...)`` or from the module's uint32
constants (module-level ``np.uint32`` assignments plus the
``core.hashing`` family), and taint propagates through arithmetic,
subscripts, and calls (``jnp.where`` / ``jnp.min`` / ``fmix`` keep the
dtype) but not through comparisons or casts to another dtype.  Checks
fire only on tainted operands, so int32 position math next to hash
math stays clean.

Scope: ``kernels/`` plus ``core/hashing.py`` / ``core/minhash.py``
(the bit-parity chain), or any file with ``# repro-lint: scope=kernel``.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.rules.base import (
    FileContext,
    Rule,
    build_parents,
    is_int_literal,
    iter_scopes,
)

# Names from repro.core.hashing that are uint32 by construction.
KNOWN_UINT32 = {
    "GOLDEN32", "NGRAM_BASE", "NGRAM_BASE2", "U32_MAX",
    "FNV_OFFSET32", "FNV_PRIME32",
    "_FMIX_C1", "_FMIX_C2",
}

_ARITH = (ast.Mult, ast.Add, ast.Sub, ast.BitXor, ast.BitOr, ast.BitAnd)
_DIV = (ast.Div, ast.FloorDiv)


def _is_uint32_cast(call: ast.Call) -> bool:
    """``jnp.uint32(x)`` / ``np.uint32(x)`` / ``x.astype(jnp.uint32)``."""
    f = call.func
    if isinstance(f, ast.Attribute) and f.attr == "uint32":
        return True
    if isinstance(f, ast.Name) and f.id == "uint32":
        return True
    if isinstance(f, ast.Attribute) and f.attr == "astype":
        return any("uint32" in ast.dump(a) for a in call.args)
    return False


def _is_other_cast(call: ast.Call) -> bool:
    """A cast to a non-uint32 dtype (breaks the taint chain)."""
    f = call.func
    if isinstance(f, ast.Attribute) and f.attr == "astype":
        return not any("uint32" in ast.dump(a) for a in call.args)
    dtypes = {"int32", "int64", "float32", "float64", "bool_", "int8",
              "int16", "uint8", "uint16", "uint64", "bfloat16",
              "float16"}
    name = f.attr if isinstance(f, ast.Attribute) else (
        f.id if isinstance(f, ast.Name) else None)
    return name in dtypes


def _is_int32_operand(node: ast.AST) -> bool:
    """``jnp.int32(x)`` / ``x.astype(jnp.int32)`` used as an operand."""
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    if isinstance(f, ast.Attribute) and f.attr in ("int32", "int64"):
        return True
    if isinstance(f, ast.Attribute) and f.attr == "astype":
        return any(_names_int32(a) for a in node.args)
    return False


def _names_int32(node: ast.AST) -> bool:
    """A dtype expression naming int32/int64 (NOT uint32/uint64)."""
    return any(isinstance(n, (ast.Attribute, ast.Name))
               and (n.attr if isinstance(n, ast.Attribute) else n.id)
               in ("int32", "int64")
               for n in ast.walk(node))


class _Taint:
    """Per-function forward taint over local names (two fixpoint passes)."""

    def __init__(self, seed: set[str]):
        self.names = set(seed)

    def expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Call):
            if _is_uint32_cast(node):
                return True
            if _is_other_cast(node):
                return False
            return any(self.expr(a) for a in node.args) or any(
                self.expr(k.value) for k in node.keywords)
        if isinstance(node, ast.Attribute):
            # Metadata reads leave the hash domain: shape/index math on
            # a tainted array's .shape is int, not uint32.
            if node.attr in ("shape", "ndim", "size", "dtype",
                             "nbytes", "itemsize"):
                return False
            return self.expr(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr(node.value)
        if isinstance(node, ast.BinOp):
            return self.expr(node.left) or self.expr(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr(node.operand)
        if isinstance(node, ast.IfExp):
            return self.expr(node.body) or self.expr(node.orelse)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        return False  # Compare, BoolOp, comprehensions, lambdas: no taint

    def learn(self, fn: ast.FunctionDef) -> None:
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                if self.expr(node.value):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            self.names.add(t.id)
            elif isinstance(node, ast.AugAssign):
                if isinstance(node.target, ast.Name) and (
                        self.expr(node.value)
                        or node.target.id in self.names):
                    self.names.add(node.target.id)
            elif isinstance(node, ast.AnnAssign):
                if node.value is not None and self.expr(node.value) and \
                        isinstance(node.target, ast.Name):
                    self.names.add(node.target.id)


class DtypeDiscipline(Rule):
    rule_id = "RPR001"
    name = "dtype-discipline"

    def applies(self, ctx: FileContext) -> bool:
        if "kernel" in ctx.scopes:
            return True
        p = ctx.relpath
        return ("/kernels/" in p or p.startswith("kernels/")
                or p.endswith("core/hashing.py")
                or p.endswith("core/minhash.py"))

    def check(self, ctx: FileContext) -> list[Finding]:
        out: list[Finding] = []
        module_taint = set(KNOWN_UINT32)
        for node in ctx.tree.body:
            if isinstance(node, ast.Assign) and isinstance(
                    node.value, ast.Call) and _is_uint32_cast(node.value):
                for t in node.targets:
                    if isinstance(t, ast.Name):
                        module_taint.add(t.id)
        parents = build_parents(ctx.tree)
        for fn, qual in iter_scopes(ctx.tree):
            taint = _Taint(module_taint)
            taint.learn(fn)
            taint.learn(fn)  # second pass: forward-referenced chains
            for node in ast.walk(fn):
                if not isinstance(node, ast.BinOp):
                    continue
                out.extend(self._check_binop(ctx, node, taint, parents,
                                             qual))
        return out

    def _check_binop(self, ctx, node: ast.BinOp, taint: _Taint,
                     parents, qual: str) -> list[Finding]:
        out: list[Finding] = []
        lt, rt = taint.expr(node.left), taint.expr(node.right)
        if isinstance(node.op, _DIV) and (lt or rt):
            out.append(self.finding(
                ctx, node,
                "division (`/` or `//`) on uint32 hash values breaks "
                "wraparound bit parity; use shifts/masks or cast off "
                "the hash domain explicitly",
                symbol="uint32-division", qualname=qual))
            return out
        if not isinstance(node.op, _ARITH):
            return out  # shifts: a literal shift amount does not promote
        for lit, other in ((node.left, node.right),
                           (node.right, node.left)):
            if is_int_literal(lit) and taint.expr(other):
                if self._wrapped_in_uint32(node, parents):
                    break
                out.append(self.finding(
                    ctx, node,
                    "bare int literal in uint32 arithmetic; wrap it "
                    "(`jnp.uint32(...)`/`np.uint32(...)`) so numpy and "
                    "jnp promote identically",
                    symbol="bare-int-literal", qualname=qual))
                break
        if (lt and _is_int32_operand(node.right)) or \
                (rt and _is_int32_operand(node.left)):
            out.append(self.finding(
                ctx, node,
                "uint32/int32 mixed arithmetic; promotion rules differ "
                "between numpy and jnp — cast both operands to uint32",
                symbol="int32-mix", qualname=qual))
        return out

    @staticmethod
    def _wrapped_in_uint32(node: ast.AST, parents) -> bool:
        """True if the whole BinOp feeds straight into a uint32 cast."""
        p = parents.get(node)
        while isinstance(p, ast.BinOp):
            node, p = p, parents.get(p)
        return (isinstance(p, ast.Call) and _is_uint32_cast(p)
                and node in p.args)
