"""RPR003 recompilation-hazard: shape-stable calls into jitted stages.

PR 7's ~350 ms-p50 serving bug was exactly this: every novel query
document length handed ``compute_signatures`` a fresh ``(D, L)`` shape
and silently jit-recompiled the signature stage per request.  The fix
— signature-invariant ``pad_len`` padding plus power-of-two shape
bucketing — lives in the callers, so nothing stops the next call site
from reintroducing the hazard.  This rule does.

A call into a jitted signature-stage entry point (``compute_arrays``,
``compute_signatures``, ``fused_ingest``, and the byte-ingest chain
``compute_arrays_bytes`` / ``bytes_to_bands`` / ``byte_token_hashes``)
must route its shape-bearing arguments through the bucketing machinery,
any of:

* an explicit ``pad_len=`` keyword at the call site;
* an enclosing function that itself takes/derives ``pad_len`` or a
  pow2/bucket helper (the pipeline's internal staged chain);
* an argument expression built by a ``*pow2*`` / ``*bucket*`` helper.

One-shot batch drivers whose chunk shapes are amortized (a single
compile per run) are grandfathered via the baseline rather than
exempted structurally — new long-lived callers start strict.  Test
files are exempt: parity tests call the stages directly on purpose.
"""
from __future__ import annotations

import ast
import re

from repro.analysis.findings import Finding
from repro.analysis.rules.base import (
    FileContext,
    Rule,
    callee_name,
    iter_scopes,
)

JIT_ENTRY_POINTS = {"compute_arrays", "compute_signatures",
                    "compute_arrays_bytes", "fused_ingest",
                    "bytes_to_bands", "byte_token_hashes"}
_BUCKET_RE = re.compile(r"(pow2|bucket|pad_len)", re.IGNORECASE)


def _has_bucketing_context(fn: ast.FunctionDef) -> bool:
    """Enclosing function takes or derives pad_len/pow2 bucketing."""
    args = fn.args
    for a in (args.posonlyargs + args.args + args.kwonlyargs):
        if _BUCKET_RE.search(a.arg):
            return True
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and _BUCKET_RE.search(t.id):
                    return True
        elif isinstance(node, ast.Call):
            name = callee_name(node)
            if name and _BUCKET_RE.search(name):
                return True
    return False


def _args_use_bucketing(call: ast.Call) -> bool:
    for a in list(call.args) + [k.value for k in call.keywords]:
        for sub in ast.walk(a):
            if isinstance(sub, ast.Call):
                name = callee_name(sub)
                if name and _BUCKET_RE.search(name):
                    return True
            elif isinstance(sub, ast.Name) and _BUCKET_RE.search(sub.id):
                return True
    return False


class RecompilationHazard(Rule):
    rule_id = "RPR003"
    name = "recompilation-hazard"

    def applies(self, ctx: FileContext) -> bool:
        return not ctx.is_test

    def check(self, ctx: FileContext) -> list[Finding]:
        # The defining modules are the implementation, not call sites.
        defined_here = {
            n.name for n in ast.walk(ctx.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        out: list[Finding] = []
        covered: set[ast.Call] = set()
        for fn, qual in iter_scopes(ctx.tree):
            ctx_ok = None  # lazy: only computed if an entry call appears
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call) or node in covered:
                    continue
                covered.add(node)
                name = callee_name(node)
                if name not in JIT_ENTRY_POINTS or name in defined_here:
                    continue
                if any(k.arg == "pad_len" for k in node.keywords):
                    continue
                if ctx_ok is None:
                    ctx_ok = _has_bucketing_context(fn)
                if ctx_ok or _args_use_bucketing(node):
                    continue
                out.append(self.finding(
                    ctx, node,
                    f"jitted entry point `{name}` called without "
                    "pad_len/pow2 shape bucketing; varying operand "
                    "shapes silently recompile per call (the PR 7 "
                    "~350ms-p50 bug, DESIGN.md §9/§10)",
                    symbol=f"unbucketed:{name}", qualname=qual))
        # Module-level calls (scripts) outside any def:
        in_fns = {id(n) for fn, _ in iter_scopes(ctx.tree)
                  for n in ast.walk(fn)}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call) and id(node) not in in_fns:
                name = callee_name(node)
                if (name in JIT_ENTRY_POINTS and name not in defined_here
                        and not any(k.arg == "pad_len"
                                    for k in node.keywords)
                        and not _args_use_bucketing(node)):
                    out.append(self.finding(
                        ctx, node,
                        f"jitted entry point `{name}` called without "
                        "pad_len/pow2 shape bucketing",
                        symbol=f"unbucketed:{name}", qualname=""))
        return out
