"""Per-rule AST visitors.  ``ALL_RULES`` is the driver's registry."""
from repro.analysis.rules.rpr001_dtype import DtypeDiscipline
from repro.analysis.rules.rpr002_purity import QueryPurity
from repro.analysis.rules.rpr003_recompile import RecompilationHazard
from repro.analysis.rules.rpr004_naming import NamingDeprecation
from repro.analysis.rules.rpr005_pallas import PallasSpec

ALL_RULES = [
    DtypeDiscipline(),
    QueryPurity(),
    RecompilationHazard(),
    NamingDeprecation(),
    PallasSpec(),
]

__all__ = ["ALL_RULES"]
