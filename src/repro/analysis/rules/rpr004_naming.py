"""RPR004 naming/deprecation: the blessed API scheme stays blessed.

ROADMAP "API stability" (PR 7) fixed the public verb scheme —
``ingest*`` adds documents to long-lived dedup state, ``compute_*`` is
pure stage computation, ``query*`` / ``view`` / ``probe_*`` /
``frozen_*`` read and never mutate — and demoted the old spellings
(``DedupPipeline.ingest_arrays``, ``ClusterSnapshot.uf``) to
``DeprecationWarning`` shims kept green until the next major
re-anchor.  New code must not grow fresh callers of the shims (they
make the eventual removal a breaking change again), and new public
defs in ``core/`` must not coin off-scheme spellings of the reserved
verbs.

Checks:

* calls to ``ingest_arrays`` (the deprecated ``compute_arrays``);
* ``.uf`` reads on snapshot-shaped receivers (``snap`` / ``snapshot``
  / ``*_snap``) — ``ClusterSnapshot.uf`` is the shim; live handles
  (``self.uf``, ``session.uf``, ``acc.uf``) stay fine;
* public defs in ``src/repro/core/`` whose name contains a reserved
  verb (``ingest`` / ``query`` / ``compute``) as a non-leading token —
  e.g. ``get_query`` or ``run_ingest`` — instead of the scheme prefix.

The shims' own definitions and regression tests suppress inline.
"""
from __future__ import annotations

import ast

from repro.analysis.findings import Finding
from repro.analysis.rules.base import (
    FileContext,
    Rule,
    callee_name,
    enclosing_qualname,
    iter_scopes,
)

DEPRECATED_CALLS = {"ingest_arrays"}
SNAPSHOT_RECEIVERS = {"snap", "snapshot"}
RESERVED_STEMS = {"ingest", "query", "compute"}
SCHEME_PREFIXES = ("ingest", "query", "compute_", "probe_", "frozen_",
                   "view")


class NamingDeprecation(Rule):
    rule_id = "RPR004"
    name = "naming-deprecation"

    def check(self, ctx: FileContext) -> list[Finding]:
        out: list[Finding] = []
        out.extend(self._check_shim_calls(ctx))
        if ("/core/" in ctx.relpath or ctx.relpath.startswith("core/")
                or "core" in ctx.scopes) and not ctx.is_test:
            out.extend(self._check_core_names(ctx))
        return out

    def _check_shim_calls(self, ctx) -> list[Finding]:
        out: list[Finding] = []
        defined_here = {
            n.name for n in ast.walk(ctx.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Call):
                name = callee_name(node)
                if name in DEPRECATED_CALLS and name not in defined_here:
                    out.append(self.finding(
                        ctx, node,
                        f"call to deprecated shim `{name}` (use "
                        "`compute_arrays`; `ingest*` names are reserved "
                        "for entry points that add documents to "
                        "long-lived state)",
                        symbol=f"deprecated-call:{name}",
                        qualname=enclosing_qualname(ctx.tree, node)))
            elif isinstance(node, ast.Attribute) and node.attr == "uf":
                base = node.value
                if isinstance(base, ast.Name) and (
                        base.id in SNAPSHOT_RECEIVERS
                        or base.id.endswith("_snap")):
                    out.append(self.finding(
                        ctx, node,
                        "`ClusterSnapshot.uf` is a DeprecationWarning "
                        "shim; snapshots are pure value objects — use "
                        "`DedupSession.uf` for the live union-find or "
                        "`snapshot.labels` for frozen roots",
                        symbol="deprecated-attr:uf",
                        qualname=enclosing_qualname(ctx.tree, node)))
        return out

    def _check_core_names(self, ctx) -> list[Finding]:
        out: list[Finding] = []
        for fn, qual in iter_scopes(ctx.tree):
            name = fn.name
            if name.startswith("_") or name.startswith(SCHEME_PREFIXES):
                continue
            tokens = name.split("_")
            offending = RESERVED_STEMS.intersection(tokens[1:])
            if offending:
                stem = sorted(offending)[0]
                out.append(self.finding(
                    ctx, fn,
                    f"public def `{name}` in core/ uses reserved verb "
                    f"`{stem}` off-scheme; spell it `{stem}*` (or "
                    "`compute_*`/`query*`/`probe_*` per the blessed "
                    "naming scheme, ROADMAP \"API stability\")",
                    symbol=f"off-scheme:{name}", qualname=qual))
        return out
