"""Lint driver: discovery, suppression, baseline, reporting, CLI.

``python -m repro.analysis [paths...]`` parses every ``.py`` file under
the given paths (default: ``src benchmarks examples tests`` minus the
intentionally-bad fixture corpus), runs the RPR rules, then resolves
each finding through two escape hatches:

* inline suppression — ``# repro-lint: disable=RPR001[,RPR002]`` (or a
  bare ``disable`` for all rules) on the finding's line or on a
  comment line directly above it;
* the committed baseline (``.repro-lint-baseline.json``) of
  grandfathered findings, matched by line-insensitive fingerprint.

Exit status is non-zero iff NEW findings remain.  Suppressed and
baselined counts are always reported so drift stays visible.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys

from repro.analysis.baseline import (
    DEFAULT_BASELINE,
    apply_baseline,
    load_baseline,
    save_baseline,
)
from repro.analysis.findings import Finding
from repro.analysis.rules import ALL_RULES
from repro.analysis.rules.base import FileContext

DEFAULT_PATHS = ("src", "benchmarks", "examples", "tests")
# The bad-fixture corpus is linted on purpose by tests, never by default.
EXCLUDED_PARTS = {"__pycache__", ".git", "fixtures"}

_DISABLE_RE = re.compile(
    r"#\s*repro-lint:\s*disable(?:=([A-Z0-9,\s]+))?(?:\s|$)")
_DISABLE_FILE_RE = re.compile(
    r"#\s*repro-lint:\s*disable-file(?:=([A-Z0-9,\s]+))?(?:\s|$)")


def discover(paths) -> list[str]:
    files: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            files.append(p)
            continue
        for root, dirs, names in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if d not in EXCLUDED_PARTS
                             and not d.startswith("."))
            for n in sorted(names):
                if n.endswith(".py"):
                    files.append(os.path.join(root, n))
    return files


def _parse_rule_set(spec: str | None) -> set[str] | None:
    """None = all rules; else the listed rule ids."""
    if spec is None or not spec.strip():
        return None
    return {s.strip().upper() for s in spec.split(",") if s.strip()}


def _suppressed(finding: Finding, lines: list[str]) -> bool:
    for lineno in (finding.line, finding.line - 1):
        if not (1 <= lineno <= len(lines)):
            continue
        text = lines[lineno - 1]
        if lineno != finding.line and not text.lstrip().startswith("#"):
            continue  # the line above only counts if comment-only
        m = _DISABLE_RE.search(text)
        if m:
            rules = _parse_rule_set(m.group(1))
            if rules is None or finding.rule in rules:
                return True
    return False


def _file_disabled(lines: list[str]) -> set[str] | None:
    """Rules disabled for the whole file ({"*"} = all)."""
    for text in lines[:15]:
        m = _DISABLE_FILE_RE.search(text)
        if m:
            rules = _parse_rule_set(m.group(1))
            return rules if rules is not None else {"*"}
    return None


def lint_file(relpath: str, source: str, *,
              vmem_limit: int = 1 << 20) -> list[Finding]:
    """All findings for one file, with suppressions already applied."""
    ctx = FileContext.parse(relpath, source, vmem_limit=vmem_limit)
    file_off = _file_disabled(ctx.lines)
    findings: list[Finding] = []
    for rule in ALL_RULES:
        if file_off is not None and ("*" in file_off
                                     or rule.rule_id in file_off):
            continue
        if not rule.applies(ctx):
            continue
        findings.extend(rule.check(ctx))
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    for f in findings:
        if _suppressed(f, ctx.lines):
            object.__setattr__(f, "status", "suppressed")
    return findings


def run_analysis(paths=DEFAULT_PATHS, *, baseline_path=DEFAULT_BASELINE,
                 use_baseline: bool = True,
                 vmem_limit: int = 1 << 20,
                 root: str = ".") -> dict:
    """Run the pass; returns the report dict the CLI renders."""
    files = discover([os.path.join(root, p) if not os.path.isabs(p)
                      else p for p in paths])
    findings: list[Finding] = []
    errors: list[str] = []
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        try:
            with open(path, "r", encoding="utf-8") as f:
                source = f.read()
            findings.extend(
                lint_file(rel, source, vmem_limit=vmem_limit))
        except SyntaxError as e:
            errors.append(f"{rel}: syntax error: {e}")
    baseline = {}
    if use_baseline:
        bp = baseline_path if os.path.isabs(baseline_path) else \
            os.path.join(root, baseline_path)
        baseline = load_baseline(bp)
        apply_baseline([f for f in findings if f.status == "new"],
                       baseline)
    new = [f for f in findings if f.status == "new"]
    return {
        "files_checked": len(files),
        "findings": findings,
        "new": new,
        "suppressed": [f for f in findings if f.status == "suppressed"],
        "baselined": [f for f in findings if f.status == "baselined"],
        "errors": errors,
        "baseline_entries": len(baseline),
    }


def _render_text(report: dict, out) -> None:
    for f in report["new"]:
        print(f.render(), file=out)
    for e in report["errors"]:
        print(e, file=out)
    n, s, b = (len(report["new"]), len(report["suppressed"]),
               len(report["baselined"]))
    print(f"repro-lint: {report['files_checked']} files checked — "
          f"{n} new finding{'s' if n != 1 else ''}, "
          f"{b} baselined, {s} suppressed", file=out)


def _render_json(report: dict, out) -> None:
    json.dump({
        "files_checked": report["files_checked"],
        "new": [f.to_json() for f in report["new"]],
        "baselined": [f.to_json() for f in report["baselined"]],
        "suppressed": [f.to_json() for f in report["suppressed"]],
        "errors": report["errors"],
    }, out, indent=2)
    out.write("\n")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-specific static analysis (RPR001-RPR005; "
                    "see DESIGN.md §10)")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files/dirs to lint (default: "
                         f"{' '.join(DEFAULT_PATHS)})")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline file (grandfathered findings)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: report everything as new")
    ap.add_argument("--write-baseline", action="store_true",
                    help="regenerate the baseline from current findings "
                         "and exit 0")
    ap.add_argument("--vmem-limit", type=int, default=1 << 20,
                    help="RPR005 VMEM ceiling in bytes (default 1 MiB; "
                         "DESIGN.md §8 budgets ~530 KiB)")
    ap.add_argument("--root", default=".",
                    help="repo root (paths/baseline resolve against it)")
    args = ap.parse_args(argv)

    paths = args.paths if args.paths else list(DEFAULT_PATHS)
    report = run_analysis(
        paths, baseline_path=args.baseline,
        use_baseline=not args.no_baseline and not args.write_baseline,
        vmem_limit=args.vmem_limit, root=args.root)

    if args.write_baseline:
        bp = args.baseline if os.path.isabs(args.baseline) else \
            os.path.join(args.root, args.baseline)
        old = {}
        try:
            old = load_baseline(bp)
        except ValueError:
            pass
        entries = save_baseline(
            bp, [f for f in report["findings"] if f.status == "new"],
            old)
        print(f"repro-lint: wrote {len(entries)} baseline "
              f"fingerprints to {bp}")
        return 0

    if args.format == "json":
        _render_json(report, sys.stdout)
    else:
        _render_text(report, sys.stdout)
    return 1 if (report["new"] or report["errors"]) else 0
