"""Baseline file: grandfathered findings so CI starts green-but-strict.

The baseline maps finding fingerprints (line-insensitive, see
``findings.Finding.fingerprint``) to ``{"count": N, "reason": ...}``.
A run matches up to ``count`` findings per fingerprint against the
baseline; the (N+1)-th occurrence of the same construct is NEW and
fails the run — adding more of a grandfathered pattern is not free.

``--write-baseline`` regenerates the file from the current findings,
preserving reasons for fingerprints that survive.
"""
from __future__ import annotations

import json
from collections import Counter

from repro.analysis.findings import Finding

DEFAULT_BASELINE = ".repro-lint-baseline.json"
_SCHEMA = 1


def load_baseline(path: str) -> dict[str, dict]:
    try:
        with open(path, "r", encoding="utf-8") as f:
            data = json.load(f)
    except FileNotFoundError:
        return {}
    if data.get("schema") != _SCHEMA:
        raise ValueError(
            f"{path}: unknown baseline schema {data.get('schema')!r} "
            f"(expected {_SCHEMA})")
    return dict(data.get("findings", {}))


def save_baseline(path: str, findings: list[Finding],
                  old: dict[str, dict] | None = None) -> dict[str, dict]:
    """Write a baseline covering ``findings``; keeps old reasons."""
    old = old or {}
    counts = Counter(f.fingerprint for f in findings)
    entries: dict[str, dict] = {}
    for fp in sorted(counts):
        entries[fp] = {
            "count": counts[fp],
            "reason": old.get(fp, {}).get("reason", "grandfathered"),
        }
    with open(path, "w", encoding="utf-8") as f:
        json.dump({"schema": _SCHEMA, "findings": entries}, f, indent=2,
                  sort_keys=True)
        f.write("\n")
    return entries


def apply_baseline(findings: list[Finding],
                   baseline: dict[str, dict]) -> None:
    """Mark findings covered by the baseline (in file order)."""
    used: Counter = Counter()
    for f in findings:
        fp = f.fingerprint
        allowed = int(baseline.get(fp, {}).get("count", 0))
        if used[fp] < allowed:
            used[fp] += 1
            object.__setattr__(f, "status", "baselined")
