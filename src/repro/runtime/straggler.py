"""Straggler detection: per-step timing EMA + z-score flagging.

At pod scale a slow host shows up as a slow *global* step (collectives
synchronize).  The detector keeps an exponential moving mean/variance of
step wall-time and flags steps whose z-score exceeds a threshold; the
mitigation hook is pluggable (real deployment: trigger elastic re-mesh or
within-step work re-balancing; here: structured log + counters that the
FT loop exports).
"""
from __future__ import annotations

from dataclasses import dataclass, field


@dataclass
class StragglerDetector:
    alpha: float = 0.1          # EMA factor
    z_threshold: float = 3.0
    warmup_steps: int = 5
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: list = field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Record a step time; returns True if flagged as straggler."""
        self.n += 1
        if self.n <= self.warmup_steps:
            # Prime the EMA.
            self.mean = (self.mean * (self.n - 1) + seconds) / self.n
            self.var = max(self.var, (seconds - self.mean) ** 2)
            return False
        std = max(self.var**0.5, 1e-6, 0.05 * self.mean)
        z = (seconds - self.mean) / std
        is_straggler = z > self.z_threshold
        if is_straggler:
            self.flagged.append((step, seconds, z))
        else:
            # Only track healthy steps in the EMA (stragglers would
            # poison the baseline).
            d = seconds - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        return is_straggler

    @property
    def num_flagged(self) -> int:
        return len(self.flagged)
