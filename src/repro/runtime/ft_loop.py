"""Fault-tolerant training loop: checkpoint/resume, straggler accounting,
simulated failure injection.

Resumability is by construction: the loop state is (params, opt_state,
step) and the data loader is a pure function of step — a restart restores
the latest checkpoint and continues on the exact batch sequence (tested:
crash-and-resume reproduces the uninterrupted loss trajectory bitwise on
CPU).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax

from repro import checkpoint as ckpt
from repro.runtime.straggler import StragglerDetector


class SimulatedFailure(RuntimeError):
    pass


@dataclass
class FTLoopConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    async_ckpt: bool = True
    fail_at_step: int | None = None     # inject a crash (tests)
    straggler_z: float = 3.0


@dataclass
class FTLoop:
    """Drives (state, batch) -> state train steps with FT plumbing."""

    config: FTLoopConfig
    train_step: Callable[[Any, Any], tuple[Any, dict]]
    batch_fn: Callable[[int], Any]       # step -> batch (pure)
    detector: StragglerDetector = field(default=None)
    pending: Any = None

    def __post_init__(self):
        if self.detector is None:
            self.detector = StragglerDetector(
                z_threshold=self.config.straggler_z)

    def resume_or(self, init_state):
        step = ckpt.latest_step(self.config.ckpt_dir)
        if step is None:
            return init_state, 0
        state = ckpt.restore(self.config.ckpt_dir, step, init_state)
        return state, step

    def _maybe_checkpoint(self, state, step: int, force: bool = False):
        if force or (step > 0 and step % self.config.ckpt_every == 0):
            if self.pending is not None:
                self.pending.result()     # back-pressure: one in flight
                self.pending = None
            fut = ckpt.save(self.config.ckpt_dir, step, state,
                            keep=self.config.keep,
                            async_=self.config.async_ckpt)
            self.pending = fut

    def run(self, init_state, num_steps: int, *, log_every: int = 0):
        """Run to ``num_steps`` total (resuming if checkpoints exist)."""
        state, start = self.resume_or(init_state)
        history = []
        for step in range(start, num_steps):
            if self.config.fail_at_step is not None and (
                    step == self.config.fail_at_step):
                raise SimulatedFailure(f"injected failure at step {step}")
            t0 = time.perf_counter()
            batch = self.batch_fn(step)
            state, metrics = self.train_step(state, batch)
            jax.block_until_ready(jax.tree.leaves(metrics)[0])
            dt = time.perf_counter() - t0
            self.detector.observe(step, dt)
            history.append(
                {k: float(v) for k, v in metrics.items()} | {
                    "step": step, "seconds": dt})
            self._maybe_checkpoint(state, step + 1)
            if log_every and step % log_every == 0:
                m = history[-1]
                print(f"step {step}: " + " ".join(
                    f"{k}={v:.4g}" for k, v in sorted(m.items())
                    if k != "step"))
        self._maybe_checkpoint(state, num_steps, force=True)
        if self.pending is not None:
            self.pending.result()
            self.pending = None
        return state, history
