from repro.runtime.ft_loop import FTLoop, FTLoopConfig, SimulatedFailure
from repro.runtime.straggler import StragglerDetector
from repro.runtime.elastic import plan_remesh, remesh, reshard_tree

__all__ = ["FTLoop", "FTLoopConfig", "SimulatedFailure",
           "StragglerDetector", "plan_remesh", "remesh", "reshard_tree"]
