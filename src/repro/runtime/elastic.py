"""Elastic re-meshing: recompute the mesh and resharding plan after a
device/host failure.

Flow on failure (as deployed): the coordinator detects missing hosts ->
``plan_remesh`` picks the largest valid (data, model) grid over survivors
(keeping the model axis as close as possible so TP groups still fit) ->
checkpoint-restore or live ``jax.device_put`` resharding moves the state
-> training resumes at the same step.  Everything here is exercised on
CPU host devices in tests.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding


@dataclass(frozen=True)
class RemeshPlan:
    old_shape: tuple
    new_shape: tuple
    axis_names: tuple
    n_lost: int

    @property
    def utilization(self) -> float:
        return float(np.prod(self.new_shape)) / (
            np.prod(self.old_shape) or 1)


def plan_remesh(n_survivors: int, old_shape: tuple,
                axis_names: tuple = ("data", "model")) -> RemeshPlan:
    """Largest (data, model) grid with model <= old model parallelism.

    Keeps TP degree a divisor of the old one (weight shards stay aligned,
    avoiding all-to-all resharding of every tensor); spends losses on the
    data axis first — the standard elastic-DP policy.
    """
    old_model = old_shape[-1]
    best = None
    model = old_model
    while model >= 1:
        if old_model % model == 0:
            data = n_survivors // model
            if data >= 1:
                size = data * model
                # Prefer keeping the TP degree (weight shards stay
                # aligned, no all-to-all resharding) unless shrinking it
                # recovers >5% more devices.
                score = size * (1.0 if model == old_model else 0.95)
                if best is None or score > best[0]:
                    best = (score, data, model)
        model //= 2
    assert best is not None, "no valid mesh"
    _, data, model = best
    new_shape = (data, model)
    if len(old_shape) == 3:   # (pod, data, model): fold pods into data
        new_shape = (1, data, model)
    return RemeshPlan(tuple(old_shape), new_shape, tuple(axis_names),
                      n_lost=int(np.prod(old_shape)) - n_survivors)


def remesh(plan: RemeshPlan, surviving_devices) -> Mesh:
    need = int(np.prod(plan.new_shape))
    devs = np.asarray(surviving_devices[:need]).reshape(plan.new_shape)
    return Mesh(devs, plan.axis_names)


def reshard_tree(tree, specs, new_mesh: Mesh):
    """Move a pytree onto the new mesh (device_put with new shardings)."""
    return jax.tree.map(
        lambda x, spec: jax.device_put(x, NamedSharding(new_mesh, spec)),
        tree, specs,
        is_leaf=lambda x: not isinstance(x, (dict, list, tuple)),
    )
