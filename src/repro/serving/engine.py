"""Continuous-batching serving engine (production serving substrate).

Slot-based scheduler over a fixed decode batch: requests queue up,
free slots are filled by prefilling the prompt into the slot's region of
the shared KV cache, every engine step decodes ONE token for all active
slots, finished sequences (EOS or max_tokens) free their slot.  This is
the vLLM-style iteration-level scheduling shape, sized for the assigned
decode cells (fixed cache length, static shapes — XLA-friendly).

Single-host CPU here; on a pod the same engine drives the sharded
decode_step (cache sharded batch->data, heads->model) — slots map to
global batch rows.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.models import lm
from repro.models.config import ModelConfig


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S_p,) int32
    max_tokens: int
    out: list = field(default_factory=list)
    enqueued_at: float = 0.0
    done: bool = False


@dataclass
class EngineStats:
    steps: int = 0
    tokens_out: int = 0
    prefills: int = 0
    batch_occupancy_sum: float = 0.0

    @property
    def mean_occupancy(self) -> float:
        return self.batch_occupancy_sum / max(1, self.steps)


class ServeEngine:
    """Fixed-slot continuous batching over a shared KV cache."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 8,
                 cache_len: int = 256, eos_id: int = 1):
        assert not cfg.encdec, "decoder-only engine"
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.queue: deque[Request] = deque()
        self.active: list[Request | None] = [None] * slots
        self.kv_len = np.zeros(slots, dtype=np.int32)
        self.next_tok = np.zeros(slots, dtype=np.int32)
        self.stats = EngineStats()
        self.cache, _ = lm.make_cache(cfg, slots, cache_len)
        self._rid = 0

        # jitted single-slot prefill (writes into the batched cache) and
        # batched decode.
        def _decode(params, cache, toks, kv_len):
            return lm.decode(cfg, params, cache, toks, kv_len)

        self._decode = jax.jit(_decode)

        def _prefill_one(params, cache, tokens, slot):
            """Prefill one slot: run the prompt, merge its K/V rows."""
            sub_cache = jax.tree.map(
                lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
                cache)
            sub_cache, logits = lm.prefill(cfg, params, tokens[None],
                                           sub_cache)
            cache = jax.tree.map(
                lambda full, sub: jax.lax.dynamic_update_slice_in_dim(
                    full, sub.astype(full.dtype), slot, axis=1),
                cache, sub_cache)
            return cache, logits[0, -1]

        self._prefill_one = jax.jit(_prefill_one,
                                    static_argnames=())

    # -- public API ----------------------------------------------------------

    def submit(self, prompt: np.ndarray, max_tokens: int = 32) -> int:
        self._rid += 1
        self.queue.append(Request(self._rid, np.asarray(prompt, np.int32),
                                  max_tokens, enqueued_at=time.time()))
        return self._rid

    def step(self) -> int:
        """One engine iteration: admit, decode, retire.  Returns #active."""
        # 1. admit queued requests into free slots (prefill).
        for s in range(self.slots):
            if self.active[s] is None and self.queue:
                req = self.queue.popleft()
                prompt = req.prompt[: self.cache_len - req.max_tokens - 1]
                self.cache, last_logits = self._prefill_one(
                    self.params, self.cache, jnp.asarray(prompt),
                    jnp.int32(s))
                self.active[s] = req
                self.kv_len[s] = len(prompt)
                self.next_tok[s] = int(jnp.argmax(last_logits))
                self.stats.prefills += 1

        active_mask = np.array([r is not None for r in self.active])
        n_active = int(active_mask.sum())
        if n_active == 0:
            return 0

        # 2. batched decode of one token for every active slot.
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.next_tok),
            jnp.asarray(self.kv_len))
        new_tok = np.asarray(jnp.argmax(logits[:, 0], axis=-1),
                             dtype=np.int32)

        # 3. commit tokens + retire finished requests.
        for s in range(self.slots):
            req = self.active[s]
            if req is None:
                continue
            req.out.append(int(self.next_tok[s]))
            self.kv_len[s] += 1
            self.stats.tokens_out += 1
            done = (len(req.out) >= req.max_tokens
                    or int(new_tok[s]) == self.eos_id
                    or self.kv_len[s] >= self.cache_len - 1)
            if done:
                req.done = True
                self.active[s] = None
                self.kv_len[s] = 0
            else:
                self.next_tok[s] = int(new_tok[s])
        self.stats.steps += 1
        self.stats.batch_occupancy_sum += n_active / self.slots
        return n_active

    def run_until_drained(self, max_steps: int = 10_000) -> list[Request]:
        finished: list[Request] = []
        seen: set[int] = set()
        all_reqs: dict[int, Request] = {}
        for r in list(self.queue):
            all_reqs[r.rid] = r
        for _ in range(max_steps):
            for r in list(self.queue):
                all_reqs[r.rid] = r
            n = self.step()
            for rid, r in all_reqs.items():
                if r.done and rid not in seen:
                    seen.add(rid)
                    finished.append(r)
            if n == 0 and not self.queue:
                break
        return finished
