"""Serving shells: LM continuous batching + the dedup query service.

Submodules are imported lazily: ``engine`` pulls the model stack
(``repro.models``), which the dedup query service does not need — so
``from repro.serving import DedupQueryService`` stays light.
"""

__all__ = [
    "ServeEngine",
    "Request",
    "EngineStats",
    "DedupQueryService",
    "QueryRequest",
    "QueryServiceStats",
]

_ENGINE = ("ServeEngine", "Request", "EngineStats")
_DEDUP = ("DedupQueryService", "QueryRequest", "QueryServiceStats")


def __getattr__(name: str):
    if name in _ENGINE:
        from repro.serving import engine

        return getattr(engine, name)
    if name in _DEDUP:
        from repro.serving import dedup_service

        return getattr(dedup_service, name)
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")
