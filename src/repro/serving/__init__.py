from repro.serving.engine import EngineStats, Request, ServeEngine

__all__ = ["ServeEngine", "Request", "EngineStats"]
